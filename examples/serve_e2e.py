"""End-to-end serving driver: plan -> deploy -> route -> serve.

The paper's pipeline in one script:
  1. AGH plans the heterogeneous fleet (model x tier x TP/PP x routing).
  2. Each planned (model, tier) pair is deployed as a serving Engine
     (smoke-scale JAX model standing in for the catalog entry on CPU).
  3. A batch of mixed-type requests is routed per the planner's fractions
     and served (real prefill + autoregressive decode), reporting TTFT and
     per-type SLO attainment against the plan's delay model.

    PYTHONPATH=src python examples/serve_e2e.py [--requests 24]
"""
import argparse
import time

import jax
import numpy as np

from repro import plan
from repro.configs import get_config
from repro.core import default_instance
from repro.core.bridge import to_deployment
from repro.models import decoder
from repro.serving.engine import Engine, Request

# smoke-scale stand-ins for the planner's model catalog
STANDIN = {"llama3-1b": "qwen2-0.5b", "llama3-3b": "qwen2-0.5b",
           "llama3-8b": "qwen2-1.5b", "llama3-11b": "qwen2-1.5b",
           "llama3-34b": "qwen2-1.5b", "llama3-70b": "qwen2-72b"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    # --- 1. plan ---------------------------------------------------------
    inst = default_instance()
    res = plan("agh", instance=inst)
    sol = res.solution
    spec = to_deployment(inst, sol)
    print(f"[plan] AGH in {res.wall_s:.2f}s -> "
          f"{len(spec.pairs)} deployed pairs")
    for p in spec.pairs:
        print(f"  {p.model} @ {p.tier} TP={p.tp} PP={p.pp} "
              f"chips={p.n_chips} routing={p.routing}")

    # --- 2. deploy -------------------------------------------------------
    engines = {}
    rng_k = jax.random.PRNGKey(0)
    for p in spec.pairs:
        cfg = get_config(STANDIN.get(p.model, "qwen2-0.5b")).smoke()
        params = decoder.init_params(rng_k, cfg)
        engines[(p.model, p.tier)] = Engine(
            cfg, params, max_len=args.prompt_len + args.new_tokens + 8,
            max_batch=args.requests)
    print(f"[deploy] {len(engines)} engines up")

    # --- 3. route + serve -------------------------------------------------
    rng = np.random.default_rng(0)
    lam = inst.lam / inst.lam.sum()
    types = rng.choice(inst.I, size=args.requests, p=lam)
    per_engine: dict = {k: [] for k in engines}
    for rid, ti in enumerate(types):
        qname = inst.query_names[ti]
        # route by the planner's fractions for this type
        pairs = [(p, p.routing.get(qname, 0.0)) for p in spec.pairs]
        weights = np.array([w for _, w in pairs])
        if weights.sum() <= 0:
            continue
        pick = pairs[rng.choice(len(pairs), p=weights / weights.sum())][0]
        vocab = engines[(pick.model, pick.tier)].cfg.vocab_size
        per_engine[(pick.model, pick.tier)].append((qname, Request(
            rid=rid,
            prompt=rng.integers(1, vocab, args.prompt_len).astype(np.int32),
            max_new_tokens=args.new_tokens)))

    t0 = time.perf_counter()
    ttfts: dict[str, list[float]] = {}
    total_toks = 0
    for key, items in per_engine.items():
        if not items:
            continue
        reqs = [r for _, r in items]
        engines[key].generate(reqs)
        for (qname, r) in items:
            ttfts.setdefault(qname, []).append(r.first_token_s)
            total_toks += len(r.output)
    wall = time.perf_counter() - t0
    print(f"[serve] {args.requests} requests, {total_toks} tokens "
          f"in {wall:.2f}s ({total_toks/wall:.1f} tok/s)")
    for i, qname in enumerate(inst.query_names):
        if qname in ttfts:
            print(f"  {qname:14s} TTFT p50={np.median(ttfts[qname])*1e3:6.1f}ms"
                  f"  (plan SLO {inst.Delta[i]:.1f}s)")


if __name__ == "__main__":
    main()
