"""Train a ~100M-parameter model for a few hundred steps on CPU.

Uses the qwen2-0.5b family at reduced width (~100M params) with the
synthetic packed-token pipeline, AdamW (warmup + cosine), remat, and
checkpointing — the full training substrate end to end.

    PYTHONPATH=src python examples/train_demo.py [--steps 200]
"""
import argparse
import dataclasses

from repro.configs import get_config
from repro.training.data import DataConfig, PackedStream
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_demo")
    args = ap.parse_args()

    # ~100M params: 12 layers x d512 on the qwen2 family, 32k vocab.
    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"),
        name="qwen2-100m", n_layers=12, d_model=512, n_heads=8,
        n_kv_heads=2, head_dim=64, d_ff=2048, vocab_size=32768,
        dtype="float32", loss_chunk=128)
    n = cfg.param_count()
    print(f"model: {cfg.name}  params={n/1e6:.1f}M")

    stream = PackedStream(DataConfig(vocab_size=cfg.vocab_size,
                                     seq_len=args.seq,
                                     batch_size=args.batch))
    opt = AdamWConfig(lr=6e-4, total_steps=args.steps,
                      warmup_steps=max(10, args.steps // 20))
    _, hist = train(cfg, opt, stream, args.steps, log_every=10,
                    ckpt_path=args.ckpt, ckpt_every=max(50, args.steps // 2))
    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{args.steps} steps ({hist[-1]['wall_s']:.0f}s)")
    assert hist[-1]["loss"] < hist[0]["loss"], "training failed to learn"
    print(f"checkpoint written to {args.ckpt}")


if __name__ == "__main__":
    main()
