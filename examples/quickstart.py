"""Quickstart: plan a heterogeneous serving fleet through the unified
planner API in <5 seconds.

    PYTHONPATH=src python examples/quickstart.py

Shows the whole surface: named scenario specs, the solver registry, the
structured `PlanResult` (cost breakdown, per-constraint slack, solver
diagnostics), and warm-started replanning with `PlanSession`.
"""
import numpy as np

from repro import PlanSession, list_scenarios, plan, scenario, solver_names
from repro.core import evaluate
from repro.core.bridge import to_deployment


def main() -> None:
    # The paper's base scenario: 6 query types (Azure-trace-calibrated),
    # 6 Llama-3.x models, 10 GPU tiers, $100/day budget.
    spec = scenario("paper-default")
    inst = spec.build()
    print("Registered solvers:", ", ".join(solver_names()))
    print("Registered scenarios:", ", ".join(list_scenarios()))
    print(f"Scenario '{spec.name}': {inst.I} query types, "
          f"{inst.J} models, {inst.K} tiers")

    for solver in ("gh", "agh"):
        res = plan(solver, instance=inst)
        cb = res.cost_breakdown
        print(f"\n{solver}: solved in {res.wall_s*1e3:.0f} ms, "
              f"objective ${res.objective:.2f} "
              f"(rental ${cb['rental']:.2f} + penalties "
              f"${cb['delay_penalty'] + cb['unmet_penalty']:.2f}), "
              f"feasible={res.feasible}")
        print("  binding slack: " + ", ".join(
            f"{k}={v:.3g}" for k, v in sorted(res.slack.items(),
                                              key=lambda kv: kv[1])[:3]))
        for p in to_deployment(inst, res.solution).pairs:
            routed = ", ".join(f"{q}:{frac:.0%}"
                               for q, frac in p.routing.items())
            print(f"  {p.model} on {p.tier}: TP={p.tp} PP={p.pp} "
                  f"({p.n_chips} GPUs) <- {routed}")

    # The XLA engine: same AGH, multi-start as one batched lane axis on
    # the accelerator, numpy path as the oracle (objective can only
    # match or beat it).  jax is optional — fall back gracefully.
    try:
        from repro import EngineUnavailableError
        res_x = plan("agh", instance=inst, engine="xla")
        print(f"\nagh on engine='xla': ${res_x.objective:.2f} in "
              f"{res_x.wall_s*1e3:.0f} ms "
              f"({res_x.diagnostics.get('orderings_evaluated')} orderings "
              f"batched, {res_x.diagnostics.get('device_calls_phase2')} "
              f"phase-2 device calls)")
    except EngineUnavailableError as exc:
        # No jax in this environment: the numpy default is unaffected.
        print(f"\nengine='xla' unavailable ({exc}); numpy engine remains "
              "the default")

    # Warm-started replanning: demand drifts, the session replans from
    # its incumbent instead of re-solving cold.
    ses = PlanSession()
    ses.plan(instance=inst)
    drifted = inst.with_lam(inst.lam * np.linspace(1.1, 0.9, inst.I))
    res = ses.replan(instance=drifted)
    print(f"\nreplan after demand drift: ${res.objective:.2f} in "
          f"{res.wall_s*1e3:.0f} ms (warm-started="
          f"{res.diagnostics.get('warm_started')}, "
          f"{res.diagnostics.get('orderings_evaluated')} orderings)")

    # Two-stage robustness check (paper §5.2, small S for the demo).
    res = plan("agh", instance=inst)
    ev = evaluate(inst, res.solution, S=50, u_cap=np.full(6, 0.02))
    print(f"\nAGH under 50 perturbed scenarios: expected cost "
          f"${ev.expected_cost:.1f}, SLO violations "
          f"{ev.violation_rate:.1%}")


if __name__ == "__main__":
    main()
