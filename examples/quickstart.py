"""Quickstart: plan a heterogeneous serving fleet with the paper's
allocator in <5 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (agh, default_instance, evaluate, gh, objective,
                        provisioning_cost)
from repro.core.bridge import to_deployment


def main() -> None:
    # The paper's base instance: 6 query types, 6 Llama-3.x models,
    # 10 GPU tiers (hardware x precision), $100/day budget.
    inst = default_instance()
    print("Query types:", list(inst.query_names))
    print("Models:", list(inst.model_names))
    print(f"Tiers: {len(inst.tier_names)} (e.g. {inst.tier_names[:3]})")

    for solver in (gh, agh):
        sol = solver(inst)
        print(f"\n{sol.method}: solved in {sol.runtime_s*1e3:.0f} ms, "
              f"objective ${objective(inst, sol):.2f}, "
              f"stage-1 ${provisioning_cost(inst, sol):.2f}, "
              f"unmet max {sol.u.max():.1%}")
        for p in to_deployment(inst, sol).pairs:
            routed = ", ".join(f"{q}:{frac:.0%}" for q, frac in p.routing.items())
            print(f"  {p.model} on {p.tier}: TP={p.tp} PP={p.pp} "
                  f"({p.n_chips} GPUs) <- {routed}")

    # Two-stage robustness check (paper §5.2, small S for the demo).
    sol = agh(inst)
    res = evaluate(inst, sol, S=50, u_cap=np.full(6, 0.02))
    print(f"\nAGH under 50 perturbed scenarios: expected cost "
          f"${res.expected_cost:.1f}, SLO violations "
          f"{res.violation_rate:.1%}")


if __name__ == "__main__":
    main()
