"""Rolling-horizon replay on the synthetic Azure-style diurnal trace
(paper §5.3, Table 5 / Fig. 6 at demo scale), on the unified planner API.

Compares AGH-static vs AGH-5min, where the 5-minute variant replans
through a `PlanSession` — every window after the first warm-starts from
the session incumbent instead of running a cold multi-start.

    PYTHONPATH=src python examples/rolling_replay.py [--windows 96]
"""
import argparse

import numpy as np

from repro import PlanOptions, PlanSession, scenario
from repro.core.rolling import rolling
from repro.core.trace import peak_to_trough


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=96)
    ap.add_argument("--day", default="busy", choices=["busy", "volatile"])
    args = ap.parse_args()

    spec = scenario("azure-diurnal" if args.day == "busy" else "bursty",
                    n_windows=args.windows)
    inst = spec.build()
    path = spec.demand_path(inst)
    print(f"trace: {args.windows} windows, "
          f"peak/trough = {peak_to_trough(path[:, 0] / inst.lam[0]):.1f}x")

    opts = PlanOptions(restarts=1, patience=2)
    r_static = rolling(inst, path, PlanSession(options=opts),
                       replan_every=None)
    session = PlanSession(options=opts)
    r_roll = rolling(inst, path, session, replan_every=4)

    print(f"\n{'':14s}{'mean/win':>10s}{'total':>12s}{'viol':>8s}{'replans':>9s}")
    for name, r in (("AGH-static", r_static), ("AGH-5min", r_roll)):
        print(f"{name:14s}{r.mean_window_cost:10.2f}{r.total_cost:12.1f}"
              f"{100*r.violation_rate:7.1f}%{r.replans:9d}")
    print(f"session: {session.plans} plans, "
          f"{session.warm_replans} warm replans")

    # coarse ASCII profile of per-window cost (static)
    c = r_static.per_window_cost
    q = np.quantile(c, [0, .5, 1])
    print(f"\nper-window cost (static): min={q[0]:.2f} med={q[1]:.2f} "
          f"max={q[2]:.2f}")
    bins = (c / max(c.max(), 1e-9) * 40).astype(int)
    for i in range(0, len(c), max(1, len(c) // 24)):
        print(f"  w{i:03d} {'#' * bins[i]}")


if __name__ == "__main__":
    main()
