"""Jitted wrapper for the Mamba2 SSD scan kernel."""
from __future__ import annotations

import jax

from .kernel import ssm_scan as _kernel
from .ref import ssm_scan_ref


def ssm_scan(x, Bm, Cm, dt, A, D, use_pallas: bool = True, chunk: int = 128):
    if not use_pallas:
        return ssm_scan_ref(x, Bm, Cm, dt, A, D)
    interpret = jax.default_backend() != "tpu"
    return _kernel(x, Bm, Cm, dt, A, D, chunk=chunk, interpret=interpret)
