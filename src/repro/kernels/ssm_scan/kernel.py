"""Mamba2 SSD chunked scan — Pallas TPU kernel.

Grid: (B, nh, T/chunk) with the chunk sweep sequential; the carried SSM
state S [hp, N] lives in VMEM scratch across chunk steps. Each step is three
MXU matmuls (intra-chunk kernel, carry read-out, state update) over a
[chunk, hp/N]-tiled VMEM working set — the TPU-native form of the paper's
"recurrent-scan sharding" substrate for SSM/hybrid architectures.

Math identical to models/mamba2.py (scalar-per-head decay SSD):
    S_t = exp(dt_t A) S_{t-1} + dt_t x_t B_t^T,   y_t = S_t C_t + D x_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params under the old TPU-prefixed name.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(x_ref, b_ref, c_ref, dt_ref, a_ref, d_ref, y_ref, s_ref,
            *, n_chunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)       # [Q, hp]
    Bm = b_ref[0].astype(jnp.float32)            # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)            # [Q, N]
    dt = dt_ref[0, :, 0].astype(jnp.float32)     # [Q]
    A = a_ref[0]                                  # scalar (per head)

    la = dt * A                                   # log decay, [Q]
    cum = jnp.cumsum(la)                          # inclusive
    # intra-chunk kernel M[t,s] = exp(cum_t - cum_s) * (C_t . B_s) * dt_s
    rel = cum[:, None] - cum[None, :]
    Q = chunk
    causal = jnp.tril(jnp.ones((Q, Q), jnp.float32))
    decay = jnp.exp(rel) * causal
    cb = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)
    M = decay * cb * dt[None, :]
    y = jnp.dot(M, x, preferred_element_type=jnp.float32)     # [Q, hp]
    # inter-chunk carry: y_t += C_t . (exp(cum_t) * S_prev)    S: [hp, N]
    y = y + jnp.exp(cum)[:, None] * jnp.dot(
        Cm, s_ref[...].T, preferred_element_type=jnp.float32)
    # state update: S' = exp(cum_Q) S + sum_s exp(cum_Q - cum_s) dt_s x_s B_s^T
    tail = jnp.exp(cum[-1] - cum) * dt                         # [Q]
    s_ref[...] = (jnp.exp(cum[-1]) * s_ref[...]
                  + jnp.dot((tail[:, None] * x).T, Bm,
                            preferred_element_type=jnp.float32))
    y = y + d_ref[0] * x
    y_ref[0, :, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan(x: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray,
             dt: jnp.ndarray, A: jnp.ndarray, D: jnp.ndarray,
             chunk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """x: [B, T, nh, hp]; Bm, Cm: [B, T, N]; dt: [B, T, nh];
    A, D: [nh]. Returns y: [B, T, nh, hp]."""
    B, T, nh, hp = x.shape
    N = Bm.shape[-1]
    ch = min(chunk, T)
    assert T % ch == 0
    n_chunks = T // ch
    grid = (B, nh, n_chunks)

    return pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks, chunk=ch),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ch, 1, hp), lambda b, h, ic: (b, ic, h, 0)),
            pl.BlockSpec((1, ch, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, ch, N), lambda b, h, ic: (b, ic, 0)),
            pl.BlockSpec((1, ch, 1), lambda b, h, ic: (b, ic, h)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
        ],
        out_specs=pl.BlockSpec((1, ch, 1, hp), lambda b, h, ic: (b, ic, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, nh, hp), x.dtype),
        scratch_shapes=[pltpu.VMEM((hp, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, Bm, Cm, dt, A.astype(jnp.float32), D.astype(jnp.float32))
