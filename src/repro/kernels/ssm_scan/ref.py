"""Pure-jnp oracle: naive per-step SSD recurrence (exact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x, Bm, Cm, dt, A, D):
    """x: [B,T,nh,hp]; Bm,Cm: [B,T,N]; dt: [B,T,nh]; A,D: [nh]."""
    B, T, nh, hp = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(S, t):
        xt, Bt, Ct, dtt = t
        a = jnp.exp(dtt * A)                       # [B, nh]
        S = (S * a[..., None, None]
             + dtt[..., None, None] * xt[..., None] * Bt[:, None, None, :])
        y = jnp.einsum("bhpn,bn->bhp", S, Ct)
        return S, y

    S0 = jnp.zeros((B, nh, hp, N), jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2), dtf.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, S0, xs)
    y = ys.transpose(1, 0, 2, 3) + D[None, None, :, None] * xf
    return y.astype(x.dtype)
