# Pallas TPU kernels for the serving hot spots (prefill attention, decode
# attention, Mamba2 SSD scan, RWKV6 WKV recurrence). Each subpackage ships
# kernel.py (pl.pallas_call + BlockSpec VMEM tiling), ops.py (jit wrapper,
# interpret mode on CPU), ref.py (pure-jnp oracle used by tests and as the
# XLA path in the 512-device dry-run).
