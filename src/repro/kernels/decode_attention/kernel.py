"""Flash-decode attention — Pallas TPU kernel for the memory-bandwidth-bound
decode phase (the paper's `d_comp = tau * B * nu / BW` regime).

One query token per sequence attends against a long KV cache. The cache
sweep is the sequential grid dim; per-step the kernel streams one
(block_k, hd) K/V tile through VMEM and maintains the online-softmax state
in scratch — the HBM traffic is exactly one pass over the cache, which is
what makes decode bandwidth-bound.

All H query heads of one KV group are processed together as the sublane dim
of a [G, hd] x [hd, bk] MXU matmul (GQA-packed flash-decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params under the old TPU-prefixed name.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(k_pos_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)          # [bk, hd]
    kp = k_pos_ref[...]                          # [bk]
    pos = pos_ref[0]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    mask = kp <= pos                             # causal vs current position
    s = jnp.where(mask[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     k_pos: jnp.ndarray, pos: jnp.ndarray,
                     block_k: int = 512, interpret: bool = True):
    """q: [B, KV, G, hd] (one token, GQA-packed); k, v: [B, KV, S, hd];
    k_pos: [S] absolute positions (ring caches pass their slot->pos map);
    pos: [] int32 current decode position. Returns [B, KV, G, hd]."""
    B, KV, G, hd = q.shape
    S = k.shape[2]
    bk = min(block_k, S)
    assert S % bk == 0
    n_k = S // bk
    grid = (B, KV, n_k)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk,), lambda b, h, ik: (ik,)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(k_pos, pos.reshape(1), q, k, v)
