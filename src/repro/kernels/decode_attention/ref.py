"""Pure-jnp oracle for flash-decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k, v, k_pos, pos):
    """q: [B, KV, G, hd]; k, v: [B, KV, S, hd]; k_pos: [S]; pos: []."""
    s = jnp.einsum("bkgh,bksh->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (q.shape[-1] ** -0.5)
    mask = k_pos <= pos
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksh->bkgh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
