"""Jitted wrapper: Pallas flash-decode on TPU, interpret mode or jnp oracle
on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import decode_attention as _kernel
from .ref import decode_attention_ref


def decode_attention(q, k, v, k_pos=None, pos=None, use_pallas: bool = True,
                     block_k: int = 512):
    S = k.shape[2]
    if k_pos is None:
        k_pos = jnp.arange(S, dtype=jnp.int32)
    if pos is None:
        pos = jnp.int32(S - 1)
    if not use_pallas:
        return decode_attention_ref(q, k, v, k_pos, pos)
    interpret = jax.default_backend() != "tpu"
    return _kernel(q, k, v, k_pos, pos, block_k=block_k, interpret=interpret)
