"""Jitted public wrapper: kernel on TPU, interpret-mode kernel or jnp oracle
on CPU (`use_pallas=False` falls back to the oracle — the XLA path used by
the 512-device dry-run)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import flash_attention as _kernel
from .ref import attention_ref


def flash_attention(q, k, v, q_pos=None, k_pos=None, window: int = 0,
                    use_pallas: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q: [B, H, Tq, hd]; k, v: [B, KV, Tk, hd]."""
    Tq, Tk = q.shape[2], k.shape[2]
    if q_pos is None:
        q_pos = jnp.arange(Tq, dtype=jnp.int32)
    if k_pos is None:
        k_pos = jnp.arange(Tk, dtype=jnp.int32)
    if not use_pallas:
        return attention_ref(q, k, v, q_pos, k_pos, window=window)
    interpret = jax.default_backend() != "tpu"
    return _kernel(q, k, v, q_pos, k_pos, window=window,
                   block_q=block_q, block_k=block_k, interpret=interpret)
