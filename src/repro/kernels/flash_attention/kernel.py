"""Flash attention (prefill) — Pallas TPU kernel.

Grid: (B, H, Tq/block_q, Tk/block_k); the last grid dim is the sequential
K sweep, with the online-softmax running state (m, l, acc) held in VMEM
scratch across K steps. Blocks are MXU-aligned (block_q, block_k multiples
of 128 at full size; head_dim is the lane dim).

Masking is position-based (causal + optional sliding window), driven by
explicit q_pos / k_pos vectors so the same kernel serves ordinary prefill
and ring-buffer sliding-window caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params under the old TPU-prefixed name.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams

NEG_INF = -1e30


def _kernel(q_pos_ref, k_pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, window: int, n_k: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)            # [bk, hd]
    qp = q_pos_ref[...]                            # [bq]
    kp = k_pos_ref[...]                            # [bk]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    mask = kp[None, :] <= qp[:, None]
    if window > 0:
        mask &= kp[None, :] > qp[:, None] - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q: [B, H, Tq, hd]; k, v: [B, KV, Tk, hd] (GQA: H % KV == 0);
    q_pos: [Tq] int32; k_pos: [Tk] int32. Returns [B, H, Tq, hd]."""
    B, H, Tq, hd = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    assert Tq % bq == 0 and Tk % bk == 0
    n_q, n_k = Tq // bq, Tk // bk
    grid = (B, H, n_q, n_k)

    return pl.pallas_call(
        functools.partial(_kernel, window=window, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq,), lambda b, h, iq, ik: (iq,)),
            pl.BlockSpec((bk,), lambda b, h, iq, ik: (ik,)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_pos, k_pos, q, k, v)
