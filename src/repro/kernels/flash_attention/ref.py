"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                  window: int = 0) -> jnp.ndarray:
    """Same layout as the kernel: q [B,H,Tq,hd], k/v [B,KV,Tk,hd]."""
    B, H, Tq, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, Tq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qf, kf) * (hd ** -0.5)
    mask = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, vf)
    return o.reshape(B, H, Tq, hd).astype(q.dtype)
