"""RWKV6 WKV recurrence — Pallas TPU kernel (data-dependent per-channel
decay, chunked closed form).

Grid: (B, H, T/chunk), chunk sweep sequential with the [hd, hd] state in
VMEM scratch. Because RWKV6's decay is per-CHANNEL (a [hd] vector each
step, not a scalar), the intra-chunk term carries a [Q, Q, hd] decay tensor
— kept tile-resident (chunk=64, hd=64 -> 1 MB fp32) so it never leaves
VMEM. Exact same math as models/rwkv6.py:

    S_t = diag(w_t) S_{t-1} + k_t v_t^T,  y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 ships the TPU compiler params under the old TPU-prefixed name.
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, :, 0].astype(jnp.float32)       # [Q, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)
    v = v_ref[0, :, 0].astype(jnp.float32)
    lw = lw_ref[0, :, 0].astype(jnp.float32)     # log decay, [Q, hd]
    u = u_ref[0].astype(jnp.float32)             # [hd]

    Q = chunk
    cum = jnp.cumsum(lw, axis=0)                 # inclusive [Q, hd]
    cum_excl = cum - lw
    # intra-chunk (s < t): dec[t,s,:] = exp(cum_excl[t] - cum[s])
    rel = cum_excl[:, None, :] - cum[None, :, :]         # [Q, Q, hd]
    causal = jnp.tril(jnp.ones((Q, Q), jnp.float32), k=-1)
    dec = jnp.exp(rel) * causal[:, :, None]
    att = jnp.einsum("tk,tsk,sk->ts", r, dec, k)         # [Q, Q]
    y = jnp.dot(att, v, preferred_element_type=jnp.float32)
    # bonus diagonal (s = t)
    bonus = jnp.sum(r * u[None, :] * k, axis=-1)         # [Q]
    y = y + bonus[:, None] * v
    # carry from previous state
    y = y + jnp.dot(r * jnp.exp(cum_excl), s_ref[...],
                    preferred_element_type=jnp.float32)
    # state update
    tail = jnp.exp(cum[-1:, :] - cum)                    # [Q, hd]
    s_ref[...] = (jnp.exp(cum[-1])[:, None] * s_ref[...]
                  + jnp.dot((tail * k).T, v,
                            preferred_element_type=jnp.float32))
    y_ref[0, :, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              lw: jnp.ndarray, u: jnp.ndarray,
              chunk: int = 64, interpret: bool = True) -> jnp.ndarray:
    """r, k, v, lw: [B, T, H, hd] (lw = log decay, < 0); u: [H, hd].
    Returns y: [B, T, H, hd]."""
    B, T, H, hd = r.shape
    ch = min(chunk, T)
    assert T % ch == 0
    grid = (B, H, T // ch)

    spec = pl.BlockSpec((1, ch, 1, hd), lambda b, h, ic: (b, ic, h, 0))
    return pl.pallas_call(
        functools.partial(_kernel, chunk=ch),
        grid=grid,
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, hd), lambda b, h, ic: (h, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, H, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, lw, u)
