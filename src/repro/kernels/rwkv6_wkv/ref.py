"""Pure-jnp oracle: naive per-step WKV6 recurrence (exact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_wkv_ref(r, k, v, lw, u):
    """r,k,v,lw: [B,T,H,hd]; u: [H,hd]."""
    B, T, H, hd = r.shape
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(lw.astype(jnp.float32))          # decay in (0,1)

    def step(S, t):
        rt, kt, vt, wt = t                       # [B,H,hd]
        kv = kt[..., None] * vt[..., None, :]    # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rf, kf, vf, w))
    _, ys = jax.lax.scan(step, S0, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype)
