"""Jitted wrapper for the RWKV6 WKV kernel."""
from __future__ import annotations

import jax

from .kernel import rwkv6_wkv as _kernel
from .ref import rwkv6_wkv_ref


def rwkv6_wkv(r, k, v, lw, u, use_pallas: bool = True, chunk: int = 64):
    if not use_pallas:
        return rwkv6_wkv_ref(r, k, v, lw, u)
    interpret = jax.default_backend() != "tpu"
    return _kernel(r, k, v, lw, u, chunk=chunk, interpret=interpret)
