"""Offline analysis tooling: model cost estimators and the static
invariant checker (`repro.analysis.lint`)."""
