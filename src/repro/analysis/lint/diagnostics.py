"""Diagnostic and rule primitives of the invariant checker.

A `Rule` is a stable code + one-line contract statement; a `Diagnostic`
is one finding pinned to ``path:line:col``.  Baselines match findings by
*fingerprint* — a hash of (path, rule, normalized source line, occurrence
index) — so a baseline survives unrelated edits that shift line numbers
but expires when the offending line itself changes.
"""
from __future__ import annotations

import dataclasses
import hashlib


@dataclasses.dataclass(frozen=True)
class Rule:
    """One checkable invariant: stable code, short name, contract text."""
    code: str           # "RPR101"
    name: str           # "unsanctioned-state-write"
    summary: str        # one-line contract statement

    def __post_init__(self) -> None:
        if not (self.code.startswith("RPR") and self.code[3:].isdigit()):
            raise ValueError(f"rule codes are RPR<digits>, got {self.code!r}")


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding: ``path:line:col: CODE message``."""
    path: str
    line: int
    col: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


def fingerprint(diag: Diagnostic, line_text: str, occurrence: int) -> str:
    """Stable baseline key for `diag`.

    ``line_text`` is the diagnostic's source line (stripped, so pure
    re-indentation does not expire a baseline); ``occurrence`` counts
    identical (path, rule, line_text) triples from the top of the file,
    disambiguating repeated findings on identical lines.
    """
    payload = f"{diag.path}\x1f{diag.rule}\x1f{line_text.strip()}" \
              f"\x1f{occurrence}"
    return hashlib.sha256(payload.encode("utf-8")).hexdigest()[:16]
