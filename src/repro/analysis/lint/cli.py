"""CLI: ``python -m repro.analysis.lint [paths] [--select ...] ...``.

Exit codes: 0 = clean (possibly via suppressions/baseline), 1 = at
least one unsuppressed diagnostic, 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Sequence

from .registry import all_rules
from .runner import run_paths, write_baseline


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="AST-based invariant checker for the repro engine "
                    "(state-mutation, determinism, f64 dtype, jit "
                    "purity).")
    p.add_argument("paths", nargs="*", default=["src"],
                   help="files or directories to lint (default: src)")
    p.add_argument("--select", default=None, metavar="RULE,...",
                   help="only run rules matching these codes/prefixes "
                        "(e.g. RPR1,RPR203)")
    p.add_argument("--baseline", default=None, metavar="FILE",
                   help="JSON baseline of accepted findings to ignore")
    p.add_argument("--write-baseline", default=None, metavar="FILE",
                   help="write the current finding set as a baseline "
                        "and exit 0")
    p.add_argument("--summary-json", default=None, metavar="FILE",
                   help="dump the run summary (counts per rule) as JSON")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="summary only, no per-finding lines")
    return p


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for r in all_rules():
            print(f"{r.code}  {r.name:32s} {r.summary}")
        return 0

    select = args.select.split(",") if args.select else None
    result = run_paths(args.paths, select=select, baseline=args.baseline)

    if args.write_baseline:
        write_baseline(result, args.write_baseline)
        print(f"repro-lint: wrote baseline "
              f"({len(result.new_fingerprints)} fingerprints) to "
              f"{args.write_baseline}")
        return 0

    if not args.quiet:
        for d in result.diagnostics:
            print(d.format())
    s = result.summary()
    if args.summary_json:
        with open(args.summary_json, "w", encoding="utf-8") as fh:
            json.dump(s, fh, indent=2)
            fh.write("\n")
    print(f"repro-lint: {s['diagnostics']} diagnostic(s), "
          f"{s['suppressed']} suppressed, {s['baselined']} baselined "
          f"— {s['files_checked']} file(s) checked", file=sys.stderr)
    return result.exit_code


if __name__ == "__main__":     # pragma: no cover
    raise SystemExit(main())
