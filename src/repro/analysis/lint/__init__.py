"""AST-based invariant checker for the repro engine.

Static enforcement of the contracts the equivalence suites check
dynamically: sanctioned State/DestCache mutation (RPR1xx), deterministic
engine paths (RPR2xx), f64 dtype discipline in the xla tier (RPR3xx),
and jit/pallas trace purity (RPR4xx).  See core/README.md "Invariants &
static enforcement" for the contract-to-rule map and the suppression
policy.

Usage::

    python -m repro.analysis.lint src/
    python -m repro.analysis.lint --select RPR101,RPR2 src/repro/core/
    python -m repro.analysis.lint --list-rules

Programmatic: `run_paths` / `lint_source` return structured reports.
"""
from .diagnostics import Diagnostic, Rule
from .registry import (BaseChecker, FileContext, all_checkers, all_rules,
                       register_checker)
from .runner import (LintResult, lint_file, lint_source, run_paths,
                     write_baseline)

__all__ = [
    "BaseChecker", "Diagnostic", "FileContext", "LintResult", "Rule",
    "all_checkers", "all_rules", "lint_file", "lint_source",
    "register_checker", "run_paths", "write_baseline",
]
