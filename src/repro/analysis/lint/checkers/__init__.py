"""Builtin checker passes.  Importing this package registers all four
(state-mutation, determinism, dtype, jit-purity) with the registry."""
from . import determinism, dtype, jit_purity, state_mutation
