"""f64 dtype discipline (RPR301-303) in the XLA tier and the kernels.

The numpy oracle runs in float64 and the xla engine's <=-objective
contract leaves no room for f32 rounding in ranking keys — but jax
*defaults* to float32, so any implicit-dtype `jnp` construction is a
latent precision downgrade that only fires where the global x64 flag is
not set (exactly the situation in an embedding application).  Hence:

* RPR301 — `jnp` array constructions in ``core/xla/`` and ``kernels/``
  must pin a dtype, either by keyword or in the positional dtype slot
  (``jnp.zeros(shape, base.dtype)`` counts; ``*_like`` helpers inherit
  and are exempt).
* RPR302 — explicit f32 narrowing (``.astype(jnp.float32)``,
  ``np.float32(...)``) is banned in ``core/xla/`` specifically.  The
  pallas kernels are OUT of scope by design: f32 is the MXU's native
  accumulate dtype and their kernels/refs narrow deliberately (see
  core/README.md "Invariants & static enforcement").
* RPR303 — bare float literals passed positionally to a known-jitted
  callable are weakly typed and can demote the whole computation; route
  them through an explicitly-dtyped array or a keyword default.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..diagnostics import Diagnostic, Rule
from ..registry import BaseChecker, FileContext, register_checker

#: constructor -> index of its positional dtype slot (None = kwarg only)
_JNP_CREATORS: dict[str, int | None] = {
    "zeros": 1, "ones": 1, "empty": 1, "full": 2, "array": 1,
    "asarray": 1, "arange": None, "linspace": None, "eye": None,
    "identity": None, "tri": None,
}

_F32_NAMES = frozenset({"float32", "bfloat16", "float16"})


def _dotted(node: ast.expr) -> tuple[str, ...]:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return tuple(reversed(parts))


def _collect_jitted_names(tree: ast.Module) -> set[str]:
    """Function names that are jitted at def site or rebound via
    ``g = jax.jit(f)`` — call sites of these are RPR303 targets."""
    jitted: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                d = dec.func if isinstance(dec, ast.Call) else dec
                dd = _dotted(d)
                if dd[-1:] == ("jit",):
                    jitted.add(node.name)
                elif dd[-1:] == ("partial",) and isinstance(dec, ast.Call) \
                        and dec.args \
                        and _dotted(dec.args[0])[-1:] == ("jit",):
                    jitted.add(node.name)
        elif isinstance(node, ast.Assign) \
                and isinstance(node.value, ast.Call) \
                and _dotted(node.value.func)[-1:] == ("jit",):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    jitted.add(t.id)
    return jitted


@register_checker
class DtypeChecker(BaseChecker):
    scope = ("repro/core/xla/", "repro/kernels/", "repro/risk/")
    rules = (
        Rule("RPR301", "implicit-jnp-dtype",
             "jnp array construction must pin an explicit dtype"),
        Rule("RPR302", "f32-narrowing",
             "no float32/bf16 narrowing in the f64 xla engine tier"),
        Rule("RPR303", "weak-float-literal-into-jit",
             "float literals entering jitted callables are weakly typed"),
    )

    #: RPR302 applies only here; `kernels/` compute in f32 by design.
    #: `risk/` is an f64 LP tier like the xla engine — narrowing banned.
    _NARROW_SCOPE = ("repro/core/xla/", "repro/risk/")

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        jitted = _collect_jitted_names(ctx.tree)
        narrow = any(s in ctx.posix for s in self._NARROW_SCOPE)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            yield from self._check_creation(ctx, node)
            if narrow:
                yield from self._check_narrowing(ctx, node)
            yield from self._check_weak_literal(ctx, node, jitted)

    def _check_creation(self, ctx: FileContext, node: ast.Call
                        ) -> Iterator[Diagnostic]:
        dd = _dotted(node.func)
        if len(dd) != 2 or dd[0] != "jnp" or dd[1] not in _JNP_CREATORS:
            return
        if any(kw.arg == "dtype" for kw in node.keywords):
            return
        slot = _JNP_CREATORS[dd[1]]
        if slot is not None and len(node.args) > slot:
            return      # positional dtype slot filled
        yield Diagnostic(
            ctx.display, node.lineno, node.col_offset, "RPR301",
            f"jnp.{dd[1]} without an explicit dtype defaults to f32 "
            f"when x64 is off — pin dtype= (f64 tier) explicitly")

    def _check_narrowing(self, ctx: FileContext, node: ast.Call
                         ) -> Iterator[Diagnostic]:
        f = node.func
        # x.astype(jnp.float32 / np.float32 / "float32")
        if isinstance(f, ast.Attribute) and f.attr == "astype" \
                and node.args:
            tgt = node.args[0]
            name = _dotted(tgt)[-1:] or (None,)
            if name[0] in _F32_NAMES or (
                    isinstance(tgt, ast.Constant)
                    and tgt.value in _F32_NAMES):
                yield Diagnostic(
                    ctx.display, node.lineno, node.col_offset, "RPR302",
                    "f32 narrowing inside the f64 xla engine tier")
            return
        # np.float32(x) / jnp.float32(x)
        dd = _dotted(f)
        if len(dd) == 2 and dd[1] in _F32_NAMES:
            yield Diagnostic(
                ctx.display, node.lineno, node.col_offset, "RPR302",
                f"{'.'.join(dd)} cast inside the f64 xla engine tier")

    def _check_weak_literal(self, ctx: FileContext, node: ast.Call,
                            jitted: set[str]) -> Iterator[Diagnostic]:
        if not (isinstance(node.func, ast.Name)
                and node.func.id in jitted):
            return
        for a in node.args:
            v = a.operand if isinstance(a, ast.UnaryOp) else a
            if isinstance(v, ast.Constant) and isinstance(v.value, float):
                yield Diagnostic(
                    ctx.display, a.lineno, a.col_offset, "RPR303",
                    f"weak float literal passed into jitted "
                    f"{node.func.id}() — wrap in an explicitly-dtyped "
                    f"array so promotion cannot demote the trace")
