"""jit/trace purity (RPR401-403) in jitted functions and pallas kernels.

Python control flow inside a traced function runs at TRACE time: an
``if`` on a traced value raises `TracerBoolConversionError` at best and
silently bakes one branch into the compiled program at worst; the same
goes for ``.item()``/``float()``/``bool()`` escapes and data-dependent
``range()`` bounds.  This pass finds the traced functions, partitions
their parameters into traced vs. static, propagates staticness through
locals, and flags Python-level control flow on traced values.

What counts as traced/static:

* ``@jax.jit`` positional parameters are traced;
  ``functools.partial(jax.jit, static_argnames=(...))`` names are
  static.
* pallas kernel bodies are found via ``pl.pallas_call(fn)`` /
  ``pl.pallas_call(functools.partial(fn, kw=...))`` — their positional
  (Ref) parameters are traced and their keyword-only parameters are
  static (the repo's idiom binds all compile-time scalars keyword-only
  through the partial).
* ``.shape`` / ``.dtype`` / ``.ndim`` / ``.size`` of anything are
  static (trace-time constants), as is arithmetic on static values.
* ``pl.when`` / ``jax.lax.cond`` / ``jnp.where`` are the sanctioned
  branching forms — they are calls, not Python ``if``, so they pass
  untouched.
"""
from __future__ import annotations

import ast
import enum
from typing import Iterator

from ..diagnostics import Diagnostic, Rule
from ..registry import BaseChecker, FileContext, register_checker


class Taint(enum.Enum):
    STATIC = 0
    TRACED = 1
    UNKNOWN = 2     # e.g. results of arbitrary calls — never flagged


_STATIC_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "itemsize"})
_HOST_FORCERS = frozenset({"float", "int", "bool", "complex"})


def _dotted(node: ast.expr) -> tuple[str, ...]:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return tuple(reversed(parts))


def _jit_static_names(fn: ast.FunctionDef) -> tuple[bool, frozenset[str]]:
    """(is_jitted, static param names) from the def's decorators."""
    for dec in fn.decorator_list:
        d = dec.func if isinstance(dec, ast.Call) else dec
        dd = _dotted(d)
        if dd[-1:] == ("jit",):
            return True, frozenset()
        if dd[-1:] == ("partial",) and isinstance(dec, ast.Call) \
                and dec.args and _dotted(dec.args[0])[-1:] == ("jit",):
            static: set[str] = set()
            for kw in dec.keywords:
                if kw.arg in ("static_argnames", "static_argnums") \
                        and isinstance(kw.value, (ast.Tuple, ast.List)):
                    for e in kw.value.elts:
                        if isinstance(e, ast.Constant) \
                                and isinstance(e.value, str):
                            static.add(e.value)
            return True, frozenset(static)
    return False, frozenset()


def _pallas_kernel_names(tree: ast.Module) -> set[str]:
    """Function names passed (possibly through functools.partial) as the
    kernel argument of a `pl.pallas_call`."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and _dotted(node.func)[-1:] == ("pallas_call",)):
            continue
        if not node.args:
            continue
        k = node.args[0]
        if isinstance(k, ast.Call) \
                and _dotted(k.func)[-1:] == ("partial",) and k.args:
            k = k.args[0]
        if isinstance(k, ast.Name):
            names.add(k.id)
    return names


class _FnScanner:
    """Taint propagation + flagging over one traced function body."""

    def __init__(self, ctx: FileContext, fn: ast.FunctionDef,
                 static_names: frozenset[str], kernel: bool):
        self.ctx = ctx
        self.fn = fn
        self.taint: dict[str, Taint] = {}
        a = fn.args
        for arg in (*a.posonlyargs, *a.args):
            self.taint[arg.arg] = (Taint.STATIC
                                   if arg.arg in static_names
                                   else Taint.TRACED)
        for arg in a.kwonlyargs:
            # pallas idiom: compile-time scalars are keyword-only, bound
            # by the functools.partial at the pallas_call site.
            self.taint[arg.arg] = (Taint.STATIC
                                   if kernel or arg.arg in static_names
                                   else Taint.TRACED)

    # -- expression taint --------------------------------------------------
    def eval(self, node: ast.expr) -> Taint:
        if isinstance(node, ast.Name):
            return self.taint.get(node.id, Taint.UNKNOWN)
        if isinstance(node, ast.Constant):
            return Taint.STATIC
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return Taint.STATIC
            return self.eval(node.value)
        if isinstance(node, ast.Subscript):
            base = self.eval(node.value)
            if base is Taint.STATIC:        # shape[0] etc.
                return Taint.STATIC
            return base
        if isinstance(node, (ast.BinOp,)):
            return self._join(self.eval(node.left), self.eval(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, ast.BoolOp):
            return self._join(*(self.eval(v) for v in node.values))
        if isinstance(node, ast.Compare):
            return self._join(self.eval(node.left),
                              *(self.eval(c) for c in node.comparators))
        if isinstance(node, (ast.Tuple, ast.List)):
            return self._join(*(self.eval(e) for e in node.elts))
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, ast.IfExp):
            return self._join(self.eval(node.body), self.eval(node.orelse))
        if isinstance(node, ast.Call):
            dd = _dotted(node.func)
            if dd[:1] in (("len",),) or dd[-1:] == ("program_id",):
                # len() of anything is static; program_id is traced.
                return (Taint.STATIC if dd[:1] == ("len",)
                        else Taint.TRACED)
            args = [self.eval(a) for a in node.args]
            args += [self.eval(kw.value) for kw in node.keywords]
            if isinstance(node.func, ast.Attribute):
                # method call: the receiver's taint flows through
                # (x.sum(), x.max(), x.astype(...) on a tracer are traced)
                args.append(self.eval(node.func.value))
            if any(t is Taint.TRACED for t in args):
                return Taint.TRACED
            return Taint.UNKNOWN
        return Taint.UNKNOWN

    @staticmethod
    def _join(*ts: Taint) -> Taint:
        if any(t is Taint.TRACED for t in ts):
            return Taint.TRACED
        if all(t is Taint.STATIC for t in ts):
            return Taint.STATIC
        return Taint.UNKNOWN

    # -- statement walk ----------------------------------------------------
    def scan(self) -> Iterator[Diagnostic]:
        yield from self._scan_body(self.fn.body)

    def _scan_body(self, body: list[ast.stmt]) -> Iterator[Diagnostic]:
        for node in body:
            yield from self._scan_stmt(node)

    def _scan_stmt(self, node: ast.stmt) -> Iterator[Diagnostic]:
        if isinstance(node, ast.Assign):
            t = self.eval(node.value)
            for tgt in node.targets:
                elts = tgt.elts if isinstance(tgt, (ast.Tuple, ast.List)) \
                    else [tgt]
                for e in elts:
                    if isinstance(e, ast.Name):
                        self.taint[e.id] = t
            yield from self._scan_expr(node.value)
        elif isinstance(node, ast.AugAssign):
            if isinstance(node.target, ast.Name):
                self.taint[node.target.id] = self._join(
                    self.taint.get(node.target.id, Taint.UNKNOWN),
                    self.eval(node.value))
            yield from self._scan_expr(node.value)
        elif isinstance(node, ast.If):
            if self.eval(node.test) is Taint.TRACED:
                yield self._diag(node, "RPR401",
                                 "Python `if` on a traced value — use "
                                 "jnp.where / jax.lax.cond / pl.when")
            yield from self._scan_expr(node.test)
            yield from self._scan_body(node.body)
            yield from self._scan_body(node.orelse)
        elif isinstance(node, ast.While):
            if self.eval(node.test) is Taint.TRACED:
                yield self._diag(node, "RPR403",
                                 "`while` on a traced value — use "
                                 "jax.lax.while_loop")
            yield from self._scan_body(node.body)
        elif isinstance(node, ast.For):
            it = node.iter
            traced_bound = False
            if isinstance(it, ast.Call) \
                    and _dotted(it.func)[-1:] == ("range",):
                traced_bound = any(self.eval(a) is Taint.TRACED
                                   for a in it.args)
            elif self.eval(it) is Taint.TRACED:
                traced_bound = True
            if traced_bound:
                yield self._diag(node, "RPR403",
                                 "data-dependent Python loop bound in a "
                                 "traced body — use jax.lax.fori_loop / "
                                 "scan")
            if isinstance(node.target, ast.Name):
                self.taint[node.target.id] = Taint.STATIC
            yield from self._scan_body(node.body)
        elif isinstance(node, ast.Assert):
            if self.eval(node.test) is Taint.TRACED:
                yield self._diag(node, "RPR401",
                                 "assert on a traced value — use "
                                 "checkify or a static precondition")
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # Nested helper: params take the taint of UNKNOWN (they are
            # usually called with traced arrays); its body is scanned
            # with the enclosing taint still visible for closures.
            yield from self._scan_body(node.body)
        elif isinstance(node, ast.Return) and node.value is not None:
            yield from self._scan_expr(node.value)
        elif isinstance(node, ast.Expr):
            yield from self._scan_expr(node.value)

    def _scan_expr(self, node: ast.expr) -> Iterator[Diagnostic]:
        for sub in ast.walk(node):
            if not isinstance(sub, ast.Call):
                continue
            # float(x) / int(x) / bool(x) on traced values
            if isinstance(sub.func, ast.Name) \
                    and sub.func.id in _HOST_FORCERS and sub.args:
                if self.eval(sub.args[0]) is Taint.TRACED:
                    yield self._diag(
                        sub, "RPR402",
                        f"{sub.func.id}() forces a traced value to host "
                        f"— keep it on device or mark the arg static")
            # x.item(), x.tolist() on traced values
            if isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in ("item", "tolist") \
                    and self.eval(sub.func.value) is Taint.TRACED:
                yield self._diag(
                    sub, "RPR402",
                    f".{sub.func.attr}() forces a traced value to host")
            # np.asarray(traced) inside a traced body
            dd = _dotted(sub.func)
            if dd[:1] == ("np",) and dd[-1:] in (("asarray",),
                                                 ("array",)) \
                    and sub.args \
                    and self.eval(sub.args[0]) is Taint.TRACED:
                yield self._diag(
                    sub, "RPR402",
                    "np.asarray on a traced value materializes at trace "
                    "time — use jnp")
        for sub in ast.walk(node):
            if isinstance(sub, ast.IfExp) \
                    and self.eval(sub.test) is Taint.TRACED:
                yield self._diag(
                    sub, "RPR401",
                    "conditional expression on a traced value — use "
                    "jnp.where")

    def _diag(self, node: ast.AST, code: str, msg: str) -> Diagnostic:
        return Diagnostic(self.ctx.display, node.lineno, node.col_offset,
                          code, f"{msg} (in `{self.fn.name}`)")


@register_checker
class JitPurityChecker(BaseChecker):
    scope = ("repro/core/xla/", "repro/kernels/", "repro/risk/")
    rules = (
        Rule("RPR401", "python-branch-on-tracer",
             "no Python branching on traced values in jit/pallas bodies"),
        Rule("RPR402", "tracer-host-escape",
             "no .item()/float()/bool() host escapes on traced values"),
        Rule("RPR403", "data-dependent-loop-bound",
             "Python loop bounds in traced bodies must be static"),
    )

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        kernels = _pallas_kernel_names(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            jitted, static = _jit_static_names(node)
            kernel = node.name in kernels
            if not (jitted or kernel):
                continue
            yield from _FnScanner(ctx, node, static, kernel).scan()
