"""Determinism discipline (RPR201-204) in the engine paths.

Scope: ``core/``, ``planner/``, ``serving/`` — everything the CI
regression gate pins objectives on.  The solvers must be bit-reproducible
for fixed inputs, so:

* RPR201 — the legacy module-level ``np.random.*`` API draws from hidden
  global state; only explicit ``np.random.default_rng(seed)`` generators
  (and the Generator/SeedSequence machinery) are deterministic.
* RPR202 — stdlib ``random`` has the same problem plus hash-dependent
  behaviors; it is banned outright in engine paths.
* RPR203 — iterating a ``set`` feeds Python's unordered iteration into
  whatever consumes it.  Order-insensitive reductions (``sorted``,
  ``len``, ``min``/``max``/``sum``/``any``/``all``, rebuilding a
  ``set``/``frozenset``, membership tests) are exempt; ``list()``/
  ``tuple()``/``enumerate()``/bare ``for`` are flagged.
* RPR204 — wall-clock and environment reads (``time.time``,
  ``datetime.now``, ``os.environ``/``getenv``) make results depend on
  when/where the solve runs.  ``time.perf_counter``/``process_time``/
  ``monotonic`` stay legal: they feed runtime *reporting*, never a
  decision.
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..diagnostics import Diagnostic, Rule
from ..registry import BaseChecker, FileContext, register_checker

_LEGAL_NP_RANDOM = frozenset({
    "default_rng", "Generator", "SeedSequence", "BitGenerator", "PCG64",
    "PCG64DXSM", "Philox", "SFC64", "MT19937",
})

#: order-insensitive consumers: set iteration inside these is fine
_ORDER_FREE_CALLS = frozenset({
    "sorted", "len", "min", "max", "sum", "any", "all", "set",
    "frozenset",
})

#: ordering-sensitive constructors over an unordered iterable
_ORDER_SENSITIVE_CALLS = frozenset({"list", "tuple", "enumerate", "iter"})

#: attributes known (from core.mechanisms) to hold sets
_SET_ATTRS = frozenset({"uncovered", "cfg_seen"})

_CLOCK_BANNED = {
    ("time", "time"), ("time", "time_ns"),
    ("datetime", "now"), ("datetime", "utcnow"), ("datetime", "today"),
    ("date", "today"),
}


def _dotted(node: ast.expr) -> tuple[str, ...]:
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return tuple(reversed(parts))


def _ann_is_set(ann: ast.expr | None) -> bool:
    if ann is None:
        return False
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        try:
            ann = ast.parse(ann.value, mode="eval").body
        except SyntaxError:
            return False
    if isinstance(ann, ast.Name):
        return ann.id in ("set", "frozenset")
    if isinstance(ann, ast.Subscript):
        return _ann_is_set(ann.value)
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return _ann_is_set(ann.left) or _ann_is_set(ann.right)
    return False


@register_checker
class DeterminismChecker(BaseChecker):
    scope = ("repro/core/", "repro/planner/", "repro/serving/")
    rules = (
        Rule("RPR201", "legacy-np-random",
             "use np.random.default_rng(seed), not the global legacy API"),
        Rule("RPR202", "stdlib-random",
             "stdlib `random` is banned in engine paths"),
        Rule("RPR203", "unordered-set-iteration",
             "set iteration must feed order-insensitive consumers only"),
        Rule("RPR204", "wallclock-or-env-read",
             "no wall-clock / environment reads in engine paths"),
    )

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        set_names = _collect_set_bindings(ctx.tree)
        for node in ast.walk(ctx.tree):
            yield from self._check_node(ctx, node, set_names)

    # -- per-node dispatch -------------------------------------------------
    def _check_node(self, ctx: FileContext, node: ast.AST,
                    set_names: set[str]) -> Iterator[Diagnostic]:
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "random" or a.name.startswith("random."):
                    yield Diagnostic(
                        ctx.display, node.lineno, node.col_offset,
                        "RPR202", "stdlib `random` import in an engine "
                        "path")
            return
        if isinstance(node, ast.ImportFrom):
            if node.module == "random":
                yield Diagnostic(
                    ctx.display, node.lineno, node.col_offset, "RPR202",
                    "stdlib `random` import in an engine path")
            return
        if isinstance(node, ast.Attribute):
            dotted = _dotted(node)
            if len(dotted) >= 3 and dotted[-3:-1] == ("np", "random") \
                    or (len(dotted) >= 3
                        and dotted[-3:-1] == ("numpy", "random")):
                if dotted[-1] not in _LEGAL_NP_RANDOM:
                    yield Diagnostic(
                        ctx.display, node.lineno, node.col_offset,
                        "RPR201",
                        f"legacy unseeded np.random.{dotted[-1]} — use a "
                        f"np.random.default_rng(seed) Generator")
            if dotted[:1] == ("random",) and len(dotted) == 2:
                yield Diagnostic(
                    ctx.display, node.lineno, node.col_offset, "RPR202",
                    f"stdlib random.{dotted[-1]} in an engine path")
            if len(dotted) >= 2 and dotted[-2:] in _CLOCK_BANNED:
                yield Diagnostic(
                    ctx.display, node.lineno, node.col_offset, "RPR204",
                    f"wall-clock read {'.'.join(dotted[-2:])} in an "
                    f"engine path (perf_counter is fine for timing)")
            if dotted[-2:] == ("os", "environ") \
                    or dotted[-2:] == ("os", "getenv"):
                yield Diagnostic(
                    ctx.display, node.lineno, node.col_offset, "RPR204",
                    "environment read in an engine path")
            return
        if isinstance(node, ast.For):
            if _is_set_expr(node.iter, set_names):
                yield Diagnostic(
                    ctx.display, node.iter.lineno, node.iter.col_offset,
                    "RPR203", "bare iteration over a set — wrap in "
                    "sorted(...) or prove order-insensitivity")
            return
        if isinstance(node, ast.comprehension):
            if _is_set_expr(node.iter, set_names):
                yield Diagnostic(
                    ctx.display, node.iter.lineno, node.iter.col_offset,
                    "RPR203", "comprehension over a set — wrap in "
                    "sorted(...) or prove order-insensitivity")
            return
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id in _ORDER_SENSITIVE_CALLS and node.args:
            if _is_set_expr(node.args[0], set_names):
                yield Diagnostic(
                    ctx.display, node.lineno, node.col_offset, "RPR203",
                    f"{node.func.id}() over a set materializes an "
                    f"arbitrary order — sort first")


def _collect_set_bindings(tree: ast.Module) -> set[str]:
    """Names statically known to hold sets: annotated params/vars and
    locals assigned from set displays / set() / frozenset()."""
    names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            a = node.args
            for arg in (*a.posonlyargs, *a.args, *a.kwonlyargs):
                if _ann_is_set(arg.annotation):
                    names.add(arg.arg)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name) \
                and _ann_is_set(node.annotation):
            names.add(node.target.id)
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            v = node.value
            if isinstance(v, (ast.Set, ast.SetComp)) or (
                    isinstance(v, ast.Call)
                    and isinstance(v.func, ast.Name)
                    and v.func.id in ("set", "frozenset")):
                names.add(node.targets[0].id)
    return names


def _is_set_expr(node: ast.expr, set_names: set[str]) -> bool:
    """Is `node` statically a set?  (Comprehension-rebuilds like
    ``set(xs)`` are sets too, but iterating them is only flagged when the
    *expression itself* appears in an iteration slot.)"""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in ("set", "frozenset"):
        return True
    if isinstance(node, ast.Name):
        return node.id in set_names
    if isinstance(node, ast.Attribute):
        return node.attr in _SET_ATTRS
    if isinstance(node, ast.BinOp) \
            and isinstance(node.op, (ast.BitOr, ast.BitAnd, ast.Sub,
                                     ast.BitXor)):
        return (_is_set_expr(node.left, set_names)
                and _is_set_expr(node.right, set_names))
    return False
