"""State-mutation discipline (RPR101-103).

`State` and `DestCache` carry the incremental aggregates every engine
tier trusts bitwise; any write outside the sanctioned mutators silently
desynchronizes the undo log / cache-invalidation protocol.  The
sanctioned set is declared in the source itself: a function decorated
``@mutates("q", "cfg", ...)`` (see `repro.core.contracts`) may write
exactly the declared fields.  Everything else must route through the
mutators.

Tracked objects are found syntactically — parameters annotated
``State``/``DestCache`` (any qualification, optional/union forms),
``self`` inside those classes, and locals assigned from the known
constructors (``State(...)``, ``State.fresh(...)``, ``DestCache(...)``,
``deployment_state(...)``).  A "write" is an attribute assignment or
aug-assignment, a subscript store through an attribute, or a mutating
method call (``.add``/``.discard``/``.fill``/...) on an attribute.
``__init__``/``__post_init__`` of the tracked classes are exempt
(construction is not mutation).
"""
from __future__ import annotations

import ast
from typing import Iterator

from ..diagnostics import Diagnostic, Rule
from ..registry import BaseChecker, FileContext, register_checker

TRACKED_CLASSES = frozenset({"State", "DestCache"})

#: calls whose result is a tracked object: name -> class
CONSTRUCTORS = {
    "State": "State",
    "DestCache": "DestCache",
    "deployment_state": "State",
}

#: attribute-method calls that mutate their receiver in place
MUTATING_METHODS = frozenset({
    "add", "discard", "remove", "clear", "update", "pop", "popitem",
    "append", "extend", "insert", "sort", "reverse", "fill", "setflags",
    "setdefault", "difference_update", "intersection_update",
    "symmetric_difference_update", "resize", "partial_sort",
})

_EXEMPT_METHODS = frozenset({"__init__", "__post_init__"})


def _terminal_name(node: ast.expr) -> str | None:
    """`Name` id, or the final attribute of a dotted path."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _annotation_class(ann: ast.expr | None) -> str | None:
    """The tracked class an annotation names, through quotes, Optional,
    and `| None` unions."""
    if ann is None:
        return None
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        try:
            ann = ast.parse(ann.value, mode="eval").body
        except SyntaxError:
            return None
    if isinstance(ann, (ast.Name, ast.Attribute)):
        t = _terminal_name(ann)
        return t if t in TRACKED_CLASSES else None
    if isinstance(ann, ast.BinOp) and isinstance(ann.op, ast.BitOr):
        return (_annotation_class(ann.left)
                or _annotation_class(ann.right))
    if isinstance(ann, ast.Subscript):        # Optional[State]
        t = _terminal_name(ann.value)
        if t == "Optional":
            return _annotation_class(ann.slice)
    return None


def _constructor_class(value: ast.expr) -> str | None:
    """Tracked class built by `value`, if it is a known constructor call
    (``State(...)``, ``State.fresh(...)``, ``deployment_state(...)``)."""
    if not isinstance(value, ast.Call):
        return None
    fn = value.func
    if isinstance(fn, ast.Attribute) and fn.attr == "fresh":
        base = _terminal_name(fn.value)
        if base in TRACKED_CLASSES:
            return base
    t = _terminal_name(fn)
    return CONSTRUCTORS.get(t) if t is not None else None


def _mutates_decl(fn: ast.FunctionDef) -> frozenset[str] | None:
    """The declared write-set of an ``@mutates(...)`` decorator, if any."""
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        if _terminal_name(dec.func) != "mutates":
            continue
        fields = set()
        for a in dec.args:
            if isinstance(a, ast.Constant) and isinstance(a.value, str):
                fields.add(a.value)
        return frozenset(fields)
    return None


def _iter_writes(body: list[ast.stmt], tracked: dict[str, str]
                 ) -> Iterator[tuple[ast.AST, str, str]]:
    """(node, object_name, field) for every tracked-field write in `body`,
    skipping nested function/class definitions (analyzed separately)."""

    def base_field(target: ast.expr) -> tuple[str, str] | None:
        # st.f = / st.f += ...
        if isinstance(target, ast.Attribute) \
                and isinstance(target.value, ast.Name) \
                and target.value.id in tracked:
            return target.value.id, target.attr
        # st.f[...] = / st.f[...] += ...
        if isinstance(target, ast.Subscript):
            return base_field(target.value)
        return None

    for node in _walk_shallow(body):
        if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) \
                    else [t]
                for e in elts:
                    bf = base_field(e)
                    if bf is not None:
                        yield node, bf[0], bf[1]
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in MUTATING_METHODS:
            bf = base_field(node.func.value)
            if bf is not None:
                yield node, bf[0], bf[1]


_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)


def _add_constructor_locals(body: list[ast.stmt],
                            tracked: dict[str, str]) -> None:
    """Add names bound by tracked-class constructor calls in `body`."""
    for node in _walk_shallow(body):
        if isinstance(node, ast.Assign):
            cls = _constructor_class(node.value)
            if cls is not None:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        tracked[t.id] = cls


def _walk_shallow(body: list[ast.stmt]) -> Iterator[ast.AST]:
    """ast.walk over the statements of ONE scope: neither nested
    def/class nodes nor their bodies are entered."""
    stack: list[ast.AST] = [n for n in body if not isinstance(n, _DEFS)]
    while stack:
        node = stack.pop()
        yield node
        for child in ast.iter_child_nodes(node):
            if not isinstance(child, _DEFS):
                stack.append(child)


@register_checker
class StateMutationChecker(BaseChecker):
    rules = (
        Rule("RPR101", "unsanctioned-state-write",
             "State/DestCache fields may only be written inside "
             "@mutates-decorated mutators"),
        Rule("RPR102", "undeclared-mutation",
             "a @mutates function may write only its declared fields"),
        Rule("RPR103", "unused-mutation-declaration",
             "every @mutates-declared field must actually be written"),
    )

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        yield from self._scan(ctx, ctx.tree.body, tracked={},
                              declared=None, owner=None)

    def _scan(self, ctx: FileContext, body: list[ast.stmt],
              tracked: dict[str, str], declared: frozenset[str] | None,
              owner: str | None) -> Iterator[Diagnostic]:
        """One scope: report its writes, then recurse into nested scopes
        with inherited tracked bindings / declaration."""
        # Locals bound by known constructors join the tracked set.
        tracked = dict(tracked)
        _add_constructor_locals(body, tracked)

        seen_fields: set[str] = set()
        for node, obj, field in _iter_writes(body, tracked):
            seen_fields.add(field)
            if declared is None:
                yield Diagnostic(
                    ctx.display, node.lineno, node.col_offset, "RPR101",
                    f"write to {tracked[obj]} field '{obj}.{field}' "
                    f"outside a @mutates mutator (route through "
                    f"core.mechanisms, or decorate and declare)")
            elif field not in declared:
                yield Diagnostic(
                    ctx.display, node.lineno, node.col_offset, "RPR102",
                    f"'{obj}.{field}' written but not declared by "
                    f"@mutates on this function")

        # Nested scopes.
        for node in _walk_all_defs(body):
            if isinstance(node, ast.ClassDef):
                cls_tracked = dict(tracked)
                is_tracked_cls = node.name in TRACKED_CLASSES
                for item in node.body:
                    if not isinstance(item, (ast.FunctionDef,
                                             ast.AsyncFunctionDef)):
                        continue
                    yield from self._scan_function(
                        ctx, item, cls_tracked,
                        owner=node.name if is_tracked_cls else None)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._scan_function(ctx, node, tracked,
                                               owner=owner,
                                               inherited=declared)

    def _scan_function(self, ctx: FileContext, fn: ast.FunctionDef,
                       tracked: dict[str, str], owner: str | None,
                       inherited: frozenset[str] | None = None
                       ) -> Iterator[Diagnostic]:
        tracked = dict(tracked)
        args = fn.args
        for a in (*args.posonlyargs, *args.args, *args.kwonlyargs):
            cls = _annotation_class(a.annotation)
            if cls is not None:
                tracked[a.arg] = cls
        if owner is not None and (args.posonlyargs or args.args):
            first = (args.posonlyargs or args.args)[0].arg
            tracked.setdefault(first, owner)

        if owner is not None and fn.name in _EXEMPT_METHODS:
            return      # construction is not mutation

        declared = _mutates_decl(fn)
        if declared is None:
            declared = inherited        # closures inside a mutator
        if declared is not None:
            full = dict(tracked)
            _add_constructor_locals(fn.body, full)
            written = {f for _, _, f in _iter_writes(fn.body, full)}
            for missing in sorted(declared - written):
                # Declared-but-unwritten fields may be written by nested
                # helpers; only flag when no nested def exists.
                if not any(True for _ in _walk_all_defs(fn.body)):
                    yield Diagnostic(
                        ctx.display, fn.lineno, fn.col_offset, "RPR103",
                        f"@mutates declares '{missing}' but the body "
                        f"never writes it")
        yield from self._scan(ctx, fn.body, tracked, declared, owner)


def _walk_all_defs(body: list[ast.stmt]) -> Iterator[ast.stmt]:
    """Function/class definitions belonging to this scope: direct members
    of `body` plus defs nested under non-def statements (`if`-guarded
    defs), without crossing another def/class boundary."""
    stack: list[ast.AST] = list(body)
    while stack:
        node = stack.pop()
        if isinstance(node, _DEFS):
            yield node          # a scope of its own: do not descend
            continue
        stack.extend(ast.iter_child_nodes(node))
