"""The lint runner: file walk, checker dispatch, suppressions, baseline.

`run_paths` is the single entry both the CLI and the test-suite use.
Per file: parse, collect suppressions (malformed ones are diagnostics
themselves), run every in-scope checker, then filter — per-file ignores
first (the frozen scalar oracle is exempt wholesale), then inline
suppressions, then the baseline.  What survives is the exit-code-1 set.
"""
from __future__ import annotations

import ast
import dataclasses
import json
from pathlib import Path
from typing import Iterable, Sequence

from .diagnostics import Diagnostic, fingerprint
from .registry import (FileContext, all_checkers, known_code_prefixes,
                       select_filter)
from .suppress import Suppression, effective_line, parse_suppressions

#: (posix substring, rule-code prefixes) pairs exempted wholesale.
#: `_scalar_ref.py` is the frozen scalar oracle — kept byte-stable as the
#: equivalence anchor, so it can neither adopt @mutates decorators nor
#: carry suppression comments; its direct State writes ARE the reference
#: semantics the mutators are checked against.
PER_FILE_IGNORES: tuple[tuple[str, tuple[str, ...]], ...] = (
    ("repro/core/_scalar_ref.py", ("RPR",)),
)

#: meta rules (suppression hygiene / parse errors) are never suppressible
_UNSUPPRESSIBLE = ("RPR000", "RPR001", "RPR002", "RPR003")


@dataclasses.dataclass
class FileReport:
    display: str
    diagnostics: list[Diagnostic]
    suppressed: list[tuple[Diagnostic, Suppression]]
    baselined: list[Diagnostic]


@dataclasses.dataclass
class LintResult:
    reports: list[FileReport]
    files_checked: int
    new_fingerprints: list[str]

    @property
    def diagnostics(self) -> list[Diagnostic]:
        return [d for r in self.reports for d in r.diagnostics]

    @property
    def suppressed_count(self) -> int:
        return sum(len(r.suppressed) for r in self.reports)

    @property
    def baselined_count(self) -> int:
        return sum(len(r.baselined) for r in self.reports)

    @property
    def exit_code(self) -> int:
        return 1 if self.diagnostics else 0

    def summary(self) -> dict:
        by_rule: dict[str, int] = {}
        for d in self.diagnostics:
            by_rule[d.rule] = by_rule.get(d.rule, 0) + 1
        return {
            "files_checked": self.files_checked,
            "diagnostics": len(self.diagnostics),
            "suppressed": self.suppressed_count,
            "baselined": self.baselined_count,
            "by_rule": dict(sorted(by_rule.items())),
        }


def iter_py_files(paths: Sequence[str | Path]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(q for q in p.rglob("*.py")
                              if "__pycache__" not in q.parts))
        elif p.suffix == ".py":
            out.append(p)
    return out


def lint_file(path: Path, select: Iterable[str] | None = None,
              display: str | None = None) -> FileReport:
    display = display if display is not None else str(path)
    posix = path.resolve().as_posix()
    source = path.read_text(encoding="utf-8")
    return lint_source(source, display=display, posix=posix,
                       select=select, path=path)


def lint_source(source: str, *, display: str, posix: str,
                select: Iterable[str] | None = None,
                path: Path | None = None) -> FileReport:
    """Lint one already-read source blob (the test-suite entry point)."""
    keep = select_filter(list(select) if select else None)
    try:
        tree = ast.parse(source)
    except SyntaxError as exc:
        d = Diagnostic(display, exc.lineno or 1, exc.offset or 0,
                       "RPR000", f"syntax error: {exc.msg}")
        return FileReport(display, [d], [], [])

    ctx = FileContext(path=path or Path(display), display=display,
                      posix=posix, source=source, tree=tree,
                      lines=source.splitlines())
    supps, supp_diags = parse_suppressions(display, source)

    diags: list[Diagnostic] = list(supp_diags)
    for checker in all_checkers():
        if not checker.applies_to(posix):
            continue
        for d in checker.check(ctx):
            if keep(d.rule):
                diags.append(d)

    # Unknown codes in suppressions (RPR003) — checked against the full
    # rule table so a suppression cannot rot silently.
    known = known_code_prefixes()
    for s in supps:
        for c in s.codes:
            if c not in known:
                diags.append(Diagnostic(
                    display, s.line, 0, "RPR003",
                    f"suppression names unknown rule {c!r}"))

    # Per-file ignores.
    for pat, prefixes in PER_FILE_IGNORES:
        if pat in posix:
            diags = [d for d in diags
                     if not any(d.rule.startswith(p) for p in prefixes)
                     or d.rule in _UNSUPPRESSIBLE]

    # Inline suppressions.  A standalone suppression comment governs the
    # next line that actually holds code (comment blocks chain through).
    code_lines = [i for i, t in enumerate(ctx.lines, 1)
                  if t.strip() and not t.lstrip().startswith("#")]
    line_of = {id(s): effective_line(s, code_lines) for s in supps}
    kept: list[Diagnostic] = []
    suppressed: list[tuple[Diagnostic, Suppression]] = []
    for d in sorted(diags, key=lambda d: (d.line, d.col, d.rule)):
        if d.rule in _UNSUPPRESSIBLE:
            kept.append(d)
            continue
        hit = next((s for s in supps
                    if line_of[id(s)] == d.line and s.matches(d.rule)),
                   None)
        if hit is not None:
            hit.used = True
            suppressed.append((d, hit))
        else:
            kept.append(d)
    return FileReport(display, kept, suppressed, [])


def run_paths(paths: Sequence[str | Path],
              select: Iterable[str] | None = None,
              baseline: str | Path | None = None) -> LintResult:
    files = iter_py_files(paths)
    reports = [lint_file(f, select=select) for f in files]

    base_fps: set[str] = set()
    if baseline is not None and Path(baseline).exists():
        data = json.loads(Path(baseline).read_text(encoding="utf-8"))
        base_fps = set(data.get("fingerprints", []))

    new_fps: list[str] = []
    for rep in reports:
        occ: dict[tuple[str, str, str], int] = {}
        remaining: list[Diagnostic] = []
        try:
            lines = Path(rep.display).read_text(
                encoding="utf-8").splitlines()
        except OSError:
            lines = []
        for d in rep.diagnostics:
            text = lines[d.line - 1] if 0 < d.line <= len(lines) else ""
            key = (d.path, d.rule, text.strip())
            n = occ.get(key, 0)
            occ[key] = n + 1
            fp = fingerprint(d, text, n)
            new_fps.append(fp)
            if fp in base_fps and d.rule not in _UNSUPPRESSIBLE:
                rep.baselined.append(d)
            else:
                remaining.append(d)
        rep.diagnostics = remaining
    return LintResult(reports, files_checked=len(files),
                      new_fingerprints=new_fps)


def write_baseline(result: LintResult, path: str | Path) -> None:
    """Freeze the current finding set as the baseline file."""
    Path(path).write_text(json.dumps(
        {"version": 1, "fingerprints": sorted(result.new_fingerprints)},
        indent=2) + "\n", encoding="utf-8")
