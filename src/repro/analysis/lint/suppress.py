"""Inline suppression syntax: ``# repro-lint: ignore[RPR203] -- reason``.

A suppression silences matching diagnostics on its own line, or — when
the comment stands alone on a line — on the next line that carries code.
The ``-- reason`` clause is MANDATORY: a bare ``ignore[...]`` is itself
a diagnostic (RPR002) and suppresses nothing, so every silenced finding
carries its justification in the source.  Codes may be exact
(``RPR203``) or a family prefix (``RPR2``); unknown codes raise RPR003
at lint time so suppressions cannot rot silently.

Comments are found with `tokenize`, not string search, so a
``repro-lint:`` inside a string literal is never misparsed.
"""
from __future__ import annotations

import dataclasses
import io
import re
import tokenize

from .diagnostics import Diagnostic

MARKER = "repro-lint:"

_IGNORE_RE = re.compile(
    r"#\s*repro-lint:\s*ignore\[(?P<codes>[^\]]*)\]"
    r"(?:\s*--\s*(?P<reason>.*))?\s*$")
_CODE_RE = re.compile(r"^RPR\d*$")


@dataclasses.dataclass
class Suppression:
    """One parsed ``ignore[...]`` comment."""
    line: int                   # line the comment sits on
    codes: tuple[str, ...]      # exact codes or RPR-prefix families
    reason: str
    standalone: bool            # comment-only line: applies to next line
    used: bool = False

    def matches(self, rule: str) -> bool:
        return any(rule == c or rule.startswith(c) for c in self.codes)


def parse_suppressions(path: str, source: str
                       ) -> tuple[list[Suppression], list[Diagnostic]]:
    """All suppressions in `source`, plus diagnostics for malformed ones.

    RPR001 — a ``repro-lint:`` comment that is not valid ``ignore[...]``
    syntax; RPR002 — an ``ignore[...]`` with no ``-- reason``.  Malformed
    suppressions are reported and NOT honored.
    """
    supps: list[Suppression] = []
    diags: list[Diagnostic] = []
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError, SyntaxError):
        return [], []           # unparseable files are reported upstream
    for tok in tokens:
        if tok.type != tokenize.COMMENT or MARKER not in tok.string:
            continue
        line_no, col = tok.start
        standalone = tok.line[:col].strip() == ""
        m = _IGNORE_RE.search(tok.string)
        if m is None:
            diags.append(Diagnostic(
                path, line_no, col, "RPR001",
                f"malformed repro-lint comment {tok.string.strip()!r}: "
                f"expected '# repro-lint: ignore[CODE,...] -- reason'"))
            continue
        codes = tuple(c.strip() for c in m.group("codes").split(",")
                      if c.strip())
        bad = [c for c in codes if not _CODE_RE.fullmatch(c)]
        if not codes or bad:
            diags.append(Diagnostic(
                path, line_no, col, "RPR001",
                f"suppression codes must be RPR-codes or RPR-prefixes, "
                f"got {list(codes)!r}"))
            continue
        reason = (m.group("reason") or "").strip()
        if not reason:
            diags.append(Diagnostic(
                path, line_no, col, "RPR002",
                "bare suppression rejected: add '-- <reason>' (the "
                "justification ships with the silenced finding)"))
            continue
        supps.append(Suppression(line_no, codes, reason, standalone))
    return supps, diags


def effective_line(supp: Suppression, code_lines: list[int]) -> int:
    """The source line `supp` governs.

    Same-line comments govern their own line; standalone comments govern
    the next line that holds code (from the sorted ``code_lines`` index).
    """
    if not supp.standalone:
        return supp.line
    for ln in code_lines:
        if ln > supp.line:
            return ln
    return supp.line
