"""Pluggable checker registry.

A checker is a class with a `rules` tuple, an optional path `scope`
(posix substrings; empty = every file), and a ``check(ctx)`` method
yielding diagnostics.  `@register_checker` adds it to the table the
runner walks; registering is the only wiring step, mirroring the solver
registry's contract (`repro.planner.registry`).
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Iterable, Iterator, Type

from .diagnostics import Diagnostic, Rule


@dataclasses.dataclass
class FileContext:
    """Everything a checker may inspect about one file."""
    path: Path              # filesystem path (for re-reads, never shown)
    display: str            # path string used in diagnostics
    posix: str              # normalized posix path, used for scoping
    source: str
    tree: ast.Module
    lines: list[str]        # source split per line (1-based via line-1)


class BaseChecker:
    """One invariant pass.  Subclass, set `rules` (+ optional `scope`),
    implement `check`, and decorate with `@register_checker`."""

    rules: tuple[Rule, ...] = ()
    #: posix path substrings this checker applies to; empty = all files.
    scope: tuple[str, ...] = ()

    def applies_to(self, posix_path: str) -> bool:
        return not self.scope or any(s in posix_path for s in self.scope)

    def check(self, ctx: FileContext) -> Iterator[Diagnostic]:
        raise NotImplementedError


_CHECKERS: dict[str, Type[BaseChecker]] = {}


def register_checker(cls: Type[BaseChecker]) -> Type[BaseChecker]:
    if not cls.rules:
        raise ValueError(f"checker {cls.__name__} declares no rules")
    _CHECKERS[cls.__name__] = cls
    return cls


def _ensure_builtin_checkers() -> None:
    from . import checkers  # noqa: F401  (import-for-side-effect)


def all_checkers() -> list[BaseChecker]:
    _ensure_builtin_checkers()
    return [cls() for cls in _CHECKERS.values()]


# Meta-rules emitted by the framework itself (suppression hygiene, parse
# failures).  Always active and never suppressible — a broken suppression
# must not be silenceable by another broken suppression.
META_RULES: tuple[Rule, ...] = (
    Rule("RPR000", "syntax-error", "file must parse under ast.parse"),
    Rule("RPR001", "malformed-suppression",
         "repro-lint comments must be 'ignore[CODE,...] -- reason'"),
    Rule("RPR002", "bare-suppression",
         "suppressions require a '-- reason' justification"),
    Rule("RPR003", "unknown-suppression-code",
         "suppressed codes must name a registered rule or family"),
)


def all_rules() -> tuple[Rule, ...]:
    _ensure_builtin_checkers()
    seen: dict[str, Rule] = {r.code: r for r in META_RULES}
    for cls in _CHECKERS.values():
        for r in cls.rules:
            if r.code in seen:
                raise ValueError(f"duplicate rule code {r.code}")
            seen[r.code] = r
    return tuple(sorted(seen.values(), key=lambda r: r.code))


def known_code_prefixes() -> frozenset[str]:
    """Every exact code plus every valid RPR-prefix family."""
    codes = {r.code for r in all_rules()}
    fams: set[str] = {"RPR"}
    for c in codes:
        for end in range(4, len(c)):
            fams.add(c[:end])
    return frozenset(codes | fams)


def select_filter(select: Iterable[str] | None):
    """Predicate over rule codes for ``--select`` (prefix semantics)."""
    if not select:
        return lambda code: True
    pats = tuple(s.strip() for s in select if s.strip())
    return lambda code: any(code == p or code.startswith(p) for p in pats)
