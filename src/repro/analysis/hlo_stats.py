"""Trip-count-aware HLO cost analysis.

XLA:CPU's `compiled.cost_analysis()` does not multiply `while`-loop bodies
by their trip counts, so any scan-over-layers program reports FLOPs that are
off by a factor of L (and more for nested scans). This module re-derives

    * dot FLOPs            (2 * prod(output dims) * prod(contracting dims))
    * bytes accessed       (operand + output bytes of top-level instructions)
    * collective bytes     (output bytes of all-gather / all-reduce /
                            reduce-scatter / all-to-all / collective-permute)

from the optimized HLO text, walking the call graph with multipliers taken
from the `known_trip_count` backend configs that the scheduler attaches to
while loops. Shapes in the SPMD module are per-device shards, so totals are
PER DEVICE — exactly what the per-chip roofline needs.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_dims(shape_txt: str):
    """All (dtype, dims list) groups in a type string."""
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_txt):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_txt: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_txt):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    op: str
    rest: str                    # operands + attributes text


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    var_types: dict[str, str]


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line.strip()) if line and not line.startswith(" ") \
            else None
        if mc and ("->" in line) and line.rstrip().endswith("{"):
            cur = Computation(mc.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        s = line.strip()
        if s == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            ins = Instr(mi.group(1), mi.group(2), mi.group(3), mi.group(4))
            cur.instrs.append(ins)
            cur.var_types[ins.name] = ins.out_type
        else:
            # parameter-style lines: %p = f32[..] parameter(0)
            mp = re.match(
                r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:\S+))\s+parameter\(",
                line)
            if mp and cur is not None:
                cur.var_types[mp.group(1)] = mp.group(2)
    return comps


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out_elems = 1.0
    for _, dims in _shape_dims(ins.out_type):
        for d in dims:
            out_elems *= d
    mc = _LHS_C_RE.search(ins.rest)
    contract = 1.0
    if mc:
        cdims = [int(x) for x in mc.group(1).split(",") if x]
        paren = ins.rest.split("),")[0]
        ops = _OPERAND_RE.findall(paren)
        if ops:
            lhs_t = comp.var_types.get(ops[0], "")
            groups = _shape_dims(lhs_t)
            if groups:
                _, dims = groups[0]
                for c in cdims:
                    if c < len(dims):
                        contract *= dims[c]
    return 2.0 * out_elems * contract


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   # control flow: bodies are traversed, the call itself
                   # moves no data
                   "while", "conditional", "call", "optimization-barrier"}
_SLICE_OPS = {"dynamic-slice", "slice", "gather"}


def _operands(ins: Instr) -> list[str]:
    paren = ins.rest.split("),")[0]
    return _OPERAND_RE.findall(paren)


def _fusion_bytes(comp: Computation, ins: Instr,
                  called: "Computation | None") -> int:
    """HBM traffic estimate for a fusion: output bytes + per-operand read
    size. An operand whose uses inside the fused computation are ALL
    slice-like (dynamic-slice / slice / gather) only reads the sliced
    bytes — this is what keeps scan-over-stacked-weights from being charged
    L x the full stack."""
    total = _shape_bytes(ins.out_type)
    operand_names = _operands(ins)
    if called is None:
        for opname in operand_names:
            t = comp.var_types.get(opname)
            if t:
                total += _shape_bytes(t)
        return total
    # parameters appear as "%name = type parameter(i)" instructions;
    # recover parameter index -> var name
    param_idx: dict[str, int] = {}
    for cins in called.instrs:
        if cins.op == "parameter":
            m = re.match(r"\s*(\d+)", cins.rest)
            if m:
                param_idx[cins.name] = int(m.group(1))
    # fallback: var_types-only parameters (captured by the parameter regex)
    for idx, opname in enumerate(operand_names):
        t = comp.var_types.get(opname)
        if not t:
            continue
        full = _shape_bytes(t)
        # find the fused-computation parameter var with this index
        pvar = None
        for name, pi in param_idx.items():
            if pi == idx:
                pvar = name
                break
        if pvar is None:
            total += full
            continue
        uses = [ci for ci in called.instrs if pvar in _OPERAND_RE.findall(
            ci.rest.split("),")[0])]
        if uses and all(u.op in _SLICE_OPS for u in uses):
            total += sum(_shape_bytes(u.out_type) for u in uses)
        else:
            total += full
    return total


def _instr_bytes(comp: Computation, ins: Instr,
                 comps: "dict[str, Computation] | None" = None) -> int:
    if ins.op in _SKIP_BYTES_OPS:
        return 0
    if ins.op == "fusion" and comps is not None:
        mf = _CALLS_RE.search(ins.rest)
        called = comps.get(mf.group(1)) if mf else None
        return _fusion_bytes(comp, ins, called)
    if ins.op in _SLICE_OPS:
        # reads only the sliced window (+ indices), writes the output
        return 2 * _shape_bytes(ins.out_type)
    total = _shape_bytes(ins.out_type)
    for opname in _operands(ins):
        t = comp.var_types.get(opname)
        if t:
            total += _shape_bytes(t)
    return total


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0        # upper bound: operands + outputs
    bytes_written: float = 0.0         # lower bound: each buffer written once
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)
    n_collectives: int = 0
    argument_bytes: float = 0.0

    @property
    def bytes_estimate(self) -> float:
        """Roofline memory-traffic estimate: geometric mean of the
        write-once lower bound (perfect fusion/VMEM reuse) and the
        operands+outputs upper bound (no reuse)."""
        lo = self.bytes_written + self.argument_bytes
        hi = max(self.bytes_accessed, lo)
        return (lo * hi) ** 0.5


def analyze(text: str, entry: str | None = None) -> HloStats:
    comps = parse_hlo(text)
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        entry = m.group(1) if m else next(iter(comps))
    stats = HloStats()

    def visit(name: str, mult: float, count_bytes: bool):
        comp = comps.get(name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op
            if base in COLLECTIVES:
                b = _shape_bytes(ins.out_type) * mult
                stats.collective_bytes += b
                stats.collectives[base] = stats.collectives.get(base, 0) + b
                stats.n_collectives += int(mult)
            if op == "dot":
                stats.flops += _dot_flops(comp, ins) * mult
            if count_bytes:
                stats.bytes_accessed += _instr_bytes(comp, ins, comps) * mult
                if op not in _SKIP_BYTES_OPS:
                    stats.bytes_written += _shape_bytes(ins.out_type) * mult
            if op == "parameter" and name == entry:
                stats.argument_bytes += _shape_bytes(ins.out_type)
            # call graph
            if op == "while":
                trip = 1
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trip = int(mt.group(1))
                mb = _BODY_RE.search(ins.rest)
                if mb:
                    visit(mb.group(1), mult * trip, count_bytes)
                mcnd = _COND_RE.search(ins.rest)
                if mcnd:
                    visit(mcnd.group(1), mult * trip, False)
            elif op == "fusion":
                mf = _CALLS_RE.search(ins.rest)
                if mf:
                    visit(mf.group(1), mult, False)  # bytes counted at site
            elif op in ("call", "custom-call"):
                mf = _TOAPPLY_RE.search(ins.rest) or _CALLS_RE.search(ins.rest)
                if mf:
                    visit(mf.group(1), mult, count_bytes)
            elif op == "conditional":
                mbr = _BRANCH_RE.search(ins.rest)
                if mbr:
                    for bname in _OPERAND_RE.findall(mbr.group(1)):
                        visit(bname, mult, count_bytes)

    visit(entry, 1.0, True)
    return stats
