"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh):
    compute    = HLO_FLOPs_per_device / peak_FLOPs          [s]
    memory     = HLO_bytes_per_device / HBM_bw              [s]
    collective = collective_bytes_per_device / ICI_bw       [s]
plus MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params,
D = tokens processed, and the usefulness ratio MODEL_FLOPS / HLO_FLOPs
(catches remat / masked-attention / capacity-factor waste).

    PYTHONPATH=src python -m repro.analysis.roofline \
        [--json experiments/dryrun_results.json] [--md]
"""
from __future__ import annotations

import argparse
import json
import sys

from ..launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from ..launch.specs import SHAPES

_ADVICE = {
    "compute": ("skip fully-masked attention blocks / drop the capacity "
                "factor — most HLO FLOPs above MODEL_FLOPS are maskable"),
    "memory": ("decode is weight-stream-bound: quantize weights or raise "
               "batch to amortize the per-token parameter read"),
    "collective": ("reshard to keep the contraction local (move FSDP "
                   "gathers off the critical path / overlap with compute)"),
}


def tokens_of(shape: str) -> int:
    s = SHAPES[shape]
    if s["kind"] == "decode":
        return s["global_batch"]          # one new token per sequence
    return s["global_batch"] * s["seq_len"]


def analyze_row(r: dict) -> dict | None:
    if r.get("status") != "ok":
        return None
    n_dev = r["n_devices"]
    comp = r["hlo_flops_per_device"] / PEAK_FLOPS_BF16
    mem = r["hlo_bytes_per_device"] / HBM_BW
    coll = r["collective_bytes_per_device"] / ICI_BW
    terms = dict(compute=comp, memory=mem, collective=coll)
    dominant = max(terms, key=terms.get)
    D = tokens_of(r["shape"])
    mult = 6.0 if r["kind"] == "train" else 2.0
    model_flops = mult * r["params_active"] * D
    hlo_total = r["hlo_flops_per_device"] * n_dev
    ratio = model_flops / hlo_total if hlo_total else float("nan")
    return dict(
        arch=r["arch"], shape=r["shape"],
        mesh="2x16x16" if r["multi_pod"] else "16x16",
        compute_s=comp, memory_s=mem, collective_s=coll,
        dominant=dominant,
        model_flops=model_flops, hlo_flops_total=hlo_total,
        useful_ratio=ratio,
        advice=_ADVICE[dominant],
        collectives=r.get("collectives", {}),
    )


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops |\n"
           "|---|---|---|---|---|---|---|---|\n")
    body = ""
    for a in rows:
        body += ("| %s | %s | %s | %.3e | %.3e | %.3e | **%s** | %.3f |\n"
                 % (a["arch"], a["shape"], a["mesh"], a["compute_s"],
                    a["memory_s"], a["collective_s"], a["dominant"],
                    a["useful_ratio"]))
    return hdr + body


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="experiments/dryrun_results.json")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    with open(args.json) as f:
        data = json.load(f)
    rows = [a for a in (analyze_row(r) for r in data) if a]
    rows.sort(key=lambda a: (a["mesh"], a["arch"], a["shape"]))
    if args.md:
        print(markdown_table(rows))
    else:
        print(json.dumps(rows, indent=1))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
