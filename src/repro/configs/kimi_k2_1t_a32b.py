"""Kimi-K2 — trillion-parameter MoE (paper-table entry) [arXiv:2501.kimi2].

61L, d_model=7168, 64H (GQA kv=8), expert d_ff=2048, vocab=163840,
384 routed experts top-8 + 1 shared expert. Upstream's first dense layer is
folded into the uniform MoE stack (noted in DESIGN.md); MLA is served here
as GQA at the assigned head counts. Long context is served with a sliding
window, so long_500k decode RUNS for this arch.

Total params ~1.0T; active ~32B/token — the framework's largest arch and
the main expert-parallel / all-to-all stress case.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", arch_type="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=2048, vocab_size=163840,
        n_experts=384, top_k=8, shared_expert_ff=2048,
        sliding_window=8192)
