"""Llama-4-Scout-17B-16E — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

48L, d_model=5120, 40H (GQA kv=8), expert d_ff=8192, vocab=202048,
16 routed experts top-1 + shared expert (the "a16e" active split). Upstream
interleaves dense/MoE layers; here every layer is MoE with a shared expert
(noted in DESIGN.md). Llama-4's long-context mode is served with
chunked/sliding-window attention, so long_500k decode RUNS for this arch.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", arch_type="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202048,
        n_experts=16, top_k=1, shared_expert_ff=8192,
        sliding_window=8192)
