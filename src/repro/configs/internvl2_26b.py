"""InternVL2-26B — VLM: InternViT frontend + InternLM2 LM backbone
[arXiv:2404.16821].

Backbone (implemented here, per the assignment carve-out): 48L,
d_model=6144, 48H (GQA kv=8), d_ff=16384, vocab=92553. The InternViT
vision encoder + MLP projector are a STUB — ``input_specs()`` supplies
pre-projected patch embeddings [B, 256, d_model].
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", arch_type="vlm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
        d_ff=16384, vocab_size=92553, n_prefix_embeds=256)
