"""RWKV6-7B ("Finch") — attention-free, data-dependent decay
[arXiv:2404.05892].

32L, d_model=4096, d_ff=14336, vocab=65536. Channel mixer is SwiGLU at the
assigned d_ff (the upstream relu^2 channel-mix is a noted simplification).
O(1) recurrent state: long_500k decode RUNS for this arch.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", arch_type="ssm",
        n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0,
        d_ff=14336, vocab_size=65536, token_mixer="rwkv6")
