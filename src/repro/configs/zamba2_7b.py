"""Zamba2-7B — hybrid Mamba2 backbone + shared attention blocks
[arXiv:2411.15242].

81L, d_model=3584, shared attn 32H (kv=32), d_ff=14336, vocab=32000,
ssm_state=64. The shared attention block (single weight set) is invoked
after every 6 Mamba2 layers, per the Zamba2 shared-block design; the
shared block here is attention-only (the upstream model adds a LoRA per
invocation — noted as a simplification in DESIGN.md).

Sliding-window on the shared attention keeps the arch sub-quadratic, so
long_500k decode RUNS for this arch.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", arch_type="hybrid",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
        d_ff=14336, vocab_size=32000,
        token_mixer="mamba2", attn_every=6, ssm_state=64,
        sliding_window=4096)
