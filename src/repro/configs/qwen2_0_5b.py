"""Qwen2-0.5B — dense GQA with QKV bias [arXiv:2407.10671].

24L, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151936.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", arch_type="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
        d_ff=4864, vocab_size=151936, qkv_bias=True)
