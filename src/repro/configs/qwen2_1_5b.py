"""Qwen2-1.5B — dense GQA with QKV bias [arXiv:2407.10671].

28L, d_model=1536, 12 heads (GQA kv=2), d_ff=8960, vocab=151936.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b", arch_type="dense",
        n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
        d_ff=8960, vocab_size=151936, qkv_bias=True)
