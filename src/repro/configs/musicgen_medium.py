"""MusicGen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L, d_model=1536, 24H (kv=24), d_ff=6144, vocab=2048 per codebook,
4 codebooks (summed embeddings, per-codebook output heads). The EnCodec
tokenizer and the T5 text-conditioning frontend are a STUB —
``input_specs()`` supplies conditioning embeddings [B, 64, d_model]
(prefix) and codebook token streams [B, S, 4]. The delay-pattern
interleaving lives in the serving layer, not the backbone.
"""
from ..models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", arch_type="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab_size=2048, n_codebooks=4, n_prefix_embeds=64)
