"""Assigned-architecture configs (``--arch <id>``).

Each module defines ``config()`` with the exact published architecture
(source cited in its docstring) and the reduced smoke variant is derived via
``ModelConfig.smoke()``. ``REGISTRY`` maps arch id -> config factory.
"""
from __future__ import annotations

from ..models.config import ModelConfig
from . import (deepseek_7b, internvl2_26b, kimi_k2_1t_a32b,
               llama4_scout_17b_a16e, musicgen_medium, qwen2_0_5b, qwen2_1_5b,
               qwen2_72b, rwkv6_7b, zamba2_7b)

REGISTRY = {
    "zamba2-7b": zamba2_7b.config,
    "internvl2-26b": internvl2_26b.config,
    "musicgen-medium": musicgen_medium.config,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.config,
    "deepseek-7b": deepseek_7b.config,
    "qwen2-72b": qwen2_72b.config,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.config,
    "qwen2-1.5b": qwen2_1_5b.config,
    "rwkv6-7b": rwkv6_7b.config,
    "qwen2-0.5b": qwen2_0_5b.config,
}

ARCH_IDS = list(REGISTRY)


def get_config(arch: str) -> ModelConfig:
    return REGISTRY[arch]()
