"""Checkpointing: flat-key .npz shards + JSON manifest (no orbax offline).

Arrays are saved host-side; under a mesh the caller should fully replicate
or gather first (the train loop saves from `jax.device_get`). Keys are
'/'-joined pytree paths so restore round-trips arbitrary nested dicts.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (tuple, list)):
        for idx, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}__{idx}/"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = np.asarray(jax.device_get(tree))
    return out


def _unflatten(flat: dict[str, np.ndarray]) -> Any:
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return node
        if node and all(re.fullmatch(r"__\d+", k) for k in node):
            return tuple(fix(node[f"__{i}"]) for i in range(len(node)))
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save(path: str, tree: Any, meta: dict | None = None,
         shard_mb: int = 512) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    shards: list[dict[str, np.ndarray]] = [{}]
    size = 0
    for k, v in flat.items():
        if size > shard_mb * 2 ** 20:
            shards.append({})
            size = 0
        shards[-1][k] = v
        size += v.nbytes
    manifest = dict(meta=meta or {}, n_shards=len(shards),
                    keys={k: i for i, sh in enumerate(shards) for k in sh})
    for i, sh in enumerate(shards):
        np.savez(os.path.join(path, f"shard_{i}.npz"), **sh)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)


def restore(path: str) -> tuple[Any, dict]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat: dict[str, np.ndarray] = {}
    for i in range(manifest["n_shards"]):
        with np.load(os.path.join(path, f"shard_{i}.npz")) as z:
            for k in z.files:
                flat[k] = z[k]
    return _unflatten(flat), manifest["meta"]
