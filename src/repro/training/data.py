"""Token data pipeline.

Offline container -> no real corpus; the pipeline synthesizes a stationary
Zipf-Markov token stream (document lengths ~ lognormal, EOS-separated,
packed into fixed-length rows) so the training loop exercises a realistic
input path: document sampling -> packing -> host-to-device batching.
Deterministic given (seed, step): the stream is restartable for
checkpoint-resume without data-state files.
"""
from __future__ import annotations

import dataclasses

import numpy as np

EOS = 0


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    n_codebooks: int = 0
    zipf_a: float = 1.2
    mean_doc_len: float = 512.0
    seed: int = 0


class PackedStream:
    """Deterministic packed token batches; batch(step) is pure in step."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipf weights over the vocab (token 0 reserved for EOS).
        ranks = np.arange(1, cfg.vocab_size, dtype=np.float64)
        w = ranks ** -cfg.zipf_a
        self._probs = w / w.sum()

    def _doc(self, rng: np.random.Generator, length: int) -> np.ndarray:
        """First-order Markov-ish doc: Zipf unigram with local repetition."""
        base = rng.choice(len(self._probs), size=length, p=self._probs) + 1
        rep = rng.random(length) < 0.15
        base[1:][rep[1:]] = base[:-1][rep[1:]]
        return base.astype(np.int32)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        rows = np.empty((cfg.batch_size, cfg.seq_len + 1), np.int32)
        for b in range(cfg.batch_size):
            buf: list[np.ndarray] = []
            n = 0
            while n < cfg.seq_len + 1:
                L = max(8, int(rng.lognormal(np.log(cfg.mean_doc_len), 0.6)))
                doc = self._doc(rng, L)
                buf.append(np.append(doc, EOS))
                n += L + 1
            row = np.concatenate(buf)[: cfg.seq_len + 1]
            rows[b] = row
        tokens, targets = rows[:, :-1], rows[:, 1:]
        if cfg.n_codebooks:
            # Multi-stream (audio): independent streams per codebook.
            t = np.stack([np.roll(tokens, q, axis=1) % cfg.vocab_size
                          for q in range(cfg.n_codebooks)], axis=-1)
            g = np.stack([np.roll(targets, q, axis=1) % cfg.vocab_size
                          for q in range(cfg.n_codebooks)], axis=-1)
            return dict(tokens=t, targets=g)
        return dict(tokens=tokens, targets=targets)
