"""AdamW optimizer in pure JAX (optax is not available offline).

State and update are pytree-structured so the whole (params, opt_state)
tree shards with the same PartitionSpecs as the parameters (moments inherit
the parameter sharding — standard ZeRO-style placement under FSDP axes).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def init_state(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return dict(mu=jax.tree.map(zeros, params),
                nu=jax.tree.map(zeros, params),
                step=jnp.zeros((), jnp.int32))


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to min_lr_frac."""
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(cfg: AdamWConfig, params: Any, grads: Any,
                  state: dict) -> tuple[Any, dict, dict]:
    """One AdamW step with global-norm clipping. Returns
    (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1t = 1 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    b2t = 1 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1t
        nhat = nu / b2t
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu, strict=True)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    new_state = dict(mu=new_mu, nu=new_nu, step=step + 1)
    return new_p, new_state, dict(grad_norm=gnorm, lr=lr)
