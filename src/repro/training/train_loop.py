"""Training step + loop (pjit-distributed, checkpointed)."""
from __future__ import annotations

import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..models import decoder
from ..models.config import ModelConfig
from . import checkpoint
from .optimizer import AdamWConfig, apply_updates, init_state


def make_train_step(cfg: ModelConfig,
                    opt_cfg: AdamWConfig) -> Callable:
    def train_step(params: Any, opt_state: dict, batch: dict):
        loss, grads = jax.value_and_grad(
            lambda p: decoder.train_loss(p, cfg, batch))(params)
        params, opt_state, metrics = apply_updates(opt_cfg, params, grads,
                                                   opt_state)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step


def train(cfg: ModelConfig, opt_cfg: AdamWConfig, stream, n_steps: int,
          rng=None, log_every: int = 10, ckpt_path: str | None = None,
          ckpt_every: int = 0, params: Any = None) -> tuple[Any, list[dict]]:
    """Single-host training loop (examples / smoke scale).

    The distributed path is the same `make_train_step` jitted with
    in/out_shardings — see launch/train.py.
    """
    rng = jax.random.PRNGKey(0) if rng is None else rng
    if params is None:
        params = decoder.init_params(rng, cfg)
    opt_state = init_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    history: list[dict] = []
    t0 = time.perf_counter()
    for step in range(n_steps):
        batch = {k: jnp.asarray(v) for k, v in stream.batch(step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == n_steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
        if ckpt_path and ckpt_every and (step + 1) % ckpt_every == 0:
            checkpoint.save(ckpt_path, dict(params=params,
                                            opt_state=opt_state),
                            meta=dict(step=step + 1, arch=cfg.name))
    return params, history
