"""Mamba2 (SSD) token mixer — chunked exact scan, TPU-friendly.

State-space update per head h with scalar decay a_t = exp(dt_t * A_h):
    S_t = a_t * S_{t-1} + dt_t * (x_t ⊗ B_t)        S: [hp, N]
    y_t = S_t @ C_t + D_h * x_t

Training uses the chunked SSD algorithm (chunk Q=128): an intra-chunk
quadratic term with decay-ratio mask plus an inter-chunk carried state —
mathematically exact for scalar-per-head decay, and it keeps the HLO free
of length-T sequential loops (one lax.scan over T/Q chunks of einsums, which
is also how the Pallas `ssm_scan` kernel tiles VMEM).

Decode is the O(1) single-step recurrence on the carried state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

CHUNK = 128


def mamba2_params(rng, cfg: ModelConfig, stacked: int | None = None) -> dict:
    d, di, N, nh = cfg.d_model, cfg.di, cfg.ssm_state, cfg.ssm_heads
    keys = jax.random.split(rng, 8)

    def mk(key, shp, fan):
        full = shp if stacked is None else (stacked,) + shp
        return (jax.random.normal(key, full, jnp.float32) * fan ** -0.5
                ).astype(cfg.jdtype)

    def mkf(val, shp):
        full = shp if stacked is None else (stacked,) + shp
        return jnp.broadcast_to(val, full).astype(jnp.float32)

    return dict(
        wx=mk(keys[0], (d, di), d), wz=mk(keys[1], (d, di), d),
        wB=mk(keys[2], (d, N), d), wC=mk(keys[3], (d, N), d),
        wdt=mk(keys[4], (d, nh), d),
        dt_bias=mkf(jnp.log(jnp.expm1(0.01)), (nh,)),
        A_log=mkf(jnp.log(1.0), (nh,)),
        D=mkf(1.0, (nh,)),
        conv=mk(keys[5], (cfg.conv_width, di), cfg.conv_width),
        wo=mk(keys[6], (di, d), di))


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray,
                 conv_state: jnp.ndarray | None):
    """Depthwise causal conv. x: [B, T, di]; kernel: [W, di];
    conv_state: [B, W-1, di] trailing inputs from the previous call."""
    W = kernel.shape[0]
    if conv_state is None:
        pad = jnp.zeros(x.shape[:1] + (W - 1,) + x.shape[2:], x.dtype)
    else:
        pad = conv_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)               # [B, T+W-1, di]
    out = sum(xp[:, i:i + x.shape[1]] * kernel[i] for i in range(W))
    new_state = xp[:, -(W - 1):]
    return jax.nn.silu(out), new_state


def mamba2_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                 cache: dict | None):
    """x: [B, T, d] -> ([B, T, d], new_cache).
    cache = dict(ssm=[B, nh, hp, N], conv=[B, W-1, di]) or None (training)."""
    B, T, d = x.shape
    di, N, nh, hp = cfg.di, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = jax.nn.silu(x @ p["wz"])                         # [B, T, di]
    xin = x @ p["wx"]
    xin, conv_state = _causal_conv(
        xin, p["conv"], None if cache is None else cache["conv"])
    Bm = (x @ p["wB"]).astype(jnp.float32)               # [B, T, N]
    Cm = (x @ p["wC"]).astype(jnp.float32)               # [B, T, N]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"])                 # [B, T, nh]
    A = -jnp.exp(p["A_log"])                             # [nh]
    xh = xin.reshape(B, T, nh, hp).astype(jnp.float32)
    la = dt * A                                          # log decay [B, T, nh]
    S0 = (jnp.zeros((B, nh, hp, N), jnp.float32) if cache is None
          else cache["ssm"].astype(jnp.float32))

    if T == 1:
        a = jnp.exp(la[:, 0])                            # [B, nh]
        S = (S0 * a[..., None, None]
             + dt[:, 0, :, None, None] * xh[:, 0][..., None]
             * Bm[:, 0][:, None, None, :])
        y = jnp.einsum("bhpn,bn->bhp", S, Cm[:, 0])[:, None]
        y = y.reshape(B, 1, nh, hp)
        S_out = S
    else:
        Q = CHUNK if T % CHUNK == 0 else (T if T < CHUNK else None)
        assert Q is not None, f"T={T} must be a multiple of {CHUNK} or < {CHUNK}"
        nch = T // Q
        la_c = la.reshape(B, nch, Q, nh).transpose(1, 0, 2, 3)
        xh_c = xh.reshape(B, nch, Q, nh, hp).transpose(1, 0, 2, 3, 4)
        Bm_c = Bm.reshape(B, nch, Q, N).transpose(1, 0, 2, 3)
        Cm_c = Cm.reshape(B, nch, Q, N).transpose(1, 0, 2, 3)
        dt_c = dt.reshape(B, nch, Q, nh).transpose(1, 0, 2, 3)

        def chunk_step(S, inp):
            lac, xc, Bc, Cc, dtc = inp
            # cumulative log-decay within the chunk, inclusive: P_t
            cum = jnp.cumsum(lac, axis=1)                # [B, Q, nh]
            # intra-chunk kernel M[t,s] = exp(P_t - P_s) * (C_t . B_s) * dt_s
            rel = cum[:, :, None, :] - cum[:, None, :, :]    # [B, Q, Q, nh]
            causal = jnp.tril(jnp.ones((Q, Q), bool))
            decay = jnp.where(causal[None, :, :, None], jnp.exp(rel), 0.0)
            cb = jnp.einsum("bqn,bsn->bqs", Cc, Bc)          # [B, Q, Q]
            M = decay * cb[..., None] * dtc[:, None, :, :]   # [B, Q, Q, nh]
            y_intra = jnp.einsum("bqsh,bshp->bqhp", M, xc)
            # inter-chunk: y_carry[t] = C_t . (exp(P_t) * S_prev)
            y_carry = jnp.einsum("bqn,bhpn,bqh->bqhp",
                                 Cc, S, jnp.exp(cum))
            # state update: S' = exp(P_Q) S + sum_s exp(P_Q - P_s) dt_s x_s B_s^T
            tail = jnp.exp(cum[:, -1:, :] - cum)             # [B, Q, nh]
            S_new = (S * jnp.exp(cum[:, -1])[..., None, None]
                     + jnp.einsum("bsh,bshp,bsn->bhpn",
                                  tail * dtc, xc, Bc))
            return S_new, y_intra + y_carry

        S_out, ys = jax.lax.scan(chunk_step, S0,
                                 (la_c, xh_c, Bm_c, Cm_c, dt_c))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, nh, hp)

    y = y + p["D"][:, None] * xh.reshape(B, T, nh, hp)
    out = (y.reshape(B, T, di).astype(x.dtype) * z) @ p["wo"]
    new_cache = dict(ssm=S_out.astype(jnp.float32), conv=conv_state)
    return out, new_cache


def mamba2_cache_init(cfg: ModelConfig, B: int, dtype=jnp.float32) -> dict:
    return dict(
        ssm=jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32),
        conv=jnp.zeros((B, cfg.conv_width - 1, cfg.di), dtype))
