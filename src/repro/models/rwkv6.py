"""RWKV6 ("Finch") token mixer with data-dependent decay.

Per head (hd = 64): state S ∈ R^{hd × hd},
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with per-channel decay w_t = exp(-exp(w0 + LoRA(x_t))) — the data-dependent
decay that distinguishes RWKV6 from RWKV4/5 — and token-shift interpolation
on every projection input.

Training scans chunks: within a chunk the recurrence is evaluated in closed
form with cumulative decay products (exact), so HLO contains T/chunk scan
steps of dense einsums rather than T sequential steps. Decode is O(1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

CHUNK = 64
LORA_R = 64


def rwkv6_params(rng, cfg: ModelConfig, stacked: int | None = None) -> dict:
    d = cfg.d_model
    keys = jax.random.split(rng, 9)

    def mk(key, shp, fan):
        full = shp if stacked is None else (stacked,) + shp
        return (jax.random.normal(key, full, jnp.float32) * fan ** -0.5
                ).astype(cfg.jdtype)

    def mkf(val, shp):
        full = shp if stacked is None else (stacked,) + shp
        return jnp.broadcast_to(jnp.asarray(val, jnp.float32), full).copy()

    H, hd = d // 64, 64
    return dict(
        wr=mk(keys[0], (d, d), d), wk=mk(keys[1], (d, d), d),
        wv=mk(keys[2], (d, d), d), wg=mk(keys[3], (d, d), d),
        wo=mk(keys[4], (d, d), d),
        # data-dependent decay: w0 + B(A x)
        w0=mkf(-6.0, (d,)),
        wA=mk(keys[5], (d, LORA_R), d), wB=mk(keys[6], (LORA_R, d), LORA_R),
        u=mkf(0.5, (H, hd)),
        mu=mkf(0.5, (5, d)),           # token-shift lerp per projection
    )


def _shift(x: jnp.ndarray, x_prev: jnp.ndarray | None) -> jnp.ndarray:
    """x_{t-1} sequence; x_prev is the last token of the previous call."""
    first = (jnp.zeros_like(x[:, :1]) if x_prev is None
             else x_prev[:, None].astype(x.dtype))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def rwkv6_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                cache: dict | None):
    """x: [B, T, d] -> ([B, T, d], cache(state=[B,H,hd,hd], xprev=[B,d]))."""
    B, T, d = x.shape
    H, hd = d // 64, 64
    xs = _shift(x, None if cache is None else cache["xprev"])
    mu = p["mu"].astype(x.dtype)
    def mix(i):
        return x * mu[i] + xs * (1 - mu[i])
    r = (mix(0) @ p["wr"]).reshape(B, T, H, hd).astype(jnp.float32)
    k = (mix(1) @ p["wk"]).reshape(B, T, H, hd).astype(jnp.float32)
    v = (mix(2) @ p["wv"]).reshape(B, T, H, hd).astype(jnp.float32)
    g = jax.nn.silu(mix(3) @ p["wg"])
    lw = (p["w0"].astype(jnp.float32)
          + (mix(4).astype(jnp.float32) @ p["wA"].astype(jnp.float32))
          @ p["wB"].astype(jnp.float32))                      # [B, T, d]
    logw = -jnp.exp(lw).reshape(B, T, H, hd)                  # log decay < 0
    u = p["u"].astype(jnp.float32)

    S0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if cache is None
          else cache["state"].astype(jnp.float32))

    if T == 1:
        kv = k[:, 0][..., None] * v[:, 0][..., None, :]        # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", r[:, 0],
                       S0 + u[..., None] * kv)[:, None]        # [B,1,H,hd]
        S_out = jnp.exp(logw[:, 0])[..., None] * S0 + kv
        y = y.reshape(B, 1, H, hd)
    else:
        Q = CHUNK if T % CHUNK == 0 else (T if T < CHUNK else None)
        assert Q is not None, f"T={T} must divide chunk {CHUNK} or be smaller"
        nch = T // Q

        def to_chunks(a):
            return a.reshape(B, nch, Q, H, hd).transpose(1, 0, 2, 3, 4)

        rc, kc, vc, lwc = map(to_chunks, (r, k, v, logw))

        def chunk_step(S, inp):
            rq, kq, vq, lq = inp                # [B, Q, H, hd]
            cum = jnp.cumsum(lq, axis=1)        # inclusive cumulative log-decay
            cum_excl = cum - lq                 # exclusive
            # intra-chunk: y[t] += sum_{s<t} (r_t * prodw_{s+1..t-1}... ) exact:
            # contribution of s to t (s < t): r_t . diag(exp(cum_excl_t - cum_s))
            #   ... note state at t-1 includes k_s v_s^T decayed by w_{s+1..t-1}
            #   = exp(cum_excl[t] - cum[s])  (zero extra decay when s = t-1)
            rel = cum_excl[:, :, None] - cum[:, None, :]       # [B,Tq,Ts,H,hd]
            causal = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
            dec = jnp.where(causal[None, :, :, None, None], jnp.exp(rel), 0.0)
            att = jnp.einsum("bthk,btshk,bshk->bths", rq, dec, kq)
            y_intra = jnp.einsum("bths,bshv->bthv", att, vq)
            # bonus (s = t): r_t . diag(u) k_t v_t^T
            bonus = jnp.einsum("bthk,hk,bthk->bth", rq, u, kq)
            y_intra = y_intra + bonus[..., None] * vq
            # carry: y[t] += r_t exp(cum_excl[t]) . S
            y_carry = jnp.einsum("bthk,bthk,bhkv->bthv",
                                 rq, jnp.exp(cum_excl), S)
            # state: S' = diag(exp(cum[-1])) S + sum_s exp(cum[-1]-cum[s]) k_s v_s^T
            tail = jnp.exp(cum[:, -1:] - cum)                  # [B, Q, H, hd]
            S_new = (jnp.exp(cum[:, -1])[..., None] * S
                     + jnp.einsum("bshk,bshv->bhkv", tail * kq, vq))
            return S_new, y_intra + y_carry

        S_out, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, lwc))
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd)

    # Per-head group norm, then gate and output projection.
    yn = y * jax.lax.rsqrt(jnp.mean(y * y, axis=-1, keepdims=True) + 1e-6)
    out = (yn.reshape(B, T, d).astype(x.dtype) * g) @ p["wo"]
    return out, dict(state=S_out, xprev=x[:, -1].astype(jnp.float32))


def rwkv6_cache_init(cfg: ModelConfig, B: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    return dict(state=jnp.zeros((B, d // 64, 64, 64), jnp.float32),
                xprev=jnp.zeros((B, d), jnp.float32))
