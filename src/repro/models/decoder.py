"""Composable decoder covering all ten assigned architectures.

Layer stacks are `jax.lax.scan` over stacked parameters, so HLO size is
independent of depth (81-layer zamba2 compiles as fast as 24-layer qwen2).
The hybrid (zamba2) family is structured as super-blocks: `attn_every`
mamba2 layers followed by ONE shared attention block (single weight set
reused at every invocation, per the Zamba2 design), scanned over
super-blocks so the shared-attention KV cache has one slot per invocation
rather than per layer.

Public entry points (used by training, serving, and the dry-run):
    init_params(rng, cfg)
    train_loss(params, cfg, batch)             # next-token CE
    prefill(params, cfg, tokens[, prefix])     # -> (last_logits, cache)
    decode_step(params, cfg, cache, tokens, pos)  # -> (logits, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import (attention_apply, attention_block_params,
                     chunked_ce_loss, mlp_apply, mlp_params, rms_norm)
from .mamba2 import mamba2_apply, mamba2_cache_init, mamba2_params
from .moe import moe_apply, moe_params
from .rwkv6 import rwkv6_apply, rwkv6_cache_init, rwkv6_params


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def _layer_params(rng, cfg: ModelConfig, stacked: int) -> dict:
    k_mix, k_ch, k_n = jax.random.split(rng, 3)
    p = dict(ln1=jnp.ones((stacked, cfg.d_model), jnp.float32),
             ln2=jnp.ones((stacked, cfg.d_model), jnp.float32))
    if cfg.token_mixer == "attention":
        p["attn"] = attention_block_params(k_mix, cfg, stacked=stacked)
    elif cfg.token_mixer == "mamba2":
        p["mamba"] = mamba2_params(k_mix, cfg, stacked=stacked)
    elif cfg.token_mixer == "rwkv6":
        p["rwkv"] = rwkv6_params(k_mix, cfg, stacked=stacked)
    else:
        raise ValueError(cfg.token_mixer)
    if cfg.n_experts:
        p["moe"] = moe_params(k_ch, cfg, stacked=stacked)
    else:
        p["mlp"] = mlp_params(k_ch, cfg.d_model, cfg.d_ff, cfg.jdtype,
                              stacked=stacked)
    del k_n
    return p


def init_params(rng, cfg: ModelConfig) -> dict:
    ks = jax.random.split(rng, 6)
    nq = max(cfg.n_codebooks, 1)
    embed_shape = ((cfg.vocab_size, cfg.d_model) if nq == 1
                   else (nq, cfg.vocab_size, cfg.d_model))
    head_shape = ((cfg.d_model, cfg.vocab_size) if nq == 1
                  else (nq, cfg.d_model, cfg.vocab_size))
    params = dict(
        embed=(jax.random.normal(ks[0], embed_shape, jnp.float32)
               * cfg.d_model ** -0.5).astype(cfg.jdtype),
        head=(jax.random.normal(ks[1], head_shape, jnp.float32)
              * cfg.d_model ** -0.5).astype(cfg.jdtype),
        final_norm=jnp.ones((cfg.d_model,), jnp.float32))
    if cfg.attn_every:  # hybrid super-block layout
        n_super, tail = _hybrid_shape(cfg)
        params["layers"] = _layer_params(ks[2], cfg,
                                         stacked=n_super * cfg.attn_every)
        if tail:
            params["tail"] = _layer_params(ks[3], cfg, stacked=tail)
        params["shared_attn"] = dict(
            attn=attention_block_params(ks[4], cfg),
            ln=jnp.ones((cfg.d_model,), jnp.float32))
    else:
        params["layers"] = _layer_params(ks[2], cfg, stacked=cfg.n_layers)
    if cfg.n_prefix_embeds:
        params["prefix_proj"] = (
            jax.random.normal(ks[5], (cfg.d_model, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5).astype(cfg.jdtype)
    return params


def _hybrid_shape(cfg: ModelConfig) -> tuple[int, int]:
    """(#super-blocks, #tail mamba layers) for attn_every-hybrid stacks."""
    n_super = cfg.n_layers // cfg.attn_every
    tail = cfg.n_layers - n_super * cfg.attn_every
    return n_super, tail


# ---------------------------------------------------------------------------
# Single layer body
# ---------------------------------------------------------------------------

def _channel_mix(lp: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(x, lp["ln2"])
    if cfg.n_experts:
        return x + moe_apply(lp["moe"], cfg, h)
    return x + mlp_apply(lp["mlp"], h)


def _layer_body(lp: dict, cfg: ModelConfig, x: jnp.ndarray,
                cache_l, pos0, window: int | None):
    h = rms_norm(x, lp["ln1"])
    if cfg.token_mixer == "attention":
        out, new_cache = attention_apply(lp["attn"], cfg, h, cache_l, pos0,
                                         window=window)
    elif cfg.token_mixer == "mamba2":
        out, new_cache = mamba2_apply(lp["mamba"], cfg, h, cache_l)
    else:
        out, new_cache = rwkv6_apply(lp["rwkv"], cfg, h, cache_l)
    x = x + out
    x = _channel_mix(lp, cfg, x)
    return x, new_cache


# ---------------------------------------------------------------------------
# Stacked forward (scan over layers / super-blocks)
# ---------------------------------------------------------------------------

def _scan_layers(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                 cache: dict | None, pos0, window: int | None):
    """Returns (hidden, new_cache)."""
    def body(carry, inp):
        lp, cache_l = inp
        h, new_c = _layer_body(lp, cfg, carry, cache_l, pos0, window)
        return h, new_c

    body_fn = jax.checkpoint(body) if cfg.remat else body

    if cfg.attn_every:
        return _scan_hybrid(params, cfg, x, cache, pos0, window, body_fn)

    cache_xs = None if cache is None else cache["layers"]
    xs = (params["layers"], cache_xs)
    h, new_cache_xs = jax.lax.scan(body_fn, x, xs)
    return h, (None if cache is None else dict(layers=new_cache_xs))


def _scan_hybrid(params, cfg, x, cache, pos0, window, body_fn):
    n_super, tail = _hybrid_shape(cfg)
    E = cfg.attn_every
    sa = params["shared_attn"]

    def super_block(carry, inp):
        h, attn_cache_slot = carry if isinstance(carry, tuple) else (carry, None)
        lp_group, mamba_cache_group, attn_cache_l = inp
        # E mamba layers (unrolled within the super-block: E is small).
        new_m_caches = []
        for e in range(E):
            lp_e = jax.tree.map(lambda a: a[e], lp_group)  # noqa: B023
            c_e = (None if mamba_cache_group is None
                   else jax.tree.map(lambda a: a[e], mamba_cache_group))  # noqa: B023
            h, nc = _layer_body(lp_e, cfg, h, c_e, pos0, window)
            new_m_caches.append(nc)
        # shared attention block (single weight set)
        hn = rms_norm(h, sa["ln"])
        out, new_attn_c = attention_apply(sa["attn"], cfg, hn, attn_cache_l,
                                          pos0, window=window)
        h = h + out
        new_m = (None if mamba_cache_group is None else
                 jax.tree.map(lambda *a: jnp.stack(a), *new_m_caches))
        return h, (new_m, new_attn_c)

    # reshape stacked mamba params (n_super*E, ...) -> (n_super, E, ...)
    lp_groups = jax.tree.map(
        lambda a: a.reshape((n_super, E) + a.shape[1:]), params["layers"])
    if cache is None:
        xs = (lp_groups, None, None)
        def body2(carry, inp):
            h, _ = super_block((carry, None), inp)
            return h, None
        h, _ = jax.lax.scan(jax.checkpoint(body2) if cfg.remat else body2,
                            x, xs)
        new_cache = None
    else:
        xs = (lp_groups, cache["mamba"], cache["attn"])
        def body3(carry, inp):
            h, (new_m, new_a) = super_block((carry, None), inp)
            return h, (new_m, new_a)
        h, (new_m_all, new_a_all) = jax.lax.scan(
            jax.checkpoint(body3) if cfg.remat else body3, x, xs)
        new_cache = dict(mamba=new_m_all, attn=new_a_all,
                         tail=cache.get("tail"))
    # tail mamba layers (unrolled: tail < attn_every)
    if tail:
        new_tail = []
        for e in range(tail):
            lp_e = jax.tree.map(lambda a: a[e], params["tail"])  # noqa: B023
            c_e = (None if cache is None
                   else jax.tree.map(lambda a: a[e], cache["tail"]))  # noqa: B023
            h, nc = _layer_body(lp_e, cfg, h, c_e, pos0, window)
            new_tail.append(nc)
        if cache is not None:
            new_cache["tail"] = jax.tree.map(lambda *a: jnp.stack(a),
                                             *new_tail)
    return h, new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def _embed(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
           prefix: jnp.ndarray | None) -> jnp.ndarray:
    if cfg.n_codebooks:
        # tokens: [B, T, nq] — sum the per-codebook embeddings.
        x = sum(params["embed"][qb][tokens[..., qb]]
                for qb in range(cfg.n_codebooks))
    else:
        x = params["embed"][tokens]
    if prefix is not None:
        pre = prefix.astype(x.dtype) @ params["prefix_proj"]
        x = jnp.concatenate([pre, x], axis=1)
    return x


def _logits(params: dict, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rms_norm(h, params["final_norm"])
    if cfg.n_codebooks:
        return jnp.einsum("btd,qdv->btqv", h, params["head"])
    return h @ params["head"]


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def train_loss(params: dict, cfg: ModelConfig, batch: dict) -> jnp.ndarray:
    """Next-token cross-entropy. batch: tokens [B,S] (or [B,S,nq]),
    targets same shape, optional prefix [B,P,d_model]."""
    prefix = batch.get("prefix")
    x = _embed(params, cfg, batch["tokens"], prefix)
    h, _ = _scan_layers(params, cfg, x, None, jnp.int32(0),
                        window=cfg.sliding_window or None)
    h = rms_norm(h, params["final_norm"])
    P = 0 if prefix is None else prefix.shape[1]
    h = h[:, P:]
    if cfg.n_codebooks:
        losses = [chunked_ce_loss(params["head"][q], h,
                                  batch["targets"][..., q], cfg.loss_chunk)
                  for q in range(cfg.n_codebooks)]
        return jnp.mean(jnp.stack(losses))
    return chunked_ce_loss(params["head"], h, batch["targets"],
                           cfg.loss_chunk)


def init_cache(cfg: ModelConfig, B: int, max_len: int) -> dict:
    """KV/state cache sized for `max_len` total positions."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    S = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    dt = cfg.jdtype

    def attn_cache(n):
        return (jnp.zeros((n, B, S, KV, hd), dt),
                jnp.zeros((n, B, S, KV, hd), dt))

    if cfg.attn_every:
        n_super, tail = _hybrid_shape(cfg)
        m = mamba2_cache_init(cfg, B, dt)
        return dict(
            mamba=jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (n_super, cfg.attn_every) + a.shape).copy(), m),
            attn=attn_cache(n_super),
            tail=(jax.tree.map(
                lambda a: jnp.broadcast_to(a, (tail,) + a.shape).copy(), m)
                if tail else None))
    if cfg.token_mixer == "attention":
        return dict(layers=attn_cache(cfg.n_layers))
    if cfg.token_mixer == "mamba2":
        m = mamba2_cache_init(cfg, B, dt)
    else:
        m = rwkv6_cache_init(cfg, B, dt)
    return dict(layers=jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape).copy(), m))


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            prefix: jnp.ndarray | None = None, max_len: int | None = None):
    """Process the prompt; return (last-position logits, filled cache)."""
    B = tokens.shape[0]
    T = tokens.shape[1] + (0 if prefix is None else prefix.shape[1])
    cache = init_cache(cfg, B, max_len or T)
    x = _embed(params, cfg, tokens, prefix)
    h, cache = _scan_layers(params, cfg, x, cache, jnp.int32(0),
                            window=cfg.sliding_window or None)
    logits = _logits(params, cfg, h[:, -1:])
    return logits, cache


def decode_step(params: dict, cfg: ModelConfig, cache: dict,
                tokens: jnp.ndarray, pos: jnp.ndarray):
    """One autoregressive step. tokens: [B, 1] (or [B, 1, nq]); pos: scalar
    int32 — the number of positions already in the cache."""
    x = _embed(params, cfg, tokens, None)
    h, cache = _scan_layers(params, cfg, x, cache, pos,
                            window=cfg.sliding_window or None)
    return _logits(params, cfg, h), cache
