"""Mixture-of-Experts channel mixer (scatter-dispatch, capacity-based).

TPU-native design: tokens are dispatched into dense per-expert buffers
[E, C, d] via cumsum-ranked scatter (no [N, E, C] one-hot einsum), expert
FFNs run as one grouped einsum over the stacked expert weights, and results
are combined by gather. Under expert-parallel sharding (E over the `model`
mesh axis) GSPMD lowers the dispatch/combine into all-to-all — the
collective the paper's MoE-serving discussion revolves around.

Compute cost is capacity-bound: E*C = N * top_k * capacity_factor tokens,
so HLO FLOPs reflect ACTIVE parameters (6*N_active*D), which is what the
roofline analysis checks against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import mlp_apply, mlp_params


def moe_params(rng, cfg: ModelConfig, stacked: int | None = None) -> dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)

    def mk(key, shp, fan):
        full = shp if stacked is None else (stacked,) + shp
        return (jax.random.normal(key, full, jnp.float32) * fan ** -0.5
                ).astype(cfg.jdtype)

    p = dict(
        router=mk(k1, (d, E), d).astype(jnp.float32),
        w1=mk(k2, (E, d, f), d), w3=mk(k3, (E, d, f), d),
        w2=mk(k4, (E, f, d), f))
    if cfg.moe_w8a8:
        # INT8 weight storage (the paper's nu=0.5 INT8 tier): per-expert,
        # per-out-channel symmetric scales.
        for name in ("w1", "w3", "w2"):
            w = p[name].astype(jnp.float32)
            scale = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 127.0
            p[name] = jnp.round(w / jnp.maximum(scale, 1e-9)).astype(jnp.int8)
            p[name + "_s"] = scale.astype(jnp.float32)
    if cfg.shared_expert_ff:
        p["shared"] = mlp_params(k5, d, cfg.shared_expert_ff, cfg.jdtype,
                                 stacked=stacked)
    return p


def _quant_act(x: jnp.ndarray):
    """Dynamic per-row symmetric int8 quantization of activations."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    q = jnp.round(x.astype(jnp.float32)
                  / jnp.maximum(scale, 1e-9)).astype(jnp.int8)
    return q, scale


def _w8a8_ffn(p: dict, buf: jnp.ndarray) -> jnp.ndarray:
    """Expert SwiGLU with INT8 x INT8 -> INT32 matmuls (W8A8). Halves the
    expert weight stream — the decode phase's dominant HBM traffic — at the
    paper's mu=1.15 accuracy cost (§Perf hillclimb #3)."""
    qb, bs = _quant_act(buf)                               # [E,C,d], [E,C,1]
    h1 = jnp.einsum("ecd,edf->ecf", qb, p["w1"],
                    preferred_element_type=jnp.int32)
    h3 = jnp.einsum("ecd,edf->ecf", qb, p["w3"],
                    preferred_element_type=jnp.int32)
    h1 = h1.astype(jnp.float32) * bs * p["w1_s"]
    h3 = h3.astype(jnp.float32) * bs * p["w3_s"]
    h = jax.nn.silu(h1) * h3
    qh, hs = _quant_act(h)
    ho = jnp.einsum("ecf,efd->ecd", qh, p["w2"],
                    preferred_element_type=jnp.int32)
    return (ho.astype(jnp.float32) * hs * p["w2_s"]).astype(buf.dtype)


def moe_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: [B, T, d] -> [B, T, d]."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * T
    xf = x.reshape(N, d)
    # Router (fp32 for stable softmax/top-k).
    logits = xf.astype(jnp.float32) @ p["router"]          # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                    # [N, k]
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    C = max(1, int(N * k * cfg.capacity_factor / E))
    e_flat = idx.reshape(-1)                               # [N*k]
    # Rank of each (token, choice) within its expert: cumsum of one-hot.
    onehot = jax.nn.one_hot(e_flat, E, dtype=jnp.int32)    # [N*k, E]
    pos = (jnp.cumsum(onehot, axis=0) - onehot)            # exclusive prefix
    slot = jnp.take_along_axis(pos, e_flat[:, None], axis=1)[:, 0]  # [N*k]
    keep = slot < C                                        # capacity drop
    slot_c = jnp.where(keep, slot, 0)
    e_safe = jnp.where(keep, e_flat, 0)

    # Dispatch: scatter token copies into [E, C, d] buffers.
    xk = jnp.repeat(xf, k, axis=0)                         # [N*k, d]
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[e_safe, slot_c].add(jnp.where(keep[:, None], xk, 0))
    if cfg.moe_expert_shard_constraint:
        # Pin the dispatch buffers to expert-parallel layout so the
        # token->expert movement lowers as all-to-all instead of a full
        # buffer all-reduce (§Perf hillclimb #2).
        from jax.sharding import PartitionSpec as P
        try:
            buf = jax.lax.with_sharding_constraint(buf, P("model", None, None))
        except Exception:
            pass  # no ambient mesh

    # Expert FFN (grouped SwiGLU einsum over stacked expert weights).
    if cfg.moe_w8a8 and "w1_s" in p:
        ho = _w8a8_ffn(p, buf)
    else:
        h1 = jnp.einsum("ecd,edf->ecf", buf, p["w1"])
        h3 = jnp.einsum("ecd,edf->ecf", buf, p["w3"])
        ho = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h1) * h3, p["w2"])
    if cfg.moe_expert_shard_constraint:
        from jax.sharding import PartitionSpec as P
        try:
            ho = jax.lax.with_sharding_constraint(ho, P("model", None, None))
        except Exception:
            pass

    # Combine: gather each copy's result, weight by its gate.
    out_k = ho[e_safe, slot_c]                             # [N*k, d]
    out_k = jnp.where(keep[:, None], out_k, 0)
    out = (out_k.reshape(N, k, d)
           * gate[..., None].astype(x.dtype)).sum(axis=1)  # [N, d]

    if "shared" in p:
        out = out + mlp_apply(p["shared"], xf)
    return out.reshape(B, T, d)


def load_balance_loss(logits: jnp.ndarray, idx: jnp.ndarray, E: int) -> jnp.ndarray:
    """Switch-style auxiliary loss (exported for the training loop)."""
    probs = jax.nn.softmax(logits, axis=-1)
    me = probs.mean(axis=0)
    ce = jnp.bincount(idx.reshape(-1), length=E) / idx.size
    return E * jnp.sum(me * ce)
