from .config import ModelConfig
from . import decoder
