from . import decoder
from .config import ModelConfig
