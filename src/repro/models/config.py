"""Model configuration shared by every assigned architecture.

A single composable decoder covers all ten architectures:
  token mixer   : attention | mamba2 | rwkv6 | hybrid (mamba2 + shared attn)
  channel mixer : dense SwiGLU | MoE (scatter-dispatch, capacity-based)
  io            : single vocab | multi-codebook (audio) | prefix embeds (vlm)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    # --- mixers ---------------------------------------------------------
    token_mixer: str = "attention"  # attention | mamba2 | rwkv6
    attn_every: int = 0             # >0: shared attention block period (zamba2)
    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 1
    shared_expert_ff: int = 0       # 0 = no shared expert
    capacity_factor: float = 1.25
    # --- SSM --------------------------------------------------------------
    ssm_state: int = 0
    d_inner: int = 0                # 0 -> 2 * d_model
    ssm_head_dim: int = 64
    conv_width: int = 4
    # --- io ----------------------------------------------------------------
    n_codebooks: int = 0            # >0: musicgen-style multi-stream tokens
    n_prefix_embeds: int = 0        # >0: vlm/audio stub frontend embeddings
    # --- attention variants -------------------------------------------------
    sliding_window: int = 0         # 0 = full causal attention
    rope_theta: float = 1e6
    # --- numerics -------------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True
    # --- beyond-paper performance variants (§Perf hillclimbs; default off =
    # paper-faithful baseline) ---------------------------------------------
    seq_shard_attention: bool = False   # context-parallel prefill attention
    moe_expert_shard_constraint: bool = False  # pin expert buffers to 'model'
    moe_w8a8: bool = False              # INT8 expert matmuls (paper's nu=0.5
    #                                     INT8 tier realized as W8A8)
    # --- loss ---------------------------------------------------------------
    loss_chunk: int = 256           # seq-chunked cross-entropy block

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def di(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.di // self.ssm_head_dim

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def has_attention(self) -> bool:
        return self.token_mixer == "attention" or self.attn_every > 0

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch serve 500k+ contexts? (SSM state or sliding window)"""
        return self.token_mixer in ("mamba2", "rwkv6") or self.sliding_window > 0

    def smoke(self) -> "ModelConfig":
        """Reduced same-family variant for CPU smoke tests: 2 layers,
        d_model <= 512, <= 4 experts (assignment requirement)."""
        d = min(self.d_model, 256)
        hd = 32
        n_heads = max(2, min(4, self.n_heads)) if self.n_heads else 0
        n_kv = max(1, min(self.n_kv_heads, n_heads)) if self.n_heads else 0
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=2, d_model=d,
            n_heads=n_heads, n_kv_heads=n_kv, head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.n_experts else 1,
            shared_expert_ff=min(self.shared_expert_ff, 128)
            if self.shared_expert_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            d_inner=2 * d if self.d_inner else 0,
            ssm_head_dim=32,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
            n_prefix_embeds=min(self.n_prefix_embeds, 4)
            if self.n_prefix_embeds else 0,
            loss_chunk=64,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytical parameter count (total)."""
        n = self.vocab_size * self.d_model * max(self.n_codebooks, 1)   # embed
        n += self.d_model * self.vocab_size * max(self.n_codebooks, 1)  # head
        per = 2 * self.d_model                                          # norms
        if self.token_mixer == "attention":
            per += self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.hd
            per += self.n_heads * self.hd * self.d_model
        elif self.token_mixer == "mamba2":
            di, N, nh = self.di, self.ssm_state, self.ssm_heads
            per += self.d_model * (2 * di + 2 * N + nh) + di * self.d_model
            per += (di + 2 * N) * self.conv_width + 2 * nh
        elif self.token_mixer == "rwkv6":
            per += 5 * self.d_model * self.d_model + self.d_model * 64 * 2
        if self.n_experts:
            per += self.d_model * self.n_experts                        # router
            per += 3 * self.n_experts * self.d_model * self.d_ff
            if self.shared_expert_ff:
                per += 3 * self.d_model * self.shared_expert_ff
        else:
            per += 3 * self.d_model * self.d_ff
        n += per * self.n_layers
        if self.attn_every:
            n += (self.d_model * (self.n_heads + 2 * self.n_kv_heads) * self.hd
                  + self.n_heads * self.hd * self.d_model + 2 * self.d_model)
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: top_k + shared only)."""
        if not self.n_experts:
            return self.param_count()
        total = self.param_count()
        moe_all = 3 * self.n_experts * self.d_model * self.d_ff * self.n_layers
        moe_act = 3 * self.top_k * self.d_model * self.d_ff * self.n_layers
        return total - moe_all + moe_act
