"""Shared neural-network primitives (pure JAX, no framework deps).

All functions are functional: params in, activations out. Attention is
implemented as a scan over query chunks with streaming softmax — the same
math as the Pallas flash kernel in `repro.kernels.flash_attention` (which is
the TPU runtime path); this keeps prefill memory O(chunk * seq) so the
512-device dry-run lowers without multi-GB attention buffers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig

NEG_INF = -1e30


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., T, H, hd]; positions: [..., T] (broadcastable)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., T, hd/2]
    ang = ang[..., None, :]                             # [..., T, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (chunked-softmax reference; mirrors the flash kernel)
# ---------------------------------------------------------------------------

def _mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int) -> jnp.ndarray:
    """[Tq, Tk] boolean mask: causal, optionally sliding-window."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        m &= k_pos[None, :] > q_pos[:, None] - window
    return m


def attention_unchunked(q, k, v, q_pos, k_pos, window: int = 0):
    """Single-einsum attention: materializes [B, KV, G, Tq, Tk] logits.
    Used by the seq-sharded (context-parallel) prefill variant, where the
    partitioner splits Tq across the `model` axis — a scan over query
    chunks would serialize that dimension instead (§Perf hillclimb #1)."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s = s * (hd ** -0.5)
    m = _mask(q_pos, k_pos, window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, H, hd).astype(q.dtype)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              q_pos: jnp.ndarray, k_pos: jnp.ndarray, window: int = 0,
              block_q: int = 256, block_k: int = 1024) -> jnp.ndarray:
    """Grouped-query attention with streaming (online-softmax) blocking —
    the same two-level tiling as the Pallas flash kernel, expressed in XLA.
    q: [B, Tq, H, hd]; k, v: [B, Tk, KV, hd]; positions: [Tq], [Tk].
    Peak memory is O(B * H * block_q * block_k), independent of Tq * Tk.
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = hd ** -0.5
    qg = q.reshape(B, Tq, KV, G, hd)

    def kv_blocks(arr, bk):
        n = Tk // bk
        return arr.reshape(B, n, bk, KV, hd).transpose(1, 0, 2, 3, 4)

    def q_chunk(qc, qp):
        """qc: [B, c, KV, G, hd]; streaming softmax over K blocks."""
        c = qc.shape[1]
        qf = qc.astype(jnp.float32)

        def k_step(carry, inp):
            m_run, l_run, o_run = carry
            kb, vb, kp = inp                     # [B, bk, KV, hd], [bk]
            logits = jnp.einsum("bqkgh,bskh->bkgqs", qf,
                                kb.astype(jnp.float32)) * scale
            msk = _mask(qp, kp, window)          # [c, bk]
            logits = jnp.where(msk[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_run, logits.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(logits - m_new[..., None])
            l_new = l_run * alpha + p.sum(axis=-1)
            o_new = (o_run * alpha[..., None]
                     + jnp.einsum("bkgqs,bskh->bkgqh", p,
                                  vb.astype(jnp.float32)))
            return (m_new, l_new, o_new), None

        if Tk <= block_k:
            (m_f, l_f, o_f), _ = k_step(
                (jnp.full((B, KV, G, c), NEG_INF, jnp.float32),
                 jnp.zeros((B, KV, G, c), jnp.float32),
                 jnp.zeros((B, KV, G, c, hd), jnp.float32)),
                (k, v, k_pos))
        else:
            assert Tk % block_k == 0, (Tk, block_k)
            kb = kv_blocks(k, block_k)
            vb = kv_blocks(v, block_k)
            kpb = k_pos.reshape(Tk // block_k, block_k)
            (m_f, l_f, o_f), _ = jax.lax.scan(
                k_step,
                (jnp.full((B, KV, G, c), NEG_INF, jnp.float32),
                 jnp.zeros((B, KV, G, c), jnp.float32),
                 jnp.zeros((B, KV, G, c, hd), jnp.float32)),
                (kb, vb, kpb))
        out = o_f / jnp.clip(l_f, 1e-30)[..., None]     # [B, KV, G, c, hd]
        return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    if Tq <= block_q:
        out = q_chunk(qg, q_pos)
    else:
        assert Tq % block_q == 0, (Tq, block_q)
        n = Tq // block_q
        qs = qg.reshape(B, n, block_q, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
        ps = q_pos.reshape(n, block_q)
        out = jax.lax.map(lambda t: q_chunk(*t), (qs, ps))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tq, KV, G, hd)
    return out.reshape(B, Tq, H, hd)


def attention_block_params(rng, cfg: ModelConfig, stacked: int | None = None):
    """Init attention projection params; leading dim `stacked` if given."""
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    shapes = dict(
        wq=(d, H * hd), wk=(d, KV * hd), wv=(d, KV * hd), wo=(H * hd, d))
    keys = jax.random.split(rng, len(shapes) + 3)
    out = {}
    for (name, shp), key in zip(shapes.items(), keys, strict=False):
        full = shp if stacked is None else (stacked,) + shp
        out[name] = (jax.random.normal(key, full, jnp.float32)
                     * (shp[0] ** -0.5)).astype(cfg.jdtype)
    if cfg.qkv_bias:
        for name, width in [("bq", H * hd), ("bk", KV * hd), ("bv", KV * hd)]:
            full = (width,) if stacked is None else (stacked, width)
            out[name] = jnp.zeros(full, cfg.jdtype)
    return out


def attention_apply(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                    cache_kv: tuple[jnp.ndarray, jnp.ndarray] | None,
                    pos0: jnp.ndarray, window: int | None = None):
    """Apply one attention block.
    x: [B, T, d].  cache_kv: (k_cache, v_cache) each [B, S, KV, hd] holding
    positions [0, pos0); the block appends the new T keys/values.
    Returns (out [B, T, d], new_cache_kv).
    """
    B, T, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if window is None:
        window = cfg.sliding_window
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, KV, hd)
    v = v.reshape(B, T, KV, hd)
    q_pos = pos0 + jnp.arange(T)
    q = apply_rope(q, q_pos, cfg.rope_theta)
    k = apply_rope(k, q_pos, cfg.rope_theta)
    seqshard = getattr(cfg, "seq_shard_attention", False) and T > 1
    if seqshard:
        # Context parallelism for prefill: shard QUERIES over the `model`
        # mesh axis (heads may not divide it — e.g. 12 heads on a 16-wide
        # axis — which otherwise makes GSPMD replicate the whole attention
        # 16x; §Perf hillclimb #1). K/V are gathered once and replicated.
        # The unchunked einsum form is required: a scan over query chunks
        # would serialize the very dimension being sharded.
        from jax.sharding import PartitionSpec as P
        for bx in (("pod", "data"), "data", None):
            try:
                q = jax.lax.with_sharding_constraint(
                    q, P(bx, "model", None, None))
                k = jax.lax.with_sharding_constraint(
                    k, P(bx, None, None, None))
                v = jax.lax.with_sharding_constraint(
                    v, P(bx, None, None, None))
                break
            except Exception:
                continue  # axis not in mesh / no ambient mesh
    attn_fn = attention_unchunked if seqshard else attention
    if cache_kv is None:
        out = attn_fn(q, k, v, q_pos, q_pos, window=window)
        new_cache = (k, v)
    elif T > 1:
        # Prefill (pos0 == 0 by convention): attend over the fresh K/V with
        # the causal(+window) mask, then write them into the cache.
        out = attn_fn(q, k, v, q_pos, q_pos, window=window)
        kc, vc = cache_kv
        S = kc.shape[1]
        if window > 0 and S == window:
            # Ring buffer: keep only the last S keys (slots are unique).
            keep = min(T, S)
            slot = (pos0 + jnp.arange(T)[-keep:]) % S
            kc = kc.at[:, slot].set(k[:, -keep:])
            vc = vc.at[:, slot].set(v[:, -keep:])
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos0, axis=1)
        new_cache = (kc, vc)
    else:
        # Decode: append one position, attend against the cache.
        kc, vc = cache_kv
        S = kc.shape[1]
        if window > 0 and S == window:
            slot = (pos0 + jnp.arange(T)) % S
            kc = kc.at[:, slot].set(k)
            vc = vc.at[:, slot].set(v)
            # Absolute position stored in ring slot s: the largest
            # p <= pos0 + T - 1 with p % S == s; negative -> never written.
            ring_idx = jnp.arange(S)
            last = pos0 + T - 1
            k_pos_abs = last - ((last - ring_idx) % S)
            k_pos_abs = jnp.where(k_pos_abs < 0, jnp.int32(2 ** 30), k_pos_abs)
            out = attention(q, kc, vc, q_pos, k_pos_abs, window=window)
        else:
            kc = jax.lax.dynamic_update_slice_in_dim(kc, k, pos0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(vc, v, pos0, axis=1)
            k_pos = jnp.arange(S)
            valid = k_pos < pos0 + T
            kmask_pos = jnp.where(valid, k_pos, jnp.int32(2 ** 30))
            out = attention(q, kc, vc, q_pos, kmask_pos, window=window)
        new_cache = (kc, vc)
    out = out.reshape(B, T, H * hd) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_params(rng, d: int, f: int, dtype, stacked: int | None = None):
    k1, k2, k3 = jax.random.split(rng, 3)
    def mk(key, shp, fan):
        full = shp if stacked is None else (stacked,) + shp
        return (jax.random.normal(key, full, jnp.float32) * fan ** -0.5
                ).astype(dtype)
    return dict(w1=mk(k1, (d, f), d), w3=mk(k2, (d, f), d), w2=mk(k3, (f, d), f))


def mlp_apply(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]


# ---------------------------------------------------------------------------
# Chunked cross-entropy (never materializes [B, S, V] logits)
# ---------------------------------------------------------------------------

def _pick_chunk(S: int, target: int) -> int:
    """Largest divisor of S that is <= target."""
    for c in range(min(S, target), 0, -1):
        if S % c == 0:
            return c
    return S


def chunked_ce_loss(head: jnp.ndarray, xs: jnp.ndarray, targets: jnp.ndarray,
                    chunk: int) -> jnp.ndarray:
    """head: [d, V]; xs: [B, S, d]; targets: [B, S] int32. Mean NLL.
    Scans over sequence chunks so [B, S, V] logits never materialize."""
    B, S, d = xs.shape
    chunk = _pick_chunk(S, chunk)
    n = S // chunk

    def body(carry, t):
        xc, tc = t                                  # [B, c, d], [B, c]
        logits = (xc @ head).astype(jnp.float32)    # [B, c, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    xs_c = xs.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    tg_c = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xs_c, tg_c))
    return total / (B * S)
