"""Pipeline parallelism via shard_map + collective_permute.

The paper treats PP depth `m` as a first-class decision variable whose cost
is (i) an additive per-token inter-stage communication delay `m * d_comm`
and (ii) a pipeline-bubble utilization factor eta (8g). This module is the
TPU-native realization the planner's decision maps onto: layers are split
into `m` contiguous stages along a `stage` mesh axis; microbatches stream
through the stages with `jax.lax.ppermute` hand-offs (GPipe schedule).

Bubble accounting matches the paper's eta: with M microbatches and m stages
the schedule runs (M + m - 1) ticks, utilization = M / (M + m - 1); the
planner's eta = 0.9 corresponds to M ≈ 9 * (m - 1) microbatches.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_utilization(n_micro: int, n_stages: int) -> float:
    """GPipe utilization = M / (M + m - 1) — the paper's eta."""
    return n_micro / (n_micro + n_stages - 1)


def pipelined_forward(stage_fn: Callable, mesh: Mesh, n_stages: int,
                      n_micro: int):
    """Build a pipelined forward pass.

    stage_fn(stage_params, x) -> x: applies ONE stage's layers.
    Returns f(stacked_stage_params, x_microbatches) where
      stacked_stage_params: pytree with leading dim n_stages (sharded over
      the 'stage' mesh axis), x_microbatches: [n_micro, mb, ...] activations.

    Schedule: (n_micro + n_stages - 1) ticks; each tick every stage runs one
    microbatch (real or bubble), then activations ppermute to the next stage.
    """
    assert "stage" in mesh.axis_names

    def per_stage(params, xs):
        # params: stage-local slice (leading dim 1); xs: [n_micro, mb, ...]
        sp = jax.tree.map(lambda a: a[0], params)
        stage_id = jax.lax.axis_index("stage")
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xs[0])                 # current activation
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            mb_in = jnp.clip(t, 0, n_micro - 1)
            x0 = xs[mb_in]
            buf = jnp.where(stage_id == 0,
                            jnp.where(t < n_micro, x0, buf), buf)
            y = stage_fn(sp, buf)
            # last stage emits microbatch (t - n_stages + 1)
            mb_out = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid_out = (t >= n_stages - 1) & (stage_id == n_stages - 1)
            outs = jnp.where(valid_out,
                             outs.at[mb_out].set(y), outs)
            # hand activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, "stage", perm)
            return buf, outs

        buf, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # Only the last stage holds real outputs (other stages carry
        # zeros); psum over the stage axis replicates the result so the
        # P() out_spec is honest.
        return jax.lax.psum(outs, "stage")

    return shard_map(
        per_stage, mesh=mesh,
        in_specs=(P("stage"), P()),
        out_specs=P(),
        check_rep=False)


def split_stages(layer_params, n_stages: int):
    """Reshape stacked layer params [L, ...] -> [n_stages, L/m, ...]."""
    def r(a):
        L = a.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return a.reshape((n_stages, L // n_stages) + a.shape[1:])
    return jax.tree.map(r, layer_params)
