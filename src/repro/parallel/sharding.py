"""Rule-based, divisibility-checked sharding for every architecture.

Strategy (Megatron-TP + FSDP hybrid, TPU-native):
  * the `model` mesh axis carries tensor parallelism: projection output dims,
    expert dims (expert parallelism), SSM inner dims, attention head dims;
  * the `data` (and `pod`) axes carry the batch AND fully-sharded parameter
    storage (FSDP) on a second tensor dim;
  * every rule checks divisibility against the mesh axis sizes and falls
    back to replication — this is what lets ten heterogeneous architectures
    (odd vocab 92553, 14-head attention, 384-expert MoE) share one codebase.

GSPMD propagates activation shardings from these seeds; the dry-run records
the collectives it inserts (all-gather/reduce-scatter for FSDP, all-reduce
for TP contractions, all-to-all for expert dispatch).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def mesh_axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def batch_axes(mesh: Mesh):
    """Axes carrying the global batch."""
    ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return ax if ax else None


def fsdp_axes(mesh: Mesh):
    """Axes carrying fully-sharded parameter storage (same as batch)."""
    return batch_axes(mesh)


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return axes is not None and dim % mesh_axis_size(mesh, axes) == 0


def _matrix_spec(shape: tuple[int, ...], mesh: Mesh, n_stack: int,
                 model_dim: int, fsdp_dim: int) -> P:
    """Spec for a (possibly stacked) matrix: `model` on model_dim, FSDP on
    fsdp_dim, each guarded by divisibility."""
    spec: list[Any] = [None] * len(shape)
    if _fits(shape[model_dim], mesh, "model" if "model" in mesh.axis_names
             else None):
        spec[model_dim] = "model"
    fx = fsdp_axes(mesh)
    if fsdp_dim != model_dim and _fits(shape[fsdp_dim], mesh, fx):
        spec[fsdp_dim] = fx
    del n_stack
    return P(*spec)


# Parameter-name classification: which dim gets TP ('model').
_COL_PARALLEL = {"wq", "wk", "wv", "w1", "w3", "wx", "wz", "wB", "wC",
                 "wdt", "wA", "wg", "wr"}
_ROW_PARALLEL = {"wo", "w2", "wB_out"}
_REPLICATED = {"ln", "ln1", "ln2", "final_norm", "dt_bias", "A_log", "D",
               "u", "mu", "w0", "router", "bq", "bk", "bv"}


def _param_spec(path: tuple[str, ...], shape: tuple[int, ...],
                mesh: Mesh) -> P:
    name = path[-1]
    in_moe = "moe" in path
    nd = len(shape)
    if name in _REPLICATED or nd <= 1:
        return P(*([None] * nd))
    if name == "embed":
        # [V, d] or [nq, V, d]
        vdim, ddim = nd - 2, nd - 1
        spec: list[Any] = [None] * nd
        if _fits(shape[vdim], mesh, "model"):
            spec[vdim] = "model"
            if _fits(shape[ddim], mesh, fsdp_axes(mesh)):
                spec[ddim] = fsdp_axes(mesh)
        elif _fits(shape[ddim], mesh, "model"):
            spec[ddim] = "model"
        return P(*spec)
    if name == "head":
        # [d, V] or [nq, d, V]
        ddim, vdim = nd - 2, nd - 1
        spec = [None] * nd
        if _fits(shape[vdim], mesh, "model"):
            spec[vdim] = "model"
            if _fits(shape[ddim], mesh, fsdp_axes(mesh)):
                spec[ddim] = fsdp_axes(mesh)
        elif _fits(shape[ddim], mesh, "model"):
            spec[ddim] = "model"
        return P(*spec)
    if name == "prefix_proj":
        return _matrix_spec(shape, mesh, 0, nd - 1, nd - 2)
    if in_moe and name in ("w1", "w3", "w2") and nd >= 3:
        # Expert-parallel: [.., E, d, f] / [.., E, f, d] — E over `model`,
        # the wide inner dim over FSDP.
        edim = nd - 3
        spec = [None] * nd
        if _fits(shape[edim], mesh, "model"):
            spec[edim] = "model"
            wide = nd - 1 if name in ("w1", "w3") else nd - 2
            if _fits(shape[wide], mesh, fsdp_axes(mesh)):
                spec[wide] = fsdp_axes(mesh)
        else:  # fall back to plain TP on the f dim
            wide = nd - 1 if name in ("w1", "w3") else nd - 2
            if _fits(shape[wide], mesh, "model"):
                spec[wide] = "model"
        return P(*spec)
    if name == "conv":
        spec = [None] * nd
        if _fits(shape[-1], mesh, "model"):
            spec[-1] = "model"
        return P(*spec)
    if name in _COL_PARALLEL:
        return _matrix_spec(shape, mesh, 0, nd - 1, nd - 2)
    if name in _ROW_PARALLEL:
        return _matrix_spec(shape, mesh, 0, nd - 2, nd - 1)
    return P(*([None] * nd))


def param_specs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching `params`."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        names = tuple(getattr(k, "key", getattr(k, "idx", "?"))
                      for k in path)
        names = tuple(str(n) for n in names)
        specs.append(_param_spec(names, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(tdef, specs)


def opt_state_specs(params_spec: Any) -> dict:
    """AdamW moments inherit the parameter sharding (ZeRO-style)."""
    return dict(mu=params_spec, nu=params_spec, step=P())


def batch_spec(mesh: Mesh, shape: tuple[int, ...]) -> P:
    """Batch-leading arrays: shard dim 0 over ('pod','data') if divisible."""
    bx = batch_axes(mesh)
    if _fits(shape[0], mesh, bx):
        return P(bx, *([None] * (len(shape) - 1)))
    # try 'data' alone (multi-pod, batch not divisible by pod*data)
    if "data" in (bx or ()) and shape[0] % mesh.shape["data"] == 0:
        return P("data", *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def cache_specs(cache: Any, mesh: Mesh, prefer_hd: bool = False) -> Any:
    """KV/state caches: batch dim over data axes; heads (or window/seq) over
    `model` when divisible. Cache trees are stacked with a leading layer
    (or super-block) dim followed by batch.

    prefer_hd: for attention caches whose KV-head count does not divide the
    `model` axis, shard the head_dim instead of the sequence — decode then
    all-reduces per-step logits instead of all-gathering the cache
    (§Perf hillclimb #4)."""
    flat, tdef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for path, leaf in flat:
        names = tuple(str(getattr(k, "key", getattr(k, "idx", "?")))
                      for k in path)
        shp = leaf.shape
        nd = len(shp)
        s: list[Any] = [None] * nd
        bx = batch_axes(mesh)
        bdim = 1 if nd >= 2 else 0
        # mamba group caches are [n_super, E, B, ...]
        if "mamba" in names and nd >= 3:
            bdim = 2
        if nd > bdim and _fits(shp[bdim], mesh, bx):
            s[bdim] = bx
        if "ssm" in names:
            # [..., B, nh, hp, N] -> shard nh over model
            if _fits(shp[bdim + 1], mesh, "model"):
                s[bdim + 1] = "model"
        elif "state" in names:
            # rwkv [..., B, H, hd, hd] -> shard H
            if _fits(shp[bdim + 1], mesh, "model"):
                s[bdim + 1] = "model"
        elif "conv" in names or "xprev" in names:
            if _fits(shp[-1], mesh, "model"):
                s[-1] = "model"
        elif nd == 5:
            # attention cache [L, B, S, KV, hd]: KV over model, else S
            # (or hd under prefer_hd)
            if _fits(shp[3], mesh, "model"):
                s[3] = "model"
            elif prefer_hd and _fits(shp[4], mesh, "model"):
                s[4] = "model"
            elif _fits(shp[2], mesh, "model"):
                s[2] = "model"
        specs.append(P(*s))
    return jax.tree_util.tree_unflatten(tdef, specs)


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
