"""repro — Fast Heterogeneous Serving reproduction.

The package root re-exports the planner API lazily (`plan`,
`PlanRequest`, `PlanResult`, `register_solver`, ...): ``from repro import
plan`` works without importing the jax-heavy kernel / model / serving
subpackages, so the allocator stays usable in numpy/scipy-only
environments (and imports in milliseconds).  Everything else lives in its
subpackage: `repro.core` (allocator), `repro.planner` (facade),
`repro.kernels`, `repro.models`, `repro.serving`, ...
"""
from __future__ import annotations

# Lazily resolved from repro.planner (numpy/scipy only — no jax).
_PLANNER_EXPORTS = (
    "plan", "PlanOptions", "PlanRequest", "PlanResult", "PlanSession",
    "SolverSpec", "UnknownSolverError", "EngineUnavailableError",
    "register_solver", "solver_names",
    "unregister_solver", "FleetSpec", "WorkloadSpec", "SLOSpec",
    "ScenarioSpec", "scenario", "list_scenarios",
)

# Lazily resolved from repro.serving — the closed-loop driver surface.
# Also numpy-only: repro.serving defers its jax engine to first use, so
# ``from repro import serve`` works in numpy/scipy-only environments.
_SERVING_EXPORTS = (
    "serve", "ServeResult", "TrafficSpec", "ControllerSpec", "Station",
)

__all__ = list(_PLANNER_EXPORTS) + list(_SERVING_EXPORTS)


def __getattr__(name: str):
    if name in _PLANNER_EXPORTS:
        from repro import planner
        return getattr(planner, name)
    if name in _SERVING_EXPORTS:
        from repro import serving
        return getattr(serving, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
