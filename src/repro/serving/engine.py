"""Batched serving engine: prefill + decode with a planner-chosen config.

This is the execution layer the paper's allocator plans FOR. A `Deployment`
corresponds to one active (model, tier) pair with its (TP, PP) config; the
engine exposes `prefill_batch` / `decode_batch` jitted steps and a simple
continuous-batching loop for the end-to-end example.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import decoder
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [T] int32
    max_new_tokens: int
    arrived_s: float = 0.0
    first_token_s: float | None = None
    done_s: float | None = None
    output: list[int] = dataclasses.field(default_factory=list)


class Engine:
    """Single-deployment engine (one model, one parallelism config)."""

    def __init__(self, cfg: ModelConfig, params: Any, max_len: int,
                 max_batch: int):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self._prefill = jax.jit(
            lambda p, t: decoder.prefill(p, cfg, t, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, t, pos: decoder.decode_step(p, cfg, c, t, pos))

    def generate(self, requests: list[Request],
                 greedy: bool = True) -> list[Request]:
        """Static-batch generation: pad prompts to a common length, prefill
        once, decode until every request has its tokens."""
        t_start = time.perf_counter()
        B = len(requests)
        assert B <= self.max_batch
        Tp = max(len(r.prompt) for r in requests)
        toks = np.zeros((B, Tp), np.int32)
        for b, r in enumerate(requests):
            toks[b, -len(r.prompt):] = r.prompt      # left-pad
        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        step_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for r, t in zip(requests, np.asarray(step_tokens), strict=True):
            r.output.append(int(t))
            r.first_token_s = time.perf_counter() - t_start
        n_new = max(r.max_new_tokens for r in requests)
        pos = Tp
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         step_tokens[:, None],
                                         jnp.int32(pos))
            step_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            pos += 1
            for r, t in zip(requests, np.asarray(step_tokens), strict=True):
                if len(r.output) < r.max_new_tokens:
                    r.output.append(int(t))
        now = time.perf_counter() - t_start
        for r in requests:
            r.done_s = now
        return requests
