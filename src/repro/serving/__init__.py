"""repro.serving — closed-loop serving layer.

Public API (numpy/stdlib only — importing it never touches jax):

* `serve(plan, instance=..., traffic=TrafficSpec(...),
  controller=ControllerSpec(...)) -> ServeResult` — the closed-loop
  driver (`driver.py`): plan-aware routing, forecast-aware replanning,
  per-window observability;
* the typed specs/result (`types.py`), the concurrency-bound derivation
  (`stations.py`), the Mélange-style router (`router.py`), and the
  controller (`controller.py`);
* `simulate()` — the legacy open-loop simulator (`simulator.py`), kept
  with its original semantics (bit-identical under an explicit
  ``max_batch``);
* `Engine` — the jax batched execution engine, loaded lazily on first
  attribute access so the rest of the layer stays importable without jax.
"""
from __future__ import annotations

from .controller import ReplanController
from .driver import serve
from .router import Router
from .simulator import SimStats, simulate
from .stations import StationSim, build_stations, station_b_max
from .types import (ControllerSpec, ReplanEvent, ServeResult, Station,
                    TrafficSpec)

_ENGINE_EXPORTS = ("Engine", "Request")

__all__ = [
    "serve", "ServeResult", "TrafficSpec", "ControllerSpec", "Station",
    "ReplanEvent", "ReplanController", "Router", "StationSim",
    "build_stations", "station_b_max", "simulate", "SimStats",
    *_ENGINE_EXPORTS,
]


def __getattr__(name: str):
    if name in _ENGINE_EXPORTS:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(__all__))
