"""Plan-aware request router — Mélange's load balancer over the plan.

Mélange ships a tiny weighted-random load balancer over per-GPU profiled
throughputs; here the weights come straight from the plan: a type-i
request is dispatched to station s = (j, k) with probability
``x[i,j,k]`` and shed with the residual probability ``1 - sum_jk x`` (the
plan's unserved fraction ``u_i``) — so the simulated traffic split
converges to the routing LP's split as requests -> infinity, which the
router-conservation test pins.

Weighted-random (rather than deterministic round-robin over fractions)
is what the plan's analytical model assumes: Poisson splitting of a
Poisson arrival stream keeps each station's arrival process Poisson at
rate ``lam_i * x_ijk``.
"""
from __future__ import annotations

import numpy as np

from ..core.instance import Instance
from ..core.solution import Solution
from .types import Station

SHED = -1                        # route() sentinel: request not served


class Router:
    """Weighted-random dispatcher over the plan's routing fractions."""

    def __init__(self, inst: Instance, sol: Solution,
                 stations: list[Station]) -> None:
        I = inst.I
        S = len(stations)
        w = np.zeros((I, S))
        for s, st in enumerate(stations):
            w[:, s] = sol.x[:, st.j, st.k]
        # Cumulative weights against a unit draw: a uniform in [0, 1)
        # falling past cum[i, -1] (= sum_s x_ijk <= 1) is shed — exactly
        # the plan's unserved fraction u_i.
        self.weights = w
        self.cum = np.cumsum(w, axis=1)
        self.n_stations = S
        self.dispatched = np.zeros((I, S), dtype=np.int64)
        self.shed = np.zeros(I, dtype=np.int64)

    def route(self, qtype: int, u: float) -> int:
        """Station index for one type-`qtype` request given a uniform
        draw `u` in [0, 1); `SHED` when the draw lands in the unserved
        residual.  The caller owns the RNG so the arrival/length/routing
        streams stay reproducible in one place."""
        cum = self.cum[qtype]
        if self.n_stations == 0 or u >= cum[-1]:
            self.shed[qtype] += 1
            return SHED
        s = int(np.searchsorted(cum, u, side="right"))
        self.dispatched[qtype, s] += 1
        return s

    def dispatch_fractions(self) -> np.ndarray:
        """Observed per-(type, station) dispatch fractions (of arrivals,
        i.e. including shed mass) — converges to `weights` by the law of
        large numbers; the conservation test pins the tolerance."""
        total = self.dispatched.sum(axis=1) + self.shed
        with np.errstate(invalid="ignore", divide="ignore"):
            return np.where(total[:, None] > 0,
                            self.dispatched / np.maximum(total[:, None], 1),
                            0.0)
