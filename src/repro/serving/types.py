"""Typed public surface of the serving layer (`repro.serving`).

Mirrors the planner facade's conventions (`planner/api.py`): frozen spec
dataclasses whose field names are checked at the call site, one structured
result object with an exact JSON round trip, and `summary()` producing the
flat registry rows the benchmark dumps and the CI regression gate consume.

* `TrafficSpec`    — what traffic to synthesize (horizon, windows, Poisson
  thinning, diurnal trace day, length noise);
* `ControllerSpec` — how the replanning controller behaves (forecast /
  fixed-cadence / static, EWMA + trigger knobs);
* `Station`        — one deployed (model, tier) continuous-batching
  station as the plan committed it, with its derived concurrency bound;
* `ReplanEvent`    — one controller firing (cause, drift, wall time);
* `ServeResult`    — per-type latency/attainment metrics, per-window rows,
  the replan log, planner-time accounting, and the simulated-vs-analytical
  calibration ratios.

Everything here is numpy/stdlib only — importing it never touches jax.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

CONTROLLER_MODES = ("forecast", "fixed", "static")


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Synthetic traffic program for `serve()`.

    | field               | meaning                                       |
    |---------------------|-----------------------------------------------|
    | ``horizon_s``       | simulated wall-clock seconds                  |
    | ``window_s``        | observation/control window length (s)         |
    | ``rate_scale``      | Poisson thinning of the fleet-scale arrival   |
    |                     | rates (1.0 = the instance's full `lam`)       |
    | ``concurrency_scale``| matching thinning of each station's derived  |
    |                     | concurrency bound so utilization — hence      |
    |                     | queueing behaviour — is invariant under       |
    |                     | thinning; ``None`` = follow ``rate_scale``    |
    | ``trace``           | diurnal multiplier day ("busy"/"volatile")    |
    |                     | applied per window; ``None`` = stationary     |
    | ``trace_seed``      | noise seed of the synthetic trace             |
    | ``len_sigma``       | lognormal sigma of token-length noise         |
    | ``seed``            | RNG seed for arrivals/lengths/routing         |

    Thinning note: a thinned system at equal utilization queues slightly
    MORE than the fleet-scale one (fewer servers at the same load), so
    thinned attainment is a conservative estimate of fleet attainment.
    """
    horizon_s: float = 3600.0
    window_s: float = 300.0
    rate_scale: float = 1.0
    concurrency_scale: float | None = None
    trace: str | None = None
    trace_seed: int = 7
    len_sigma: float = 0.25
    seed: int = 0

    def effective_concurrency_scale(self) -> float:
        return (self.rate_scale if self.concurrency_scale is None
                else self.concurrency_scale)

    def n_windows(self) -> int:
        return max(1, int(np.ceil(self.horizon_s / self.window_s)))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "TrafficSpec":
        return TrafficSpec(**d)


@dataclasses.dataclass(frozen=True)
class ControllerSpec:
    """Replanning-controller program for `serve()`.

    ``mode``:

    * ``"forecast"`` — the tentpole controller: EWMA arrival-rate forecast
      (`core.forecast.EwmaForecaster`) + `DriftTrigger`; replans only when
      forecast drift against the incumbent plan's demand basis crosses
      ``drift_threshold`` or the observed per-window SLO-violation
      fraction exceeds ``violation_budget`` for ``budget_windows``
      consecutive windows;
    * ``"fixed"``    — the blind baseline: replan every ``replan_every``
      windows regardless of drift (PR 5's cadence, kept for comparison);
    * ``"static"``   — never replan (the frozen-plan floor).

    ``ewma_alpha`` matches `core.rolling.rolling(forecast_ewma=)`; the
    trigger knobs map 1:1 onto `core.forecast.DriftTrigger`.

    ``rho_max`` (when set) makes every replan plan against
    `core.queueing.with_queueing_margin(inst, rho_max)` — the same
    utilization-headroom view the operator presumably used for the
    initial plan — so a mid-run replan does not silently shed the
    queueing margin the deployed plan was carrying.
    """
    mode: str = "forecast"
    ewma_alpha: float = 0.35
    drift_threshold: float = 0.25
    violation_budget: float = 0.05
    budget_windows: int = 2
    cooldown: int = 4
    warmup: int = 2
    replan_every: int = 12
    rho_max: float | None = None

    def __post_init__(self) -> None:
        if self.mode not in CONTROLLER_MODES:
            raise ValueError(f"mode must be one of {CONTROLLER_MODES}, "
                             f"got {self.mode!r}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "ControllerSpec":
        return ControllerSpec(**d)


@dataclasses.dataclass(frozen=True)
class Station:
    """One active (model, tier) pair as the plan deployed it."""
    j: int
    k: int
    model: str
    tier: str
    tp: int
    pp: int
    gpus: float
    b_max: int          # derived concurrency bound at full (unthinned) scale

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "Station":
        return Station(**d)


@dataclasses.dataclass(frozen=True)
class ReplanEvent:
    """One controller firing and what the planner did about it."""
    window: int
    t_s: float
    cause: str          # "drift" | "slo" | "scheduled" | "fault"
    drift: float
    viol_frac: float
    wall_s: float
    objective: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "ReplanEvent":
        return ReplanEvent(**d)


@dataclasses.dataclass
class ServeResult:
    """Closed-loop outcome of `serve()` — the serving counterpart of
    `PlanResult`.

    ``windows`` rows are flat JSON-safe dicts (one per control window):
    ``t0_s, arrivals, served, shed, attain, ttft_p50, e2e_p95, e2e_p99,
    viol_frac, drift, stations``.  ``calibration`` is the per-type ratio
    of simulated p95 e2e to the time-averaged analytical delay of the
    plans in effect — the closed-loop model-calibration error the paper
    leaves as future work.
    """
    stations: list[Station]
    per_type_ttft_p50: np.ndarray       # [I] seconds (nan if unserved)
    per_type_e2e_p95: np.ndarray        # [I]
    per_type_e2e_p99: np.ndarray        # [I]
    per_type_slo_attain: np.ndarray     # [I] fraction within Delta_i
    analytic_delay: np.ndarray          # [I] time-averaged planner D_i
    n_arrived: int
    n_served: int
    n_shed: int
    windows: list[dict]
    replans: list[ReplanEvent]
    planner_wall_s: float
    horizon_s: float
    traffic: dict
    controller: dict
    # Time-weighted fleet rental rate ($/h) over the horizon — the
    # autoscaling observable: trough replans shrink the fleet, so a
    # forecast-aware controller runs cheaper than a frozen plan.
    mean_rental_per_h: float = 0.0

    # ------------------------------------------------------------------
    def attainment(self) -> float:
        """Served-weighted overall SLO attainment in [0, 1]."""
        if not self.windows:
            return 0.0
        served = sum(w["served"] for w in self.windows)
        if served == 0:
            return 0.0
        hit = sum(w["served"] * w["attain"] for w in self.windows)
        return float(hit / served)

    def planner_frac(self) -> float:
        """Planner wall time as a fraction of the simulated horizon."""
        return float(self.planner_wall_s / max(self.horizon_s, 1e-12))

    def calibration(self) -> np.ndarray:
        """Simulated p95 e2e / analytical delay per type (nan unserved)."""
        with np.errstate(invalid="ignore", divide="ignore"):
            return self.per_type_e2e_p95 / self.analytic_delay

    def summary(self) -> dict:
        """Flat registry-row summary (no arrays) for benchmark dumps."""
        cal = self.calibration()
        cal_med = (float(np.nanmedian(cal))
                   if np.any(np.isfinite(cal)) else None)
        return {
            "attain": round(self.attainment(), 6),
            "served": self.n_served, "shed": self.n_shed,
            "replans": len(self.replans),
            "planner_wall_s": round(self.planner_wall_s, 4),
            "planner_frac": round(self.planner_frac(), 6),
            "mean_rental_per_h": round(self.mean_rental_per_h, 4),
            "calibration_median": (round(cal_med, 4)
                                   if cal_med is not None else None),
        }

    # ------------------------------------------------------------------
    def to_dict(self) -> dict:
        def arr(a: np.ndarray) -> list:
            return [None if not np.isfinite(v) else float(v) for v in a]
        return {
            "stations": [s.to_dict() for s in self.stations],
            "per_type_ttft_p50": arr(self.per_type_ttft_p50),
            "per_type_e2e_p95": arr(self.per_type_e2e_p95),
            "per_type_e2e_p99": arr(self.per_type_e2e_p99),
            "per_type_slo_attain": arr(self.per_type_slo_attain),
            "analytic_delay": arr(self.analytic_delay),
            "n_arrived": self.n_arrived, "n_served": self.n_served,
            "n_shed": self.n_shed, "windows": self.windows,
            "replans": [r.to_dict() for r in self.replans],
            "planner_wall_s": self.planner_wall_s,
            "horizon_s": self.horizon_s,
            "mean_rental_per_h": self.mean_rental_per_h,
            "traffic": self.traffic, "controller": self.controller,
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_dict(d: dict) -> "ServeResult":
        def arr(xs: list) -> np.ndarray:
            return np.array([np.nan if v is None else float(v) for v in xs])
        return ServeResult(
            stations=[Station.from_dict(s) for s in d["stations"]],
            per_type_ttft_p50=arr(d["per_type_ttft_p50"]),
            per_type_e2e_p95=arr(d["per_type_e2e_p95"]),
            per_type_e2e_p99=arr(d["per_type_e2e_p99"]),
            per_type_slo_attain=arr(d["per_type_slo_attain"]),
            analytic_delay=arr(d["analytic_delay"]),
            n_arrived=int(d["n_arrived"]), n_served=int(d["n_served"]),
            n_shed=int(d["n_shed"]), windows=list(d["windows"]),
            replans=[ReplanEvent.from_dict(r) for r in d["replans"]],
            planner_wall_s=float(d["planner_wall_s"]),
            horizon_s=float(d["horizon_s"]),
            mean_rental_per_h=float(d.get("mean_rental_per_h", 0.0)),
            traffic=dict(d["traffic"]), controller=dict(d["controller"]))

    @staticmethod
    def from_json(s: str) -> "ServeResult":
        return ServeResult.from_dict(json.loads(s))
