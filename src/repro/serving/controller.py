"""Forecast-aware replanning controller for the closed-loop driver.

Composes the shared `core.forecast` primitives — the same EWMA recursion
and drift measure the offline rolling replay uses — into the streaming
decision the driver asks once per window: *should the planner run now?*

* ``forecast`` mode is the tentpole: `EwmaForecaster` tracks observed
  full-scale arrival rates; `DriftTrigger` fires on forecast drift
  against the incumbent plan's demand basis or on a sustained
  SLO-violation-budget breach.  Replans happen when the workload has
  actually moved, not on a clock.
* ``fixed`` mode reproduces the blind `replan_every` cadence
  (`core.rolling`'s PR-5 behaviour) as the comparison baseline.
* ``static`` mode never replans — the frozen-plan floor.

The controller only *decides*; the driver owns the `PlanSession` and
performs the warm `replan()` / `repair()`, then reports adoption back via
`adopted()` so the trigger's cooldown and the drift basis re-arm.
"""
from __future__ import annotations

import numpy as np

from ..core.forecast import DriftTrigger, EwmaForecaster, relative_drift
from .types import ControllerSpec


class ReplanController:
    """Per-window replan decision: `observe()` -> cause or None."""

    def __init__(self, spec: ControllerSpec, lam_basis: np.ndarray) -> None:
        self.spec = spec
        self.lam_basis = np.asarray(lam_basis, float).copy()
        self.forecaster = EwmaForecaster(alpha=spec.ewma_alpha,
                                         forecast=self.lam_basis)
        self.trigger = DriftTrigger(
            drift_threshold=spec.drift_threshold,
            violation_budget=spec.violation_budget,
            budget_windows=spec.budget_windows,
            cooldown=spec.cooldown, warmup=spec.warmup)

    @property
    def forecast(self) -> np.ndarray:
        return self.forecaster.forecast

    def observe(self, window: int, lam_obs: np.ndarray,
                viol_frac: float) -> tuple[str | None, float]:
        """Ingest one window's observed full-scale arrival rates and SLO
        violation fraction; returns ``(cause, drift)`` where cause is
        ``"drift"`` / ``"slo"`` / ``"scheduled"`` / None."""
        fc = self.forecaster.update(lam_obs)
        drift = relative_drift(fc, self.lam_basis)
        if self.spec.mode == "static":
            return None, drift
        if self.spec.mode == "fixed":
            fire = window > 0 and window % self.spec.replan_every == 0
            return ("scheduled" if fire else None), drift
        return self.trigger.observe(window, drift, viol_frac), drift

    def adopted(self, window: int, lam_basis: np.ndarray) -> None:
        """A replan was adopted: reset the drift basis to the rates the
        new plan was built for and re-arm the trigger cooldown."""
        self.lam_basis = np.asarray(lam_basis, float).copy()
        self.trigger.fired(window)
