"""Continuous-batching stations: build from a plan, derive the concurrency
bound the docstring always promised, and run an event-compressed
discrete-event loop per station.

`station_b_max` is the satellite bugfix: `simulator.simulate()` documented
a compute-bound concurrency ``B_max`` derived from the station's
utilization headroom but hard-coded ``max_batch=32`` for every station.
The bound here is Little's-law on the committed capacity:

* **compute**: the pair's token throughput cap is
  ``eta * P_k[TFLOP/s] * 1e3 * y / alpha[GFLOP/token]`` tokens/s; at the
  routed mix's mean per-token decode latency ``d_tok`` the sustainable
  concurrency is ``throughput * d_tok`` in-flight requests;
* **memory**: KV space left after weights, ``y*C_gpu - B_eff``, divided
  by the mean per-request KV footprint ``beta/KB_PER_GB * mean(r)``.

``B_max = max(1, floor(min(compute, memory)))`` — a small-capacity station
now admits what its committed GPUs can actually sustain instead of 32.

`StationSim` replaces the token-by-token loop with event-compressed
stepping: between admissions/completions the decode step time is constant,
so the loop jumps ``k = min(tokens to next completion, steps to next
admission opportunity, steps to the window end)`` tokens at once — O(#events)
per window, not O(#tokens) — which is what makes hours of fleet-scale
simulated traffic tractable in Python.
"""
from __future__ import annotations

import collections
import dataclasses
import math

import numpy as np

from ..core.instance import KB_PER_GB, Instance
from ..core.solution import Solution
from .types import Station


def station_b_max(inst: Instance, sol: Solution, j: int, k: int) -> int:
    """Concurrency bound of pair (j,k) from its committed `y` capacity."""
    cfg = sol.config_of(inst, j, k)
    if cfg is None or sol.y[j, k] <= 0:
        return 1
    tp, pp = cfg
    y = float(sol.y[j, k])
    w = np.asarray(sol.x[:, j, k], float)
    if w.sum() <= 0:
        w = np.ones(inst.I)                 # unrouted pair: unweighted mix
    w = w / w.sum()
    # Routed-mix means (per-token decode latency, per-request tokens).
    d_tok = float(w @ (inst.d_comp[:, j, k] / tp
                       + pp * inst.d_comm[:, j, k]))
    r_mean = float(w @ inst.r)
    alpha = float(w @ inst.alpha[:, j, k])  # GFLOP/token
    # Compute bound: tokens/s the committed GPUs sustain, times the time
    # each in-flight request holds a slot per token (Little's law).
    tok_per_s = inst.eta * float(inst.P_gpu[k]) * 1e3 * y / max(alpha, 1e-12)
    b_comp = tok_per_s * d_tok
    # Memory bound: KV space after weights over mean per-request KV bytes.
    free_gb = y * float(inst.C_gpu[k]) - float(inst.B_eff[j, k])
    kv_gb_per_req = float(inst.beta[j]) / KB_PER_GB * r_mean
    b_mem = free_gb / max(kv_gb_per_req, 1e-12)
    return max(1, int(math.floor(min(b_comp, b_mem))))


def build_stations(inst: Instance, sol: Solution) -> list[Station]:
    """Frozen `Station` records for every active pair of the plan."""
    out: list[Station] = []
    for j in range(inst.J):
        for k in range(inst.K):
            if sol.q[j, k] < 0.5:
                continue
            cfg = sol.config_of(inst, j, k)
            if cfg is None:
                continue
            tp, pp = cfg
            out.append(Station(
                j=j, k=k, model=str(inst.model_names[j]),
                tier=str(inst.tier_names[k]), tp=tp, pp=pp,
                gpus=float(sol.y[j, k]),
                b_max=station_b_max(inst, sol, j, k)))
    return out


@dataclasses.dataclass
class Req:
    """One in-simulation request (times relative to simulation start)."""
    qtype: int
    t_arrive: float
    h: int
    f: int
    t_first: float = -1.0
    t_done: float = -1.0
    produced: int = 0


class StationSim:
    """Event-compressed continuous-batching loop for one station.

    Token-interleaved stepping, coherent with the planner's load model:
    constraint (8g) charges prompt AND output tokens to the same per-token
    compute capacity, so the station advances every in-flight request by
    one token per batch step — prompt tokens first (chunked-prefill
    style), then output tokens — at the slowest member's per-token time
    ``d_comp/TP + PP*d_comm``.  TTFT is recorded when a request's prompt
    is consumed.  (The legacy `simulator.simulate()` instead runs prefill
    inline, blocking the whole batch per admission — a model under which
    no fleet-scale station is stable, which is why the closed-loop driver
    does not inherit it.)

    Between admissions, TTFT crossings, completions, and window ends the
    step time is constant, so the loop jumps whole blocks of steps at
    once: O(#events), not O(#tokens) — hours of fleet-scale traffic stay
    tractable in Python.
    """

    def __init__(self, inst: Instance, station: Station,
                 b_eff: int) -> None:
        self.station = station
        self.b_eff = max(1, int(b_eff))     # co-thinned concurrency bound
        j, k = station.j, station.k
        self.d_step = (inst.d_comp[:, j, k] / station.tp
                       + station.pp * inst.d_comm[:, j, k])   # s / token
        self.t = 0.0
        self.pending: collections.deque[Req] = collections.deque()
        self.inflight: list[Req] = []
        self.done: list[Req] = []           # drained by the driver
        self.peak_inflight = 0

    def push(self, reqs: list[Req]) -> None:
        """Enqueue arrivals (must be in nondecreasing t_arrive order)."""
        self.pending.extend(reqs)

    def _admit(self) -> None:
        while (self.pending and len(self.inflight) < self.b_eff
               and self.pending[0].t_arrive <= self.t):
            r = self.pending.popleft()
            r.produced = 0
            self.inflight.append(r)
            self.peak_inflight = max(self.peak_inflight, len(self.inflight))

    def advance(self, until: float) -> None:
        """Run the station loop until the clock reaches `until` (or all
        currently queued work is finished, whichever is later-bounded)."""
        while True:
            self._admit()
            if not self.inflight:
                if self.pending and self.pending[0].t_arrive < until:
                    self.t = max(self.t, self.pending[0].t_arrive)
                    continue
                # Idle: park the clock at the window end.
                self.t = max(self.t, until)
                return
            if self.t >= until:
                return
            step = max(float(self.d_step[r.qtype]) for r in self.inflight)
            # Jump whole steps to the next event: a completion, a TTFT
            # crossing (prompt consumed), an admission opportunity (an
            # arrival while a slot is free), or the window end.
            k = min(r.h + r.f - r.produced for r in self.inflight)
            k_ttft = min((r.h - r.produced for r in self.inflight
                          if r.produced < r.h), default=k)
            k = min(k, k_ttft)
            if (self.pending and len(self.inflight) < self.b_eff
                    and self.pending[0].t_arrive > self.t):
                k_arr = math.ceil((self.pending[0].t_arrive - self.t) / step)
                k = min(k, max(1, k_arr))
            k_end = math.ceil((until - self.t) / step)
            k = max(1, min(k, max(1, k_end)))
            self.t += k * step
            still: list[Req] = []
            for r in self.inflight:
                r.produced += k
                if r.t_first < 0 and r.produced >= r.h:
                    # k never overshoots a crossing (k <= k_ttft), so the
                    # prompt finishes exactly at the current clock.
                    r.t_first = self.t - r.t_arrive
                if r.produced >= r.h + r.f:
                    r.t_done = self.t - r.t_arrive
                    self.done.append(r)
                else:
                    still.append(r)
            self.inflight = still

    def drain(self) -> None:
        """Finish all queued and in-flight work (end-of-horizon flush)."""
        while self.pending or self.inflight:
            self.advance(self.t + 3600.0)

    def take_done(self) -> list[Req]:
        out, self.done = self.done, []
        return out
