"""`serve()` — the closed-loop serving driver.

Runs a planned fleet as continuous-batching stations against hours of
simulated Poisson traffic and closes the loop the paper leaves as future
work: plan -> traffic -> observed SLO -> forecast -> warm replan.

Per control window (`TrafficSpec.window_s`):

1. **synthesize** Poisson arrivals at the instance's fleet-scale rates
   (diurnal `core.trace` multipliers, lognormal token-length noise,
   `rate_scale` thinning with matching concurrency co-thinning so
   utilization — hence queueing — is scale-invariant);
2. **route** each request through the plan-aware `Router` (weighted-random
   over the plan's `x` fractions, shed with the plan's `u` residual);
3. **advance** every station's event-compressed DES to the window end;
4. **observe** completions (attainment, TTFT/e2e percentiles, violation
   fraction) and the full-scale arrival-rate estimate;
5. **decide** via `ReplanController` (EWMA forecast + drift/SLO trigger,
   or the fixed-cadence baseline) and, on a firing, run a warm
   `PlanSession.replan()` on the forecast rates — or `repair()` when a
   `FaultSchedule` has revoked capacity under the incumbent — then swap
   stations diff-aware: surviving (j, k, config) stations keep their
   in-flight work, removed stations drain their backlog without taking
   new traffic, added stations start empty.

The result is a `ServeResult`: per-type latency/attainment, per-window
rows, the replan log with causes, planner wall time as a fraction of the
simulated horizon, and the simulated-vs-analytical calibration ratios.

numpy/stdlib only — `from repro import serve` works without jax.
"""
from __future__ import annotations

import time

import numpy as np

from ..core.faults import FaultSchedule, apply_faults, lost_pairs
from ..core.instance import Instance
from ..core.queueing import with_queueing_margin
from ..core.solution import Solution, proc_delay
from ..core.trace import diurnal_multipliers
from .controller import ReplanController
from .router import SHED, Router
from .stations import Req, StationSim, build_stations
from .types import ControllerSpec, ReplanEvent, ServeResult, TrafficSpec


def _resolve(plan, instance, session):
    """Normalize (plan, instance, session) -> (inst, PlanSession)."""
    from ..planner.api import PlanResult, build_result
    from ..planner.session import PlanSession
    if isinstance(plan, PlanSession):
        if instance is not None or session is not None:
            raise ValueError("pass a PlanSession alone, or a plan with "
                             "instance= (and optionally session=)")
        if plan.last_instance is None or plan.last_result is None:
            raise ValueError("PlanSession has no incumbent: call "
                             ".plan()/.replan() first")
        return plan.last_instance, plan
    if instance is None:
        raise ValueError("serve(plan, instance=...) needs the Instance the "
                         "plan was solved on (or pass a PlanSession)")
    if isinstance(plan, Solution):
        plan = build_result(plan.method or "agh", instance, plan,
                            0.0, 0.0, {}, (session.options if session
                                           else PlanSession().options))
    if not isinstance(plan, PlanResult):
        raise TypeError("plan must be a PlanResult, Solution, or "
                        f"PlanSession, got {type(plan).__name__}")
    sess = session if session is not None else PlanSession()
    sess.seed(instance, plan)
    return instance, sess


def _make_sims(inst: Instance, sol: Solution, cscale: float,
               now: float, old: list[StationSim]
               ) -> tuple[list[StationSim], list[StationSim]]:
    """Diff-aware station (re)build: same (j, k, TP, PP) keeps its state;
    removed stations drain; added ones start empty at the current clock."""
    stations = build_stations(inst, sol)
    prev = {(s.station.j, s.station.k, s.station.tp, s.station.pp): s
            for s in old}
    sims: list[StationSim] = []
    for st in stations:
        b_eff = max(1, round(st.b_max * cscale))
        sim = prev.pop((st.j, st.k, st.tp, st.pp), None)
        if sim is not None:
            sim.station = st
            sim.b_eff = b_eff
        else:
            sim = StationSim(inst, st, b_eff=b_eff)
            sim.t = now
        sims.append(sim)
    return sims, list(prev.values())


def serve(plan, instance: Instance | None = None, *,
          traffic: TrafficSpec | None = None,
          controller: ControllerSpec | None = None,
          session=None, faults: FaultSchedule | None = None) -> ServeResult:
    """Serve simulated traffic against a plan with closed-loop replanning.

    ``plan`` is a `PlanResult` or bare `Solution` (with ``instance=``), or
    a `PlanSession` that already holds an incumbent.  ``faults`` replays a
    `core.faults.FaultSchedule` with the control window as the fault time
    index: capacity revoked under the incumbent triggers a warm
    `session.repair()` (cause ``"fault"``) regardless of controller mode.
    """
    traffic = traffic or TrafficSpec()
    controller = controller or ControllerSpec()
    inst, sess = _resolve(plan, instance, session)
    rng = np.random.default_rng(traffic.seed)
    I = inst.I
    W = traffic.n_windows()
    cscale = traffic.effective_concurrency_scale()
    mult = (diurnal_multipliers(traffic.trace, seed=traffic.trace_seed,
                                n_windows=W)
            if traffic.trace is not None else np.ones(W))

    sol = sess.incumbent
    assert sol is not None
    ctl = ReplanController(controller, inst.lam)
    sims, draining = _make_sims(inst, sol, cscale, 0.0, [])
    router = Router(inst, sol, [s.station for s in sims])

    windows: list[dict] = []
    replans: list[ReplanEvent] = []
    planner_wall = 0.0
    analytic_sum = np.zeros(I)
    all_done: list[Req] = []
    n_arrived = 0
    n_shed = 0
    handled_lost: set[tuple[int, int]] = set()

    rental_sum = 0.0                    # $/h x simulated seconds
    for w in range(W):
        t0 = w * traffic.window_s
        t1 = min(t0 + traffic.window_s, traffic.horizon_s)
        span = max(t1 - t0, 1e-12)
        analytic_sum += proc_delay(inst, sol) * span
        rental_h = float(np.sum(inst.p_c[None, :] * sol.y))
        rental_sum += rental_h * span
        lam_w = inst.lam * mult[w]

        # 1-2. Synthesize this window's arrivals and route them.
        batch: list[tuple[float, int, Req]] = []
        counts = np.zeros(I)
        shed_w = 0
        for i in range(I):
            rate_s = lam_w[i] / 3600.0 * traffic.rate_scale
            n = int(rng.poisson(rate_s * span)) if rate_s > 0 else 0
            if n == 0:
                continue
            counts[i] = n
            times = t0 + np.sort(rng.random(n)) * span
            hs = np.maximum(8, (inst.h[i] * rng.lognormal(
                0, traffic.len_sigma, n)).astype(int))
            fs = np.maximum(4, (inst.f[i] * rng.lognormal(
                0, traffic.len_sigma, n)).astype(int))
            us = rng.random(n)
            for a in range(n):
                s = router.route(i, us[a])
                if s == SHED:
                    shed_w += 1
                    continue
                batch.append((float(times[a]), s,
                              Req(i, float(times[a]), int(hs[a]),
                                  int(fs[a]))))
        n_arrived += int(counts.sum())
        n_shed += shed_w
        batch.sort(key=lambda e: e[0])
        per_station: dict[int, list[Req]] = {}
        for t_a, s, req in batch:
            per_station.setdefault(s, []).append(req)
        for s, reqs in per_station.items():
            sims[s].push(reqs)

        # 3. Advance every station (and drainers) to the window end.
        win_done: list[Req] = []
        for sim in sims:
            sim.advance(t1)
            win_done.extend(sim.take_done())
        still_draining = []
        for sim in draining:
            sim.advance(t1)
            win_done.extend(sim.take_done())
            if sim.pending or sim.inflight:
                still_draining.append(sim)
        draining = still_draining
        all_done.extend(win_done)

        # 4. Observe the window.
        if win_done:
            e2e = np.array([r.t_done for r in win_done])
            slo = inst.Delta[[r.qtype for r in win_done]]
            viol_frac = float(np.mean(e2e > slo))
            row_ttft = float(np.median([r.t_first for r in win_done]))
            row_p95 = float(np.percentile(e2e, 95))
            row_p99 = float(np.percentile(e2e, 99))
        else:
            viol_frac, row_ttft, row_p95, row_p99 = 0.0, None, None, None
        lam_obs = counts / span / max(traffic.rate_scale, 1e-12) * 3600.0

        # 5. Decide and (maybe) replan.
        cause, drift = ctl.observe(w, lam_obs, viol_frac)
        if faults is not None:
            inst_w = apply_faults(inst, faults, w)
            lost = {(int(j), int(k)) for j, k in lost_pairs(inst_w, sol.y)}
            if lost - handled_lost:
                cause = "fault"
                handled_lost = lost
            elif not lost:
                handled_lost = set()
        if cause is not None:
            p0 = time.perf_counter()
            # The planning basis is always the PRISTINE supply at the
            # forecast rates, re-faulted for the current window — so a
            # drift replan during an outage plans on the degraded supply,
            # and one after recovery is not stuck with stale caps.  The
            # queueing-margin view is re-applied so replans keep the
            # headroom policy of the initial plan.
            inst_basis = inst.with_lam(ctl.forecast)
            if controller.rho_max is not None:
                inst_basis = with_queueing_margin(inst_basis,
                                                  controller.rho_max)
            if faults is not None:
                inst_basis = apply_faults(inst_basis, faults, w)
            if cause == "fault":
                res = sess.repair(instance=inst_basis, cause=cause)
            else:
                res = sess.replan(instance=inst_basis, cause=cause)
            wall = time.perf_counter() - p0
            planner_wall += wall
            sol = res.solution
            sims, newly_drained = _make_sims(inst, sol, cscale, t1, sims)
            draining.extend(newly_drained)
            router = Router(inst, sol, [s.station for s in sims])
            ctl.adopted(w, ctl.forecast)
            replans.append(ReplanEvent(
                window=w, t_s=float(t1), cause=cause, drift=float(drift),
                viol_frac=float(viol_frac), wall_s=float(wall),
                objective=float(res.objective)))

        windows.append({
            "t0_s": float(t0), "arrivals": int(counts.sum()),
            "served": len(win_done), "shed": shed_w,
            "attain": float(1.0 - viol_frac), "ttft_p50": row_ttft,
            "e2e_p95": row_p95, "e2e_p99": row_p99,
            "viol_frac": float(viol_frac), "drift": float(drift),
            "stations": len(sims), "rental_per_h": rental_h,
        })

    # Flush: finish all queued and in-flight work past the horizon.
    for sim in sims + draining:
        sim.drain()
        all_done.extend(sim.take_done())

    ttft = np.full(I, np.nan)
    p95 = np.full(I, np.nan)
    p99 = np.full(I, np.nan)
    attain = np.zeros(I)
    by_type: list[list[Req]] = [[] for _ in range(I)]
    for r in all_done:
        by_type[r.qtype].append(r)
    for i in range(I):
        mine = by_type[i]
        if not mine:
            continue
        e2e = np.array([r.t_done for r in mine])
        ttft[i] = float(np.median([r.t_first for r in mine]))
        p95[i] = float(np.percentile(e2e, 95))
        p99[i] = float(np.percentile(e2e, 99))
        attain[i] = float(np.mean(e2e <= inst.Delta[i]))

    return ServeResult(
        stations=[s.station for s in sims],
        per_type_ttft_p50=ttft, per_type_e2e_p95=p95, per_type_e2e_p99=p99,
        per_type_slo_attain=attain,
        analytic_delay=analytic_sum / max(traffic.horizon_s, 1e-12),
        n_arrived=n_arrived, n_served=len(all_done),
        n_shed=n_shed, windows=windows, replans=replans,
        planner_wall_s=float(planner_wall), horizon_s=float(traffic.horizon_s),
        mean_rental_per_h=float(rental_sum / max(traffic.horizon_s, 1e-12)),
        traffic=traffic.to_dict(), controller=controller.to_dict())
