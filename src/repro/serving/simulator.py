"""Closed-loop serving simulator — validates the planner's analytical
delay model against engine-level dynamics (the paper's second future-work
item: "integration with a concrete serving engine for closed-loop
deployment", here as a discrete-event simulation of the planned fleet).

Each active (model, tier) pair becomes a continuous-batching station:

  * requests of type i arrive Poisson(lam_i * x_ijk), carrying h_i prompt
    tokens and f_i output tokens (lognormal length noise);
  * the station runs a token-level loop: every decode step advances each
    in-flight request by one token and costs
        step = d_comp/TP + PP * d_comm   (the paper's per-token model)
    amortized over the batch up to the station's concurrency bound
        B_max = min(compute, KV-memory) in-flight requests, derived from
        the plan's committed y/capacity by `stations.station_b_max`
        (an explicit ``max_batch=`` overrides it);
  * prefill is compute-bound: h_i * d_comp / TP, admitted when a slot
    frees (FCFS).

Outputs per type: achieved TTFT / end-to-end latency percentiles vs the
SLO Delta_i, and the ratio to the planner's analytical D — the calibration
error of the paper's planning-layer model under load.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.instance import Instance
from ..core.solution import Solution


@dataclasses.dataclass
class SimRequest:
    rid: int
    qtype: int
    t_arrive: float
    h: int
    f: int
    t_first: float = -1.0
    t_done: float = -1.0
    produced: int = 0


@dataclasses.dataclass
class SimStats:
    per_type_ttft_p50: np.ndarray
    per_type_e2e_p95: np.ndarray
    per_type_slo_attain: np.ndarray
    analytic_delay: np.ndarray
    n_served: int

    def model_error(self) -> np.ndarray:
        """simulated p95 e2e / planner analytical delay (nan if unserved)."""
        with np.errstate(invalid="ignore", divide="ignore"):
            return self.per_type_e2e_p95 / self.analytic_delay


def simulate(inst: Instance, sol: Solution, horizon_s: float = 600.0,
             rate_scale: float = 1.0, max_batch: int | None = None,
             seed: int = 0) -> SimStats:
    """Event-driven simulation of the deployment in `sol` serving Poisson
    traffic for `horizon_s` seconds (arrival rates scaled by rate_scale).

    ``max_batch=None`` (the default) derives each station's concurrency
    bound from the plan's committed capacity via
    `stations.station_b_max` — the compute/memory B_max this docstring
    always promised; a small-capacity station no longer over-admits to a
    blanket 32.  Passing an explicit int restores the historical fixed
    bound bit-identically (the regression test pins this)."""
    from .stations import station_b_max
    rng = np.random.default_rng(seed)
    I = inst.I

    # stations: one per active (j, k) with its (TP, PP) config
    stations = []
    for j in range(inst.J):
        for k in range(inst.K):
            if sol.q[j, k] < 0.5:
                continue
            cfg = sol.config_of(inst, j, k)
            if cfg is None:
                continue
            n, m = cfg
            b_max = (max_batch if max_batch is not None
                     else station_b_max(inst, sol, j, k))
            stations.append(dict(j=j, k=k, tp=n, pp=m, b_max=b_max,
                                 inflight=[], queue=[], t_free=0.0))
    if not stations:
        return SimStats(np.full(I, np.nan), np.full(I, np.nan),
                        np.zeros(I), np.zeros(I), 0)

    # per (type, station) routing weights from x
    route_w = np.zeros((I, len(stations)))
    for s_idx, st in enumerate(stations):
        for i in range(I):
            route_w[i, s_idx] = sol.x[i, st["j"], st["k"]]

    # Poisson arrivals over the horizon
    reqs: list[SimRequest] = []
    rid = 0
    for i in range(I):
        rate = inst.lam[i] / 3600.0 * rate_scale * float(route_w[i].sum())
        if rate <= 0:
            continue
        t = rng.exponential(1.0 / rate)
        while t < horizon_s:
            h = max(8, int(inst.h[i] * rng.lognormal(0, 0.25)))
            f = max(4, int(inst.f[i] * rng.lognormal(0, 0.25)))
            reqs.append(SimRequest(rid, i, t, h, f))
            rid += 1
            t += rng.exponential(1.0 / rate)
    reqs.sort(key=lambda r: r.t_arrive)

    # assign each request to a station by routing fractions
    assign: dict[int, list[SimRequest]] = {s: [] for s in range(len(stations))}
    for r in reqs:
        w = route_w[r.qtype]
        if w.sum() <= 0:
            continue
        s = int(rng.choice(len(stations), p=w / w.sum()))
        assign[s].append(r)

    # simulate each station independently (token-level continuous batching)
    for s_idx, st in enumerate(stations):
        j, k, tp, pp = st["j"], st["k"], st["tp"], st["pp"]
        pending = assign[s_idx]
        ptr = 0
        inflight: list[SimRequest] = []
        t = 0.0
        b_max = st["b_max"]
        while ptr < len(pending) or inflight:
            # admit arrivals (up to the station's concurrency bound)
            while (ptr < len(pending) and len(inflight) < b_max
                   and pending[ptr].t_arrive <= t):
                r = pending[ptr]
                ptr += 1
                # prefill cost (compute-bound, runs inline)
                d_comp = inst.d_comp[r.qtype, j, k]
                t_pre = r.h * d_comp / tp
                t = max(t, r.t_arrive) + t_pre
                r.t_first = t - r.t_arrive
                r.produced = 1
                inflight.append(r)
            if not inflight:
                if ptr < len(pending):
                    t = max(t, pending[ptr].t_arrive)
                    continue
                break
            # one decode step for the whole batch: the slowest member's
            # per-token time bounds the step (batch shares the weights
            # stream; per-token compute is amortized)
            step = max(inst.d_comp[r.qtype, j, k] / tp
                       + pp * inst.d_comm[r.qtype, j, k]
                       for r in inflight)
            t += step
            done = []
            for r in inflight:
                r.produced += 1
                if r.produced >= r.f:
                    r.t_done = t - r.t_arrive
                    done.append(r)
            inflight = [r for r in inflight if r.t_done < 0]
            del done

    ttft = np.full(I, np.nan)
    e2e = np.full(I, np.nan)
    attain = np.zeros(I)
    served = [r for r in reqs if r.t_done > 0]
    for i in range(I):
        mine = [r for r in served if r.qtype == i]
        if not mine:
            continue
        ttft[i] = float(np.median([r.t_first for r in mine]))
        e2e[i] = float(np.percentile([r.t_done for r in mine], 95))
        attain[i] = float(np.mean([r.t_done <= inst.Delta[i] for r in mine]))

    from ..core.solution import proc_delay
    return SimStats(per_type_ttft_p50=ttft, per_type_e2e_p95=e2e,
                    per_type_slo_attain=attain,
                    analytic_delay=proc_delay(inst, sol),
                    n_served=len(served))
