"""Exact (oracle) chunk solver + shared host-side bookkeeping.

Pure numpy/scipy — `risk_evaluate(engine="exact")` routes here and never
imports jax.  `BatchedStage2Solver` (the pdhg engine) subclasses
`ExactChunkSolver` to share the LP pattern plumbing, the linprog oracle,
and the per-scenario statistics recorder, guaranteeing both engines
compute cost/violation/utilization through the SAME code.

The LP solved is the relaxed Stage-2 protocol (u <= 1): always feasible,
so every scenario yields a realized cost — what the tail statistics
need.  The objective bookkeeping matches `Stage2System.solve` exactly:
cost = c_x @ x + c_u @ clip(u, 0, 1).
"""
from __future__ import annotations

import numpy as np
from scipy import sparse
from scipy.optimize import linprog

from ..core.instance import ScenarioBatch
from ..core.stage2 import Stage2System


class _ChunkArrays:
    """Per-chunk result accumulator: costs, violations, tail inputs."""

    def __init__(self, S: int, n_fam: int):
        self.costs = np.zeros(S)
        self.viols = np.zeros(S, dtype=np.int64)
        self.unmet = np.zeros(S)
        self.util = np.zeros((S, n_fam))

    def record_z(self, s: int, vals: np.ndarray, z: np.ndarray,
                 solver: "ExactChunkSolver") -> None:
        rowsv = np.zeros(solver.m)
        np.add.at(rowsv, solver.rows, vals * z[solver.cols])
        self._stats(np.array([s]), z[None, :], rowsv[None, :], solver)

    def record_batch(self, sel: np.ndarray, z: np.ndarray,
                     rowsv: np.ndarray, solver: "ExactChunkSolver") -> None:
        self._stats(sel, z, rowsv, solver)

    def _stats(self, sel: np.ndarray, z: np.ndarray, rowsv: np.ndarray,
               solver: "ExactChunkSolver") -> None:
        u = np.clip(z[:, solver.nx:], 0.0, 1.0)
        self.viols[sel] = np.sum(u > 0.01, axis=1)
        self.unmet[sel] = u.sum(axis=1)
        fam = solver.system.row_family
        safe = np.maximum(solver.rhs0[:solver.m_ub], 1e-12)
        ratio = rowsv[:, :solver.m_ub] / safe[None, :]
        for f in range(self.util.shape[1]):
            rows_f = np.where(fam == f)[0]
            if rows_f.size:
                self.util[sel, f] = ratio[:, rows_f].max(axis=1)


class ExactChunkSolver:
    """Every scenario through linprog/HiGHS — the exact oracle path."""

    def __init__(self, system: Stage2System):
        self.system = system
        self.n, self.nx, self.I = system.n, system.nx, system.I
        self.m_ub = system.m_ub
        self.m = system.m_ub + system.I
        self.rows = system.rows_all.astype(np.int64)
        self.cols = system.cols_all.astype(np.int64)
        self.nnz_all = system.nnz_all
        self.rhs0 = system.row_ub.copy()
        self.ub = np.ones(self.n)                 # relaxed protocol
        self.is_eq = np.zeros(self.m, dtype=bool)
        self.is_eq[self.m_ub:] = True
        self.n_fam = len(Stage2System.ROW_FAMILIES)
        self.diagnostics: dict = {"n_exact": 0}

    def _exact(self, vals: np.ndarray, c: np.ndarray):
        """One exact scenario solve via linprog/HiGHS (exposes duals)."""
        K = sparse.coo_matrix((vals, (self.rows, self.cols)),
                              shape=(self.m, self.n)).tocsr()
        bounds = np.stack([np.zeros(self.n), self.ub], axis=1)
        return linprog(c, A_ub=K[:self.m_ub], b_ub=self.rhs0[:self.m_ub],
                       A_eq=K[self.m_ub:], b_eq=self.rhs0[self.m_ub:],
                       bounds=bounds, method="highs")

    def _record_exact(self, s: int, vals: np.ndarray, c: np.ndarray, res,
                      out: _ChunkArrays) -> None:
        z = np.concatenate([res.x[:self.nx],
                            np.clip(res.x[self.nx:], 0.0, 1.0)])
        out.costs[s] = float(c[:self.nx] @ z[:self.nx]
                             + c[self.nx:] @ z[self.nx:])
        out.record_z(s, vals, z, self)

    def solve_scenarios(self, batch: ScenarioBatch) -> _ChunkArrays:
        vals, c = self.system.coefficient_batch(batch)
        out = _ChunkArrays(batch.S, self.n_fam)
        for s in range(batch.S):
            res = self._exact(vals[s], c[s])
            self._record_exact(s, vals[s], c[s], res, out)
        self.diagnostics["n_exact"] += batch.S
        return out
