"""Risk statistics over per-scenario cost / violation vectors.

Pure numpy — importable without jax (the `engine="exact"` path and the
report serialization never touch the tensor tier).

CVaR follows Rockafellar-Uryasev: with VaR_a = the a-quantile of the
cost distribution,  CVaR_a = VaR_a + E[(cost - VaR_a)+] / (1 - a)  — the
expected cost conditional on landing in the worst (1-a) tail.  For an
empirical distribution this is exact (not the discrete-tail-mean
approximation, which is biased for small S·(1-a)).
"""
from __future__ import annotations

import numpy as np

#: default CVaR levels reported by `risk_evaluate`.
ALPHAS = (0.90, 0.95, 0.99)

#: violation quantiles reported (per-scenario viol count + unmet mass).
VIOLATION_QUANTILES = (0.99, 0.999)


def var_cvar(costs: np.ndarray, alpha: float) -> tuple[float, float]:
    """(VaR_alpha, CVaR_alpha) of an empirical cost sample."""
    costs = np.asarray(costs, float)
    var = float(np.quantile(costs, alpha))
    excess = np.maximum(costs - var, 0.0)
    cvar = var + float(excess.mean()) / (1.0 - alpha)
    return var, cvar


def tail_attribution(costs: np.ndarray, util: np.ndarray,
                     families: tuple[str, ...],
                     alpha: float = 0.95) -> dict[str, dict[str, float]]:
    """Which constraint family drives the cost tail.

    `util[s, f]` is scenario s's max utilization (lhs/rhs) over family
    f's inequality rows.  Returns, per family, the mean utilization over
    all scenarios vs over the worst (1-alpha) cost tail — a family whose
    tail utilization pulls clearly above its overall mean is the binding
    resource in the scenarios that make the deployment expensive.
    """
    costs = np.asarray(costs, float)
    var = np.quantile(costs, alpha)
    tail = costs >= var
    if not tail.any():                      # degenerate (constant costs)
        tail = np.ones_like(tail)
    return {
        fam: {
            "mean_util": float(util[:, f].mean()),
            "tail_util": float(util[tail, f].mean()),
        }
        for f, fam in enumerate(families)
    }


def risk_stats(costs: np.ndarray, viols: np.ndarray, unmet: np.ndarray,
               util: np.ndarray, families: tuple[str, ...],
               alphas: tuple[float, ...] = ALPHAS,
               tail_alpha: float = 0.95) -> dict:
    """The full statistics block of a `RiskReport` (costs are Stage-2)."""
    costs = np.asarray(costs, float)
    viols = np.asarray(viols, float)
    unmet = np.asarray(unmet, float)
    S = costs.size
    var = {}
    cvar = {}
    for a in alphas:
        v, cv = var_cvar(costs, a)
        key = f"{a:.2f}"
        var[key] = v
        cvar[key] = cv
    viol_q = {f"p{q * 100:g}": float(np.quantile(viols, q))
              for q in VIOLATION_QUANTILES}
    unmet_q = {f"p{q * 100:g}": float(np.quantile(unmet, q))
               for q in VIOLATION_QUANTILES}
    return {
        "S": int(S),
        "expected_cost": float(costs.mean()),
        "cost_std": float(costs.std()),
        "var": var,
        "cvar": cvar,
        "viol_total": float(viols.sum()),
        "viol_quantiles": viol_q,
        "unmet_quantiles": unmet_q,
        "tail_attribution": tail_attribution(costs, util, families,
                                             alpha=tail_alpha),
    }
