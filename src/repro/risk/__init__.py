"""repro.risk — scenario-batched tail-risk evaluation.

Lazy exports: importing `repro.risk` stays cheap and jax-free; the
batched solver (which pulls in jax) loads only when the pdhg engine or
`BatchedStage2Solver` itself is first touched.
"""
from __future__ import annotations

from typing import Any

_EXPORTS = {
    "risk_evaluate": ".api",
    "rank_deployments": ".api",
    "RiskReport": ".api",
    "ENGINES": ".api",
    "risk_stats": ".metrics",
    "var_cvar": ".metrics",
    "tail_attribution": ".metrics",
    "ALPHAS": ".metrics",
    "ExactChunkSolver": ".solver_exact",
    "BatchedStage2Solver": ".solver",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str) -> Any:
    mod_name = _EXPORTS.get(name)
    if mod_name is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(mod_name, __name__)
    value = getattr(mod, name)
    globals()[name] = value
    return value


def __dir__() -> list[str]:
    return sorted(set(globals()) | set(__all__))
