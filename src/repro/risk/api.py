"""Public risk-evaluation API.

`risk_evaluate(inst, deploy, S=20_000, engine="pdhg"|"exact")` draws the
evaluation protocol's scenario family in memory-bounded chunks
(`Instance.perturbed_chunks`), solves every scenario's relaxed Stage-2
LP through the batched first-order solver (or the exact oracle), and
folds the per-scenario costs into a `RiskReport`: expected cost,
CVaR_a, violation quantiles, per-constraint tail attribution, and the
solver's convergence diagnostics (anchor hits, harvests, PDHG
iterations, exact fallbacks — non-converged scenarios are solved
exactly and counted, never dropped).

`rank_deployments` scores a set of candidate plans CVaR-vs-expected
under the paper's 1.5x stress family — the report the risk subsystem
exists to produce.

jax is imported lazily (inside the pdhg engine path only): the exact
engine and the report plumbing stay importable on jax-free hosts.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

import numpy as np

from ..core.instance import Instance
from ..core.solution import Solution, provisioning_cost
from ..core.stage2 import Stage2System
from .metrics import ALPHAS, risk_stats

ENGINES = ("pdhg", "exact")

#: evaluation-protocol scenario family (matches `core.evaluate.evaluate`).
PROTOCOL = {"d_infl": 0.15, "e_infl": 0.10, "lam_pm": 0.20, "seed": 1234}


@dataclasses.dataclass
class RiskReport:
    """Risk statistics of one (instance, deployment) pair.

    Costs are TOTAL (stage-1 provisioning + per-scenario stage-2
    operation), so expected/CVaR columns are directly comparable across
    deployments with different provisioning spend.
    """
    method: str
    engine: str
    S: int
    stage1_cost: float
    expected_cost: float              # stage1 + mean stage2
    cost_std: float
    var: dict[str, float]             # alpha -> total-cost VaR
    cvar: dict[str, float]            # alpha -> total-cost CVaR
    violation_rate: float             # P(type-scenario pair unmet > 1%)
    viol_quantiles: dict[str, float]  # per-scenario violation counts
    unmet_quantiles: dict[str, float]  # per-scenario unmet mass
    tail_attribution: dict[str, dict[str, float]]
    diagnostics: dict[str, Any]
    wall_s: float

    # -- serialization --------------------------------------------------
    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_dict(cls, d: dict) -> "RiskReport":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    @classmethod
    def from_json(cls, s: str) -> "RiskReport":
        return cls.from_dict(json.loads(s))

    def summary(self) -> dict[str, float | int | str]:
        """Flat registry row (planner diagnostics, benchmark tables)."""
        row: dict[str, float | int | str] = {
            "method": self.method,
            "engine": self.engine,
            "S": self.S,
            "expected_cost": self.expected_cost,
            "violation_rate": self.violation_rate,
            "wall_s": self.wall_s,
        }
        for k, v in self.cvar.items():
            row[f"cvar_{k}"] = v
        for k, v in self.viol_quantiles.items():
            row[f"viol_{k}"] = v
        d = self.diagnostics
        for k in ("n_anchor0", "n_harvest_exact", "n_pdhg",
                  "n_fallback_exact", "n_anchors"):
            if k in d:
                row[k] = d[k]
        return row


def risk_evaluate(inst: Instance, deploy: Solution, S: int = 20_000,
                  engine: str = "pdhg", *,
                  seed: int | None = None,
                  d_infl: float | None = None, e_infl: float | None = None,
                  lam_pm: float | None = None,
                  chunk: int = 8192, max_anchors: int = 32,
                  alphas: tuple[float, ...] = ALPHAS,
                  tail_alpha: float = 0.95) -> RiskReport:
    """Tail-risk evaluation of a frozen deployment over S scenarios.

    Both engines solve the RELAXED Stage-2 protocol (u <= 1, always
    feasible) and draw bit-identical scenarios from the evaluation
    family, so `engine="exact"` is the oracle for `engine="pdhg"`
    (objectives agree to rtol 1e-5; pinned in tests/test_risk.py).
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; pick from {ENGINES}")
    seed = PROTOCOL["seed"] if seed is None else seed
    d_infl = PROTOCOL["d_infl"] if d_infl is None else d_infl
    e_infl = PROTOCOL["e_infl"] if e_infl is None else e_infl
    lam_pm = PROTOCOL["lam_pm"] if lam_pm is None else lam_pm

    t0 = time.perf_counter()
    system = Stage2System(inst, deploy)
    if engine == "pdhg":
        from .solver import BatchedStage2Solver  # lazy: pulls in jax
        solver = BatchedStage2Solver(system, max_anchors=max_anchors)
        solve_chunk = solver.solve_scenarios
    else:
        from .solver_exact import ExactChunkSolver
        solver = ExactChunkSolver(system)
        solve_chunk = solver.solve_scenarios

    rng = np.random.default_rng(seed)
    costs = np.zeros(S)
    viols = np.zeros(S, dtype=np.int64)
    unmet = np.zeros(S)
    util = np.zeros((S, len(Stage2System.ROW_FAMILIES)))
    done = 0
    for batch in inst.perturbed_chunks(rng, S, chunk=chunk, d_infl=d_infl,
                                       e_infl=e_infl, lam_pm=lam_pm):
        out = solve_chunk(batch)
        sl = slice(done, done + batch.S)
        costs[sl] = out.costs
        viols[sl] = out.viols
        unmet[sl] = out.unmet
        util[sl] = out.util
        done += batch.S
    wall = time.perf_counter() - t0

    s1 = provisioning_cost(inst, deploy)
    stats = risk_stats(s1 + costs, viols, unmet, util,
                       Stage2System.ROW_FAMILIES, alphas=alphas,
                       tail_alpha=tail_alpha)
    diag = dict(solver.diagnostics)
    diag["n_anchors"] = len(getattr(solver, "anchors", ()))
    return RiskReport(
        method=deploy.method, engine=engine, S=S, stage1_cost=float(s1),
        expected_cost=stats["expected_cost"], cost_std=stats["cost_std"],
        var=stats["var"], cvar=stats["cvar"],
        violation_rate=stats["viol_total"] / (S * inst.I),
        viol_quantiles=stats["viol_quantiles"],
        unmet_quantiles=stats["unmet_quantiles"],
        tail_attribution=stats["tail_attribution"],
        diagnostics=diag, wall_s=float(wall))


def rank_deployments(inst: Instance, deployments: dict[str, Solution],
                     S: int = 20_000, engine: str = "pdhg", *,
                     stress: float = 1.5, alpha: float = 0.95,
                     chunk: int = 8192) -> dict[str, Any]:
    """CVaR-vs-expected ranking of candidate plans under stress.

    Evaluates every deployment on `inst.stressed(stress)` (the paper's
    1.5x delay/error inflation family) and returns both orderings —
    the interesting output is where they DISAGREE: a plan that wins on
    expected cost but loses on CVaR_alpha is buying its average from
    the tail.
    """
    key = f"{alpha:.2f}"
    stressed = inst.stressed(stress)
    reports = {
        name: risk_evaluate(stressed, dep, S=S, engine=engine, chunk=chunk)
        for name, dep in deployments.items()
    }
    by_exp = sorted(reports, key=lambda k: reports[k].expected_cost)
    by_cvar = sorted(reports, key=lambda k: reports[k].cvar[key])
    return {
        "stress": stress,
        "alpha": alpha,
        "S": S,
        "engine": engine,
        "ranking_expected": by_exp,
        "ranking_cvar": by_cvar,
        "agree": by_exp == by_cvar,
        "summaries": {k: r.summary() for k, r in reports.items()},
        "reports": reports,
    }
