"""Batched first-order Stage-2 LP solver (tentpole of the risk subsystem).

All S scenarios of a `ScenarioBatch` are solved against one frozen
deployment as ONE stacked tensor program in jax (f64, scenario axis
leading), with the scipy/HiGHS path as the exact oracle.  Three phases,
cheapest first:

1. **Anchor-basis warm start.**  Each scenario's LP is a one-factor
   rescale of the base LP, so optimal bases cluster into a small set
   (~30 distinct bases cover tens of thousands of scenarios of the
   evaluation family).  An *anchor* is an optimal basis harvested from
   one exact solve: (active rows, basic columns, nonbasic-at-upper-bound
   columns), completed to a square basis through pivoted Gram-Schmidt
   when the vertex is degenerate.  For a batch of scenarios the solver
   proposes the candidate vertex/dual of the most promising anchor
   (first pass: nearest hit-centroid in perturbation space; retries:
   most-hit untried anchor).  The k x k active systems
   B(s) z_B = rhs_eff(s)  and  B(s)^T y = -c_B(s)  are solved EXACTLY
   in closed form by exploiting how scenarios perturb the constraint
   matrix: equality rows are scenario-constant, kv/compute/storage rows
   are pure per-row rescales (every entry of row i carries the same
   lam/tau factor), and only active delay/error rows change shape — of
   which an optimal basis holds a bounded number (q capped by the
   largest `_SHAPE_CLASSES` entry; anchors pad to the smallest fitting
   class so nominal deployments keep tiny q).  Writing
   B(s) = D(s) B0 + U dR(s) with D(s) the diagonal of row factors and
   U the q unit columns of the changed rows, Woodbury gives
   B(s)^{-1} = (I - G0 M(s)^{-1} dR(s)) B0^{-1} D(s)^{-1} with
   G0 = B0^{-1} U precomputed per anchor and M(s) = I_q + dR(s) G0 a
   tiny q x q system solved by a statically unrolled LU.  Everything is
   gathers and small dgemms — B(s) is never materialized and no batched
   LAPACK is invoked (XLA lowers those to serial per-element loops on
   CPU, which would dominate wall time),
   then *verifies* each candidate with the PDHG convergence criteria
   proper (primal feasibility < `TOL_PF`, relative duality gap <
   `TOL_GAP`, duals clipped to sign-validity before the gap is formed).
   A passing candidate IS PDHG converged at iteration 0 — the stopping
   rule, not the proposer, is the correctness authority.  Scenarios that
   no anchor explains trigger an exact solve of one representative whose
   basis joins the anchor set (adaptive harvesting).

2. **PDHG iterations.**  Scenarios left over once the anchor set stops
   growing run restarted PDHG from the best candidate: Ruiz
   equilibration, diagonal (Pock-Chambolle) preconditioning, primal
   weight omega adapted at restarts, restart-to-average, and the same
   duality-gap stopping rule.

3. **Exact fallback.**  Scenarios that fail to converge within the
   iteration budget fall back to the exact oracle and are *counted* in
   the diagnostics — never silently dropped.

The LP solved here is the relaxed Stage-2 protocol (u <= 1, always
feasible), matching `Stage2System.solve(u_cap=ones)` — the risk
statistics want the realized cost of every scenario, not a strict-cap
feasibility verdict.  Per-scenario objectives agree with the oracle to
rtol 1e-5 (in practice ~1e-14); pinned in tests/test_risk.py.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile

import numpy as np
from scipy import sparse

import jax

jax.config.update("jax_enable_x64", True)
try:
    # Persistent kernel cache: the candidate kernel compiles once per
    # scenario bucket (~1-2 s each); caching the executables on disk
    # makes every process after the first start warm.  Best-effort —
    # older jax builds without the knobs just compile per process.
    if "JAX_COMPILATION_CACHE_DIR" not in os.environ:
        jax.config.update(
            "jax_compilation_cache_dir",
            os.path.join(tempfile.gettempdir(), "repro-jax-cache"))
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.5)
except Exception:  # pragma: no cover - depends on jax build
    pass

import jax.numpy as jnp  # noqa: E402  (after the x64 switch, deliberately)

from ..core.instance import ScenarioBatch  # noqa: E402
from ..core.stage2 import Stage2System  # noqa: E402
from .solver_exact import ExactChunkSolver, _ChunkArrays  # noqa: E402

# PDHG convergence criteria — the single correctness authority for every
# non-exact scenario (anchor candidates must pass the SAME test).
TOL_PF = 1e-8       # max primal constraint violation (unscaled rows)
TOL_GAP = 1e-7      # relative duality gap |p-d| / (1+|p|+|d|)

_RUIZ_ITERS = 10
# Woodbury shape classes (q, eg): q = max scenario-varying (delay/error)
# rows per anchor basis, eg = max matrix entries in those rows x basic
# columns.  `_pack` pads each anchor to the SMALLEST fitting class, so
# nominal deployments (q <= 2 in practice) keep the small fast shapes
# while stressed deployments (15-16 active delay/error rows) still get
# kernel-representable anchors instead of degenerating to per-scenario
# exact solves.  One jit specialization per class actually used.
_SHAPE_CLASSES = ((8, 64), (24, 192))
_S_BUCKETS = (256, 1024, 4096, 8192)


def _bucket(S: int) -> int:
    for b in _S_BUCKETS:
        if S <= b:
            return b
    return int(2 ** np.ceil(np.log2(S)))


# ---------------------------------------------------------------------------
# Candidate kernel: propose the anchor's vertex/dual for every scenario in
# the batch and verify it with the PDHG stopping rule.  One compile per
# (S bucket); every anchor reuses it (all anchor tensors are padded to the
# system-wide static sizes).
# ---------------------------------------------------------------------------

def _lu_small(M):
    """No-pivot LU (compact storage) on [S, q, q] blocks, unrolled.

    M = I_q + dR G0 is diagonally dominated for in-cell scenarios and
    exactly the identity on padding slots, so pivoting is unnecessary;
    a scenario whose M is ill-conditioned produces a garbage candidate
    that the verification stage rejects (exactness is never assumed).
    q is read off the array shape (static under jit), so each shape
    class gets its own unrolled specialization.
    """
    Q = M.shape[1]
    for j in range(Q - 1):
        f = M[:, j + 1:, j] / M[:, j, j][:, None]
        M = M.at[:, j + 1:, j].set(f)
        M = M.at[:, j + 1:, j + 1:].add(
            -f[:, :, None] * M[:, j:j + 1, j + 1:])
    return M


def _solve_small(Mlu, r):
    """Solve M h = r from the compact LU ([S, q] right-hand sides)."""
    Q = Mlu.shape[1]
    h = r
    for j in range(1, Q):
        h = h.at[:, j].add(-jnp.sum(Mlu[:, j, :j] * h[:, :j], axis=1))
    for j in reversed(range(Q)):
        h = h.at[:, j].add(-jnp.sum(Mlu[:, j, j + 1:] * h[:, j + 1:],
                                    axis=1))
        h = h.at[:, j].mul(1.0 / Mlu[:, j, j])
    return h


def _solve_small_t(Mlu, r):
    """Solve M^T g = r from the same compact LU (M^T = U^T L^T)."""
    Q = Mlu.shape[1]
    a = r
    for j in range(Q):
        if j:
            a = a.at[:, j].add(-jnp.sum(Mlu[:, :j, j] * a[:, :j], axis=1))
        a = a.at[:, j].mul(1.0 / Mlu[:, j, j])
    for j in reversed(range(Q - 1)):
        a = a.at[:, j].add(-jnp.sum(Mlu[:, j + 1:, j] * a[:, j + 1:],
                                    axis=1))
    return a


@jax.jit
def _candidate_kernel(vals_all, c_all, pad, rhs0, is_eq, rows_a, cols_a,
                      ub, Rm, Rn,
                      e_r, m_r, M_r, rhs_act,
                      scale_e, scale_m, scale_mask,
                      e_g, dv0, jpos_g, rowq_g, Hq, Hk, P_M, Hg,
                      bas_idx, bas_mask, nb_vec, act_idx, act_mask,
                      B0inv, G0):
    # All index-space reductions here are (gather, one-hot matmul) pairs
    # rather than `.at[].add` scatters: XLA CPU lowers batched scatters
    # to a serial per-index loop (~ms per call at S=8192), while the
    # equivalent [S, E] @ [E, K] dgemm is what the whole kernel budget
    # rides on.  Rm/Rn are the system-wide one-hot row/col maps; the
    # anchor tensors are padded to static sizes with zero-weight tails.
    # The group gather (pad -> rows of the chunk-resident tensors) lives
    # INSIDE the jit: done outside, each gather pays ~ms of trace and
    # dispatch overhead per call.
    vals = vals_all[pad]
    c = c_all[pad]
    S = pad.shape[0]
    m = rhs0.shape[0]

    # Woodbury pieces (see module docstring): row factors D(s) for the
    # pure-rescale rows, entry deltas dv of the q shape-changing rows.
    w_r = vals[:, e_r] * m_r[None, :]
    rhs_eff = rhs_act[None, :] - w_r @ M_r
    c_b = jnp.take_along_axis(c, bas_idx[None, :], axis=1) * bas_mask[None, :]
    dinv = 1.0 / (scale_mask[None, :] * vals[:, scale_e] * scale_m[None, :]
                  + (1.0 - scale_mask)[None, :])
    dv = vals[:, e_g] - dv0[None, :]
    Q = Hq.shape[1]
    Mlu = _lu_small(jnp.eye(Q, dtype=vals.dtype)[None, :, :]
                    + (dv @ P_M).reshape(S, Q, Q))

    # Primal:  B z_B = rhs_eff.
    t = (rhs_eff * dinv) @ B0inv.T
    h = _solve_small(Mlu, (dv * t[:, jpos_g]) @ Hq)
    z_b = t - h @ G0.T
    # Dual:  B^T y_act = -c_B.
    w0 = ((-c_b) @ B0inv) * dinv
    g = _solve_small_t(Mlu, w0 @ Hg)
    w = w0 - (((dv * g[:, rowq_g]) @ Hk) @ B0inv) * dinv

    z = (z_b * bas_mask[None, :]) @ jax.nn.one_hot(
        bas_idx, c.shape[1], dtype=vals.dtype) + nb_vec[None, :]
    z = jnp.clip(z, 0.0, ub[None, :])
    y = (w * act_mask[None, :]) @ jax.nn.one_hot(
        act_idx, m, dtype=vals.dtype)
    y = jnp.where(is_eq[None, :], y, jnp.maximum(y, 0.0))

    # Verification = the PDHG convergence criteria on the candidate.
    rowsv = (vals * z[:, cols_a]) @ Rm
    viol = jnp.where(is_eq[None, :], jnp.abs(rowsv - rhs0[None, :]),
                     jnp.maximum(rowsv - rhs0[None, :], 0.0))
    pf = jnp.max(viol, axis=1)
    p = jnp.sum(c * z, axis=1)
    rc = c + (vals * y[:, rows_a]) @ Rn
    d = -jnp.sum(rhs0[None, :] * y, axis=1) + jnp.sum(
        jnp.minimum(rc * ub[None, :], 0.0), axis=1)
    gap = jnp.abs(p - d) / (1.0 + jnp.abs(p) + jnp.abs(d))
    pf = jnp.where(jnp.isfinite(pf), pf, jnp.inf)
    gap = jnp.where(jnp.isfinite(gap), gap, jnp.inf)
    ok = (pf < TOL_PF) & (gap < TOL_GAP)
    score = jnp.maximum(pf, gap)
    return ok, p, z, y, rowsv, score


# ---------------------------------------------------------------------------
# PDHG kernels (phase 2): per-scenario Ruiz scaling + preconditioned
# restarted iterations, all S scenarios in lockstep.
# ---------------------------------------------------------------------------

@jax.jit
def _pdhg_setup(vals, c, rhs0, rows_a, cols_a, ub, z0, y0):
    S, nnz = vals.shape
    m = rhs0.shape[0]
    n = c.shape[1]
    vs = vals
    dr = jnp.ones((S, m), dtype=vals.dtype)
    dc = jnp.ones((S, n), dtype=vals.dtype)
    for _ in range(_RUIZ_ITERS):
        av = jnp.abs(vs)
        rmax = jnp.zeros((S, m), dtype=vals.dtype).at[:, rows_a].max(av)
        cmax = jnp.zeros((S, n), dtype=vals.dtype).at[:, cols_a].max(av)
        er = 1.0 / jnp.sqrt(jnp.maximum(rmax, 1e-12))
        ec = 1.0 / jnp.sqrt(jnp.maximum(cmax, 1e-12))
        vs = vs * er[:, rows_a] * ec[:, cols_a]
        dr = dr * er
        dc = dc * ec
    cs = c * dc
    rhss = rhs0[None, :] * dr
    ubs = ub[None, :] / dc
    av = jnp.abs(vs)
    sig0 = 1.0 / jnp.maximum(
        jnp.zeros((S, m), dtype=vals.dtype).at[:, rows_a].add(av), 1e-12)
    tau0 = 1.0 / jnp.maximum(
        jnp.zeros((S, n), dtype=vals.dtype).at[:, cols_a].add(av), 1e-12)
    omega = jnp.maximum(
        jnp.linalg.norm(cs, axis=1)
        / jnp.maximum(jnp.linalg.norm(rhss, axis=1), 1.0), 1e-4)
    z = jnp.clip(z0 / dc, 0.0, ubs)
    y = y0 * dr
    return vs, cs, rhss, ubs, sig0, tau0, omega, dr, dc, z, y


def _pdhg_residuals(vs, cs, rhss, ubs, dr, is_eq, rows_a, cols_a, Rm, Rn,
                    z, y):
    p = jnp.sum(cs * z, axis=1)
    kz = (vs * z[:, cols_a]) @ Rm
    r0 = kz - rhss
    pf = jnp.max(jnp.where(is_eq[None, :], jnp.abs(r0),
                           jnp.maximum(r0, 0.0)) / dr, axis=1)
    yc = jnp.where(is_eq[None, :], y, jnp.maximum(y, 0.0))
    rc = cs + (vs * yc[:, rows_a]) @ Rn
    d = -jnp.sum(rhss * yc, axis=1) + jnp.sum(
        jnp.minimum(rc * ubs, 0.0), axis=1)
    gap = jnp.abs(p - d) / (1.0 + jnp.abs(p) + jnp.abs(d))
    return p, pf, gap


@jax.jit
def _pdhg_block(vs, cs, rhss, ubs, sig0, tau0, is_eq, rows_a, cols_a,
                Rm, Rn, dr, omega, z, y, z_r, y_r, n_inner):
    """`n_inner` PDHG iterations + one restart/adaptation step."""
    tau = tau0 / omega[:, None]
    sig = sig0 * omega[:, None]

    def body(_, state):
        z, y, zs, ys = state
        kty = (vs * y[:, rows_a]) @ Rn
        zn = jnp.clip(z - tau * (cs + kty), 0.0, ubs)
        arg = 2.0 * zn - z
        kz = (vs * arg[:, cols_a]) @ Rm
        t = y + sig * (kz - rhss)
        yn = jnp.where(is_eq[None, :], t, jnp.maximum(t, 0.0))
        return zn, yn, zs + zn, ys + yn

    z, y, zs, ys = jax.lax.fori_loop(
        0, n_inner, body, (z, y, jnp.zeros_like(z), jnp.zeros_like(y)))
    cnt = n_inner.astype(vs.dtype)
    za, ya = zs / cnt, ys / cnt

    p, pf, gap = _pdhg_residuals(vs, cs, rhss, ubs, dr, is_eq,
                                 rows_a, cols_a, Rm, Rn, z, y)
    pa, pfa, gapa = _pdhg_residuals(vs, cs, rhss, ubs, dr, is_eq,
                                    rows_a, cols_a, Rm, Rn, za, ya)
    take_avg = jnp.maximum(pfa, gapa) < jnp.maximum(pf, gap)
    z = jnp.where(take_avg[:, None], za, z)
    y = jnp.where(take_avg[:, None], ya, y)
    p = jnp.where(take_avg, pa, p)
    pf = jnp.where(take_avg, pfa, pf)
    gap = jnp.where(take_avg, gapa, gap)

    dz = jnp.linalg.norm(z - z_r, axis=1)
    dy = jnp.linalg.norm(y - y_r, axis=1)
    can = (dz > 1e-12) & (dy > 1e-12)
    omega_new = jnp.exp(0.5 * jnp.log(jnp.where(can, dy / dz, 1.0))
                        + 0.5 * jnp.log(omega))
    omega = jnp.where(can, omega_new, omega)
    return z, y, omega, p, pf, gap


# ---------------------------------------------------------------------------
# Host-side anchors.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Anchor:
    act: np.ndarray            # active rows
    bas: np.ndarray            # basic columns (sorted; keying only)
    nb_ub: np.ndarray          # nonbasic columns at upper bound
    feat: np.ndarray           # perturbation-space features of the source
    pack: tuple                # padded device tensors for _candidate_kernel
    hits: int = 0
    feat_sum: np.ndarray = dataclasses.field(default=None)  # type: ignore[assignment]

    def __post_init__(self) -> None:
        if self.feat_sum is None:
            self.feat_sum = np.zeros_like(self.feat)

    @property
    def key(self) -> tuple:
        return (tuple(self.act.tolist()), tuple(self.bas.tolist()))

    @property
    def centroid(self) -> np.ndarray:
        """Running mean of the features this anchor has solved.

        Far more predictive than the harvest scenario's own features —
        the source sits at the EDGE of its basis cell, the centroid near
        the middle.  Falls back to the source until the first hit.
        """
        return self.feat_sum / self.hits if self.hits else self.feat


class BatchedStage2Solver(ExactChunkSolver):
    """Solve `ScenarioBatch`es against one `Stage2System`, batched.

    Anchors persist across `solve_scenarios` calls, so later chunks of a
    large S resolve almost entirely at iteration 0.  Thread-compatible
    with the relaxed Stage-2 protocol only (u_cap is pinned to ones).
    The exact oracle, the pattern plumbing, and the statistics recorder
    come from `ExactChunkSolver` — both engines share them verbatim.
    """

    def __init__(self, system: Stage2System, *, max_anchors: int = 32,
                 pdhg_max_iter: int = 20000, pdhg_check: int = 50):
        super().__init__(system)
        self.max_anchors = max_anchors
        self.pdhg_max_iter = pdhg_max_iter
        self.pdhg_check = pdhg_check
        inst = system.inst
        base_e = inst.e_base.mean(axis=1)
        self._feat_base = np.concatenate([inst.tau, inst.lam, base_e])
        self.anchors: list[_Anchor] = []
        self._anchor_keys: set[tuple] = set()
        self.diagnostics = {
            "n_anchor0": 0, "n_harvest_exact": 0, "n_pdhg": 0,
            "n_fallback_exact": 0, "pdhg_iters_max": 0, "n_scenarios": 0,
        }
        # Static device-side pattern tensors, shared by every kernel call.
        f64 = jnp.float64
        self._d_rhs0 = jnp.asarray(self.rhs0, dtype=f64)
        self._d_is_eq = jnp.asarray(self.is_eq, dtype=jnp.bool_)
        self._d_rows = jnp.asarray(self.rows, dtype=jnp.int64)
        self._d_cols = jnp.asarray(self.cols, dtype=jnp.int64)
        self._d_ub = jnp.asarray(self.ub, dtype=f64)
        # System-wide one-hot accumulation maps (see _candidate_kernel:
        # matmul accumulation beats XLA CPU's serial scatter lowering).
        E = self.nnz_all
        Rm = np.zeros((E, self.m))
        Rm[np.arange(E), self.rows] = 1.0
        Rn = np.zeros((E, self.n))
        Rn[np.arange(E), self.cols] = 1.0
        self._d_Rm = jnp.asarray(Rm, dtype=f64)
        self._d_Rn = jnp.asarray(Rn, dtype=f64)

    def _harvest_anchor(self, res, vals: np.ndarray, feat: np.ndarray
                        ) -> bool:
        """Extract an optimal basis from a linprog result; True if new."""
        n, nx, m_ub, I = self.n, self.nx, self.m_ub, self.I
        z = res.x
        y_ineq = -res.ineqlin.marginals
        resid = res.ineqlin.residual
        act = np.concatenate([
            np.where((np.abs(resid) < 1e-7) | (y_ineq > 1e-9))[0],
            m_ub + np.arange(I)])
        if act.size > n:
            # More active rows than columns: a square basis over the
            # column space cannot exist; trim to the rows with the
            # largest |dual| plus the equality block.
            strong = np.argsort(-np.abs(y_ineq[act[:-I]]))[:n - I]
            act = np.concatenate([act[:-I][strong], m_ub + np.arange(I)])
        at_lb = np.abs(z) < 1e-8
        at_ub = np.abs(z - self.ub) < 1e-8
        inside = ~(at_lb | at_ub)
        order = np.concatenate([
            np.where(inside)[0], np.where(at_ub)[0],
            np.where(at_lb & (np.arange(n) < nx))[0],
            np.where(at_lb & (np.arange(n) >= nx))[0]])
        Ad = sparse.coo_matrix((vals, (self.rows, self.cols)),
                               shape=(self.m, self.n)).toarray()
        W = Ad[np.ix_(act, order)].copy()
        k = act.size
        chosen: list[int] = []
        left = list(range(W.shape[1]))
        for _ in range(k):
            norms = np.linalg.norm(W[:, left], axis=0)
            good = np.where(norms > 1e-8)[0]
            if not good.size:
                return False
            j = left[good[0]]
            chosen.append(j)
            v = W[:, j] / np.linalg.norm(W[:, j])
            W -= np.outer(v, v @ W)
            left.remove(j)
        bas = np.sort(order[np.array(chosen)])
        nonbas = np.setdiff1d(np.arange(n), bas)
        nb_ub = nonbas[at_ub[nonbas]]
        key = (tuple(act.tolist()), tuple(bas.tolist()))
        if key in self._anchor_keys:
            return False
        pack = self._pack(act, bas, nb_ub, vals, Ad)
        if pack is None:                    # over the Woodbury budget
            return False
        self._anchor_keys.add(key)
        self.anchors.append(
            _Anchor(act=act, bas=bas, nb_ub=nb_ub, feat=feat, pack=pack))
        return True

    def _pack(self, act: np.ndarray, bas: np.ndarray, nb_ub: np.ndarray,
              vals: np.ndarray, Ad: np.ndarray) -> tuple | None:
        """Build an anchor's padded device tensors for `_candidate_kernel`.

        `vals`/`Ad` are the SOURCE scenario's entry values / dense matrix
        — the basis block (identity tail) is inverted once here and the
        kernel reconstructs every scenario's solve from it via Woodbury.
        Returns None when the basis exceeds every `_SHAPE_CLASSES`
        budget (shape-changing rows / their entry count): such an
        anchor is rejected and its scenarios take the PDHG/exact path.
        Otherwise pads to the smallest fitting (q, eg) class — the
        kernel jit-specializes per class, so small-q anchors never pay
        big-q shapes.
        """
        K, E = self.n, self.nnz_all
        k = act.size
        row_pos = np.full(self.m, -1)
        row_pos[act] = np.arange(k)
        col_pos = np.full(self.n, -1)
        col_pos[bas] = np.arange(k)
        in_nb = np.zeros(self.n, dtype=bool)
        in_nb[nb_ub] = True

        sel_r = np.where((row_pos[self.rows] >= 0) & in_nb[self.cols])[0]
        e_r = np.zeros(E, dtype=np.int64)
        i_r = np.zeros(E, dtype=np.int64)
        m_r = np.zeros(E)
        e_r[:sel_r.size] = sel_r
        i_r[:sel_r.size] = row_pos[self.rows[sel_r]]
        # ub == 1 everywhere in the relaxed protocol, so the nb_ub
        # contribution to rhs_eff is just the coefficient itself.
        m_r[:sel_r.size] = self.ub[self.cols[sel_r]]
        M_r = np.zeros((E, K))
        M_r[np.arange(E), i_r] = np.where(m_r != 0.0, 1.0, 0.0)

        # Row classification: eq rows are scenario-constant, kv/compute/
        # storage rows rescale as a whole (one factor per row), delay/
        # error rows genuinely change shape -> Woodbury slots.
        fam = self.system.row_family
        scale_e = np.zeros(K, dtype=np.int64)
        scale_m = np.zeros(K)
        scale_mask = np.zeros(K)
        gen_pos: list[int] = []
        for p, r in enumerate(act):
            if r >= self.m_ub:
                continue                    # equality row: constant
            if fam[r] >= 3:
                gen_pos.append(p)           # delay/error: shape-changing
                continue
            ee = np.where(self.rows == r)[0]
            rep = ee[np.argmax(np.abs(vals[ee]))]
            if abs(vals[rep]) < 1e-12:      # degenerate rescale source
                gen_pos.append(p)
                continue
            scale_e[p] = rep
            scale_m[p] = 1.0 / vals[rep]
            scale_mask[p] = 1.0
        gen_rows = act[np.array(gen_pos, dtype=np.int64)]
        slot = {int(r): a for a, r in enumerate(gen_rows)}
        sel_g = np.where(np.isin(self.rows, gen_rows)
                         & (col_pos[self.cols] >= 0))[0]
        cls = next((c for c in _SHAPE_CLASSES
                    if len(gen_pos) <= c[0] and sel_g.size <= c[1]), None)
        if cls is None:
            return None
        Q, EG = cls

        P0 = np.eye(K)
        P0[:k, :k] = Ad[np.ix_(act, bas)]
        B0inv = np.linalg.inv(P0)
        G0 = np.zeros((K, Q))
        Hg = np.zeros((K, Q))
        for a, p in enumerate(gen_pos):
            G0[:, a] = B0inv[:, p]
            Hg[p, a] = 1.0
        e_g = np.zeros(EG, dtype=np.int64)
        dv0 = np.zeros(EG)
        jpos_g = np.zeros(EG, dtype=np.int64)
        rowq_g = np.zeros(EG, dtype=np.int64)
        Hq = np.zeros((EG, Q))
        Hk = np.zeros((EG, K))
        P_M = np.zeros((EG, Q * Q))
        for t, e in enumerate(sel_g):
            e_g[t] = e
            dv0[t] = vals[e]
            jp = col_pos[self.cols[e]]
            a = slot[int(self.rows[e])]
            jpos_g[t] = jp
            rowq_g[t] = a
            Hq[t, a] = 1.0
            Hk[t, jp] = 1.0
            P_M[t, a * Q:(a + 1) * Q] = G0[jp, :]

        rhs_act = np.zeros(K)
        rhs_act[:k] = self.rhs0[act]
        bas_idx = np.zeros(K, dtype=np.int64)
        bas_idx[:k] = bas
        bas_mask = np.zeros(K)
        bas_mask[:k] = 1.0
        nb_vec = np.zeros(self.n)
        nb_vec[nb_ub] = self.ub[nb_ub]
        act_idx = np.zeros(K, dtype=np.int64)
        act_idx[:k] = act
        act_mask = np.zeros(K)
        act_mask[:k] = 1.0
        f64, i64 = jnp.float64, jnp.int64
        return (jnp.asarray(e_r, dtype=i64), jnp.asarray(m_r, dtype=f64),
                jnp.asarray(M_r, dtype=f64), jnp.asarray(rhs_act, dtype=f64),
                jnp.asarray(scale_e, dtype=i64),
                jnp.asarray(scale_m, dtype=f64),
                jnp.asarray(scale_mask, dtype=f64),
                jnp.asarray(e_g, dtype=i64), jnp.asarray(dv0, dtype=f64),
                jnp.asarray(jpos_g, dtype=i64),
                jnp.asarray(rowq_g, dtype=i64),
                jnp.asarray(Hq, dtype=f64), jnp.asarray(Hk, dtype=f64),
                jnp.asarray(P_M, dtype=f64), jnp.asarray(Hg, dtype=f64),
                jnp.asarray(bas_idx, dtype=i64),
                jnp.asarray(bas_mask, dtype=f64),
                jnp.asarray(nb_vec, dtype=f64),
                jnp.asarray(act_idx, dtype=i64),
                jnp.asarray(act_mask, dtype=f64),
                jnp.asarray(B0inv, dtype=f64), jnp.asarray(G0, dtype=f64))

    # -- scenario features (anchor ordering only; no correctness role) --
    def _features(self, batch: ScenarioBatch) -> np.ndarray:
        inst = self.system.inst
        S = batch.S
        tau = (np.broadcast_to(inst.tau, (S, inst.I)) if batch.tau is None
               else batch.tau)
        lam = (np.broadcast_to(inst.lam, (S, inst.I)) if batch.lam is None
               else batch.lam)
        eb = (np.broadcast_to(inst.e_base.mean(axis=1), (S, inst.I))
              if batch.e_base is None else batch.e_base.mean(axis=2))
        feats = np.concatenate([tau, lam, eb], axis=1)
        return feats / np.maximum(self._feat_base[None, :], 1e-12)

    # -- the batched solve ----------------------------------------------
    def solve_scenarios(self, batch: ScenarioBatch) -> _ChunkArrays:
        system = self.system
        S = batch.S
        vals, c = system.coefficient_batch(batch)
        feats = self._features(batch)
        out = _ChunkArrays(S, self.n_fam)
        diag = self.diagnostics
        diag["n_scenarios"] += S

        if not self.anchors:
            v0, c0 = system.coefficient_batch(ScenarioBatch(S=1))
            res0 = self._exact(v0[0], c0[0])
            self._harvest_anchor(res0, v0[0],
                                 np.ones_like(self._feat_base))

        # One chunk-wide device residency (padded to a bucket so chunk
        # length doesn't multiply kernel compiles); per-group rows are
        # gathered on device, inside the kernel's jit.
        Scb = _bucket(S)
        vals_p = np.zeros((Scb, vals.shape[1]))
        vals_p[:S] = vals
        c_p = np.zeros((Scb, c.shape[1]))
        c_p[:S] = c
        d_vals_all = jnp.asarray(vals_p, dtype=jnp.float64)
        d_c_all = jnp.asarray(c_p, dtype=jnp.float64)
        feat_sq = np.sum(feats * feats, axis=1)

        unresolved = np.arange(S)
        tried = np.zeros((S, 0), dtype=bool)
        best_score = np.full(S, np.inf)
        best_z = np.zeros((S, self.n))
        best_y = np.zeros((S, self.m))

        while unresolved.size:
            A = len(self.anchors)
            still: list[np.ndarray] = []
            if A == 0:
                # No kernel-representable anchor yet (every harvested
                # basis tripped every _SHAPE_CLASSES cap): skip the anchor
                # pass — the harvest/PDHG tail below sees everything
                # exhausted and keeps making progress one exact solve
                # (or one PDHG batch) at a time.
                exhausted_idx = unresolved
                live = pick = np.zeros(0, dtype=np.int64)
            else:
                if tried.shape[1] < A:
                    tried = np.concatenate(
                        [tried, np.zeros((S, A - tried.shape[1]), bool)],
                        axis=1)
                # Anchor ordering (heuristic only — never affects
                # correctness): first pass goes to the nearest
                # hit-centroid, retries walk the untried anchors by hit
                # frequency.
                afeat = np.stack([a.centroid for a in self.anchors])
                hits = np.array([a.hits for a in self.anchors], dtype=float)
                t_u = tried[unresolved]
                fu = feats[unresolved]
                dist = (feat_sq[unresolved, None]
                        + np.sum(afeat * afeat, axis=1)[None, :]
                        - 2.0 * (fu @ afeat.T))
                dist[t_u] = np.inf
                hit_score = np.where(t_u, -np.inf, hits[None, :])
                first = ~t_u.any(axis=1)
                pick = np.where(first, np.argmin(dist, axis=1),
                                np.argmax(hit_score, axis=1))
                exhausted = ~np.isfinite(
                    dist[np.arange(unresolved.size), pick])
                exhausted_idx = unresolved[exhausted]
                live = unresolved[~exhausted]
                pick = pick[~exhausted]

            for a_id in np.unique(pick):
                grp = live[pick == a_id]
                tried[grp, a_id] = True
                anchor = self.anchors[a_id]
                # Gather the group's rows and pad to a compile bucket —
                # the kernel only ever does work proportional to the
                # scenarios actually trying this anchor.
                Sg = grp.size
                Sb = _bucket(Sg)
                pad = np.concatenate([grp, np.repeat(grp[:1], Sb - Sg)])
                d_pad = jnp.asarray(pad, dtype=jnp.int64)
                ok, p, z, y, rowsv, score = _candidate_kernel(
                    d_vals_all, d_c_all, d_pad,
                    self._d_rhs0, self._d_is_eq,
                    self._d_rows, self._d_cols, self._d_ub,
                    self._d_Rm, self._d_Rn, *anchor.pack)
                ok_np = np.asarray(ok)[:Sg]
                hit = grp[ok_np]
                z_np = None
                if hit.size:
                    anchor.hits += int(hit.size)
                    anchor.feat_sum += feats[hit].sum(axis=0)
                    diag["n_anchor0"] += int(hit.size)
                    out.costs[hit] = np.asarray(p)[:Sg][ok_np]
                    z_np = np.asarray(z)[:Sg]
                    rows_np = np.asarray(rowsv)[:Sg]
                    out.record_batch(hit, z_np[ok_np], rows_np[ok_np], self)
                miss = grp[~ok_np]
                if miss.size:
                    sc = np.asarray(score)[:Sg][~ok_np]
                    better = sc < best_score[miss]
                    upd = miss[better]
                    if upd.size:
                        if z_np is None:
                            z_np = np.asarray(z)[:Sg]
                        best_score[upd] = sc[better]
                        best_z[upd] = z_np[~ok_np][better]
                        best_y[upd] = np.asarray(y)[:Sg][~ok_np][better]
                    still.append(miss)

            leftovers = (np.concatenate(still) if still
                         else np.zeros(0, dtype=np.int64))
            if exhausted_idx.size:
                if len(self.anchors) < self.max_anchors:
                    # Harvest: exact-solve one representative; its basis
                    # joins the anchor set, the others retry against it.
                    s = int(exhausted_idx[0])
                    res = self._exact(vals[s], c[s])
                    diag["n_harvest_exact"] += 1
                    self._record_exact(s, vals[s], c[s], res, out)
                    self._harvest_anchor(res, vals[s], feats[s])
                    unresolved = np.concatenate(
                        [leftovers, exhausted_idx[1:]])
                    continue
                # Anchor space exhausted: hand the rest to PDHG.
                unresolved = np.zeros(0, dtype=np.int64)
                pdhg_idx = np.concatenate([leftovers, exhausted_idx])
                self._run_pdhg(pdhg_idx, vals, c, best_z, best_y, out)
                return out
            unresolved = leftovers

        return out

    def _run_pdhg(self, idx: np.ndarray, vals: np.ndarray, c: np.ndarray,
                  best_z: np.ndarray, best_y: np.ndarray,
                  out: _ChunkArrays) -> None:
        """Phase 2 (restarted PDHG) + phase 3 (exact fallback)."""
        diag = self.diagnostics
        if not idx.size:
            return
        Sp = idx.size
        Sb = _bucket(Sp)
        pad = np.concatenate([idx, np.repeat(idx[:1], Sb - Sp)])
        d_vals = jnp.asarray(vals[pad], dtype=jnp.float64)
        d_c = jnp.asarray(c[pad], dtype=jnp.float64)
        d_z0 = jnp.asarray(best_z[pad], dtype=jnp.float64)
        d_y0 = jnp.asarray(best_y[pad], dtype=jnp.float64)
        (vs, cs, rhss, ubs, sig0, tau0, omega, dr, dc, z, y) = _pdhg_setup(
            d_vals, d_c, self._d_rhs0, self._d_rows, self._d_cols,
            self._d_ub, d_z0, d_y0)
        z_r, y_r = z, y
        n_inner = jnp.asarray(self.pdhg_check, dtype=jnp.int64)
        done = np.zeros(Sb, dtype=bool)
        p_done = np.zeros(Sb)
        z_done = np.zeros((Sb, self.n))
        it = 0
        while it < self.pdhg_max_iter:
            z, y, omega, p, pf, gap = _pdhg_block(
                vs, cs, rhss, ubs, sig0, tau0, self._d_is_eq,
                self._d_rows, self._d_cols, self._d_Rm, self._d_Rn,
                dr, omega, z, y, z_r, y_r, n_inner)
            z_r, y_r = z, y
            it += self.pdhg_check
            ok = np.asarray((pf < TOL_PF) & (gap < TOL_GAP))
            new = ok & ~done
            if new.any():
                p_np = np.asarray(p)
                z_phys = np.asarray(z * dc)
                p_done[new] = p_np[new]
                z_done[new] = z_phys[new]
                done |= new
            if done[:Sp].all():
                break
        diag["pdhg_iters_max"] = max(diag["pdhg_iters_max"], it)
        conv = np.where(done[:Sp])[0]
        if conv.size:
            diag["n_pdhg"] += int(conv.size)
            sel = idx[conv]
            out.costs[sel] = p_done[conv]
            for j, s in zip(conv, sel, strict=True):
                out.record_z(int(s), vals[s], z_done[j], self)
        fail = np.where(~done[:Sp])[0]
        for j in fail:
            s = int(idx[j])
            res = self._exact(vals[s], c[s])
            diag["n_fallback_exact"] += 1
            self._record_exact(s, vals[s], c[s], res, out)
