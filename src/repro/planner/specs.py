"""Declarative scenario specs: fleet x workload x SLO -> `Instance`.

Replaces the ad-hoc `default_instance(...)` / `random_instance(...)`
kwarg-wiring that every benchmark and example hand-rolled.  A scenario is
three orthogonal pieces:

* `FleetSpec`    — which hardware catalog serves (the paper's GPU tier
  table, or the TPU tier catalog from `core/bridge.py`) and which (TP, PP)
  lattice is allowed;
* `WorkloadSpec` — which query-type population (the paper's Azure-trace-
  calibrated six types, or a synthetic population of any size) and which
  demand process drives replays (flat / diurnal / bursty / random-walk);
* `SLOSpec`      — budget, penalty multipliers, unmet caps, and optional
  uniform delay+error stress.

`ScenarioSpec.build()` composes them into a fully derived `Instance`;
`ScenarioSpec.demand_path()` materializes the demand process as a
[T, I] arrival path for rolling-horizon replays.  Named generators
(`scenario("paper-default")`, "azure-diurnal", "bursty", "budget-tight",
"tpu-fleet", "fleet-scale", ...) cover the repo's standard studies; new
workload families are one registry entry, not a new kwargs plumbing job.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.instance import Instance, default_instance, random_instance
from repro.core.trace import (diurnal_multipliers, multi_day_multipliers,
                              random_walk_lambdas)


# Grid carbon intensity by region, kgCO2e per kWh (rounded long-run
# averages: hydro/nuclear-heavy EU-North vs coal-heavy Asia-East).  Keyed
# by the region names `FleetSpec.regions` draws from.
REGION_INTENSITY: dict[str, float] = {
    "eu-north": 0.04,
    "us-central": 0.40,
    "asia-east": 0.60,
}


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Hardware catalog + parallelism lattice + supply economics.

    ``spot_tiers`` marks part of the catalog spot-priced through
    `core.faults.with_spot_tiers` — ``"quantized"`` puts the
    INT-quantized tiers on spot (the cheap, revocable capacity pool),
    ``"all"`` the whole fleet; rental is discounted by ``spot_discount``
    and revocable at ``spot_revoke_rate`` Poisson revocations/hour
    (consumed by `ScenarioSpec.fault_schedule`).

    ``regions`` places tiers round-robin across named regions and, with
    ``carbon_price`` ($/kgCO2e), folds each region's grid carbon
    intensity (`REGION_INTENSITY`) into the rental rate via
    `core.carbon.carbon_priced` — the multi-region cost asymmetry the
    planner then arbitrages.  ``carbon_price`` without ``regions`` prices
    every tier at the default grid intensity.
    """
    catalog: str = "gpu"                    # "gpu" (paper) | "tpu" (bridge)
    tp_degrees: tuple[int, ...] | None = None
    pp_depths: tuple[int, ...] | None = None
    spot_tiers: str | None = None           # None | "quantized" | "all"
    spot_discount: float = 0.8
    spot_revoke_rate: float = 0.25
    regions: tuple[str, ...] | None = None
    carbon_price: float | None = None

    def apply(self, inst: Instance) -> Instance:
        if self.catalog == "tpu":
            from repro.core.bridge import tpu_instance
            inst = tpu_instance(inst)
        elif self.catalog != "gpu":
            raise ValueError(f"unknown fleet catalog {self.catalog!r} "
                             f"(expected 'gpu' or 'tpu')")
        if self.tp_degrees is not None or self.pp_depths is not None:
            inst = dataclasses.replace(
                inst,
                tp_degrees=list(self.tp_degrees or inst.tp_degrees),
                pp_depths=list(self.pp_depths or inst.pp_depths))
            inst.__post_init__()
        if self.carbon_price is not None:
            from repro.core.carbon import carbon_priced
            inst = carbon_priced(inst, carbon_price=self.carbon_price,
                                 intensity=self.tier_intensity(inst))
        if self.spot_tiers is not None:
            from repro.core.faults import with_spot_tiers
            inst = with_spot_tiers(inst, self.spot_mask(inst),
                                   discount=self.spot_discount,
                                   revoke_rate=self.spot_revoke_rate)
        return inst

    def spot_mask(self, inst: Instance) -> np.ndarray:
        """[K] bool mask of the spot-priced tiers under ``spot_tiers``."""
        if self.spot_tiers == "all":
            return np.ones(inst.K, dtype=bool)
        if self.spot_tiers == "quantized":
            return np.array(["INT" in str(n).upper()
                             for n in inst.tier_names], dtype=bool)
        raise ValueError(f"unknown spot_tiers {self.spot_tiers!r} "
                         f"(expected 'quantized' or 'all')")

    def region_of(self, inst: Instance) -> tuple[str, ...] | None:
        """Tier -> region assignment (round-robin over ``regions``)."""
        if self.regions is None:
            return None
        R = len(self.regions)
        return tuple(self.regions[k % R] for k in range(inst.K))

    def tier_intensity(self, inst: Instance) -> dict[str, float] | None:
        """Per-tier-name grid intensity for `core.carbon` (None = default
        intensity everywhere)."""
        placed = self.region_of(inst)
        if placed is None:
            return None
        return {str(n): REGION_INTENSITY[r]
                for n, r in zip(inst.tier_names, placed, strict=True)}


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """Query-type population + demand process.

    ``family="paper"`` uses the Azure-trace-calibrated base population
    (§5.1); ``family="synthetic"`` draws a population of (I, J, K) types /
    models / tiers with `random_instance`.  ``demand`` picks the temporal
    process for `demand_path`: "flat" (constant), "diurnal" (busy-day
    trace replica), "bursty" (volatile-day replica: deeper peaks, heavier
    noise), "multi-day" (busy+volatile concatenation), or "random-walk"
    (geometric, volatility ``sigma``).
    """
    family: str = "paper"
    I: int = 6
    J: int = 6
    K: int = 10
    lam_scale: float = 1.0
    demand: str = "flat"
    n_windows: int = 288
    days: tuple[str, ...] = ("busy", "volatile")
    sigma: float = 0.03


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Budget / penalty / stress knobs."""
    budget: float | None = None
    phi_v_mult: float = 1.0
    zeta: float = 1.0
    stress: float | None = None             # uniform delay+error inflation


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str = "custom"
    fleet: FleetSpec = dataclasses.field(default_factory=FleetSpec)
    workload: WorkloadSpec = dataclasses.field(default_factory=WorkloadSpec)
    slo: SLOSpec = dataclasses.field(default_factory=SLOSpec)
    seed: int = 0

    def build(self) -> Instance:
        """The fully derived `Instance` for this scenario."""
        w, s = self.workload, self.slo
        if w.family == "paper":
            inst = default_instance(
                seed=self.seed,
                budget=100.0 if s.budget is None else s.budget,
                phi_v_mult=s.phi_v_mult, zeta=s.zeta)
        elif w.family == "synthetic":
            inst = random_instance(w.I, w.J, w.K, seed=self.seed,
                                   budget=s.budget)
            if s.zeta != 1.0 or s.phi_v_mult != 1.0:
                inst = dataclasses.replace(
                    inst, zeta=np.full(inst.I, s.zeta),
                    phi=inst.phi * s.phi_v_mult)
                inst.__post_init__()
        else:
            raise ValueError(f"unknown workload family {w.family!r} "
                             f"(expected 'paper' or 'synthetic')")
        inst = self.fleet.apply(inst)
        if s.stress is not None:
            inst = inst.stressed(s.stress)
        if w.lam_scale != 1.0:
            inst = inst.with_lam(inst.lam * w.lam_scale)
        return inst

    def demand_path(self, inst: Instance | None = None) -> np.ndarray:
        """[T, I] arrival path realizing the workload's demand process."""
        inst = inst if inst is not None else self.build()
        w = self.workload
        if w.demand == "flat":
            return np.tile(inst.lam, (w.n_windows, 1))
        if w.demand == "diurnal":
            mult = diurnal_multipliers("busy", seed=self.seed + 7,
                                       n_windows=w.n_windows)
        elif w.demand == "bursty":
            mult = diurnal_multipliers("volatile", seed=self.seed + 7,
                                       n_windows=w.n_windows)
        elif w.demand == "multi-day":
            mult = multi_day_multipliers(w.days, seed=self.seed + 7,
                                         n_windows=w.n_windows)
        elif w.demand == "random-walk":
            rng = np.random.default_rng(self.seed)
            return random_walk_lambdas(inst.lam, w.sigma, w.n_windows, rng)
        else:
            raise ValueError(f"unknown demand process {w.demand!r}")
        return np.outer(mult, inst.lam)

    def fault_schedule(self, inst: Instance | None = None,
                       n_windows: int | None = None,
                       frac: float = 1.0):
        """Seeded supply-fault schedule matching this scenario's spot
        economics: a Poisson revocation process over the spot tiers
        (`core.faults.poisson_revocations`, rate from the fleet's
        ``spot_revoke_rate``).  Returns an EMPTY `FaultSchedule` when the
        fleet has no spot tiers — callers can pass it to `rolling`
        unconditionally."""
        from repro.core.faults import FaultSchedule, poisson_revocations
        inst = inst if inst is not None else self.build()
        T = n_windows if n_windows is not None else self.workload.n_windows
        events = poisson_revocations(inst, T, seed=self.seed + 13,
                                     frac=frac)
        return FaultSchedule(n_windows=T, events=tuple(events))


# ---------------------------------------------------------------------------
# Named scenario generators
# ---------------------------------------------------------------------------

SCENARIOS: dict[str, ScenarioSpec] = {
    # The paper's base instance (§5.1): Azure-trace-calibrated workload
    # statistics on the NVIDIA GPU tier table.
    "paper-default": ScenarioSpec(name="paper-default"),
    # Same calibration with the diurnal busy-day replay process attached
    # (Table 5 / Fig. 6).
    "azure-diurnal": ScenarioSpec(
        name="azure-diurnal",
        workload=WorkloadSpec(demand="diurnal")),
    # Volatile-day replica: ~15.6x peak-to-trough, heavier-tailed noise.
    "bursty": ScenarioSpec(
        name="bursty", workload=WorkloadSpec(demand="bursty")),
    # Tight-budget stress (the paper's S3 scenario: $72/day).
    "budget-tight": ScenarioSpec(
        name="budget-tight", slo=SLOSpec(budget=72.0)),
    # High-penalty + tight budget (S5): image/video unmet penalties x5.
    "high-penalty": ScenarioSpec(
        name="high-penalty", slo=SLOSpec(budget=72.0, phi_v_mult=5.0)),
    # The paper's planner provisioning a TPU fleet (core/bridge.py tier
    # catalog: v5e/v5p/v4 x bf16/int8, TP up to 16).
    "tpu-fleet": ScenarioSpec(
        name="tpu-fleet", fleet=FleetSpec(catalog="tpu")),
    # Beyond-paper fleet-scale population (the PR-4 acceptance size).
    "fleet-scale": ScenarioSpec(
        name="fleet-scale",
        workload=WorkloadSpec(family="synthetic", I=100, J=80, K=40),
        seed=42),
    # Out-of-sample robustness: 1.5x uniform delay+error inflation.
    "stress-1.5x": ScenarioSpec(
        name="stress-1.5x", slo=SLOSpec(stress=1.5)),
    # Spot economics: the INT-quantized tiers move to a 20%-discounted,
    # revocable spot pool; `.fault_schedule()` yields the matching Poisson
    # revocation process for failure replays (core/faults.py).
    "spot-fleet": ScenarioSpec(
        name="spot-fleet",
        fleet=FleetSpec(spot_tiers="quantized"),
        workload=WorkloadSpec(demand="diurnal")),
    # Carbon-priced multi-region fleet: tiers round-robin across three
    # grids (core/carbon.py intensities), carbon folded into rental at
    # $0.15/kgCO2e — clean-region capacity gets structurally cheaper.
    "multi-region": ScenarioSpec(
        name="multi-region",
        fleet=FleetSpec(regions=("eu-north", "us-central", "asia-east"),
                        carbon_price=0.15)),
}


def list_scenarios() -> tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


def scenario(name: str, *, seed: int | None = None,
             n_windows: int | None = None,
             budget: float | None = None) -> ScenarioSpec:
    """Look up a named scenario, optionally overriding the common knobs.

    Unknown names raise with the registered list, mirroring the solver
    registry's contract.
    """
    spec = SCENARIOS.get(name)
    if spec is None:
        raise KeyError(f"unknown scenario {name!r}; registered scenarios: "
                       f"{', '.join(list_scenarios())}")
    if seed is not None:
        spec = dataclasses.replace(spec, seed=seed)
    if n_windows is not None:
        spec = dataclasses.replace(
            spec, workload=dataclasses.replace(spec.workload,
                                               n_windows=n_windows))
    if budget is not None:
        spec = dataclasses.replace(
            spec, slo=dataclasses.replace(spec.slo, budget=budget))
    return spec
