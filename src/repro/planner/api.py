"""The unified planning facade: `plan(request) -> PlanResult`.

One entry point replaces the five loose solver functions (`gh`, `agh`,
`solve_milp`, `dvr`/`hf`/`lpr`) and their divergent kwargs:

* `PlanOptions` — the typed option set every solver draws from (restarts,
  local-search mode, workers, time limit, ...).  Irrelevant options are
  ignored by construction (each adapter picks the fields it understands),
  but the *names* are checked: `PlanOptions` is a frozen dataclass, so a
  typo'd option fails at the call site instead of vanishing into `**kw`.
* `PlanRequest` — solver name (resolved through the registry) + problem
  (an `Instance`, or a declarative scenario spec / scenario name from
  `repro.planner.specs`) + options + optional warm-start incumbent.
* `PlanResult` — solution, objective, cost breakdown, per-constraint
  slack report, wall/CPU timings, and solver diagnostics; JSON-round-
  trippable so benchmark dumps and the CI regression gate consume
  registry-keyed rows directly.

The old entry points remain as thin, bit-identical shims — the facade
calls exactly them, pinned by tests/test_planner_api.py.
"""
from __future__ import annotations

import dataclasses
import json
import time

from repro.core.instance import Instance
from repro.core.solution import (Solution, _constraint_usage, cost_terms,
                                 feasibility, objective, slack_report)

from .registry import get_solver


@dataclasses.dataclass(frozen=True)
class PlanOptions:
    """Typed solver options (the union of what the backends understand).

    | field          | consumed by        | meaning                        |
    |----------------|--------------------|--------------------------------|
    | ``seed``       | agh                | RNG seed for random restarts   |
    | ``restarts``   | agh                | random-restart count R         |
    |                |                    | (None = Remark-2 adaptive)     |
    | ``passes``     | agh                | local-search pass cap L        |
    | ``patience``   | agh                | early-stop patience            |
    | ``local_search``| agh               | "batched" / "batched-rescan" / |
    |                |                    | "reference"                    |
    | ``engine``     | agh                | "numpy" (default, the oracle)  |
    |                |                    | / "xla" (jitted batched tier;  |
    |                |                    | needs jax, loaded lazily)      |
    | ``batch_width``| agh (engine=xla)   | lanes per device call in the   |
    |                |                    | lockstep batch (None = all)    |
    | ``workers``    | agh                | multi-start fan-out width      |
    | ``validate``   | agh                | per-move debug consistency     |
    | ``order``      | gh                 | Phase-2 type ordering override |
    | ``run_phase1`` | gh                 | coverage pre-allocation on/off |
    | ``ablation``   | gh                 | M1/M2/M3 ablation switches     |
    | ``time_limit`` | milp, lpr          | solver wall-clock cap (s);     |
    |                |                    | None = the backend's own       |
    |                |                    | default (milp 600, lpr 120),   |
    |                |                    | keeping facade == direct call  |
    | ``mip_rel_gap``| milp               | MIP relative-gap tolerance     |
    | ``relax``      | milp               | solve the LP relaxation        |
    | ``risk``       | plan() post-pass   | kwargs for `repro.risk.        |
    |                |                    | risk_evaluate` run on the      |
    |                |                    | solved plan (e.g. {"S": 5000,  |
    |                |                    | "engine": "pdhg"}); the report |
    |                |                    | summary lands in               |
    |                |                    | diagnostics["risk"].  None     |
    |                |                    | (default) skips it — no jax    |
    |                |                    | import, bit-identical output   |
    """
    seed: int = 0
    restarts: int | None = None
    passes: int = 3
    patience: int = 5
    local_search: str = "batched"
    engine: str = "numpy"
    batch_width: int | None = None
    workers: int | None = None
    validate: bool = False
    order: tuple[int, ...] | None = None
    run_phase1: bool = True
    ablation: frozenset = frozenset()
    time_limit: float | None = None
    mip_rel_gap: float = 1e-3
    relax: bool = False
    risk: dict | None = None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ablation"] = sorted(self.ablation)
        d["order"] = list(self.order) if self.order is not None else None
        return d

    @staticmethod
    def from_dict(d: dict) -> "PlanOptions":
        d = dict(d)
        if d.get("ablation") is not None:
            d["ablation"] = frozenset(d["ablation"])
        if d.get("order") is not None:
            d["order"] = tuple(d["order"])
        return PlanOptions(**d)


@dataclasses.dataclass
class PlanRequest:
    """What to solve, with what, and how.

    Exactly one of `instance` / `scenario` must be given; `scenario` is a
    `ScenarioSpec` or a registered scenario name (see
    `repro.planner.specs.scenario`).
    """
    solver: str = "agh"
    instance: Instance | None = None
    scenario: object | None = None      # ScenarioSpec | str
    options: PlanOptions = dataclasses.field(default_factory=PlanOptions)
    warm_start: Solution | None = None

    def resolve_instance(self) -> Instance:
        if (self.instance is None) == (self.scenario is None):
            raise ValueError("PlanRequest needs exactly one of "
                             "instance= or scenario=")
        if self.instance is not None:
            return self.instance
        from .specs import ScenarioSpec, scenario
        spec = self.scenario
        if isinstance(spec, str):
            spec = scenario(spec)
        if not isinstance(spec, ScenarioSpec):
            raise TypeError(f"scenario must be a ScenarioSpec or a "
                            f"registered name, got {type(spec).__name__}")
        return spec.build()


@dataclasses.dataclass
class PlanResult:
    """Structured solver output — everything a caller used to re-derive by
    hand from a bare `Solution` (and several things none could get at all).

    ``diagnostics`` is solver-specific but JSON-safe: AGH reports
    orderings evaluated, local-search moves applied, drains, fallback
    rescans, and warm-start provenance; MILP reports its status string.
    """
    solver: str
    solution: Solution
    objective: float
    cost_breakdown: dict[str, float]
    slack: dict[str, float]
    violations: dict[str, float]
    feasible: bool
    wall_s: float
    cpu_s: float
    diagnostics: dict
    options: dict

    def summary(self) -> dict:
        """Flat registry-row summary (no arrays) for benchmark JSON dumps."""
        return {"solver": self.solver, "objective": round(self.objective, 4),
                "wall_s": round(self.wall_s, 4),
                "feasible": self.feasible, **{
                    f"slack_{k}": (round(v, 6) if v != float("inf") else None)
                    for k, v in self.slack.items()}}

    def to_dict(self) -> dict:
        return {
            "solver": self.solver, "solution": self.solution.to_dict(),
            "objective": self.objective,
            "cost_breakdown": self.cost_breakdown, "slack": self.slack,
            "violations": self.violations, "feasible": self.feasible,
            "wall_s": self.wall_s, "cpu_s": self.cpu_s,
            "diagnostics": self.diagnostics, "options": self.options,
        }

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    @staticmethod
    def from_dict(d: dict) -> "PlanResult":
        return PlanResult(
            solver=d["solver"], solution=Solution.from_dict(d["solution"]),
            objective=float(d["objective"]),
            cost_breakdown=dict(d["cost_breakdown"]),
            slack={k: (float("inf") if v is None else float(v))
                   for k, v in d["slack"].items()},
            violations=dict(d["violations"]), feasible=bool(d["feasible"]),
            wall_s=float(d["wall_s"]), cpu_s=float(d["cpu_s"]),
            diagnostics=dict(d["diagnostics"]), options=dict(d["options"]))

    @staticmethod
    def from_json(s: str) -> "PlanResult":
        return PlanResult.from_dict(json.loads(s))


def plan(request: PlanRequest | str | None = None, *,
         instance: Instance | None = None, scenario: object | None = None,
         options: PlanOptions | None = None,
         warm_start: Solution | None = None,
         engine: str | None = None) -> PlanResult:
    """Solve one planning request through the registry.

    Accepts a full `PlanRequest`, or the convenience form
    ``plan("agh", instance=inst, options=PlanOptions(...))``.
    ``engine=`` is convenience-form shorthand for
    ``options=dataclasses.replace(options, engine=...)`` — e.g.
    ``plan(instance=inst, engine="xla")`` runs AGH on the jitted XLA
    tier (requires jax; raises `EngineUnavailableError` otherwise).
    """
    if isinstance(request, str) or request is None:
        opts = options or PlanOptions()
        if engine is not None:
            opts = dataclasses.replace(opts, engine=engine)
        request = PlanRequest(solver=request or "agh", instance=instance,
                              scenario=scenario, options=opts,
                              warm_start=warm_start)
    elif (instance is not None or scenario is not None
          or options is not None or warm_start is not None
          or engine is not None):
        raise ValueError("pass either a PlanRequest or keyword fields, "
                         "not both")
    spec = get_solver(request.solver)
    inst = request.resolve_instance()
    warm = request.warm_start if spec.supports_warm_start else None
    t0, c0 = time.perf_counter(), time.process_time()
    sol, diag = spec.solve(inst, request.options, warm)
    wall, cpu = time.perf_counter() - t0, time.process_time() - c0
    diag = dict(diag)
    if request.warm_start is not None:
        diag.setdefault("warm_started", spec.supports_warm_start)
    result = build_result(spec.name, inst, sol, wall, cpu, diag,
                          request.options)
    if request.options.risk is not None:
        # Post-pass tail-risk evaluation of the solved plan.  Lazy
        # import: plans without risk= never touch repro.risk (nor jax).
        from repro.risk import risk_evaluate
        report = risk_evaluate(inst, result.solution,
                               **request.options.risk)
        result.diagnostics["risk"] = report.summary()
    return result


def build_result(solver: str, inst: Instance, sol: Solution, wall_s: float,
                 cpu_s: float, diagnostics: dict,
                 options: PlanOptions) -> PlanResult:
    """Assemble a `PlanResult` from a solved `Solution` — the one place
    the violation/slack views are derived, shared by `plan()` and
    `PlanSession.repair()` (which scores ladder retries against the REAL
    faulted instance through this same path).

    The constraint system is evaluated INCLUDING the zeta unmet cap, so
    `feasible` can never contradict slack["unmet"].  (The heuristics
    themselves treat zeta as soft — Stage-2 routing enforces it — so a
    zeta-violating plan is reported infeasible here yet still operable.)
    One shared usage pass feeds both the violation and slack views.
    """
    usage = _constraint_usage(inst, sol)
    viol = feasibility(inst, sol, enforce_zeta=True, usage=usage)
    return PlanResult(
        solver=solver, solution=sol, objective=objective(inst, sol),
        cost_breakdown=cost_terms(inst, sol),
        slack=slack_report(inst, sol, usage=usage), violations=viol,
        feasible=all(v <= 1e-4 for v in viol.values()),
        wall_s=wall_s, cpu_s=cpu_s, diagnostics=diagnostics,
        options=options.to_dict())
