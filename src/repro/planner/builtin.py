"""Builtin solver adapters: the paper's algorithms behind the registry.

Each adapter maps the typed `PlanOptions` onto the underlying entry
point's native signature and returns `(Solution, diagnostics)`.  The
underlying functions are called UNCHANGED — the facade is a wrapper, so
facade solutions are bitwise-identical to direct calls (pinned by
tests/test_planner_api.py on the equivalence suite).
"""
from __future__ import annotations

import numpy as np

from repro.core.agh import agh
from repro.core.baselines import dvr, hf, lpr
from repro.core.gh import gh
from repro.core.milp import solve_milp

from .registry import SolverSpec, register_solver


def _solve_gh(inst, options, warm_start):
    order = (np.asarray(options.order)
             if options.order is not None else None)
    sol = gh(inst, order=order, run_phase1=options.run_phase1,
             ablation=options.ablation)
    return sol, {"active_pairs": int(np.sum(sol.q > 0.5))}


def _solve_agh(inst, options, warm_start):
    stats: dict = {}
    # For AGH, `options.order` is a PRIORITY ordering: evaluated before the
    # standard multi-start list (PlanSession passes the ordering that
    # produced the incumbent).  GH instead treats it as THE ordering.
    priority = ([np.asarray(options.order)]
                if options.order is not None else None)
    engine = getattr(options, "engine", "numpy") or "numpy"
    extra = {}
    if engine == "xla":
        # Lazy tier load: jax is only imported when the xla engine is
        # actually requested (`from repro import plan` stays jax-free;
        # a missing jax surfaces as EngineUnavailableError, not a deep
        # ModuleNotFoundError).
        from repro.core.xla import load_engine
        solver = load_engine().agh_xla
        extra["batch_width"] = options.batch_width
    elif engine == "numpy":
        solver = agh
    else:
        raise ValueError(f"unknown engine {engine!r}: "
                         "expected 'numpy' or 'xla'")
    sol = solver(inst, R=options.restarts, L=options.passes, **extra,
                 seed=options.seed, patience=options.patience,
                 validate=options.validate,
                 local_search=options.local_search,
                 workers=options.workers, warm_start=warm_start,
                 priority_orders=priority, stats=stats)
    stats["active_pairs"] = int(np.sum(sol.q > 0.5))
    return sol, stats


def _solve_milp(inst, options, warm_start):
    # time_limit=None defers to the backend's own default (600 s) so the
    # facade matches a bare solve_milp(inst) call exactly.
    sol = solve_milp(inst,
                     time_limit=(600.0 if options.time_limit is None
                                 else options.time_limit),
                     mip_rel_gap=options.mip_rel_gap, relax=options.relax)
    return sol, {"status": sol.method,
                 "timed_out": sol.method.endswith("(timeout)")}


def _solve_lpr(inst, options, warm_start):
    # lpr's own default is 120 s — distinct from milp's 600 s.
    return lpr(inst, time_limit=(120.0 if options.time_limit is None
                                 else options.time_limit)), {}


def _solve_dvr(inst, options, warm_start):
    return dvr(inst), {}


def _solve_hf(inst, options, warm_start):
    return hf(inst), {}


for _spec in (
    SolverSpec("gh", _solve_gh,
               "Greedy Heuristic (paper Alg. 1), vectorized single pass"),
    SolverSpec("agh", _solve_agh,
               "Adaptive GH (paper Alg. 2): multi-start + incremental "
               "local search; warm-startable from an incumbent",
               supports_warm_start=True),
    SolverSpec("milp", _solve_milp,
               "Exact P_DM MILP via scipy/HiGHS (anytime under time_limit)",
               aliases=("dm",)),
    SolverSpec("lpr", _solve_lpr,
               "LP-relaxation rounding baseline (+ Stage-2 re-routing)"),
    SolverSpec("dvr", _solve_dvr,
               "Decoupled VM-selection-then-routing baseline"),
    SolverSpec("hf", _solve_hf,
               "Homogeneous-fleet provisioning baseline"),
):
    register_solver(_spec)
