# The unified planner API (ISSUE 5): one facade over every allocation
# solver, declarative scenario specs, and warm-started replanning
# sessions.  `plan()`/`PlanRequest`/`PlanResult` are the primary surface;
# the legacy per-solver entry points in `repro.core` remain as thin,
# bit-identical shims.
from repro.core.xla import EngineUnavailableError  # jax-free module

from .api import PlanOptions, PlanRequest, PlanResult, plan
from .registry import (SolverSpec, UnknownSolverError, get_solver,
                       register_solver, solver_names, unregister_solver)
from .session import PlanSession
from .specs import (SCENARIOS, FleetSpec, ScenarioSpec, SLOSpec,
                    WorkloadSpec, list_scenarios, scenario)

__all__ = [
    "EngineUnavailableError",
    "PlanOptions", "PlanRequest", "PlanResult", "plan",
    "SolverSpec", "UnknownSolverError", "get_solver", "register_solver",
    "solver_names", "unregister_solver",
    "PlanSession",
    "SCENARIOS", "FleetSpec", "ScenarioSpec", "SLOSpec", "WorkloadSpec",
    "list_scenarios", "scenario",
]
