"""Solver registry — the pluggable backend table of the planner facade.

Every allocation algorithm (the paper's GH/AGH/DM and the external
baselines, plus any user-defined solver) is described by a `SolverSpec`
and looked up by name at `plan()` time.  Registering a solver is the ONLY
step needed to make it reachable from the facade, the benchmarks
(registry-keyed JSON rows), and `PlanSession` replanning — no caller
enumerates algorithms by hand anymore.

A spec's `solve` callable receives ``(instance, options, warm_start)`` and
returns ``(Solution, diagnostics_dict)``.  `warm_start` is an incumbent
`Solution` (or None); solvers that cannot use one (declared via
``supports_warm_start=False``) simply receive None from the facade.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.instance import Instance
from repro.core.solution import Solution


class UnknownSolverError(KeyError):
    """Raised when a `plan()` request names a solver nobody registered."""


@dataclasses.dataclass(frozen=True)
class SolverSpec:
    """One registered planning backend.

    ``solve(inst, options, warm_start) -> (Solution, diagnostics)`` must be
    deterministic for fixed inputs (the CI regression gate pins objectives
    exactly); `diagnostics` is a JSON-safe dict of solver-specific counters
    (orderings evaluated, moves applied, rescans, MILP status, ...).
    """
    name: str
    solve: Callable[[Instance, object, Solution | None],
                    tuple[Solution, dict]]
    description: str = ""
    supports_warm_start: bool = False
    aliases: tuple[str, ...] = ()


_REGISTRY: dict[str, SolverSpec] = {}
_ALIASES: dict[str, str] = {}


def register_solver(spec: SolverSpec, overwrite: bool = False) -> SolverSpec:
    """Add `spec` (and its aliases) to the registry and return it.

    Re-registering an existing name requires ``overwrite=True`` so plugins
    cannot silently shadow the paper's solvers.  Builtins are loaded
    first, so a plugin colliding with a builtin name fails loudly HERE —
    not later, inside the builtin module's own deferred import.
    """
    # Load the builtin table before checking collisions (guarded against
    # recursion: during the builtin module's own import this re-entry
    # finds it already in sys.modules and is a no-op).
    _ensure_builtins()
    names = (spec.name, *spec.aliases)
    for name in names:
        taken = _ALIASES.get(name, name) in _REGISTRY
        if taken and not overwrite and _ALIASES.get(name, name) != spec.name:
            raise ValueError(f"solver name {name!r} is already registered "
                             f"(pass overwrite=True to replace it)")
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(f"solver {spec.name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    # Replacing a spec (or promoting a name that was previously an alias,
    # e.g. overwriting "dm") must drop every stale alias mapping — lookups
    # resolve aliases first, so a leftover entry would silently shadow
    # the new registration.
    replaced = _REGISTRY.get(spec.name)
    if replaced is not None:
        for alias in replaced.aliases:
            _ALIASES.pop(alias, None)
    _ALIASES.pop(spec.name, None)
    _REGISTRY[spec.name] = spec
    for alias in spec.aliases:
        _ALIASES[alias] = spec.name
    return spec


def unregister_solver(name: str) -> None:
    """Remove a solver by name OR alias (tests / plugin teardown) —
    lookups resolve aliases, so removal does too."""
    spec = _REGISTRY.pop(_ALIASES.get(name, name), None)
    if spec is not None:
        for alias in spec.aliases:
            _ALIASES.pop(alias, None)


def solver_names() -> tuple[str, ...]:
    """Canonical registered names, sorted (aliases excluded)."""
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def get_solver(name: str) -> SolverSpec:
    """Look up a solver by name or alias.

    Unknown names raise `UnknownSolverError` whose message lists every
    registered name — a typo'd solver fails loudly and helpfully.
    """
    _ensure_builtins()
    canonical = _ALIASES.get(name, name)
    spec = _REGISTRY.get(canonical)
    if spec is None:
        raise UnknownSolverError(
            f"unknown solver {name!r}; registered solvers: "
            f"{', '.join(solver_names())}")
    return spec


def _ensure_builtins() -> None:
    """Idempotently import the builtin adapter module, which registers the
    paper's solvers on first import (lazy so `repro.planner.registry` can
    be imported without pulling scipy in)."""
    from . import builtin  # noqa: F401  (import-for-side-effect)
