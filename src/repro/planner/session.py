"""`PlanSession` — warm-started replanning over a drifting workload.

A session holds the latest incumbent plan.  `replan()` solves the drifted
problem by seeding AGH's multi-start from that incumbent: the incumbent's
deployment (q, cfg, y) is re-routed under the new demand by one GH
Phase-2 pass, polished by the incremental local search, and installed as
the multi-start's starting best — so the early-stop patience counts from
a strong bound immediately and the solve finishes after a handful of
orderings instead of a cold multi-start.  SageServe's observation
operationalized: at fleet scale, forecast-aware *replanning* beats cold
re-solves because consecutive windows share most of their structure.

The replan protocol trades the cold run's ordering coverage for wall
clock (patience drops from 5 to `replan_patience`, random restarts are
skipped); on drifted workloads the warm seed's head start more than
covers the difference — `benchmarks/allocator_scaling.py` demonstrates
objective <= cold AGH at measurably lower wall time on the (100,80,40)
fleet, and tests/test_perf_smoke.py guards it.

`core.rolling.rolling()` accepts a session wherever it took a bare
planner callable, which turns every rolling-horizon window after the
first into a warm-started solve.

`repair()` is the supply-fault counterpart of `replan()`: same warm
incumbent, but the drift is on the SUPPLY side (tier outages, spot
revocations, capacity shocks from `core.faults`) — assignments on lost
capacity are evicted and the displaced load re-routed by
`core.agh.agh_repair`, with a graceful-degradation ladder (unmet-cap →
delay-relax → budget-overdraft) instead of a bare infeasibility error.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.agh import agh_repair
from repro.core.faults import FaultSchedule, apply_faults
from repro.core.forecast import relative_drift
from repro.core.instance import Instance
from repro.core.solution import Solution

from .api import PlanOptions, PlanRequest, PlanResult, build_result, plan
from .registry import get_solver
from .specs import ScenarioSpec


def _unmet_excess(inst: Instance, sol: Solution) -> float:
    """Arrival-weighted unmet demand beyond the per-type zeta caps — the
    quantity the degradation ladder is minimizing when strict repair is
    out of reach (queries/hour left unserved past the SLO contract)."""
    return float(np.sum(np.maximum(sol.u - inst.zeta, 0.0) * inst.lam))


def _ladder_score(inst: Instance, res: PlanResult) -> tuple[float, float]:
    """Lexicographic degradation score: excess unmet first, then total
    constraint-violation mass.  Ladder retries are adopted only on a
    strict improvement, so a relaxed re-solve can never make the
    operated plan worse than what the strict rung already produced."""
    return (_unmet_excess(inst, res.solution),
            float(sum(res.violations.values())))


@dataclasses.dataclass
class PlanSession:
    """Stateful planning handle: cold-solve once, warm-replan thereafter.

    ``replan_patience`` / ``replan_restarts`` shape the warm protocol
    (early-stop patience and random-restart budget of replans); the cold
    first solve always uses the full `options` as given.  Solvers that
    cannot warm-start (everything but AGH today) fall back to cold solves
    on every call — the session is still useful as a uniform driver.

    ``engine=`` is shorthand for setting ``options.engine``:
    ``PlanSession(engine="xla")`` runs both the cold solve and every
    warm replan on the jitted XLA tier (the replan option override goes
    through `dataclasses.replace`, so the engine choice survives it).
    """
    solver: str = "agh"
    options: PlanOptions = dataclasses.field(default_factory=PlanOptions)
    engine: str | None = None
    replan_patience: int = 2
    replan_restarts: int = 0
    repair_delay_relax: float = 1.5
    repair_budget_overdraft: float = 1.5
    incumbent: Solution | None = None
    last_result: PlanResult | None = None
    last_instance: Instance | None = None
    winning_order: tuple[int, ...] | None = None
    plans: int = 0
    warm_replans: int = 0
    repairs: int = 0
    # Controller hooks (repro.serving.driver): every solve appends one
    # JSON-safe row {kind, cause, wall_s, objective, warm} here, so the
    # closed-loop replan log and the session's own accounting can never
    # disagree.  `cause=` on replan()/repair() is recorded verbatim.
    replan_log: list[dict] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        if self.engine is not None:
            self.options = dataclasses.replace(self.options,
                                               engine=self.engine)

    def plan(self, instance: Instance | None = None,
             scenario: ScenarioSpec | str | None = None) -> PlanResult:
        """Cold solve; installs the result as the session incumbent."""
        inst = self._resolve(instance, scenario)
        res = plan(PlanRequest(solver=self.solver, instance=inst,
                               options=self.options))
        self._install(inst, res, kind="plan")
        return res

    def replan(self, instance: Instance | None = None,
               scenario: ScenarioSpec | str | None = None,
               lam: np.ndarray | None = None,
               cause: str | None = None) -> PlanResult:
        """Warm-started solve for a drifted problem.

        ``lam=`` is shorthand for "same instance, new demand vector"; it
        requires a prior solve (the session remembers the instance).
        Without an incumbent this degrades to a cold `plan()`.
        ``cause=`` tags the `replan_log` row (the serving controller
        passes its trigger cause — "drift"/"slo"/"scheduled").
        """
        if lam is not None:
            if instance is not None or scenario is not None:
                raise ValueError("pass lam= alone, or instance=/scenario=")
            if self.last_instance is None:
                raise ValueError("lam= replan needs a prior plan()/replan() "
                                 "on a full instance")
            instance = self.last_instance.with_lam(np.asarray(lam, float))
        inst = self._resolve(instance, scenario)
        if (self.incumbent is None
                or self.incumbent.x.shape != (inst.I, inst.J, inst.K)):
            # No incumbent, or one from a differently-shaped problem
            # (population changed): nothing to warm-start from.
            return self.plan(instance=inst)
        warm = get_solver(self.solver).supports_warm_start
        opts = self.options
        if warm:
            # Fast-replan protocol: tighter patience, no random restarts,
            # and the incumbent's winning ordering replayed first (the
            # multi-start winner is empirically stable under drift — see
            # core/agh.py `priority_orders`).  The sequential driver is
            # pinned unless the caller set workers explicitly: AGH's
            # auto fan-out evaluates EVERY ordering with no early stop,
            # which would silently discard the patience the warm seed
            # buys — exactly at the fleet scales where auto engages.
            opts = dataclasses.replace(
                opts, patience=self.replan_patience,
                restarts=self.replan_restarts, order=self.winning_order,
                workers=0 if opts.workers is None else opts.workers)
        res = plan(PlanRequest(solver=self.solver, instance=inst,
                               options=opts, warm_start=self.incumbent))
        self._install(inst, res, warm=warm, kind="replan", cause=cause)
        return res

    def drift(self, lam: np.ndarray) -> float:
        """Demand-weighted relative L1 drift of `lam` against the rates
        the incumbent plan was built for (`core.forecast.relative_drift`)
        — the controller's trigger statistic, exposed for inspection."""
        if self.last_instance is None:
            raise ValueError("drift() needs a prior plan()/replan()")
        return relative_drift(np.asarray(lam, float),
                              self.last_instance.lam)

    def repair(self, instance: Instance | None = None,
               scenario: ScenarioSpec | str | None = None,
               schedule: FaultSchedule | None = None, t: int = 0,
               passes: int = 1, cause: str | None = None) -> PlanResult:
        """Repair the incumbent after a supply-side fault, degrading
        gracefully instead of erroring when strict repair is infeasible.

        The instance is the *faulted* supply view — either passed
        directly (e.g. from `core.rolling`'s fault replay), or derived
        here via ``schedule=``/``t=`` (`core.faults.apply_faults` on the
        remembered or given instance).  With a shape-compatible AGH
        incumbent the solve is `core.agh.agh_repair`: surviving
        assignments pinned, pairs on lost capacity evicted through the
        drain machinery, displaced load re-routed by one Phase-2 pass and
        `passes` incremental local-search passes.  Otherwise (no
        incumbent, population changed, non-warm-startable solver) it
        falls back to a cold registry solve on the faulted instance.

        When the strict solve is infeasible, a graceful-degradation
        ladder runs — each rung adopted only if it strictly improves
        `_ladder_score` against the REAL faulted instance:

        1. **unmet-cap** — hard constraints hold; only the zeta unmet cap
           overshoots.  No re-solve: the overshoot is reported.
        2. **delay-relax** — re-solve with the delay SLOs stretched by
           ``repair_delay_relax`` (coverage bought with latency).
        3. **budget-overdraft** — re-solve with the budget stretched by
           ``repair_budget_overdraft`` on top; the overdraft is flagged.

        The result always carries ``diagnostics["repair"]`` with the
        evicted pairs, warm/cold provenance, and a ``degradation`` report
        (``level`` 0–3, rung ``name``, the ``ladder`` rungs attempted,
        and the residual violation families) — an infeasible repair is
        never silent: its level is >= 1 with a non-empty report."""
        if instance is None and scenario is None:
            if self.last_instance is None:
                raise ValueError("repair() without instance=/scenario= "
                                 "needs a prior plan()/replan()")
            inst = self.last_instance
        else:
            inst = self._resolve(instance, scenario)
        if schedule is not None:
            inst = apply_faults(inst, schedule, t)
        t0, c0 = time.perf_counter(), time.process_time()
        sol, diag, warm = self._repair_solve(inst, passes)
        evicted = [list(map(int, jk)) for jk in diag.get("evicted", [])]
        res = build_result(self.solver, inst, sol, 0.0, 0.0, dict(diag),
                           self.options)
        level, name = 0, "strict"
        tried = ["strict"]
        if not res.feasible:
            level, name = 1, "unmet-cap"
            tried.append("delay-relax")
            relaxed = dataclasses.replace(
                inst, Delta=inst.Delta * self.repair_delay_relax)
            cand = self._ladder_retry(inst, relaxed, res, passes)
            base = inst
            if cand is not None:
                res, base = cand, relaxed
                level, name = 2, "delay-relax"
            if not res.feasible:
                tried.append("budget-overdraft")
                overdrawn = dataclasses.replace(
                    base, delta=inst.delta * self.repair_budget_overdraft)
                cand = self._ladder_retry(inst, overdrawn, res, passes)
                if cand is not None:
                    res = cand
                    level, name = 3, "budget-overdraft"
        if res.feasible:
            # A ladder retry may land a plan that satisfies the REAL
            # constraint system outright — then nothing was degraded.
            level, name = 0, "strict"
        res.wall_s = time.perf_counter() - t0
        res.cpu_s = time.process_time() - c0
        res.diagnostics["repair"] = {
            "evicted": evicted, "warm": warm, "wall_s": res.wall_s,
            "degradation": {
                "level": level, "name": name, "ladder": tried,
                "violations": {k: float(v)
                               for k, v in res.violations.items()
                               if v > 1e-4},
                "unmet_excess": _unmet_excess(inst, res.solution),
                "zeta_overshoot": float(
                    res.violations.get("unmet_cap", 0.0)),
                "budget_overdraft": float(
                    res.violations.get("budget", 0.0)),
            }}
        self._install(inst, res, warm=warm, kind="repair", cause=cause)
        self.repairs += 1
        return res

    def _repair_solve(self, inst: Instance,
                      passes: int) -> tuple[Solution, dict, bool]:
        """One repair solve: warm `agh_repair` when the incumbent can seed
        it, else a cold registry solve.  Returns (solution, diagnostics,
        warm?)."""
        spec = get_solver(self.solver)
        if (self.incumbent is not None and spec.supports_warm_start
                and self.incumbent.x.shape == (inst.I, inst.J, inst.K)):
            stats: dict = {}
            sol = agh_repair(inst, self.incumbent, L=max(1, passes),
                             local_search=self.options.local_search,
                             validate=self.options.validate, stats=stats)
            return sol, stats, True
        sol, diag = spec.solve(inst, self.options, None)
        return sol, dict(diag), False

    def _ladder_retry(self, real: Instance, relaxed: Instance,
                      cur: PlanResult, passes: int) -> PlanResult | None:
        """Solve one ladder rung on the `relaxed` instance, score it
        against the REAL faulted instance, and return it only on a strict
        `_ladder_score` improvement over the current best."""
        sol, diag, _ = self._repair_solve(relaxed, passes)
        cand = build_result(self.solver, real, sol, 0.0, 0.0, dict(diag),
                            self.options)
        if _ladder_score(real, cand) < _ladder_score(real, cur):
            return cand
        return None

    def seed(self, instance: Instance, result: PlanResult) -> None:
        """Install an externally computed `PlanResult` as the incumbent
        (e.g. one loaded from a JSON dump, or a solve a benchmark already
        paid for) without re-solving."""
        self._install(instance, result)

    # Back-compat with the bare-callable planner protocol: a session IS a
    # planner (rolling() and the benchmarks accept either the same way).
    def __call__(self, inst: Instance) -> Solution:
        return self.replan(instance=inst).solution

    @staticmethod
    def _resolve(instance: Instance | None,
                 scenario: ScenarioSpec | str | None) -> Instance:
        return PlanRequest(instance=instance,
                           scenario=scenario).resolve_instance()

    def _install(self, inst: Instance, res: PlanResult,
                 warm: bool = False, kind: str = "plan",
                 cause: str | None = None) -> None:
        self.incumbent = res.solution
        self.last_result = res
        self.last_instance = inst
        self.plans += 1
        self.warm_replans += int(warm)
        self.replan_log.append({
            "kind": kind, "cause": cause, "warm": warm,
            "wall_s": float(res.wall_s),
            "objective": float(res.objective)})
        win = res.diagnostics.get("winning_order")
        if win is not None:
            # Keep the previous remembered ordering when the warm seed
            # itself won — it is still the last known-good construction.
            self.winning_order = tuple(int(i) for i in win)
