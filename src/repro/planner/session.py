"""`PlanSession` — warm-started replanning over a drifting workload.

A session holds the latest incumbent plan.  `replan()` solves the drifted
problem by seeding AGH's multi-start from that incumbent: the incumbent's
deployment (q, cfg, y) is re-routed under the new demand by one GH
Phase-2 pass, polished by the incremental local search, and installed as
the multi-start's starting best — so the early-stop patience counts from
a strong bound immediately and the solve finishes after a handful of
orderings instead of a cold multi-start.  SageServe's observation
operationalized: at fleet scale, forecast-aware *replanning* beats cold
re-solves because consecutive windows share most of their structure.

The replan protocol trades the cold run's ordering coverage for wall
clock (patience drops from 5 to `replan_patience`, random restarts are
skipped); on drifted workloads the warm seed's head start more than
covers the difference — `benchmarks/allocator_scaling.py` demonstrates
objective <= cold AGH at measurably lower wall time on the (100,80,40)
fleet, and tests/test_perf_smoke.py guards it.

`core.rolling.rolling()` accepts a session wherever it took a bare
planner callable, which turns every rolling-horizon window after the
first into a warm-started solve.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.instance import Instance
from repro.core.solution import Solution

from .api import PlanOptions, PlanRequest, PlanResult, plan
from .registry import get_solver
from .specs import ScenarioSpec


@dataclasses.dataclass
class PlanSession:
    """Stateful planning handle: cold-solve once, warm-replan thereafter.

    ``replan_patience`` / ``replan_restarts`` shape the warm protocol
    (early-stop patience and random-restart budget of replans); the cold
    first solve always uses the full `options` as given.  Solvers that
    cannot warm-start (everything but AGH today) fall back to cold solves
    on every call — the session is still useful as a uniform driver.

    ``engine=`` is shorthand for setting ``options.engine``:
    ``PlanSession(engine="xla")`` runs both the cold solve and every
    warm replan on the jitted XLA tier (the replan option override goes
    through `dataclasses.replace`, so the engine choice survives it).
    """
    solver: str = "agh"
    options: PlanOptions = dataclasses.field(default_factory=PlanOptions)
    engine: str | None = None
    replan_patience: int = 2
    replan_restarts: int = 0
    incumbent: Solution | None = None
    last_result: PlanResult | None = None
    last_instance: Instance | None = None
    winning_order: tuple[int, ...] | None = None
    plans: int = 0
    warm_replans: int = 0

    def __post_init__(self) -> None:
        if self.engine is not None:
            self.options = dataclasses.replace(self.options,
                                               engine=self.engine)

    def plan(self, instance: Instance | None = None,
             scenario: ScenarioSpec | str | None = None) -> PlanResult:
        """Cold solve; installs the result as the session incumbent."""
        inst = self._resolve(instance, scenario)
        res = plan(PlanRequest(solver=self.solver, instance=inst,
                               options=self.options))
        self._install(inst, res)
        return res

    def replan(self, instance: Instance | None = None,
               scenario: ScenarioSpec | str | None = None,
               lam: np.ndarray | None = None) -> PlanResult:
        """Warm-started solve for a drifted problem.

        ``lam=`` is shorthand for "same instance, new demand vector"; it
        requires a prior solve (the session remembers the instance).
        Without an incumbent this degrades to a cold `plan()`.
        """
        if lam is not None:
            if instance is not None or scenario is not None:
                raise ValueError("pass lam= alone, or instance=/scenario=")
            if self.last_instance is None:
                raise ValueError("lam= replan needs a prior plan()/replan() "
                                 "on a full instance")
            instance = self.last_instance.with_lam(np.asarray(lam, float))
        inst = self._resolve(instance, scenario)
        if (self.incumbent is None
                or self.incumbent.x.shape != (inst.I, inst.J, inst.K)):
            # No incumbent, or one from a differently-shaped problem
            # (population changed): nothing to warm-start from.
            return self.plan(instance=inst)
        warm = get_solver(self.solver).supports_warm_start
        opts = self.options
        if warm:
            # Fast-replan protocol: tighter patience, no random restarts,
            # and the incumbent's winning ordering replayed first (the
            # multi-start winner is empirically stable under drift — see
            # core/agh.py `priority_orders`).  The sequential driver is
            # pinned unless the caller set workers explicitly: AGH's
            # auto fan-out evaluates EVERY ordering with no early stop,
            # which would silently discard the patience the warm seed
            # buys — exactly at the fleet scales where auto engages.
            opts = dataclasses.replace(
                opts, patience=self.replan_patience,
                restarts=self.replan_restarts, order=self.winning_order,
                workers=0 if opts.workers is None else opts.workers)
        res = plan(PlanRequest(solver=self.solver, instance=inst,
                               options=opts, warm_start=self.incumbent))
        self._install(inst, res, warm=warm)
        return res

    def seed(self, instance: Instance, result: PlanResult) -> None:
        """Install an externally computed `PlanResult` as the incumbent
        (e.g. one loaded from a JSON dump, or a solve a benchmark already
        paid for) without re-solving."""
        self._install(instance, result)

    # Back-compat with the bare-callable planner protocol: a session IS a
    # planner (rolling() and the benchmarks accept either the same way).
    def __call__(self, inst: Instance) -> Solution:
        return self.replan(instance=inst).solution

    @staticmethod
    def _resolve(instance: Instance | None,
                 scenario: ScenarioSpec | str | None) -> Instance:
        return PlanRequest(instance=instance,
                           scenario=scenario).resolve_instance()

    def _install(self, inst: Instance, res: PlanResult,
                 warm: bool = False) -> None:
        self.incumbent = res.solution
        self.last_result = res
        self.last_instance = inst
        self.plans += 1
        self.warm_replans += int(warm)
        win = res.diagnostics.get("winning_order")
        if win is not None:
            # Keep the previous remembered ordering when the warm seed
            # itself won — it is still the last known-good construction.
            self.winning_order = tuple(int(i) for i in win)
