"""Rolling-horizon adaptation study (paper §5.3).

Two complementary settings:
  * synthetic geometric-random-walk volatility (Table 4);
  * diurnal trace replay (Table 5 / Fig. 6).

Static variants solve Stage 1 once at t=0; the 5-minute variants re-optimize
the deployment each window with an EWMA demand forecast and a keep-best rule
(adopt the new plan only if it improves the forecast objective). In every
window, the current deployment is operated through the exact Stage-2 routing
LP with the strict per-type unmet cap u_i <= 0.02 (the stress protocol).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .instance import Instance
from .solution import Solution, objective, provisioning_cost
from .stage2 import stage2_cost, stage2_lp
from .trace import random_walk_lambdas

STRICT_CAP = 0.02


@dataclasses.dataclass
class RollingResult:
    method: str
    mean_window_cost: float
    total_cost: float
    violation_rate: float
    per_window_cost: np.ndarray
    replans: int = 0


def _window_cost(inst_w: Instance, deploy: Solution,
                 rental_per_window: float) -> tuple[float, int]:
    cap = np.full(inst_w.I, STRICT_CAP)
    sol, _ = stage2_lp(inst_w, deploy, u_cap=cap)
    # Stage-2 penalties accrue per window: scale horizon-priced terms down.
    op = stage2_cost(inst_w, sol) / inst_w.Delta_T * (24.0 / 288.0)
    viol = int(np.sum(sol.u > 0.01))
    return rental_per_window + op * inst_w.Delta_T, viol


def rolling(inst0: Instance, lam_path: np.ndarray,
            planner: Callable[[Instance], Solution],
            replan_every: int | None = None,
            forecast_ewma: float = 0.4,
            static_forecast: str = "first") -> RollingResult:
    """Replay `lam_path` ([T, I] arrivals). If `replan_every` is None the
    Stage-1 plan is held fixed (static); otherwise the planner re-runs
    every `replan_every` windows on an EWMA forecast with keep-best.
    static_forecast: 'first' plans on the first window's demand (synthetic
    GRW study — the walk starts at the forecast); 'mean' plans on the
    day-average (the paper's protocol for the diurnal trace replay).
    """
    T = lam_path.shape[0]
    window_h = 24.0 / T
    lam_fc = (lam_path.mean(axis=0) if static_forecast == "mean"
              else lam_path[0])
    deploy = planner(inst0.with_lam(lam_fc))
    best_forecast_obj = objective(inst0.with_lam(lam_fc), deploy)
    rental_w = provisioning_cost(inst0, deploy) / inst0.Delta_T * window_h

    costs = np.zeros(T)
    viols = 0
    replans = 0
    forecast = lam_path[0].copy()
    for t in range(T):
        lam_t = lam_path[t]
        forecast = forecast_ewma * lam_t + (1 - forecast_ewma) * forecast
        if replan_every is not None and t > 0 and t % replan_every == 0:
            cand = planner(inst0.with_lam(forecast))
            cand_obj = objective(inst0.with_lam(forecast), cand)
            incumbent_obj = objective(inst0.with_lam(forecast), deploy)
            if cand_obj < incumbent_obj - 1e-6:     # keep-best rule
                deploy = cand
                rental_w = provisioning_cost(inst0, deploy) / inst0.Delta_T * window_h
                best_forecast_obj = cand_obj
                replans += 1
        inst_w = inst0.with_lam(lam_t)
        costs[t], v = _window_cost(inst_w, deploy, rental_w)
        viols += v
    del best_forecast_obj
    return RollingResult(method="", mean_window_cost=float(costs.mean()),
                         total_cost=float(costs.sum()),
                         violation_rate=viols / (T * inst0.I),
                         per_window_cost=costs, replans=replans)


def volatility_study(inst0: Instance, sigma: float, trials: int,
                     planner: Callable[[Instance], Solution],
                     replan_every: int | None, seed: int = 0,
                     n_windows: int = 288) -> float:
    """Mean 24 h cost over `trials` random-walk demand paths (Table 4)."""
    totals = []
    for trial in range(trials):
        rng = np.random.default_rng(seed + 1000 * trial)
        path = random_walk_lambdas(inst0.lam, sigma, n_windows, rng)
        res = rolling(inst0, path, planner, replan_every=replan_every)
        totals.append(res.total_cost)
    return float(np.mean(totals))
