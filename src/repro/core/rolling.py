"""Rolling-horizon adaptation study (paper §5.3).

Two complementary settings:
  * synthetic geometric-random-walk volatility (Table 4);
  * diurnal trace replay (Table 5 / Fig. 6), extended to multi-day,
    volatile-day, non-288-window, and inflation-stress replays.

Static variants solve Stage 1 once at t=0; the 5-minute variants re-optimize
the deployment each window with an EWMA demand forecast and a keep-best rule
(adopt the new plan only if it beats the incumbent's objective on the SAME
current forecast).  In every window, the current deployment is operated
through the exact Stage-2 routing LP with the strict per-type unmet cap
u_i <= 0.02 (the stress protocol).

Fast path: the EWMA forecasts are precomputed for the whole path, the
replan schedule is resolved first (it depends only on forecasts and planner
outputs, never on window costs), and each constant-deployment segment is
then solved as one stacked `ScenarioBatch` through a single `Stage2System`
— the LP pattern is rebuilt only when a replan is adopted.  `batched=False`
keeps the per-window `stage2_lp` loop for agreement tests and the
before/after benchmark.

Window pricing (PR-2 bugfix): Stage-2 penalties are horizon-priced ($ over
Delta_T as if the window's demand persisted all day); one window accrues
the `window_h`-hour share.  The seed hardcoded the T=288 fraction
(24.0/288.0), mispricing every replay with n_windows != 288 — `window_h`
is now threaded through, so total replay cost is invariant to the window
count for the same demand profile (pinned by tests/test_rolling.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np
from scipy.optimize import linprog

from .faults import FaultSchedule, apply_faults, evict_unavailable
from .forecast import ewma_forecasts, relative_drift
from .instance import Instance, ScenarioBatch
from .solution import Solution, objective, provisioning_cost
from .stage2 import Stage2System, stage2_cost, stage2_lp
from .trace import multi_day_multipliers, random_walk_lambdas

STRICT_CAP = 0.02

# The EWMA recursion moved to `core.forecast` (shared with the serving
# controller); the old private name stays importable for callers/tests.
_ewma_forecasts = ewma_forecasts


@dataclasses.dataclass
class RollingResult:
    method: str
    mean_window_cost: float
    total_cost: float
    violation_rate: float
    per_window_cost: np.ndarray
    replans: int = 0
    # Supply-fault replay extensions (populated only when a FaultSchedule
    # is passed to `rolling`; defaults keep the base path's result shape).
    fault_replans: int = 0                   # event-driven re-solves
    evictions: int = 0                       # pairs lost to capacity
    repair_wall_s: tuple = ()                # per-event re-solve wall (s)
    degradation_levels: tuple = ()           # repair ladder level per event


def _as_planner(planner) -> Callable[[Instance], Solution]:
    """Normalize the planner argument: a bare ``Instance -> Solution``
    callable passes through; a `repro.planner.PlanSession`-like object
    (anything with a ``replan`` method returning a result with a
    ``.solution``) is adapted so every window after the first becomes a
    warm-started replan seeded from the session's incumbent."""
    if hasattr(planner, "replan"):
        return lambda inst: planner.replan(instance=inst).solution
    return planner


def rolling(inst0: Instance, lam_path: np.ndarray,
            planner: Callable[[Instance], Solution],
            replan_every: int | None = None,
            forecast_ewma: float = 0.4,
            static_forecast: str = "first",
            window_h: float | None = None,
            batched: bool = True,
            lp_reuse: bool = True,
            faults: FaultSchedule | None = None,
            fault_response: str = "repair",
            replan_drift: float | None = None) -> RollingResult:
    """Replay `lam_path` ([T, I] arrivals).  If `replan_every` is None the
    Stage-1 plan is held fixed (static); otherwise the planner re-runs
    every `replan_every` windows on an EWMA forecast with keep-best.
    `planner` is either a bare ``Instance -> Solution`` callable or a
    `PlanSession` (see `_as_planner`) — with a session, every re-solve
    warm-starts from the session incumbent instead of running cold.
    static_forecast: 'first' plans on the first window's demand (synthetic
    GRW study — the walk starts at the forecast); 'mean' plans on the
    day-average (the paper's protocol for the diurnal trace replay).
    window_h: hours per window; defaults to 24/T (a one-day path).  Pass it
    explicitly for multi-day replays, where T spans more than 24 h.

    `faults` injects a supply-side `FaultSchedule` (core/faults.py): every
    supply change point triggers an EVENT-DRIVEN re-solve in addition to
    the `replan_every` schedule, and each window is operated on the
    faulted effective instance (pairs on lost capacity are evicted from
    the operated deployment).  `fault_response` picks the reaction:
    ``"repair"`` (warm `PlanSession.repair` when `planner` is a session,
    else a planner re-solve), ``"cold"`` (full planner re-solve), or
    ``"static"`` (no reaction — the frozen placement rides through the
    fault, the degradation baseline).  With ``faults=None`` this function
    is byte-identical to the pre-fault fast path.

    `lp_reuse` enables the affine-in-lambda re-solve skip on the batched
    fault-free path: within a constant-deployment segment only `lam`
    varies, so when one window's optimal basis touches no lam-scaled
    constraint row (kv/compute/storage all slack — the segment is
    *unsaturated*), the routing (x, u) is provably constant across the
    segment and only the objective moves.  `_affine_segment` certifies
    this from one exact solve + its duals and prices the remaining
    windows by dot products; any failed certificate falls back to the
    always-solve batch.  Pinned bit-identical to `lp_reuse=False` on the
    replay suite (tests/test_rolling.py).

    `replan_drift` makes the `replan_every` cadence forecast-aware (the
    same `core.forecast.relative_drift` trigger the closed-loop serving
    controller uses): a scheduled replan point actually re-solves only
    when the EWMA forecast has drifted more than `replan_drift`
    (relative L1) from the rates the incumbent plan was built for.
    ``None`` (the default) keeps the blind cadence, bit-identical to the
    pre-drift behavior.
    """
    if faults is not None and not faults.is_empty:
        return _rolling_faulted(inst0, lam_path, planner, replan_every,
                                forecast_ewma, static_forecast, window_h,
                                faults, fault_response)
    session = planner if hasattr(planner, "replan") else None
    planner = _as_planner(planner)
    lam_path = np.asarray(lam_path, float)
    T = lam_path.shape[0]
    if window_h is None:
        window_h = 24.0 / T
    lam_fc = (lam_path.mean(axis=0) if static_forecast == "mean"
              else lam_path[0])
    deploy = planner(inst0.with_lam(lam_fc))

    # Resolve the replan schedule first: adoption depends only on forecasts
    # and the keep-best comparison, never on window costs, so the path
    # splits into constant-deployment segments [t0, t1) solvable in batch.
    replans = 0
    segments: list[tuple[int, int, Solution]] = []
    if replan_every is not None:
        fc = ewma_forecasts(lam_path, forecast_ewma)
        lam_basis = lam_fc          # rates the deployed plan was built for
        t0 = 0
        for t in range(T):
            if t > 0 and t % replan_every == 0:
                if (replan_drift is not None
                        and relative_drift(fc[t], lam_basis) <= replan_drift):
                    continue        # forecast hasn't moved: keep the plan
                inst_fc = inst0.with_lam(fc[t])
                cand = planner(inst_fc)
                # Keep-best: both plans scored on the SAME current forecast
                # (the incumbent's score moves with the forecast, so it is
                # re-evaluated here rather than carried over).
                if objective(inst_fc, cand) < objective(inst_fc, deploy) - 1e-6:
                    segments.append((t0, t, deploy))
                    deploy, t0 = cand, t
                    lam_basis = fc[t]
                    replans += 1
                elif session is not None:
                    # Keep-best rejected the candidate: re-anchor the
                    # session's incumbent to the plan actually deployed,
                    # so later warm replans seed from the best-known plan
                    # rather than from the rejected candidate.
                    session.incumbent = deploy
        segments.append((t0, T, deploy))
    else:
        segments = [(0, T, deploy)]

    costs = np.zeros(T)
    viols = 0
    cap = np.full(inst0.I, STRICT_CAP)
    for (t0, t1, dep) in segments:
        if t1 <= t0:
            continue
        rental_w = provisioning_cost(inst0, dep) / inst0.Delta_T * window_h
        if batched:
            system = Stage2System(inst0, dep)
            reused = (_affine_segment(system, lam_path[t0:t1], cap)
                      if lp_reuse else None)
            if reused is not None:
                op, seg_viols = reused
                viols += seg_viols
            else:
                batch = ScenarioBatch.from_lam_path(lam_path[t0:t1])
                op, v, _ = system.solve_batch(batch, u_cap=cap)
                viols += int(v.sum())
        else:
            op = np.zeros(t1 - t0)
            for t in range(t0, t1):
                inst_w = inst0.with_lam(lam_path[t])
                sol, _ = stage2_lp(inst_w, dep, u_cap=cap)
                op[t - t0] = stage2_cost(inst_w, sol)
                viols += int(np.sum(sol.u > 0.01))
        # Horizon-priced penalties accrue the window_h-hour share per
        # window (the seed hardcoded 24/288 here — the headline bugfix).
        costs[t0:t1] = rental_w + op * window_h
    return RollingResult(method="", mean_window_cost=float(costs.mean()),
                         total_cost=float(costs.sum()),
                         violation_rate=viols / (T * inst0.I),
                         per_window_cost=costs, replans=replans)


def _affine_segment(system: Stage2System, lam_seg: np.ndarray,
                    cap: np.ndarray) -> tuple[np.ndarray, int] | None:
    """Certificate-gated LP re-solve skip for one rolling segment.

    Within a constant-deployment segment only `lam` varies window to
    window; tau/e_base stay nominal.  Of the inequality families, kv,
    compute and storage coefficients scale with lam while delay and
    error rows (and the equality block, rhs, bounds) are lam-free.  If
    one window's optimal basis touches NO lam-scaled row, the optimal
    (x, u) is the same vertex for every window — only the objective
    (affine in lam) moves — provided the certificate holds over the
    segment's lam range:

      * active inequality rows and nonzero inequality duals confined to
        the lam-free families (delay, error) — those rows' lhs is
        constant in lam, so they stay exactly tight at every window;
      * per window t, reduced costs rc(lam_t) = c(lam_t) + A^T y keep
        the basis-optimal sign pattern (A^T y is segment-constant since
        y lives on lam-free rows), and the slack lam-scaled rows stay
        strictly slack under lam_t (primal feasibility of the fixed x).

    Certification is per window: a diurnal segment is typically
    unsaturated off-peak and saturated at the peak, so the windows the
    certificate covers are priced by dot products while the rest go
    through the exact per-window solve — identical to what the
    always-solve batch would do for them.

    Returns (per-window operation costs, total violations) with the one
    exact solve's (x, u) reused verbatim and certified windows priced
    through `_coefficients` + the identical cost dot expression — or
    None when the representative solve yields no usable certificate
    (caller falls back to the always-solve batch).
    """
    T = lam_seg.shape[0]
    nx, m_ub = system.nx, system.m_ub
    if T < 2 or nx == 0:
        return None
    inst = system.inst
    # Representative window through the SAME milp path the always-solve
    # batch uses, so window 0's cost is reproduced bit-for-bit.
    r = system.solve(lam=lam_seg[0], u_cap=cap)
    if not r.capped_ok or r.x is None:
        return None
    # Duals come from linprog (milp exposes none); system.A still holds
    # window 0's coefficients after `solve`.
    _, c0 = system._coefficients(inst.tau, inst.e_base, lam_seg[0])
    K = system.A.tocsr()
    bounds = np.stack([system._lb,
                       np.concatenate([np.ones(nx), cap])], axis=1)
    res = linprog(c0, A_ub=K[:m_ub], b_ub=system.row_ub[:m_ub],
                  A_eq=K[m_ub:], b_eq=np.ones(system.I),
                  bounds=bounds, method="highs")
    if not res.success:
        return None
    zfull = np.concatenate([r.x, r.u])
    # The two HiGHS entry points must agree on the vertex — alternate
    # optima would make the reused (x, u) ambiguous.
    if not np.allclose(res.x, zfull, atol=1e-7):
        return None

    fam = system.row_family
    lam_free = fam >= 3                       # delay, error
    y_ub = -res.ineqlin.marginals             # >= 0 for A_ub x <= b_ub
    y_eq = -res.eqlin.marginals
    resid = res.ineqlin.residual
    active = (np.abs(resid) < 1e-9) | (np.abs(y_ub) > 1e-9)
    if np.any(active & ~lam_free):
        return None

    at_y = K[:m_ub].T @ y_ub + K[m_ub:].T @ y_eq
    ub_vec = bounds[:, 1]
    at_lb = zfull <= 1e-9
    at_ub = zfull >= ub_vec - 1e-9
    interior = ~(at_lb | at_ub)

    # Vectorized per-window certificate over the whole segment.
    batch = ScenarioBatch.from_lam_path(lam_seg)
    vals_all, c_all = system.coefficient_batch(batch)
    rc = c_all + at_y[None, :]
    dual_ok = (np.all(rc[:, at_lb] >= -1e-9, axis=1)
               & np.all(rc[:, at_ub] <= 1e-9, axis=1)
               & np.all(np.abs(rc[:, interior]) <= 1e-7, axis=1))
    rows_i = system.rows_all[:system.nnz]
    cols_i = system.cols_all[:system.nnz]
    lhs = np.zeros((T, m_ub))
    np.add.at(lhs, (np.arange(T)[:, None], rows_i[None, :]),
              vals_all[:, :system.nnz] * zfull[cols_i][None, :])
    slack = system.row_ub[:m_ub][None, :] - lhs
    prim_ok = np.all(slack[:, ~lam_free] > 1e-9, axis=1)
    certified = dual_ok & prim_ok
    certified[0] = True          # window 0 is the exact solve itself
    if certified.sum() <= max(1, T // 4):
        return None              # too saturated to pay off: batch-solve

    op = np.empty(T)
    viols = r.viol * int(certified.sum())
    op[0] = r.cost
    for t in range(1, T):
        if certified[t]:
            _, c_t = system._coefficients(inst.tau, inst.e_base, lam_seg[t])
            op[t] = float(c_t[:nx] @ r.x + system.c_u @ r.u)
        else:
            rt = system.solve(lam=lam_seg[t], u_cap=cap)
            op[t] = rt.cost
            viols += rt.viol
    return op, viols


def _rolling_faulted(inst0: Instance, lam_path: np.ndarray, planner_obj,
                     replan_every: int | None, forecast_ewma: float,
                     static_forecast: str, window_h: float | None,
                     faults: FaultSchedule,
                     fault_response: str) -> RollingResult:
    """The supply-faulted replay: `rolling` with a `FaultSchedule`.

    Segments break at every supply change point (event-driven replans)
    AND at every adopted scheduled replan, so each segment has one
    deployment operated under one effective instance.  The operated
    deployment is always the eviction image of the planned one under the
    segment's availability caps — a frozen static placement therefore
    *loses* the traffic its revoked pairs carried, which is exactly the
    degradation the repair modes are measured against.  Event re-solves
    are adopted unconditionally (the incumbent is illegal under the new
    supply); scheduled replans keep the base path's keep-best rule,
    scored against the evicted incumbent."""
    if fault_response not in ("repair", "cold", "static"):
        raise ValueError(f"unknown fault_response {fault_response!r} "
                         f"(expected 'repair', 'cold', or 'static')")
    session = planner_obj if hasattr(planner_obj, "replan") else None
    planner = _as_planner(planner_obj)
    lam_path = np.asarray(lam_path, float)
    T = lam_path.shape[0]
    if window_h is None:
        window_h = 24.0 / T
    K = inst0.K
    # Effective-instance cache: one `apply_faults` materialization (and
    # one `__post_init__` tensor rebuild) per distinct supply state, not
    # per window.
    eff_cache: dict[bytes, Instance] = {}

    def eff_inst(t: int) -> Instance:
        key = faults.state_key(t, K)
        got = eff_cache.get(key)
        if got is None:
            got = apply_faults(inst0, faults, t)
            eff_cache[key] = got
        return got

    lam_fc0 = (lam_path.mean(axis=0) if static_forecast == "mean"
               else lam_path[0])
    deploy = planner(apply_faults(inst0.with_lam(lam_fc0), faults, 0))
    fc = ewma_forecasts(lam_path, forecast_ewma)
    events = set(faults.change_points(K))
    replans = fault_replans = evictions = 0
    repair_walls: list[float] = []
    degradations: list[int] = []
    segments: list[tuple[int, int, Solution]] = []
    t0 = 0
    for t in range(1, T):
        event = t in events
        sched = replan_every is not None and t % replan_every == 0
        if not (event or sched):
            continue
        inst_t = apply_faults(inst0.with_lam(fc[t]), faults, t)
        new_dep = None
        if event and fault_response != "static":
            # Event-driven re-solve, adopted unconditionally: the
            # incumbent deployment is illegal under the new supply.
            w0 = time.perf_counter()
            if fault_response == "repair" and session is not None:
                res = session.repair(instance=inst_t)
                rep = res.diagnostics.get("repair", {})
                evictions += len(rep.get("evicted", []))
                degradations.append(
                    int(rep.get("degradation", {}).get("level", 0)))
                new_dep = res.solution
            else:
                new_dep = planner(inst_t)
            repair_walls.append(time.perf_counter() - w0)
            fault_replans += 1
        elif sched and fault_response != "static":
            cand = planner(inst_t)
            # Keep-best against what the incumbent can actually run under
            # the current supply (its eviction image).
            inc_op, _ = evict_unavailable(inst_t, deploy)
            if objective(inst_t, cand) < objective(inst_t, inc_op) - 1e-6:
                new_dep = cand
                replans += 1
            elif session is not None:
                session.incumbent = inc_op
        if new_dep is not None or event:
            segments.append((t0, t, deploy))
            t0 = t
            if new_dep is not None:
                deploy = new_dep
    segments.append((t0, T, deploy))

    costs = np.zeros(T)
    viols = 0
    cap = np.full(inst0.I, STRICT_CAP)
    for (a, b, dep) in segments:
        if b <= a:
            continue
        ie = eff_inst(a)     # supply state is constant over the segment
        op_dep, lost = evict_unavailable(ie, dep)
        evictions += len(lost)
        rental_w = provisioning_cost(ie, op_dep) / inst0.Delta_T * window_h
        if np.any(op_dep.q > 0.5):
            system = Stage2System(ie, op_dep)
            batch = ScenarioBatch.from_lam_path(lam_path[a:b])
            op, v, _ = system.solve_batch(batch, u_cap=cap)
            viols += int(v.sum())
            costs[a:b] = rental_w + op * window_h
        else:
            # Nothing left deployed: every type fully unmet every window.
            viols += inst0.I * (b - a)
            pen = inst0.Delta_T * float(np.sum(inst0.phi))
            costs[a:b] = rental_w + pen * window_h
    return RollingResult(method="", mean_window_cost=float(costs.mean()),
                         total_cost=float(costs.sum()),
                         violation_rate=viols / (T * inst0.I),
                         per_window_cost=costs, replans=replans,
                         fault_replans=fault_replans, evictions=evictions,
                         repair_wall_s=tuple(repair_walls),
                         degradation_levels=tuple(degradations))


def volatility_study(inst0: Instance, sigma: float, trials: int,
                     planner: Callable[[Instance], Solution],
                     replan_every: int | None, seed: int = 0,
                     n_windows: int = 288) -> float:
    """Mean 24 h cost over `trials` random-walk demand paths (Table 4)."""
    totals = []
    for trial in range(trials):
        rng = np.random.default_rng(seed + 1000 * trial)
        path = random_walk_lambdas(inst0.lam, sigma, n_windows, rng)
        res = rolling(inst0, path, planner, replan_every=replan_every)
        totals.append(res.total_cost)
    return float(np.mean(totals))


def replay_study(inst0: Instance, planner: Callable[[Instance], Solution],
                 days: Sequence[str] = ("busy",), n_windows: int = 288,
                 stress: float | None = None,
                 replan_every: int | None = None, seed: int = 7,
                 forecast_ewma: float = 0.4,
                 faults: FaultSchedule | None = None,
                 fault_response: str = "repair") -> RollingResult:
    """Diurnal trace replay over one or more synthetic days (§5.3 extended).

    `days` concatenates per-day multiplier series ("busy"/"volatile") into a
    multi-day path; `n_windows` is windows PER DAY (window_h stays 24/n
    regardless of the number of days); `stress` applies a uniform
    delay+error inflation (e.g. 1.5 for the 1.5x out-of-sample stress) to
    the operated instance before the replay.  `faults`/`fault_response`
    inject a supply-side `FaultSchedule` exactly as in `rolling` (the
    schedule's `n_windows` should cover the full multi-day path).
    """
    inst = inst0.stressed(stress) if stress is not None else inst0
    mult = multi_day_multipliers(days, seed=seed, n_windows=n_windows)
    path = np.outer(mult, inst.lam)
    return rolling(inst, path, planner, replan_every=replan_every,
                   forecast_ewma=forecast_ewma, static_forecast="mean",
                   window_h=24.0 / n_windows, faults=faults,
                   fault_response=fault_response)
