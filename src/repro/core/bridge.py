"""Planner -> JAX bridge: the paper's allocator as a first-class framework
feature.

Three pieces:
  1. A TPU tier catalog (slice classes × serving dtype) mirroring the
     paper's GPU tiers, so the SAME planner (GH/AGH/MILP) provisions TPU
     fleets. Precision tiers map to weight dtypes (bf16 / int8 / int4
     weight-only) with the paper's nu/mu multipliers.
  2. Roofline-calibrated delay coefficients: the planner's analytical
     d_comp per (model, tier) is re-fit from the compiled dry-run's
     per-device HBM bytes (decode is bandwidth-bound — eq. d_comp =
     bytes_per_token / BW), replacing NVIDIA-datasheet constants with
     numbers derived from the ACTUAL compiled program.
  3. `DeploymentSpec`: maps each active (model, tier) pair's (TP, PP)
     decision onto a concrete jax mesh (TP -> 'model' axis, PP -> 'stage'
     axis) plus routing fractions for the serving router.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from .instance import MU, NU, Instance
from .solution import Solution

# TPU tier catalog: (chip class, serving dtype). Hourly prices follow
# public on-demand per-chip pricing ratios; v5e is the production target.
TPU_TIERS = [
    # name,        mem GB, TFLOP/s(bf16), $/h,  BW GB/s, precision
    ("v5e-bf16",   16.0,   197.0,         1.20, 819.0,  "FP16"),
    ("v5e-int8",   16.0,   394.0,         1.20, 819.0,  "INT8"),
    ("v5p-bf16",   95.0,   459.0,         4.20, 2765.0, "FP16"),
    ("v5p-int8",   95.0,   918.0,         4.20, 2765.0, "INT8"),
    ("v4-bf16",    32.0,   275.0,         3.22, 1228.0, "FP16"),
    ("v4-int8",    32.0,   550.0,         3.22, 1228.0, "INT8"),
]


def tpu_instance(base: Instance) -> Instance:
    """The paper's instance with the GPU tier table swapped for TPU tiers.
    TP degrees extend to 16 (one 4x4 ICI ring) — the `model` mesh axis."""
    names, C, Pg, pc, BW, nu, mu = [], [], [], [], [], [], []
    for name, mem, tf, price, bw, prec in TPU_TIERS:
        names.append(name)
        C.append(mem)
        Pg.append(tf)
        pc.append(price)
        BW.append(bw)
        nu.append(NU[prec])
        mu.append(MU[prec])
    inst = dataclasses.replace(
        base, tier_names=names, C_gpu=np.array(C), P_gpu=np.array(Pg),
        p_c=np.array(pc), BW=np.array(BW), nu=np.array(nu), mu=np.array(mu),
        tp_degrees=[1, 2, 4, 8, 16])
    inst.__post_init__()
    return inst


def calibrate_from_dryrun(inst: Instance, dryrun_json: str,
                          arch_to_model: dict[str, int]) -> Instance:
    """Re-fit d_comp from compiled decode dry-runs: per-token HBM bytes per
    device / BW — the planner's bandwidth-bound decode roofline, measured on
    the actual compiled program instead of a datasheet."""
    with open(dryrun_json) as f:
        rows = json.load(f)
    scale = {}
    for r in rows:
        if (r.get("status") == "ok" and r.get("shape") == "decode_32k"
                and not r.get("multi_pod") and r["arch"] in arch_to_model):
            j = arch_to_model[r["arch"]]
            bytes_per_tok_dev = r["hlo_bytes_per_device"] / r["n_devices"]
            # analytical weight-stream bytes per device at this sharding
            analytic = 2.0 * r["params_active"] / r["n_devices"]
            scale[j] = max(0.25, min(4.0, bytes_per_tok_dev / max(analytic, 1)))
    if not scale:
        return inst
    inst = dataclasses.replace(inst)
    tau_scale = np.ones(inst.J)
    for j, s in scale.items():
        tau_scale[j] = s
    # d_comp = tau_i * B_j * nu_k / BW_k  -> fold the compiled-bytes ratio
    # into an effective per-model multiplier on B_j.
    inst.B = inst.B * tau_scale
    inst.__post_init__()
    return inst


@dataclasses.dataclass
class PairDeployment:
    model: str
    tier: str
    tp: int
    pp: int
    n_chips: int
    routing: dict[str, float]      # query type -> fraction of that type


@dataclasses.dataclass
class DeploymentSpec:
    pairs: list[PairDeployment]

    def mesh_shape_for(self, pair: PairDeployment):
        """(stage, model) mesh axes for one pair's serving engine."""
        return dict(shape=(pair.pp, pair.tp), axes=("stage", "model"))


def to_deployment(inst: Instance, sol: Solution) -> DeploymentSpec:
    pairs = []
    for j in range(inst.J):
        for k in range(inst.K):
            if sol.q[j, k] < 0.5:
                continue
            cfg = sol.config_of(inst, j, k)
            if cfg is None:
                continue
            n, m = cfg
            routing = {inst.query_names[i]: float(sol.x[i, j, k])
                       for i in range(inst.I) if sol.x[i, j, k] > 1e-9}
            pairs.append(PairDeployment(
                model=inst.model_names[j], tier=inst.tier_names[k],
                tp=n, pp=m, n_chips=int(sol.y[j, k]), routing=routing))
    return DeploymentSpec(pairs=pairs)
