"""Solution container + shared objective / feasibility evaluator for `P_DM`.

Every solver (exact MILP, GH, AGH, LPR, DVR, HF) returns a `Solution`;
the objective (8a) and the constraint system (8b)–(8k) are evaluated by ONE
shared implementation so that costs and feasibility verdicts are comparable
across methods and checkable by property tests.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .instance import KB_PER_GB, Instance


@dataclasses.dataclass
class Solution:
    x: np.ndarray            # [I,J,K] routing fractions
    y: np.ndarray            # [J,K]   GPUs per pair (int)
    q: np.ndarray            # [J,K]   deployment flag
    w: np.ndarray            # [J,K,C] joint TP/PP selector
    z: np.ndarray            # [I,J,K] admission flag
    u: np.ndarray            # [I]     unserved fraction
    runtime_s: float = 0.0
    method: str = ""

    @staticmethod
    def empty(inst: Instance) -> "Solution":
        I, J, K, C = inst.I, inst.J, inst.K, inst.n_cfg
        return Solution(x=np.zeros((I, J, K)), y=np.zeros((J, K)),
                        q=np.zeros((J, K)), w=np.zeros((J, K, C)),
                        z=np.zeros((I, J, K)), u=np.ones(I))

    def copy(self) -> "Solution":
        return Solution(self.x.copy(), self.y.copy(), self.q.copy(),
                        self.w.copy(), self.z.copy(), self.u.copy(),
                        self.runtime_s, self.method)

    def routed_copy(self) -> "Solution":
        """Copy of this deployment with the routing cleared: y/q/w/z frozen,
        x zeroed and u all-unmet, ready for a Stage-2 scenario LP to fill.
        """
        return Solution(x=np.zeros_like(self.x), y=self.y.copy(),
                        q=self.q.copy(), w=self.w.copy(), z=self.z.copy(),
                        u=np.ones(self.u.shape[0]), method=self.method)

    def config_of(self, inst: Instance, j: int, k: int) -> tuple[int, int] | None:
        c = np.argmax(self.w[j, k])
        if self.w[j, k, c] <= 0.5:
            return None
        return inst.configs[c]

    def to_dict(self) -> dict:
        """JSON-safe dict (arrays as nested lists); `from_dict` inverts it
        exactly — the planner's `PlanResult` serialization rides this."""
        return dict(x=self.x.tolist(), y=self.y.tolist(), q=self.q.tolist(),
                    w=self.w.tolist(), z=self.z.tolist(), u=self.u.tolist(),
                    runtime_s=self.runtime_s, method=self.method)

    @staticmethod
    def from_dict(d: dict) -> "Solution":
        return Solution(x=np.asarray(d["x"], float),
                        y=np.asarray(d["y"], float),
                        q=np.asarray(d["q"], float),
                        w=np.asarray(d["w"], float),
                        z=np.asarray(d["z"], float),
                        u=np.asarray(d["u"], float),
                        runtime_s=float(d.get("runtime_s", 0.0)),
                        method=str(d.get("method", "")))


# ---------------------------------------------------------------------------
# Objective (8a)
# ---------------------------------------------------------------------------

def proc_delay(inst: Instance, sol: Solution) -> np.ndarray:
    """D_i^proc (eq. 6) in seconds, using the selected (TP, PP) per pair."""
    # D_cfg[i,j,k,c] weighted by x * w  (the McCormick product, exact here
    # because w is integral in any committed solution).
    xw = sol.x[:, :, :, None] * sol.w[None, :, :, :]
    return np.einsum("ijkc,ijkc->i", xw, inst.D_cfg)


def cost_terms(inst: Instance, sol: Solution) -> dict[str, float]:
    """The five objective components of (8a), in dollars over Delta_T."""
    rental = inst.Delta_T * float(np.sum(inst.p_c[None, :] * sol.y))
    model_storage = inst.Delta_T * inst.p_s * float(
        np.sum(inst.B[None, :, None] * sol.z))
    data_gb_h = (inst.theta[:, None, None] / KB_PER_GB
                 * inst.r[:, None, None] * inst.lam[:, None, None] * sol.x)
    data_storage = inst.Delta_T * inst.p_s * float(np.sum(data_gb_h))
    delay_pen = float(np.sum(inst.rho * proc_delay(inst, sol) * 1e3))  # rho $/ms
    unmet_pen = inst.Delta_T * float(np.sum(inst.phi * sol.u))
    return dict(rental=rental, model_storage=model_storage,
                data_storage=data_storage, delay_penalty=delay_pen,
                unmet_penalty=unmet_pen)


def objective(inst: Instance, sol: Solution) -> float:
    return float(sum(cost_terms(inst, sol).values()))


def provisioning_cost(inst: Instance, sol: Solution) -> float:
    """Stage-1 cost: rental + model storage (deterministic given deployment)."""
    t = cost_terms(inst, sol)
    return t["rental"] + t["model_storage"]


# ---------------------------------------------------------------------------
# Constraints (8b)–(8k)
# ---------------------------------------------------------------------------

def kv_gb_per_device(inst: Instance, sol: Solution, j: int, k: int,
                     nm: float) -> float:
    """KV-cache GB per device for pair (j,k) under config product nm (8f)."""
    if not inst.kv_applicable[j]:
        # SSM-state models: constant recurrent state, not per-token KV.
        return (inst.beta[j] / KB_PER_GB) * 64.0 / nm
    tokens = float(np.sum(inst.r * inst.T_res[:, j, k] * sol.x[:, j, k]))
    return (inst.beta[j] / KB_PER_GB) / nm * tokens


def _constraint_usage(inst: Instance, sol: Solution) -> dict:
    """Shared usage/capacity arithmetic of (8c) and (8f)–(8j), consumed by
    BOTH `feasibility` (max violation) and `slack_report` (min headroom) —
    one implementation, so the violation and slack views of a constraint
    can never drift apart.

    Returns: ``spend`` (8c $), ``active`` ([J,K] deployment mask),
    ``mem_used`` ([J,K] per-device GB at active pairs; None when nothing
    is deployed), ``load``/``cap`` ([J,K] GFLOP, (8g)), ``stor`` ([I] GB,
    (8h)), ``dproc`` ([I] s, (8i)), ``err`` ([I], (8j)).
    """
    data_gb_h = (inst.theta[:, None, None] / KB_PER_GB
                 * inst.r[:, None, None] * inst.lam[:, None, None] * sol.x)
    spend = (inst.Delta_T * np.sum(inst.p_c[None, :] * sol.y)
             + inst.Delta_T * inst.p_s
             * (np.sum(inst.B[None, :, None] * sol.z) + np.sum(data_gb_h)))
    active = sol.q > 0.5
    mem_used = None
    if active.any():
        nm_sel = np.einsum("jkc,c->jk", sol.w, inst.nm)
        nm_safe = np.maximum(nm_sel, 1.0)
        tokens = np.einsum("i,ijk,ijk->jk", inst.r, inst.T_res, sol.x)
        kv_gb = np.where(
            inst.kv_applicable[:, None],
            (inst.beta[:, None] / KB_PER_GB) / nm_safe * tokens,
            (inst.beta[:, None] / KB_PER_GB) * 64.0 / nm_safe)
        mem_used = inst.B_eff / nm_safe + kv_gb
    load = np.einsum("ijk,ijk->jk",
                     inst.alpha * (inst.r * inst.lam)[:, None, None] / 1e3,
                     sol.x)
    cap = inst.eta * 3600.0 * inst.P_gpu[None, :] * sol.y
    stor = (np.sum(inst.B[None, :, None] * sol.z, axis=(1, 2))
            + np.sum(data_gb_h, axis=(1, 2)))
    err = np.einsum("ijk,ijk->i", inst.e_bar, sol.x)
    return dict(spend=spend, active=active, mem_used=mem_used, load=load,
                cap=cap, stor=stor, dproc=proc_delay(inst, sol), err=err)


def feasibility(inst: Instance, sol: Solution, tol: float = 1e-6,
                enforce_zeta: bool = True,
                usage: dict | None = None) -> dict[str, float]:
    """Max violation per constraint family; all ≈0 ⇒ feasible.

    `usage` optionally reuses a `_constraint_usage(inst, sol)` result for
    this exact (inst, sol) pair — callers evaluating both views (the
    planner facade pairs this with `slack_report`) pay the vectorized
    pass once."""
    v: dict[str, float] = {}
    u = usage if usage is not None else _constraint_usage(inst, sol)
    # (8b) routing + unmet = 1
    v["demand"] = float(np.max(np.abs(sol.x.sum(axis=(1, 2)) + sol.u - 1.0)))
    # (8c) budget
    v["budget"] = max(0.0, float(u["spend"] - inst.delta))
    # (8d)-(8e) configuration consistency
    v["config_sum"] = float(np.max(np.abs(sol.w.sum(axis=2) - sol.q)))
    v["y_eq_nm"] = float(np.max(np.abs(sol.y - np.einsum("jkc,c->jk", sol.w, inst.nm))))
    # (8f) per-device memory: inactive pairs count any routed traffic as a
    # "ghost routing" violation, active pairs check weights + resident KV
    # (or the constant SSM state) per device.
    active = u["active"]
    worst = 0.0
    if (~active).any():
        worst = float(np.max(np.where(~active, sol.x.sum(axis=0), 0.0)))
    if u["mem_used"] is not None:
        worst = max(worst, float(np.max(
            np.where(active, u["mem_used"] - inst.C_gpu[None, :], -np.inf))))
    v["memory"] = max(0.0, worst)
    # (8g) compute throughput
    v["compute"] = max(0.0, float(np.max(u["load"] - u["cap"])))
    # (8h) storage (per query type, as displayed with free i)
    v["storage"] = max(0.0, float(np.max(u["stor"] - inst.C_s)))
    # (8i) delay SLO
    v["delay"] = max(0.0, float(np.max(u["dproc"] - inst.Delta)))
    # (8j) error SLO
    v["error"] = max(0.0, float(np.max(u["err"] - inst.eps)))
    # (8k) chain x <= z <= q
    v["chain"] = max(0.0, float(np.max(sol.x - sol.z - tol)),
                     float(np.max(sol.z - sol.q[None, :, :] - tol)))
    # tier availability caps (supply-side faults; core/faults.py) — only
    # reported when caps are set, so the base constraint-family keys are
    # unchanged for uncapped instances.
    if inst.avail_gpus is not None:
        v["availability"] = max(0.0, float(
            np.max(sol.y.sum(axis=0) - inst.avail_gpus)))
    # unmet cap
    if enforce_zeta:
        v["unmet_cap"] = max(0.0, float(np.max(sol.u - inst.zeta)))
    return v


def is_feasible(inst: Instance, sol: Solution, tol: float = 1e-4,
                enforce_zeta: bool = True) -> bool:
    return all(val <= tol for val in
               feasibility(inst, sol, enforce_zeta=enforce_zeta).values())


def slack_report(inst: Instance, sol: Solution,
                 usage: dict | None = None) -> dict[str, float]:
    """Signed headroom per constraint family (positive = slack remaining,
    negative = violated by that much) — the planner's `PlanResult` carries
    this next to the `feasibility()` violation report so operators can see
    which constraint BINDS a plan, not just whether it is satisfied.

    * ``budget``  — $ left under (8c);
    * ``memory``  — min over active pairs of per-device GB free under (8f)
      (inf when nothing is deployed);
    * ``compute`` — min over active pairs of GFLOP-capacity headroom (8g);
    * ``storage`` — min over types of storage-cap headroom (8h);
    * ``delay``   — min over types of delay-SLO headroom (8i), seconds;
    * ``error``   — min over types of error-SLO headroom (8j);
    * ``unmet``   — min over types of zeta-cap headroom.

    `usage` reuses a `_constraint_usage` result exactly as in
    `feasibility`.
    """
    u = usage if usage is not None else _constraint_usage(inst, sol)
    rep = {"budget": float(inst.delta - u["spend"])}
    active = u["active"]
    if u["mem_used"] is not None:
        rep["memory"] = float(np.min(
            np.where(active, inst.C_gpu[None, :] - u["mem_used"], np.inf)))
        rep["compute"] = float(np.min(
            np.where(active, u["cap"] - u["load"], np.inf)))
    else:
        rep["memory"] = float("inf")
        rep["compute"] = float("inf")
    rep["storage"] = float(np.min(inst.C_s - u["stor"]))
    rep["delay"] = float(np.min(inst.Delta - u["dproc"]))
    rep["error"] = float(np.min(inst.eps - u["err"]))
    rep["unmet"] = float(np.min(inst.zeta - sol.u))
    if inst.avail_gpus is not None:
        # devices still rentable on the scarcest tier (faulted instances)
        rep["availability"] = float(
            np.min(inst.avail_gpus - sol.y.sum(axis=0)))
    return rep
