"""Load-dependent queueing extension of the planning-layer delay model —
the paper's first named future-work item ("a load-dependent queueing term
that extends the planning-layer delay model toward engine-level dynamics").

Model: each active pair (j,k) is an M/G/1-PS station. Tokens routed to the
pair occupy its compute at utilization

    rho_jk = sum_i alpha_ijk * r_i * lam_i * x_ijk / (eta * T_conv * P_k * y_jk)

(the LHS/RHS of the paper's compute constraint (8g)), and the processing
delay inflates by the processor-sharing factor 1/(1 - rho):

    D_queue(i) = sum_jk x_ijk * D_ijk(n,m) / (1 - rho_jk)

This keeps the planner linear-solvable by the same heuristics: GH/AGH gain
a `rho_max` knob (utilization-capped commits) that upper-bounds the
inflation factor at construction time — provisioning headroom becomes an
explicit, tunable quantity instead of a side effect of config granularity.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .instance import Instance
from .solution import Solution, proc_delay


def utilization(inst: Instance, sol: Solution) -> np.ndarray:
    """rho[j,k] per active pair (0 for inactive)."""
    load = np.einsum("ijk,ijk->jk",
                     inst.alpha * (inst.r * inst.lam)[:, None, None] / 1e3,
                     sol.x)
    cap = inst.eta * 3600.0 * inst.P_gpu[None, :] * np.maximum(sol.y, 1e-9)
    rho = np.where(sol.y > 0, load / cap, 0.0)
    return np.clip(rho, 0.0, 0.999)


def queueing_delay(inst: Instance, sol: Solution) -> np.ndarray:
    """D_i^proc with the M/G/1-PS load factor applied per pair."""
    rho = utilization(inst, sol)
    infl = 1.0 / (1.0 - rho)                       # [J,K]
    xw = sol.x[:, :, :, None] * sol.w[None, :, :, :]
    D = np.einsum("ijkc,ijkc,jk->i", xw, inst.D_cfg, infl)
    return D


def queueing_violations(inst: Instance, sol: Solution) -> np.ndarray:
    """Per-type boolean: does the queueing-adjusted delay break the SLO
    that the load-free planning model claimed to satisfy?"""
    return queueing_delay(inst, sol) > inst.Delta + 1e-9


def with_queueing_margin(inst: Instance, rho_max: float) -> Instance:
    """Planner-side counterpart: plan against queueing-aware coefficients.

    Two coupled changes such that the TRUE queueing-adjusted delay of any
    emitted plan satisfies the original SLO:
      1. cap utilization at rho_max (deflate per-pair capacity: eta *=
         rho_max), so the PS inflation is bounded by 1/(1 - rho_max);
      2. pre-inflate the per-token delay coefficients by that worst-case
         factor (tau *= 1/(1 - rho_max)), so M1/M2/M3 pick configurations
         whose LOADED delay still meets Delta_i.
    Then D_true = D/(1-rho) <= D * 1/(1-rho_max) = D_planned <= Delta.
    Headroom becomes an explicit knob instead of a config-granularity
    accident."""
    infl = 1.0 / (1.0 - rho_max)
    inst2 = dataclasses.replace(inst, eta=inst.eta * rho_max,
                                tau=inst.tau * infl)
    inst2.__post_init__()
    return inst2


def slo_attainment_with_queueing(inst: Instance, sol: Solution) -> dict:
    """Summary: load-free vs queueing-adjusted delays and margins."""
    d0 = proc_delay(inst, sol)
    dq = queueing_delay(inst, sol)
    rho = utilization(inst, sol)
    return dict(
        proc_delay=d0, queue_delay=dq,
        max_rho=float(rho.max()),
        violations_load_free=int(np.sum(d0 > inst.Delta + 1e-9)),
        violations_queueing=int(np.sum(dq > inst.Delta + 1e-9)),
        margin_min=float(np.min((inst.Delta - dq) / inst.Delta)))
