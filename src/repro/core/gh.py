"""Greedy Heuristic (GH) — paper Algorithm 1.

Phase 1 (coverage pre-allocation): greedy set-cover that activates one
(model, tier) pair at a time, maximizing uncovered-types-covered per dollar
of horizon rental, until every type is covered or the Phase-1 budget cap
(beta * delta, beta = 0.8) is reached.

Phase 2 (sequential allocation): processes query types in a given order
(default: descending arrival rate), ranking candidates with M2 and committing
traffic with full (8f)-(8h) + budget verification.
"""
from __future__ import annotations

import time

import numpy as np

from .instance import Instance
from .mechanisms import (State, commit, m1_select, m3_upgrade, marginal_cost,
                         max_commit, rank_key)
from .solution import Solution


def _phase1(st: State) -> None:
    inst = st.inst
    while st.uncovered and st.spend < inst.phase1_beta * inst.delta:
        best = None  # (score, j, k, cfg_idx, nm, members)
        for j in range(inst.J):
            for k in range(inst.K):
                if st.q[j, k] > 0.5:
                    continue
                members, worst_c, worst_nm = [], None, 0
                for i in sorted(st.uncovered):
                    c = m1_select(inst, i, j, k, ablation=st.ablation)
                    if c is None or inst.e_bar[i, j, k] > inst.eps[i]:
                        continue
                    members.append(i)
                    if inst.nm[c] > worst_nm:
                        worst_nm, worst_c = int(inst.nm[c]), c
                if not members:
                    continue
                cost = inst.Delta_T * inst.p_c[k] * worst_nm   # eq. (14)
                if st.spend + cost > inst.phase1_beta * inst.delta:
                    continue
                score = len(members) / cost
                if best is None or score > best[0]:
                    best = (score, j, k, worst_c, worst_nm, members)
        if best is None:
            break
        _, j, k, c, nm, members = best
        st.q[j, k] = 1.0
        st.cfg[j, k] = c
        st.y[j, k] = nm
        st.spend += inst.Delta_T * inst.p_c[k] * nm
        for i in members:
            st.uncovered.discard(i)


def _phase2(st: State, order: np.ndarray) -> None:
    inst = st.inst
    for i in order:
        i = int(i)
        cands: list[tuple[tuple[int, float], int, int, int]] = []
        for j in range(inst.J):
            for k in range(inst.K):
                if st.q[j, k] > 0.5:
                    c = int(st.cfg[j, k])
                    if inst.D_cfg[i, j, k, c] > inst.Delta[i]:
                        if "no_m3" in st.ablation:
                            pass                           # route anyway
                        else:
                            c2 = m3_upgrade(st, i, j, k)   # M3
                            if c2 is None:
                                continue
                            c = c2
                else:
                    c0 = m1_select(inst, i, j, k,
                                   ablation=st.ablation)   # M1
                    if c0 is None:
                        continue
                    c = c0
                key = rank_key(st, i, j, k, c)             # M2
                if not np.isfinite(key[1]):
                    continue
                cands.append((key, j, k, c))
        cands.sort(key=lambda t: t[0])
        for key, j, k, c in cands:
            if st.r_rem[i] <= 1e-9:
                break
            # Re-validate under the *current* state (the pair may have been
            # upgraded while serving an earlier candidate of this type).
            if st.q[j, k] > 0.5 and c != st.cfg[j, k] and inst.nm[c] <= st.y[j, k]:
                c_use = int(st.cfg[j, k])
                if inst.D_cfg[i, j, k, c_use] > inst.Delta[i]:
                    continue
            else:
                c_use = c
            frac = min(st.r_rem[i], max_commit(st, i, j, k, c_use))
            if frac <= 1e-9:
                continue
            commit(st, i, j, k, c_use, frac)


def greedy_heuristic(inst: Instance, order: np.ndarray | None = None,
                     run_phase1: bool = True,
                     ablation: frozenset = frozenset()) -> Solution:
    """Single-pass GH (Algorithm 1). `order` overrides the Phase-2 query
    ordering (used by AGH's multi-start); default is descending lambda.
    `ablation` disables mechanisms for the Table-3 study."""
    t0 = time.perf_counter()
    st = State.fresh(inst, ablation=ablation)
    if run_phase1:
        _phase1(st)
    if order is None:
        order = np.argsort(-inst.lam)
    _phase2(st, np.asarray(order))
    sol = Solution.empty(inst)
    sol.x, sol.y, sol.q, sol.z = st.x, st.y, st.q, st.z
    sol.u = np.clip(st.r_rem, 0.0, None)
    for j in range(inst.J):
        for k in range(inst.K):
            if st.q[j, k] > 0.5 and st.cfg[j, k] >= 0:
                sol.w[j, k, int(st.cfg[j, k])] = 1.0
    sol.runtime_s = time.perf_counter() - t0
    sol.method = "GH"
    return sol, st


def gh(inst: Instance, **kw) -> Solution:
    sol, _ = greedy_heuristic(inst, **kw)
    return sol
