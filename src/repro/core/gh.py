"""Greedy Heuristic (GH) — paper Algorithm 1, vectorized.

Phase 1 (coverage pre-allocation): greedy set-cover that activates one
(model, tier) pair at a time, maximizing uncovered-types-covered per dollar
of horizon rental, until every type is covered or the Phase-1 budget cap
(beta * delta, beta = 0.8) is reached.  Each round scores every candidate
pair with one pass of array ops over the precomputed M1 tables instead of a
triple Python loop.

Phase 2 (sequential allocation): processes query types in a given order
(default: descending arrival rate).  Per type, the M2 keys of all (j,k)
candidates are produced by `rank_keys_all` and ordered with one stable
lexsort; commits then run down that order with O(1) `max_commit` checks
against the State's incremental aggregates.

Behavioral equivalence with the scalar seed path (`_scalar_ref.gh_scalar`)
is enforced by tests/test_vectorized_equivalence.py.
"""
from __future__ import annotations

import time

import numpy as np

from .contracts import mutates
from .instance import Instance
from .mechanisms import (State, commit, m3_upgrade, max_commit,
                         max_commit_batch, rank_keys_all, solution_from_state,
                         state_restore)
from .solution import Solution


@mutates("q", "cfg", "y", "spend", "uncovered")
def _phase1(st: State) -> None:
    inst = st.inst
    I, J, K = inst.I, inst.J, inst.K
    no_m1 = "no_m1" in st.ablation
    if no_m1:
        # Ablated M1 "selects" the cheapest config everywhere; only the
        # error-SLO filter remains on membership.
        cfg_eff = np.full((I, J, K), inst.cfg_min_nm, dtype=np.int64)
        nm_eff = np.full((I, J, K), int(inst.nm[inst.cfg_min_nm]),
                         dtype=np.int64)
        cover = inst.e_ok
    else:
        cfg_eff, nm_eff, cover = inst.cfg_m1, inst.m1_nm, inst.cover_ok
    cap = inst.phase1_beta * inst.delta
    while st.uncovered and st.spend < cap:
        unc = np.zeros(I, dtype=bool)
        # repro-lint: ignore[RPR203] -- boolean-mask fill: every index is
        # set True regardless of visit order, so set order cannot leak.
        unc[list(st.uncovered)] = True
        members = cover & unc[:, None, None]              # [I,J,K]
        cnt = members.sum(axis=0)                         # [J,K]
        valid = (cnt > 0) & (st.q <= 0.5)
        if not valid.any():
            break
        nm_m = np.where(members, nm_eff, 0)
        worst_nm = nm_m.max(axis=0)                       # [J,K]
        # Config of the first (lowest-i) member attaining the max nm —
        # the scalar scan's `nm > worst_nm` keep-first tie-breaking.
        first_i = np.argmax(members & (nm_m == worst_nm[None]), axis=0)
        worst_c = np.take_along_axis(cfg_eff, first_i[None], axis=0)[0]
        cost = inst.Delta_T * inst.p_c[None, :] * worst_nm   # eq. (14)
        valid &= st.spend + cost <= cap
        if inst.avail_gpus is not None:
            # Phase 1 activates pairs directly (no max_commit): enforce the
            # shared tier availability cap on the candidate's device count.
            tier_used = st.y.sum(axis=0)
            valid &= (tier_used[None, :] + worst_nm
                      <= inst.avail_gpus[None, :] + 1e-9)
        if not valid.any():
            break
        score = np.full((J, K), -np.inf)
        score[valid] = cnt[valid] / cost[valid]
        flat = int(np.argmax(score))                      # first max: j-major
        j, k = flat // K, flat % K
        st.q[j, k] = 1.0
        st.cfg[j, k] = int(worst_c[j, k])
        st.y[j, k] = int(worst_nm[j, k])
        st.spend += float(cost[j, k])
        st.uncovered -= set(int(i) for i in np.flatnonzero(members[:, j, k]))


def _phase2_prep(st: State, i: int, active: np.ndarray, jj: np.ndarray,
                 kk: np.ndarray) -> tuple[np.ndarray, np.ndarray | None]:
    """Candidate configs and delays for one Phase-2 type: the M1 winners
    with the active cells overwritten by each pair's own (possibly
    M3-upgraded) config.  `active`/`jj`/`kk` are the caller-maintained
    active-pair mask and its nonzero index lists.  Shared by `_phase2`
    and the XLA engine's lockstep driver (which computes the M2 keys on
    device from exactly these rows)."""
    inst = st.inst
    no_m1 = "no_m1" in st.ablation
    no_m3 = "no_m3" in st.ablation
    if no_m1:
        c_inact = np.full((inst.J, inst.K), inst.cfg_min_nm, dtype=np.int64)
    else:
        c_inact = inst.cfg_m1[i]
    c_arr = np.where(active, st.cfg, c_inact)             # [J,K], -1 = none
    # Active pairs whose current config breaks the type's delay SLO
    # either get an M3 upgrade or (ablated) are routed to anyway.
    if not no_m3 and jj.size:
        # Gather the few active cells' delays directly — the full
        # [J,K] take_along_axis grid is pure overhead here.
        d_act = inst.D_cfg[i, jj, kk, c_arr[jj, kk]]
        for a in np.flatnonzero(d_act > inst.Delta[i]):
            j, k = int(jj[a]), int(kk[a])
            c2 = m3_upgrade(st, i, j, k)                  # M3
            c_arr[j, k] = -1 if c2 is None else c2
    # Per-pair delay of the candidate configs: precomputed M1 delays
    # with the active cells overwritten (post-upgrade values; dead
    # cells are masked by `valid` downstream).
    if no_m1:
        d_sel = None
    else:
        d_sel = inst.m1_delay[i].copy()
        if jj.size:
            d_sel[jj, kk] = inst.D_cfg[i, jj, kk,
                                       np.maximum(c_arr[jj, kk], 0)]
    return c_arr, d_sel


def _phase2_walk(st: State, i: int, c_arr: np.ndarray, kap0: np.ndarray,
                 kap1: np.ndarray, active: np.ndarray, jj: np.ndarray,
                 kk: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """The lazy (pi, kappa)-lexicographic commit scan of one Phase-2 type.

    `kap0`/`kap1` are the flattened per-class key rows (+inf = invalid),
    consumed destructively (visited masking).  All pi=0 (full-coverage)
    cells are visited before any pi=1 cell, each class in ascending
    kappa, and `argmin` returns the first minimum, which reproduces the
    stable lexsort's j-major tie order exactly.  A visited cell is
    masked to +inf and never revisited (the sorted walk's `p` only moved
    forward), so the visit sequence is identical to a sorted walk.
    Mutates `active` in place on fresh activations and returns the
    updated (jj, kk) index lists."""
    inst = st.inst
    K = inst.K
    caps = None
    probes = 0
    while st.r_rem[i] > 1e-9:
        flat = int(np.argmin(kap0))
        cur = kap0
        if not np.isfinite(kap0[flat]):
            flat = int(np.argmin(kap1))
            cur = kap1
            if not np.isfinite(kap1[flat]):
                break
        cur[flat] = np.inf      # visited: the walk never backtracks
        j, k = flat // K, flat % K
        c = int(c_arr[j, k])
        # Re-validate under the *current* state (the pair may have
        # been upgraded while serving an earlier candidate).
        if (st.q[j, k] > 0.5 and c != st.cfg[j, k]
                and inst.nm[c] <= st.y[j, k]):
            c_use = int(st.cfg[j, k])
            if inst.D_cfg[i, j, k, c_use] > inst.Delta[i]:
                continue
        else:
            c_use = c
        if c_use != c:      # rare post-upgrade path: row config stale
            cap = max_commit(st, i, j, k, c_use)
        elif caps is not None:
            cap = float(caps[j, k])
        elif probes < 6:
            cap = max_commit(st, i, j, k, c)
            probes += 1
        else:               # long dead scan: batch the rest of the row
            caps = max_commit_batch(st, i, c_arr)
            # Wholesale-mask candidates the batch proves dead, except
            # stale-config cells (they re-validate to the pair's own
            # config above, so their row cap is not authoritative).
            stale = (active & (c_arr != st.cfg)
                     & (inst.nm[np.maximum(c_arr, 0)] <= st.y))
            dead = ~(stale | (caps > 1e-9))
            kap0[dead.ravel()] = np.inf
            kap1[dead.ravel()] = np.inf
            cap = float(caps[j, k])
        frac = min(st.r_rem[i], cap)
        if frac <= 1e-9:
            continue
        was_active = st.q[j, k] > 0.5
        commit(st, i, j, k, c_use, frac)
        if not was_active:
            active[j, k] = True
            jj, kk = np.nonzero(active)
        caps = None         # state changed: cached row caps invalid
        probes = 0
    return jj, kk


def _phase2(st: State, order: np.ndarray) -> None:
    inst = st.inst
    # The active set changes only when a commit activates a fresh pair —
    # track that instead of recomputing the mask per type.
    active = st.q > 0.5
    jj, kk = np.nonzero(active)                           # j-major order
    for i in order:
        i = int(i)
        c_arr, d_sel = _phase2_prep(st, i, active, jj, kk)
        pi, kappa, valid = rank_keys_all(st, i, c_arr, d_sel=d_sel)  # M2
        if not valid.any():
            continue
        # Lazy candidate selection: see `_phase2_walk`.
        kap0 = np.where(valid & (pi == 0), kappa, np.inf).ravel()
        kap1 = np.where(valid & (pi == 1), kappa, np.inf).ravel()
        jj, kk = _phase2_walk(st, i, c_arr, kap0, kap1, active, jj, kk)


def greedy_heuristic(inst: Instance, order: np.ndarray | None = None,
                     run_phase1: bool = True,
                     ablation: frozenset = frozenset(),
                     phase1_snapshot: tuple | None = None
                     ) -> tuple[Solution, State]:
    """Single-pass GH (Algorithm 1).

    `order` overrides the Phase-2 query ordering (used by AGH's
    multi-start); default is descending lambda.  `ablation` disables
    mechanisms for the Table-3 study.  Phase 1 is ordering-independent, so
    AGH's multi-start runs it once and passes the resulting
    `state_snapshot` as `phase1_snapshot` — restored here bit-identically
    instead of being recomputed per ordering.

    Returns the materialized `Solution` together with the running `State`
    (whose arrays the Solution shares) so AGH's local search can continue
    from the construction state without a rebuild.
    """
    t0 = time.perf_counter()
    st = State.fresh(inst, ablation=ablation)
    if phase1_snapshot is not None:
        state_restore(st, phase1_snapshot)
    elif run_phase1:
        _phase1(st)
    if order is None:
        order = np.argsort(-inst.lam)
    _phase2(st, np.asarray(order))
    sol = solution_from_state(inst, st)
    sol.runtime_s = time.perf_counter() - t0
    sol.method = "GH"
    return sol, st


def gh(inst: Instance, order: np.ndarray | None = None,
       run_phase1: bool = True, ablation: frozenset = frozenset(),
       phase1_snapshot: tuple | None = None) -> Solution:
    """Solution-only wrapper of `greedy_heuristic` with the same explicit
    signature — a typo'd option fails loudly here instead of vanishing
    into a ``**kw`` pass-through."""
    sol, _ = greedy_heuristic(inst, order=order, run_phase1=run_phase1,
                              ablation=ablation,
                              phase1_snapshot=phase1_snapshot)
    return sol
