"""Greedy Heuristic (GH) — paper Algorithm 1, vectorized.

Phase 1 (coverage pre-allocation): greedy set-cover that activates one
(model, tier) pair at a time, maximizing uncovered-types-covered per dollar
of horizon rental, until every type is covered or the Phase-1 budget cap
(beta * delta, beta = 0.8) is reached.  Each round scores every candidate
pair with one pass of array ops over the precomputed M1 tables instead of a
triple Python loop.

Phase 2 (sequential allocation): processes query types in a given order
(default: descending arrival rate).  Per type, the M2 keys of all (j,k)
candidates are produced by `rank_keys_all` and ordered with one stable
lexsort; commits then run down that order with O(1) `max_commit` checks
against the State's incremental aggregates.

Behavioral equivalence with the scalar seed path (`_scalar_ref.gh_scalar`)
is enforced by tests/test_vectorized_equivalence.py.
"""
from __future__ import annotations

import time

import numpy as np

from .instance import Instance
from .mechanisms import (State, commit, m3_upgrade, max_commit,
                         max_commit_batch, rank_keys_all, solution_from_state)
from .solution import Solution


def _phase1(st: State) -> None:
    inst = st.inst
    I, J, K = inst.I, inst.J, inst.K
    no_m1 = "no_m1" in st.ablation
    if no_m1:
        # Ablated M1 "selects" the cheapest config everywhere; only the
        # error-SLO filter remains on membership.
        cfg_eff = np.full((I, J, K), inst.cfg_min_nm, dtype=np.int64)
        nm_eff = np.full((I, J, K), int(inst.nm[inst.cfg_min_nm]),
                         dtype=np.int64)
        cover = inst.e_ok
    else:
        cfg_eff, nm_eff, cover = inst.cfg_m1, inst.m1_nm, inst.cover_ok
    cap = inst.phase1_beta * inst.delta
    while st.uncovered and st.spend < cap:
        unc = np.zeros(I, dtype=bool)
        unc[list(st.uncovered)] = True
        members = cover & unc[:, None, None]              # [I,J,K]
        cnt = members.sum(axis=0)                         # [J,K]
        valid = (cnt > 0) & (st.q <= 0.5)
        if not valid.any():
            break
        nm_m = np.where(members, nm_eff, 0)
        worst_nm = nm_m.max(axis=0)                       # [J,K]
        # Config of the first (lowest-i) member attaining the max nm —
        # the scalar scan's `nm > worst_nm` keep-first tie-breaking.
        first_i = np.argmax(members & (nm_m == worst_nm[None]), axis=0)
        worst_c = np.take_along_axis(cfg_eff, first_i[None], axis=0)[0]
        cost = inst.Delta_T * inst.p_c[None, :] * worst_nm   # eq. (14)
        valid &= st.spend + cost <= cap
        if not valid.any():
            break
        score = np.full((J, K), -np.inf)
        score[valid] = cnt[valid] / cost[valid]
        flat = int(np.argmax(score))                      # first max: j-major
        j, k = flat // K, flat % K
        st.q[j, k] = 1.0
        st.cfg[j, k] = int(worst_c[j, k])
        st.y[j, k] = int(worst_nm[j, k])
        st.spend += float(cost[j, k])
        st.uncovered -= set(int(i) for i in np.flatnonzero(members[:, j, k]))


def _phase2(st: State, order: np.ndarray) -> None:
    inst = st.inst
    K = inst.K
    no_m1 = "no_m1" in st.ablation
    no_m3 = "no_m3" in st.ablation
    for i in order:
        i = int(i)
        active = st.q > 0.5
        if no_m1:
            c_inact = np.full((inst.J, K), inst.cfg_min_nm, dtype=np.int64)
        else:
            c_inact = inst.cfg_m1[i]
        c_arr = np.where(active, st.cfg, c_inact)         # [J,K], -1 = none
        # Active pairs whose current config breaks the type's delay SLO
        # either get an M3 upgrade or (ablated) are routed to anyway.
        if not no_m3:
            d_cur = np.take_along_axis(
                inst.D_cfg[i], np.maximum(c_arr, 0)[:, :, None],
                axis=2)[:, :, 0]
            viol = active & (c_arr >= 0) & (d_cur > inst.Delta[i])
            for j, k in zip(*np.nonzero(viol)):
                c2 = m3_upgrade(st, i, int(j), int(k))    # M3
                c_arr[j, k] = -1 if c2 is None else c2
        pi, kappa, valid = rank_keys_all(st, i, c_arr)    # M2 (batched)
        idx = np.flatnonzero(valid.ravel())
        if idx.size == 0:
            continue
        # Stable lexsort by (pi, kappa) keeps j-major scan order on ties —
        # identical to the scalar path's stable tuple sort.
        idx = idx[np.lexsort((kappa.ravel()[idx], pi.ravel()[idx]))]
        # Commit caps for the whole ranked row come from one
        # `max_commit_batch` pass instead of a scalar call per candidate.
        # The batch is pure in the state, so it stays valid across skipped
        # candidates and is recomputed only after a commit mutates the
        # state (typically 1–2 commits per type vs J·K candidates).
        caps = None
        for flat in idx:
            if st.r_rem[i] <= 1e-9:
                break
            j, k = int(flat) // K, int(flat) % K
            c = int(c_arr[j, k])
            # Re-validate under the *current* state (the pair may have been
            # upgraded while serving an earlier candidate of this type).
            if st.q[j, k] > 0.5 and c != st.cfg[j, k] and inst.nm[c] <= st.y[j, k]:
                c_use = int(st.cfg[j, k])
                if inst.D_cfg[i, j, k, c_use] > inst.Delta[i]:
                    continue
            else:
                c_use = c
            if c_use == c:
                if caps is None:
                    caps = max_commit_batch(st, i, c_arr)
                cap = float(caps[j, k])
            else:   # rare post-upgrade path: the row's config is stale here
                cap = max_commit(st, i, j, k, c_use)
            frac = min(st.r_rem[i], cap)
            if frac <= 1e-9:
                continue
            commit(st, i, j, k, c_use, frac)
            caps = None


def greedy_heuristic(inst: Instance, order: np.ndarray | None = None,
                     run_phase1: bool = True,
                     ablation: frozenset = frozenset()
                     ) -> tuple[Solution, State]:
    """Single-pass GH (Algorithm 1).

    `order` overrides the Phase-2 query ordering (used by AGH's
    multi-start); default is descending lambda.  `ablation` disables
    mechanisms for the Table-3 study.

    Returns the materialized `Solution` together with the running `State`
    (whose arrays the Solution shares) so AGH's local search can continue
    from the construction state without a rebuild.
    """
    t0 = time.perf_counter()
    st = State.fresh(inst, ablation=ablation)
    if run_phase1:
        _phase1(st)
    if order is None:
        order = np.argsort(-inst.lam)
    _phase2(st, np.asarray(order))
    sol = solution_from_state(inst, st)
    sol.runtime_s = time.perf_counter() - t0
    sol.method = "GH"
    return sol, st


def gh(inst: Instance, **kw) -> Solution:
    sol, _ = greedy_heuristic(inst, **kw)
    return sol
