"""Problem instance for the joint allocation MILP (paper §3).

An instance bundles every parameter of `P_DM`: query types (I), foundation
models (J), GPU tiers (K = hardware × precision), feasible TP degrees N and
PP depths M, the two-phase delay coefficients, SLOs, prices, and budgets.

Workload statistics are calibrated to the Azure LLM Inference Trace as the
paper describes (§5.1); the trace itself is not available offline, so
`default_instance()` reproduces the paper's published calibration ranges
(arrival rates 1k–25k queries/h across six types, token-length buckets per
Splitwise-style rules, GPU tier table from NVIDIA datasheets, GPTQ-keyed
precision multipliers).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# Precision-keyed multipliers (paper eq. (1) and Table 1).
PRECISIONS = ("FP16", "INT8", "INT4")
NU = {"FP16": 1.0, "INT8": 0.5, "INT4": 0.25}     # latency / bytes-per-weight scale
MU = {"FP16": 1.0, "INT8": 1.15, "INT4": 1.35}    # error multiplier

# Hardware table: (memory GB, TFLOP/s, HBM bandwidth GB/s, $/h at FP16).
# Values follow the paper's footnote ranges (24–80 GB, 768–3350 GB/s,
# 40.7–1484 TFLOPs, $0.35–$2.50/h).
GPU_HW = {
    "RTX4090": dict(mem=24.0, tflops=82.6, bw=1008.0, price=0.35),
    "A6000": dict(mem=48.0, tflops=40.7 * 2, bw=768.0, price=0.80),
    "A100-40": dict(mem=40.0, tflops=312.0, bw=1555.0, price=1.20),
    "H100-80": dict(mem=80.0, tflops=1484.0, bw=3350.0, price=2.50),
}
# Tier list (hardware, precision) — A100/H100 INT4 excluded per paper §5.1.
DEFAULT_TIERS = [
    ("A6000", "FP16"), ("A6000", "INT8"), ("A6000", "INT4"),
    ("RTX4090", "FP16"), ("RTX4090", "INT8"), ("RTX4090", "INT4"),
    ("A100-40", "FP16"), ("A100-40", "INT8"),
    ("H100-80", "FP16"), ("H100-80", "INT8"),
]

QUERY_TYPES = ("Summarization", "CodeGen", "Translation",
               "MathSolving", "ImageGen", "VideoGen")

NVLINK_BW_GBPS = 750.0          # mid of the paper's 600–900 GB/s range
T_CONV = 3600.0                 # seconds per hour
KB_PER_GB = 1e6


@dataclasses.dataclass
class Instance:
    """All parameters of `P_DM`. Arrays are indexed [i], [j], [k] or combos."""

    # --- sets -----------------------------------------------------------
    query_names: Sequence[str]
    model_names: Sequence[str]
    tier_names: Sequence[str]
    tp_degrees: Sequence[int]       # N
    pp_depths: Sequence[int]        # M

    # --- workload -------------------------------------------------------
    lam: np.ndarray                 # [I] queries/hour
    h: np.ndarray                   # [I] input tokens
    f: np.ndarray                   # [I] output tokens
    theta: np.ndarray               # [I] KB/token storage footprint

    # --- models ---------------------------------------------------------
    B: np.ndarray                   # [J] weight footprint GB (FP16)
    beta: np.ndarray                # [J] KV-cache KB/token
    e_base: np.ndarray              # [I, J] FP16 base error rate

    # --- tiers ----------------------------------------------------------
    C_gpu: np.ndarray               # [K] GB per device
    P_gpu: np.ndarray               # [K] TFLOP/s
    p_c: np.ndarray                 # [K] $/h
    BW: np.ndarray                  # [K] GB/s
    nu: np.ndarray                  # [K] latency/bytes scale
    mu: np.ndarray                  # [K] error multiplier

    # --- SLOs / prices / budgets -----------------------------------------
    Delta: np.ndarray               # [I] delay SLO (s)
    eps: np.ndarray                 # [I] error SLO
    rho: np.ndarray                 # [I] $/ms/query delay penalty
    phi: np.ndarray                 # [I] $/h unmet penalty
    zeta: np.ndarray                # [I] unmet-demand cap
    p_s: float                      # $/GB-h storage
    delta: float                    # global budget $
    C_s: float                      # storage cap GB
    Delta_T: float = 24.0           # scheduling horizon (h)
    eta: float = 0.9                # PP-bubble compute-utilization factor
    phase1_beta: float = 0.8        # GH Phase-1 budget fraction
    tau: np.ndarray | None = None   # [I] task-specific overhead for d_comp
    kv_applicable: np.ndarray | None = None  # [J] bool; False for SSM-state models
    # --- supply-side availability (core/faults.py) -----------------------
    # All three default to None, which means "the unbounded on-demand fleet
    # of the paper" — every solver/tensor path is bit-identical to the
    # pre-fault code until a cap is set.
    avail_gpus: np.ndarray | None = None   # [K] max rentable devices per tier
    spot: np.ndarray | None = None         # [K] bool: spot-priced (revocable)
    revoke_rate: np.ndarray | None = None  # [K] Poisson revocations / hour

    # ------------------------------------------------------------------
    # Derived quantities (computed once in __post_init__).
    # ------------------------------------------------------------------
    def __post_init__(self):
        I, J, K = self.I, self.J, self.K
        if self.tau is None:
            self.tau = np.ones(I)
        if self.kv_applicable is None:
            self.kv_applicable = np.ones(J, dtype=bool)
        self.r = self.h + self.f                                  # [I]
        # Effective weight footprint: nu shrinks bytes-per-weight (§3.1(4)).
        self.B_eff = self.B[:, None] * self.nu[None, :]            # [J, K]
        # Per-token compute delay at TP=1 (memory-bandwidth-bound decode
        # roofline, d_comp = tau_i * B_j * nu_k / BW_k) — paper §5.1.
        self.d_comp = (self.tau[:, None, None] * self.B[None, :, None]
                       * self.nu[None, None, :] / self.BW[None, None, :])  # [I,J,K]
        # Per-token inter-stage communication delay: activation bytes over
        # NVLink-class interconnect plus a fixed per-hop latency.
        act_gb = (self.beta * 8.0) / KB_PER_GB                     # [J] ~activation size
        self.d_comm = np.broadcast_to(
            (act_gb[None, :, None] / NVLINK_BW_GBPS) + 5e-6, (I, J, K)).copy()
        # Per-token compute cost (GFLOP/token): ~2 FLOP per active parameter,
        # scaled by precision (paper: "model FLOPs scaled by tier precision").
        self.alpha = np.broadcast_to(
            self.B[None, :, None] * self.nu[None, None, :], (I, J, K)).copy()
        # KV residency weight (see README of core/): the paper's T_res is
        # "calibrated as the per-token decode duration"; we fold the arrival
        # rate into the calibration so that beta_j * sum_i r_i * T_res * x
        # equals the steady-state resident KV bytes:
        #   resident tokens = (lam/3600 q/s) * f_i tokens in flight * t/token.
        self.T_res = (self.lam[:, None, None] / T_CONV
                      * self.f[:, None, None] * self.d_comp)       # [I,J,K]
        # Joint (TP, PP) configuration lattice.
        self.configs = [(n, m) for n in self.tp_degrees for m in self.pp_depths]
        self.nm = np.array([n * m for (n, m) in self.configs])     # [C]
        n_arr = np.array([n for (n, _) in self.configs], float)
        m_arr = np.array([m for (_, m) in self.configs], float)
        # D^k_ij(n,m) = d_comp * r_i / n + m * d_comm * f_i  (paper §3.1(7)).
        self.D_cfg = (self.d_comp[..., None] * self.r[:, None, None, None] / n_arr
                      + m_arr * self.d_comm[..., None]
                      * self.f[:, None, None, None])               # [I,J,K,C]
        # Effective per-token error rate (eq. 1).
        self.e_bar = self.e_base[:, :, None] * self.mu[None, None, :]  # [I,J,K]
        self._precompute_allocation_tensors()

    def _precompute_allocation_tensors(self) -> None:
        """State-independent tensors for the vectorized allocation engine.

        Everything here depends only on instance parameters, so it is
        computed once per instance (and recomputed by `perturbed` /
        `stressed` / manual `__post_init__` calls) and then reused by every
        GH construction, AGH ordering, and local-search move:

        * `mem_ok[J,K,C]`   — per-device weight-memory feasibility of each
                              (TP,PP) config (the memory half of M1 / eq. 9);
        * `cfg_m1[I,J,K]`   — the M1 winner: lexicographically (nm, delay,
                              index)-minimal config that fits memory AND the
                              delay SLO; -1 where no config is feasible;
        * `m1_nm[I,J,K]`    — nm of the M1 winner (0 where infeasible);
        * `e_ok` / `cover_ok` — error-SLO admissibility and the Phase-1
                              coverage mask (M1 feasible AND e_bar <= eps);
        * `data_gb[I]`      — the static data-storage term of eq. (10),
                              theta_i/KB * r_i * lam_i (also the per-unit-x
                              storage coefficient of (8h));
        * `kv_tok_per_x[I,J,K]` — resident KV tokens per unit x ((8f));
        * `load_per_x[I,J,K]`   — GFLOP-load per unit x ((8g));
        * `budget_per_x[I]`     — $ per unit x of data storage ((8c));
        * `cfg_by_nm[C]`    — config indices sorted by (nm, index), the scan
                              order M1/M3 tie-breaking is defined over.
        """
        I, J, K = self.I, self.J, self.K
        C = len(self.configs)
        # Memory feasibility of each config: B_eff/nm <= C_gpu (strict `>`
        # is the scalar discard condition, so keep `<=` here).
        per_dev = self.B_eff[:, :, None] / self.nm[None, None, :]   # [J,K,C]
        self.mem_ok = per_dev <= self.C_gpu[None, :, None]          # [J,K,C]
        if self.avail_gpus is not None:
            # Tier availability caps (core/faults.py): a config whose device
            # count alone exceeds the tier's cap can never be deployed there,
            # so it is statically infeasible — masking it here propagates
            # through cfg_m1 / m1_nm / cover_ok / m1_delay below.  The
            # cross-pair (shared-cap) part of the constraint is dynamic and
            # enforced by the `max_commit*` / `m3_upgrade` / Phase-1 guards.
            self.avail_gpus = np.asarray(self.avail_gpus, float)
            self.mem_ok = self.mem_ok & (
                self.nm[None, None, :] <= self.avail_gpus[None, :, None])
        # Joint M1 feasibility per candidate: memory AND delay SLO.
        feas = self.mem_ok[None, :, :, :] & (
            self.D_cfg <= self.Delta[:, None, None, None])          # [I,J,K,C]
        # Lexicographic argmin over (nm, delay, config index): first take the
        # minimal nm among feasible configs, then the minimal delay within
        # that nm level, then the first config index (np.argmax on a boolean
        # picks the first True) — exactly the scalar scan's tie-breaking.
        big = np.iinfo(np.int64).max
        nm_masked = np.where(feas, self.nm[None, None, None, :], big)
        nm_min = nm_masked.min(axis=3)                              # [I,J,K]
        any_feas = nm_min < big
        tie = feas & (nm_masked == nm_min[..., None])
        d_masked = np.where(tie, self.D_cfg, np.inf)
        d_min = d_masked.min(axis=3)
        first = tie & (d_masked == d_min[..., None])
        self.cfg_m1 = np.where(any_feas, first.argmax(axis=3), -1)  # [I,J,K]
        self.m1_nm = np.where(any_feas, nm_min, 0).astype(np.int64)
        # No-M1 ablation always "selects" the globally cheapest config.
        self.cfg_min_nm = int(np.argmin(self.nm))
        # Error-SLO admissibility and Phase-1 coverage mask.
        self.e_ok = self.e_bar <= self.eps[:, None, None]           # [I,J,K]
        self.cover_ok = (self.cfg_m1 >= 0) & self.e_ok
        # Static eq. (10) data term == per-unit-x coefficient of (8h).
        self.data_gb = self.theta / KB_PER_GB * self.r * self.lam   # [I]
        # Per-unit-x coefficients of the running-state caps.
        self.kv_tok_per_x = self.r[:, None, None] * self.T_res      # [I,J,K]
        self.load_per_x = (self.alpha * self.r[:, None, None]
                           * self.lam[:, None, None] / 1e3)         # [I,J,K]
        self.budget_per_x = self.Delta_T * self.p_s * self.data_gb  # [I]
        # Config scan order for M3: ascending (nm, index).
        self.cfg_by_nm = np.lexsort((np.arange(C), self.nm))
        # Gather support for the batched local-search engine: delay of the
        # M1 winner per (i,j,k) (value at config 0 where infeasible — dead
        # cells are always masked by the caller), a flat [J*K] index row,
        # and a zero-copy [I, J*K, C] view of D_cfg.  Flat fancy gathers
        # through these replace per-call `np.take_along_axis` grids, which
        # dominate the per-move cost at local-search call rates.
        self.m1_delay = np.take_along_axis(
            self.D_cfg, np.maximum(self.cfg_m1, 0)[..., None],
            axis=3)[..., 0]                                         # [I,J,K]
        self.jk_idx = np.arange(J * K)
        self.D_cfg_flat = self.D_cfg.reshape(I, J * K, C)
        # Flat [I, J*K] / [J*K] zero-copy views for the compressed-cells
        # cap evaluator (`max_commit_cells`) and the relocate screen's
        # upper-bound prefilter — gathering through these skips a reshape
        # per call, which adds up at local-search call rates.
        self.kv_tok_per_x_flat = self.kv_tok_per_x.reshape(I, J * K)
        self.load_per_x_flat = self.load_per_x.reshape(I, J * K)
        self.B_eff_flat = self.B_eff.reshape(J * K)
        # Constant factors hoisted out of `max_commit_batch` /
        # `rank_keys_all` — same operations on the same inputs, computed
        # once per instance instead of per call (the per-op dispatch cost
        # dominates at local-search call rates).
        self.kv_gb_per_tok = self.beta / KB_PER_GB                  # [J]
        self.comp_cap_coef = self.eta * 3600.0 * self.P_gpu         # [K]
        self.p_s_B = self.p_s * self.B                              # [J]
        self.e_bar_floor = np.maximum(self.e_bar, 1e-12)            # [I,J,K]
        self.e_bar_floor_flat = self.e_bar_floor.reshape(I, J * K)
        self.m1_feasible = self.cfg_m1 >= 0                         # [I,J,K]
        # Incremental rental of activating a pair at its M1 winner for type
        # i (0 GPUs where infeasible) — the inactive-destination branch of
        # the relocate delta objective, hoisted to a per-instance tensor.
        self.m1_rental = self.p_c[None, None, :] * self.m1_nm       # [I,J,K]
        # Device-resident tensor bundle for the XLA engine, built lazily
        # on first `engine="xla"` solve (see core/xla/tensors.py).  The
        # perturbed()/stressed()/with_lam() helpers construct fresh
        # Instance objects, so a cached bundle can never go stale.
        self._xla_tensors = None

    # --- sizes ---------------------------------------------------------
    @property
    def I(self) -> int:
        return len(self.query_names)

    @property
    def J(self) -> int:
        return len(self.model_names)

    @property
    def K(self) -> int:
        return len(self.tier_names)

    @property
    def n_cfg(self) -> int:
        return len(self.configs)

    def with_lam(self, lam: np.ndarray) -> "Instance":
        """A copy of this instance with a different demand vector."""
        new = dataclasses.replace(self, lam=np.asarray(lam, float))
        return new

    def perturbed(self, rng: np.random.Generator, d_infl: float = 0.25,
                  e_infl: float = 0.25, lam_pm: float = 0.20) -> "Instance":
        """One Stage-2 scenario: one-sided delay/error inflation, ±lam."""
        inst = dataclasses.replace(self)
        inst.tau = self.tau * (1.0 + rng.uniform(0.0, d_infl, self.I))
        inst.e_base = self.e_base * (1.0 + rng.uniform(0.0, e_infl, (self.I, self.J)))
        inst.lam = self.lam * (1.0 + rng.uniform(-lam_pm, lam_pm, self.I))
        inst.__post_init__()
        return inst

    def perturbed_batch(self, rng: np.random.Generator, S: int,
                        d_infl: float = 0.25, e_infl: float = 0.25,
                        lam_pm: float = 0.20) -> "ScenarioBatch":
        """S Stage-2 scenarios as stacked parameter tensors.

        Draws are taken scenario by scenario in exactly the order
        `perturbed` uses, so with the same generator the s-th row is
        bit-identical to the s-th sequential `perturbed` call — the batched
        and looped evaluation protocols sample the same scenarios.
        """
        I, J = self.I, self.J
        tau = np.empty((S, I))
        e_base = np.empty((S, I, J))
        lam = np.empty((S, I))
        for s in range(S):
            tau[s] = self.tau * (1.0 + rng.uniform(0.0, d_infl, I))
            e_base[s] = self.e_base * (1.0 + rng.uniform(0.0, e_infl, (I, J)))
            lam[s] = self.lam * (1.0 + rng.uniform(-lam_pm, lam_pm, I))
        return ScenarioBatch(S=S, tau=tau, e_base=e_base, lam=lam)

    def perturbed_chunks(self, rng: np.random.Generator, S: int,
                         chunk: int = 8192,
                         d_infl: float = 0.25, e_infl: float = 0.25,
                         lam_pm: float = 0.20):
        """Yield `perturbed_batch(S)` as successive `ScenarioBatch` chunks.

        Draws come from the same generator in the same scenario order, so
        concatenating the chunks is bit-identical to the one-shot
        `perturbed_batch(rng, S)` — but peak memory is O(chunk·I·J) instead
        of O(S·I·J), which is what lets `risk_evaluate` run S=10⁵ without a
        ~GB e_base allocation.  Pinned in tests/test_risk.py.
        """
        done = 0
        while done < S:
            n = min(chunk, S - done)
            yield self.perturbed_batch(rng, n, d_infl=d_infl,
                                       e_infl=e_infl, lam_pm=lam_pm)
            done += n

    def stressed(self, alpha_mult: float) -> "Instance":
        """Uniform delay+error inflation by `alpha_mult` (Fig. 3 / Fig. 5)."""
        inst = dataclasses.replace(self)
        inst.tau = self.tau * alpha_mult
        inst.e_base = self.e_base * alpha_mult
        inst.__post_init__()
        return inst


@dataclasses.dataclass
class ScenarioBatch:
    """Stacked realized parameters for S Stage-2 scenarios.

    Only the perturbable parameters are stored ([S, ...] rows of tau,
    e_base, lam); a `None` field means "base value in every scenario".
    `Stage2System.solve_batch` consumes the batch directly — no per-scenario
    `Instance` (and no `__post_init__` tensor rebuild) is ever materialized
    on the fast path.  `materialize` builds the s-th full `Instance` for
    cross-checking against the per-scenario reference protocol.
    """
    S: int
    tau: np.ndarray | None = None       # [S, I]
    e_base: np.ndarray | None = None    # [S, I, J]
    lam: np.ndarray | None = None       # [S, I]

    @staticmethod
    def from_lam_path(lam_path: np.ndarray) -> "ScenarioBatch":
        """A demand-only batch (rolling-horizon replay windows)."""
        lam_path = np.asarray(lam_path, float)
        return ScenarioBatch(S=lam_path.shape[0], lam=lam_path)

    def materialize(self, base: Instance, s: int) -> Instance:
        inst = dataclasses.replace(base)
        if self.tau is not None:
            inst.tau = self.tau[s].copy()
        if self.e_base is not None:
            inst.e_base = self.e_base[s].copy()
        if self.lam is not None:
            inst.lam = self.lam[s].copy()
        inst.__post_init__()
        return inst


def default_instance(seed: int = 0, budget: float = 100.0,
                     phi_v_mult: float = 1.0, zeta: float = 1.0) -> Instance:
    """The paper's base instance: I=6 query types, J=6 Llama-3.x models,
    K=10 GPU tiers (hardware × precision)."""
    rng = np.random.default_rng(seed)
    # Llama-3.x catalog: 1B..70B; B_j 2–140 GB; beta 31–305 KB/token (§5.1).
    model_names = ["llama3-1b", "llama3-3b", "llama3-8b",
                   "llama3-11b", "llama3-34b", "llama3-70b"]
    B = np.array([2.0, 6.0, 16.0, 22.0, 68.0, 140.0])
    beta = np.array([31.0, 52.0, 98.0, 122.0, 210.0, 305.0])

    lam = np.array([18000.0, 15000.0, 12000.0, 8000.0, 2500.0, 1500.0])
    h = np.array([2000.0, 512.0, 800.0, 300.0, 100.0, 150.0])
    f = np.array([200.0, 800.0, 600.0, 700.0, 1200.0, 2500.0])
    # Storage footprints are scaled below the paper's nominal KB/token range
    # so that the $100/day budget admits full coverage under OUR d_comp
    # calibration (documented deviation; the paper's relative text/image/
    # video ordering is preserved).
    theta = np.array([5.0, 4.0, 6.0, 4.5, 25.0, 40.0])
    Delta = np.array([2.5, 1.5, 2.0, 5.0, 16.0, 25.0])
    # ImageGen is the strict-accuracy type (eps 1.3%): only 34B+ models at
    # FP16/INT8 are admissible, so the big-model-on-small-tier tension the
    # paper's M1 guards against is present in the candidate set.
    eps = np.array([0.05, 0.02, 0.04, 0.03, 0.0155, 0.08])
    rho = np.array([2e-4, 3e-4, 1e-4, 6e-4, 7e-4, 1e-3])
    phi = np.array([600.0, 750.0, 500.0, 700.0,
                    1200.0 * phi_v_mult, 1500.0 * phi_v_mult])
    # FP16 base error rate: decreasing in model size, per-type difficulty.
    # Calibrated so that mid-size quantized models can meet strict accuracy
    # SLOs (INT8/INT4 within eps for 8B+), putting the INT-tier/accuracy
    # trade-off of §3.1(4) in play exactly as the paper describes.
    size_quality = np.array([0.055, 0.030, 0.015, 0.0138, 0.010, 0.007])
    difficulty = np.array([0.9, 0.85, 0.8, 1.1, 1.0, 1.0])
    e_base = difficulty[:, None] * size_quality[None, :]

    tier_names, C_gpu, P_gpu, p_c, BW, nu, mu = [], [], [], [], [], [], []
    for hw, prec in DEFAULT_TIERS:
        spec = GPU_HW[hw]
        tier_names.append(f"{hw}-{prec}")
        C_gpu.append(spec["mem"])
        P_gpu.append(spec["tflops"])
        # Quantized tiers rent slightly cheaper (spot-style discount).
        p_c.append(spec["price"] * {"FP16": 1.0, "INT8": 0.9, "INT4": 0.85}[prec])
        BW.append(spec["bw"])
        nu.append(NU[prec])
        mu.append(MU[prec])

    tau = np.array([1.0, 0.9, 0.95, 1.1, 1.2, 1.3])
    return Instance(
        query_names=list(QUERY_TYPES), model_names=model_names,
        tier_names=tier_names, tp_degrees=[1, 2, 4, 8], pp_depths=[1, 2, 4],
        lam=lam, h=h, f=f, theta=theta, B=B, beta=beta, e_base=e_base,
        C_gpu=np.array(C_gpu), P_gpu=np.array(P_gpu), p_c=np.array(p_c),
        BW=np.array(BW), nu=np.array(nu), mu=np.array(mu),
        Delta=Delta, eps=eps, rho=rho, phi=phi,
        zeta=np.full(6, zeta), p_s=float(rng.uniform(0.0005, 0.001)),
        delta=budget, C_s=1000.0, tau=tau)


def random_instance(I: int, J: int, K: int, seed: int = 0,
                    budget: float | None = None) -> Instance:
    """Synthetic instance of arbitrary size for the runtime-scaling study
    (paper Table 6 expands (I,J,K) up to (20,20,20))."""
    rng = np.random.default_rng(seed)
    base = default_instance(seed=seed)
    qi = rng.integers(0, base.I, size=I)
    lam = base.lam[qi] * rng.uniform(0.7, 1.3, I)
    h = base.h[qi] * rng.uniform(0.8, 1.2, I)
    f = base.f[qi] * rng.uniform(0.8, 1.2, I)
    theta = base.theta[qi] * rng.uniform(0.9, 1.1, I)
    Delta = base.Delta[qi] * rng.uniform(0.9, 1.3, I)
    eps = base.eps[qi] * rng.uniform(0.9, 1.4, I)
    rho, phi, tau = base.rho[qi], base.phi[qi], base.tau[qi]

    # Model catalog: log-spaced sizes 1B..70B.
    sizes = np.exp(rng.uniform(np.log(2.0), np.log(140.0), J))
    order = np.argsort(sizes)
    B = sizes[order]
    beta = 31.0 + (305.0 - 31.0) * (B - B.min()) / max(B.max() - B.min(), 1e-9)
    quality = 0.049 * (B / 2.0) ** -0.75 + 0.006
    difficulty = rng.uniform(0.8, 1.15, I)
    e_base = difficulty[:, None] * quality[None, :]

    hw_keys = list(GPU_HW)
    tier_names, C_gpu, P_gpu, p_c, BW, nu, mu = [], [], [], [], [], [], []
    for t in range(K):
        hw = hw_keys[t % len(hw_keys)]
        prec = PRECISIONS[(t // len(hw_keys)) % 3]
        spec = GPU_HW[hw]
        tier_names.append(f"{hw}-{prec}-{t}")
        C_gpu.append(spec["mem"])
        P_gpu.append(spec["tflops"] * rng.uniform(0.9, 1.1))
        p_c.append(spec["price"] * rng.uniform(0.85, 1.15)
                   * {"FP16": 1.0, "INT8": 0.9, "INT4": 0.85}[prec])
        BW.append(spec["bw"] * rng.uniform(0.95, 1.05))
        nu.append(NU[prec])
        mu.append(MU[prec])

    if budget is None:
        budget = 100.0 * I / 6.0
    return Instance(
        query_names=[f"q{i}" for i in range(I)],
        model_names=[f"m{j}" for j in range(J)], tier_names=tier_names,
        tp_degrees=[1, 2, 4, 8], pp_depths=[1, 2, 4],
        lam=lam, h=h, f=f, theta=theta, B=B, beta=beta, e_base=e_base,
        C_gpu=np.array(C_gpu), P_gpu=np.array(P_gpu), p_c=np.array(p_c),
        BW=np.array(BW), nu=np.array(nu), mu=np.array(mu),
        Delta=Delta, eps=eps, rho=rho, phi=phi, zeta=np.ones(I),
        p_s=float(rng.uniform(0.0005, 0.001)), delta=budget, C_s=1000.0 * I / 6.0,
        tau=tau)
