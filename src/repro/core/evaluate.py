"""Two-stage evaluation protocol (paper §5.2).

Stage 1: each algorithm computes deployment decisions (y*, z*, w*) on the
forecast instance; the deployment is then frozen.
Stage 2: for each of S perturbed scenarios (delay/error inflated one-sided
by up to 10–25%, arrivals ±20%), only routing x and unmet u are re-optimized
— an exact LP.

Primary metric: SLO violation rate = fraction of (scenario, type) pairs with
more than 1% of demand unserved.  Secondary: expected total cost = Stage-1
provisioning cost + scenario-averaged Stage-2 storage/delay/unmet penalties.

Fast path (default): the S scenarios are sampled as one stacked
`ScenarioBatch` and solved through a single `Stage2System` — the LP pattern
is assembled once for the frozen deployment and only coefficients are
refreshed per scenario.  `batched=False` keeps the original per-scenario
loop (one `Instance.perturbed` + one `stage2_lp` per scenario); both paths
draw bit-identical scenarios, so they agree to solver precision — pinned by
tests/test_stage2_equivalence.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .instance import Instance
from .solution import Solution, provisioning_cost
from .stage2 import Stage2System, stage2_cost, stage2_lp


@dataclasses.dataclass
class EvalResult:
    method: str
    stage1_cost: float
    expected_cost: float
    violation_rate: float
    runtime_s: float
    per_scenario_cost: np.ndarray


def evaluate(inst: Instance, deploy: Solution, S: int = 500, seed: int = 1234,
             d_infl: float = 0.15, e_infl: float = 0.10, lam_pm: float = 0.20,
             u_cap: np.ndarray | None = None, batched: bool = True,
             workers: int | None = None) -> EvalResult:
    rng = np.random.default_rng(seed)
    s1 = provisioning_cost(inst, deploy)
    if batched:
        batch = inst.perturbed_batch(rng, S, d_infl=d_infl, e_infl=e_infl,
                                     lam_pm=lam_pm)
        system = Stage2System(inst, deploy)
        costs, viols, _ = system.solve_batch(batch, u_cap=u_cap,
                                             workers=workers)
        viol = int(viols.sum())
    else:
        costs = np.zeros(S)
        viol = 0
        for s in range(S):
            scen = inst.perturbed(rng, d_infl=d_infl, e_infl=e_infl,
                                  lam_pm=lam_pm)
            sol, _ = stage2_lp(scen, deploy, u_cap=u_cap)
            costs[s] = stage2_cost(scen, sol)
            viol += int(np.sum(sol.u > 0.01))
    return EvalResult(method=deploy.method, stage1_cost=s1,
                      expected_cost=s1 + float(costs.mean()),
                      violation_rate=viol / (S * inst.I),
                      runtime_s=deploy.runtime_s, per_scenario_cost=costs)
