"""Stage-2 operation LP (paper §5.2): with the Stage-1 deployment
(y, q, w, z) held fixed, re-optimize only routing x and unmet u under the
realized (perturbed) parameters. The problem is a pure LP and is solved
exactly with HiGHS.
"""
from __future__ import annotations

import numpy as np
from scipy import sparse
from scipy.optimize import linprog

from .instance import Instance, KB_PER_GB
from .solution import Solution, cost_terms


def stage2_lp(inst: Instance, deploy: Solution, u_cap: np.ndarray | None = None,
              allow_any_deployed: bool = False) -> tuple[Solution, bool]:
    """Solve the Stage-2 routing LP for `inst` (realized params) given the
    fixed deployment in `deploy`. Returns (solution, capped_feasible):
    if the strict unmet cap is infeasible, re-solves with the cap relaxed
    (u <= 1) and returns capped_feasible = False.
    """
    I, J, K = inst.I, inst.J, inst.K
    if u_cap is None:
        u_cap = inst.zeta
    # Active pairs and their fixed config.
    pairs = [(j, k) for j in range(J) for k in range(K) if deploy.q[j, k] > 0.5]
    cfg = {p: int(np.argmax(deploy.w[p[0], p[1]])) for p in pairs}
    # admissible (i,j,k): z fixed from Stage 1 (or any deployed pair).
    adm = []
    for i in range(I):
        for (j, k) in pairs:
            if allow_any_deployed or deploy.z[i, j, k] > 0.5:
                adm.append((i, j, k))
    nx = len(adm)
    n = nx + I                                    # x's then u's
    col_x = {t: idx for idx, t in enumerate(adm)}

    def solve(cap: np.ndarray):
        rows, cols, vals, lbs, ubs = [], [], [], [], []
        row = 0

        def add(entries, lb, ub):
            nonlocal row
            for cc, vv in entries:
                rows.append(row); cols.append(cc); vals.append(vv)
            lbs.append(lb); ubs.append(ub)
            row += 1

        # (8b)
        for i in range(I):
            ent = [(col_x[(i, j, k)], 1.0) for (ii, j, k) in adm if ii == i]
            ent.append((nx + i, 1.0))
            add(ent, 1.0, 1.0)
        # (8f) memory per active pair (weight shard fixed; KV linear in x)
        for (j, k) in pairs:
            c = cfg[(j, k)]
            nm = float(inst.nm[c])
            if not inst.kv_applicable[j]:
                continue
            ent = []
            for i in range(I):
                if (i, j, k) in col_x:
                    coef = (inst.beta[j] / KB_PER_GB / nm
                            * inst.r[i] * inst.T_res[i, j, k])
                    ent.append((col_x[(i, j, k)], coef))
            if ent:
                add(ent, -np.inf,
                    inst.C_gpu[k] - inst.B_eff[j, k] / nm)
        # (8g) compute per active pair
        for (j, k) in pairs:
            ent = []
            for i in range(I):
                if (i, j, k) in col_x:
                    ent.append((col_x[(i, j, k)],
                                inst.alpha[i, j, k] * inst.r[i] * inst.lam[i] / 1e3))
            if ent:
                add(ent, -np.inf,
                    inst.eta * 3600.0 * inst.P_gpu[k] * float(deploy.y[j, k]))
        # (8h) storage per type
        for i in range(I):
            ent = []
            base = float(np.sum(inst.B[None, :, None] * deploy.z[i]))
            for (ii, j, k) in adm:
                if ii == i:
                    ent.append((col_x[(i, j, k)],
                                inst.theta[i] / KB_PER_GB
                                * inst.r[i] * inst.lam[i]))
            if ent:
                add(ent, -np.inf, inst.C_s - base)
        # (8i) delay
        for i in range(I):
            ent = []
            for (ii, j, k) in adm:
                if ii == i:
                    ent.append((col_x[(i, j, k)],
                                float(inst.D_cfg[i, j, k, cfg[(j, k)]])))
            if ent:
                add(ent, -np.inf, float(inst.Delta[i]))
        # (8j) error
        for i in range(I):
            ent = [(col_x[(i, j, k)], float(inst.e_bar[i, j, k]))
                   for (ii, j, k) in adm if ii == i]
            if ent:
                add(ent, -np.inf, float(inst.eps[i]))

        A = sparse.csr_matrix((vals, (rows, cols)), shape=(row, n))
        # Objective: data storage + delay penalty + unmet penalty.
        c_obj = np.zeros(n)
        for (i, j, k), idx in col_x.items():
            c_obj[idx] += (inst.Delta_T * inst.p_s * inst.theta[i] / KB_PER_GB
                           * inst.r[i] * inst.lam[i])
            c_obj[idx] += inst.rho[i] * 1e3 * float(
                inst.D_cfg[i, j, k, cfg[(j, k)]])
        for i in range(I):
            c_obj[nx + i] = inst.Delta_T * inst.phi[i]
        bounds = [(0.0, 1.0)] * nx + [(0.0, float(cap[i])) for i in range(I)]
        lbs_a, ubs_a = np.array(lbs), np.array(ubs)
        eq_mask = lbs_a == ubs_a
        res = linprog(c_obj,
                      A_ub=A[~eq_mask], b_ub=ubs_a[~eq_mask],
                      A_eq=A[eq_mask], b_eq=ubs_a[eq_mask],
                      bounds=bounds, method="highs")
        return res

    res = solve(u_cap)
    capped_ok = res.status == 0
    if not capped_ok:
        res = solve(np.ones(I))
    sol = Solution.empty(inst)
    sol.y, sol.q, sol.w, sol.z = (deploy.y.copy(), deploy.q.copy(),
                                  deploy.w.copy(), deploy.z.copy())
    if res.status == 0:
        for (i, j, k), idx in col_x.items():
            sol.x[i, j, k] = res.x[idx]
        sol.u = np.clip(res.x[nx:], 0.0, 1.0)
    else:  # fully unserved fallback (deployment cannot route anything)
        sol.u = np.ones(I)
    sol.method = deploy.method + "+stage2"
    return sol, capped_ok


def stage2_cost(inst: Instance, sol: Solution) -> float:
    """Operation cost of a Stage-2 solution: storage + delay + unmet terms."""
    t = cost_terms(inst, sol)
    return t["data_storage"] + t["delay_penalty"] + t["unmet_penalty"]
