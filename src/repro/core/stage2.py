"""Stage-2 operation LP (paper §5.2): with the Stage-1 deployment
(y, q, w, z) held fixed, re-optimize only routing x and unmet u under the
realized (perturbed) parameters.  The problem is a pure LP solved exactly
with HiGHS.

Vectorized engine (PR 2)
------------------------
The evaluation protocols (§5.2 Tables 2/4/5, §5.3 rolling horizon) solve
this LP hundreds of times against the SAME frozen deployment — only the
realized (tau, e_base, lam) differ per scenario.  The constraint *pattern*
(admissible triples, sparsity, equality block, rhs, bounds) is therefore a
function of the deployment alone, and every per-scenario coefficient is a
one-factor rescale of a per-triple base array:

  (8f) KV coef      kvA_t · lam_i · tau_i      (T_res ∝ lam · d_comp ∝ tau)
  (8g) compute coef gA_t  · lam_i
  (8h) storage coef sA_t  · lam_i
  (8i) delay coef   dA_t  · tau_i + dB_t       (comm term is tau-free)
  (8j) error coef   mu_k  · e_base_ij

`Stage2System` assembles the COO pattern once per deployment (rhs included
— it is scenario-invariant), keeps a CSC template whose `.data` is refreshed
in place per scenario, and solves scenarios back-to-back through HiGHS via
`scipy.optimize.milp` — the thin wrapper; scipy exposes no basis warm-start
API, so structure reuse is the part of the warm start we can keep.
`solve_batch` runs a whole `ScenarioBatch` this way, optionally fanned out
over a process pool.  No per-scenario `Instance` (nor its [I,J,K,C] tensor
rebuild) is materialized anywhere on this path.

Equivalence with the frozen per-call assembly (`_scalar_ref.stage2_lp_ref`)
is pinned by tests/test_stage2_equivalence.py.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from scipy import sparse
from scipy.optimize import Bounds, LinearConstraint, milp

from .instance import KB_PER_GB, T_CONV, Instance, ScenarioBatch
from .solution import Solution, cost_terms

# Optional true basis warm-start across scenarios (ROADMAP risk item):
# scipy's HiGHS wrappers rebuild the solver per call, discarding the
# optimal basis between scenarios.  When the `highspy` bindings are
# installed, `solve_batch(warm_start=...)` can instead drive one
# persistent Highs model whose basis carries over from scenario to
# scenario.  The import is gated — this container (and CI) ships without
# highspy, and the scipy path stays the byte-identical default.
try:
    import highspy
except ImportError:            # pragma: no cover - exercised via the flag
    highspy = None

HAVE_HIGHSPY = highspy is not None


@dataclasses.dataclass
class _LPResult:
    """Raw per-scenario solve outcome (pre-`Solution` materialization)."""
    x: np.ndarray | None     # [nx] routing values (None if both solves failed)
    u: np.ndarray            # [I] unmet, clipped to [0, 1]
    cost: float              # stage-2 operation cost (storage+delay+unmet)
    capped_ok: bool          # strict-cap LP was feasible
    viol: int                # SLO violations: #{i : u_i > 0.01}


class Stage2System:
    """Fixed-structure Stage-2 routing LP for one (instance, deployment).

    Build once per deployment; `solve`/`solve_batch` refresh only the
    coefficient values from each scenario's (tau, e_base, lam).
    """

    #: constraint families, in `row_family` code order (rows 0..m_ub).
    ROW_FAMILIES = ("kv", "compute", "storage", "delay", "error")

    def __init__(self, inst: Instance, deploy: Solution,
                 allow_any_deployed: bool = False):
        self.inst = inst
        self.deploy = deploy
        I = inst.I
        self.I = I
        n_arr = np.array([n for (n, _) in inst.configs], float)
        m_arr = np.array([m for (_, m) in inst.configs], float)

        # Active pairs, j-major / k-minor (the legacy scan order).
        pj, pk = np.nonzero(deploy.q > 0.5)
        P = pj.size
        cfg_p = (deploy.w[pj, pk].argmax(axis=1) if P
                 else np.zeros(0, dtype=int))
        nm_p = inst.nm[cfg_p].astype(float)
        self.pj, self.pk, self.cfg_p = pj, pk, cfg_p

        # Admissible triples in legacy `adm` order: i-major, pair-minor.
        if allow_any_deployed:
            mask_ip = np.ones((I, P), dtype=bool)
        else:
            mask_ip = deploy.z[:, pj, pk] > 0.5 if P else np.zeros((I, 0), bool)
        ti, tp = np.nonzero(mask_ip)
        tj, tk = pj[tp], pk[tp]
        self.ti, self.tp, self.tj, self.tk = ti, tp, tj, tk
        nx = ti.size
        self.nx = nx
        self.n = nx + I

        # --- per-triple base factors (scenario value = base × factor) -----
        bw_term = inst.B[tj] * inst.nu[tk] / inst.BW[tk]   # d_comp / tau
        r_t, f_t = inst.r[ti], inst.f[ti]
        nm_t, n_t, m_t = nm_p[tp], n_arr[cfg_p][tp], m_arr[cfg_p][tp]
        # (8f) applies only to KV-cache models (SSM-state models have no
        # per-token resident KV and get no memory row, as in the seed):
        # beta/KB/nm · r · T_res, with T_res = lam/3600 · f · d_comp.
        sel_kv = inst.kv_applicable[tj]
        self.kvA = (inst.beta[tj] / KB_PER_GB / nm_t * r_t
                    * f_t / T_CONV * bw_term)[sel_kv]
        self.gA = inst.B[tj] * inst.nu[tk] * r_t / 1e3     # alpha · r (8g)
        self.sA = inst.theta[ti] / KB_PER_GB * r_t         # (8h) and c_x
        self.dA = bw_term * r_t / n_t                      # D_cfg tau-part
        self.dB = m_t * inst.d_comm[ti, tj, tk] * f_t      # D_cfg comm-part
        self.eA = inst.mu[tk]                              # e_bar / e_base

        # --- row layout (legacy order: kv, compute, storage, delay, err) --
        pair_n = np.bincount(tp, minlength=P) if P else np.zeros(0, int)
        pair_has = pair_n > 0
        kv_pair = pair_has & inst.kv_applicable[pj]
        i_n = np.bincount(ti, minlength=I)
        i_has = i_n > 0
        row = 0
        kv_row = np.full(P, -1)
        kv_row[kv_pair] = row + np.arange(kv_pair.sum())
        row += int(kv_pair.sum())
        g_row = np.full(P, -1)
        g_row[pair_has] = row + np.arange(pair_has.sum())
        row += int(pair_has.sum())
        s_row = np.full(I, -1)
        s_row[i_has] = row + np.arange(i_has.sum())
        row += int(i_has.sum())
        d_row = np.full(I, -1)
        d_row[i_has] = row + np.arange(i_has.sum())
        row += int(i_has.sum())
        e_row = np.full(I, -1)
        e_row[i_has] = row + np.arange(i_has.sum())
        row += int(i_has.sum())
        self.m_ub = row

        # Constraint-family label per inequality row (repro.risk tail
        # attribution): index into ROW_FAMILIES.
        fam = np.empty(self.m_ub, dtype=np.int64)
        fam[kv_row[kv_pair]] = 0
        fam[g_row[pair_has]] = 1
        fam[s_row[i_has]] = 2
        fam[d_row[i_has]] = 3
        fam[e_row[i_has]] = 4
        self.row_family = fam

        self.ti_kv = ti[sel_kv]
        t_col = np.arange(nx)
        rows_ub = np.concatenate([
            kv_row[tp[sel_kv]], g_row[tp], s_row[ti], d_row[ti], e_row[ti],
        ]) if nx else np.zeros(0, int)
        cols_ub = np.concatenate(
            [t_col[sel_kv], t_col, t_col, t_col, t_col]) if nx else \
            np.zeros(0, int)
        self.nnz = rows_ub.size

        # Scenario-invariant rhs, in row order.
        b_ub = np.empty(self.m_ub)
        b_ub[kv_row[kv_pair]] = (inst.C_gpu[pk] - inst.B_eff[pj, pk] / nm_p
                                 )[kv_pair]
        b_ub[g_row[pair_has]] = (inst.eta * 3600.0 * inst.P_gpu[pk]
                                 * deploy.y[pj, pk])[pair_has]
        stor_base = np.sum(inst.B[None, :, None] * deploy.z, axis=(1, 2))
        b_ub[s_row[i_has]] = (inst.C_s - stor_base)[i_has]
        b_ub[d_row[i_has]] = inst.Delta[i_has]
        b_ub[e_row[i_has]] = inst.eps[i_has]

        # One combined constraint block: the m_ub inequality rows on top of
        # the I equality rows of (8b) (x-row sums + u = 1, scenario-
        # invariant).  A single CSC template is built once with
        # data = COO-entry-index so `A.data = vals[perm]` refreshes the
        # per-scenario coefficients in place; HiGHS is then fed through
        # `scipy.optimize.milp` (the thin wrapper — `linprog` re-validates
        # and re-stacks A_ub/A_eq on every call, which at ~1 ms/solve would
        # dominate these tiny LPs).
        eq_rows = self.m_ub + np.concatenate([ti, np.arange(I)])
        eq_cols = np.concatenate([t_col, nx + np.arange(I)])
        all_rows = np.concatenate([rows_ub, eq_rows])
        all_cols = np.concatenate([cols_ub, eq_cols])
        nnz_all = all_rows.size
        # Concat-order COO pattern, exposed for tensor engines (repro.risk):
        # entry e of `coefficient_batch`'s value rows lives at
        # (rows_all[e], cols_all[e]); the first `self.nnz` entries are the
        # scenario-dependent inequality coefficients, the tail is the
        # constant equality block (value 1.0).
        self.rows_all = all_rows
        self.cols_all = all_cols
        self.nnz_all = nnz_all
        self.m = self.m_ub + I
        coo = sparse.coo_matrix(
            (np.arange(nnz_all, dtype=float), (all_rows, all_cols)),
            shape=(self.m_ub + I, self.n))
        self.A = coo.tocsc()
        self._perm = self.A.data.astype(np.int64)
        self._vals = np.ones(nnz_all)          # eq tail stays 1.0 forever
        self.A.data = self._vals[self._perm]   # drop the index template
        self.row_lb = np.concatenate([np.full(self.m_ub, -np.inf),
                                      np.ones(I)])
        self.row_ub = np.concatenate([b_ub, np.ones(I)])

        # Bounds template: x in [0,1]; u rows refreshed per cap.
        self._lb = np.zeros(self.n)
        self._ub = np.ones(self.n)
        self.c_u = inst.Delta_T * inst.phi                  # unmet objective

    # ------------------------------------------------------------------
    def _coefficients(self, tau: np.ndarray, e_base: np.ndarray,
                      lam: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(A_ub COO values, objective c) for one scenario's parameters."""
        inst, ti = self.inst, self.ti
        lam_t = lam[ti]
        sx = self.sA * lam_t                               # (8h) coef
        D_t = self.dA * tau[ti] + self.dB                  # (8i) coef
        vals = np.concatenate([
            self.kvA * (lam * tau)[self.ti_kv],
            self.gA * lam_t,
            sx,
            D_t,
            self.eA * e_base[ti, self.tj],
        ]) if self.nx else np.zeros(0)
        c = np.empty(self.n)
        c[:self.nx] = (inst.Delta_T * inst.p_s * sx
                       + inst.rho[ti] * 1e3 * D_t)
        c[self.nx:] = self.c_u
        return vals, c

    def coefficient_batch(self, batch: ScenarioBatch
                          ) -> tuple[np.ndarray, np.ndarray]:
        """Stacked `_coefficients` over a whole batch, for tensor engines.

        Returns (vals[S, nnz_all], c[S, n]): per-scenario COO values in
        concat order (see `rows_all`/`cols_all`; the equality tail is the
        constant 1.0) and per-scenario objective vectors.  Elementwise ops
        match `_coefficients` exactly, so each row is bit-identical to the
        per-scenario path — pinned in tests/test_risk.py.
        """
        inst, ti = self.inst, self.ti
        S = batch.S
        tau = (np.broadcast_to(inst.tau, (S, inst.I)) if batch.tau is None
               else batch.tau)
        lam = (np.broadcast_to(inst.lam, (S, inst.I)) if batch.lam is None
               else batch.lam)
        e_base = (np.broadcast_to(inst.e_base, (S, inst.I, inst.J))
                  if batch.e_base is None else batch.e_base)
        vals = np.ones((S, self.nnz_all))
        c = np.empty((S, self.n))
        if self.nx:
            lam_t = lam[:, ti]
            sx = self.sA * lam_t
            D_t = self.dA * tau[:, ti] + self.dB
            k0 = self.ti_kv.size
            vals[:, :k0] = self.kvA * (lam * tau)[:, self.ti_kv]
            vals[:, k0:k0 + self.nx] = self.gA * lam_t
            vals[:, k0 + self.nx:k0 + 2 * self.nx] = sx
            vals[:, k0 + 2 * self.nx:k0 + 3 * self.nx] = D_t
            vals[:, k0 + 3 * self.nx:self.nnz] = self.eA * e_base[
                :, ti, self.tj]
            c[:, :self.nx] = (inst.Delta_T * inst.p_s * sx
                              + inst.rho[ti] * 1e3 * D_t)
        c[:, self.nx:] = self.c_u
        return vals, c

    def _highs(self, c: np.ndarray, cap: np.ndarray):
        self._ub[self.nx:] = cap
        return milp(c,
                    constraints=LinearConstraint(self.A, self.row_lb,
                                                 self.row_ub),
                    bounds=Bounds(self._lb, self._ub))

    def solve(self, tau: np.ndarray | None = None,
              e_base: np.ndarray | None = None,
              lam: np.ndarray | None = None,
              u_cap: np.ndarray | None = None) -> _LPResult:
        """Solve one scenario; strict cap first, relaxed (u<=1) fallback —
        the legacy `stage2_lp` protocol."""
        inst = self.inst
        tau = inst.tau if tau is None else tau
        e_base = inst.e_base if e_base is None else e_base
        lam = inst.lam if lam is None else lam
        cap = inst.zeta if u_cap is None else u_cap
        vals, c = self._coefficients(tau, e_base, lam)
        if self.nnz:
            self._vals[:self.nnz] = vals
            self.A.data = self._vals[self._perm]
        res = self._highs(c, cap)
        capped_ok = res.status == 0
        if not capped_ok:
            res = self._highs(c, np.ones(self.I))
        if res.status == 0:
            u = np.clip(res.x[self.nx:], 0.0, 1.0)
            x = res.x[:self.nx]
            # stage2_cost of the materialized solution: the LP objective
            # with the clipped u (x terms are exactly c's x terms).
            cost = float(c[:self.nx] @ x + self.c_u @ u)
        else:   # fully unserved fallback (deployment cannot route anything)
            x, u = None, np.ones(self.I)
            cost = float(self.c_u @ u)
        return _LPResult(x=x, u=u, cost=cost, capped_ok=capped_ok,
                         viol=int(np.sum(u > 0.01)))

    def solve_batch(self, batch: ScenarioBatch,
                    u_cap: np.ndarray | None = None,
                    workers: int | None = None,
                    warm_start: bool | None = None
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Solve every scenario of `batch` against the fixed deployment.

        Returns (costs[S], viols[S], capped_ok[S]).  With `workers`, the
        scenario list is fanned out over a process pool (each worker reuses
        this system's pattern; chunked to amortize pickling).

        `warm_start` requests the persistent-Highs basis warm start across
        scenarios (sequential only; requires the optional `highspy`
        bindings).  `None` means "use it when available and sequential";
        `True` raises if highspy is absent — the scipy path is never
        silently swapped out.
        """
        S = batch.S
        if warm_start and not HAVE_HIGHSPY:
            raise RuntimeError(
                "warm_start=True requires the optional highspy bindings; "
                "install highspy or pass warm_start=False/None")
        use_pool = workers and workers > 1 and S >= 2 * workers
        if warm_start is None:
            warm_start = HAVE_HIGHSPY and not use_pool
        if warm_start and not use_pool:
            return _solve_chunk_highspy(self, batch, u_cap)
        if use_pool:
            import concurrent.futures as cf
            import multiprocessing as mp
            chunks = np.array_split(np.arange(S), workers)
            parts = []
            # spawn, not fork: the parent is typically multithreaded (jax,
            # BLAS) and forking such a process can deadlock the children.
            with cf.ProcessPoolExecutor(
                    max_workers=workers,
                    mp_context=mp.get_context("spawn")) as ex:
                futs = [ex.submit(_solve_chunk, self, _batch_slice(batch, c),
                                  u_cap) for c in chunks if c.size]
                parts = [f.result() for f in futs]
            costs = np.concatenate([p[0] for p in parts])
            viols = np.concatenate([p[1] for p in parts])
            capped = np.concatenate([p[2] for p in parts])
            return costs, viols, capped
        return _solve_chunk(self, batch, u_cap)

    def materialize(self, r: _LPResult) -> Solution:
        """Legacy `stage2_lp` output: deployment copy + scenario routing."""
        sol = self.deploy.routed_copy()
        if r.x is not None:
            sol.x[self.ti, self.tj, self.tk] = r.x
        sol.u = r.u.copy()
        return sol


def _batch_slice(batch: ScenarioBatch, idx: np.ndarray) -> ScenarioBatch:
    pick = lambda a: None if a is None else a[idx]
    return ScenarioBatch(S=idx.size, tau=pick(batch.tau),
                         e_base=pick(batch.e_base), lam=pick(batch.lam))


def _solve_chunk(system: Stage2System, batch: ScenarioBatch,
                 u_cap: np.ndarray | None
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sequential scenario loop over one chunk (process-pool task body)."""
    S = batch.S
    costs = np.zeros(S)
    viols = np.zeros(S, dtype=np.int64)
    capped = np.zeros(S, dtype=bool)
    for s in range(S):
        r = system.solve(
            tau=None if batch.tau is None else batch.tau[s],
            e_base=None if batch.e_base is None else batch.e_base[s],
            lam=None if batch.lam is None else batch.lam[s],
            u_cap=u_cap)
        costs[s], viols[s], capped[s] = r.cost, r.viol, r.capped_ok
    return costs, viols, capped


def _solve_chunk_highspy(system: Stage2System, batch: ScenarioBatch,
                         u_cap: np.ndarray | None
                         ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sequential chunk via one persistent Highs model (basis warm start).

    Mirrors `_solve_chunk`'s strict-cap-then-relax protocol; only the LP
    backend differs.  HiGHS keeps the previous optimal basis between
    `run()` calls on the same model, so consecutive scenarios — one-factor
    rescales of each other — typically re-optimize in a handful of dual
    simplex iterations instead of solving from scratch.
    """
    if highspy is None:          # pragma: no cover - guarded by callers
        raise RuntimeError("highspy is not installed")
    inst = system.inst
    cap = inst.zeta if u_cap is None else u_cap
    S = batch.S
    costs = np.zeros(S)
    viols = np.zeros(S, dtype=np.int64)
    capped = np.zeros(S, dtype=bool)

    h = highspy.Highs()
    h.setOptionValue("output_flag", False)
    lp = highspy.HighsLp()
    lp.num_col_ = system.n
    lp.num_row_ = system.m
    lp.col_cost_ = np.zeros(system.n)
    lp.col_lower_ = system._lb.copy()
    ub0 = np.ones(system.n)
    ub0[system.nx:] = cap
    lp.col_upper_ = ub0
    lp.row_lower_ = system.row_lb.copy()
    lp.row_upper_ = system.row_ub.copy()
    lp.a_matrix_.format_ = highspy.MatrixFormat.kColwise
    lp.a_matrix_.start_ = system.A.indptr.astype(np.int32)
    lp.a_matrix_.index_ = system.A.indices.astype(np.int32)
    lp.a_matrix_.value_ = system._vals[system._perm].copy()
    h.passModel(lp)

    col_idx = np.arange(system.n, dtype=np.int32)
    u_idx = col_idx[system.nx:]
    u_lb = np.zeros(system.I)
    rows_ineq = system.rows_all[:system.nnz]
    cols_ineq = system.cols_all[:system.nnz]
    kOptimal = highspy.HighsModelStatus.kOptimal

    def _run(c: np.ndarray, u_ub: np.ndarray) -> tuple[bool, np.ndarray]:
        h.changeColsCost(system.n, col_idx, c)
        h.changeColsBounds(system.I, u_idx, u_lb, u_ub)
        h.run()
        if h.getModelStatus() != kOptimal:
            return False, np.zeros(system.n)
        return True, np.array(h.getSolution().col_value)

    for s in range(S):
        vals, c = system._coefficients(
            inst.tau if batch.tau is None else batch.tau[s],
            inst.e_base if batch.e_base is None else batch.e_base[s],
            inst.lam if batch.lam is None else batch.lam[s])
        for e in range(system.nnz):
            h.changeCoeff(int(rows_ineq[e]), int(cols_ineq[e]),
                          float(vals[e]))
        ok, xfull = _run(c, cap)
        capped[s] = ok
        if not ok:
            ok, xfull = _run(c, np.ones(system.I))
        if ok:
            u = np.clip(xfull[system.nx:], 0.0, 1.0)
            costs[s] = float(c[:system.nx] @ xfull[:system.nx]
                             + system.c_u @ u)
        else:
            u = np.ones(system.I)
            costs[s] = float(system.c_u @ u)
        viols[s] = int(np.sum(u > 0.01))
    return costs, viols, capped


def stage2_lp(inst: Instance, deploy: Solution, u_cap: np.ndarray | None = None,
              allow_any_deployed: bool = False) -> tuple[Solution, bool]:
    """Solve the Stage-2 routing LP for `inst` (realized params) given the
    fixed deployment in `deploy`.  Returns (solution, capped_feasible):
    if the strict unmet cap is infeasible, re-solves with the cap relaxed
    (u <= 1) and returns capped_feasible = False.

    One-shot wrapper over `Stage2System`; callers solving many scenarios
    against the same deployment should build the system once instead.
    """
    system = Stage2System(inst, deploy, allow_any_deployed=allow_any_deployed)
    r = system.solve(u_cap=u_cap)
    sol = system.materialize(r)
    sol.method = deploy.method + "+stage2"
    return sol, r.capped_ok


def stage2_cost(inst: Instance, sol: Solution) -> float:
    """Operation cost of a Stage-2 solution: storage + delay + unmet terms."""
    t = cost_terms(inst, sol)
    return t["data_storage"] + t["delay_penalty"] + t["unmet_penalty"]
