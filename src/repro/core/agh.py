"""Adaptive Greedy Heuristic (AGH) — paper Algorithm 2, vectorized.

Enhancements over GH:
  * multi-start construction: 8 deterministic orderings (ascending/descending
    each of lambda_i, phi_i, per-type weight-footprint proxy, and error
    tightness eps_i) plus R adaptive random permutations (Remark 2:
    R = 3 / 5 / 10 / 20 by problem scale N = I*J*K), early stop after five
    consecutive non-improving orderings;
  * relocate local search (L = 3 passes): move committed (i,j,k) fractions to
    alternative pairs when feasible and strictly improving;
  * consolidation: drain lightly loaded active pairs onto other active pairs
    and deactivate them when feasible and strictly improving.

Local-search evaluation is delta-based: a trial move mutates the running
`State` through `remove_assignment` / `commit` (each pushing an exact undo
record), the objective delta comes from `state_objective` in O(I), and a
rejected move is rolled back with `undo_all` — no Solution copies, no
from-scratch State rebuilds, no full constraint-system re-evaluation per
trial.  Feasibility is guaranteed by construction (`max_commit` caps every
commit); the full `feasibility()` pass survives as the final debug check on
the returned solution (and per-move when `validate=True`).  The seed's
rebuild-everything implementation is preserved in `_scalar_ref.agh_scalar`
and pinned to this one by tests/test_vectorized_equivalence.py.
"""
from __future__ import annotations

import time

import numpy as np

from .gh import greedy_heuristic
from .instance import Instance
from .mechanisms import (State, commit, deactivate_pair, max_commit,
                         max_commit_batch, remove_assignment,
                         solution_from_state, state_objective, state_restore,
                         state_snapshot, undo_all)
from .solution import Solution, is_feasible, objective


def _orderings(inst: Instance, R: int, rng: np.random.Generator) -> list[np.ndarray]:
    lam, phi, eps = inst.lam, inst.phi, inst.eps
    # Per-type weight-footprint proxy: smallest model whose FP16 error meets
    # the type's SLO ("B_j as it appears for that type").
    bproxy = np.empty(inst.I)
    for i in range(inst.I):
        ok = np.where(inst.e_base[i] <= inst.eps[i])[0]
        bproxy[i] = inst.B[ok].min() if len(ok) else inst.B.max()
    keys = [lam, phi, bproxy, eps]
    orders = []
    for key in keys:
        orders.append(np.argsort(key))
        orders.append(np.argsort(-key))
    for _ in range(R):
        orders.append(rng.permutation(inst.I))
    return orders


def _adaptive_R(inst: Instance) -> int:
    N = inst.I * inst.J * inst.K
    if N > 5000:
        return 3
    if N > 2000:
        return 5
    if N > 500:
        return 10
    return 20


# ---------------------------------------------------------------------------
# Local search (delta moves on the running State)
# ---------------------------------------------------------------------------

def _try_move(st: State, i: int, j: int, k: int, j2: int, k2: int,
              best_obj: float, validate: bool) -> float | None:
    """Move all of x[i,j,k] to (j2,k2); keep if feasible & improving.

    Returns the new objective on success (state mutated), None on rejection
    (state rolled back exactly)."""
    inst = st.inst
    undo: list = []
    frac = remove_assignment(st, i, j, k, undo=undo)
    if st.q[j2, k2] > 0.5:
        c = int(st.cfg[j2, k2])
        if inst.D_cfg[i, j2, k2, c] > inst.Delta[i]:
            undo_all(st, undo)
            return None
    else:
        c = int(inst.cfg_m1[i, j2, k2])
        if c < 0:
            undo_all(st, undo)
            return None
    if max_commit(st, i, j2, k2, c) < frac - 1e-9:
        undo_all(st, undo)
        return None
    commit(st, i, j2, k2, c, frac, undo=undo)
    obj_new = state_objective(st)
    if obj_new < best_obj - 1e-9:
        if validate:
            _assert_state_consistent(st)
        return obj_new
    undo_all(st, undo)
    return None


def _move_targets(st: State, i: int, ranked_jk: np.ndarray,
                  n_inactive: int = 3) -> list[tuple[int, int]]:
    """Candidate destinations for relocating type i: every ACTIVE pair plus
    the few cheapest inactive pairs that pass M1 for this type. (The paper
    scans all (j', k'); restricting to this set keeps relocate inside the
    paper's runtime envelope — the optimum of a move almost always shares
    or cheaply activates.)  `ranked_jk` is the per-type list of admissible
    pairs pre-sorted by activation cost, computed once per AGH call."""
    K = st.inst.K
    targets = [(int(f) // K, int(f) % K)
               for f in np.flatnonzero((st.q > 0.5).ravel())]
    taken = 0
    for f in ranked_jk:
        j, k = int(f) // K, int(f) % K
        if st.q[j, k] > 0.5:
            continue
        targets.append((j, k))
        taken += 1
        if taken >= n_inactive:
            break
    return targets


def _rank_inactive_targets(inst: Instance) -> list[np.ndarray]:
    """Per type: flat (j,k) indices of M1+error-admissible pairs, sorted by
    activation cost p_c[k] * nm(M1 config) with j-major tie order — the
    state-independent part of `_move_targets`."""
    ranked = []
    for i in range(inst.I):
        flat = np.flatnonzero(inst.cover_ok[i].ravel())
        cost = (inst.p_c[flat % inst.K]
                * inst.nm[inst.cfg_m1[i].ravel()[flat]])
        ranked.append(flat[np.argsort(cost, kind="stable")])
    return ranked


def _relocate(st: State, L: int, ranked: list[np.ndarray],
              validate: bool) -> None:
    inst = st.inst
    for _ in range(L):
        improved = False
        obj = state_objective(st)
        for i in range(inst.I):
            assigned = [(int(f) // inst.K, int(f) % inst.K)
                        for f in np.flatnonzero((st.x[i] > 1e-9).ravel())]
            for (j, k) in assigned:
                for (j2, k2) in _move_targets(st, i, ranked[i]):
                    if (j2, k2) == (j, k):
                        continue
                    obj_new = _try_move(st, i, j, k, j2, k2, obj, validate)
                    if obj_new is not None:
                        obj = obj_new
                        improved = True
                        break
        if not improved:
            break


def _try_drain(st: State, j: int, k: int, validate: bool) -> bool:
    """Drain every type off pair (j,k) onto other active pairs and shut the
    pair down; keep only if all traffic lands and the objective improves.

    Replicates the scalar reference's per-type rebuild semantics: after the
    first successful placement the drained pair's config selector is
    cleared, so its remaining traffic stops counting toward D_used while
    the later types are being placed."""
    inst = st.inst
    snap = state_snapshot(st)
    obj0 = state_objective(st)
    types = [int(i) for i in np.flatnonzero(st.x[:, j, k] > 1e-9)]
    c_pair = int(st.cfg[j, k])
    suspended = False
    ok = True
    for i in types:
        frac = float(st.x[i, j, k])
        remove_assignment(st, i, j, k, timed=not suspended,
                          auto_deactivate=False)
        # One batched (8c)–(8h) cap evaluation over all destinations; the
        # first-fit scan below then touches no per-pair Python arithmetic.
        c_dest = np.where(st.q > 0.5, st.cfg, -1)
        c_dest[j, k] = -1
        caps = max_commit_batch(st, i, c_dest)
        d_dest = np.take_along_axis(
            inst.D_cfg[i], np.maximum(c_dest, 0)[:, :, None], axis=2)[:, :, 0]
        fits = ((c_dest >= 0) & (d_dest <= inst.Delta[i])
                & (caps >= frac - 1e-9)).ravel()
        placed = False
        for f in np.flatnonzero(fits):
            j2, k2 = int(f) // inst.K, int(f) % inst.K
            commit(st, i, j2, k2, int(st.cfg[j2, k2]), frac)
            placed = True
            break
        if not placed:
            ok = False
            break
        if not suspended:
            # First placement materialized a solution with the drained
            # pair's w zeroed — its residual delay contributions vanish.
            st.D_used -= inst.D_cfg[:, j, k, c_pair] * st.x[:, j, k]
            st.q[j, k] = 0.0
            st.cfg[j, k] = -1
            suspended = True
    if ok:
        if not suspended:
            if c_pair >= 0:
                st.D_used -= inst.D_cfg[:, j, k, c_pair] * st.x[:, j, k]
        deactivate_pair(st, j, k)
        if state_objective(st) < obj0 - 1e-9:
            if validate:
                _assert_state_consistent(st)
            return True
    state_restore(st, snap)
    return False


def _consolidate(st: State, validate: bool) -> None:
    """Drain lightly loaded pairs onto other active pairs (Alg. 2 l.10–12)."""
    inst = st.inst
    while True:
        flat = np.flatnonzero((st.q > 0.5).ravel())
        active = sorted((float(st.y.ravel()[f]), int(f) // inst.K,
                         int(f) % inst.K) for f in flat)
        improved = False
        for _, j, k in active:
            if _try_drain(st, j, k, validate):
                improved = True
                break
        if not improved:
            return


def _assert_state_consistent(st: State) -> None:
    """Debug path: the incremental state must match a from-scratch
    objective/feasibility evaluation of its materialized solution."""
    inst = st.inst
    sol = solution_from_state(inst, st)
    full = objective(inst, sol)
    fast = state_objective(st)
    assert abs(full - fast) <= 1e-6 * max(1.0, abs(full)), (full, fast)
    assert is_feasible(inst, sol, enforce_zeta=False)


# ---------------------------------------------------------------------------
# AGH driver
# ---------------------------------------------------------------------------

def agh(inst: Instance, R: int | None = None, L: int = 3, seed: int = 0,
        patience: int = 5, validate: bool = False) -> Solution:
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    if R is None:
        R = _adaptive_R(inst)
    ranked = _rank_inactive_targets(inst)
    best: Solution | None = None
    best_obj = np.inf
    stale = 0
    for order in _orderings(inst, R, rng):
        _, st = greedy_heuristic(inst, order=order)
        _relocate(st, L, ranked, validate)
        _consolidate(st, validate)
        obj = state_objective(st)
        if obj < best_obj - 1e-9:
            best, best_obj = solution_from_state(inst, st), obj
            stale = 0
        else:
            stale += 1
            if stale >= patience:
                break
    assert best is not None
    # Final check: the delta-maintained state must stand up to the full
    # constraint system (cheap — once per AGH call, not per move).
    assert is_feasible(inst, best, enforce_zeta=False), \
        "AGH produced an infeasible solution (incremental-state bug)"
    best.runtime_s = time.perf_counter() - t0
    best.method = "AGH"
    return best
