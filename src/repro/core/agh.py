"""Adaptive Greedy Heuristic (AGH) — paper Algorithm 2, vectorized.

Enhancements over GH:
  * multi-start construction: 8 deterministic orderings (ascending/descending
    each of lambda_i, phi_i, per-type weight-footprint proxy, and error
    tightness eps_i) plus R adaptive random permutations (Remark 2:
    R = 3 / 5 / 10 / 20 by problem scale N = I*J*K; the batched engine
    raises the schedule to 5 / 8 / 14 / 24 with the wall-clock it frees),
    early stop after five consecutive non-improving orderings;
  * relocate local search (L = 3 passes): move committed (i,j,k) fractions to
    alternative pairs when feasible and strictly improving;
  * consolidation: drain lightly loaded active pairs onto other active pairs
    and deactivate them when feasible and strictly improving.

Two improvement engines share the construction state:

``local_search="batched"`` (default) — the scored-matrix engine.  Per
source cell, `score_moves_batch` evaluates *every* (j2,k2) destination in
one pass (config selection, delay/M1 admissibility, one `max_commit_batch`
cap evaluation, vectorized delta objective) and `_relocate_batched` applies
the best improving move from that matrix; `_try_drain_batched` batch-scores
all (type x destination) placements of a draining pair up front and places
each type on its cheapest verified destination.  Because it scores the full
destination grid (the paper's "scan all (j',k')") instead of the reference
path's active-pairs-plus-3 shortlist, it both runs faster and never returns
a worse objective on the equivalence suite.

``local_search="reference"`` — the first-improvement scalar probe loop
(PR-1/PR-2 behavior), kept bit-identical to `_scalar_ref.agh_scalar` by
tests/test_vectorized_equivalence.py.

Multi-start fans out over a process pool when `workers` is given (auto for
large instances): Phase 1 is ordering-independent, so its snapshot and the
precomputed `Instance` tensors are shared with forked workers, and the
reduction applies the sequential driver's strict-improvement rule in
ordering-index order — the selected solution is independent of worker
count and scheduling.
"""
from __future__ import annotations

import os
import time

import numpy as np

from .contracts import mutates
from .gh import _phase1, _phase2, greedy_heuristic
from .instance import Instance
from .mechanisms import (DestCache, State, commit, deactivate_pair,
                         delay_sel, deployment_state, max_commit,
                         max_commit_batch, remove_assignment,
                         score_moves_batch, solution_from_state,
                         state_objective, state_restore, state_snapshot,
                         undo_all)
from .solution import Solution, is_feasible, objective


def _orderings(inst: Instance, R: int, rng: np.random.Generator) -> list[np.ndarray]:
    lam, phi, eps = inst.lam, inst.phi, inst.eps
    # Per-type weight-footprint proxy: smallest model whose FP16 error meets
    # the type's SLO ("B_j as it appears for that type") — one masked min
    # over [I,J] instead of a per-type Python loop.
    ok = inst.e_base <= inst.eps[:, None]
    bmin = np.where(ok, inst.B[None, :], np.inf).min(axis=1)
    bproxy = np.where(np.isfinite(bmin), bmin, inst.B.max())
    keys = [lam, phi, bproxy, eps]
    orders = []
    for key in keys:
        orders.append(np.argsort(key))
        orders.append(np.argsort(-key))
    for _ in range(R):
        orders.append(rng.permutation(inst.I))
    return orders


def _adaptive_R(inst: Instance, batched: bool = False) -> int:
    """Remark-2 random-restart budget; the batched engine runs a raised
    schedule, spending the wall-clock the scored-matrix search frees."""
    N = inst.I * inst.J * inst.K
    if N > 5000:
        return 5 if batched else 3
    if N > 2000:
        return 8 if batched else 5
    if N > 500:
        return 14 if batched else 10
    return 24 if batched else 20


# ---------------------------------------------------------------------------
# Reference local search (first-improvement scalar probes, PR-1/PR-2 path)
# ---------------------------------------------------------------------------

def _try_move(st: State, i: int, j: int, k: int, j2: int, k2: int,
              best_obj: float, validate: bool) -> float | None:
    """Move all of x[i,j,k] to (j2,k2); keep if feasible & improving.

    Returns the new objective on success (state mutated), None on rejection
    (state rolled back exactly)."""
    inst = st.inst
    undo: list = []
    frac = remove_assignment(st, i, j, k, undo=undo)
    if st.q[j2, k2] > 0.5:
        c = int(st.cfg[j2, k2])
        if inst.D_cfg[i, j2, k2, c] > inst.Delta[i]:
            undo_all(st, undo)
            return None
    else:
        c = int(inst.cfg_m1[i, j2, k2])
        if c < 0:
            undo_all(st, undo)
            return None
    if max_commit(st, i, j2, k2, c) < frac - 1e-9:
        undo_all(st, undo)
        return None
    commit(st, i, j2, k2, c, frac, undo=undo)
    obj_new = state_objective(st)
    if obj_new < best_obj - 1e-9:
        if validate:
            _assert_state_consistent(st)
        return obj_new
    undo_all(st, undo)
    return None


def _move_targets(st: State, i: int, ranked_jk: np.ndarray,
                  n_inactive: int = 3) -> list[tuple[int, int]]:
    """Candidate destinations for relocating type i: every ACTIVE pair plus
    the few cheapest inactive pairs that pass M1 for this type (the
    reference path's shortlist; the batched engine scores the full grid).
    `ranked_jk` is the per-type list of admissible pairs pre-sorted by
    activation cost, computed once per AGH call."""
    K = st.inst.K
    targets = [(int(f) // K, int(f) % K)
               for f in np.flatnonzero((st.q > 0.5).ravel())]
    taken = 0
    for f in ranked_jk:
        j, k = int(f) // K, int(f) % K
        if st.q[j, k] > 0.5:
            continue
        targets.append((j, k))
        taken += 1
        if taken >= n_inactive:
            break
    return targets


def _rank_inactive_targets(inst: Instance) -> list[np.ndarray]:
    """Per type: flat (j,k) indices of M1+error-admissible pairs, sorted by
    activation cost p_c[k] * nm(M1 config) with j-major tie order — the
    state-independent part of `_move_targets`.  One masked stable argsort
    over the [I, J*K] cost matrix replaces the per-type Python loop; the
    inadmissible cells sort to the tail as +inf and are sliced off."""
    I, JK = inst.I, inst.J * inst.K
    adm = inst.cover_ok.reshape(I, JK)
    cost = (inst.p_c[None, None, :]
            * inst.nm[np.maximum(inst.cfg_m1, 0)]).reshape(I, JK)
    order = np.argsort(np.where(adm, cost, np.inf), axis=1, kind="stable")
    counts = adm.sum(axis=1)
    return [order[i, :counts[i]] for i in range(I)]


def _relocate(st: State, L: int, ranked: list[np.ndarray],
              validate: bool) -> None:
    inst = st.inst
    for _ in range(L):
        improved = False
        obj = state_objective(st)
        for i in range(inst.I):
            assigned = [(int(f) // inst.K, int(f) % inst.K)
                        for f in np.flatnonzero((st.x[i] > 1e-9).ravel())]
            for (j, k) in assigned:
                for (j2, k2) in _move_targets(st, i, ranked[i]):
                    if (j2, k2) == (j, k):
                        continue
                    obj_new = _try_move(st, i, j, k, j2, k2, obj, validate)
                    if obj_new is not None:
                        obj = obj_new
                        improved = True
                        break
        if not improved:
            break


@mutates("D_used", "q", "cfg")
def _try_drain(st: State, j: int, k: int, validate: bool) -> bool:
    """Drain every type off pair (j,k) onto other active pairs and shut the
    pair down; keep only if all traffic lands and the objective improves.

    Replicates the scalar reference's per-type rebuild semantics: after the
    first successful placement the drained pair's config selector is
    cleared, so its remaining traffic stops counting toward D_used while
    the later types are being placed."""
    inst = st.inst
    snap = state_snapshot(st)
    obj0 = state_objective(st)
    types = [int(i) for i in np.flatnonzero(st.x[:, j, k] > 1e-9)]
    c_pair = int(st.cfg[j, k])
    suspended = False
    ok = True
    for i in types:
        frac = float(st.x[i, j, k])
        remove_assignment(st, i, j, k, timed=not suspended,
                          auto_deactivate=False)
        # One batched (8c)–(8h) cap evaluation over all destinations; the
        # first-fit scan below then touches no per-pair Python arithmetic.
        c_dest = np.where(st.q > 0.5, st.cfg, -1)
        c_dest[j, k] = -1
        caps = max_commit_batch(st, i, c_dest)
        d_dest = delay_sel(inst, i, c_dest)
        fits = ((c_dest >= 0) & (d_dest <= inst.Delta[i])
                & (caps >= frac - 1e-9)).ravel()
        placed = False
        for f in np.flatnonzero(fits):
            j2, k2 = int(f) // inst.K, int(f) % inst.K
            commit(st, i, j2, k2, int(st.cfg[j2, k2]), frac)
            placed = True
            break
        if not placed:
            ok = False
            break
        if not suspended:
            # First placement materialized a solution with the drained
            # pair's w zeroed — its residual delay contributions vanish.
            st.D_used -= inst.D_cfg[:, j, k, c_pair] * st.x[:, j, k]
            st.q[j, k] = 0.0
            st.cfg[j, k] = -1
            suspended = True
    if ok:
        if not suspended:
            if c_pair >= 0:
                st.D_used -= inst.D_cfg[:, j, k, c_pair] * st.x[:, j, k]
        deactivate_pair(st, j, k)
        if state_objective(st) < obj0 - 1e-9:
            if validate:
                _assert_state_consistent(st)
            return True
    state_restore(st, snap)
    return False


def _consolidate(st: State, validate: bool) -> None:
    """Drain lightly loaded pairs onto other active pairs (Alg. 2 l.10–12)."""
    inst = st.inst
    while True:
        flat = np.flatnonzero((st.q > 0.5).ravel())
        active = sorted((float(st.y.ravel()[f]), int(f) // inst.K,
                         int(f) % inst.K) for f in flat)
        improved = False
        for _, j, k in active:
            if _try_drain(st, j, k, validate):
                improved = True
                break
        if not improved:
            return


# ---------------------------------------------------------------------------
# Batched local search (scored move matrices, best-improvement, incremental)
# ---------------------------------------------------------------------------

def _invalidate_sources(clean: set, types, cells: set) -> None:
    """Drop every clean-source mark whose score inputs an applied move may
    have touched: all sources of the moved types (their type-local scalars
    — r_rem, E/D_used, stor_used, z row — shifted) and all sources sitting
    on a touched pair whose removal economics changed (`cells` — the
    callers pass pairs left with a single traffic type, whose survivor
    gains the deactivation refund, and drained/deactivated pairs).
    Destination-side reveals — capacity freed on a touched pair making
    someone else's move into it viable — are deliberately NOT tracked
    here; the verification rescan at the fixed point catches them."""
    tset = types if isinstance(types, set) else {types}
    # repro-lint: ignore[RPR203] -- feeds difference_update (an order-
    # insensitive set reduction); iteration order cannot reach any output.
    stale = [s for s in clean if s[0] in tset or (s[1], s[2]) in cells]
    clean.difference_update(stale)


def _relocate_batched(st: State, L: int, validate: bool,
                      cache: DestCache | None = None,
                      clean: set | None = None,
                      fallback: bool = True,
                      stats: dict | None = None) -> bool:
    """Relocate via `score_moves_batch`: per source cell, every destination
    is scored in one pass and the best strictly-improving move is applied.
    Scans the full (j',k') grid (the paper's scan), not the reference
    path's active-pairs-plus-3 shortlist.

    With `clean` (the dirty-source protocol), sources that failed to
    improve stay skipped until an applied move touches their score inputs
    (`_invalidate_sources`); a sweep that found no improving move among
    the dirty sources clears the set and rescans everything (`fallback`;
    `_improve_batched` disables it per call and runs one shared
    verification rescan at the joint relocate/consolidate fixed point
    instead), so the search never declares convergence on stale marks —
    an improving move can be deferred by the approximate invalidation
    rule, never missed.  The improvement test itself is
    threshold-independent (a move improves iff its own delta is negative),
    so marks taken against an older, higher objective stay valid as the
    objective descends.  `L` caps the number of improving sweeps,
    mirroring the fixed-pass engine's bound; rescans that find nothing are
    free.  Returns whether any move was applied."""
    inst = st.inst
    K = inst.K
    track = clean is not None
    improving = 0
    any_improved = False
    while True:
        improved = False
        skipped = False
        obj = state_objective(st)
        for i in range(inst.I):
            for f in np.flatnonzero((st.x[i] > 1e-9).ravel()):
                j, k = int(f) // K, int(f) % K
                if st.x[i, j, k] <= 1e-9:   # merged away earlier this pass
                    continue
                if track and (i, j, k) in clean:
                    skipped = True
                    continue
                ms = score_moves_batch(st, i, j, k, improve_below=obj - 1e-9,
                                       cache=cache, obj_cur=obj)
                if not ms.admissible.any():
                    if track:
                        clean.add((i, j, k))
                    continue
                flat = int(np.argmin(ms.obj_after))
                j2, k2 = flat // K, flat % K
                remove_assignment(st, i, j, k)
                commit(st, i, j2, k2, int(ms.c_dest[j2, k2]), ms.frac)
                obj = state_objective(st)
                improved = True
                if stats is not None:
                    stats["moves_applied"] = stats.get("moves_applied", 0) + 1
                if cache is not None:
                    cache.invalidate_type(i)
                if track and clean:
                    # The source pair's survivors re-score only when the
                    # move leaves exactly one traffic type behind (its
                    # removal now also refunds the pair); arrivals at the
                    # destination pair lose refund appeal, never gain it.
                    cells = set()
                    if np.count_nonzero(st.x[:, j, k] > 1e-9) == 1:
                        cells.add((j, k))
                    _invalidate_sources(clean, i, cells)
                if validate:
                    _assert_state_consistent(st)
        any_improved |= improved
        if improved:
            improving += 1
            if improving >= L:
                break
        elif skipped and fallback:
            clean.clear()       # fallback full rescan before convergence
            if stats is not None:
                stats["rescans"] = stats.get("rescans", 0) + 1
        else:
            break
    return any_improved


def _try_drain_batched(st: State, j: int, k: int,
                       validate: bool) -> tuple[set, set] | None:
    """Drain pair (j,k): one vectorized pass scores every (type x
    destination) placement — delay fits and the commit-cost delta over the
    compressed active-destination list — then each type lands on its
    cheapest destination in score order, with one O(1) `max_commit` check
    at commit time (caps only shrink as earlier types are placed, so the
    pre-placement scores over-approximate and the check restores
    exactness).  Structurally impossible drains (some type has no
    delay-admissible destination — the common case at a converged state)
    are rejected before the detach round trip; a rejected drain rolls back
    through its undo records (exact restore) instead of a full-state
    snapshot, which at (100,80,40) scale saves two multi-MB array copies
    per probe.  Returns `(moved_types, touched_cells)` on success (the
    dirty-source invalidation set) or None."""
    inst = st.inst
    K = inst.K
    types = np.flatnonzero(st.x[:, j, k] > 1e-9)
    dest = np.flatnonzero((st.q > 0.5).ravel())
    dest = dest[dest != j * K + k]
    obj0 = state_objective(st)
    if types.size:
        if dest.size == 0:
            return None
        jj, kk = dest // K, dest % K
        cfg_d = st.cfg[jj, kk]
        # One (T, n_dest) score pass: delay admissibility is state-free and
        # the delta rows read only type-local state (z[i], r_rem[i]), which
        # other types' placements never touch — so the matrix computed here
        # stays exact for each type at its own placement time.
        d_td = inst.D_cfg[types[:, None], jj[None, :], kk[None, :],
                          cfg_d[None, :]]
        fits = d_td <= inst.Delta[types, None]
        if not fits.any(axis=1).all():
            return None
        fr = st.x[types, j, k][:, None]
        if not st.ablation:
            # Cap upper bound per (type, destination) on the pre-detach
            # state: each type's own scalars are computed post-removal in
            # closed form (exact at its placement time — other types'
            # placements never touch them), and destination loads only
            # grow as earlier types land, so this bounds the real commit
            # cap from above.  A type whose best admissible destination
            # cannot absorb its traffic dooms the whole drain before the
            # detach/rollback round trip — the common case at a converged
            # state with near-full destinations.
            frv = st.x[types, j, k]
            c_pair = int(st.cfg[j, k])
            rr2 = st.r_rem[types] + frv
            e2 = st.E_used[types] - inst.e_bar[types, j, k] * frv
            dd2 = st.D_used[types] - inst.D_cfg[types, j, k, c_pair] * frv
            ub = np.minimum(
                rr2[:, None],
                (inst.eps[types, None] - e2[:, None])
                / inst.e_bar_floor[types[:, None], jj[None, :], kk[None, :]])
            ub = np.minimum(ub, (inst.Delta[types, None] - dd2[:, None])
                            / np.maximum(d_td, 1e-12))
            lpx = inst.load_per_x[types[:, None], jj[None, :], kk[None, :]]
            comp = inst.comp_cap_coef[kk] * inst.nm[cfg_d] - st.load[jj, kk]
            with np.errstate(divide="ignore", invalid="ignore"):
                ub = np.where(lpx > 1e-18,
                              np.minimum(ub, comp[None, :] / lpx), ub)
            best_ub = np.where(fits, ub, -np.inf).max(axis=1)
            if np.any(best_ub < frv - 1e-9):
                return None
        delta = (inst.Delta_T * inst.p_s
                 * (np.where(st.z[types][:, jj, kk] < 0.5,
                             inst.B[jj][None, :], 0.0)
                    + inst.data_gb[types, None] * fr)
                 + inst.rho[types, None] * d_td * 1e3 * fr)
        score = np.where(fits, delta, np.inf)
        if not st.ablation:
            # Objective lower bound: routing every type to its *cheapest*
            # admissible destination still costs at least
            # sum_t min(delta) against the removal + deactivation refunds
            # — if that cannot clear the strict-improvement bar (with a
            # 1e-6 margin over float reassociation), the drain cannot
            # either, and the detach round trip is skipped.  The common
            # failure mode at a converged state is exactly this
            # "placeable but not profitable" case.
            hz = st.z[types, j, k] > 0.5
            refunds = (inst.Delta_T * inst.p_s
                       * (inst.data_gb[types] * frv
                          + np.where(hz, inst.B[j], 0.0))
                       + inst.rho[types] * inst.D_cfg[types, j, k, c_pair]
                       * 1e3 * frv)
            n_str = (int(np.count_nonzero(st.z[:, j, k] > 0.5))
                     - int(np.count_nonzero(hz)))
            lb = (score.min(axis=1).sum() - refunds.sum()
                  - inst.Delta_T * (inst.p_s * inst.B[j] * n_str
                                    + inst.p_c[k] * float(st.y[j, k])))
            if lb >= 1e-6:
                return None
        order = np.argsort(score, axis=1, kind="stable")
    undo: list = []
    fracs = [remove_assignment(st, int(i), j, k, undo=undo,
                               auto_deactivate=False)
             for i in types]
    deactivate_pair(st, j, k, undo=undo)
    ok = True
    used: set = set()
    for t, i in enumerate(types):
        i, frac = int(i), float(fracs[t])
        placed = False
        for p in order[t]:
            if not np.isfinite(score[t, p]):
                break
            j2, k2 = int(jj[p]), int(kk[p])
            if max_commit(st, i, j2, k2, int(st.cfg[j2, k2])) >= frac - 1e-9:
                commit(st, i, j2, k2, int(st.cfg[j2, k2]), frac, undo=undo)
                used.add((j2, k2))
                placed = True
                break
        if not placed:
            ok = False
            break
    if ok and state_objective(st) < obj0 - 1e-9:
        if validate:
            _assert_state_consistent(st)
        return {int(i) for i in types}, used | {(j, k)}
    undo_all(st, undo)
    return None


@mutates("cfg_dirty")
def _consolidate_batched(st: State, validate: bool,
                         cache: DestCache | None = None,
                         clean: set | None = None,
                         stats: dict | None = None) -> bool:
    """Drain lightly loaded pairs, restarting the ascending-y scan after
    every success (unchanged protocol).  A successful drain invalidates
    the relocate engine's clean-source marks (and cached admission rows)
    for the moved types and every touched cell, so the following relocate
    sweep re-scores exactly the sources the drain disturbed.  Returns
    whether any pair was drained."""
    inst = st.inst
    any_improved = False
    while True:
        flat = np.flatnonzero((st.q > 0.5).ravel())
        active = sorted((float(st.y.ravel()[f]), int(f) // inst.K,
                         int(f) % inst.K) for f in flat)
        improved = False
        for _, j, k in active:
            res = _try_drain_batched(st, j, k, validate)
            if res is not None:
                if cache is not None:
                    # Arm the config diff even when the drained pair had
                    # no traffic (empty moved-type set): its cfg flipped
                    # to -1 and the cache must not keep scoring it as an
                    # active, rental-free destination.
                    cache.cfg_dirty = True
                    for t in res[0]:
                        cache.invalidate_type(t)
                if clean is not None and clean:
                    _invalidate_sources(clean, res[0], res[1])
                if stats is not None:
                    stats["drains_applied"] = stats.get("drains_applied",
                                                        0) + 1
                improved = True
                break
        if not improved:
            return any_improved
        any_improved = True


def _improve_batched(st: State, L: int, validate: bool,
                     incremental: bool = True,
                     stats: dict | None = None) -> None:
    """The batched improvement phase: relocate and consolidation iterate
    to a joint fixed point (a consolidation that drained something hands
    the disturbed sources back to relocate; one that drained nothing
    terminates — relocate had already converged on the same state).  One
    `DestCache` carries the destination scoring tensors across all sweeps
    of all rounds, diff-synced against the state's config vector; with
    `incremental`, the clean-source set persists across rounds too, so a
    round after a drain re-scores only what the drain touched.

    Inner relocate calls skip clean sources without their own fallback
    rescan; instead, once the dirty fixed point is reached, the clean set
    is cleared and one full verification rescan runs (plus a consolidation
    retry if it moved anything) — the "no improving move is ever missed"
    guarantee costs one extra sweep per ordering, not one per round."""
    cache = DestCache(st)
    clean: set | None = set() if incremental else None
    while True:
        _relocate_batched(st, L, validate, cache, clean, fallback=False,
                          stats=stats)
        if _consolidate_batched(st, validate, cache, clean, stats=stats):
            continue
        if not (incremental and clean):
            return
        # Dirty fixed point: verify with one full rescan.  Only an applied
        # move (deferred by the approximate invalidation rule) keeps the
        # loop alive — and then the next fixed point is verified again, so
        # the state returned has survived a full rescan unimproved.
        clean.clear()
        if stats is not None:
            stats["rescans"] = stats.get("rescans", 0) + 1
        if not _relocate_batched(st, L, validate, cache, clean,
                                 fallback=False, stats=stats):
            return
        _consolidate_batched(st, validate, cache, clean, stats=stats)


def _assert_state_consistent(st: State) -> None:
    """Debug path: the incremental state must match a from-scratch
    objective/feasibility evaluation of its materialized solution."""
    inst = st.inst
    sol = solution_from_state(inst, st)
    full = objective(inst, sol)
    fast = state_objective(st)
    assert abs(full - fast) <= 1e-6 * max(1.0, abs(full)), (full, fast)
    assert is_feasible(inst, sol, enforce_zeta=False)


# ---------------------------------------------------------------------------
# AGH driver (sequential early-stop or deterministic parallel fan-out)
# ---------------------------------------------------------------------------

_PARALLEL_MIN_N = 24000     # auto fan-out only beyond (20,20,20)-class sizes


def _run_ordering(inst: Instance, order: np.ndarray, p1_snap: tuple, L: int,
                  batched: bool, ranked: list[np.ndarray] | None,
                  validate: bool, incremental: bool = True,
                  stats: dict | None = None) -> State:
    """Construction + improvement for one multi-start ordering."""
    _, st = greedy_heuristic(inst, order=order, phase1_snapshot=p1_snap)
    if batched:
        _improve_batched(st, L, validate, incremental=incremental,
                         stats=stats)
    else:
        _relocate(st, L, ranked, validate)
        _consolidate(st, validate)
    return st


def _warm_start_state(inst: Instance, incumbent: Solution, L: int,
                      batched: bool, ranked: list[np.ndarray] | None,
                      validate: bool, incremental: bool,
                      stats: dict | None = None) -> State:
    """The warm-start seed: re-route the NEW instance's demand over the
    incumbent's deployment (one Phase-2 pass — Phase 1's coverage search
    is what the incumbent already paid for), then run the configured
    improvement engine to a fixed point.  Replaces a full multi-start
    ordering at roughly one ordering's cost while typically starting at a
    much better objective than any cold construction.

    Under availability caps the incumbent may sit on capacity this
    instance no longer has (supply drift: revocations, outages) — those
    pairs are evicted first, as in `agh_repair`, so the seed is legal
    before any demand is routed onto it."""
    st = deployment_state(inst, incumbent)
    if inst.avail_gpus is not None:
        from .faults import lost_pairs
        for (j, k) in lost_pairs(inst, st.y):
            deactivate_pair(st, j, k)
    _phase2(st, np.argsort(-inst.lam))
    if batched:
        _improve_batched(st, L, validate, incremental=incremental,
                         stats=stats)
    else:
        _relocate(st, L, ranked, validate)
        _consolidate(st, validate)
    return st


def agh_repair(inst: Instance, incumbent: Solution, L: int = 1,
               local_search: str = "batched", validate: bool = False,
               stats: dict | None = None) -> Solution:
    """One-pass warm *repair* solve for a supply-faulted instance.

    The sub-second replan path behind `PlanSession.repair()`: no
    multi-start, no Phase-1 coverage search — the incumbent's structure
    is what the fleet is already running, so repair (1) seeds the state
    from the incumbent's deployment with routing cleared
    (`deployment_state` — the drain: displaced traffic is simply demand
    to re-route), (2) evicts every pair that no longer fits its tier's
    availability cap via `deactivate_pair` (rental refunded, admissions
    dropped), (3) re-routes ALL demand over the surviving deployment
    with one GH Phase-2 pass — the commit machinery's availability
    guards keep fresh activations inside the reduced caps — and (4)
    polishes with the configured improvement engine capped at `L`
    passes (default 1: latency beats the last percent of objective
    mid-incident).

    Like `agh`, the result is asserted feasible for the hard constraint
    system (zeta excluded — the unmet cap is the first rung of the
    planner's degradation ladder, reported there, never silently
    violated)."""
    t0 = time.perf_counter()
    from .faults import lost_pairs
    batched = local_search != "reference"
    incremental = local_search != "batched-rescan"
    st = deployment_state(inst, incumbent)
    evicted = lost_pairs(inst, st.y)
    for (j, k) in evicted:
        deactivate_pair(st, j, k)
    _phase2(st, np.argsort(-inst.lam))
    if batched:
        _improve_batched(st, L, validate, incremental=incremental,
                         stats=stats)
    else:
        _relocate(st, L, _rank_inactive_targets(inst), validate)
        _consolidate(st, validate)
    best = solution_from_state(inst, st)
    if stats is not None:
        stats.update(repair=True, evicted=[[j, k] for (j, k) in evicted],
                     repair_objective=state_objective(st))
    assert is_feasible(inst, best, enforce_zeta=False), \
        "repair produced an infeasible plan (incremental-state bug)"
    best.runtime_s = time.perf_counter() - t0
    best.method = "AGH-repair"
    return best


# Fork-shared work description for the multi-start pool: set in the parent
# immediately before the pool is created, inherited copy-on-write by the
# forked workers (no per-task pickling of the Instance tensors).
_FANOUT: dict = {}


def _fanout_worker(idx: int):
    inst = _FANOUT["inst"]
    st = _run_ordering(inst, _FANOUT["orders"][idx],
                       _FANOUT["p1"], _FANOUT["L"], _FANOUT["batched"],
                       _FANOUT["ranked"], _FANOUT["validate"],
                       _FANOUT["incremental"])
    # Materialize through the one shared materializer so the parallel and
    # sequential paths can never drift apart.
    return (idx, state_objective(st), solution_from_state(inst, st))


def _multi_start_parallel(inst: Instance, orders: list[np.ndarray],
                          p1_snap: tuple, L: int, batched: bool,
                          ranked: list[np.ndarray] | None, validate: bool,
                          workers: int, incremental: bool = True):
    """Evaluate every ordering (no early stop) and reduce deterministically.

    The reduction scans results in ordering-index order with the sequential
    driver's strict-improvement rule, so the returned solution is identical
    for any worker count — and never worse than the early-stop sequential
    protocol, which evaluates a prefix of the same orderings."""
    import multiprocessing as mp
    if workers > 1 and (mp.current_process().daemon
                        or "fork" not in mp.get_all_start_methods()):
        workers = 1     # pool unavailable here; same protocol inline
    _FANOUT.update(inst=inst, orders=orders, p1=p1_snap, L=L,
                   batched=batched, ranked=ranked, validate=validate,
                   incremental=incremental)
    try:
        if workers > 1:
            import concurrent.futures as cf
            from concurrent.futures.process import BrokenProcessPool
            ctx = mp.get_context("fork")
            try:
                with cf.ProcessPoolExecutor(max_workers=workers,
                                            mp_context=ctx) as ex:
                    results = list(ex.map(_fanout_worker,
                                          range(len(orders))))
            except (OSError, BrokenProcessPool):
                # Pool-infrastructure failure only (sandboxed spawn, killed
                # worker): same protocol inline — the deterministic
                # reduction makes the results identical.  Worker-side
                # algorithm errors propagate unchanged.
                results = [_fanout_worker(i) for i in range(len(orders))]
        else:
            results = [_fanout_worker(i) for i in range(len(orders))]
    finally:
        _FANOUT.clear()
    results.sort(key=lambda r: r[0])
    best, best_obj, best_idx = None, np.inf, -1
    for idx, obj, sol in results:
        if obj < best_obj - 1e-9:
            best, best_obj, best_idx = sol, obj, idx
    return best, best_obj, best_idx


def _auto_workers(inst: Instance, n_orders: int) -> int:
    """Fan out only where it wins: large instances on boxes with enough
    cores.  On <= 2 cores the pool's fork/IPC overhead plus the loss of
    early stopping (the parallel protocol evaluates every ordering) beats
    the speedup, measured end to end — so auto mode stays sequential
    there and `workers=` remains an explicit opt-in."""
    if inst.I * inst.J * inst.K < _PARALLEL_MIN_N:
        return 0
    cpus = os.cpu_count() or 1
    return 0 if cpus < 4 else min(cpus, n_orders, 8)


def agh(inst: Instance, R: int | None = None, L: int = 3, seed: int = 0,
        patience: int = 5, validate: bool = False,
        local_search: str = "batched",
        workers: int | None = None,
        warm_start: Solution | None = None,
        priority_orders: list[np.ndarray] | None = None,
        stats: dict | None = None) -> Solution:
    """Adaptive Greedy Heuristic.

    `local_search` picks the improvement engine: "batched" (default, the
    incremental scored-matrix engine — amortized destination tensors plus
    dirty-source tracking with a fallback full rescan before convergence),
    "batched-rescan" (the same engine with dirty-source tracking disabled:
    every sweep re-scores every source — the oracle the incremental mode
    is tested bit-equal against), or "reference" (the first-improvement
    probe loop, bit-identical to the frozen scalar seed path).  `workers`
    controls the multi-start driver: ``0`` forces
    the sequential early-stop protocol, ``n >= 1`` evaluates every ordering
    under the deterministic-reduction protocol (fanning out over ``n``
    forked processes when ``n > 1``; results are independent of ``n``), and
    ``None`` picks automatically — sequential below `_PARALLEL_MIN_N`,
    fan-out above it.

    `warm_start` seeds the multi-start from an incumbent solution (the
    `PlanSession.replan` path): the incumbent's deployment is re-routed
    under this instance's demand and improved, and that result enters the
    protocol as the starting best — the early-stop patience then counts
    non-improving orderings against a strong bound from the first
    ordering on.  ``R=0`` with a warm start is the fast-replan protocol:
    only the 8 deterministic orderings remain as challengers.

    `priority_orders` are extra Phase-2 orderings evaluated BEFORE the
    standard multi-start list.  `PlanSession` passes the ordering that
    produced the incumbent: the multi-start winner is empirically stable
    under workload drift, so replaying it recovers the cold run's best
    basin at one ordering's cost even when the warm seed's own basin has
    degraded.

    `stats`, when given, is filled in place with solver diagnostics
    (orderings evaluated, local-search moves applied, drains, fallback
    rescans, the winning ordering, warm-start provenance) — collected on
    the sequential driver; the parallel fan-out reports ordering counts
    and the winning ordering only.
    """
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    batched = local_search != "reference"
    incremental = local_search != "batched-rescan"
    if R is None:
        R = _adaptive_R(inst, batched=batched)
    orders = _orderings(inst, R, rng)
    if priority_orders:
        orders = [np.asarray(o) for o in priority_orders] + orders
    # Phase 1 is ordering-independent: run it once and share the snapshot
    # with every start (and every forked worker).
    st0 = State.fresh(inst)
    _phase1(st0)
    p1_snap = state_snapshot(st0)
    ranked = None if batched else _rank_inactive_targets(inst)
    if workers is None:
        workers = _auto_workers(inst, len(orders)) if batched else 0
    if stats is not None:
        stats.update(restarts=R, warm_started=warm_start is not None,
                     local_search=local_search)
    best, best_obj, best_order = None, np.inf, None
    if warm_start is not None:
        st = _warm_start_state(inst, warm_start, L, batched, ranked,
                               validate, incremental, stats=stats)
        best, best_obj = solution_from_state(inst, st), state_objective(st)
        if stats is not None:
            stats["warm_objective"] = best_obj
    if workers:
        par, par_obj, par_idx = _multi_start_parallel(
            inst, orders, p1_snap, L, batched, ranked, validate, workers,
            incremental=incremental)
        # Same strict-improvement rule as the sequential reduction: the
        # warm seed came first, so it wins ties.
        if par_obj < best_obj - 1e-9:
            best, best_obj = par, par_obj
            best_order = orders[par_idx]
        if stats is not None:
            stats["orderings_evaluated"] = len(orders)
    else:
        stale = 0
        evaluated = 0
        for order in orders:
            st = _run_ordering(inst, order, p1_snap, L, batched, ranked,
                               validate, incremental=incremental,
                               stats=stats)
            evaluated += 1
            obj = state_objective(st)
            if obj < best_obj - 1e-9:
                best, best_obj = solution_from_state(inst, st), obj
                best_order = order
                stale = 0
            else:
                stale += 1
                if stale >= patience:
                    break
        if stats is not None:
            stats["orderings_evaluated"] = evaluated
            stats["early_stopped"] = evaluated < len(orders)
    if stats is not None:
        # The ordering whose basin won (None when the warm seed held) —
        # `PlanSession` replays it on the next replan.
        stats["winning_order"] = (None if best_order is None
                                  else [int(i) for i in best_order])
    assert best is not None
    # Final check: the delta-maintained state must stand up to the full
    # constraint system (cheap — once per AGH call, not per move).
    assert is_feasible(inst, best, enforce_zeta=False), \
        "AGH produced an infeasible solution (incremental-state bug)"
    best.runtime_s = time.perf_counter() - t0
    best.method = "AGH"
    return best
