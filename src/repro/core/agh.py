"""Adaptive Greedy Heuristic (AGH) — paper Algorithm 2.

Enhancements over GH:
  * multi-start construction: 8 deterministic orderings (ascending/descending
    each of lambda_i, phi_i, per-type weight-footprint proxy, and error
    tightness eps_i) plus R adaptive random permutations (Remark 2:
    R = 3 / 5 / 10 / 20 by problem scale N = I*J*K), early stop after five
    consecutive non-improving orderings;
  * relocate local search (L = 3 passes): move committed (i,j,k) fractions to
    alternative pairs when feasible and strictly improving;
  * consolidation: drain lightly loaded active pairs onto other active pairs
    and deactivate them when feasible and strictly improving.
"""
from __future__ import annotations

import time

import numpy as np

from .gh import greedy_heuristic
from .instance import Instance
from .mechanisms import State, commit, m1_select, max_commit
from .solution import Solution, is_feasible, objective


def _orderings(inst: Instance, R: int, rng: np.random.Generator) -> list[np.ndarray]:
    lam, phi, eps = inst.lam, inst.phi, inst.eps
    # Per-type weight-footprint proxy: smallest model whose FP16 error meets
    # the type's SLO ("B_j as it appears for that type").
    bproxy = np.empty(inst.I)
    for i in range(inst.I):
        ok = np.where(inst.e_base[i] <= inst.eps[i])[0]
        bproxy[i] = inst.B[ok].min() if len(ok) else inst.B.max()
    keys = [lam, phi, bproxy, eps]
    orders = []
    for key in keys:
        orders.append(np.argsort(key))
        orders.append(np.argsort(-key))
    for _ in range(R):
        orders.append(rng.permutation(inst.I))
    return orders


def _adaptive_R(inst: Instance) -> int:
    N = inst.I * inst.J * inst.K
    if N > 5000:
        return 3
    if N > 2000:
        return 5
    if N > 500:
        return 10
    return 20


# ---------------------------------------------------------------------------
# Local search
# ---------------------------------------------------------------------------

def _rebuild_state(inst: Instance, sol: Solution) -> State:
    st = State.fresh(inst)
    st.x = sol.x.copy()
    st.y = sol.y.copy()
    st.q = sol.q.copy()
    st.z = sol.z.copy()
    st.cfg = np.where(sol.q > 0.5, np.argmax(sol.w, axis=2), -1)
    st.r_rem = np.clip(1.0 - sol.x.sum(axis=(1, 2)), 0.0, None)
    st.E_used = np.einsum("ijk,ijk->i", inst.e_bar, sol.x)
    xw = sol.x[:, :, :, None] * sol.w[None, :, :, :]
    st.D_used = np.einsum("ijkc,ijkc->i", xw, inst.D_cfg)
    from .instance import KB_PER_GB
    data = inst.Delta_T * inst.p_s * float(np.sum(
        inst.theta[:, None, None] / KB_PER_GB * inst.r[:, None, None]
        * inst.lam[:, None, None] * sol.x))
    st.spend = (inst.Delta_T * float(np.sum(inst.p_c[None, :] * sol.y))
                + inst.Delta_T * inst.p_s * float(np.sum(inst.B[None, :, None] * sol.z))
                + data)
    st.uncovered = set()
    return st


def _solution_from_state(inst: Instance, st: State) -> Solution:
    sol = Solution.empty(inst)
    sol.x, sol.y, sol.q, sol.z = st.x, st.y, st.q, st.z
    sol.u = np.clip(st.r_rem, 0.0, None)
    for j in range(inst.J):
        for k in range(inst.K):
            if st.q[j, k] > 0.5 and st.cfg[j, k] >= 0:
                sol.w[j, k, int(st.cfg[j, k])] = 1.0
    return sol


def _try_move(inst: Instance, sol: Solution, i: int, j: int, k: int,
              j2: int, k2: int, best_obj: float) -> Solution | None:
    """Move all of x[i,j,k] to (j2,k2); accept if feasible & improving."""
    frac = sol.x[i, j, k]
    trial = sol.copy()
    trial.x[i, j, k] = 0.0
    trial.z[i, j, k] = 0.0
    # Deactivate (j,k) if nothing else uses it.
    if trial.x[:, j, k].sum() <= 1e-12:
        trial.q[j, k] = 0.0
        trial.y[j, k] = 0.0
        trial.w[j, k, :] = 0.0
        trial.z[:, j, k] = 0.0
    st = _rebuild_state(inst, trial)
    if st.q[j2, k2] > 0.5:
        c = int(st.cfg[j2, k2])
        if inst.D_cfg[i, j2, k2, c] > inst.Delta[i]:
            return None
    else:
        c = m1_select(inst, i, j2, k2)
        if c is None:
            return None
    if max_commit(st, i, j2, k2, c) < frac - 1e-9:
        return None
    commit(st, i, j2, k2, c, frac)
    cand = _solution_from_state(inst, st)
    if not is_feasible(inst, cand, enforce_zeta=False):
        return None
    if objective(inst, cand) < best_obj - 1e-9:
        return cand
    return None


def _move_targets(inst: Instance, sol: Solution, i: int,
                  n_inactive: int = 3) -> list[tuple[int, int]]:
    """Candidate destinations for relocating type i: every ACTIVE pair plus
    the few cheapest inactive pairs that pass M1 for this type. (The paper
    scans all (j', k'); restricting to this set is what keeps the pure-
    Python relocate within the paper's runtime envelope — the optimum of
    a move almost always shares or cheaply activates.)"""
    active = [(j, k) for j in range(inst.J) for k in range(inst.K)
              if sol.q[j, k] > 0.5]
    inactive = []
    for j in range(inst.J):
        for k in range(inst.K):
            if sol.q[j, k] > 0.5:
                continue
            c = m1_select(inst, i, j, k)
            if c is None or inst.e_bar[i, j, k] > inst.eps[i]:
                continue
            inactive.append((inst.p_c[k] * inst.nm[c], j, k))
    inactive.sort()
    return active + [(j, k) for _, j, k in inactive[:n_inactive]]


def _relocate(inst: Instance, sol: Solution, L: int) -> Solution:
    for _ in range(L):
        improved = False
        obj = objective(inst, sol)
        for i in range(inst.I):
            assigned = [(j, k) for j in range(inst.J) for k in range(inst.K)
                        if sol.x[i, j, k] > 1e-9]
            for (j, k) in assigned:
                for (j2, k2) in _move_targets(inst, sol, i):
                    if (j2, k2) == (j, k):
                        continue
                    cand = _try_move(inst, sol, i, j, k, j2, k2, obj)
                    if cand is not None:
                        sol = cand
                        obj = objective(inst, sol)
                        improved = True
                        break
        if not improved:
            break
    return sol


def _consolidate(inst: Instance, sol: Solution) -> Solution:
    """Drain lightly loaded pairs onto other active pairs (Alg. 2 l.10–12)."""
    while True:
        active = [(float(sol.y[j, k]), j, k)
                  for j in range(inst.J) for k in range(inst.K)
                  if sol.q[j, k] > 0.5]
        active.sort()
        improved = False
        for _, j, k in active:
            types = [i for i in range(inst.I) if sol.x[i, j, k] > 1e-9]
            trial = sol.copy()
            obj = objective(inst, sol)
            ok = True
            for i in types:
                frac = trial.x[i, j, k]
                trial.x[i, j, k] = 0.0
                trial.z[i, j, k] = 0.0
                st = _rebuild_state(inst, trial)
                st.q[j, k] = 0.0  # forbid re-landing on the pair being drained
                placed = False
                for j2 in range(inst.J):
                    for k2 in range(inst.K):
                        if (j2, k2) == (j, k) or st.q[j2, k2] < 0.5:
                            continue
                        c = int(st.cfg[j2, k2])
                        if inst.D_cfg[i, j2, k2, c] > inst.Delta[i]:
                            continue
                        if max_commit(st, i, j2, k2, c) >= frac - 1e-9:
                            commit(st, i, j2, k2, c, frac)
                            trial = _solution_from_state(inst, st)
                            placed = True
                            break
                    if placed:
                        break
                if not placed:
                    ok = False
                    break
            if not ok:
                continue
            trial.q[j, k] = 0.0
            trial.y[j, k] = 0.0
            trial.w[j, k, :] = 0.0
            trial.z[:, j, k] = 0.0
            if (is_feasible(inst, trial, enforce_zeta=False)
                    and objective(inst, trial) < obj - 1e-9):
                sol = trial
                improved = True
                break
        if not improved:
            return sol


# ---------------------------------------------------------------------------
# AGH driver
# ---------------------------------------------------------------------------

def agh(inst: Instance, R: int | None = None, L: int = 3, seed: int = 0,
        patience: int = 5) -> Solution:
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    if R is None:
        R = _adaptive_R(inst)
    best: Solution | None = None
    best_obj = np.inf
    stale = 0
    for order in _orderings(inst, R, rng):
        sol, _ = greedy_heuristic(inst, order=order)
        sol = _relocate(inst, sol, L)
        sol = _consolidate(inst, sol)
        obj = objective(inst, sol)
        if obj < best_obj - 1e-9:
            best, best_obj = sol, obj
            stale = 0
        else:
            stale += 1
            if stale >= patience:
                break
    assert best is not None
    best.runtime_s = time.perf_counter() - t0
    best.method = "AGH"
    return best
