"""Shared demand-forecast and replan-trigger primitives.

One implementation of the EWMA forecast (paper §5.3's rolling-horizon
predictor) and of the drift measure that decides when a forecast has
moved far enough to justify a replan — consumed by BOTH the offline
rolling-horizon replay (`core.rolling`) and the closed-loop serving
controller (`repro.serving.controller`), so the two layers can never
disagree about what "the forecast" or "drift" means.

* `ewma_forecasts` — the whole-path batch form used by `rolling()`
  (forecasts precomputed before the replay loop runs);
* `EwmaForecaster` — the streaming form used by the serving driver
  (one `update()` per observed window, same recursion, same seeding);
* `relative_drift` — demand-weighted relative L1 distance between two
  arrival-rate vectors.  Demand-weighted so a fleet-scale population's
  tiny types cannot trigger replans on their own noise, while a drift of
  the dominant types registers at its true magnitude;
* `DriftTrigger` — the replan trigger state machine: fires when forecast
  drift since the last replan crosses a threshold OR an observed
  SLO-violation budget is breached for enough consecutive windows,
  subject to a warmup and a cooldown.  This is the controller PR 5 left
  open ("replace the blind `replan_every` cadence").
"""
from __future__ import annotations

import dataclasses

import numpy as np


def ewma_forecasts(lam_path: np.ndarray, alpha: float) -> np.ndarray:
    """Stacked EWMA forecasts: fc[t] = a·lam[t] + (1-a)·fc[t-1], seeded at
    lam[0] — fc[t] is the forecast available AFTER observing window t."""
    fc = np.empty_like(lam_path)
    prev = lam_path[0].copy()
    for t in range(lam_path.shape[0]):
        prev = alpha * lam_path[t] + (1.0 - alpha) * prev
        fc[t] = prev
    return fc


def relative_drift(lam: np.ndarray, lam_ref: np.ndarray,
                   floor: float = 1e-12) -> float:
    """Demand-weighted relative L1 drift of `lam` against `lam_ref`:
    sum|lam - ref| / max(sum ref, floor).  0 = identical; 0.25 = the
    aggregate arrival rate has moved by 25% of the reference volume."""
    lam = np.asarray(lam, float)
    lam_ref = np.asarray(lam_ref, float)
    return float(np.sum(np.abs(lam - lam_ref))
                 / max(float(np.sum(lam_ref)), floor))


@dataclasses.dataclass
class EwmaForecaster:
    """Streaming EWMA over per-window observed arrival rates.

    Seeded at the plan-basis rates so the forecast starts exactly where
    the deployed plan assumed demand to be — the first observed windows
    then pull it toward reality at rate `alpha`, matching the recursion
    of `ewma_forecasts` element for element.
    """
    alpha: float
    forecast: np.ndarray

    def __post_init__(self) -> None:
        self.forecast = np.asarray(self.forecast, float).copy()

    def update(self, lam_obs: np.ndarray) -> np.ndarray:
        self.forecast = (self.alpha * np.asarray(lam_obs, float)
                         + (1.0 - self.alpha) * self.forecast)
        return self.forecast


@dataclasses.dataclass
class DriftTrigger:
    """Forecast-aware replan trigger.

    `observe(window, drift, viol_frac)` returns the trigger cause
    (``"drift"`` / ``"slo"``) when a replan is justified, else None:

    * **drift** — the forecast has moved more than `drift_threshold`
      (relative_drift units) away from the rates the incumbent plan was
      built for;
    * **slo**  — the observed per-window SLO-violation fraction exceeded
      `violation_budget` for `budget_windows` consecutive windows (one
      bad window is noise; a streak is a capacity problem).

    `warmup` windows are trigger-free (the forecast needs observations
    before drift is meaningful); after every adopted replan the caller
    invokes `fired(window)`, which re-arms the `cooldown` — no two
    replans closer than `cooldown` windows, so a breach that a replan
    cannot fix (e.g. a calibration gap) cannot ring the planner
    continuously.
    """
    drift_threshold: float = 0.25
    violation_budget: float = 0.05
    budget_windows: int = 2
    cooldown: int = 4
    warmup: int = 2
    _breach_streak: int = 0
    _last_fire: int = -(1 << 30)

    def observe(self, window: int, drift: float,
                viol_frac: float) -> str | None:
        if viol_frac > self.violation_budget:
            self._breach_streak += 1
        else:
            self._breach_streak = 0
        if window < self.warmup or window - self._last_fire < self.cooldown:
            return None
        if drift > self.drift_threshold:
            return "drift"
        if self._breach_streak >= self.budget_windows:
            return "slo"
        return None

    def fired(self, window: int) -> None:
        self._last_fire = window
        self._breach_streak = 0
