"""Mutation contracts for the allocation engine's shared state objects.

The engine's bit-identity guarantees (scalar ref == numpy == xla,
incremental DestCache == always-rescan) hold only if `State` and
`DestCache` fields are written exclusively by a small, known set of
mutators whose effects the undo log and the cache invalidation protocol
account for.  `@mutates("q", "cfg", ...)` declares that write-set on the
mutator itself:

* at runtime the decorator is a no-op (zero overhead on the hot path) —
  it only records the declared field names on ``fn.__mutates__``;
* statically, ``repro.analysis.lint`` reads the decorator from the AST:
  a write to a State/DestCache field outside a decorated mutator is
  RPR101, a write the decorator does not declare is RPR102, and a
  declared field the body never writes is RPR103.

The decorator is deliberately dumb: no wrapping, no signature changes,
no introspection of the target — `fn` comes back the same object, so
jit, pickling for process pools, and `functools.partial` all see the
undecorated function.
"""
from __future__ import annotations

from typing import Callable, TypeVar

F = TypeVar("F", bound=Callable)


def mutates(*fields: str) -> Callable[[F], F]:
    """Declare the exact State/DestCache fields a mutator may write.

    ``fields`` are attribute names (``"q"``, ``"cfg_dirty"``, ...).  The
    declaration is the *complete* write-set: the static checker flags
    both undeclared writes and unused declarations, so the decorator
    stays an accurate, machine-checked piece of documentation.
    """
    if not fields:
        raise ValueError("@mutates needs at least one field name")
    for f in fields:
        if not (isinstance(f, str) and f.isidentifier()):
            raise ValueError(f"@mutates field names must be identifiers, "
                             f"got {f!r}")
    declared = frozenset(fields)

    def mark(fn: F) -> F:
        fn.__mutates__ = declared  # type: ignore[attr-defined]
        return fn

    return mark
