"""XLA-batched allocator engine — lazy, jax-free entry point.

This package hosts the ``engine="xla"`` tier of the allocator: the hot
numeric core of GH Phase-2 ranking and the local search's candidate
screen run as jitted XLA programs over device-resident instance tensors,
with every multi-start ordering evaluated in lockstep as a batch lane.
The numpy engine (`core.agh.agh`) remains the bit-exact oracle and the
default; this tier must only ever match or beat its objective (enforced
by tests/test_engine_xla.py).

Importing *this* module never imports jax — the heavy modules
(`tensors`, `kernels`, `engine`) load on first use via `load_engine()`,
so ``from repro import plan`` stays jax-free unless ``engine="xla"`` is
actually requested.
"""
from __future__ import annotations


class EngineUnavailableError(RuntimeError):
    """Raised when ``engine="xla"`` is requested but jax is not importable.

    Carries an actionable message naming the missing extra, so callers on
    jax-free hosts see exactly what to install rather than a bare
    ModuleNotFoundError from deep inside the registry adapter.
    """


def load_engine():
    """Import and return the XLA engine module (`repro.core.xla.engine`).

    The import happens here, not at package import, so jax is only paid
    for when the xla tier is requested.  Raises `EngineUnavailableError`
    with install guidance when jax is absent.
    """
    try:
        from . import engine
    except ImportError as exc:
        raise EngineUnavailableError(
            "engine='xla' requires jax, which is not installed in this "
            "environment. Install the accelerator extra (pip install "
            "jax) or use the default engine='numpy'."
        ) from exc
    return engine


def agh_xla(*args, **kwargs):
    """Convenience delegate to `repro.core.xla.engine.agh_xla` (lazy)."""
    return load_engine().agh_xla(*args, **kwargs)


__all__ = ["EngineUnavailableError", "load_engine", "agh_xla"]
