"""The ``engine="xla"`` AGH driver: host-orchestrated, device-scored.

Multi-start becomes a batched lane axis.  Every ordering (plus the warm
seed, when given) gets its own numpy `State` lane; the lanes advance in
lockstep and the expensive grid arithmetic of each step — GH Phase-2's
M2 ranking keys and the local search's relocate screen — runs as one
jitted XLA call over all lanes at once (`core/xla/kernels.py`), against
instance tensors resident on the device (`core/xla/tensors.py`).  All
state mutation stays on the host and goes through the numpy engine's own
exact machinery (`commit`, `remove_assignment`, `score_moves_batch`,
`_try_drain_batched`), which is what anchors the <=-objective contract:

* Phase 2 runs the exact `_phase2_walk` per lane; only the walk's input
  keys come from the device, computed by the same formulas as
  `rank_keys_all` in float64 (active-cell overrides are computed on the
  host with exact numpy arithmetic and scattered in).
* The relocate sweep batch-screens its dirty sources on the device at
  sweep-start state; a source that fails the screen — a sound
  over-approximation of `score_moves_batch`'s improvement and cap-bound
  filters, with slack absorbing XLA fusion ulps — is marked clean
  without the exact scan, but only while the sweep has applied no move
  (until then the screened state IS the live state, so a trusted clean
  is exactly a live scan's conclusion; the first move invalidates all
  remaining verdicts and the sweep falls back to exact scans).  Clean
  marking is therefore identical to the numpy engine's dirty-source
  protocol and each lane's descent is bit-identical to the numpy
  lane's; every applied move is exact-validated strictly improving, so
  descent is monotone, and the terminating verification rescan (no
  moves => all verdicts computed at the true fixed point) guarantees no
  improving move is missed.  A cost-aware gate measures device vs
  host-scan time online and bypasses the screen (all-True verdicts =
  plain numpy protocol, same results) whenever it cannot pay — e.g. on
  1-core hosts where the kernel is memory-bound at host-scan cost.
* Construction runs on every lane; improvement runs in lane-order waves
  with the sequential early-stop rule replayed between waves, so the
  improved prefix is always a superset of the sequential driver's
  evaluated set.  The reduction scans that prefix in ordering-index
  order with the strict-improvement rule — never worse than the
  sequential early-stop protocol it replaces.

The numpy engine remains the default and the oracle:
tests/test_engine_xla.py holds this engine to objective <= numpy's
(within float-reassociation tolerance) on the whole equivalence suite,
with feasibility checked by the frozen scalar path.
"""
from __future__ import annotations

import time

import numpy as np

from ..agh import (_adaptive_R, _assert_state_consistent,
                   _consolidate_batched, _invalidate_sources, _orderings)
from ..gh import _phase1, _phase2_prep, _phase2_walk
from ..instance import Instance
from ..mechanisms import (DestCache, State, commit, deployment_state,
                          remove_assignment, removal_terms,
                          score_moves_batch, solution_from_state,
                          state_objective, state_restore, state_snapshot)
from ..solution import Solution, is_feasible
from . import kernels
from .tensors import tensors_for

# Source-chunk caps for one screen call: bounded transient [S, J*K]
# buffers; the smaller cap kicks in when the active-cell axis is wide.
_SCREEN_CHUNK = 4096
_SCREEN_CHUNK_WIDE = 1024


class _Lane:
    """One multi-start ordering's host state inside the lockstep batch."""

    __slots__ = ("st", "order", "is_warm", "cache", "clean", "active",
                 "jj", "kk")

    def __init__(self, st: State, order: np.ndarray, is_warm: bool = False):
        self.st = st
        self.order = order
        self.is_warm = is_warm
        self.cache: DestCache | None = None
        self.clean: set | None = None
        self.active: np.ndarray | None = None
        self.jj: np.ndarray | None = None
        self.kk: np.ndarray | None = None


def _chunked(seq, width):
    if not width or width >= len(seq):
        yield seq
        return
    for i in range(0, len(seq), width):
        yield seq[i:i + width]


# ---------------------------------------------------------------------------
# Phase 2 in lockstep: device keys, exact host walk
# ---------------------------------------------------------------------------

def _phase2_item(ln: _Lane, i: int, c_arr: np.ndarray,
                 d_sel: np.ndarray) -> tuple:
    """Kernel inputs for one lane at type `i`: the type-local scalars and
    the active-cell override vectors, computed with the exact elementwise
    numpy arithmetic of `rank_keys_all` restricted to the active cells."""
    st = ln.st
    inst = st.inst
    jj, kk = ln.jj, ln.kk
    cc = c_arr[jj, kk]
    ccl = np.maximum(cc, 0)
    d_a = d_sel[jj, kk]
    inc = np.maximum(0.0, inst.nm[ccl] - st.y[jj, kk])
    cost_a = (inst.Delta_T * (inst.p_c[kk] * inc
                              + inst.p_s * (inst.B[jj] + inst.data_gb[i]))
              + inst.rho[i] * d_a * 1e3)
    return (i, st.y.reshape(-1), float(st.r_rem[i]), float(st.E_used[i]),
            float(st.D_used[i]), jj * inst.K + kk, cost_a, d_a, cc >= 0)


def _phase2_lockstep(lanes: list[_Lane], tx, batch_width: int | None,
                     counters: dict) -> None:
    inst = lanes[0].st.inst
    for ln in lanes:
        ln.active = ln.st.q > 0.5
        ln.jj, ln.kk = np.nonzero(ln.active)
    for t in range(inst.I):
        for chunk in _chunked(lanes, batch_width):
            preps = []
            for ln in chunk:
                i = int(ln.order[t])
                c_arr, d_sel = _phase2_prep(ln.st, i, ln.active, ln.jj,
                                            ln.kk)
                preps.append((i, c_arr, d_sel))
            items = [_phase2_item(ln, i, c_arr, d_sel)
                     for ln, (i, c_arr, d_sel) in zip(chunk, preps, strict=True)]
            kap0, kap1 = kernels.phase2_keys(tx, items, counters)
            for r, ln in enumerate(chunk):
                i, c_arr, _ = preps[r]
                ln.jj, ln.kk = _phase2_walk(ln.st, i, c_arr, kap0[r],
                                            kap1[r], ln.active, ln.jj,
                                            ln.kk)


# ---------------------------------------------------------------------------
# Improvement in lockstep: generator-per-lane, batched screen
# ---------------------------------------------------------------------------

def _relocate_screened(st: State, L: int, validate: bool,
                       cache: DestCache, clean: set | None,
                       counters: dict, rescan: bool = False):
    """`_relocate_batched(fallback=False)` with the dirty-source scans
    gated by a device screen: a generator that yields
    ``("screen", obj, sources, rescan)`` once per sweep and receives the
    verdict list.  A screen-fail verdict is trusted — the source marked
    clean without the exact scan — only while this sweep has applied NO
    move: until then the sweep-start state the screen evaluated IS the
    live state, so a trusted clean is exactly what a live
    `score_moves_batch` scan would conclude.  The first applied move
    invalidates every remaining verdict (an applied move vacates load at
    its source cell, which can bring destinations alive for sources the
    sweep-start screen proved dead), and the rest of the sweep falls
    back to exact live scans.  Clean-marking is therefore identical to
    the numpy engine's and each lane's descent trajectory is
    bit-identical to `_relocate_batched`'s for the same ordering — the
    driver may answer any request with all-True verdicts (= screen off)
    without changing results, only the time split.  Returns (via
    StopIteration) whether any move was applied."""
    inst = st.inst
    K = inst.K
    track = clean is not None
    improving = 0
    any_improved = False
    while True:
        improved = False
        skipped = False
        obj = state_objective(st)
        screen_fail: set = set()
        # One sweep-start enumeration in (type, cell) row-major order —
        # the same order the per-type flatnonzero walk would visit.
        ii, ff = np.nonzero(st.x.reshape(inst.I, -1) > 1e-9)
        all_sources = [(int(i), int(f) // K, int(f) % K)
                       for i, f in zip(ii, ff, strict=True)]
        if track:
            sources = [s for s in all_sources if s not in clean]
            if sources:
                verdicts = yield ("screen", obj, sources, rescan)
                screen_fail = {s for s, ok in zip(sources, verdicts, strict=True)
                               if not ok}
        stats_bucket = None
        if "_screen_stats" in counters:
            stats_bucket = counters["_screen_stats"][
                "rescan" if rescan else "regular"]
        for (i, j, k) in all_sources:
            if st.x[i, j, k] <= 1e-9:   # merged away earlier this sweep
                continue
            if track and (i, j, k) in clean:
                skipped = True
                continue
            if (i, j, k) in screen_fail and not improved:
                clean.add((i, j, k))
                counters["screened_clean"] = \
                    counters.get("screened_clean", 0) + 1
                if stats_bucket is not None:
                    stats_bucket[1] += 1
                continue
            t0 = time.perf_counter()
            ms = score_moves_batch(st, i, j, k,
                                   improve_below=obj - 1e-9,
                                   cache=cache, obj_cur=obj)
            counters["scans"] = counters.get("scans", 0) + 1
            counters["scan_s"] = (counters.get("scan_s", 0.0)
                                  + time.perf_counter() - t0)
            if not ms.admissible.any():
                if track:
                    clean.add((i, j, k))
                continue
            flat = int(np.argmin(ms.obj_after))
            j2, k2 = flat // K, flat % K
            remove_assignment(st, i, j, k)
            commit(st, i, j2, k2, int(ms.c_dest[j2, k2]), ms.frac)
            obj = state_objective(st)
            improved = True
            counters["moves_applied"] = \
                counters.get("moves_applied", 0) + 1
            cache.invalidate_type(i)
            if track and clean:
                cells = set()
                if np.count_nonzero(st.x[:, j, k] > 1e-9) == 1:
                    cells.add((j, k))
                _invalidate_sources(clean, i, cells)
            if validate:
                _assert_state_consistent(st)
        any_improved |= improved
        if improved:
            improving += 1
            if improving >= L:
                break
        else:
            # No fallback rescan here (the caller's verification rescan
            # covers it); `skipped` sweeps end like non-tracking ones.
            del skipped
            break
    return any_improved


def _improve_lane(ln: _Lane, L: int, validate: bool, counters: dict):
    """`_improve_batched` as a generator: relocate/consolidate to the
    joint fixed point, then one verification rescan (fresh screens —
    the clean set is cleared, so every source is re-screened against the
    current state)."""
    st, cache, clean = ln.st, ln.cache, ln.clean
    while True:
        yield from _relocate_screened(st, L, validate, cache, clean,
                                      counters)
        if _consolidate_batched(st, validate, cache, clean,
                                stats=counters):
            continue
        if not (clean is not None and clean):
            return
        clean.clear()
        counters["rescans"] = counters.get("rescans", 0) + 1
        moved = yield from _relocate_screened(st, L, validate, cache,
                                              clean, counters,
                                              rescan=True)
        if not moved:
            return
        _consolidate_batched(st, validate, cache, clean, stats=counters)


# Cost-aware adaptive screen policy.  Screening a source is profitable
# exactly when the device time it costs is below the host-scan time its
# expected TRUSTED clean verdict saves (verdicts after a sweep's first
# applied move are discarded, so only trusted cleans save a scan):
#
#     dev_s / screened  <=  trusted_rate * scan_s / scans
#
# All four quantities are measured online (the kernel wall clock and the
# exact `score_moves_batch` wall clock accumulate in the solve's
# counters), so the gate self-tunes per host: on a many-core box the
# threaded XLA kernel amortizes far below the per-source scan cost and
# the screen stays on; on a 1-core CI container the kernel is
# memory-bound at roughly scan cost and no clean rate can justify it, so
# the screen shuts off after warmup and the sweep degrades to the plain
# numpy dirty-source protocol.  Clean rates differ sharply between
# regular sweeps (early sweeps: most sources genuinely move) and
# verification rescans (fixed point: almost nothing moves), so the two
# are gated as separate buckets.  The verdict set never changes results
# — a bypassed request just scans exactly — only where the time goes.
_SCREEN_WARMUP = 64


def _screen_worthwhile(counters: dict, bucket: list) -> bool:
    """The cost-aware gate: device cost per screened source vs the scan
    time a trusted clean verdict saves, at this bucket's observed
    trusted-clean rate."""
    shots, trusted = bucket
    if shots < _SCREEN_WARMUP:
        return True
    screened = counters.get("screen_sources", 0)
    scans = counters.get("scans", 0)
    if not screened or not scans:
        return True
    dev_per_src = counters.get("screen_s", 0.0) / screened
    scan_per_src = counters.get("scan_s", 0.0) / scans
    return dev_per_src <= (trusted / shots) * scan_per_src


def _screen_batch(tx, requests: list[tuple], load: np.ndarray,
                  counters: dict) -> list[np.ndarray]:
    """Serve a batch of screen requests — one per lane — with as few
    padded kernel calls as possible.

    ``requests[r] = (lane_idx, st, obj, sources, rescan)``.  Builds one
    (lane, type) group row per distinct source type and the per-source
    closed-form removal scalars (`removal_terms` — the same values the
    exact scan consumes), chunks the stacked source list, and returns
    one verdict array per request.  Requests skipped by the cost-aware
    policy get all-True verdicts (screen off = the plain numpy
    dirty-source protocol)."""
    inst = tx.inst
    K = inst.K
    groups: list[tuple] = []
    gidx: dict[tuple, int] = {}
    srcs: list[tuple] = []
    src_req: list[tuple] = []
    lane_act: dict[int, tuple] = {}
    screen_stats = counters.get("_screen_stats")
    for r, (lane_idx, st, obj, sources, rescan) in enumerate(requests):
        if screen_stats is not None:
            bucket = screen_stats["rescan" if rescan else "regular"]
            if not _screen_worthwhile(counters, bucket):
                counters["screen_bypassed"] = \
                    counters.get("screen_bypassed", 0) + len(sources)
                continue    # all-True verdicts: every source scans exactly
            bucket[0] += len(sources)
        load[lane_idx] = st.load.reshape(-1)
        if lane_idx not in lane_act:
            jj, kk = np.nonzero(st.cfg >= 0)
            lane_act[lane_idx] = (jj, kk, jj * K + kk,
                                  inst.nm[st.cfg[jj, kk]].astype(float))
        jj, kk, a_jk, a_nm = lane_act[lane_idx]
        # The screen relaxes the exact filters by `slack` so device-side
        # fusion ulps can only ever add false passes, never false fails.
        slack = 1e-6 * max(1.0, abs(obj))
        for n, (i, j, k) in enumerate(sources):
            key = (lane_idx, i)
            g = gidx.get(key)
            if g is None:
                c_act = st.cfg[jj, kk]
                d_act = inst.D_cfg[i, jj, kk, c_act]
                g = gidx[key] = len(groups)
                groups.append((lane_idx, i,
                               (st.z[i] < 0.5).reshape(-1), a_jk, a_nm,
                               d_act, d_act <= inst.Delta[i]))
            rt = removal_terms(st, i, j, k)
            base = obj - rt.gain + inst.Delta_T * (inst.p_s * rt.data)
            rr2, e2, d2 = rt.over[0], rt.over[1], rt.over[2]
            srcs.append((g, j * K + k,
                         float(inst.rho[i]) * 1e3 * rt.frac,
                         (obj - 1e-9) - base + slack,
                         rr2, inst.eps[i] - e2, inst.Delta[i] - d2,
                         rt.frac - 1e-9 - slack))
            src_req.append((r, n))
    out = [np.ones(len(req[3]), dtype=bool) for req in requests]
    a_max = max((g[3].shape[0] for g in groups), default=0)
    chunk = _SCREEN_CHUNK if a_max <= 1024 else _SCREEN_CHUNK_WIDE
    for lo in range(0, len(srcs), chunk):
        part = srcs[lo:lo + chunk]
        # Re-index this chunk's groups compactly so the group axis stays
        # inside its bucket.
        remap: dict[int, int] = {}
        sub_groups: list[tuple] = []
        sub_srcs: list[tuple] = []
        for s in part:
            g = s[0]
            ng = remap.get(g)
            if ng is None:
                ng = remap[g] = len(sub_groups)
                sub_groups.append(groups[g])
            sub_srcs.append((ng,) + s[1:])
        t0 = time.perf_counter()
        alive = kernels.screen_sources(tx, sub_groups, sub_srcs, load,
                                       counters)
        counters["screen_s"] = (counters.get("screen_s", 0.0)
                                + time.perf_counter() - t0)
        for (r, n), v in zip(src_req[lo:lo + chunk], alive, strict=True):
            out[r][n] = bool(v)
    return out


def _improve_wave(wave: list[_Lane], offset: int, tx, L: int,
                  validate: bool, incremental: bool, counters: dict,
                  load: np.ndarray) -> None:
    """Run the improvement loop of one wave of lanes in lockstep.

    ``offset`` is the wave's position in the full lane list — lane
    indices into the (solve-constant) ``load`` buffer stay global so the
    screen kernel's compiled shape never changes between waves."""
    pending: list[tuple] = []
    for idx, ln in enumerate(wave):
        ln.cache = DestCache(ln.st)
        ln.clean = set() if incremental else None
        gen = _improve_lane(ln, L, validate, counters)
        try:
            req = gen.send(None)
            pending.append((offset + idx, ln, gen, req))
        except StopIteration:
            pass
    while pending:
        requests = [(idx, ln.st, req[1], req[2], req[3])
                    for idx, ln, gen, req in pending]
        verdicts = _screen_batch(tx, requests, load, counters)
        nxt = []
        for (idx, ln, gen, _), v in zip(pending, verdicts, strict=True):
            try:
                req = gen.send(v)
                nxt.append((idx, ln, gen, req))
            except StopIteration:
                pass
        pending = nxt


def _improve_lockstep(lanes: list[_Lane], tx, L: int, validate: bool,
                      incremental: bool, patience: int, counters: dict,
                      batch_width: int | None = None) -> int:
    """Improve lanes in lane-order waves with the sequential early-stop
    rule replayed between waves.

    The numpy sequential driver improves orderings one at a time and
    stops after `patience` consecutive non-improvers; improving a whole
    wave before checking means the evaluated set here is always a
    SUPERSET of the sequential driver's prefix, so the final reduction
    can only match or beat it — while lanes past the stop point skip
    their (dominant-cost) local search entirely.  Returns the number of
    lanes improved; the caller must reduce over exactly that prefix."""
    inst = lanes[0].st.inst
    load = np.zeros((len(lanes), inst.J * inst.K))
    # [shots, trusted-clean] per screen bucket; the generators count the
    # trusted side, `_screen_batch` the shots (see _screen_worthwhile).
    counters["_screen_stats"] = {"regular": [0, 0], "rescan": [0, 0]}
    done = 0
    stale = 0
    while done < len(lanes):
        # First wave covers the warm lane plus at least patience+1
        # orderings (the minimum the sequential rule can ever stop at);
        # each later wave advances by exactly the lanes the sequential
        # rule could still evaluate before stopping (`patience` minus
        # the prefix's trailing stale streak), so the improved prefix
        # never overshoots the sequential stop point by more than the
        # wave that contains it.  A `batch_width` cap shrinks the waves,
        # which replays the stop rule more often — the evaluated prefix
        # stays a superset of the sequential driver's for any wave
        # partition.
        take = (max(patience + 1, 8) + sum(ln.is_warm for ln in lanes)
                if done == 0 else max(patience - stale, 1))
        if batch_width:
            take = min(take, batch_width)
        wave = lanes[done:done + take]
        _improve_wave(wave, done, tx, L, validate, incremental, counters,
                      load)
        done += len(wave)
        best_obj, stale = np.inf, 0
        for ln in lanes[:done]:
            obj = state_objective(ln.st)
            if ln.is_warm:     # warm seed initializes best, wins ties
                best_obj = obj
            elif obj < best_obj - 1e-9:
                best_obj, stale = obj, 0
            else:
                stale += 1
                if stale >= patience:
                    return done
    return done


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def agh_xla(inst: Instance, R: int | None = None, L: int = 3,
            seed: int = 0, patience: int = 5, validate: bool = False,
            local_search: str = "batched", workers: int | None = None,
            warm_start: Solution | None = None,
            priority_orders: list[np.ndarray] | None = None,
            stats: dict | None = None,
            batch_width: int | None = None) -> Solution:
    """AGH on the XLA engine — drop-in for `core.agh.agh`.

    Construction (Phase 2) runs on every lane; the improvement loop
    honors `patience` at wave granularity — lanes improve in
    device-batched waves and the sequential early-stop rule is replayed
    between waves, so the evaluated set is always a superset of the
    sequential numpy driver's and the returned objective can only match
    or beat it.  `workers` is accepted for signature compatibility and
    ignored (the lane batch replaces the process pool).  `batch_width`
    caps how many lanes advance together — per device call in the
    Phase-2 lockstep and per improvement wave — the knob behind the
    benchmark's batch-width scaling curve; ``None`` batches all lanes at
    once.  Narrower waves replay the early-stop rule more often (width 1
    = the exact sequential protocol), so results across widths are
    dominance-ordered, not identical, unless patience is effectively
    infinite.
    """
    t0 = time.perf_counter()
    if inst.avail_gpus is not None:
        # Tier availability caps (core/faults.py) are enforced by the
        # numpy commit guards; the device screening kernels don't model
        # them, so capped (faulted) instances run the numpy oracle path.
        from ..agh import agh as _agh_numpy
        if stats is not None:
            stats["xla_avail_fallback"] = True
        return _agh_numpy(inst, R=R, L=L, seed=seed, patience=patience,
                          validate=validate, local_search=local_search,
                          workers=workers, warm_start=warm_start,
                          priority_orders=priority_orders, stats=stats)
    if local_search == "reference":
        raise ValueError("engine='xla' does not implement "
                         "local_search='reference'; use 'batched' or "
                         "'batched-rescan'")
    del workers   # the lane batch replaces the process pool
    incremental = local_search != "batched-rescan"
    rng = np.random.default_rng(seed)
    if R is None:
        R = _adaptive_R(inst, batched=True)
    orders = _orderings(inst, R, rng)
    if priority_orders:
        orders = [np.asarray(o) for o in priority_orders] + orders
    tx = tensors_for(inst)
    counters: dict = {}
    # Phase 1 is ordering-independent: one run, shared snapshot.
    st0 = State.fresh(inst)
    _phase1(st0)
    p1 = state_snapshot(st0)
    lanes: list[_Lane] = []
    if warm_start is not None:
        lanes.append(_Lane(deployment_state(inst, warm_start),
                           np.argsort(-inst.lam), is_warm=True))
    for order in orders:
        st = State.fresh(inst)
        state_restore(st, p1)
        lanes.append(_Lane(st, np.asarray(order)))
    _phase2_lockstep(lanes, tx, batch_width, counters)
    done = _improve_lockstep(lanes, tx, L, validate, incremental,
                             patience, counters, batch_width)
    # Deterministic reduction over the improved prefix, in lane order;
    # the warm lane comes first and therefore wins ties, matching the
    # numpy warm-start protocol.
    best, best_obj, best_order, warm_obj = None, np.inf, None, None
    n_warm = 0
    for ln in lanes[:done]:
        obj = state_objective(ln.st)
        if ln.is_warm:
            warm_obj = obj
            n_warm += 1
        if obj < best_obj - 1e-9:
            best_obj = obj
            best = solution_from_state(inst, ln.st)
            best_order = None if ln.is_warm else ln.order
    assert best is not None
    if stats is not None:
        stats.update(engine="xla", restarts=R,
                     warm_started=warm_start is not None,
                     local_search=local_search,
                     orderings_evaluated=done - n_warm,
                     early_stopped=done < len(lanes),
                     winning_order=(None if best_order is None
                                    else [int(i) for i in best_order]))
        if warm_obj is not None:
            stats["warm_objective"] = warm_obj
        counters.pop("_screen_stats", None)
        stats.update(counters)
        for key in ("scan_s", "screen_s"):
            if key in stats:
                stats[key] = round(stats[key], 4)
    assert is_feasible(inst, best, enforce_zeta=False), \
        "AGH-XLA produced an infeasible solution (engine bug)"
    best.runtime_s = time.perf_counter() - t0
    best.method = "AGH-XLA"
    return best
