"""Device-resident instance tensors for the XLA engine.

One `XlaInstanceTensors` bundle per `Instance`, cached on the instance
itself (`inst._xla_tensors`; the perturbed()/stressed()/with_lam()
helpers build fresh Instance objects, so a cached bundle can never go
stale).  Every tensor is a flat ``[I, J*K]`` (or ``[J*K]``) float64 view
of a precomputed numpy tensor the numpy engine already uses — the host
arrays are the source of truth, the device copies are uploaded once and
reused by every jitted kernel call of every solve on the instance.

float64 is non-negotiable: the numpy oracle runs in float64, and the
engine's <=-objective contract against it leaves no room for float32
rounding in the ranking keys.  jax defaults to float32, so x64 mode is
enabled here, at first import of the lazy xla tier — before any kernel
is traced.  (Pallas kernels elsewhere in the repo pin their own dtypes
explicitly and are unaffected by the global flag.)
"""
from __future__ import annotations

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after the x64 switch, deliberately)

from ..instance import Instance  # noqa: E402


class XlaInstanceTensors:
    """Flat [I, J*K] device tensors shared by the phase-2 ranking kernel
    and the relocate screen kernel.

    The derived products (`psb_data`, `rho_d`) are computed with numpy in
    exactly the elementwise op order of `rank_keys_all`, so the values
    shipped to the device match the oracle's intermediate grids bitwise;
    any remaining divergence comes only from XLA's instruction fusion on
    the final arithmetic (last-ulp), which the engine's tolerance /
    screen-slack policy absorbs.
    """

    def __init__(self, inst: Instance):
        I, J, K = inst.I, inst.J, inst.K
        JK = J * K
        self.inst = inst
        self.JK = JK
        m1_delay = inst.m1_delay.reshape(I, JK)
        f64, b8 = jnp.float64, jnp.bool_
        # --- shared by both kernels -----------------------------------
        self.m1_delay = jnp.asarray(m1_delay, dtype=f64)
        self.m1_valid = jnp.asarray(inst.m1_feasible.reshape(I, JK),
                                    dtype=b8)
        self.ebf = jnp.asarray(inst.e_bar_floor_flat, dtype=f64)
        self.eps = jnp.asarray(inst.eps, dtype=f64)
        self.Delta = jnp.asarray(inst.Delta, dtype=f64)
        self.Delta_T = float(inst.Delta_T)
        # --- phase-2 ranking (rank_keys_all's cost pieces) ------------
        # Cost term p_s * (B_j + data_gb_i), elementwise in the oracle's
        # own op order (add, then scale).
        B_jk = np.repeat(inst.B, K)
        self.psb_data = jnp.asarray(
            inst.p_s * (B_jk[None, :] + inst.data_gb[:, None]), dtype=f64)
        # Routed-delay cost rho_i * d * 1e3 at the M1 winner (active
        # cells are overridden per call).
        self.rho_d = jnp.asarray((inst.rho[:, None] * m1_delay) * 1e3,
                                 dtype=f64)
        self.m1_nm = jnp.asarray(inst.m1_nm.reshape(I, JK).astype(float),
                                 dtype=f64)
        self.pc_flat = jnp.asarray(np.tile(inst.p_c, J), dtype=f64)
        # --- relocate screen (DestCache row ingredients) --------------
        self.m1_rental = jnp.asarray(inst.m1_rental.reshape(I, JK),
                                     dtype=f64)
        self.lpx = jnp.asarray(inst.load_per_x_flat, dtype=f64)
        self.psB_flat = jnp.asarray(np.repeat(inst.p_s_B, K), dtype=f64)
        self.comp_flat = jnp.asarray(np.tile(inst.comp_cap_coef, J),
                                     dtype=f64)


def tensors_for(inst: Instance) -> XlaInstanceTensors:
    """The instance's cached tensor bundle, built on first use."""
    if inst._xla_tensors is None:
        inst._xla_tensors = XlaInstanceTensors(inst)
    return inst._xla_tensors
