"""Jitted XLA kernels of the allocator engine, with host-side padding.

Two device programs cover the engine's hot numeric loops:

* `phase2_keys` — the batched M2 ranking keys of GH Phase 2 for every
  multi-start lane at once (`rank_keys_all` over a lane axis): one call
  per lockstep step computes the (pi, kappa) argmin-walk inputs of all
  orderings, each lane at its own current type.  Active cells arrive as
  host-computed override values (exact numpy arithmetic) scattered over
  the resident M1 grids.

* `screen_sources` — the relocate screen: for a stacked batch of
  (lane, source-cell) rows, reproduce `score_moves_batch`'s improvement
  filter and cap-upper-bound prefilter against each lane's sweep-start
  state and reduce to one boolean per source ("could any destination
  improve?").  Sources that fail are provably non-improving (the caller
  adds slack to the thresholds so XLA fusion ulps can never flip a
  verdict from pass to fail); sources that pass get the exact numpy
  scan.

Shapes are padded to a small set of bucket sizes so jit retraces stay
bounded: scatter indices are padded with the one-past-the-end column
trick (a dummy column is appended, written, then sliced off), and padded
sources carry ``bound = -inf`` so they can never report alive.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .tensors import XlaInstanceTensors


def _bucket(n: int, steps: tuple[int, ...], cap: int) -> int:
    """Smallest padded size >= n from `steps` (clamped to `cap`)."""
    for s in steps:
        s = min(s, cap)
        if n <= s:
            return s
    return cap


def _pad2(rows: list[np.ndarray], n_rows: int, n_cols: int, fill,
          dtype) -> np.ndarray:
    out = np.full((n_rows, n_cols), fill, dtype=dtype)
    for r, a in enumerate(rows):
        out[r, : a.shape[0]] = a
    return out


# ---------------------------------------------------------------------------
# Phase-2 ranking keys (rank_keys_all over a lane axis)
# ---------------------------------------------------------------------------

@jax.jit
def _phase2_keys_jit(m1_nm, psb_data, rho_d, m1_delay, m1_valid, ebf,
                     pc_flat, eps, Delta, Delta_T, i_idx, y, rr, E, D,
                     act_jk, act_cost, act_d, act_valid):
    R, JK = y.shape
    rows = jnp.arange(R, dtype=jnp.int64)[:, None]

    def scat(base, vals):
        p = jnp.concatenate([base, jnp.zeros((R, 1), base.dtype)], axis=1)
        return p.at[rows, act_jk].set(vals)[:, :JK]

    # Cost/delay/validity grids: M1 rows gathered at each lane's current
    # type, the lane's active cells overridden with the host's exact
    # per-cell values (post-M3 configs, pair-config delays).
    inc = jnp.maximum(0.0, m1_nm[i_idx] - y)
    cost = (Delta_T * (pc_flat[None, :] * inc + psb_data[i_idx])
            + rho_d[i_idx])
    d = scat(m1_delay[i_idx], act_d)
    valid = scat(m1_valid[i_idx], act_valid)
    cost = scat(cost, act_cost)
    # x-bar = min(r_rem, error headroom, delay headroom); keys as in
    # rank_keys_all: pi=0 iff the pair absorbs the full residual.
    err_cap = (eps[i_idx] - E)[:, None] / ebf[i_idx]
    del_cap = (Delta[i_idx] - D)[:, None] / jnp.maximum(d, 1e-12)
    xbar = jnp.minimum(jnp.minimum(rr[:, None], err_cap), del_cap)
    live = xbar > 1e-9
    valid = valid & live
    pi = xbar < rr[:, None] - 1e-9
    kappa = jnp.where(live, cost / jnp.where(live, xbar, 1.0), jnp.inf)
    kap0 = jnp.where(valid & ~pi, kappa, jnp.inf)
    kap1 = jnp.where(valid & pi, kappa, jnp.inf)
    return kap0, kap1


_ACT_STEPS = (64, 512, 4096)


def phase2_keys(tx: XlaInstanceTensors, items: list[tuple],
                counters: dict | None = None
                ) -> tuple[np.ndarray, np.ndarray]:
    """Ranking keys for one lockstep step over a chunk of lanes.

    ``items`` holds one tuple per lane:
    ``(i, y_flat, rr, E, D, act_jk, act_cost, act_d, act_valid)`` —
    the lane's current type, its flat GPU-count grid, the type-local
    scalars, and the active-cell override vectors.  Returns writable
    numpy ``(kap0, kap1)`` of shape [len(items), J*K] ready for
    `_phase2_walk`'s destructive visited-masking.
    """
    JK = tx.JK
    R = len(items)
    a_max = max((it[5].shape[0] for it in items), default=0)
    A = _bucket(max(a_max, 1), _ACT_STEPS, JK)
    i_idx = np.fromiter((it[0] for it in items), np.int64, R)
    y = np.stack([it[1] for it in items])
    rr = np.fromiter((it[2] for it in items), np.float64, R)
    E = np.fromiter((it[3] for it in items), np.float64, R)
    D = np.fromiter((it[4] for it in items), np.float64, R)
    act_jk = _pad2([it[5] for it in items], R, A, JK, np.int64)
    act_cost = _pad2([it[6] for it in items], R, A, 0.0, np.float64)
    act_d = _pad2([it[7] for it in items], R, A, 0.0, np.float64)
    act_valid = _pad2([it[8] for it in items], R, A, False, bool)
    kap0, kap1 = _phase2_keys_jit(
        tx.m1_nm, tx.psb_data, tx.rho_d, tx.m1_delay, tx.m1_valid, tx.ebf,
        tx.pc_flat, tx.eps, tx.Delta, tx.Delta_T, i_idx, y, rr, E, D,
        act_jk, act_cost, act_d, act_valid)
    if counters is not None:
        counters["device_calls_phase2"] = \
            counters.get("device_calls_phase2", 0) + 1
    return np.array(kap0), np.array(kap1)


# ---------------------------------------------------------------------------
# Relocate screen (score_moves_batch's filters, any-destination reduce)
# ---------------------------------------------------------------------------

@jax.jit
def _screen_jit(m1_delay, m1_valid, m1_rental, m1_nm, ebf, lpx, psB_flat,
                comp_flat, Delta_T, g_i, g_lane, z_lt, act_jk, act_nm,
                act_d, act_ok, load, s_g, s_jk, dyn, bound, rr2, err_num,
                del_num, fthr):
    G, JK = z_lt.shape
    S = s_g.shape[0]
    gr = jnp.arange(G, dtype=jnp.int64)[:, None]

    def scat(base, vals):
        p = jnp.concatenate([base, jnp.zeros((G, 1), base.dtype)], axis=1)
        return p.at[gr, act_jk].set(vals)[:, :JK]

    # Destination rows per (lane, type) group — the DestCache row
    # construction: M1 grids with each lane's active cells overridden
    # (pair config delay/validity, zero incremental rental, pair GPU
    # count), plus the type's admission-dependent static cost.
    d_sel = scat(m1_delay[g_i], act_d)
    okr = scat(m1_valid[g_i], act_ok)
    rent = scat(m1_rental[g_i], jnp.zeros_like(act_d))
    nmd = scat(m1_nm[g_i], act_nm)
    dcost = Delta_T * (rent + jnp.where(z_lt, 0.0, psB_flat[None, :]))
    comp = comp_flat[None, :] * nmd - load[g_lane]
    # Per-source improvement filter + cap upper bound, reduced to one
    # "any destination alive" bit.
    ds = d_sel[s_g]
    delta = dcost[s_g] + dyn[:, None] * ds
    cand = okr[s_g] & (delta < bound[:, None])
    candp = jnp.concatenate([cand, jnp.zeros((S, 1), bool)], axis=1)
    cand = candp.at[jnp.arange(S, dtype=jnp.int64), s_jk].set(False)[:, :JK]
    si = g_i[s_g]
    ub = jnp.minimum(rr2[:, None], err_num[:, None] / ebf[si])
    ub = jnp.minimum(ub, del_num[:, None] / jnp.maximum(ds, 1e-12))
    lpx_s = lpx[si]
    gcap = comp[s_g] / jnp.where(lpx_s > 1e-18, lpx_s, 1.0)
    ub = jnp.where(lpx_s > 1e-18, jnp.minimum(ub, gcap), ub)
    alive = cand & (ub >= fthr[:, None])
    return jnp.any(alive, axis=1)


# Geometric bucket ladders: each distinct (S, G, A) triple costs one jit
# trace, so steps double — retraces stay O(log) while padding waste is
# bounded at 2x (the coarse ladders this replaced padded the common
# ~700-source screen call to 4096 rows, 5x wasted device work).
_SRC_STEPS = (128, 256, 512, 1024, 2048, 4096)
_GRP_STEPS = (64, 128, 256, 512, 1024, 2048, 4096)
_SCREEN_ACT_STEPS = (128, 512, 2048, 8192)


def screen_sources(tx: XlaInstanceTensors, groups: list[tuple],
                   srcs: list[tuple], load: np.ndarray,
                   counters: dict | None = None) -> np.ndarray:
    """One padded screen call; see the module docstring.

    ``groups[g] = (lane_idx, type, z_lt_flat, act_jk, act_nm, act_d,
    act_ok)`` — one row per (lane, type) with the lane's active-cell
    overrides; ``srcs[s] = (g, s_jk, dyn, bound, rr2, err_num, del_num,
    fthr)``; ``load`` is the [n_lanes, J*K] stacked per-lane compute
    load (padded to the solve's full lane count so the compiled shape is
    per-solve constant).  Returns a bool verdict per real source
    (True = may improve, run the exact scan).
    """
    JK = tx.JK
    nG, nS = len(groups), len(srcs)
    a_max = max((g[3].shape[0] for g in groups), default=0)
    A = _bucket(max(a_max, 1), _SCREEN_ACT_STEPS, JK)
    G = _bucket(nG, _GRP_STEPS, max(nG, 1))
    S = _bucket(nS, _SRC_STEPS, max(nS, 1))
    g_i = np.zeros(G, np.int64)
    g_lane = np.zeros(G, np.int64)
    z_lt = np.zeros((G, JK), bool)
    act_jk = np.full((G, A), JK, np.int64)
    act_nm = np.zeros((G, A), np.float64)
    act_d = np.zeros((G, A), np.float64)
    act_ok = np.zeros((G, A), bool)
    for g, (lane, ty, z_row, a_jk, a_nm, a_d, a_ok) in enumerate(groups):
        g_i[g] = ty
        g_lane[g] = lane
        z_lt[g] = z_row
        n = a_jk.shape[0]
        act_jk[g, :n] = a_jk
        act_nm[g, :n] = a_nm
        act_d[g, :n] = a_d
        act_ok[g, :n] = a_ok
    s_g = np.zeros(S, np.int64)
    s_jk = np.full(S, JK, np.int64)
    dyn = np.zeros(S, np.float64)
    bound = np.full(S, -np.inf)
    rr2 = np.zeros(S, np.float64)
    err_num = np.zeros(S, np.float64)
    del_num = np.zeros(S, np.float64)
    fthr = np.zeros(S, np.float64)
    for s, (g, jk, dy, bd, r2, en, dn, ft) in enumerate(srcs):
        s_g[s], s_jk[s] = g, jk
        dyn[s], bound[s], rr2[s] = dy, bd, r2
        err_num[s], del_num[s], fthr[s] = en, dn, ft
    alive = _screen_jit(tx.m1_delay, tx.m1_valid, tx.m1_rental, tx.m1_nm,
                        tx.ebf, tx.lpx, tx.psB_flat, tx.comp_flat,
                        tx.Delta_T, g_i, g_lane, z_lt, act_jk, act_nm,
                        act_d, act_ok, load, s_g, s_jk, dyn, bound, rr2,
                        err_num, del_num, fthr)
    if counters is not None:
        counters["device_calls_screen"] = \
            counters.get("device_calls_screen", 0) + 1
        counters["screen_sources"] = \
            counters.get("screen_sources", 0) + nS
    return np.array(alive[:nS])
