"""State-of-the-art-derived heuristic baselines (paper §2, §5.1).

LPR — LP relaxation of `P_DM` with LP-warmstart greedy rounding: solve the
      relaxation, round configuration selectors by descending fractional
      value, fix the deployment, then re-solve routing as a Stage-2 LP.
DVR — decoupled VM-selection-then-routing (after Kim et al., EuroSys'25):
      per query type, pick the cheapest (model, tier) meeting its error SLO
      in isolation and provision it for the expected load; route afterwards.
      No coupled feasibility enforcement at selection time.
HF  — homogeneous-fleet provisioning (after DynamoLLM, HPCA'25): pick one
      tier for the whole fleet (best perf/$ subject to fitting the largest
      required model), deploy on that tier only, then route.

These deliberately reproduce the failure modes the paper targets: selection
ignores memory/delay/budget coupling, which the Stage-2 LP then exposes.
"""
from __future__ import annotations

import time

import numpy as np

from .instance import Instance
from .mechanisms import State, commit, m1_select, max_commit
from .milp import lp_relaxation_values
from .solution import Solution
from .stage2 import stage2_lp


def _route_with_stage2(inst: Instance, deploy: Solution) -> Solution:
    routed, _ = stage2_lp(inst, deploy, u_cap=np.ones(inst.I),
                          allow_any_deployed=True)
    routed.z = np.where(routed.x > 1e-9, 1.0, 0.0)
    return routed


# ---------------------------------------------------------------------------
# LPR
# ---------------------------------------------------------------------------

def lpr(inst: Instance, time_limit: float = 120.0) -> Solution:
    t0 = time.perf_counter()
    vec, ix = lp_relaxation_values(inst, time_limit=time_limit)
    sol = Solution.empty(inst)
    if vec is not None:
        # Round configuration selectors by descending fractional mass,
        # activating a pair's best fractional config if its q is >= 0.5 of
        # the largest fractional deployment signal.
        qfrac = np.array([[vec[ix.q(j, k)] for k in range(inst.K)]
                          for j in range(inst.J)])
        thresh = max(0.25, 0.5 * float(qfrac.max(initial=0.0)))
        for j in range(inst.J):
            for k in range(inst.K):
                if qfrac[j, k] >= thresh:
                    wf = np.array([vec[ix.w(j, k, c)] for c in range(inst.n_cfg)])
                    c = int(np.argmax(wf))
                    sol.q[j, k] = 1.0
                    sol.w[j, k, c] = 1.0
                    sol.y[j, k] = float(inst.nm[c])
        if sol.q.sum() == 0 and qfrac.max(initial=0.0) > 0:
            j, k = np.unravel_index(np.argmax(qfrac), qfrac.shape)
            wf = np.array([vec[ix.w(j, k, c)] for c in range(inst.n_cfg)])
            c = int(np.argmax(wf))
            sol.q[j, k] = 1.0
            sol.w[j, k, c] = 1.0
            sol.y[j, k] = float(inst.nm[c])
    sol = _route_with_stage2(inst, sol)
    sol.runtime_s = time.perf_counter() - t0
    sol.method = "LPR"
    return sol


# ---------------------------------------------------------------------------
# DVR
# ---------------------------------------------------------------------------

def dvr(inst: Instance) -> Solution:
    t0 = time.perf_counter()
    deploy = Solution.empty(inst)
    for i in range(inst.I):
        # Cheapest (j,k) whose error meets the SLO in isolation —
        # decoupled: no memory/delay/budget coupling at selection time.
        best, best_price = None, np.inf
        for j in range(inst.J):
            for k in range(inst.K):
                if inst.e_bar[i, j, k] > inst.eps[i]:
                    continue
                if inst.p_c[k] < best_price:
                    best, best_price = (j, k), inst.p_c[k]
        if best is None:
            continue
        j, k = best
        # Provision for expected load with the smallest config that fits
        # memory (delay ignored — the decoupling the paper criticizes).
        fit = [c for c in range(inst.n_cfg)
               if inst.B_eff[j, k] / inst.nm[c] <= inst.C_gpu[k]]
        if not fit:
            continue
        c = fit[int(np.argmin(inst.nm[fit]))]
        deploy.q[j, k] = 1.0
        deploy.w[j, k, :] = 0.0
        deploy.w[j, k, c] = 1.0
        deploy.y[j, k] = float(inst.nm[c])
        deploy.z[i, j, k] = 1.0
    sol = _route_with_stage2(inst, deploy)
    sol.runtime_s = time.perf_counter() - t0
    sol.method = "DVR"
    return sol


# ---------------------------------------------------------------------------
# HF
# ---------------------------------------------------------------------------

def hf(inst: Instance) -> Solution:
    t0 = time.perf_counter()
    # One tier for the whole fleet: best TFLOP-per-dollar among tiers that
    # can hold the largest model needed at max parallelism.
    need_B = inst.B_eff.min(axis=0)  # cheapest-model proxy per tier
    score = inst.P_gpu / inst.p_c
    order = np.argsort(-score)
    k_star = None
    for k in order:
        if need_B[k] / float(np.max(inst.nm)) <= inst.C_gpu[k]:
            k_star = int(k)
            break
    deploy = Solution.empty(inst)
    if k_star is not None:
        st = State.fresh(inst)
        for i in np.argsort(-inst.lam):
            i = int(i)
            # Smallest model on k_star meeting the error SLO.
            for j in np.argsort(inst.B):
                j = int(j)
                if inst.e_bar[i, j, k_star] > inst.eps[i]:
                    continue
                c = m1_select(inst, i, j, k_star)
                if c is None:
                    continue
                if st.q[j, k_star] > 0.5:
                    c = int(st.cfg[j, k_star])
                    if inst.D_cfg[i, j, k_star, c] > inst.Delta[i]:
                        continue
                frac = min(st.r_rem[i], max_commit(st, i, j, k_star, c))
                if frac <= 1e-9:
                    continue
                commit(st, i, j, k_star, c, frac)
                if st.r_rem[i] <= 1e-9:
                    break
        deploy.x, deploy.y, deploy.q, deploy.z = st.x, st.y, st.q, st.z
        deploy.u = np.clip(st.r_rem, 0.0, None)
        for j in range(inst.J):
            if st.q[j, k_star] > 0.5 and st.cfg[j, k_star] >= 0:
                deploy.w[j, k_star, int(st.cfg[j, k_star])] = 1.0
    sol = _route_with_stage2(inst, deploy)
    sol.runtime_s = time.perf_counter() - t0
    sol.method = "HF"
    return sol
