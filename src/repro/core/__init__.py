# The paper's primary contribution: joint model selection, heterogeneous
# GPU provisioning, (TP, PP) parallelism configuration, and workload routing
# for SLO-constrained LLM inference — exact MILP (P_DM) plus the
# constraint-aware GH / AGH heuristics built on mechanisms M1–M3.
from .agh import agh, agh_repair
from .baselines import dvr, hf, lpr
from .evaluate import EvalResult, evaluate
from .faults import (CapacityShock, FaultSchedule, PriceSpike, Recovery,
                     SpotRevocation, TierOutage, apply_faults,
                     diurnal_outages, evict_unavailable, lost_pairs,
                     poisson_revocations, with_spot_tiers)
from .gh import gh, greedy_heuristic
from .instance import (Instance, ScenarioBatch, default_instance,
                       random_instance)
from .mechanisms import (MoveScores, State, m1_select, m3_upgrade,
                         max_commit, max_commit_batch, rank_keys_all,
                         score_moves_batch, solution_from_state,
                         state_objective)
from .milp import solve_milp
from .queueing import (queueing_delay, slo_attainment_with_queueing,
                       utilization, with_queueing_margin)
from .rolling import RollingResult, replay_study, rolling, volatility_study
from .solution import (Solution, cost_terms, feasibility, is_feasible,
                       objective, proc_delay, provisioning_cost,
                       slack_report)
from .stage2 import Stage2System, stage2_cost, stage2_lp

__all__ = [
    "agh", "agh_repair", "dvr", "hf", "lpr", "EvalResult", "evaluate", "gh",
    "greedy_heuristic", "Instance", "ScenarioBatch", "default_instance",
    "random_instance",
    "CapacityShock", "FaultSchedule", "PriceSpike", "Recovery",
    "SpotRevocation", "TierOutage", "apply_faults", "diurnal_outages",
    "evict_unavailable", "lost_pairs", "poisson_revocations",
    "with_spot_tiers",
    "MoveScores", "State", "m1_select", "m3_upgrade", "max_commit",
    "max_commit_batch", "rank_keys_all", "score_moves_batch",
    "solution_from_state", "state_objective",
    "solve_milp", "RollingResult", "replay_study",
    "rolling", "volatility_study", "Solution", "cost_terms", "feasibility",
    "is_feasible", "objective", "proc_delay", "provisioning_cost",
    "slack_report", "Stage2System", "stage2_cost", "stage2_lp",
]
