"""The three constraint-aware mechanisms shared by GH and AGH (paper §4.1).

M1 — TP-aware feasibility selection (eq. 9): for candidate (i,j,k), pick the
     cheapest (TP,PP) that simultaneously fits per-device memory and the
     delay SLO; discard the candidate if none exists.
M2 — cost-per-effective-coverage ranking (eqs. 10–11): rank candidates by
     incremental cost per unit of traffic they can actually absorb within
     the remaining error/delay budgets, with a full-coverage tie-breaker.
M3 — TP upgrade on active pairs (eq. 12): before activating a fresh pair,
     try a higher-parallelism configuration on an already-active pair,
     paying only the incremental GPU cost.

Vectorized engine notes
-----------------------
M1 winners are precomputed per instance (`Instance.cfg_m1`), M2 keys are
evaluated for all (j,k) at once (`rank_keys_all`), and the `State` carries
incremental aggregates — per-pair resident KV tokens (`kv_tok`), per-pair
compute load (`load`), and per-type storage (`stor_used`) — maintained by
`commit` / `remove_assignment` so that `max_commit` and the objective are
O(1) instead of O(I·J·K).  `commit` and `remove_assignment` optionally push
inverse records onto an undo list (`undo_all` rolls them back exactly),
which is what lets AGH's local search evaluate a move without copying the
solution.  The scalar seed implementations live in `_scalar_ref.py` and the
equivalence suite checks the two paths produce the same allocations.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .contracts import mutates
from .instance import KB_PER_GB, Instance


@dataclasses.dataclass
class State:
    """Running construction state (paper §4, 'Running state').

    Invariants maintained by `commit` / `remove_assignment` (and relied on
    by `max_commit` / `state_objective`):
      * kv_tok[j,k]   == sum_i kv_tok_per_x[i,j,k] * x[i,j,k]
      * load[j,k]     == sum_i load_per_x[i,j,k]   * x[i,j,k]
      * stor_used[i]  == sum_jk B[j]*z[i,j,k] + data_gb[i]*sum_jk x[i,j,k]
      * spend         == Delta_T*(sum p_c*y + p_s*(sum B*z + sum data_gb*x))
      * D_used[i]     == sum_jk D_cfg[i,j,k,cfg[j,k]] * x[i,j,k]  (over
                         active pairs), E_used likewise with e_bar
    up to float accumulation order (the equivalence tests allow 1e-9).
    """
    inst: Instance
    x: np.ndarray          # [I,J,K]
    y: np.ndarray          # [J,K]
    q: np.ndarray          # [J,K]
    cfg: np.ndarray        # [J,K] config index, -1 if inactive
    z: np.ndarray          # [I,J,K]
    r_rem: np.ndarray      # [I] remaining unserved fraction (tilde r)
    E_used: np.ndarray     # [I] cumulative error
    D_used: np.ndarray     # [I] cumulative delay
    spend: float           # committed budget $
    uncovered: set[int]    # I^unc
    kv_tok: np.ndarray     # [J,K] resident KV tokens routed to each pair
    load: np.ndarray       # [J,K] committed GFLOP load per pair
    stor_used: np.ndarray  # [I] storage GB committed per query type
    # Ablation switches (paper Table 3): subsets of
    # {"no_m1", "no_m2", "no_m3"}; used ONLY by the ablation benchmark.
    ablation: frozenset = frozenset()

    @staticmethod
    def fresh(inst: Instance, ablation: frozenset = frozenset()) -> "State":
        I, J, K = inst.I, inst.J, inst.K
        return State(inst=inst, x=np.zeros((I, J, K)), y=np.zeros((J, K)),
                     q=np.zeros((J, K)), cfg=-np.ones((J, K), dtype=int),
                     z=np.zeros((I, J, K)), r_rem=np.ones(I),
                     E_used=np.zeros(I), D_used=np.zeros(I), spend=0.0,
                     uncovered=set(range(I)), kv_tok=np.zeros((J, K)),
                     load=np.zeros((J, K)), stor_used=np.zeros(I),
                     ablation=ablation)


# ---------------------------------------------------------------------------
# M1
# ---------------------------------------------------------------------------

def m1_select(inst: Instance, i: int, j: int, k: int,
              ablation: frozenset = frozenset()) -> int | None:
    """Cheapest feasible config index for (i,j,k) per eq. (9), else None.

    O(1): the lex-(nm, delay, index)-minimal feasible config is precomputed
    per instance in `Instance.cfg_m1`."""
    if "no_m1" in ablation:
        # Cost-only: always "select" the cheapest config (nm = 1) without
        # the memory/delay filter (paper Table 3: memory violation).
        return inst.cfg_min_nm
    c = int(inst.cfg_m1[i, j, k])
    return None if c < 0 else c


# ---------------------------------------------------------------------------
# M3
# ---------------------------------------------------------------------------

def m3_upgrade(st: State, i: int, j: int, k: int) -> int | None:
    """Smallest config with nm > y_jk meeting the delay SLO within budget
    (eq. 12). Returns the config index or None.

    Candidate filtering is one mask over all configs; only the re-timing
    check walks the (nm, index)-sorted survivors, stopping at the first
    config that keeps every routed type within its SLO."""
    inst = st.inst
    y_cur = st.y[j, k]
    nm = inst.nm
    mask = ((nm > y_cur) & inst.mem_ok[j, k]
            & (inst.D_cfg[i, j, k] <= inst.Delta[i])
            & (st.spend + inst.Delta_T * inst.p_c[k] * (nm - y_cur)
               <= inst.delta))
    if inst.avail_gpus is not None:
        # Shared tier cap: the upgrade swaps this pair's y_cur for nm,
        # so the tier's total usage must stay within availability.
        used_k = float(st.y[:, k].sum())
        mask &= used_k - y_cur + nm <= inst.avail_gpus[k] + 1e-9
    if not mask.any():
        return None
    c_old = int(st.cfg[j, k])
    if c_old < 0:
        for c in inst.cfg_by_nm:
            if mask[c]:
                return int(c)
        return None
    x_col = st.x[:, j, k]
    routed = x_col > 1e-12
    for c in inst.cfg_by_nm:
        if not mask[c]:
            continue
        # Upgrading the pair's config re-times every type already routed to
        # it; require the new config to keep all of them within their SLO.
        d_new = st.D_used + (inst.D_cfg[:, j, k, c]
                             - inst.D_cfg[:, j, k, c_old]) * x_col
        if np.any(d_new[routed] > inst.Delta[routed] + 1e-9):
            continue
        return int(c)
    return None


# ---------------------------------------------------------------------------
# M2 (plus the constraint checks of GH Step 4)
# ---------------------------------------------------------------------------

def effective_coverage(st: State, i: int, j: int, k: int, c: int) -> float:
    """x̄ per eq. (11): min of remaining demand, error slack, delay slack."""
    inst = st.inst
    e = inst.e_bar[i, j, k]
    d = inst.D_cfg[i, j, k, c]
    err_cap = (inst.eps[i] - st.E_used[i]) / max(e, 1e-12)
    del_cap = (inst.Delta[i] - st.D_used[i]) / max(d, 1e-12)
    if "no_m3" in st.ablation:
        # Ablated variant routes on whatever parallelism exists, blind to
        # the accumulated delay (paper Table 3: delay violation).
        del_cap = st.r_rem[i]
    return float(min(st.r_rem[i], err_cap, del_cap))


def delay_sel(inst: Instance, i: int, c_arr: np.ndarray) -> np.ndarray:
    """[J,K] delay of type i at each pair's selected config (config 0's
    value where `c_arr` is -1; dead cells are the caller's problem).  A flat
    fancy gather through `D_cfg_flat` — same values as the take_along_axis
    it replaces at a fraction of the per-call cost."""
    cc = np.maximum(c_arr, 0)
    return inst.D_cfg_flat[i, inst.jk_idx, cc.ravel()].reshape(c_arr.shape)


def rank_keys_all(st: State, i: int, c_arr: np.ndarray,
                  d_sel: np.ndarray | None = None
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched M2 keys for type i over every (model, tier) pair at once.

    `c_arr[J,K]` holds the candidate config per pair (-1 where none);
    `d_sel` optionally passes the already-gathered per-pair delay.
    Returns `(pi, kappa, valid)` arrays [J,K]; sorting valid candidates by
    (pi, kappa) with a stable sort reproduces the scalar candidate scan's
    ordering, including its j-major/k-minor tie-breaking."""
    inst = st.inst
    cc = np.maximum(c_arr, 0)
    d = delay_sel(inst, i, c_arr) if d_sel is None else d_sel
    r_rem = float(st.r_rem[i])
    err_cap = (inst.eps[i] - st.E_used[i]) / inst.e_bar_floor[i]
    del_cap = (inst.Delta[i] - st.D_used[i]) / np.maximum(d, 1e-12)
    if "no_m3" in st.ablation:
        del_cap = np.full_like(d, r_rem)
    xbar = np.minimum(np.minimum(r_rem, err_cap), del_cap)
    inc_gpus = np.maximum(0.0, inst.nm[cc] - st.y)
    cost = (inst.Delta_T * (inst.p_c[None, :] * inc_gpus
                            + inst.p_s * (inst.B[:, None] + inst.data_gb[i]))
            + inst.rho[i] * d * 1e3)
    live = xbar > 1e-9
    valid = (c_arr >= 0) & live
    if "no_m2" in st.ablation:
        # Raw-cost ranking, no effective-coverage normalization, no
        # full-coverage tie-breaker (paper Table 3: ~+50% cost).
        pi = np.zeros(c_arr.shape, dtype=np.int64)
        kappa = cost
    else:
        pi = (xbar < r_rem - 1e-9).astype(np.int64)
        kappa = np.divide(cost, xbar, out=np.full_like(cost, np.inf),
                          where=live)
    return pi, kappa, valid


# ---------------------------------------------------------------------------
# Commit machinery (GH Phase-2 Step 4): verify (8f)-(8h) + budget, commit.
# ---------------------------------------------------------------------------

def max_commit(st: State, i: int, j: int, k: int, c: int,
               over: tuple | None = None) -> float:
    """Largest additional fraction of type-i traffic committable to (j,k)
    at config c without violating (8f) memory, (8g) compute, (8h) storage,
    or the budget (8c).  O(1): reads the State's incremental aggregates.

    `over` optionally substitutes the type-local scalars
    ``(r_rem_i, E_used_i, D_used_i, stor_used_i, spend)`` — see
    `max_commit_batch`; the arithmetic below is `effective_coverage` plus
    the cap chain on those values, bit-identical to the plain path when
    `over` carries the state's own scalars."""
    inst = st.inst
    nm = float(inst.nm[c])
    if over is None:
        cap = effective_coverage(st, i, j, k, c)
        stor_i = st.stor_used[i]
        spend = st.spend
    else:
        rr_i, e_i, d_i, stor_i, spend = over
        e = inst.e_bar[i, j, k]
        d = inst.D_cfg[i, j, k, c]
        err_cap = (inst.eps[i] - e_i) / max(e, 1e-12)
        del_cap = (inst.Delta[i] - d_i) / max(d, 1e-12)
        if "no_m3" in st.ablation:
            del_cap = rr_i
        cap = float(min(rr_i, err_cap, del_cap))
    # (8f): per-device memory headroom -> token budget -> x budget.
    if "no_m1" in st.ablation:
        pass  # ablated: commit blindly past the memory budget
    elif inst.kv_applicable[j]:
        head_gb = inst.C_gpu[k] - inst.B_eff[j, k] / nm \
            - (inst.beta[j] / KB_PER_GB) / nm * st.kv_tok[j, k]
        per_x = (inst.beta[j] / KB_PER_GB) / nm * inst.kv_tok_per_x[i, j, k]
        if per_x > 1e-18:
            cap = min(cap, head_gb / per_x)
        elif head_gb < 0:
            return 0.0
    else:
        if inst.C_gpu[k] - inst.B_eff[j, k] / nm < 0:
            return 0.0
    # (8g): compute headroom of the y GPUs this config provides.
    comp_cap = inst.eta * 3600.0 * inst.P_gpu[k] * nm
    per_x = inst.load_per_x[i, j, k]
    if per_x > 1e-18:
        cap = min(cap, (comp_cap - st.load[j, k]) / per_x)
    # (8h): storage headroom for type i.
    new_weight = inst.B[j] if st.z[i, j, k] < 0.5 else 0.0
    per_x = inst.data_gb[i]
    if per_x > 1e-18:
        cap = min(cap, (inst.C_s - stor_i - new_weight) / per_x)
    # budget (8c): incremental rental + data storage per unit x.
    inc_gpus = max(0.0, inst.nm[c] - st.y[j, k])
    if (inst.avail_gpus is not None and inc_gpus > 0.0
            and st.y[:, k].sum() + inc_gpus > inst.avail_gpus[k] + 1e-9):
        return 0.0   # tier availability cap: the extra devices don't exist
    fixed = inst.Delta_T * (inst.p_c[k] * inc_gpus
                            + (inst.p_s * inst.B[j] if st.z[i, j, k] < 0.5 else 0.0))
    per_x = inst.budget_per_x[i]
    if spend + fixed > inst.delta:
        return 0.0
    if per_x > 1e-18:
        cap = min(cap, (inst.delta - spend - fixed) / per_x)
    return max(0.0, float(cap))


def max_commit_batch(st: State, i: int, c_arr: np.ndarray,
                     d_sel: np.ndarray | None = None,
                     over: tuple | None = None) -> np.ndarray:
    """`max_commit` for type i over every (j,k) pair at once.

    `c_arr[J,K]` gives the config per pair (-1 -> cap 0).  Pure in the
    state, so one batched evaluation replaces a row of scalar calls as long
    as no commit happens in between — used by the batched relocate /
    consolidation destination scans.  `d_sel` optionally passes the
    already-gathered per-pair delay (`delay_sel`) so callers that need it
    anyway don't pay the gather twice.  Elementwise arithmetic mirrors
    `max_commit` exactly.

    `over` optionally substitutes the type-local scalars
    ``(r_rem_i, E_used_i, D_used_i, stor_used_i, spend)`` — the relocate
    screen passes the source-removed values computed in closed form (same
    float ops `remove_assignment` would apply, so the caps equal a real
    remove → batch → undo round trip bitwise on every non-source cell)
    without mutating the state.
    """
    inst = st.inst
    if over is None:
        rr_i = float(st.r_rem[i])
        e_i = st.E_used[i]
        d_i = st.D_used[i]
        stor_i = st.stor_used[i]
        spend = st.spend
    else:
        rr_i, e_i, d_i, stor_i, spend = over
    cc = np.maximum(c_arr, 0)
    nm = inst.nm[cc]
    d = delay_sel(inst, i, c_arr) if d_sel is None else d_sel
    err_cap = (inst.eps[i] - e_i) / inst.e_bar_floor[i]
    del_cap = (inst.Delta[i] - d_i) / np.maximum(d, 1e-12)
    if "no_m3" in st.ablation:
        del_cap = np.full_like(d, rr_i)
    cap = np.minimum(np.minimum(rr_i, err_cap), del_cap)
    dead = c_arr < 0
    zm = st.z[i] < 0.5
    with np.errstate(divide="ignore", invalid="ignore"):
        # (8f)
        if "no_m1" not in st.ablation:
            b_dev = inst.B_eff / nm
            kvd = inst.kv_gb_per_tok[:, None] / nm
            head_gb = inst.C_gpu[None, :] - b_dev - kvd * st.kv_tok
            per_x = kvd * inst.kv_tok_per_x[i]
            kv = inst.kv_applicable[:, None]
            has_px = per_x > 1e-18
            # Unguarded divide: per_x == 0 cells produce inf/nan but are
            # never selected by the mask (errstate silences the warning).
            cap = np.where(kv & has_px, np.minimum(cap, head_gb / per_x),
                           cap)
            dead |= kv & ~has_px & (head_gb < 0)
            dead |= ~kv & (inst.C_gpu[None, :] - b_dev < 0)
        # (8g)
        per_x = inst.load_per_x[i]
        has_px = per_x > 1e-18
        cap = np.where(has_px,
                       np.minimum(cap, (inst.comp_cap_coef[None, :] * nm
                                        - st.load) / per_x),
                       cap)
        # (8h)
        new_weight = np.where(zm, inst.B[:, None], 0.0)
        if inst.data_gb[i] > 1e-18:
            cap = np.minimum(cap, (inst.C_s - stor_i - new_weight)
                             / inst.data_gb[i])
        # budget (8c)
        inc_gpus = np.maximum(0.0, nm - st.y)
        if inst.avail_gpus is not None:
            # tier availability: extra devices beyond the cap don't exist
            tier_used = st.y.sum(axis=0)
            dead |= (inc_gpus > 0) & (tier_used[None, :] + inc_gpus
                                      > inst.avail_gpus[None, :] + 1e-9)
        fixed = inst.Delta_T * (inst.p_c[None, :] * inc_gpus
                                + np.where(zm, inst.p_s_B[:, None], 0.0))
        dead |= spend + fixed > inst.delta
        if inst.budget_per_x[i] > 1e-18:
            cap = np.minimum(cap, (inst.delta - spend - fixed)
                             / inst.budget_per_x[i])
    return np.where(dead, 0.0, np.maximum(0.0, cap))


def max_commit_cells(st: State, i: int, cells: np.ndarray,
                     c_cells: np.ndarray, d_cells: np.ndarray,
                     over: tuple | None = None) -> np.ndarray:
    """`max_commit_batch` on a compressed 1-D list of flat (j,k) cells.

    The pure relocate scan's improvement filter usually leaves a handful
    of candidate destinations; evaluating their (8c)-(8h) caps on [n]
    gathered vectors costs a flat ~25 small-array ops instead of the full
    [J,K] grid pass.  Elementwise arithmetic mirrors `max_commit_batch`
    cell for cell (same ops on the same values — no reductions — so the
    results are bitwise identical to the grid pass at those cells).
    `c_cells`/`d_cells` are the candidate configs and delays at `cells`;
    all cells must hold valid configs (>= 0).  `over` as in
    `max_commit_batch`."""
    inst = st.inst
    if over is None:
        rr_i = float(st.r_rem[i])
        e_i = st.E_used[i]
        d_i = st.D_used[i]
        stor_i = st.stor_used[i]
        spend = st.spend
    else:
        rr_i, e_i, d_i, stor_i, spend = over
    K = inst.K
    jj = cells // K
    kk = cells - jj * K
    nm = inst.nm[c_cells]
    err_cap = (inst.eps[i] - e_i) / inst.e_bar_floor_flat[i][cells]
    del_cap = (inst.Delta[i] - d_i) / np.maximum(d_cells, 1e-12)
    if "no_m3" in st.ablation:
        del_cap = np.full_like(d_cells, rr_i)
    cap = np.minimum(np.minimum(rr_i, err_cap), del_cap)
    dead = np.zeros(cells.shape, dtype=bool)
    zm = st.z[i].reshape(-1)[cells] < 0.5
    kv_tok = st.kv_tok.reshape(-1)[cells]
    load = st.load.reshape(-1)[cells]
    y = st.y.reshape(-1)[cells]
    with np.errstate(divide="ignore", invalid="ignore"):
        # (8f)
        if "no_m1" not in st.ablation:
            b_dev = inst.B_eff_flat[cells] / nm
            kvd = inst.kv_gb_per_tok[jj] / nm
            head_gb = inst.C_gpu[kk] - b_dev - kvd * kv_tok
            per_x = kvd * inst.kv_tok_per_x_flat[i][cells]
            kv = inst.kv_applicable[jj]
            has_px = per_x > 1e-18
            cap = np.where(kv & has_px, np.minimum(cap, head_gb / per_x),
                           cap)
            dead |= kv & ~has_px & (head_gb < 0)
            dead |= ~kv & (inst.C_gpu[kk] - b_dev < 0)
        # (8g)
        per_x = inst.load_per_x_flat[i][cells]
        has_px = per_x > 1e-18
        cap = np.where(has_px,
                       np.minimum(cap, (inst.comp_cap_coef[kk] * nm
                                        - load) / per_x),
                       cap)
        # (8h)
        new_weight = np.where(zm, inst.B[jj], 0.0)
        if inst.data_gb[i] > 1e-18:
            cap = np.minimum(cap, (inst.C_s - stor_i - new_weight)
                             / inst.data_gb[i])
        # budget (8c)
        inc_gpus = np.maximum(0.0, nm - y)
        if inst.avail_gpus is not None:
            tier_used = st.y.sum(axis=0)
            dead |= (inc_gpus > 0) & (tier_used[kk] + inc_gpus
                                      > inst.avail_gpus[kk] + 1e-9)
        fixed = inst.Delta_T * (inst.p_c[kk] * inc_gpus
                                + np.where(zm, inst.p_s_B[jj], 0.0))
        dead |= spend + fixed > inst.delta
        if inst.budget_per_x[i] > 1e-18:
            cap = np.minimum(cap, (inst.delta - spend - fixed)
                             / inst.budget_per_x[i])
    return np.where(dead, 0.0, np.maximum(0.0, cap))


class DestCache:
    """Amortized destination scoring tensors for the incremental engine.

    `score_moves_batch` derives four [J,K] destination matrices per scan —
    candidate config, delay at that config, delay/M1 admissibility, and
    incremental rental — from the per-instance M1 tensors with the active
    cells overwritten.  Those matrices depend only on each pair's selected
    config (`st.cfg`; >= 0 iff the pair is active), not on the source cell
    being scanned, so the cache holds them as stacked [I,J,K] tensors:
    each type's rows are materialized lazily on first scan (one build per
    type per local search instead of four copies per scan), and `sync`
    refreshes only the columns whose config changed since the last call —
    one [J,K] int compare plus O(I) per touched cell.  Cell values are
    computed by the same expressions as the uncached path, so cached scans
    are bit-identical to uncached ones (pinned by the oracle tests).

    `rows` must be called while the state's `cfg` is consistent (i.e. not
    between a scan's internal remove/undo pair); `score_moves_batch` syncs
    before detaching the source.
    """

    def __init__(self, st: State):
        inst = st.inst
        I, J, K = inst.I, inst.J, inst.K
        self.inst = inst
        self.cfg_seen = st.cfg.copy()
        self.c_dest = np.empty((I, J, K), dtype=inst.cfg_m1.dtype)
        self.d_sel = np.empty((I, J, K))
        self.ok = np.empty((I, J, K), dtype=bool)
        self.rental = np.empty((I, J, K))
        # Static destination cost: Delta_T * (incremental rental + the
        # first-admission weight-storage term) — the destination delta
        # minus its frac-scaled parts, so the scan's improvement filter is
        # two array ops.  Depends on cfg (rental) AND on the type's own
        # admission row z[i] — `invalidate_type` flags the latter.
        self.dcost = np.empty((I, J, K))
        self.built = [False] * I
        self.zbuilt = [False] * I
        # Shared all-dead result arrays for the (dominant) no-candidate
        # return — read-only so an aliasing caller cannot corrupt them.
        self.caps0 = np.zeros((J, K))
        self.caps0.setflags(write=False)
        self.adm0 = np.zeros((J, K), dtype=bool)
        self.adm0.setflags(write=False)
        self.inf0 = np.full((J, K), np.inf)
        self.inf0.setflags(write=False)
        # Every cfg change during local search is part of an applied move
        # or drain, which must call `invalidate_type` — that sets this
        # flag, and `rows` only diffs cfg_seen while it is up.
        self.cfg_dirty = False

    @mutates("zbuilt", "cfg_dirty")
    def invalidate_type(self, i: int) -> None:
        """Notify the cache of an applied move/drain placement of type i:
        its admission row z[i] changed (static-cost row rebuilds on next
        use) and the move may have activated/deactivated pairs (cfg diff
        re-enabled)."""
        self.zbuilt[i] = False
        self.cfg_dirty = True

    @mutates("c_dest", "d_sel", "ok", "rental", "dcost", "cfg_seen")
    def _sync(self, st: State) -> None:
        changed = np.flatnonzero(st.cfg != self.cfg_seen)
        if changed.size == 0:
            return
        inst = self.inst
        K = st.cfg.shape[1]
        # Column updates are vectorized over all I rows; rows not yet
        # built get overwritten at build time anyway.  dcost columns use
        # the live z column — exactly what a row rebuild would read.
        for f in changed:
            j, k = int(f) // K, int(f) % K
            c = int(st.cfg[j, k])
            if c >= 0:
                d = inst.D_cfg[:, j, k, c]
                self.c_dest[:, j, k] = c
                self.d_sel[:, j, k] = d
                self.ok[:, j, k] = d <= inst.Delta
                self.rental[:, j, k] = 0.0
                self.dcost[:, j, k] = inst.Delta_T * np.where(
                    st.z[:, j, k] < 0.5, inst.p_s_B[j], 0.0)
            else:
                self.c_dest[:, j, k] = inst.cfg_m1[:, j, k]
                self.d_sel[:, j, k] = inst.m1_delay[:, j, k]
                self.ok[:, j, k] = inst.m1_feasible[:, j, k]
                self.rental[:, j, k] = inst.m1_rental[:, j, k]
                self.dcost[:, j, k] = inst.Delta_T * (
                    inst.m1_rental[:, j, k]
                    + np.where(st.z[:, j, k] < 0.5, inst.p_s_B[j], 0.0))
            self.cfg_seen[j, k] = c

    @mutates("cfg_dirty", "c_dest", "d_sel", "ok", "rental", "dcost",
             "built", "zbuilt")
    def rows(self, st: State, i: int):
        """Synced (c_dest, d_sel, ok, rental, dcost) rows for type i
        (built on first use).  The returned arrays are cache-owned views —
        callers must not mutate them."""
        if self.cfg_dirty:
            self._sync(st)
            self.cfg_dirty = False
        if not self.built[i]:
            inst = self.inst
            jj, kk = np.nonzero(self.cfg_seen >= 0)
            c_act = self.cfg_seen[jj, kk]
            d_act = inst.D_cfg[i, jj, kk, c_act]
            self.c_dest[i] = inst.cfg_m1[i]
            self.c_dest[i, jj, kk] = c_act
            self.d_sel[i] = inst.m1_delay[i]
            self.d_sel[i, jj, kk] = d_act
            self.ok[i] = inst.m1_feasible[i]
            self.ok[i, jj, kk] = d_act <= inst.Delta[i]
            self.rental[i] = inst.m1_rental[i]
            self.rental[i, jj, kk] = 0.0
            self.built[i] = True
            self.zbuilt[i] = False
        if not self.zbuilt[i]:
            inst = self.inst
            self.dcost[i] = inst.Delta_T * (
                self.rental[i] + np.where(st.z[i] < 0.5,
                                          inst.p_s_B[:, None], 0.0))
            self.zbuilt[i] = True
        return (self.c_dest[i], self.d_sel[i], self.ok[i], self.rental[i],
                self.dcost[i])


@dataclasses.dataclass
class RemovalTerms:
    """Closed-form scalars of detaching ALL of x[i,j,k] from its pair.

    Mirrors `remove_assignment` (+ `deactivate_pair` when the source is
    the pair's last traffic) term by term, in the same float op order, so
    `over` equals a real remove → score → undo round trip bitwise on
    every non-source cell.  Shared by `score_moves_batch`'s pure scan
    path and the XLA engine's batched relocate screen — the two consumers
    must agree on these scalars exactly, which is why they are computed
    in one place."""
    frac: float           # removed fraction (= x[i,j,k])
    data: float           # data_gb[i] * frac
    d_src: float          # per-unit delay at the source pair's config
    gain: float           # objective decrease of the bare removal
    deact: bool           # removal empties the pair (deactivation refund)
    over: tuple           # (r_rem, E_used, D_used, stor_used, spend) after


def removal_terms(st: State, i: int, j: int, k: int) -> RemovalTerms:
    """Source-removal scalars for relocating all of x[i,j,k]; see
    `RemovalTerms`.  Pure — the state is never touched."""
    inst = st.inst
    frac = float(st.x[i, j, k])
    c_src = int(st.cfg[j, k])
    had_z = bool(st.z[i, j, k] > 0.5)
    data = inst.data_gb[i] * frac
    weight = inst.B[j] if had_z else 0.0
    d_src = inst.D_cfg[i, j, k, c_src]
    gain = (inst.Delta_T * inst.p_s * (data + weight)
            + inst.rho[i] * d_src * 1e3 * frac)
    deact = float(st.x[:, j, k].sum()) - frac <= 1e-12
    n_oth = 0
    if deact:
        n_oth = int(np.count_nonzero(st.z[:, j, k] > 0.5))
        if had_z:
            n_oth -= 1
        gain += inst.Delta_T * (inst.p_s * inst.B[j] * n_oth
                                + inst.p_c[k] * float(st.y[j, k]))
    # Source-removed scalars, in `remove_assignment`'s own op order,
    # so the caps equal a real remove -> score -> undo round trip.
    rr2 = float(st.r_rem[i]) + frac
    e2 = st.E_used[i] - inst.e_bar[i, j, k] * frac
    d2 = st.D_used[i] - d_src * frac
    stor2 = st.stor_used[i] - (data + weight)
    sp2 = st.spend - inst.Delta_T * inst.p_s * (data + weight)
    if deact:
        if n_oth:
            sp2 -= inst.Delta_T * inst.p_s * inst.B[j] * n_oth
        sp2 -= inst.Delta_T * inst.p_c[k] * float(st.y[j, k])
    return RemovalTerms(frac=frac, data=data, d_src=d_src, gain=gain,
                        deact=deact, over=(rr2, e2, d2, stor2, sp2))


@dataclasses.dataclass
class MoveScores:
    """Scored relocate destinations for one (i, j, k) source cell.

    Produced by `score_moves_batch`; `obj_after[j2,k2]` is the objective of
    the solution after moving the full fraction to (j2,k2) (`inf` where the
    move is inadmissible), `caps` the destination's (8c)-(8h) commit cap,
    `c_dest` the config the move would commit at, and `obj_removed` the
    objective of the intermediate source-removed state.

    The pure path (`cache` + `improve_below`) is *lazy*: cap verification
    stops at the best admissible destination, so `admissible` marks only
    that cell (the exact argmin of the full scan's admissible set — see
    the best-first argument in the source) and `caps` is populated only
    there; `obj_removed` is the closed-form value, accurate to float
    reassociation.  The exhaustive grids come from the non-lazy paths."""
    i: int
    j: int
    k: int
    frac: float
    c_dest: np.ndarray      # [J,K]
    caps: np.ndarray        # [J,K]
    admissible: np.ndarray  # [J,K] bool
    obj_after: np.ndarray   # [J,K]
    obj_removed: float


def score_moves_batch(st: State, i: int, j: int, k: int,
                      improve_below: float | None = None,
                      cache: DestCache | None = None,
                      obj_cur: float | None = None) -> MoveScores:
    """Score moving all of x[i,j,k] to every destination (j2,k2) at once.

    One pass replaces the scalar probe-per-destination loop: config
    selection (active pairs route at their current config, inactive pairs
    at the M1 winner), the delay/M1 admissibility masks, one
    `max_commit_batch` cap evaluation, and the vectorized delta objective
    of `commit_delta_batch`.  Admissibility and caps agree with sequential
    `_try_move` probing cell-for-cell (pinned by the property suite); the
    state is restored exactly before returning.

    With `improve_below`, destinations whose post-move objective is not
    strictly under the bound are filtered from `admissible` *before* the
    cap evaluation — the scan's fast path: a converged source pays only
    the delta arithmetic (caps stay zero, `obj_after` stays inf) and the
    expensive (8c)-(8h) pass runs only when an improving candidate exists.

    With `cache` (a `DestCache`) and `improve_below` together, the scan is
    *pure* — the state is never touched.  The destination matrices come
    from the cache's lazily built, diff-synced per-type rows (same cell
    values bit-for-bit as the uncached rebuild); the source-removed
    objective is derived in closed form (the removal's refunds mirror
    `remove_assignment` + `deactivate_pair` term by term, accurate to
    float reassociation, ~1e-12 at objective scale); and the commit caps
    come from `max_commit_batch` with the source-removed type scalars
    passed as overrides — the same float ops a real removal would apply,
    so the caps equal the remove → score → undo protocol bitwise on every
    non-source cell.  `obj_cur` optionally passes the caller's current
    objective so the sweep loop's value is reused instead of recomputed.
    """
    inst = st.inst
    if cache is not None and improve_below is not None:
        c_dest, d_sel, ok_c, rental, dcost = cache.rows(st, i)
        # Removal gain in closed form: refunded data storage, weight
        # storage on first-admission drop, routed delay — plus the rental
        # and stranded-admission refunds of `deactivate_pair` when the
        # source is the pair's last traffic.  The removal's unmet-penalty
        # increase (phi * frac exactly, since r_rem >= 0 invariantly)
        # cancels against the destination's `d_unmet` term, so obj_after
        # reduces to obj_cur - gain + the destination delta.
        rt = removal_terms(st, i, j, k)
        frac, gain = rt.frac, rt.gain
        if obj_cur is None:
            obj_cur = state_objective(st)
        obj0 = obj_cur - gain + inst.Delta_T * inst.phi[i] * frac
        # Improvement filter in two array ops: the frac-scaled delay term
        # plus the cached static destination cost against a folded bound.
        dyn = float(inst.rho[i]) * 1e3 * frac
        base = obj_cur - gain + inst.Delta_T * (inst.p_s * rt.data)
        delta = dcost + dyn * d_sel
        ok = ok_c & (delta < improve_below - base)
        ok[j, k] = False
        cells = np.flatnonzero(ok.reshape(-1))
        if cells.size == 0:
            return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest,
                              caps=cache.caps0, admissible=cache.adm0,
                              obj_after=cache.inf0, obj_removed=obj0)
        over = rt.over
        rr2, e2, d2 = over[0], over[1], over[2]
        # Cap upper bound on the surviving cells: `max_commit`'s chain
        # starts from min(r_rem, err_cap, del_cap) and the (8g) compute
        # term and only min()s further, so any cell whose bound is already
        # under `frac` is dead — killing it here cannot change the scan's
        # outcome, and most improving-but-undercap candidates die on
        # these four cheap compressed-vector terms.
        d_cells0 = d_sel.reshape(-1)[cells]
        ub = np.minimum((inst.eps[i] - e2) / inst.e_bar_floor_flat[i][cells],
                        (inst.Delta[i] - d2) / np.maximum(d_cells0, 1e-12))
        if "no_m3" in st.ablation:
            ub = np.full_like(d_cells0, rr2)
        ub = np.minimum(rr2, ub)
        per_x = inst.load_per_x_flat[i][cells]
        with np.errstate(divide="ignore", invalid="ignore"):
            kk_c = cells % inst.K
            nm_c = inst.nm[c_dest.reshape(-1)[cells]]
            gcap = (inst.comp_cap_coef[kk_c] * nm_c
                    - st.load.reshape(-1)[cells]) / per_x
        ub = np.where(per_x > 1e-18, np.minimum(ub, gcap), ub)
        alive = ub >= frac - 1e-9
        if not alive.all():
            cells = cells[alive]
            if cells.size == 0:
                return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest,
                                  caps=cache.caps0, admissible=cache.adm0,
                                  obj_after=cache.inf0, obj_removed=obj0)
        # Best-first cap verification: obj_after is `delta` plus a
        # constant, so walking candidates in ascending-delta order (stable
        # — flat-index ties keep the grid argmin's j-major order) and
        # stopping at the first one whose cap fits selects exactly the
        # argmin of obj_after over the admissible set, at the cost of a
        # few O(1) cap checks instead of a full cap pass.  Long undercap
        # runs fall back to one vectorized pass over the remaining cells.
        d_cells = delta.reshape(-1)[cells]
        cap_order = np.argsort(d_cells, kind="stable")
        found = -1
        cap_found = 0.0
        n_try = min(cap_order.size, 8)
        for t in range(n_try):
            f = int(cells[cap_order[t]])
            j2, k2 = f // inst.K, f % inst.K
            cap = max_commit(st, i, j2, k2, int(c_dest[j2, k2]), over=over)
            if cap >= frac - 1e-9:
                found, cap_found = f, cap
                break
        if found < 0 and cap_order.size > n_try:
            rest = cells[cap_order[n_try:]]
            caps_r = max_commit_cells(st, i, rest,
                                      c_dest.reshape(-1)[rest],
                                      d_sel.reshape(-1)[rest], over=over)
            hits = np.flatnonzero(caps_r >= frac - 1e-9)
            if hits.size:
                found = int(rest[hits[0]])
                cap_found = float(caps_r[hits[0]])
        if found < 0:
            return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest,
                              caps=cache.caps0, admissible=cache.adm0,
                              obj_after=cache.inf0, obj_removed=obj0)
        caps = np.zeros_like(d_sel)
        caps.reshape(-1)[found] = cap_found
        adm = np.zeros(ok.shape, dtype=bool)
        adm.reshape(-1)[found] = True
        obj_after = np.full_like(d_sel, np.inf)
        obj_after.reshape(-1)[found] = delta.reshape(-1)[found] + base
        return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest,
                          caps=caps, admissible=adm, obj_after=obj_after,
                          obj_removed=obj0)
    if cache is not None:
        # Rows are read on the pre-detach state: the removal below may
        # deactivate the source pair, and that transient must not enter
        # the cache.  The only cell where the rows can then disagree with
        # the detached state is the source itself, which the
        # `ok[j, k] = False` exclusion masks either way.
        c_dest, d_sel, ok_c, rental, _ = cache.rows(st, i)
    undo: list = []
    frac = remove_assignment(st, i, j, k, undo=undo)
    if cache is None:
        # Destination configs/delays: the precomputed M1 winner everywhere,
        # overwritten on the (few) active cells with the pair's own config.
        jj, kk = np.nonzero(st.q > 0.5)
        c_act = st.cfg[jj, kk]
        c_dest = inst.cfg_m1[i].copy()
        c_dest[jj, kk] = c_act
        d_sel = inst.m1_delay[i].copy()
        d_act = inst.D_cfg[i, jj, kk, c_act]
        d_sel[jj, kk] = d_act
        ok = inst.m1_feasible[i].copy()
        ok[jj, kk] = d_act <= inst.Delta[i]
        rental = inst.m1_rental[i].copy()
        rental[jj, kk] = 0.0
    else:
        ok = ok_c.copy()
    ok[j, k] = False
    obj0 = state_objective(st)
    # Delta objective of committing `frac` at each destination, mirroring
    # `commit` + `state_objective`: incremental rental (active pairs run at
    # their own config, so only fresh activations rent GPUs — the
    # precomputed M1 rental with active cells zeroed), first-admission
    # model storage, per-fraction data storage, routed delay, and the
    # absorbed unmet penalty (a destination-independent scalar).
    rr = float(st.r_rem[i])
    d_unmet = max(rr - frac, 0.0) - max(rr, 0.0)
    obj_after = (obj0 + inst.Delta_T * inst.phi[i] * d_unmet
                 + inst.Delta_T * (rental
                                   + np.where(st.z[i] < 0.5,
                                              inst.p_s_B[:, None], 0.0)
                                   + inst.p_s * inst.data_gb[i] * frac)
                 + inst.rho[i] * d_sel * 1e3 * frac)
    if improve_below is not None:
        ok &= obj_after < improve_below
        n_ok = int(np.count_nonzero(ok))
        if n_ok == 0:
            undo_all(st, undo)
            return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest,
                              caps=np.zeros_like(d_sel), admissible=ok,
                              obj_after=np.full_like(d_sel, np.inf),
                              obj_removed=obj0)
        if n_ok <= 6:
            # Few surviving candidates: O(1) scalar caps (identical
            # arithmetic) beat the full-grid batch pass.
            caps = np.zeros_like(d_sel)
            K = c_dest.shape[1]
            for f in np.flatnonzero(ok.ravel()):
                j2, k2 = int(f) // K, int(f) % K
                caps[j2, k2] = max_commit(st, i, j2, k2,
                                          int(c_dest[j2, k2]))
            adm = ok & (caps >= frac - 1e-9)
            obj_after = np.where(adm, obj_after, np.inf)
            undo_all(st, undo)
            return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest,
                              caps=caps, admissible=adm,
                              obj_after=obj_after, obj_removed=obj0)
    caps = max_commit_batch(st, i, np.where(ok, c_dest, -1), d_sel=d_sel)
    adm = ok & (caps >= frac - 1e-9)
    obj_after = np.where(adm, obj_after, np.inf)
    undo_all(st, undo)
    return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest, caps=caps,
                      admissible=adm, obj_after=obj_after, obj_removed=obj0)


@mutates("x", "z", "q", "cfg", "y", "r_rem", "E_used", "D_used", "spend",
         "kv_tok", "load", "stor_used", "uncovered")
def commit(st: State, i: int, j: int, k: int, c: int, frac: float,
           undo: list | None = None) -> None:
    """Apply an accepted assignment to the running state, maintaining every
    incremental aggregate.  When `undo` is given, push a record that
    `undo_all` restores exactly (bitwise)."""
    inst = st.inst
    if frac <= 0:
        return
    c_old = int(st.cfg[j, k])
    retime = c_old >= 0 and c_old != c
    if undo is not None:
        undo.append((
            "commit", i, j, k,
            float(st.x[i, j, k]), float(st.z[i, j, k]), float(st.q[j, k]),
            c_old, float(st.y[j, k]), float(st.r_rem[i]),
            float(st.E_used[i]), float(st.D_used[i]), st.spend,
            float(st.kv_tok[j, k]), float(st.load[j, k]),
            float(st.stor_used[i]),
            st.D_used.copy() if retime else None,
            i in st.uncovered))
    nm = int(inst.nm[c])
    inc_gpus = max(0, nm - int(st.y[j, k]))
    new_adm = st.z[i, j, k] < 0.5
    if retime:
        # Config change re-times previously routed traffic on this pair.
        x_col = st.x[:, j, k]
        st.D_used += np.where(
            x_col > 1e-12,
            (inst.D_cfg[:, j, k, c] - inst.D_cfg[:, j, k, c_old]) * x_col,
            0.0)
    st.x[i, j, k] += frac
    st.z[i, j, k] = 1.0
    st.q[j, k] = 1.0
    st.cfg[j, k] = c
    st.y[j, k] = nm
    st.r_rem[i] = max(0.0, st.r_rem[i] - frac)
    st.E_used[i] += inst.e_bar[i, j, k] * frac
    st.D_used[i] += inst.D_cfg[i, j, k, c] * frac
    st.kv_tok[j, k] += inst.kv_tok_per_x[i, j, k] * frac
    st.load[j, k] += inst.load_per_x[i, j, k] * frac
    st.stor_used[i] += (inst.B[j] if new_adm else 0.0) + inst.data_gb[i] * frac
    st.spend += inst.Delta_T * (
        inst.p_c[k] * inc_gpus
        + (inst.p_s * inst.B[j] if new_adm else 0.0)
        + inst.p_s * inst.data_gb[i] * frac)
    st.uncovered.discard(i)


@mutates("x", "z", "r_rem", "E_used", "D_used", "spend", "kv_tok", "load",
         "stor_used")
def remove_assignment(st: State, i: int, j: int, k: int,
                      undo: list | None = None, timed: bool = True,
                      auto_deactivate: bool = True) -> float:
    """Inverse delta of `commit`: take type i entirely off pair (j,k).

    Zeroes x/z for the cell and rolls every aggregate back by the removed
    fraction.  With `auto_deactivate`, a pair left without traffic is shut
    down (y/q/cfg cleared, all admissions on it dropped) — the relocate
    move's source-side semantics.  `timed=False` skips the D_used
    subtraction for pairs whose delay contribution was already suspended
    (consolidation).  Returns the removed fraction."""
    inst = st.inst
    frac = float(st.x[i, j, k])
    had_z = st.z[i, j, k] > 0.5
    c_jk = int(st.cfg[j, k])
    st.x[i, j, k] = 0.0
    deact = auto_deactivate and float(st.x[:, j, k].sum()) <= 1e-12
    if undo is not None:
        undo.append((
            "remove", i, j, k, frac, had_z, deact, c_jk,
            float(st.q[j, k]), float(st.y[j, k]),
            float(st.r_rem[i]), float(st.E_used[i]), float(st.D_used[i]),
            st.spend, float(st.kv_tok[j, k]), float(st.load[j, k]),
            st.stor_used.copy() if deact else float(st.stor_used[i]),
            st.z[:, j, k].copy() if deact else None))
    st.z[i, j, k] = 0.0
    st.r_rem[i] = st.r_rem[i] + frac
    st.E_used[i] -= inst.e_bar[i, j, k] * frac
    if timed and c_jk >= 0:
        st.D_used[i] -= inst.D_cfg[i, j, k, c_jk] * frac
    st.kv_tok[j, k] -= inst.kv_tok_per_x[i, j, k] * frac
    st.load[j, k] -= inst.load_per_x[i, j, k] * frac
    data = inst.data_gb[i] * frac
    weight = inst.B[j] if had_z else 0.0
    st.stor_used[i] -= data + weight
    st.spend -= inst.Delta_T * inst.p_s * (data + weight)
    if deact:
        deactivate_pair(st, j, k)
    return frac


@mutates("z", "q", "y", "cfg", "spend", "stor_used")
def deactivate_pair(st: State, j: int, k: int,
                    undo: list | None = None) -> None:
    """Shut pair (j,k) down: drop every remaining admission on it (model
    storage spend + per-type storage), refund the rental, clear y/q/cfg.
    With `undo`, push a record `undo_all` restores exactly; otherwise
    callers own the rollback (enclosing undo record or snapshot)."""
    inst = st.inst
    if undo is not None:
        undo.append(("deact", j, k, float(st.q[j, k]), float(st.y[j, k]),
                     int(st.cfg[j, k]), st.spend, st.z[:, j, k].copy(),
                     st.stor_used.copy()))
    others = st.z[:, j, k] > 0.5
    n_other = int(np.count_nonzero(others))
    if n_other:
        st.spend -= inst.Delta_T * inst.p_s * inst.B[j] * n_other
        st.stor_used[others] -= inst.B[j]
        st.z[:, j, k] = 0.0
    st.spend -= inst.Delta_T * inst.p_c[k] * float(st.y[j, k])
    st.q[j, k] = 0.0
    st.y[j, k] = 0.0
    st.cfg[j, k] = -1


@mutates("x", "z", "q", "cfg", "y", "r_rem", "E_used", "D_used", "spend",
         "kv_tok", "load", "stor_used", "uncovered")
def undo_all(st: State, undo: list) -> None:
    """Roll back every record pushed by `commit` / `remove_assignment`, in
    reverse order.  Restoration is exact: each record carries the previous
    raw values, so the state is bitwise-identical to before the moves."""
    while undo:
        rec = undo.pop()
        if rec[0] == "deact":
            (_, j, k, q0, y0, cfg0, sp0, zcol, stor0) = rec
            st.stor_used[:] = stor0
            st.z[:, j, k] = zcol
            st.q[j, k] = q0
            st.y[j, k] = y0
            st.cfg[j, k] = cfg0
            st.spend = sp0
        elif rec[0] == "commit":
            (_, i, j, k, x0, z0, q0, cfg0, y0, rr0, e0, d0, sp0,
             kv0, ld0, su0, dvec, unc_had) = rec
            st.x[i, j, k] = x0
            st.z[i, j, k] = z0
            st.q[j, k] = q0
            st.cfg[j, k] = cfg0
            st.y[j, k] = y0
            st.r_rem[i] = rr0
            st.E_used[i] = e0
            if dvec is not None:
                st.D_used[:] = dvec
            else:
                st.D_used[i] = d0
            st.spend = sp0
            st.kv_tok[j, k] = kv0
            st.load[j, k] = ld0
            st.stor_used[i] = su0
            if unc_had:
                st.uncovered.add(i)
        else:
            (_, i, j, k, frac, had_z, deact, cfg0, q0, y0,
             rr0, e0, d0, sp0, kv0, ld0, su0, zcol) = rec
            st.x[i, j, k] = frac
            st.q[j, k] = q0
            st.y[j, k] = y0
            st.cfg[j, k] = cfg0
            st.r_rem[i] = rr0
            st.E_used[i] = e0
            st.D_used[i] = d0
            st.spend = sp0
            st.kv_tok[j, k] = kv0
            st.load[j, k] = ld0
            if deact:
                st.stor_used[:] = su0
                st.z[:, j, k] = zcol
            else:
                st.stor_used[i] = su0
                st.z[i, j, k] = 1.0 if had_z else 0.0


# ---------------------------------------------------------------------------
# State-level objective / snapshots (AGH local search support)
# ---------------------------------------------------------------------------

def state_objective(st: State) -> float:
    """Objective (8a) straight from the running state: spend already holds
    rental + model storage + data storage; D_used is exactly proc_delay and
    clip(r_rem) is the unmet fraction.  O(I) — no einsum over [I,J,K,C]."""
    inst = st.inst
    unmet = np.clip(st.r_rem, 0.0, None)
    return float(st.spend + np.dot(inst.rho, st.D_used) * 1e3
                 + inst.Delta_T * np.dot(inst.phi, unmet))


def state_snapshot(st: State) -> tuple:
    """Deep copy of every mutable field (multi-step rollback)."""
    return (st.x.copy(), st.y.copy(), st.q.copy(), st.cfg.copy(),
            st.z.copy(), st.r_rem.copy(), st.E_used.copy(), st.D_used.copy(),
            st.spend, set(st.uncovered), st.kv_tok.copy(), st.load.copy(),
            st.stor_used.copy())


@mutates("x", "z", "q", "cfg", "y", "r_rem", "E_used", "D_used", "spend",
         "kv_tok", "load", "stor_used", "uncovered")
def state_restore(st: State, snap: tuple) -> None:
    (x, y, q, cfg, z, r_rem, E, D, spend, unc, kv, load, stor) = snap
    st.x[:] = x
    st.y[:] = y
    st.q[:] = q
    st.cfg[:] = cfg
    st.z[:] = z
    st.r_rem[:] = r_rem
    st.E_used[:] = E
    st.D_used[:] = D
    st.spend = spend
    st.uncovered = set(unc)
    st.kv_tok[:] = kv
    st.load[:] = load
    st.stor_used[:] = stor


def solution_from_state(inst: Instance, st: State):
    """Materialize a `Solution` from the running state (shared by GH/AGH)."""
    from .solution import Solution

    sol = Solution.empty(inst)
    sol.x, sol.y, sol.q, sol.z = st.x, st.y, st.q, st.z
    sol.u = np.clip(st.r_rem, 0.0, None)
    jj, kk = np.nonzero((st.q > 0.5) & (st.cfg >= 0))
    sol.w[jj, kk, st.cfg[jj, kk]] = 1.0
    return sol


@mutates("q", "cfg", "y", "spend")
def deployment_state(inst: Instance, sol, ablation: frozenset = frozenset()
                     ) -> State:
    """A fresh `State` seeded with an existing solution's DEPLOYMENT —
    active pairs, their configs, and their GPU counts — with all routing
    cleared (x = 0, every type fully unserved, z = 0).

    This is the warm-start entry point of AGH's replanning path: the
    incumbent's Stage-1 structure is kept, rentals are charged into
    `spend` (so the (8c) budget cap sees them), and GH Phase 2 then
    re-routes the *new* demand over that structure — activating extra
    pairs only where the incumbent's capacity cannot absorb the drift.
    The seeded state trivially satisfies every State invariant (all
    running aggregates are zero except `spend`), so commit/undo and the
    local-search engines operate on it unchanged.
    """
    st = State.fresh(inst, ablation=ablation)
    active = sol.q > 0.5
    has_cfg = sol.w.max(axis=2) > 0.5
    keep = active & has_cfg
    st.q[:] = np.where(keep, 1.0, 0.0)
    st.cfg[:] = np.where(keep, sol.w.argmax(axis=2), -1)
    st.y[:] = np.where(keep, sol.y, 0.0)
    st.spend = float(inst.Delta_T * np.sum(inst.p_c[None, :] * st.y))
    return st
