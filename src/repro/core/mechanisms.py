"""The three constraint-aware mechanisms shared by GH and AGH (paper §4.1).

M1 — TP-aware feasibility selection (eq. 9): for candidate (i,j,k), pick the
     cheapest (TP,PP) that simultaneously fits per-device memory and the
     delay SLO; discard the candidate if none exists.
M2 — cost-per-effective-coverage ranking (eqs. 10–11): rank candidates by
     incremental cost per unit of traffic they can actually absorb within
     the remaining error/delay budgets, with a full-coverage tie-breaker.
M3 — TP upgrade on active pairs (eq. 12): before activating a fresh pair,
     try a higher-parallelism configuration on an already-active pair,
     paying only the incremental GPU cost.

Vectorized engine notes
-----------------------
M1 winners are precomputed per instance (`Instance.cfg_m1`), M2 keys are
evaluated for all (j,k) at once (`rank_keys_all`), and the `State` carries
incremental aggregates — per-pair resident KV tokens (`kv_tok`), per-pair
compute load (`load`), and per-type storage (`stor_used`) — maintained by
`commit` / `remove_assignment` so that `max_commit` and the objective are
O(1) instead of O(I·J·K).  `commit` and `remove_assignment` optionally push
inverse records onto an undo list (`undo_all` rolls them back exactly),
which is what lets AGH's local search evaluate a move without copying the
solution.  The scalar seed implementations live in `_scalar_ref.py` and the
equivalence suite checks the two paths produce the same allocations.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .instance import Instance, KB_PER_GB


@dataclasses.dataclass
class State:
    """Running construction state (paper §4, 'Running state').

    Invariants maintained by `commit` / `remove_assignment` (and relied on
    by `max_commit` / `state_objective`):
      * kv_tok[j,k]   == sum_i kv_tok_per_x[i,j,k] * x[i,j,k]
      * load[j,k]     == sum_i load_per_x[i,j,k]   * x[i,j,k]
      * stor_used[i]  == sum_jk B[j]*z[i,j,k] + data_gb[i]*sum_jk x[i,j,k]
      * spend         == Delta_T*(sum p_c*y + p_s*(sum B*z + sum data_gb*x))
      * D_used[i]     == sum_jk D_cfg[i,j,k,cfg[j,k]] * x[i,j,k]  (over
                         active pairs), E_used likewise with e_bar
    up to float accumulation order (the equivalence tests allow 1e-9).
    """
    inst: Instance
    x: np.ndarray          # [I,J,K]
    y: np.ndarray          # [J,K]
    q: np.ndarray          # [J,K]
    cfg: np.ndarray        # [J,K] config index, -1 if inactive
    z: np.ndarray          # [I,J,K]
    r_rem: np.ndarray      # [I] remaining unserved fraction (tilde r)
    E_used: np.ndarray     # [I] cumulative error
    D_used: np.ndarray     # [I] cumulative delay
    spend: float           # committed budget $
    uncovered: set[int]    # I^unc
    kv_tok: np.ndarray     # [J,K] resident KV tokens routed to each pair
    load: np.ndarray       # [J,K] committed GFLOP load per pair
    stor_used: np.ndarray  # [I] storage GB committed per query type
    # Ablation switches (paper Table 3): subsets of
    # {"no_m1", "no_m2", "no_m3"}; used ONLY by the ablation benchmark.
    ablation: frozenset = frozenset()

    @staticmethod
    def fresh(inst: Instance, ablation: frozenset = frozenset()) -> "State":
        I, J, K = inst.I, inst.J, inst.K
        return State(inst=inst, x=np.zeros((I, J, K)), y=np.zeros((J, K)),
                     q=np.zeros((J, K)), cfg=-np.ones((J, K), dtype=int),
                     z=np.zeros((I, J, K)), r_rem=np.ones(I),
                     E_used=np.zeros(I), D_used=np.zeros(I), spend=0.0,
                     uncovered=set(range(I)), kv_tok=np.zeros((J, K)),
                     load=np.zeros((J, K)), stor_used=np.zeros(I),
                     ablation=ablation)


# ---------------------------------------------------------------------------
# M1
# ---------------------------------------------------------------------------

def m1_select(inst: Instance, i: int, j: int, k: int,
              ablation: frozenset = frozenset()) -> int | None:
    """Cheapest feasible config index for (i,j,k) per eq. (9), else None.

    O(1): the lex-(nm, delay, index)-minimal feasible config is precomputed
    per instance in `Instance.cfg_m1`."""
    if "no_m1" in ablation:
        # Cost-only: always "select" the cheapest config (nm = 1) without
        # the memory/delay filter (paper Table 3: memory violation).
        return inst.cfg_min_nm
    c = int(inst.cfg_m1[i, j, k])
    return None if c < 0 else c


# ---------------------------------------------------------------------------
# M3
# ---------------------------------------------------------------------------

def m3_upgrade(st: State, i: int, j: int, k: int) -> int | None:
    """Smallest config with nm > y_jk meeting the delay SLO within budget
    (eq. 12). Returns the config index or None.

    Candidate filtering is one mask over all configs; only the re-timing
    check walks the (nm, index)-sorted survivors, stopping at the first
    config that keeps every routed type within its SLO."""
    inst = st.inst
    y_cur = st.y[j, k]
    nm = inst.nm
    mask = ((nm > y_cur) & inst.mem_ok[j, k]
            & (inst.D_cfg[i, j, k] <= inst.Delta[i])
            & (st.spend + inst.Delta_T * inst.p_c[k] * (nm - y_cur)
               <= inst.delta))
    if not mask.any():
        return None
    c_old = int(st.cfg[j, k])
    if c_old < 0:
        for c in inst.cfg_by_nm:
            if mask[c]:
                return int(c)
        return None
    x_col = st.x[:, j, k]
    routed = x_col > 1e-12
    for c in inst.cfg_by_nm:
        if not mask[c]:
            continue
        # Upgrading the pair's config re-times every type already routed to
        # it; require the new config to keep all of them within their SLO.
        d_new = st.D_used + (inst.D_cfg[:, j, k, c]
                             - inst.D_cfg[:, j, k, c_old]) * x_col
        if np.any(d_new[routed] > inst.Delta[routed] + 1e-9):
            continue
        return int(c)
    return None


# ---------------------------------------------------------------------------
# M2 (plus the constraint checks of GH Step 4)
# ---------------------------------------------------------------------------

def effective_coverage(st: State, i: int, j: int, k: int, c: int) -> float:
    """x̄ per eq. (11): min of remaining demand, error slack, delay slack."""
    inst = st.inst
    e = inst.e_bar[i, j, k]
    d = inst.D_cfg[i, j, k, c]
    err_cap = (inst.eps[i] - st.E_used[i]) / max(e, 1e-12)
    del_cap = (inst.Delta[i] - st.D_used[i]) / max(d, 1e-12)
    if "no_m3" in st.ablation:
        # Ablated variant routes on whatever parallelism exists, blind to
        # the accumulated delay (paper Table 3: delay violation).
        del_cap = st.r_rem[i]
    return float(min(st.r_rem[i], err_cap, del_cap))


def delay_sel(inst: Instance, i: int, c_arr: np.ndarray) -> np.ndarray:
    """[J,K] delay of type i at each pair's selected config (config 0's
    value where `c_arr` is -1; dead cells are the caller's problem).  A flat
    fancy gather through `D_cfg_flat` — same values as the take_along_axis
    it replaces at a fraction of the per-call cost."""
    cc = np.maximum(c_arr, 0)
    return inst.D_cfg_flat[i, inst.jk_idx, cc.ravel()].reshape(c_arr.shape)


def rank_keys_all(st: State, i: int, c_arr: np.ndarray,
                  d_sel: np.ndarray | None = None
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched M2 keys for type i over every (model, tier) pair at once.

    `c_arr[J,K]` holds the candidate config per pair (-1 where none);
    `d_sel` optionally passes the already-gathered per-pair delay.
    Returns `(pi, kappa, valid)` arrays [J,K]; sorting valid candidates by
    (pi, kappa) with a stable sort reproduces the scalar candidate scan's
    ordering, including its j-major/k-minor tie-breaking."""
    inst = st.inst
    cc = np.maximum(c_arr, 0)
    d = delay_sel(inst, i, c_arr) if d_sel is None else d_sel
    r_rem = float(st.r_rem[i])
    err_cap = (inst.eps[i] - st.E_used[i]) / inst.e_bar_floor[i]
    del_cap = (inst.Delta[i] - st.D_used[i]) / np.maximum(d, 1e-12)
    if "no_m3" in st.ablation:
        del_cap = np.full_like(d, r_rem)
    xbar = np.minimum(np.minimum(r_rem, err_cap), del_cap)
    inc_gpus = np.maximum(0.0, inst.nm[cc] - st.y)
    cost = (inst.Delta_T * (inst.p_c[None, :] * inc_gpus
                            + inst.p_s * (inst.B[:, None] + inst.data_gb[i]))
            + inst.rho[i] * d * 1e3)
    live = xbar > 1e-9
    valid = (c_arr >= 0) & live
    if "no_m2" in st.ablation:
        # Raw-cost ranking, no effective-coverage normalization, no
        # full-coverage tie-breaker (paper Table 3: ~+50% cost).
        pi = np.zeros(c_arr.shape, dtype=np.int64)
        kappa = cost
    else:
        pi = (xbar < r_rem - 1e-9).astype(np.int64)
        kappa = np.divide(cost, xbar, out=np.full_like(cost, np.inf),
                          where=live)
    return pi, kappa, valid


# ---------------------------------------------------------------------------
# Commit machinery (GH Phase-2 Step 4): verify (8f)-(8h) + budget, commit.
# ---------------------------------------------------------------------------

def max_commit(st: State, i: int, j: int, k: int, c: int) -> float:
    """Largest additional fraction of type-i traffic committable to (j,k)
    at config c without violating (8f) memory, (8g) compute, (8h) storage,
    or the budget (8c).  O(1): reads the State's incremental aggregates."""
    inst = st.inst
    nm = float(inst.nm[c])
    cap = effective_coverage(st, i, j, k, c)
    # (8f): per-device memory headroom -> token budget -> x budget.
    if "no_m1" in st.ablation:
        pass  # ablated: commit blindly past the memory budget
    elif inst.kv_applicable[j]:
        head_gb = inst.C_gpu[k] - inst.B_eff[j, k] / nm \
            - (inst.beta[j] / KB_PER_GB) / nm * st.kv_tok[j, k]
        per_x = (inst.beta[j] / KB_PER_GB) / nm * inst.kv_tok_per_x[i, j, k]
        if per_x > 1e-18:
            cap = min(cap, head_gb / per_x)
        elif head_gb < 0:
            return 0.0
    else:
        if inst.C_gpu[k] - inst.B_eff[j, k] / nm < 0:
            return 0.0
    # (8g): compute headroom of the y GPUs this config provides.
    comp_cap = inst.eta * 3600.0 * inst.P_gpu[k] * nm
    per_x = inst.load_per_x[i, j, k]
    if per_x > 1e-18:
        cap = min(cap, (comp_cap - st.load[j, k]) / per_x)
    # (8h): storage headroom for type i.
    new_weight = inst.B[j] if st.z[i, j, k] < 0.5 else 0.0
    per_x = inst.data_gb[i]
    if per_x > 1e-18:
        cap = min(cap, (inst.C_s - st.stor_used[i] - new_weight) / per_x)
    # budget (8c): incremental rental + data storage per unit x.
    inc_gpus = max(0.0, inst.nm[c] - st.y[j, k])
    fixed = inst.Delta_T * (inst.p_c[k] * inc_gpus
                            + (inst.p_s * inst.B[j] if st.z[i, j, k] < 0.5 else 0.0))
    per_x = inst.budget_per_x[i]
    if st.spend + fixed > inst.delta:
        return 0.0
    if per_x > 1e-18:
        cap = min(cap, (inst.delta - st.spend - fixed) / per_x)
    return max(0.0, float(cap))


def max_commit_batch(st: State, i: int, c_arr: np.ndarray,
                     d_sel: np.ndarray | None = None) -> np.ndarray:
    """`max_commit` for type i over every (j,k) pair at once.

    `c_arr[J,K]` gives the config per pair (-1 -> cap 0).  Pure in the
    state, so one batched evaluation replaces a row of scalar calls as long
    as no commit happens in between — used by the batched relocate /
    consolidation destination scans.  `d_sel` optionally passes the
    already-gathered per-pair delay (`delay_sel`) so callers that need it
    anyway don't pay the gather twice.  Elementwise arithmetic mirrors
    `max_commit` exactly.
    """
    inst = st.inst
    cc = np.maximum(c_arr, 0)
    nm = inst.nm[cc]
    d = delay_sel(inst, i, c_arr) if d_sel is None else d_sel
    err_cap = (inst.eps[i] - st.E_used[i]) / inst.e_bar_floor[i]
    del_cap = (inst.Delta[i] - st.D_used[i]) / np.maximum(d, 1e-12)
    if "no_m3" in st.ablation:
        del_cap = np.full_like(d, float(st.r_rem[i]))
    cap = np.minimum(np.minimum(float(st.r_rem[i]), err_cap), del_cap)
    dead = c_arr < 0
    zm = st.z[i] < 0.5
    with np.errstate(divide="ignore", invalid="ignore"):
        # (8f)
        if "no_m1" not in st.ablation:
            b_dev = inst.B_eff / nm
            kvd = inst.kv_gb_per_tok[:, None] / nm
            head_gb = inst.C_gpu[None, :] - b_dev - kvd * st.kv_tok
            per_x = kvd * inst.kv_tok_per_x[i]
            kv = inst.kv_applicable[:, None]
            has_px = per_x > 1e-18
            # Unguarded divide: per_x == 0 cells produce inf/nan but are
            # never selected by the mask (errstate silences the warning).
            cap = np.where(kv & has_px, np.minimum(cap, head_gb / per_x),
                           cap)
            dead |= kv & ~has_px & (head_gb < 0)
            dead |= ~kv & (inst.C_gpu[None, :] - b_dev < 0)
        # (8g)
        per_x = inst.load_per_x[i]
        has_px = per_x > 1e-18
        cap = np.where(has_px,
                       np.minimum(cap, (inst.comp_cap_coef[None, :] * nm
                                        - st.load) / per_x),
                       cap)
        # (8h)
        new_weight = np.where(zm, inst.B[:, None], 0.0)
        if inst.data_gb[i] > 1e-18:
            cap = np.minimum(cap, (inst.C_s - st.stor_used[i] - new_weight)
                             / inst.data_gb[i])
        # budget (8c)
        inc_gpus = np.maximum(0.0, nm - st.y)
        fixed = inst.Delta_T * (inst.p_c[None, :] * inc_gpus
                                + np.where(zm, inst.p_s_B[:, None], 0.0))
        dead |= st.spend + fixed > inst.delta
        if inst.budget_per_x[i] > 1e-18:
            cap = np.minimum(cap, (inst.delta - st.spend - fixed)
                             / inst.budget_per_x[i])
    return np.where(dead, 0.0, np.maximum(0.0, cap))


@dataclasses.dataclass
class MoveScores:
    """Scored relocate destinations for one (i, j, k) source cell.

    Produced by `score_moves_batch`; `obj_after[j2,k2]` is the objective of
    the solution after moving the full fraction to (j2,k2) (`inf` where the
    move is inadmissible), `caps` the destination's (8c)-(8h) commit cap,
    `c_dest` the config the move would commit at, and `obj_removed` the
    objective of the intermediate source-removed state."""
    i: int
    j: int
    k: int
    frac: float
    c_dest: np.ndarray      # [J,K]
    caps: np.ndarray        # [J,K]
    admissible: np.ndarray  # [J,K] bool
    obj_after: np.ndarray   # [J,K]
    obj_removed: float


def score_moves_batch(st: State, i: int, j: int, k: int,
                      improve_below: float | None = None) -> MoveScores:
    """Score moving all of x[i,j,k] to every destination (j2,k2) at once.

    One pass replaces the scalar probe-per-destination loop: config
    selection (active pairs route at their current config, inactive pairs
    at the M1 winner), the delay/M1 admissibility masks, one
    `max_commit_batch` cap evaluation, and the vectorized delta objective
    of `commit_delta_batch`.  Admissibility and caps agree with sequential
    `_try_move` probing cell-for-cell (pinned by the property suite); the
    state is restored exactly before returning.

    With `improve_below`, destinations whose post-move objective is not
    strictly under the bound are filtered from `admissible` *before* the
    cap evaluation — the scan's fast path: a converged source pays only
    the delta arithmetic (caps stay zero, `obj_after` stays inf) and the
    expensive (8c)-(8h) pass runs only when an improving candidate exists.
    """
    inst = st.inst
    undo: list = []
    frac = remove_assignment(st, i, j, k, undo=undo)
    # Destination configs/delays: the precomputed M1 winner everywhere,
    # overwritten on the (few) active cells with the pair's own config.
    jj, kk = np.nonzero(st.q > 0.5)
    c_act = st.cfg[jj, kk]
    c_dest = inst.cfg_m1[i].copy()
    c_dest[jj, kk] = c_act
    d_sel = inst.m1_delay[i].copy()
    d_act = inst.D_cfg[i, jj, kk, c_act]
    d_sel[jj, kk] = d_act
    ok = inst.m1_feasible[i].copy()
    ok[jj, kk] = d_act <= inst.Delta[i]
    ok[j, k] = False
    obj0 = state_objective(st)
    # Delta objective of committing `frac` at each destination, mirroring
    # `commit` + `state_objective`: incremental rental (active pairs run at
    # their own config, so only fresh activations rent GPUs — the
    # precomputed M1 rental with active cells zeroed), first-admission
    # model storage, per-fraction data storage, routed delay, and the
    # absorbed unmet penalty (a destination-independent scalar).
    rental = inst.m1_rental[i].copy()
    rental[jj, kk] = 0.0
    rr = float(st.r_rem[i])
    d_unmet = max(rr - frac, 0.0) - max(rr, 0.0)
    obj_after = (obj0 + inst.Delta_T * inst.phi[i] * d_unmet
                 + inst.Delta_T * (rental
                                   + np.where(st.z[i] < 0.5,
                                              inst.p_s_B[:, None], 0.0)
                                   + inst.p_s * inst.data_gb[i] * frac)
                 + inst.rho[i] * d_sel * 1e3 * frac)
    if improve_below is not None:
        ok &= obj_after < improve_below
        n_ok = int(np.count_nonzero(ok))
        if n_ok == 0:
            undo_all(st, undo)
            return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest,
                              caps=np.zeros_like(d_sel), admissible=ok,
                              obj_after=np.full_like(d_sel, np.inf),
                              obj_removed=obj0)
        if n_ok <= 6:
            # Few surviving candidates: O(1) scalar caps (identical
            # arithmetic) beat the full-grid batch pass.
            caps = np.zeros_like(d_sel)
            K = c_dest.shape[1]
            for f in np.flatnonzero(ok.ravel()):
                j2, k2 = int(f) // K, int(f) % K
                caps[j2, k2] = max_commit(st, i, j2, k2,
                                          int(c_dest[j2, k2]))
            adm = ok & (caps >= frac - 1e-9)
            obj_after = np.where(adm, obj_after, np.inf)
            undo_all(st, undo)
            return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest,
                              caps=caps, admissible=adm,
                              obj_after=obj_after, obj_removed=obj0)
    caps = max_commit_batch(st, i, np.where(ok, c_dest, -1), d_sel=d_sel)
    adm = ok & (caps >= frac - 1e-9)
    obj_after = np.where(adm, obj_after, np.inf)
    undo_all(st, undo)
    return MoveScores(i=i, j=j, k=k, frac=frac, c_dest=c_dest, caps=caps,
                      admissible=adm, obj_after=obj_after, obj_removed=obj0)


def commit(st: State, i: int, j: int, k: int, c: int, frac: float,
           undo: list | None = None) -> None:
    """Apply an accepted assignment to the running state, maintaining every
    incremental aggregate.  When `undo` is given, push a record that
    `undo_all` restores exactly (bitwise)."""
    inst = st.inst
    if frac <= 0:
        return
    c_old = int(st.cfg[j, k])
    retime = c_old >= 0 and c_old != c
    if undo is not None:
        undo.append((
            "commit", i, j, k,
            float(st.x[i, j, k]), float(st.z[i, j, k]), float(st.q[j, k]),
            c_old, float(st.y[j, k]), float(st.r_rem[i]),
            float(st.E_used[i]), float(st.D_used[i]), st.spend,
            float(st.kv_tok[j, k]), float(st.load[j, k]),
            float(st.stor_used[i]),
            st.D_used.copy() if retime else None,
            i in st.uncovered))
    nm = int(inst.nm[c])
    inc_gpus = max(0, nm - int(st.y[j, k]))
    new_adm = st.z[i, j, k] < 0.5
    if retime:
        # Config change re-times previously routed traffic on this pair.
        x_col = st.x[:, j, k]
        st.D_used += np.where(
            x_col > 1e-12,
            (inst.D_cfg[:, j, k, c] - inst.D_cfg[:, j, k, c_old]) * x_col,
            0.0)
    st.x[i, j, k] += frac
    st.z[i, j, k] = 1.0
    st.q[j, k] = 1.0
    st.cfg[j, k] = c
    st.y[j, k] = nm
    st.r_rem[i] = max(0.0, st.r_rem[i] - frac)
    st.E_used[i] += inst.e_bar[i, j, k] * frac
    st.D_used[i] += inst.D_cfg[i, j, k, c] * frac
    st.kv_tok[j, k] += inst.kv_tok_per_x[i, j, k] * frac
    st.load[j, k] += inst.load_per_x[i, j, k] * frac
    st.stor_used[i] += (inst.B[j] if new_adm else 0.0) + inst.data_gb[i] * frac
    st.spend += inst.Delta_T * (
        inst.p_c[k] * inc_gpus
        + (inst.p_s * inst.B[j] if new_adm else 0.0)
        + inst.p_s * inst.data_gb[i] * frac)
    st.uncovered.discard(i)


def remove_assignment(st: State, i: int, j: int, k: int,
                      undo: list | None = None, timed: bool = True,
                      auto_deactivate: bool = True) -> float:
    """Inverse delta of `commit`: take type i entirely off pair (j,k).

    Zeroes x/z for the cell and rolls every aggregate back by the removed
    fraction.  With `auto_deactivate`, a pair left without traffic is shut
    down (y/q/cfg cleared, all admissions on it dropped) — the relocate
    move's source-side semantics.  `timed=False` skips the D_used
    subtraction for pairs whose delay contribution was already suspended
    (consolidation).  Returns the removed fraction."""
    inst = st.inst
    frac = float(st.x[i, j, k])
    had_z = st.z[i, j, k] > 0.5
    c_jk = int(st.cfg[j, k])
    st.x[i, j, k] = 0.0
    deact = auto_deactivate and float(st.x[:, j, k].sum()) <= 1e-12
    if undo is not None:
        undo.append((
            "remove", i, j, k, frac, had_z, deact, c_jk,
            float(st.q[j, k]), float(st.y[j, k]),
            float(st.r_rem[i]), float(st.E_used[i]), float(st.D_used[i]),
            st.spend, float(st.kv_tok[j, k]), float(st.load[j, k]),
            st.stor_used.copy() if deact else float(st.stor_used[i]),
            st.z[:, j, k].copy() if deact else None))
    st.z[i, j, k] = 0.0
    st.r_rem[i] = st.r_rem[i] + frac
    st.E_used[i] -= inst.e_bar[i, j, k] * frac
    if timed and c_jk >= 0:
        st.D_used[i] -= inst.D_cfg[i, j, k, c_jk] * frac
    st.kv_tok[j, k] -= inst.kv_tok_per_x[i, j, k] * frac
    st.load[j, k] -= inst.load_per_x[i, j, k] * frac
    data = inst.data_gb[i] * frac
    weight = inst.B[j] if had_z else 0.0
    st.stor_used[i] -= data + weight
    st.spend -= inst.Delta_T * inst.p_s * (data + weight)
    if deact:
        deactivate_pair(st, j, k)
    return frac


def deactivate_pair(st: State, j: int, k: int) -> None:
    """Shut pair (j,k) down: drop every remaining admission on it (model
    storage spend + per-type storage), refund the rental, clear y/q/cfg.
    Callers own the rollback (undo record or snapshot)."""
    inst = st.inst
    others = st.z[:, j, k] > 0.5
    n_other = int(np.count_nonzero(others))
    if n_other:
        st.spend -= inst.Delta_T * inst.p_s * inst.B[j] * n_other
        st.stor_used[others] -= inst.B[j]
        st.z[:, j, k] = 0.0
    st.spend -= inst.Delta_T * inst.p_c[k] * float(st.y[j, k])
    st.q[j, k] = 0.0
    st.y[j, k] = 0.0
    st.cfg[j, k] = -1


def undo_all(st: State, undo: list) -> None:
    """Roll back every record pushed by `commit` / `remove_assignment`, in
    reverse order.  Restoration is exact: each record carries the previous
    raw values, so the state is bitwise-identical to before the moves."""
    while undo:
        rec = undo.pop()
        if rec[0] == "commit":
            (_, i, j, k, x0, z0, q0, cfg0, y0, rr0, e0, d0, sp0,
             kv0, ld0, su0, dvec, unc_had) = rec
            st.x[i, j, k] = x0
            st.z[i, j, k] = z0
            st.q[j, k] = q0
            st.cfg[j, k] = cfg0
            st.y[j, k] = y0
            st.r_rem[i] = rr0
            st.E_used[i] = e0
            if dvec is not None:
                st.D_used[:] = dvec
            else:
                st.D_used[i] = d0
            st.spend = sp0
            st.kv_tok[j, k] = kv0
            st.load[j, k] = ld0
            st.stor_used[i] = su0
            if unc_had:
                st.uncovered.add(i)
        else:
            (_, i, j, k, frac, had_z, deact, cfg0, q0, y0,
             rr0, e0, d0, sp0, kv0, ld0, su0, zcol) = rec
            st.x[i, j, k] = frac
            st.q[j, k] = q0
            st.y[j, k] = y0
            st.cfg[j, k] = cfg0
            st.r_rem[i] = rr0
            st.E_used[i] = e0
            st.D_used[i] = d0
            st.spend = sp0
            st.kv_tok[j, k] = kv0
            st.load[j, k] = ld0
            if deact:
                st.stor_used[:] = su0
                st.z[:, j, k] = zcol
            else:
                st.stor_used[i] = su0
                st.z[i, j, k] = 1.0 if had_z else 0.0


# ---------------------------------------------------------------------------
# State-level objective / snapshots (AGH local search support)
# ---------------------------------------------------------------------------

def state_objective(st: State) -> float:
    """Objective (8a) straight from the running state: spend already holds
    rental + model storage + data storage; D_used is exactly proc_delay and
    clip(r_rem) is the unmet fraction.  O(I) — no einsum over [I,J,K,C]."""
    inst = st.inst
    unmet = np.clip(st.r_rem, 0.0, None)
    return float(st.spend + np.dot(inst.rho, st.D_used) * 1e3
                 + inst.Delta_T * np.dot(inst.phi, unmet))


def state_snapshot(st: State) -> tuple:
    """Deep copy of every mutable field (multi-step rollback)."""
    return (st.x.copy(), st.y.copy(), st.q.copy(), st.cfg.copy(),
            st.z.copy(), st.r_rem.copy(), st.E_used.copy(), st.D_used.copy(),
            st.spend, set(st.uncovered), st.kv_tok.copy(), st.load.copy(),
            st.stor_used.copy())


def state_restore(st: State, snap: tuple) -> None:
    (x, y, q, cfg, z, r_rem, E, D, spend, unc, kv, load, stor) = snap
    st.x[:] = x
    st.y[:] = y
    st.q[:] = q
    st.cfg[:] = cfg
    st.z[:] = z
    st.r_rem[:] = r_rem
    st.E_used[:] = E
    st.D_used[:] = D
    st.spend = spend
    st.uncovered = set(unc)
    st.kv_tok[:] = kv
    st.load[:] = load
    st.stor_used[:] = stor


def solution_from_state(inst: Instance, st: State):
    """Materialize a `Solution` from the running state (shared by GH/AGH)."""
    from .solution import Solution

    sol = Solution.empty(inst)
    sol.x, sol.y, sol.q, sol.z = st.x, st.y, st.q, st.z
    sol.u = np.clip(st.r_rem, 0.0, None)
    jj, kk = np.nonzero((st.q > 0.5) & (st.cfg >= 0))
    sol.w[jj, kk, st.cfg[jj, kk]] = 1.0
    return sol
