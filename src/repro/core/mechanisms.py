"""The three constraint-aware mechanisms shared by GH and AGH (paper §4.1).

M1 — TP-aware feasibility selection (eq. 9): for candidate (i,j,k), pick the
     cheapest (TP,PP) that simultaneously fits per-device memory and the
     delay SLO; discard the candidate if none exists.
M2 — cost-per-effective-coverage ranking (eqs. 10–11): rank candidates by
     incremental cost per unit of traffic they can actually absorb within
     the remaining error/delay budgets, with a full-coverage tie-breaker.
M3 — TP upgrade on active pairs (eq. 12): before activating a fresh pair,
     try a higher-parallelism configuration on an already-active pair,
     paying only the incremental GPU cost.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .instance import Instance, KB_PER_GB


@dataclasses.dataclass
class State:
    """Running construction state (paper §4, 'Running state')."""
    inst: Instance
    x: np.ndarray          # [I,J,K]
    y: np.ndarray          # [J,K]
    q: np.ndarray          # [J,K]
    cfg: np.ndarray        # [J,K] config index, -1 if inactive
    z: np.ndarray          # [I,J,K]
    r_rem: np.ndarray      # [I] remaining unserved fraction (tilde r)
    E_used: np.ndarray     # [I] cumulative error
    D_used: np.ndarray     # [I] cumulative delay
    spend: float           # committed budget $
    uncovered: set[int]    # I^unc
    # Ablation switches (paper Table 3): subsets of
    # {"no_m1", "no_m2", "no_m3"}; used ONLY by the ablation benchmark.
    ablation: frozenset = frozenset()

    @staticmethod
    def fresh(inst: Instance, ablation: frozenset = frozenset()) -> "State":
        I, J, K = inst.I, inst.J, inst.K
        return State(inst=inst, x=np.zeros((I, J, K)), y=np.zeros((J, K)),
                     q=np.zeros((J, K)), cfg=-np.ones((J, K), dtype=int),
                     z=np.zeros((I, J, K)), r_rem=np.ones(I),
                     E_used=np.zeros(I), D_used=np.zeros(I), spend=0.0,
                     uncovered=set(range(I)), ablation=ablation)


# ---------------------------------------------------------------------------
# M1
# ---------------------------------------------------------------------------

def m1_select(inst: Instance, i: int, j: int, k: int,
              ablation: frozenset = frozenset()) -> int | None:
    """Cheapest feasible config index for (i,j,k) per eq. (9), else None."""
    if "no_m1" in ablation:
        # Cost-only: always "select" the cheapest config (nm = 1) without
        # the memory/delay filter (paper Table 3: memory violation).
        return int(np.argmin(inst.nm))
    best, best_nm, best_d = None, np.inf, np.inf
    for c, (n, m) in enumerate(inst.configs):
        nm = n * m
        if inst.B_eff[j, k] / nm > inst.C_gpu[k]:
            continue
        d = inst.D_cfg[i, j, k, c]
        if d > inst.Delta[i]:
            continue
        if nm < best_nm or (nm == best_nm and d < best_d):
            best, best_nm, best_d = c, nm, d
    return best


# ---------------------------------------------------------------------------
# M3
# ---------------------------------------------------------------------------

def m3_upgrade(st: State, i: int, j: int, k: int) -> int | None:
    """Smallest config with nm > y_jk meeting the delay SLO within budget
    (eq. 12). Returns the config index or None."""
    inst = st.inst
    y_cur = st.y[j, k]
    best, best_nm = None, np.inf
    for c, (n, m) in enumerate(inst.configs):
        nm = n * m
        if nm <= y_cur or nm >= best_nm:
            continue
        if inst.B_eff[j, k] / nm > inst.C_gpu[k]:
            continue
        if inst.D_cfg[i, j, k, c] > inst.Delta[i]:
            continue
        inc_cost = inst.Delta_T * inst.p_c[k] * (nm - y_cur)
        if st.spend + inc_cost > inst.delta:
            continue
        # Upgrading the pair's config re-times every type already routed to
        # it; require the new config to keep all of them within their SLO.
        if st.cfg[j, k] >= 0 and not _retime_ok(st, j, k, c):
            continue
        best, best_nm = c, nm
    return best


def _retime_ok(st: State, j: int, k: int, c_new: int) -> bool:
    inst = st.inst
    c_old = st.cfg[j, k]
    for i2 in range(inst.I):
        if st.x[i2, j, k] <= 1e-12:
            continue
        d_new = (st.D_used[i2]
                 + (inst.D_cfg[i2, j, k, c_new] - inst.D_cfg[i2, j, k, c_old])
                 * st.x[i2, j, k])
        if d_new > inst.Delta[i2] + 1e-9:
            return False
    return True


# ---------------------------------------------------------------------------
# M2 (plus the constraint checks of GH Step 4)
# ---------------------------------------------------------------------------

def effective_coverage(st: State, i: int, j: int, k: int, c: int) -> float:
    """x̄ per eq. (11): min of remaining demand, error slack, delay slack."""
    inst = st.inst
    e = inst.e_bar[i, j, k]
    d = inst.D_cfg[i, j, k, c]
    err_cap = (inst.eps[i] - st.E_used[i]) / max(e, 1e-12)
    del_cap = (inst.Delta[i] - st.D_used[i]) / max(d, 1e-12)
    if "no_m3" in st.ablation:
        # Ablated variant routes on whatever parallelism exists, blind to
        # the accumulated delay (paper Table 3: delay violation).
        del_cap = st.r_rem[i]
    return float(min(st.r_rem[i], err_cap, del_cap))


def marginal_cost(st: State, i: int, j: int, k: int, c: int) -> float:
    """c^k_ij per eq. (10): incremental rental + storage + delay penalty."""
    inst = st.inst
    nm = inst.nm[c]
    inc_gpus = max(0.0, nm - st.y[j, k])
    data_gb = inst.theta[i] / KB_PER_GB * inst.r[i] * inst.lam[i]
    return (inst.Delta_T * (inst.p_c[k] * inc_gpus
                            + inst.p_s * (inst.B[j] + data_gb))
            + inst.rho[i] * inst.D_cfg[i, j, k, c] * 1e3)


def rank_key(st: State, i: int, j: int, k: int, c: int) -> tuple[int, float]:
    """M2 lexicographic key (pi, kappa)."""
    xbar = effective_coverage(st, i, j, k, c)
    if xbar <= 1e-9:
        return (2, np.inf)
    if "no_m2" in st.ablation:
        # Raw-cost ranking, no effective-coverage normalization, no
        # full-coverage tie-breaker (paper Table 3: ~+50% cost).
        return (0, marginal_cost(st, i, j, k, c))
    pi = int(xbar < st.r_rem[i] - 1e-9)
    kappa = marginal_cost(st, i, j, k, c) / xbar
    return (pi, kappa)


# ---------------------------------------------------------------------------
# Commit machinery (GH Phase-2 Step 4): verify (8f)-(8h) + budget, commit.
# ---------------------------------------------------------------------------

def _kv_tokens(st: State, j: int, k: int, extra_i: int | None = None,
               extra_x: float = 0.0) -> float:
    inst = st.inst
    t = float(np.sum(inst.r * inst.T_res[:, j, k] * st.x[:, j, k]))
    if extra_i is not None:
        t += inst.r[extra_i] * inst.T_res[extra_i, j, k] * extra_x
    return t


def max_commit(st: State, i: int, j: int, k: int, c: int) -> float:
    """Largest additional fraction of type-i traffic committable to (j,k)
    at config c without violating (8f) memory, (8g) compute, (8h) storage,
    or the budget (8c)."""
    inst = st.inst
    nm = float(inst.nm[c])
    cap = effective_coverage(st, i, j, k, c)
    # (8f): per-device memory headroom -> token budget -> x budget.
    if "no_m1" in st.ablation:
        pass  # ablated: commit blindly past the memory budget
    elif inst.kv_applicable[j]:
        head_gb = inst.C_gpu[k] - inst.B_eff[j, k] / nm \
            - (inst.beta[j] / KB_PER_GB) / nm * _kv_tokens(st, j, k)
        per_x = (inst.beta[j] / KB_PER_GB) / nm \
            * inst.r[i] * inst.T_res[i, j, k]
        if per_x > 1e-18:
            cap = min(cap, head_gb / per_x)
        elif head_gb < 0:
            return 0.0
    else:
        if inst.C_gpu[k] - inst.B_eff[j, k] / nm < 0:
            return 0.0
    # (8g): compute headroom of the y GPUs this config provides.
    load = float(np.sum(inst.alpha[:, j, k] * inst.r * inst.lam / 1e3
                        * st.x[:, j, k]))
    comp_cap = inst.eta * 3600.0 * inst.P_gpu[k] * nm
    per_x = inst.alpha[i, j, k] * inst.r[i] * inst.lam[i] / 1e3
    if per_x > 1e-18:
        cap = min(cap, (comp_cap - load) / per_x)
    # (8h): storage headroom for type i.
    stor_used = float(np.sum(inst.B[None, :, None] * st.z[i])
                      + np.sum(inst.theta[i] / KB_PER_GB * inst.r[i]
                               * inst.lam[i] * st.x[i]))
    new_weight = inst.B[j] if st.z[i, j, k] < 0.5 else 0.0
    per_x = inst.theta[i] / KB_PER_GB * inst.r[i] * inst.lam[i]
    if per_x > 1e-18:
        cap = min(cap, (inst.C_s - stor_used - new_weight) / per_x)
    # budget (8c): incremental rental + data storage per unit x.
    inc_gpus = max(0.0, inst.nm[c] - st.y[j, k])
    fixed = inst.Delta_T * (inst.p_c[k] * inc_gpus
                            + (inst.p_s * inst.B[j] if st.z[i, j, k] < 0.5 else 0.0))
    per_x = inst.Delta_T * inst.p_s * inst.theta[i] / KB_PER_GB \
        * inst.r[i] * inst.lam[i]
    if st.spend + fixed > inst.delta:
        return 0.0
    if per_x > 1e-18:
        cap = min(cap, (inst.delta - st.spend - fixed) / per_x)
    return max(0.0, float(cap))


def commit(st: State, i: int, j: int, k: int, c: int, frac: float) -> None:
    """Apply an accepted assignment to the running state."""
    inst = st.inst
    if frac <= 0:
        return
    nm = int(inst.nm[c])
    inc_gpus = max(0, nm - int(st.y[j, k]))
    new_adm = st.z[i, j, k] < 0.5
    # Config change re-times previously routed traffic on this pair.
    c_old = int(st.cfg[j, k])
    if c_old >= 0 and c_old != c:
        for i2 in range(inst.I):
            if st.x[i2, j, k] > 1e-12:
                st.D_used[i2] += (inst.D_cfg[i2, j, k, c]
                                  - inst.D_cfg[i2, j, k, c_old]) * st.x[i2, j, k]
    st.x[i, j, k] += frac
    st.z[i, j, k] = 1.0
    st.q[j, k] = 1.0
    st.cfg[j, k] = c
    st.y[j, k] = nm
    st.r_rem[i] = max(0.0, st.r_rem[i] - frac)
    st.E_used[i] += inst.e_bar[i, j, k] * frac
    st.D_used[i] += inst.D_cfg[i, j, k, c] * frac
    st.spend += inst.Delta_T * (
        inst.p_c[k] * inc_gpus
        + (inst.p_s * inst.B[j] if new_adm else 0.0)
        + inst.p_s * inst.theta[i] / KB_PER_GB * inst.r[i] * inst.lam[i] * frac)
    st.uncovered.discard(i)
