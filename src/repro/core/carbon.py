"""Carbon-intensity-aware tier costs — the paper's third named future-work
item ("carbon-intensity-aware tier costs").

Each tier gets an operational carbon rate (kgCO2e per GPU-hour = device
board power x PUE x grid intensity of the tier's region). Two planner
modes, both reusing the unmodified GH/AGH machinery:

  * carbon-priced: fold carbon into the effective rental price
        p_c' = p_c + carbon_price * carbon_rate            ($/h)
    (an internal carbon price in $/kgCO2e) — the planner then trades
    dollars against emissions continuously;
  * carbon-capped: treat the horizon's total emissions like the budget
    (8c): scale prices so that the dollar budget binds exactly when the
    carbon cap would — a conservative surrogate that keeps the MILP/
    heuristics unchanged (exact cap support would add one linear
    constraint to `milp.build`; the surrogate is what the heuristics use).

Carbon accounting of any solution is exact either way.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .instance import Instance
from .solution import Solution

# Board power (kW) per hardware family x PUE(1.2); grid intensity varies
# by deployment region per tier (kgCO2e/kWh).
_POWER_KW = {
    "A6000": 0.30, "RTX4090": 0.45, "A100-40": 0.40, "H100-80": 0.70,
    "v5e": 0.25, "v5p": 0.45, "v4": 0.35,
}
_DEFAULT_INTENSITY = 0.35          # kgCO2e/kWh (mixed grid)


def carbon_rates(inst: Instance,
                 intensity: dict[str, float] | None = None) -> np.ndarray:
    """kgCO2e per device-hour per tier [K]."""
    rates = np.zeros(inst.K)
    for k, name in enumerate(inst.tier_names):
        hw = name.split("-")[0]
        for key in _POWER_KW:
            if name.startswith(key):
                hw = key
                break
        kw = _POWER_KW.get(hw, 0.4)
        gi = (intensity or {}).get(name, _DEFAULT_INTENSITY)
        rates[k] = kw * 1.2 * gi
    return rates


def emissions(inst: Instance, sol: Solution,
              rates: np.ndarray | None = None) -> float:
    """Total kgCO2e over the horizon for a plan's provisioned devices."""
    if rates is None:
        rates = carbon_rates(inst)
    return float(inst.Delta_T * np.sum(rates[None, :] * sol.y))


def carbon_priced(inst: Instance, carbon_price: float = 0.15,
                  intensity: dict[str, float] | None = None) -> Instance:
    """Instance with carbon internal-priced into the rental rates
    (carbon_price in $/kgCO2e; 0.15 ≈ upper-bound EU ETS levels)."""
    rates = carbon_rates(inst, intensity)
    inst2 = dataclasses.replace(inst, p_c=inst.p_c + carbon_price * rates)
    inst2.__post_init__()
    return inst2
