"""Supply-side fault injection — the ROADMAP's "failures, spot tiers,
regions" scenario axis.

Every scenario family so far perturbs *demand* (`perturbed`, `stressed`,
the diurnal traces); production fleets also lose *supply*: spot-priced
tiers get revoked, a region's capacity drops mid-replay, a tier's rental
price spikes.  This module models those disruptions as typed, seeded
events composed into a `FaultSchedule` over replay windows, and turns a
schedule into effective per-window instances:

* Event taxonomy — `TierOutage` (a tier's capacity goes to zero),
  `SpotRevocation` (a fraction of a spot tier's pool is reclaimed),
  `CapacityShock` (fleet-wide or single-tier availability multiplier),
  `PriceSpike` (rental price multiplier), `Recovery` (clips every event
  active on a tier — "the provider restored capacity early").  All are
  frozen dataclasses over window indices: an event spans ``[t0, t1)``.
* `FaultSchedule.avail_frac(t)` / `price_mult(t)` fold the active events
  into per-tier multipliers (availability composes by min, prices by
  product); `change_points()` lists every window where the supply state
  differs from the previous window — the event-driven replan triggers.
* `apply_faults(inst, schedule, t)` materializes the effective instance
  for window t: prices scaled, `Instance.avail_gpus` capped at
  ``floor(frac * nominal)``.  With no nominal cap set, only full outages
  (frac == 0) bind — partial revocation of an unbounded fleet is a no-op
  by construction (documented; benchmarks set nominal caps from the
  initial plan's usage).
* Generators — `poisson_revocations` (seeded Poisson process per
  spot-priced tier), `diurnal_outages` (outage start times biased toward
  the demand peak — correlated failures are the hard case), plus
  `with_spot_tiers` to mark a subset of tiers spot-priced (discounted
  rental, revocation rate).
* Eviction — `lost_pairs` / `evict_unavailable`: which active pairs must
  be shut down so every tier fits its (newly reduced) cap, dropping the
  smallest deployments first (minimal disruption, deterministic order).
  This is the supply-side entry point of the repair path
  (`core.agh.agh_repair`, `planner.PlanSession.repair`).

Determinism: every generator takes an explicit seed and draws through
`np.random.default_rng` — the schedule for a given (instance, seed) is
reproducible byte for byte, and `repro.analysis.lint`'s RPR2xx rules
apply to this module like the rest of core/.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .instance import Instance
from .solution import Solution
from .trace import diurnal_multipliers

_EPS = 1e-9


# ---------------------------------------------------------------------------
# Event taxonomy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TierOutage:
    """Tier `tier` has zero capacity over windows [t0, t1)."""
    tier: int
    t0: int
    t1: int


@dataclasses.dataclass(frozen=True)
class SpotRevocation:
    """Fraction `frac` of tier `tier`'s pool is reclaimed over [t0, t1).

    ``frac=1.0`` (the default drawn by `poisson_revocations`) reclaims
    the whole tier — on an unbounded fleet that is the only binding
    shape; fractional revocations bind once `Instance.avail_gpus` sets a
    nominal pool size."""
    tier: int
    t0: int
    t1: int
    frac: float = 1.0


@dataclasses.dataclass(frozen=True)
class CapacityShock:
    """Availability multiplier `avail_frac` over [t0, t1) — fleet-wide
    when `tier` is None, else that tier only."""
    t0: int
    t1: int
    avail_frac: float
    tier: int | None = None


@dataclasses.dataclass(frozen=True)
class PriceSpike:
    """Rental price of tier `tier` multiplied by `mult` over [t0, t1)."""
    tier: int
    t0: int
    t1: int
    mult: float


@dataclasses.dataclass(frozen=True)
class Recovery:
    """At window `t`, every event active on `tier` ends early (all tiers
    when `tier` is None) — capacity restored ahead of schedule."""
    t: int
    tier: int | None = None


FaultEvent = TierOutage | SpotRevocation | CapacityShock | PriceSpike


def _event_tier(e: FaultEvent) -> int | None:
    return getattr(e, "tier", None)


# ---------------------------------------------------------------------------
# Schedule
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A set of fault events over an `n_windows`-window replay.

    The schedule is pure data: `avail_frac` / `price_mult` fold the
    events active at a window into per-tier multipliers, and
    `change_points` resolves every window at which the folded supply
    state changes — the replay's event-driven replan triggers.
    """
    n_windows: int
    events: tuple[FaultEvent | Recovery, ...] = ()

    @property
    def is_empty(self) -> bool:
        return not any(not isinstance(e, Recovery) for e in self.events)

    def _end(self, e: FaultEvent) -> int:
        """Effective end window of `e` after Recovery clipping."""
        t1 = int(e.t1)
        tier = _event_tier(e)
        for r in self.events:
            if not isinstance(r, Recovery):
                continue
            if r.tier is not None and tier is not None and r.tier != tier:
                continue
            if e.t0 < r.t < t1:
                t1 = int(r.t)
        return t1

    def active(self, t: int) -> tuple[FaultEvent, ...]:
        """Events in force at window t (Recovery clipping applied)."""
        return tuple(e for e in self.events
                     if not isinstance(e, Recovery)
                     and e.t0 <= t < self._end(e))

    def avail_frac(self, t: int, K: int) -> np.ndarray:
        """[K] per-tier availability multiplier at window t (min-composed)."""
        frac = np.ones(K)
        for e in self.active(t):
            if isinstance(e, TierOutage):
                frac[e.tier] = 0.0
            elif isinstance(e, SpotRevocation):
                frac[e.tier] = min(frac[e.tier], 1.0 - float(e.frac))
            elif isinstance(e, CapacityShock):
                if e.tier is None:
                    frac = np.minimum(frac, float(e.avail_frac))
                else:
                    frac[e.tier] = min(frac[e.tier], float(e.avail_frac))
        return np.clip(frac, 0.0, 1.0)

    def price_mult(self, t: int, K: int) -> np.ndarray:
        """[K] per-tier rental-price multiplier at window t (product)."""
        mult = np.ones(K)
        for e in self.active(t):
            if isinstance(e, PriceSpike):
                mult[e.tier] *= float(e.mult)
        return mult

    def state_key(self, t: int, K: int) -> bytes:
        """Hashable supply state at window t — equal keys mean the same
        effective instance (used to cache `apply_faults` materializations
        across windows)."""
        return (self.avail_frac(t, K).tobytes()
                + self.price_mult(t, K).tobytes())

    def change_points(self, K: int) -> list[int]:
        """Windows t >= 1 where the supply state differs from window t-1
        (sorted).  Window 0's state is the initial plan's problem, not a
        change."""
        pts = []
        prev = self.state_key(0, K)
        for t in range(1, self.n_windows):
            cur = self.state_key(t, K)
            if cur != prev:
                pts.append(t)
            prev = cur
        return pts


# ---------------------------------------------------------------------------
# Effective instances
# ---------------------------------------------------------------------------

def apply_faults(inst: Instance, schedule: FaultSchedule, t: int) -> Instance:
    """The effective instance at window t: rental prices scaled by the
    active price multipliers, `avail_gpus` capped at ``floor(frac *
    nominal)`` per tier.  Returns `inst` itself (no copy, no tensor
    rebuild) when nothing is active at t."""
    K = inst.K
    af = schedule.avail_frac(t, K)
    pm = schedule.price_mult(t, K)
    if np.all(af >= 1.0 - _EPS) and np.all(np.abs(pm - 1.0) <= _EPS):
        return inst
    changes: dict = {}
    if np.any(np.abs(pm - 1.0) > _EPS):
        changes["p_c"] = inst.p_c * pm
    if np.any(af < 1.0 - _EPS):
        if inst.avail_gpus is not None:
            nominal = np.asarray(inst.avail_gpus, float)
            changes["avail_gpus"] = np.where(
                af <= _EPS, 0.0, np.floor(nominal * af))
        else:
            # Unbounded nominal fleet: partial fractions cannot bind (a
            # fraction of infinity is infinity); full outages become a
            # zero cap, everything else stays unbounded.
            changes["avail_gpus"] = np.where(af <= _EPS, 0.0, np.inf)
    return dataclasses.replace(inst, **changes)


def with_spot_tiers(inst: Instance, tiers: np.ndarray,
                    discount: float = 0.8,
                    revoke_rate: float = 0.25) -> Instance:
    """Mark a subset of tiers spot-priced: rental discounted by
    `discount`, revocable at `revoke_rate` Poisson revocations/hour.
    `tiers` is a [K] boolean mask or an index array."""
    mask = np.zeros(inst.K, dtype=bool)
    tiers = np.asarray(tiers)
    if tiers.dtype == bool:
        mask[:] = tiers
    else:
        mask[tiers] = True
    return dataclasses.replace(
        inst,
        p_c=np.where(mask, inst.p_c * discount, inst.p_c),
        spot=mask,
        revoke_rate=np.where(mask, float(revoke_rate), 0.0))


# ---------------------------------------------------------------------------
# Seeded generators
# ---------------------------------------------------------------------------

def poisson_revocations(inst: Instance, n_windows: int,
                        window_h: float | None = None, seed: int = 0,
                        frac: float = 1.0,
                        duration_windows: int | None = None
                        ) -> list[SpotRevocation]:
    """Seeded Poisson revocation process per spot-priced tier.

    Each tier with ``revoke_rate > 0`` draws revocation events at its
    rate (events/hour x `window_h` hours per window); each event
    reclaims `frac` of the tier's pool for `duration_windows` windows
    (default: ~1 hour's worth, at least one window).  Deterministic for
    a given (instance, seed) pair."""
    if inst.revoke_rate is None:
        return []
    if window_h is None:
        window_h = 24.0 / n_windows
    if duration_windows is None:
        duration_windows = max(1, int(round(1.0 / window_h)))
    rng = np.random.default_rng(seed)
    events: list[SpotRevocation] = []
    for k in range(inst.K):
        rate = float(inst.revoke_rate[k])
        if rate <= 0.0:
            continue
        # Exponential inter-arrival times in hours over the replay span.
        t_h = float(rng.exponential(1.0 / rate))
        span_h = n_windows * window_h
        while t_h < span_h:
            t0 = int(t_h / window_h)
            if t0 < n_windows:
                events.append(SpotRevocation(
                    tier=k, t0=t0,
                    t1=min(n_windows, t0 + duration_windows),
                    frac=float(frac)))
            t_h += float(rng.exponential(1.0 / rate))
    return events


def diurnal_outages(inst: Instance, n_windows: int, n_events: int,
                    seed: int = 0, day: str = "busy",
                    duration_windows: int | None = None
                    ) -> list[TierOutage]:
    """Outages whose start times are biased toward the diurnal demand
    peak — correlated supply loss under load is the stress case the
    repair path must survive.  Tiers are drawn uniformly; start windows
    are drawn proportionally to the diurnal multiplier."""
    if duration_windows is None:
        duration_windows = max(1, n_windows // 12)
    rng = np.random.default_rng(seed)
    mult = diurnal_multipliers(day, seed=seed, n_windows=n_windows)
    p = np.asarray(mult, float)
    p = p / p.sum()
    starts = rng.choice(n_windows, size=n_events, p=p)
    tiers = rng.integers(0, inst.K, size=n_events)
    return [TierOutage(tier=int(k), t0=int(t0),
                       t1=min(n_windows, int(t0) + duration_windows))
            for k, t0 in zip(tiers, starts, strict=True)]


# ---------------------------------------------------------------------------
# Eviction (the supply-side entry point of the repair path)
# ---------------------------------------------------------------------------

def lost_pairs(inst: Instance, y: np.ndarray) -> list[tuple[int, int]]:
    """Pairs to evict so every tier fits its availability cap.

    Per over-subscribed tier, active pairs are dropped smallest-y-first
    (ties by model index) until the tier is within its cap —
    deterministic, minimal-disruption.  Empty when no caps are set or
    nothing is over."""
    if inst.avail_gpus is None:
        return []
    y = np.asarray(y, float)
    out: list[tuple[int, int]] = []
    for k in range(inst.K):
        cap = float(inst.avail_gpus[k])
        used = float(y[:, k].sum())
        if used <= cap + _EPS:
            continue
        jj = np.nonzero(y[:, k] > 0.5)[0]
        for j in jj[np.lexsort((jj, y[jj, k]))]:
            out.append((int(j), int(k)))
            used -= float(y[j, k])
            if used <= cap + _EPS:
                break
    return out


def evict_unavailable(inst: Instance, sol: Solution
                      ) -> tuple[Solution, list[tuple[int, int]]]:
    """Solution-level eviction: zero out the pairs on lost capacity and
    push their routed traffic into unmet — what a frozen static
    placement actually serves while operated through a fault."""
    lost = lost_pairs(inst, sol.y)
    if not lost:
        return sol, []
    out = sol.copy()
    for (j, k) in lost:
        out.x[:, j, k] = 0.0
        out.z[:, j, k] = 0.0
        out.q[j, k] = 0.0
        out.y[j, k] = 0.0
        out.w[j, k, :] = 0.0
    out.u = np.clip(1.0 - out.x.sum(axis=(1, 2)), 0.0, 1.0)
    return out, lost
