"""Synthetic Azure-style diurnal trace (paper §5.3).

The public Azure LLM Inference Trace is not downloadable in this offline
container; this module synthesizes a per-window demand-multiplier series
matching the statistics the paper reports for its replay:

  * 288 five-minute windows over a 24 h horizon;
  * ~10x peak-to-trough ratio on the "busy" day (2024-05-14 analogue) with
    an early-morning trough (~28 k/h) and an evening peak (~300 k/h);
  * ~15.6x ratio on the more volatile second day (2024-05-15 analogue);
  * heavy-tailed short-horizon noise on top of the diurnal envelope.

The multiplier is relative to the day average; the replay scales each query
type's nominal arrival rate by it, exactly as the paper does.
"""
from __future__ import annotations

import numpy as np

WINDOWS_PER_DAY = 288


def diurnal_multipliers(day: str = "busy", seed: int = 7,
                        n_windows: int = WINDOWS_PER_DAY) -> np.ndarray:
    """Per-window demand multiplier (mean ≈ 1) for a synthetic trace day."""
    rng = np.random.default_rng(seed + {"busy": 0, "volatile": 1}[day])
    t = np.arange(n_windows) / n_windows            # 0..1 day fraction
    # Trough around 04:30, evening peak around 20:00 — two-harmonic shape.
    phase = 2 * np.pi * (t - 20.0 / 24.0)
    base = 1.0 + 0.72 * np.cos(phase) + 0.18 * np.cos(2 * phase + 0.9)
    base = np.clip(base, 0.05, None)
    if day == "volatile":
        base = base ** 1.35                          # deepen trough/peak
    # Heavy-tailed multiplicative noise (lognormal).
    noise = np.exp(rng.normal(0.0, 0.06 if day == "busy" else 0.10, n_windows))
    series = base * noise
    series = series / series.mean()
    return series


def multi_day_multipliers(days=("busy", "volatile"), seed: int = 7,
                          n_windows: int = WINDOWS_PER_DAY) -> np.ndarray:
    """Concatenated multi-day replay series: one diurnal multiplier block
    per entry of `days` ("busy"/"volatile"), each with its own noise draw
    (seed offset per position so repeated day types differ).  `n_windows`
    is windows PER DAY; the result has len(days)*n_windows windows."""
    return np.concatenate([
        diurnal_multipliers(day, seed=seed + 11 * idx, n_windows=n_windows)
        for idx, day in enumerate(days)])


def peak_to_trough(series: np.ndarray) -> float:
    return float(series.max() / series.min())


def random_walk_lambdas(lam0: np.ndarray, sigma: float, n_windows: int,
                        rng: np.random.Generator) -> np.ndarray:
    """Geometric random walk demand path (paper Table 4):
    lam^{t+1} = lam^t * exp(N(0, sigma)), per query type."""
    I = len(lam0)
    out = np.empty((n_windows, I))
    lam = lam0.astype(float).copy()
    for tstep in range(n_windows):
        out[tstep] = lam
        lam = lam * np.exp(rng.normal(0.0, sigma, I))
    return out
