"""Scalar reference implementations of GH / AGH (pre-vectorization).

This module freezes the original pure-Python triple-loop allocation path —
per-candidate `m1_select` config scans, per-candidate `rank_key` evaluation,
from-scratch `State` rebuilds for every trial move, and full
`objective()`/`is_feasible()` recomputation per local-search step.

It exists ONLY as the behavioral oracle for the vectorized engine in
`mechanisms.py` / `gh.py` / `agh.py`: `tests/test_vectorized_equivalence.py`
asserts that the fast path returns the same solutions (same active pairs and
configs, objectives within 1e-9) as this reference on default, random, and
stressed instances.  It is intentionally slow (the (20,20,20) AGH takes ~8 s
here vs < 1 s on the vectorized path) and must not be used by any production
caller.

Every function is a verbatim copy of the seed implementation; only the
sharing with the live module differs — the reference recomputes each
aggregate (KV tokens, compute load, per-type storage, spend) from the raw
x/y/q/z arrays instead of reading the incremental `State` fields.
"""
from __future__ import annotations

import numpy as np

from .instance import KB_PER_GB, Instance
from .mechanisms import State
from .solution import Solution, is_feasible, objective

# ---------------------------------------------------------------------------
# Mechanisms (scalar)
# ---------------------------------------------------------------------------


def m1_select_ref(inst: Instance, i: int, j: int, k: int,
                  ablation: frozenset = frozenset()) -> int | None:
    """Cheapest feasible config index for (i,j,k) per eq. (9), else None."""
    if "no_m1" in ablation:
        return int(np.argmin(inst.nm))
    best, best_nm, best_d = None, np.inf, np.inf
    for c, (n, m) in enumerate(inst.configs):
        nm = n * m
        if inst.B_eff[j, k] / nm > inst.C_gpu[k]:
            continue
        d = inst.D_cfg[i, j, k, c]
        if d > inst.Delta[i]:
            continue
        if nm < best_nm or (nm == best_nm and d < best_d):
            best, best_nm, best_d = c, nm, d
    return best


def m3_upgrade_ref(st: State, i: int, j: int, k: int) -> int | None:
    inst = st.inst
    y_cur = st.y[j, k]
    best, best_nm = None, np.inf
    for c, (n, m) in enumerate(inst.configs):
        nm = n * m
        if nm <= y_cur or nm >= best_nm:
            continue
        if inst.B_eff[j, k] / nm > inst.C_gpu[k]:
            continue
        if inst.D_cfg[i, j, k, c] > inst.Delta[i]:
            continue
        inc_cost = inst.Delta_T * inst.p_c[k] * (nm - y_cur)
        if st.spend + inc_cost > inst.delta:
            continue
        if st.cfg[j, k] >= 0 and not _retime_ok_ref(st, j, k, c):
            continue
        best, best_nm = c, nm
    return best


def _retime_ok_ref(st: State, j: int, k: int, c_new: int) -> bool:
    inst = st.inst
    c_old = st.cfg[j, k]
    for i2 in range(inst.I):
        if st.x[i2, j, k] <= 1e-12:
            continue
        d_new = (st.D_used[i2]
                 + (inst.D_cfg[i2, j, k, c_new] - inst.D_cfg[i2, j, k, c_old])
                 * st.x[i2, j, k])
        if d_new > inst.Delta[i2] + 1e-9:
            return False
    return True


def effective_coverage_ref(st: State, i: int, j: int, k: int, c: int) -> float:
    inst = st.inst
    e = inst.e_bar[i, j, k]
    d = inst.D_cfg[i, j, k, c]
    err_cap = (inst.eps[i] - st.E_used[i]) / max(e, 1e-12)
    del_cap = (inst.Delta[i] - st.D_used[i]) / max(d, 1e-12)
    if "no_m3" in st.ablation:
        del_cap = st.r_rem[i]
    return float(min(st.r_rem[i], err_cap, del_cap))


def marginal_cost_ref(st: State, i: int, j: int, k: int, c: int) -> float:
    inst = st.inst
    nm = inst.nm[c]
    inc_gpus = max(0.0, nm - st.y[j, k])
    data_gb = inst.theta[i] / KB_PER_GB * inst.r[i] * inst.lam[i]
    return (inst.Delta_T * (inst.p_c[k] * inc_gpus
                            + inst.p_s * (inst.B[j] + data_gb))
            + inst.rho[i] * inst.D_cfg[i, j, k, c] * 1e3)


def rank_key_ref(st: State, i: int, j: int, k: int, c: int) -> tuple[int, float]:
    xbar = effective_coverage_ref(st, i, j, k, c)
    if xbar <= 1e-9:
        return (2, np.inf)
    if "no_m2" in st.ablation:
        return (0, marginal_cost_ref(st, i, j, k, c))
    pi = int(xbar < st.r_rem[i] - 1e-9)
    kappa = marginal_cost_ref(st, i, j, k, c) / xbar
    return (pi, kappa)


def _kv_tokens_ref(st: State, j: int, k: int) -> float:
    inst = st.inst
    return float(np.sum(inst.r * inst.T_res[:, j, k] * st.x[:, j, k]))


def max_commit_ref(st: State, i: int, j: int, k: int, c: int) -> float:
    """From-scratch (8f)/(8g)/(8h)/(8c) cap computation over the raw state."""
    inst = st.inst
    nm = float(inst.nm[c])
    cap = effective_coverage_ref(st, i, j, k, c)
    if "no_m1" in st.ablation:
        pass
    elif inst.kv_applicable[j]:
        head_gb = inst.C_gpu[k] - inst.B_eff[j, k] / nm \
            - (inst.beta[j] / KB_PER_GB) / nm * _kv_tokens_ref(st, j, k)
        per_x = (inst.beta[j] / KB_PER_GB) / nm \
            * inst.r[i] * inst.T_res[i, j, k]
        if per_x > 1e-18:
            cap = min(cap, head_gb / per_x)
        elif head_gb < 0:
            return 0.0
    else:
        if inst.C_gpu[k] - inst.B_eff[j, k] / nm < 0:
            return 0.0
    load = float(np.sum(inst.alpha[:, j, k] * inst.r * inst.lam / 1e3
                        * st.x[:, j, k]))
    comp_cap = inst.eta * 3600.0 * inst.P_gpu[k] * nm
    per_x = inst.alpha[i, j, k] * inst.r[i] * inst.lam[i] / 1e3
    if per_x > 1e-18:
        cap = min(cap, (comp_cap - load) / per_x)
    stor_used = float(np.sum(inst.B[None, :, None] * st.z[i])
                      + np.sum(inst.theta[i] / KB_PER_GB * inst.r[i]
                               * inst.lam[i] * st.x[i]))
    new_weight = inst.B[j] if st.z[i, j, k] < 0.5 else 0.0
    per_x = inst.theta[i] / KB_PER_GB * inst.r[i] * inst.lam[i]
    if per_x > 1e-18:
        cap = min(cap, (inst.C_s - stor_used - new_weight) / per_x)
    inc_gpus = max(0.0, inst.nm[c] - st.y[j, k])
    fixed = inst.Delta_T * (inst.p_c[k] * inc_gpus
                            + (inst.p_s * inst.B[j] if st.z[i, j, k] < 0.5 else 0.0))
    per_x = inst.Delta_T * inst.p_s * inst.theta[i] / KB_PER_GB \
        * inst.r[i] * inst.lam[i]
    if st.spend + fixed > inst.delta:
        return 0.0
    if per_x > 1e-18:
        cap = min(cap, (inst.delta - st.spend - fixed) / per_x)
    return max(0.0, float(cap))


def commit_ref(st: State, i: int, j: int, k: int, c: int, frac: float) -> None:
    """The seed commit: per-cell updates plus a Python retime loop. Does not
    maintain the incremental aggregates of the vectorized State."""
    inst = st.inst
    if frac <= 0:
        return
    nm = int(inst.nm[c])
    inc_gpus = max(0, nm - int(st.y[j, k]))
    new_adm = st.z[i, j, k] < 0.5
    c_old = int(st.cfg[j, k])
    if c_old >= 0 and c_old != c:
        for i2 in range(inst.I):
            if st.x[i2, j, k] > 1e-12:
                st.D_used[i2] += (inst.D_cfg[i2, j, k, c]
                                  - inst.D_cfg[i2, j, k, c_old]) * st.x[i2, j, k]
    st.x[i, j, k] += frac
    st.z[i, j, k] = 1.0
    st.q[j, k] = 1.0
    st.cfg[j, k] = c
    st.y[j, k] = nm
    st.r_rem[i] = max(0.0, st.r_rem[i] - frac)
    st.E_used[i] += inst.e_bar[i, j, k] * frac
    st.D_used[i] += inst.D_cfg[i, j, k, c] * frac
    st.spend += inst.Delta_T * (
        inst.p_c[k] * inc_gpus
        + (inst.p_s * inst.B[j] if new_adm else 0.0)
        + inst.p_s * inst.theta[i] / KB_PER_GB * inst.r[i] * inst.lam[i] * frac)
    st.uncovered.discard(i)


# ---------------------------------------------------------------------------
# GH (scalar)
# ---------------------------------------------------------------------------

def _phase1_ref(st: State) -> None:
    inst = st.inst
    while st.uncovered and st.spend < inst.phase1_beta * inst.delta:
        best = None  # (score, j, k, cfg_idx, nm, members)
        for j in range(inst.J):
            for k in range(inst.K):
                if st.q[j, k] > 0.5:
                    continue
                members, worst_c, worst_nm = [], None, 0
                for i in sorted(st.uncovered):
                    c = m1_select_ref(inst, i, j, k, ablation=st.ablation)
                    if c is None or inst.e_bar[i, j, k] > inst.eps[i]:
                        continue
                    members.append(i)
                    if inst.nm[c] > worst_nm:
                        worst_nm, worst_c = int(inst.nm[c]), c
                if not members:
                    continue
                cost = inst.Delta_T * inst.p_c[k] * worst_nm   # eq. (14)
                if st.spend + cost > inst.phase1_beta * inst.delta:
                    continue
                score = len(members) / cost
                if best is None or score > best[0]:
                    best = (score, j, k, worst_c, worst_nm, members)
        if best is None:
            break
        _, j, k, c, nm, members = best
        st.q[j, k] = 1.0
        st.cfg[j, k] = c
        st.y[j, k] = nm
        st.spend += inst.Delta_T * inst.p_c[k] * nm
        for i in members:
            st.uncovered.discard(i)


def _phase2_ref(st: State, order: np.ndarray) -> None:
    inst = st.inst
    for i in order:
        i = int(i)
        cands: list[tuple[tuple[int, float], int, int, int]] = []
        for j in range(inst.J):
            for k in range(inst.K):
                if st.q[j, k] > 0.5:
                    c = int(st.cfg[j, k])
                    if inst.D_cfg[i, j, k, c] > inst.Delta[i]:
                        if "no_m3" in st.ablation:
                            pass                               # route anyway
                        else:
                            c2 = m3_upgrade_ref(st, i, j, k)   # M3
                            if c2 is None:
                                continue
                            c = c2
                else:
                    c0 = m1_select_ref(inst, i, j, k,
                                       ablation=st.ablation)   # M1
                    if c0 is None:
                        continue
                    c = c0
                key = rank_key_ref(st, i, j, k, c)             # M2
                if not np.isfinite(key[1]):
                    continue
                cands.append((key, j, k, c))
        cands.sort(key=lambda t: t[0])
        for key, j, k, c in cands:
            if st.r_rem[i] <= 1e-9:
                break
            if st.q[j, k] > 0.5 and c != st.cfg[j, k] and inst.nm[c] <= st.y[j, k]:
                c_use = int(st.cfg[j, k])
                if inst.D_cfg[i, j, k, c_use] > inst.Delta[i]:
                    continue
            else:
                c_use = c
            frac = min(st.r_rem[i], max_commit_ref(st, i, j, k, c_use))
            if frac <= 1e-9:
                continue
            commit_ref(st, i, j, k, c_use, frac)


def gh_scalar(inst: Instance, order: np.ndarray | None = None,
              run_phase1: bool = True,
              ablation: frozenset = frozenset()) -> tuple[Solution, State]:
    """Reference single-pass GH; mirrors `gh.greedy_heuristic`."""
    st = State.fresh(inst, ablation=ablation)
    if run_phase1:
        _phase1_ref(st)
    if order is None:
        order = np.argsort(-inst.lam)
    _phase2_ref(st, np.asarray(order))
    sol = Solution.empty(inst)
    sol.x, sol.y, sol.q, sol.z = st.x, st.y, st.q, st.z
    sol.u = np.clip(st.r_rem, 0.0, None)
    for j in range(inst.J):
        for k in range(inst.K):
            if st.q[j, k] > 0.5 and st.cfg[j, k] >= 0:
                sol.w[j, k, int(st.cfg[j, k])] = 1.0
    sol.method = "GH-ref"
    return sol, st


# ---------------------------------------------------------------------------
# AGH (scalar): from-scratch state rebuilds per trial move
# ---------------------------------------------------------------------------

def _rebuild_state_ref(inst: Instance, sol: Solution) -> State:
    st = State.fresh(inst)
    st.x = sol.x.copy()
    st.y = sol.y.copy()
    st.q = sol.q.copy()
    st.z = sol.z.copy()
    st.cfg = np.where(sol.q > 0.5, np.argmax(sol.w, axis=2), -1)
    st.r_rem = np.clip(1.0 - sol.x.sum(axis=(1, 2)), 0.0, None)
    st.E_used = np.einsum("ijk,ijk->i", inst.e_bar, sol.x)
    xw = sol.x[:, :, :, None] * sol.w[None, :, :, :]
    st.D_used = np.einsum("ijkc,ijkc->i", xw, inst.D_cfg)
    data = inst.Delta_T * inst.p_s * float(np.sum(
        inst.theta[:, None, None] / KB_PER_GB * inst.r[:, None, None]
        * inst.lam[:, None, None] * sol.x))
    st.spend = (inst.Delta_T * float(np.sum(inst.p_c[None, :] * sol.y))
                + inst.Delta_T * inst.p_s * float(np.sum(inst.B[None, :, None] * sol.z))
                + data)
    st.uncovered = set()
    return st


def _solution_from_state_ref(inst: Instance, st: State) -> Solution:
    sol = Solution.empty(inst)
    sol.x, sol.y, sol.q, sol.z = st.x, st.y, st.q, st.z
    sol.u = np.clip(st.r_rem, 0.0, None)
    for j in range(inst.J):
        for k in range(inst.K):
            if st.q[j, k] > 0.5 and st.cfg[j, k] >= 0:
                sol.w[j, k, int(st.cfg[j, k])] = 1.0
    return sol


def _try_move_ref(inst: Instance, sol: Solution, i: int, j: int, k: int,
                  j2: int, k2: int, best_obj: float) -> Solution | None:
    frac = sol.x[i, j, k]
    trial = sol.copy()
    trial.x[i, j, k] = 0.0
    trial.z[i, j, k] = 0.0
    if trial.x[:, j, k].sum() <= 1e-12:
        trial.q[j, k] = 0.0
        trial.y[j, k] = 0.0
        trial.w[j, k, :] = 0.0
        trial.z[:, j, k] = 0.0
    st = _rebuild_state_ref(inst, trial)
    if st.q[j2, k2] > 0.5:
        c = int(st.cfg[j2, k2])
        if inst.D_cfg[i, j2, k2, c] > inst.Delta[i]:
            return None
    else:
        c = m1_select_ref(inst, i, j2, k2)
        if c is None:
            return None
    if max_commit_ref(st, i, j2, k2, c) < frac - 1e-9:
        return None
    commit_ref(st, i, j2, k2, c, frac)
    cand = _solution_from_state_ref(inst, st)
    if not is_feasible(inst, cand, enforce_zeta=False):
        return None
    if objective(inst, cand) < best_obj - 1e-9:
        return cand
    return None


def _move_targets_ref(inst: Instance, sol: Solution, i: int,
                      n_inactive: int = 3) -> list[tuple[int, int]]:
    active = [(j, k) for j in range(inst.J) for k in range(inst.K)
              if sol.q[j, k] > 0.5]
    inactive = []
    for j in range(inst.J):
        for k in range(inst.K):
            if sol.q[j, k] > 0.5:
                continue
            c = m1_select_ref(inst, i, j, k)
            if c is None or inst.e_bar[i, j, k] > inst.eps[i]:
                continue
            inactive.append((inst.p_c[k] * inst.nm[c], j, k))
    inactive.sort()
    return active + [(j, k) for _, j, k in inactive[:n_inactive]]


def _relocate_ref(inst: Instance, sol: Solution, L: int) -> Solution:
    for _ in range(L):
        improved = False
        obj = objective(inst, sol)
        for i in range(inst.I):
            assigned = [(j, k) for j in range(inst.J) for k in range(inst.K)
                        if sol.x[i, j, k] > 1e-9]
            for (j, k) in assigned:
                for (j2, k2) in _move_targets_ref(inst, sol, i):
                    if (j2, k2) == (j, k):
                        continue
                    cand = _try_move_ref(inst, sol, i, j, k, j2, k2, obj)
                    if cand is not None:
                        sol = cand
                        obj = objective(inst, sol)
                        improved = True
                        break
        if not improved:
            break
    return sol


def _consolidate_ref(inst: Instance, sol: Solution) -> Solution:
    while True:
        active = [(float(sol.y[j, k]), j, k)
                  for j in range(inst.J) for k in range(inst.K)
                  if sol.q[j, k] > 0.5]
        active.sort()
        improved = False
        for _, j, k in active:
            types = [i for i in range(inst.I) if sol.x[i, j, k] > 1e-9]
            trial = sol.copy()
            obj = objective(inst, sol)
            ok = True
            for i in types:
                frac = trial.x[i, j, k]
                trial.x[i, j, k] = 0.0
                trial.z[i, j, k] = 0.0
                st = _rebuild_state_ref(inst, trial)
                st.q[j, k] = 0.0  # forbid re-landing on the pair being drained
                placed = False
                for j2 in range(inst.J):
                    for k2 in range(inst.K):
                        if (j2, k2) == (j, k) or st.q[j2, k2] < 0.5:
                            continue
                        c = int(st.cfg[j2, k2])
                        if inst.D_cfg[i, j2, k2, c] > inst.Delta[i]:
                            continue
                        if max_commit_ref(st, i, j2, k2, c) >= frac - 1e-9:
                            commit_ref(st, i, j2, k2, c, frac)
                            trial = _solution_from_state_ref(inst, st)
                            placed = True
                            break
                    if placed:
                        break
                if not placed:
                    ok = False
                    break
            if not ok:
                continue
            trial.q[j, k] = 0.0
            trial.y[j, k] = 0.0
            trial.w[j, k, :] = 0.0
            trial.z[:, j, k] = 0.0
            if (is_feasible(inst, trial, enforce_zeta=False)
                    and objective(inst, trial) < obj - 1e-9):
                sol = trial
                improved = True
                break
        if not improved:
            return sol


def agh_scalar(inst: Instance, R: int | None = None, L: int = 3, seed: int = 0,
               patience: int = 5) -> Solution:
    """Reference AGH; mirrors `agh.agh` (same orderings / early stop)."""
    from .agh import _adaptive_R, _orderings

    rng = np.random.default_rng(seed)
    if R is None:
        R = _adaptive_R(inst)
    best: Solution | None = None
    best_obj = np.inf
    stale = 0
    for order in _orderings(inst, R, rng):
        sol, _ = gh_scalar(inst, order=order)
        sol = _relocate_ref(inst, sol, L)
        sol = _consolidate_ref(inst, sol)
        obj = objective(inst, sol)
        if obj < best_obj - 1e-9:
            best, best_obj = sol, obj
            stale = 0
        else:
            stale += 1
            if stale >= patience:
                break
    assert best is not None
    best.method = "AGH-ref"
    return best


# ---------------------------------------------------------------------------
# Stage-2 LP (scalar assembly) — frozen PR-1-era reference
# ---------------------------------------------------------------------------

def stage2_lp_ref(inst: Instance, deploy: Solution,
                  u_cap: np.ndarray | None = None,
                  allow_any_deployed: bool = False):
    """Verbatim copy of the pre-vectorization `stage2_lp`: Python
    dict-of-tuples constraint assembly, one matrix rebuilt per call.
    Oracle for `tests/test_stage2_equivalence.py` only."""
    from scipy import sparse
    from scipy.optimize import linprog

    I, J, K = inst.I, inst.J, inst.K
    if u_cap is None:
        u_cap = inst.zeta
    pairs = [(j, k) for j in range(J) for k in range(K) if deploy.q[j, k] > 0.5]
    cfg = {p: int(np.argmax(deploy.w[p[0], p[1]])) for p in pairs}
    adm = []
    for i in range(I):
        for (j, k) in pairs:
            if allow_any_deployed or deploy.z[i, j, k] > 0.5:
                adm.append((i, j, k))
    nx = len(adm)
    n = nx + I                                    # x's then u's
    col_x = {t: idx for idx, t in enumerate(adm)}

    def solve(cap: np.ndarray):
        rows, cols, vals, lbs, ubs = [], [], [], [], []
        row = 0

        def add(entries, lb, ub):
            nonlocal row
            for cc, vv in entries:
                rows.append(row); cols.append(cc); vals.append(vv)
            lbs.append(lb); ubs.append(ub)
            row += 1

        # (8b)
        for i in range(I):
            ent = [(col_x[(i, j, k)], 1.0) for (ii, j, k) in adm if ii == i]
            ent.append((nx + i, 1.0))
            add(ent, 1.0, 1.0)
        # (8f) memory per active pair (weight shard fixed; KV linear in x)
        for (j, k) in pairs:
            c = cfg[(j, k)]
            nm = float(inst.nm[c])
            if not inst.kv_applicable[j]:
                continue
            ent = []
            for i in range(I):
                if (i, j, k) in col_x:
                    coef = (inst.beta[j] / KB_PER_GB / nm
                            * inst.r[i] * inst.T_res[i, j, k])
                    ent.append((col_x[(i, j, k)], coef))
            if ent:
                add(ent, -np.inf,
                    inst.C_gpu[k] - inst.B_eff[j, k] / nm)
        # (8g) compute per active pair
        for (j, k) in pairs:
            ent = []
            for i in range(I):
                if (i, j, k) in col_x:
                    ent.append((col_x[(i, j, k)],
                                inst.alpha[i, j, k] * inst.r[i] * inst.lam[i] / 1e3))
            if ent:
                add(ent, -np.inf,
                    inst.eta * 3600.0 * inst.P_gpu[k] * float(deploy.y[j, k]))
        # (8h) storage per type
        for i in range(I):
            ent = []
            base = float(np.sum(inst.B[None, :, None] * deploy.z[i]))
            for (ii, j, k) in adm:
                if ii == i:
                    ent.append((col_x[(i, j, k)],
                                inst.theta[i] / KB_PER_GB
                                * inst.r[i] * inst.lam[i]))
            if ent:
                add(ent, -np.inf, inst.C_s - base)
        # (8i) delay
        for i in range(I):
            ent = []
            for (ii, j, k) in adm:
                if ii == i:
                    ent.append((col_x[(i, j, k)],
                                float(inst.D_cfg[i, j, k, cfg[(j, k)]])))
            if ent:
                add(ent, -np.inf, float(inst.Delta[i]))
        # (8j) error
        for i in range(I):
            ent = [(col_x[(i, j, k)], float(inst.e_bar[i, j, k]))
                   for (ii, j, k) in adm if ii == i]
            if ent:
                add(ent, -np.inf, float(inst.eps[i]))

        A = sparse.csr_matrix((vals, (rows, cols)), shape=(row, n))
        c_obj = np.zeros(n)
        for (i, j, k), idx in col_x.items():
            c_obj[idx] += (inst.Delta_T * inst.p_s * inst.theta[i] / KB_PER_GB
                           * inst.r[i] * inst.lam[i])
            c_obj[idx] += inst.rho[i] * 1e3 * float(
                inst.D_cfg[i, j, k, cfg[(j, k)]])
        for i in range(I):
            c_obj[nx + i] = inst.Delta_T * inst.phi[i]
        bounds = [(0.0, 1.0)] * nx + [(0.0, float(cap[i])) for i in range(I)]
        lbs_a, ubs_a = np.array(lbs), np.array(ubs)
        eq_mask = lbs_a == ubs_a
        res = linprog(c_obj,
                      A_ub=A[~eq_mask], b_ub=ubs_a[~eq_mask],
                      A_eq=A[eq_mask], b_eq=ubs_a[eq_mask],
                      bounds=bounds, method="highs")
        return res

    res = solve(u_cap)
    capped_ok = res.status == 0
    if not capped_ok:
        res = solve(np.ones(I))
    sol = Solution.empty(inst)
    sol.y, sol.q, sol.w, sol.z = (deploy.y.copy(), deploy.q.copy(),
                                  deploy.w.copy(), deploy.z.copy())
    if res.status == 0:
        for (i, j, k), idx in col_x.items():
            sol.x[i, j, k] = res.x[idx]
        sol.u = np.clip(res.x[nx:], 0.0, 1.0)
    else:  # fully unserved fallback (deployment cannot route anything)
        sol.u = np.ones(I)
    sol.method = deploy.method + "+stage2"
    return sol, capped_ok
