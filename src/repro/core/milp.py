"""Exact MILP for `P_DM` (paper §3.2), solved with scipy's HiGHS backend.

Gurobi is unavailable offline; HiGHS is an exact branch-and-cut MILP solver
with the same time-limit semantics, so the "DM" column remains the true
optimum wherever the solver converges within its cap.

Variable vector layout (concatenated):
    x  [I*J*K]   continuous routing fractions in [0,1]
    u  [I]       continuous unmet fractions in [0, zeta_i]
    y  [J*K]     integer GPU counts in [0, max(n*m)]
    q  [J*K]     binary deployment flags
    w  [J*K*C]   binary joint (TP,PP) selectors
    z  [I*J*K]   binary admission flags
    v  [I*J*K*C] continuous McCormick auxiliaries (eq. 7)
"""
from __future__ import annotations

import time

import numpy as np
from scipy import sparse
from scipy.optimize import Bounds, LinearConstraint, milp

from .instance import KB_PER_GB, Instance
from .solution import Solution


class _Index:
    def __init__(self, inst: Instance):
        I, J, K, C = inst.I, inst.J, inst.K, inst.n_cfg
        self.I, self.J, self.K, self.C = I, J, K, C
        self.nx = I * J * K
        self.nu = I
        self.ny = J * K
        self.nq = J * K
        self.nw = J * K * C
        self.nz = I * J * K
        self.nv = I * J * K * C
        ofs = 0
        self.ox = ofs
        ofs += self.nx
        self.ou = ofs
        ofs += self.nu
        self.oy = ofs
        ofs += self.ny
        self.oq = ofs
        ofs += self.nq
        self.ow = ofs
        ofs += self.nw
        self.oz = ofs
        ofs += self.nz
        self.ov = ofs
        ofs += self.nv
        self.n = ofs

    def x(self, i, j, k): return self.ox + (i * self.J + j) * self.K + k
    def u(self, i): return self.ou + i
    def y(self, j, k): return self.oy + j * self.K + k
    def q(self, j, k): return self.oq + j * self.K + k
    def w(self, j, k, c): return self.ow + (j * self.K + k) * self.C + c
    def z(self, i, j, k): return self.oz + (i * self.J + j) * self.K + k
    def v(self, i, j, k, c):
        return self.ov + ((i * self.J + j) * self.K + k) * self.C + c


def build(inst: Instance):
    """Build (c, LinearConstraint, integrality, Bounds) for `P_DM`."""
    ix = _Index(inst)
    I, J, K, C = ix.I, ix.J, ix.K, ix.C
    rows, cols, vals, lbs, ubs = [], [], [], [], []
    row = 0

    def add(entries, lb, ub):
        nonlocal row
        for col, val in entries:
            rows.append(row)
            cols.append(col)
            vals.append(val)
        lbs.append(lb)
        ubs.append(ub)
        row += 1

    # (8b) sum_jk x + u = 1
    for i in range(I):
        ent = [(ix.x(i, j, k), 1.0) for j in range(J) for k in range(K)]
        ent.append((ix.u(i), 1.0))
        add(ent, 1.0, 1.0)
    # (8c) budget
    ent = []
    for j in range(J):
        for k in range(K):
            ent.append((ix.y(j, k), inst.Delta_T * inst.p_c[k]))
    for i in range(I):
        for j in range(J):
            for k in range(K):
                ent.append((ix.z(i, j, k), inst.Delta_T * inst.p_s * inst.B[j]))
                ent.append((ix.x(i, j, k),
                            inst.Delta_T * inst.p_s * inst.theta[i] / KB_PER_GB
                            * inst.r[i] * inst.lam[i]))
    add(ent, -np.inf, inst.delta)
    # (8d) sum_c w = q ; (8e) y = sum_c nm w
    for j in range(J):
        for k in range(K):
            add([*((ix.w(j, k, c), 1.0) for c in range(C)), (ix.q(j, k), -1.0)],
                0.0, 0.0)
            add([(ix.y(j, k), 1.0),
                 *((ix.w(j, k, c), -float(inst.nm[c])) for c in range(C))],
                0.0, 0.0)
    # (8f) per-device memory
    for j in range(J):
        for k in range(K):
            ent = []
            for c in range(C):
                nm = float(inst.nm[c])
                ent.append((ix.w(j, k, c), inst.B_eff[j, k] / nm))
                if inst.kv_applicable[j]:
                    for i in range(I):
                        coef = (inst.beta[j] / KB_PER_GB / nm
                                * inst.r[i] * inst.T_res[i, j, k])
                        if coef:
                            ent.append((ix.v(i, j, k, c), coef))
                else:
                    ent.append((ix.w(j, k, c),
                                inst.beta[j] / KB_PER_GB * 64.0 / nm))
            ent.append((ix.q(j, k), -float(inst.C_gpu[k])))
            add(ent, -np.inf, 0.0)
    # (8g) compute throughput
    for j in range(J):
        for k in range(K):
            ent = [(ix.x(i, j, k),
                    inst.alpha[i, j, k] * inst.r[i] * inst.lam[i] / 1e3)
                   for i in range(I)]
            ent.append((ix.y(j, k), -inst.eta * 3600.0 * inst.P_gpu[k]))
            add(ent, -np.inf, 0.0)
    # (8h) storage per type
    for i in range(I):
        ent = []
        for j in range(J):
            for k in range(K):
                ent.append((ix.z(i, j, k), inst.B[j]))
                ent.append((ix.x(i, j, k),
                            inst.theta[i] / KB_PER_GB
                            * inst.r[i] * inst.lam[i]))
        add(ent, -np.inf, inst.C_s)
    # (8i) delay SLO via McCormick v
    for i in range(I):
        ent = [(ix.v(i, j, k, c), float(inst.D_cfg[i, j, k, c]))
               for j in range(J) for k in range(K) for c in range(C)]
        add(ent, -np.inf, float(inst.Delta[i]))
    # (8j) error SLO
    for i in range(I):
        ent = [(ix.x(i, j, k), float(inst.e_bar[i, j, k]))
               for j in range(J) for k in range(K)]
        add(ent, -np.inf, float(inst.eps[i]))
    # (8k) x <= z <= q
    for i in range(I):
        for j in range(J):
            for k in range(K):
                add([(ix.x(i, j, k), 1.0), (ix.z(i, j, k), -1.0)], -np.inf, 0.0)
                add([(ix.z(i, j, k), 1.0), (ix.q(j, k), -1.0)], -np.inf, 0.0)
    # (7) McCormick envelopes
    for i in range(I):
        for j in range(J):
            for k in range(K):
                for c in range(C):
                    add([(ix.v(i, j, k, c), 1.0), (ix.x(i, j, k), -1.0)],
                        -np.inf, 0.0)
                    add([(ix.v(i, j, k, c), 1.0), (ix.w(j, k, c), -1.0)],
                        -np.inf, 0.0)
                    add([(ix.x(i, j, k), 1.0), (ix.w(j, k, c), 1.0),
                         (ix.v(i, j, k, c), -1.0)], -np.inf, 1.0)

    A = sparse.csr_matrix((vals, (rows, cols)), shape=(row, ix.n))
    constraint = LinearConstraint(A, np.array(lbs), np.array(ubs))

    # Objective (8a)
    cobj = np.zeros(ix.n)
    for j in range(J):
        for k in range(K):
            cobj[ix.y(j, k)] += inst.Delta_T * inst.p_c[k]
    for i in range(I):
        cobj[ix.u(i)] += inst.Delta_T * inst.phi[i]
        for j in range(J):
            for k in range(K):
                cobj[ix.z(i, j, k)] += inst.Delta_T * inst.p_s * inst.B[j]
                cobj[ix.x(i, j, k)] += (inst.Delta_T * inst.p_s
                                        * inst.theta[i] / KB_PER_GB
                                        * inst.r[i] * inst.lam[i])
                for c in range(C):
                    cobj[ix.v(i, j, k, c)] += (inst.rho[i] * 1e3
                                               * inst.D_cfg[i, j, k, c])

    lo = np.zeros(ix.n)
    hi = np.ones(ix.n)
    hi[ix.oy:ix.oy + ix.ny] = float(np.max(inst.nm))
    for i in range(I):
        hi[ix.u(i)] = float(inst.zeta[i])
    integrality = np.zeros(ix.n)
    integrality[ix.oy:ix.oy + ix.ny] = 1
    integrality[ix.oq:ix.oq + ix.nq] = 1
    integrality[ix.ow:ix.ow + ix.nw] = 1
    integrality[ix.oz:ix.oz + ix.nz] = 1
    return cobj, constraint, integrality, Bounds(lo, hi), ix


def _extract(inst: Instance, ix: _Index, sol_vec: np.ndarray) -> Solution:
    I, J, K, C = ix.I, ix.J, ix.K, ix.C
    s = Solution.empty(inst)
    for i in range(I):
        s.u[i] = sol_vec[ix.u(i)]
        for j in range(J):
            for k in range(K):
                s.x[i, j, k] = sol_vec[ix.x(i, j, k)]
                s.z[i, j, k] = round(sol_vec[ix.z(i, j, k)])
    for j in range(J):
        for k in range(K):
            s.y[j, k] = round(sol_vec[ix.y(j, k)])
            s.q[j, k] = round(sol_vec[ix.q(j, k)])
            for c in range(C):
                s.w[j, k, c] = round(sol_vec[ix.w(j, k, c)])
    s.x = np.clip(s.x, 0.0, 1.0)
    s.u = np.clip(s.u, 0.0, None)
    return s


def solve_milp(inst: Instance, time_limit: float = 600.0,
               mip_rel_gap: float = 1e-3, relax: bool = False) -> Solution:
    """Solve `P_DM` exactly (or its LP relaxation with relax=True)."""
    t0 = time.perf_counter()
    c, constraint, integrality, bounds, ix = build(inst)
    if relax:
        integrality = np.zeros_like(integrality)
    res = milp(c, constraints=[constraint], integrality=integrality,
               bounds=bounds,
               options=dict(time_limit=time_limit, mip_rel_gap=mip_rel_gap,
                            disp=False))
    dt = time.perf_counter() - t0
    if res.x is None:
        s = Solution.empty(inst)
        s.runtime_s = dt
        s.method = "DM(timeout)" if not relax else "LP(fail)"
        return s
    s = _extract(inst, ix, res.x)
    s.runtime_s = dt
    s.method = "DM" if not relax else "LP-relax"
    return s


def lp_relaxation_values(inst: Instance, time_limit: float = 120.0):
    """Raw fractional variable vector of the LP relaxation (for LPR)."""
    c, constraint, integrality, bounds, ix = build(inst)
    res = milp(c, constraints=[constraint],
               integrality=np.zeros_like(integrality), bounds=bounds,
               options=dict(time_limit=time_limit, disp=False))
    return res.x, ix
