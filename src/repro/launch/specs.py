"""ShapeDtypeStruct input stand-ins for every (architecture × input shape).

No device allocation happens here — everything is `jax.eval_shape` /
`ShapeDtypeStruct`, the pattern required for the multi-pod dry-run.

Input shapes (assignment):
    train_4k     seq=4,096    global_batch=256   (training)
    prefill_32k  seq=32,768   global_batch=32    (inference prefill)
    decode_32k   seq=32,768   global_batch=128   (one-token decode vs cache)
    long_500k    seq=524,288  global_batch=1     (long-context decode)

[vlm]/[audio] carve-out: the modality frontend is a stub — `input_specs`
supplies pre-projected patch/conditioning embeddings of the right shape;
the text length shrinks so prefix + text == the assigned seq_len.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models import decoder
from ..models.config import ModelConfig

SHAPES = {
    "train_4k": dict(seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
}


@dataclasses.dataclass
class ShapeCase:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


def shape_case(name: str) -> ShapeCase:
    s = SHAPES[name]
    return ShapeCase(name=name, **s)


def applicable(cfg: ModelConfig, case: ShapeCase) -> tuple[bool, str]:
    """long_500k requires a sub-quadratic serving path (DESIGN.md
    §Arch-applicability); every other (arch, shape) pair runs."""
    if case.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("full-attention arch without sliding-window variant; "
                       "O(seq^2)/O(seq) decode at 524k is out of scope "
                       "(skip noted in DESIGN.md)")
    return True, ""


def _tok_sds(cfg: ModelConfig, B: int, T: int) -> jax.ShapeDtypeStruct:
    shape = (B, T, cfg.n_codebooks) if cfg.n_codebooks else (B, T)
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def input_specs(cfg: ModelConfig, case: ShapeCase) -> dict:
    """ShapeDtypeStruct stand-ins for the step function's inputs."""
    B = case.global_batch
    P = cfg.n_prefix_embeds
    if case.kind == "train":
        text = case.seq_len - P
        out = dict(tokens=_tok_sds(cfg, B, text),
                   targets=_tok_sds(cfg, B, text))
        if P:
            out["prefix"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                 cfg.jdtype)
        return out
    if case.kind == "prefill":
        text = case.seq_len - P
        out = dict(tokens=_tok_sds(cfg, B, text))
        if P:
            out["prefix"] = jax.ShapeDtypeStruct((B, P, cfg.d_model),
                                                 cfg.jdtype)
        return out
    # decode: one new token against a cache holding `seq_len` positions.
    cache = jax.eval_shape(
        lambda: decoder.init_cache(cfg, B, case.seq_len))
    return dict(cache=cache, tokens=_tok_sds(cfg, B, 1),
                pos=jax.ShapeDtypeStruct((), jnp.int32))


def params_specs(cfg: ModelConfig) -> dict:
    return jax.eval_shape(
        lambda: decoder.init_params(jax.random.PRNGKey(0), cfg))
