"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  512 chips as (pod=2, data=16, model=16) — the `pod` axis carries
data parallelism across pods (batch + FSDP), keeping TP traffic inside a pod
where ICI bandwidth lives; only gradient/FSDP collectives cross pods.

Defined as a FUNCTION so importing this module never touches jax device
state (device count is locked at first jax init — the dry-run sets
XLA_FLAGS before any import).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link
