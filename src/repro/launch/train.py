"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        [--smoke] [--steps 100] [--batch 8] [--seq 256] [--ckpt DIR]

With --smoke the reduced config trains on host devices; the full config
path builds the same jitted step with production-mesh shardings (used by
the dry-run; executing it requires real chips).
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args(argv)

    from ..configs import get_config
    from ..training.data import DataConfig, PackedStream
    from ..training.optimizer import AdamWConfig
    from ..training.train_loop import train

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    stream = PackedStream(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        n_codebooks=cfg.n_codebooks))
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 10))
    _, history = train(cfg, opt, stream, args.steps,
                       ckpt_path=args.ckpt, ckpt_every=args.ckpt_every)
    for h in history:
        print("step=%4d loss=%.4f grad_norm=%.3f lr=%.2e wall=%.1fs"
              % (h["step"], h["loss"], h["grad_norm"], h["lr"], h["wall_s"]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
