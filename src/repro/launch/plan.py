"""Planner CLI: run AGH (or GH / exact MILP) on the paper instance or the
TPU tier catalog and emit the deployment spec the serving launcher consumes.

    PYTHONPATH=src python -m repro.launch.plan --method agh --tiers tpu \
        [--budget 100] [--calibrate experiments/dryrun_results.json] \
        [--out deployment.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="agh",
                    choices=["agh", "gh", "milp", "lpr", "dvr", "hf"])
    ap.add_argument("--tiers", default="gpu", choices=["gpu", "tpu"])
    ap.add_argument("--budget", type=float, default=100.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--calibrate", default=None,
                    help="dry-run JSON to re-fit decode coefficients from")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from ..core import (agh, default_instance, dvr, gh, hf, lpr, objective,
                        provisioning_cost, solve_milp)
    from ..core.bridge import calibrate_from_dryrun, to_deployment, tpu_instance

    inst = default_instance(seed=args.seed, budget=args.budget)
    if args.tiers == "tpu":
        inst = tpu_instance(inst)
    if args.calibrate:
        arch_to_model = {  # framework archs standing in for catalog sizes
            "qwen2-0.5b": 0, "qwen2-1.5b": 1, "rwkv6-7b": 2,
            "deepseek-7b": 3, "internvl2-26b": 4, "qwen2-72b": 5}
        inst = calibrate_from_dryrun(inst, args.calibrate, arch_to_model)

    solver = dict(agh=agh, gh=gh, lpr=lpr, dvr=dvr, hf=hf,
                  milp=lambda i: solve_milp(i, time_limit=600))[args.method]
    sol = solver(inst)
    spec = to_deployment(inst, sol)
    out = dict(
        method=sol.method, runtime_s=round(sol.runtime_s, 4),
        objective=round(objective(inst, sol), 2),
        stage1_cost=round(provisioning_cost(inst, sol), 2),
        unmet=[round(float(u), 4) for u in sol.u],
        pairs=[dataclasses.asdict(p) for p in spec.pairs])
    txt = json.dumps(out, indent=2)
    print(txt)
    if args.out:
        with open(args.out, "w") as f:
            f.write(txt)
    return 0


if __name__ == "__main__":
    sys.exit(main())
