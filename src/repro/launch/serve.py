"""Serving launcher: plan with AGH, deploy the planned pairs as engines,
route batched requests per the planner's routing fractions.

    PYTHONPATH=src python -m repro.launch.serve --requests 32 [--smoke-arch qwen2-0.5b]

On CPU this serves the reduced config end-to-end (real prefill + decode);
the production path is the same engine with production-mesh shardings.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke-arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import jax

    from ..configs import get_config
    from ..core import agh, default_instance
    from ..core.bridge import to_deployment
    from ..models import decoder
    from ..serving.engine import Engine, Request

    # 1. Plan (the paper's allocator).
    inst = default_instance(seed=args.seed)
    sol = agh(inst)
    spec = to_deployment(inst, sol)
    print(f"AGH plan ({sol.runtime_s:.2f}s): "
          f"{[(p.model, p.tier, p.tp, p.pp) for p in spec.pairs]}")

    # 2. Deploy (smoke-scale engine standing in for each planned pair).
    cfg = get_config(args.smoke_arch).smoke()
    params = decoder.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(cfg, params,
                    max_len=args.prompt_len + args.new_tokens + 8,
                    max_batch=args.requests)

    # 3. Route + serve a request batch.
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    engine.generate(reqs)
    dt = time.perf_counter() - t0
    ttft = np.mean([r.first_token_s for r in reqs])
    total_toks = sum(len(r.output) for r in reqs)
    print(f"served {len(reqs)} requests: TTFT={ttft*1e3:.1f}ms "
          f"throughput={total_toks/dt:.1f} tok/s wall={dt:.2f}s")
    for r in reqs[:2]:
        print(f"  req {r.rid}: {len(r.output)} tokens, first 8 = {r.output[:8]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
