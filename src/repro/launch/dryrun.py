import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent.

For one (architecture × input shape × mesh) combination this script
`.lower().compile()`s the step function on 512 placeholder host devices
(single-pod 16x16 and multi-pod 2x16x16 meshes), prints
`compiled.memory_analysis()` (proves the program fits) and
`compiled.cost_analysis()` (FLOPs/bytes for the roofline), and extracts
the collective schedule from the optimized HLO.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k [--multi-pod] [--json out.json]

The XLA_FLAGS line above MUST run before any jax import (device count is
locked at first init) — do not move it.
"""
import argparse
import json
import sys
import time


def run_one(arch: str, shape: str, multi_pod: bool,
            donate: bool = True, opts: tuple[str, ...] = ()) -> dict:
    import dataclasses

    import jax
    import jax.numpy as jnp

    from ..configs import get_config
    from ..models import decoder
    from ..models.config import ModelConfig
    from ..parallel import sharding as shd
    from ..training.optimizer import AdamWConfig, init_state
    from ..training.train_loop import make_train_step
    from ..analysis.hlo_stats import analyze
    from .mesh import make_production_mesh
    from .specs import (applicable, input_specs, params_specs, shape_case)

    cfg: ModelConfig = get_config(arch)
    # Beyond-paper optimization variants (§Perf): baseline has all off.
    flag_map = dict(seqshard="seq_shard_attention",
                    moeshard="moe_expert_shard_constraint",
                    w8a8="moe_w8a8")
    cfg_opts = {flag_map[o]: True for o in opts if o in flag_map}
    if cfg_opts:
        cfg = dataclasses.replace(cfg, **cfg_opts)
    case = shape_case(shape)
    ok, why = applicable(cfg, case)
    if not ok:
        return dict(arch=arch, shape=shape, multi_pod=multi_pod,
                    status="skipped", reason=why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    t0 = time.perf_counter()

    p_shapes = params_specs(cfg)
    p_spec = shd.param_specs(p_shapes, mesh)
    p_shard = shd.to_shardings(p_spec, mesh)
    inputs = input_specs(cfg, case)

    with mesh:
        if case.kind == "train":
            opt_shapes = jax.eval_shape(init_state, p_shapes)
            opt_spec = dict(mu=p_spec, nu=p_spec,
                            step=jax.sharding.PartitionSpec())
            opt_shard = shd.to_shardings(opt_spec, mesh)
            batch_shard = {k: jax.sharding.NamedSharding(
                mesh, shd.batch_spec(mesh, v.shape))
                for k, v in inputs.items()}
            step = make_train_step(cfg, AdamWConfig())
            jitted = jax.jit(step,
                             in_shardings=(p_shard, opt_shard, batch_shard),
                             out_shardings=(p_shard, opt_shard, None),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(p_shapes, opt_shapes, inputs)
        elif case.kind == "prefill":
            def prefill_step(params, tokens, prefix=None):
                return decoder.prefill(params, cfg, tokens, prefix,
                                       max_len=case.seq_len)
            args = [p_shapes, inputs["tokens"]]
            shards = [p_shard, jax.sharding.NamedSharding(
                mesh, shd.batch_spec(mesh, inputs["tokens"].shape))]
            if "prefix" in inputs:
                args.append(inputs["prefix"])
                shards.append(jax.sharding.NamedSharding(
                    mesh, shd.batch_spec(mesh, inputs["prefix"].shape)))
            jitted = jax.jit(prefill_step, in_shardings=tuple(shards))
            lowered = jitted.lower(*args)
        else:  # decode
            cache_shapes = inputs["cache"]
            cache_spec = shd.cache_specs(cache_shapes, mesh,
                                         prefer_hd="kvhd" in opts)
            cache_shard = shd.to_shardings(cache_spec, mesh)

            def serve_step(params, cache, tokens, pos):
                return decoder.decode_step(params, cfg, cache, tokens, pos)

            tok_shard = jax.sharding.NamedSharding(
                mesh, shd.batch_spec(mesh, inputs["tokens"].shape))
            jitted = jax.jit(
                serve_step,
                in_shardings=(p_shard, cache_shard, tok_shard, None),
                out_shardings=(None, cache_shard),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(p_shapes, cache_shapes,
                                   inputs["tokens"], inputs["pos"])

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = dict(
            argument_bytes=getattr(mem, "argument_size_in_bytes", None),
            output_bytes=getattr(mem, "output_size_in_bytes", None),
            temp_bytes=getattr(mem, "temp_size_in_bytes", None),
            generated_code_bytes=getattr(
                mem, "generated_code_size_in_bytes", None))
    except Exception as e:  # CPU backend may not implement it
        mem_info = dict(error=str(e))

    # Trip-count-aware per-device HLO stats (XLA:CPU's cost_analysis does
    # not multiply while-loop bodies by trip count — see analysis/hlo_stats).
    stats = analyze(compiled.as_text())

    result = dict(
        arch=arch, shape=shape, multi_pod=multi_pod, status="ok",
        opts=list(opts),
        n_devices=n_dev, kind=case.kind,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        # per-device (the SPMD module is the per-partition program)
        hlo_flops_per_device=stats.flops,
        hlo_bytes_per_device=stats.bytes_estimate,
        hlo_bytes_upper=stats.bytes_accessed,
        hlo_bytes_lower=stats.bytes_written + stats.argument_bytes,
        collective_bytes_per_device=stats.collective_bytes,
        collectives=stats.collectives,
        n_collectives=stats.n_collectives,
        raw_cost_analysis_flops=float(cost.get("flops", 0.0)),
        memory=mem_info,
        params_total=cfg.param_count(),
        params_active=cfg.active_param_count(),
    )
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--json", default=None, help="append result to this file")
    ap.add_argument("--opt", action="append", default=[],
                    choices=["seqshard", "moeshard", "w8a8", "kvhd"],
                    help="enable a beyond-paper optimization variant")
    args = ap.parse_args(argv)

    res = run_one(args.arch, args.shape, args.multi_pod,
                  opts=tuple(args.opt))
    print(json.dumps(res, indent=2, default=str))
    if args.json:
        try:
            with open(args.json) as f:
                data = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError):
            data = []
        data = [r for r in data
                if not (r["arch"] == res["arch"] and r["shape"] == res["shape"]
                        and r["multi_pod"] == res["multi_pod"]
                        and r.get("opts", []) == res["opts"])]
        data.append(res)
        with open(args.json, "w") as f:
            json.dump(data, f, indent=1, default=str)
    return 0 if res["status"] in ("ok", "skipped") else 1


if __name__ == "__main__":
    sys.exit(main())
