"""Run the full dry-run sweep: every (arch × shape × mesh) combination.

Each combination runs in a subprocess (fresh XLA device-count env, isolation
against compile failures) and appends its result to the JSON artifact that
the roofline analysis and EXPERIMENTS.md read.

Usage:
    PYTHONPATH=src python -m repro.launch.sweep \
        [--json experiments/dryrun_results.json] [--multi-pod-only] \
        [--single-pod-only] [--arch A ...] [--timeout 3600]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from ..configs import ARCH_IDS
from .specs import SHAPES

# Cheap combos first: coverage accumulates fastest and failures surface early.
_ARCH_ORDER = ["qwen2-0.5b", "qwen2-1.5b", "musicgen-medium", "rwkv6-7b",
               "deepseek-7b", "zamba2-7b", "llama4-scout-17b-a16e",
               "internvl2-26b", "qwen2-72b", "kimi-k2-1t-a32b"]
_SHAPE_ORDER = ["train_4k", "decode_32k", "prefill_32k", "long_500k"]


def load(path: str) -> list:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return []


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="experiments/dryrun_results.json")
    ap.add_argument("--timeout", type=float, default=3600.0)
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--rerun", action="store_true",
                    help="re-run combos already present in the JSON")
    args = ap.parse_args(argv)

    archs = args.arch or [a for a in _ARCH_ORDER if a in ARCH_IDS]
    meshes = [False, True]
    if args.multi_pod_only:
        meshes = [True]
    if args.single_pod_only:
        meshes = [False]

    combos = [(a, s, mp) for mp in meshes for a in archs
              for s in _SHAPE_ORDER if s in SHAPES]
    done = {(r["arch"], r["shape"], r["multi_pod"]) for r in load(args.json)
            if r.get("status") in ("ok", "skipped")}
    t0 = time.time()
    n_fail = 0
    for i, (a, s, mp) in enumerate(combos):
        if not args.rerun and (a, s, mp) in done:
            print(f"[{i+1}/{len(combos)}] skip (done): {a} {s} mp={mp}",
                  flush=True)
            continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s, "--json", args.json]
        if mp:
            cmd.append("--multi-pod")
        print(f"[{i+1}/{len(combos)}] {a} {s} mp={mp} "
              f"(t={time.time()-t0:.0f}s)", flush=True)
        env = dict(os.environ)
        env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=args.timeout, env=env)
            if proc.returncode != 0:
                n_fail += 1
                tail = (proc.stderr or proc.stdout or "")[-2000:]
                print(f"  FAILED rc={proc.returncode}\n{tail}", flush=True)
                _record_failure(args.json, a, s, mp, tail)
        except subprocess.TimeoutExpired:
            n_fail += 1
            print("  TIMEOUT", flush=True)
            _record_failure(args.json, a, s, mp, "timeout")
    print(f"sweep done: {len(combos)} combos, {n_fail} failures, "
          f"{time.time()-t0:.0f}s", flush=True)
    return 1 if n_fail else 0


def _record_failure(path: str, arch: str, shape: str, mp: bool,
                    msg: str) -> None:
    data = load(path)
    data = [r for r in data if not (r["arch"] == arch and r["shape"] == shape
                                    and r["multi_pod"] == mp)]
    data.append(dict(arch=arch, shape=shape, multi_pod=mp, status="failed",
                     error=msg))
    with open(path, "w") as f:
        json.dump(data, f, indent=1, default=str)


if __name__ == "__main__":
    sys.exit(main())
