"""Allocator scaling: registry-keyed rows for every engine generation.

Each row is one instance size; solver columns are sub-dicts keyed by the
planner-registry name (plus a ``+variant`` suffix for non-default engine
modes), produced directly from `PlanResult.summary()` — the CI
regression gate (`benchmarks/check_regression.py`) flattens and diffs
them against the committed baseline:

* ``gh``             — vectorized GH through the facade;
* ``agh``            — the incremental engine (default);
* ``agh+rescan``     — dirty-source tracking disabled (PR-3-style);
* ``agh+reference``  — the PR-1/PR-2 first-improvement probe loop;
* ``agh+warm``       — `PlanSession.replan` on a ±15% drifted demand
  vector, seeded from the undrifted incumbent, next to the cold AGH
  solve of the same drifted instance (``cold_*`` fields + ``speedup``);
* flat ``GH_before_us`` / ``AGH_before_us`` — the frozen scalar seed
  path, kept at sizes where it finishes in seconds.

Emits one ``name,us_per_call`` line per cell so perf regressions show up
directly in CI logs.
"""
from __future__ import annotations

import numpy as np

from repro.core import random_instance
from repro.core._scalar_ref import agh_scalar, gh_scalar
from repro.core.solution import objective
from repro.planner import PlanOptions, PlanResult, PlanSession, plan

from .common import Timer, emit

SIZES = [(6, 6, 10), (10, 10, 10), (15, 15, 10), (20, 20, 20), (30, 30, 20),
         (40, 40, 30), (60, 60, 40)]
# Beyond-paper sizes: the PR-4 acceptance instance plus two fleet-scale
# points (the paper's Table 6 stops at (20,20,20)).
SIZES_XL = SIZES + [(100, 80, 40), (150, 120, 60), (200, 160, 80)]
QUICK_SIZES = [(6, 6, 10), (20, 20, 20)]
SCALAR_AGH_MAX = 10 * 10 * 10   # scalar AGH above this takes minutes
SCALAR_GH_MAX = 30 * 30 * 20    # scalar GH above this takes tens of seconds
REF_AGH_MAX = 100 * 80 * 40     # reference-mode AGH above this: minutes
DRIFT_PM = 0.15                 # warm-replan demo: ±15% per-type demand


def _cell(row: dict, size: str, key: str, inst,
          options=None) -> PlanResult:
    """One facade solve -> registry-keyed summary + CSV line."""
    solver = key.split("+")[0]
    res = plan(solver, instance=inst, options=options or PlanOptions())
    row[key] = res.summary()
    emit(f"allocator_scaling.{size}.{key}", res.wall_s * 1e6,
         f"obj={res.objective:.2f}")
    return res


def run(sizes=SIZES, scalar_agh_max: int = SCALAR_AGH_MAX,
        scalar_gh_max: int = SCALAR_GH_MAX,
        ref_agh_max: int = REF_AGH_MAX, warm_demo: bool = True) -> list[dict]:
    rows = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        size = f"({I},{J},{K})"
        row: dict = dict(size=size)

        if I * J * K <= scalar_gh_max:
            with Timer() as t:
                g_ref, _ = gh_scalar(inst)
            row["GH_before_us"] = t.us
            emit(f"allocator_scaling.{size}.GH.before", t.us,
                 f"obj={objective(inst, g_ref):.2f}")
        _cell(row, size, "gh", inst)

        if I * J * K <= scalar_agh_max:
            with Timer() as t:
                a_ref = agh_scalar(inst)
            row["AGH_before_us"] = t.us
            emit(f"allocator_scaling.{size}.AGH.before", t.us,
                 f"obj={objective(inst, a_ref):.2f}")
        if I * J * K <= ref_agh_max:
            _cell(row, size, "agh+reference", inst,
                  PlanOptions(local_search="reference"))
        _cell(row, size, "agh+rescan", inst,
              PlanOptions(local_search="batched-rescan"))
        agh_res = _cell(row, size, "agh", inst)

        if warm_demo:
            # Warm-started replanning (ISSUE 5 acceptance): drift every
            # type's demand by ±15%, solve cold, then replan warm from the
            # undrifted incumbent.  The session is seeded with the `agh`
            # row's result (no duplicate cold solve); the drifted cold
            # comparator and the replan both run the sequential driver
            # (workers=0) so the comparison is machine-independent.
            drift = np.random.default_rng(7).uniform(
                1.0 - DRIFT_PM, 1.0 + DRIFT_PM, inst.I)
            drifted = inst.with_lam(inst.lam * drift)
            cold = plan("agh", instance=drifted,
                        options=PlanOptions(workers=0))
            ses = PlanSession(options=PlanOptions(workers=0))
            ses.seed(inst, agh_res)
            warm = ses.replan(instance=drifted)
            row["agh+warm"] = {
                **warm.summary(),
                "cold_objective": round(cold.objective, 4),
                "cold_wall_s": round(cold.wall_s, 4),
                "speedup": round(cold.wall_s / max(warm.wall_s, 1e-9), 2),
                "orderings": warm.diagnostics.get("orderings_evaluated"),
            }
            emit(f"allocator_scaling.{size}.agh+warm", warm.wall_s * 1e6,
                 f"obj={warm.objective:.2f};cold_obj={cold.objective:.2f};"
                 f"speedup={row['agh+warm']['speedup']:.2f}x")
        rows.append(row)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest + acceptance size only (CI smoke)")
    ap.add_argument("--xl", action="store_true",
                    help="include the beyond-paper sizes up to (200,160,80)")
    ap.add_argument("--scalar-agh-max", type=int, default=SCALAR_AGH_MAX,
                    help="largest I*J*K for which the scalar AGH is timed")
    args = ap.parse_args()
    run(sizes=(QUICK_SIZES if args.quick else
               (SIZES_XL if args.xl else SIZES)),
        scalar_agh_max=args.scalar_agh_max)
