"""Allocator scaling: before/after rows for the vectorized engine.

Times the frozen scalar seed path (`_scalar_ref`, the "before") against the
vectorized engine ("after") on random instances growing to (30,30,20) —
beyond the paper's largest Table-6 size — and emits one
``name,us_per_call`` row per (size, method, path) so perf regressions show
up directly in CI logs.

The scalar AGH is capped at sizes where it finishes in a few seconds; for
larger sizes only its GH "before" row is emitted (the AGH-before cost is
the reason this engine exists).
"""
from __future__ import annotations

from repro.core import agh, gh, objective, random_instance
from repro.core._scalar_ref import agh_scalar, gh_scalar

from .common import Timer, emit

SIZES = [(6, 6, 10), (10, 10, 10), (15, 15, 10), (20, 20, 20), (30, 30, 20)]
SCALAR_AGH_MAX = 10 * 10 * 10   # scalar AGH above this takes minutes


def run(sizes=SIZES, scalar_agh_max: int = SCALAR_AGH_MAX) -> list[dict]:
    rows = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        size = f"({I},{J},{K})"
        row = dict(size=size)

        with Timer() as t:
            g_ref, _ = gh_scalar(inst)
        row["GH_before_us"] = t.us
        emit(f"allocator_scaling.{size}.GH.before", t.us,
             f"obj={objective(inst, g_ref):.2f}")

        with Timer() as t:
            g_vec = gh(inst)
        row["GH_after_us"] = t.us
        emit(f"allocator_scaling.{size}.GH.after", t.us,
             f"obj={objective(inst, g_vec):.2f};"
             f"speedup={row['GH_before_us'] / max(t.us, 1e-9):.1f}x")

        if I * J * K <= scalar_agh_max:
            with Timer() as t:
                a_ref = agh_scalar(inst)
            row["AGH_before_us"] = t.us
            emit(f"allocator_scaling.{size}.AGH.before", t.us,
                 f"obj={objective(inst, a_ref):.2f}")

        with Timer() as t:
            a_vec = agh(inst)
        row["AGH_after_us"] = t.us
        derived = f"obj={objective(inst, a_vec):.2f}"
        if "AGH_before_us" in row:
            derived += f";speedup={row['AGH_before_us'] / max(t.us, 1e-9):.1f}x"
        emit(f"allocator_scaling.{size}.AGH.after", t.us, derived)
        rows.append(row)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scalar-agh-max", type=int, default=SCALAR_AGH_MAX,
                    help="largest I*J*K for which the scalar AGH is timed")
    args = ap.parse_args()
    run(scalar_agh_max=args.scalar_agh_max)
