"""Allocator scaling: before/after rows for the vectorized engine.

Up to four points per size and method so every engine generation is
visible in CI logs:

* ``before``   — the frozen scalar seed path (`_scalar_ref`, pre-PR-1);
* ``ref``      — AGH with ``local_search="reference"`` (the PR-1/PR-2
                 vectorized engine with the first-improvement probe loop);
* ``rescan``   — the PR-3-style batched engine with dirty-source tracking
                 disabled (``local_search="batched-rescan"``);
* ``after``    — the PR-4 incremental engine (amortized destination
                 tensors + dirty-source tracking, the default).

Emits one ``name,us_per_call`` row per (size, method, path) so perf
regressions show up directly in CI logs, and returns row dicts carrying
the objectives — `benchmarks/check_regression.py` diffs those against the
committed baseline.  The scalar/reference paths are capped at sizes where
they finish in seconds; for larger sizes only the fast rows are emitted
(the scalar cost is the reason the engine exists).
"""
from __future__ import annotations

from repro.core import agh, gh, objective, random_instance
from repro.core._scalar_ref import agh_scalar, gh_scalar

from .common import Timer, emit

SIZES = [(6, 6, 10), (10, 10, 10), (15, 15, 10), (20, 20, 20), (30, 30, 20),
         (40, 40, 30), (60, 60, 40)]
# Beyond-paper sizes: the PR-4 acceptance instance plus two fleet-scale
# points (the paper's Table 6 stops at (20,20,20)).
SIZES_XL = SIZES + [(100, 80, 40), (150, 120, 60), (200, 160, 80)]
QUICK_SIZES = [(6, 6, 10), (20, 20, 20)]
SCALAR_AGH_MAX = 10 * 10 * 10   # scalar AGH above this takes minutes
SCALAR_GH_MAX = 30 * 30 * 20    # scalar GH above this takes tens of seconds
REF_AGH_MAX = 100 * 80 * 40     # reference-mode AGH above this: minutes


def run(sizes=SIZES, scalar_agh_max: int = SCALAR_AGH_MAX,
        scalar_gh_max: int = SCALAR_GH_MAX,
        ref_agh_max: int = REF_AGH_MAX) -> list[dict]:
    rows = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        size = f"({I},{J},{K})"
        row = dict(size=size)

        if I * J * K <= scalar_gh_max:
            with Timer() as t:
                g_ref, _ = gh_scalar(inst)
            row["GH_before_us"] = t.us
            emit(f"allocator_scaling.{size}.GH.before", t.us,
                 f"obj={objective(inst, g_ref):.2f}")

        with Timer() as t:
            g_vec = gh(inst)
        row["GH_after_us"] = t.us
        row["GH_obj"] = round(objective(inst, g_vec), 4)
        derived = f"obj={row['GH_obj']:.2f}"
        if "GH_before_us" in row:
            derived += f";speedup={row['GH_before_us'] / max(t.us, 1e-9):.1f}x"
        emit(f"allocator_scaling.{size}.GH.after", t.us, derived)

        if I * J * K <= scalar_agh_max:
            with Timer() as t:
                a_ref = agh_scalar(inst)
            row["AGH_before_us"] = t.us
            emit(f"allocator_scaling.{size}.AGH.before", t.us,
                 f"obj={objective(inst, a_ref):.2f}")

        if I * J * K <= ref_agh_max:
            with Timer() as t:
                a_mode_ref = agh(inst, local_search="reference")
            row["AGH_ref_us"] = t.us
            row["AGH_ref_obj"] = round(objective(inst, a_mode_ref), 4)
            emit(f"allocator_scaling.{size}.AGH.ref", t.us,
                 f"obj={row['AGH_ref_obj']:.2f}")

        with Timer() as t:
            a_rescan = agh(inst, local_search="batched-rescan")
        row["AGH_rescan_us"] = t.us
        row["AGH_rescan_obj"] = round(objective(inst, a_rescan), 4)
        emit(f"allocator_scaling.{size}.AGH.rescan", t.us,
             f"obj={row['AGH_rescan_obj']:.2f}")

        with Timer() as t:
            a_vec = agh(inst)
        row["AGH_after_us"] = t.us
        row["AGH_obj"] = round(objective(inst, a_vec), 4)
        derived = f"obj={row['AGH_obj']:.2f}"
        if "AGH_ref_us" in row:
            derived += f";ls_speedup={row['AGH_ref_us'] / max(t.us, 1e-9):.1f}x"
        if "AGH_before_us" in row:
            derived += f";speedup={row['AGH_before_us'] / max(t.us, 1e-9):.1f}x"
        emit(f"allocator_scaling.{size}.AGH.after", t.us, derived)
        rows.append(row)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest + acceptance size only (CI smoke)")
    ap.add_argument("--xl", action="store_true",
                    help="include the beyond-paper sizes up to (200,160,80)")
    ap.add_argument("--scalar-agh-max", type=int, default=SCALAR_AGH_MAX,
                    help="largest I*J*K for which the scalar AGH is timed")
    args = ap.parse_args()
    run(sizes=(QUICK_SIZES if args.quick else
               (SIZES_XL if args.xl else SIZES)),
        scalar_agh_max=args.scalar_agh_max)
