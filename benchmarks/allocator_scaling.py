"""Allocator scaling: registry-keyed rows for every engine generation.

Each row is one (instance size, engine) pair; solver columns are
sub-dicts keyed by the planner-registry name (plus a ``+variant`` suffix
for non-default engine modes), produced directly from
`PlanResult.summary()` — the CI regression gate
(`benchmarks/check_regression.py`) flattens and diffs them against the
committed baseline:

* ``gh``             — vectorized GH through the facade;
* ``agh``            — the incremental engine (default);
* ``agh+rescan``     — dirty-source tracking disabled (PR-3-style);
* ``agh+reference``  — the PR-1/PR-2 first-improvement probe loop;
* ``agh+warm``       — `PlanSession.replan` on a ±15% drifted demand
  vector, seeded from the undrifted incumbent, next to the cold AGH
  solve of the same drifted instance (``cold_*`` fields + ``speedup``);
* ``agh+workersN``   — `--workers-sweep`: the multi-start process-pool
  fan-out at widths 1/2/4/8 (numpy engine only);
* ``agh+xla``        — `--engine xla`: the jitted lane-batched tier.
  Every xla cell is solved twice: the first run pays jit tracing and is
  reported as ``compile_s`` (first minus second wall), the second run's
  steady-state timing is what the row and the regression gate see;
* ``agh+xla+bwN``    — `--bw-curve`: the orderings-batch-width scaling
  curve (device lanes per call capped at N = 1/2/4/8);
* flat ``GH_before_us`` / ``AGH_before_us`` — the frozen scalar seed
  path, kept at sizes where it finishes in seconds.

``--trajectory-out PATH`` appends this run's rows to the append-only
repo-root ``BENCH_allocator.json`` artifact (see
`benchmarks/trajectory.py`).  Emits one ``name,us_per_call`` line per
cell so perf regressions show up directly in CI logs.
"""
from __future__ import annotations

import numpy as np

from repro.core import random_instance
from repro.core._scalar_ref import agh_scalar, gh_scalar
from repro.core.solution import objective
from repro.planner import PlanOptions, PlanResult, PlanSession, plan

from .common import Timer, emit

SIZES = [(6, 6, 10), (10, 10, 10), (15, 15, 10), (20, 20, 20), (30, 30, 20),
         (40, 40, 30), (60, 60, 40)]
# Beyond-paper sizes: the PR-4 acceptance instance plus two fleet-scale
# points (the paper's Table 6 stops at (20,20,20)).
SIZES_XL = SIZES + [(100, 80, 40), (150, 120, 60), (200, 160, 80)]
QUICK_SIZES = [(6, 6, 10), (20, 20, 20)]
SCALAR_AGH_MAX = 10 * 10 * 10   # scalar AGH above this takes minutes
SCALAR_GH_MAX = 30 * 30 * 20    # scalar GH above this takes tens of seconds
REF_AGH_MAX = 100 * 80 * 40     # reference-mode AGH above this: minutes
DRIFT_PM = 0.15                 # warm-replan demo: ±15% per-type demand
WORKER_WIDTHS = (1, 2, 4, 8)    # --workers-sweep fan-out widths
BW_WIDTHS = (1, 2, 4, 8)        # --bw-curve xla lane-batch widths


def _cell(row: dict, size: str, key: str, inst,
          options=None) -> PlanResult:
    """One facade solve -> registry-keyed summary + CSV line.

    xla cells are solved twice: run 1 includes jit tracing (reported as
    ``compile_s``), run 2 is the steady-state row the gate diffs."""
    solver = key.split("+")[0]
    opts = options or PlanOptions()
    res = plan(solver, instance=inst, options=opts)
    cell = res.summary()
    if opts.engine == "xla":
        warm = plan(solver, instance=inst, options=opts)
        cell = warm.summary()
        cell["compile_s"] = round(max(0.0, res.wall_s - warm.wall_s), 4)
        res = warm
    row[key] = cell
    emit(f"allocator_scaling.{size}.{key}", res.wall_s * 1e6,
         f"obj={res.objective:.2f}")
    return res


def _run_xla_row(row: dict, size: str, inst, bw_curve: bool) -> None:
    res = _cell(row, size, "agh+xla", inst, PlanOptions(engine="xla"))
    emit(f"allocator_scaling.{size}.agh+xla.compile",
         row["agh+xla"]["compile_s"] * 1e6,
         f"steady_s={res.wall_s:.3f}")
    if bw_curve:
        for bw in BW_WIDTHS:
            _cell(row, size, f"agh+xla+bw{bw}", inst,
                  PlanOptions(engine="xla", batch_width=bw))


def run(sizes=SIZES, scalar_agh_max: int = SCALAR_AGH_MAX,
        scalar_gh_max: int = SCALAR_GH_MAX,
        ref_agh_max: int = REF_AGH_MAX, warm_demo: bool = True,
        engine: str = "numpy", workers_sweep: bool = False,
        bw_curve: bool = False) -> list[dict]:
    rows = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        size = f"({I},{J},{K})"
        row: dict = dict(size=size, engine=engine)

        if engine == "xla":
            # The xla tier rides its own rows (same sizes, engine-keyed
            # so the gate never diffs them against numpy timings).
            _run_xla_row(row, size, inst, bw_curve)
            rows.append(row)
            continue

        if I * J * K <= scalar_gh_max:
            with Timer() as t:
                g_ref, _ = gh_scalar(inst)
            row["GH_before_us"] = t.us
            emit(f"allocator_scaling.{size}.GH.before", t.us,
                 f"obj={objective(inst, g_ref):.2f}")
        _cell(row, size, "gh", inst)

        if I * J * K <= scalar_agh_max:
            with Timer() as t:
                a_ref = agh_scalar(inst)
            row["AGH_before_us"] = t.us
            emit(f"allocator_scaling.{size}.AGH.before", t.us,
                 f"obj={objective(inst, a_ref):.2f}")
        if I * J * K <= ref_agh_max:
            _cell(row, size, "agh+reference", inst,
                  PlanOptions(local_search="reference"))
        _cell(row, size, "agh+rescan", inst,
              PlanOptions(local_search="batched-rescan"))
        agh_res = _cell(row, size, "agh", inst)

        if workers_sweep:
            # Multi-start fan-out scaling: all orderings, no early stop
            # (the pool protocol), at fixed pool widths.
            for w in WORKER_WIDTHS:
                _cell(row, size, f"agh+workers{w}", inst,
                      PlanOptions(workers=w))

        if warm_demo:
            # Warm-started replanning (ISSUE 5 acceptance): drift every
            # type's demand by ±15%, solve cold, then replan warm from the
            # undrifted incumbent.  The session is seeded with the `agh`
            # row's result (no duplicate cold solve); the drifted cold
            # comparator and the replan both run the sequential driver
            # (workers=0) so the comparison is machine-independent.
            drift = np.random.default_rng(7).uniform(
                1.0 - DRIFT_PM, 1.0 + DRIFT_PM, inst.I)
            drifted = inst.with_lam(inst.lam * drift)
            cold = plan("agh", instance=drifted,
                        options=PlanOptions(workers=0))
            ses = PlanSession(options=PlanOptions(workers=0))
            ses.seed(inst, agh_res)
            warm = ses.replan(instance=drifted)
            row["agh+warm"] = {
                **warm.summary(),
                "cold_objective": round(cold.objective, 4),
                "cold_wall_s": round(cold.wall_s, 4),
                "speedup": round(cold.wall_s / max(warm.wall_s, 1e-9), 2),
                "orderings": warm.diagnostics.get("orderings_evaluated"),
            }
            emit(f"allocator_scaling.{size}.agh+warm", warm.wall_s * 1e6,
                 f"obj={warm.objective:.2f};cold_obj={cold.objective:.2f};"
                 f"speedup={row['agh+warm']['speedup']:.2f}x")
        rows.append(row)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smallest + acceptance size only (CI smoke)")
    ap.add_argument("--xl", action="store_true",
                    help="include the beyond-paper sizes up to (200,160,80)")
    ap.add_argument("--engine", default="numpy", choices=("numpy", "xla"),
                    help="allocator engine for the agh rows (xla adds "
                         "compile-vs-steady split; needs jax)")
    ap.add_argument("--workers-sweep", action="store_true",
                    help="add agh+workersN rows at widths 1/2/4/8")
    ap.add_argument("--bw-curve", action="store_true",
                    help="with --engine xla: add agh+xla+bwN rows "
                         "(orderings-batch-width scaling curve)")
    ap.add_argument("--trajectory-out", default=None, metavar="PATH",
                    help="append this run's rows to the trajectory "
                         "artifact (e.g. BENCH_allocator.json)")
    ap.add_argument("--scalar-agh-max", type=int, default=SCALAR_AGH_MAX,
                    help="largest I*J*K for which the scalar AGH is timed")
    args = ap.parse_args()
    out_rows = run(sizes=(QUICK_SIZES if args.quick else
                          (SIZES_XL if args.xl else SIZES)),
                   scalar_agh_max=args.scalar_agh_max,
                   engine=args.engine, workers_sweep=args.workers_sweep,
                   bw_curve=args.bw_curve)
    if args.trajectory_out:
        from .trajectory import append
        append(args.trajectory_out, out_rows,
               label=f"allocator_scaling --engine {args.engine}")
