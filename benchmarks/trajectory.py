"""Benchmark trajectory artifact: an append-only JSON history of
allocator benchmark rows across commits.

`append(path, rows)` loads the artifact (a JSON list of entries), adds
one entry stamped with the current git SHA, a UTC timestamp, and the dump
schema version, and rewrites the file.  CI runs
``allocator_scaling --quick --trajectory-out BENCH_allocator.json`` and
uploads the repo-root file as a build artifact, so the allocator's
objective/runtime trajectory is recoverable per commit without digging
through job logs.  Entries with stale schema versions are kept verbatim
(the file is a history, not a gate — `check_regression.py` is the gate).
"""
from __future__ import annotations

import datetime
import json
import os

from .common import JSON_SCHEMA_VERSION, ensure_outdir, git_sha

DEFAULT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_allocator.json")


def append(path: str, rows: list[dict], label: str | None = None) -> dict:
    """Append one trajectory entry holding `rows`; returns the entry."""
    history: list = []
    if os.path.exists(path):
        try:
            with open(path) as fh:
                loaded = json.load(fh)
            if isinstance(loaded, list):
                history = loaded
        except (OSError, json.JSONDecodeError):
            # A corrupt artifact must not fail the benchmark run — start
            # a fresh history (the old file is overwritten below).
            history = []
    entry = {
        "git_sha": git_sha(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "schema_version": JSON_SCHEMA_VERSION,
        "rows": rows,
    }
    if label:
        entry["label"] = label
    history.append(entry)
    ensure_outdir(path)
    with open(path, "w") as fh:
        json.dump(history, fh, indent=2)
        fh.write("\n")
    print(f"# trajectory: appended entry {len(history)} to {path} "
          f"({len(rows)} rows)", flush=True)
    return entry
