"""Render the paper-figure analogues as PNGs under experiments/figures/.

    PYTHONPATH=src python -m benchmarks.make_figures
"""
from __future__ import annotations

import os

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt
import numpy as np

OUT = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "experiments", "figures")


def fig6_diurnal():
    """Fig. 6 analogue: per-window cost, static vs rolling, on the trace."""
    from repro.core import agh, default_instance
    from repro.core.rolling import rolling
    from repro.core.trace import diurnal_multipliers

    inst = default_instance()
    mult = diurnal_multipliers("busy", seed=7, n_windows=96)
    path = np.outer(mult, inst.lam)
    fast = lambda i: agh(i, R=1, patience=2)
    r_static = rolling(inst, path, fast, replan_every=None,
                       static_forecast="mean")
    r_roll = rolling(inst, path, fast, replan_every=4)

    fig, axes = plt.subplots(2, 1, figsize=(9, 6), sharex=True)
    t = np.arange(96) * 0.25
    axes[0].plot(t, mult * 100, "k--", lw=1, label="demand (% of mean)")
    axes[0].set_ylabel("demand %")
    axes[0].legend()
    axes[1].plot(t, r_static.per_window_cost, label="AGH-static")
    axes[1].plot(t, r_roll.per_window_cost, label="AGH-5min")
    axes[1].set_xlabel("hour of day")
    axes[1].set_ylabel("cost per window ($)")
    axes[1].legend()
    fig.suptitle("Diurnal trace replay (Fig. 6 analogue)")
    fig.savefig(os.path.join(OUT, "fig6_diurnal.png"), dpi=120,
                bbox_inches="tight")
    plt.close(fig)


def roofline_scatter():
    """Roofline terms per (arch, shape), single-pod."""
    import json
    path = os.path.join(os.path.dirname(OUT), "roofline.json")
    rows = [r for r in json.load(open(path)) if r["mesh"] == "16x16"]
    fig, ax = plt.subplots(figsize=(9, 6))
    colors = {"train_4k": "tab:blue", "prefill_32k": "tab:orange",
              "decode_32k": "tab:green", "long_500k": "tab:red"}
    for r in rows:
        total = r["compute_s"] + r["memory_s"] + r["collective_s"]
        ax.scatter(r["useful_ratio"], total,
                   c=colors[r["shape"]],
                   marker={"memory": "o", "collective": "^",
                           "compute": "s"}[r["dominant"]], s=60, alpha=0.8)
    for shape, c in colors.items():
        ax.scatter([], [], c=c, label=shape)
    ax.scatter([], [], c="gray", marker="o", label="memory-dominant")
    ax.scatter([], [], c="gray", marker="^", label="collective-dominant")
    ax.set_xscale("log")
    ax.set_yscale("log")
    ax.set_xlabel("MODEL_FLOPS / HLO_FLOPS (usefulness)")
    ax.set_ylabel("sum of roofline terms (s/step)")
    ax.legend(fontsize=8)
    ax.set_title("Roofline terms per (arch x shape), 16x16 mesh")
    fig.savefig(os.path.join(OUT, "roofline_scatter.png"), dpi=120,
                bbox_inches="tight")
    plt.close(fig)


def perf_waterfall():
    """Hillclimb before/after bars for the three + bonus pairs."""
    pairs = [
        ("qwen2-1.5b\nprefill flops", 1.488e15, 3.38e13),
        ("llama4 prefill\ncollective B", 3.43e13, 4.40e11),
        ("kimi decode\nbytes", 1.94e11, 1.46e11),
        ("qwen2-72b decode\ncollective B", 1.84e11, 1.04e11),
    ]
    fig, ax = plt.subplots(figsize=(8, 5))
    x = np.arange(len(pairs))
    ax.bar(x - 0.2, [p[1] for p in pairs], width=0.4, label="paper-faithful")
    ax.bar(x + 0.2, [p[2] for p in pairs], width=0.4, label="optimized")
    ax.set_yscale("log")
    ax.set_xticks(x)
    ax.set_xticklabels([p[0] for p in pairs], fontsize=8)
    ax.set_ylabel("per-device (log)")
    ax.legend()
    ax.set_title("§Perf hillclimbs: baseline vs beyond-paper variant")
    fig.savefig(os.path.join(OUT, "perf_hillclimbs.png"), dpi=120,
                bbox_inches="tight")
    plt.close(fig)


def main():
    os.makedirs(OUT, exist_ok=True)
    roofline_scatter()
    perf_waterfall()
    fig6_diurnal()
    print("figures written to", OUT)


if __name__ == "__main__":
    main()
