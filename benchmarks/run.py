"""Benchmark harness entry point — one section per paper table/figure plus
the kernel microbenchmarks and the roofline summary.

Prints ``name,us_per_call,derived`` CSV lines (harness contract).

    PYTHONPATH=src python -m benchmarks.run            # standard pass
    PYTHONPATH=src python -m benchmarks.run --quick    # fastest smoke
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale (slow)
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: table2,table3,...")
    ap.add_argument("--workers", type=int, default=None,
                    help="shared process-pool width for the sections that "
                         "fan out (table2's scenario x method grid)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump every section's row dicts as one JSON "
                         "file (CI uploads it as a workflow artifact)")
    args = ap.parse_args(argv)

    S = 30 if args.quick else (500 if args.full else 120)
    trials = 1 if args.quick else (30 if args.full else 3)
    windows = 48 if args.quick else (288 if args.full else 96)

    from . import (allocator_scaling, extensions, failure_replay, figs,
                   kernels_bench, risk_scaling, serve_closed_loop,
                   stage2_scaling, table2, table3, table4, table5, table6)

    sections = {
        "table2": lambda: table2.run(S=S, include_dm=False,
                                     workers=args.workers),
        "table3": lambda: table3.run(),
        "table4": lambda: table4.run(trials=trials, n_windows=windows,
                                     dm_limit=120.0 if not args.full else 600.0,
                                     replan_every=4 if not args.full else 1),
        "table5": lambda: table5.run(n_windows=windows,
                                     dm_limit=60.0 if not args.full else 120.0,
                                     include_baselines=not args.quick,
                                     replan_every=4 if not args.full else 1),
        "table6": lambda: table6.run(
            dm_limit=120.0 if not args.full else 600.0,
            dm_max_size=1000 if not args.full else 10**9,
            sizes=(table6.SIZES[:3] if args.quick
                   else (table6.SIZES_EXT if args.full else table6.SIZES))),
        "allocator_scaling": lambda: allocator_scaling.run(
            sizes=(allocator_scaling.QUICK_SIZES if args.quick
                   else (allocator_scaling.SIZES_XL if args.full
                         else allocator_scaling.SIZES))),
        "stage2_scaling": lambda: stage2_scaling.run(
            quick=args.quick, S=(500 if args.full else 120)),
        "risk_scaling": lambda: risk_scaling.run(quick=args.quick,
                                                 full=args.full),
        "failure_replay": lambda: failure_replay.run(quick=args.quick),
        "serve_closed_loop": lambda: serve_closed_loop.run(quick=args.quick),
        "figs": lambda: figs.run(S=max(20, S // 4)),
        "extensions": extensions.run,
        "kernels": kernels_bench.run,
        "roofline": _roofline_summary,
    }
    only = set(args.only.split(",")) if args.only else None
    t0 = time.time()
    collected: dict[str, object] = {}
    for name, fn in sections.items():
        if only and name not in only:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            rows = fn()
            if args.json and isinstance(rows, list):
                collected[name] = rows
        except Exception as e:  # keep the harness running
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            if args.json:
                collected[name] = {"error": f"{type(e).__name__}: {e}"}
    if args.json:
        import json

        from .common import JSON_SCHEMA_VERSION, ensure_outdir, git_sha

        ensure_outdir(args.json)
        payload = {
            "schema_version": JSON_SCHEMA_VERSION,
            "git_sha": git_sha(),
            "sections": collected,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2, default=str)
        print(f"# wrote {args.json}", flush=True)
    print(f"# benchmarks done in {time.time()-t0:.0f}s", flush=True)
    return 0


def _roofline_summary() -> None:
    """Per (arch x shape x mesh) roofline rows from the dry-run artifact."""
    import json

    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "experiments", "dryrun_results.json")
    if not os.path.exists(path):
        print("roofline,0,missing-dryrun-artifact", flush=True)
        return
    from repro.analysis.roofline import analyze_row
    rows = json.load(open(path))
    for r in rows:
        a = analyze_row(r)
        if a is None:
            continue
        print(f"roofline.{a['arch']}.{a['shape']}.{a['mesh']},0,"
              f"compute={a['compute_s']:.3e};memory={a['memory_s']:.3e};"
              f"collective={a['collective_s']:.3e};dom={a['dominant']};"
              f"useful={a['useful_ratio']:.3f}", flush=True)


if __name__ == "__main__":
    sys.exit(main())
