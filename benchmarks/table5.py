"""Table 5 / Fig. 6: rolling-horizon cost on the (synthetic replica of the)
Azure diurnal trace — static vs 5-minute keep-best re-optimization for
AGH, GH, DM and the external baselines, all driven through the planner
registry.  The 5-minute AGH column rides a `PlanSession`, so every
window after the first is a warm-started replan."""
from __future__ import annotations

import numpy as np

from repro.core import default_instance
from repro.core.rolling import rolling
from repro.core.trace import diurnal_multipliers, peak_to_trough
from repro.planner import PlanOptions, PlanSession, plan

from .common import emit


def run(n_windows: int = 288, day: str = "busy", dm_limit: float = 120.0,
        include_baselines: bool = True, replan_every: int = 1) -> list[dict]:
    inst = default_instance()
    mult = diurnal_multipliers(day, seed=7, n_windows=n_windows)
    path = np.outer(mult, inst.lam)
    print(f"# trace day={day} peak/trough={peak_to_trough(mult):.1f}x",
          flush=True)

    def facade(mname, **opt):
        return lambda i: plan(mname, instance=i,
                              options=PlanOptions(**opt)).solution

    methods: list[tuple[str, object, object]] = [
        # (name, static planner, rolling planner)
        ("AGH", facade("agh"),
         PlanSession(solver="agh",
                     options=PlanOptions(restarts=1, patience=2))),
        ("GH", facade("gh"), facade("gh")),
        ("DM", facade("milp", time_limit=dm_limit),
         facade("milp", time_limit=15.0)),
    ]
    if include_baselines:
        methods += [("HF", facade("hf"), facade("hf")),
                    ("LPR", facade("lpr", time_limit=30),
                     facade("lpr", time_limit=10)),
                    ("DVR", facade("dvr"), facade("dvr"))]

    rows = []
    for name, static_fn, roll_fn in methods:
        # Paper protocol: the static variant plans on the DAY-AVERAGE
        # forecast; the diurnal swing around that mean is what stresses it.
        dep = static_fn(inst.with_lam(path.mean(axis=0)))
        r_static = rolling(inst, path, lambda i, p=dep: p, replan_every=None)
        rows.append(dict(method=f"{name}-static",
                         mean_win=r_static.mean_window_cost,
                         total=r_static.total_cost,
                         viol=r_static.violation_rate))
        emit(f"table5.{name}-static", 0.0,
             f"mean/win=${r_static.mean_window_cost:.1f};"
             f"total=${r_static.total_cost:.1f};"
             f"viol={100*r_static.violation_rate:.1f}%")
        r_roll = rolling(inst, path, roll_fn, replan_every=replan_every)
        rows.append(dict(method=f"{name}-5min",
                         mean_win=r_roll.mean_window_cost,
                         total=r_roll.total_cost, viol=r_roll.violation_rate,
                         replans=r_roll.replans))
        emit(f"table5.{name}-5min", 0.0,
             f"mean/win=${r_roll.mean_window_cost:.1f};"
             f"total=${r_roll.total_cost:.1f};"
             f"viol={100*r_roll.violation_rate:.1f}%;"
             f"replans={r_roll.replans}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=288)
    ap.add_argument("--day", default="busy", choices=["busy", "volatile"])
    args = ap.parse_args()
    run(n_windows=args.windows, day=args.day)
