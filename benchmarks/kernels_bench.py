"""Kernel microbenchmarks: interpret-mode Pallas vs jnp oracle timing +
flops accounting. (Wall times on CPU are for harness plumbing only — the
kernels target TPU; correctness is asserted in tests/test_kernels.py.)"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit


def _time(fn, *args, iters: int = 3) -> float:
    fn(*args)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> None:
    rng = np.random.default_rng(0)
    # flash attention (prefill hot spot)
    from repro.kernels.flash_attention.ops import flash_attention
    B, H, KV, T, hd = 1, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, H, T, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, T, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, T, hd)), jnp.float32)
    flops = 4 * B * H * T * T * hd
    us_ref = _time(lambda *a: flash_attention(*a, use_pallas=False), q, k, v)
    emit("kernel.flash_attention.xla_ref", us_ref,
         f"shape=B{B}H{H}T{T}hd{hd};flops={flops:.2e}")
    us_pl = _time(lambda *a: flash_attention(*a, use_pallas=True), q, k, v)
    emit("kernel.flash_attention.pallas_interp", us_pl, "interpret=True")

    # decode attention (bandwidth-bound phase)
    from repro.kernels.decode_attention.ops import decode_attention
    G, S = H // KV, 2048
    q1 = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, KV, S, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, KV, S, hd)), jnp.float32)
    cache_bytes = 2 * B * KV * S * hd * 4
    us_ref = _time(lambda *a: decode_attention(*a, use_pallas=False),
                   q1, kc, vc)
    emit("kernel.decode_attention.xla_ref", us_ref,
         f"cache_bytes={cache_bytes:.2e}")
    us_pl = _time(lambda *a: decode_attention(*a, use_pallas=True),
                  q1, kc, vc)
    emit("kernel.decode_attention.pallas_interp", us_pl, "interpret=True")

    # ssm scan
    from repro.kernels.ssm_scan.ops import ssm_scan
    B2, T2, nh, hp, N = 1, 512, 2, 64, 64
    x = jnp.asarray(rng.normal(size=(B2, T2, nh, hp)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B2, T2, N)) * .5, jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B2, T2, N)) * .5, jnp.float32)
    dt = jnp.asarray(rng.uniform(1e-3, .1, (B2, T2, nh)), jnp.float32)
    A = -jnp.asarray(rng.uniform(.5, 2., (nh,)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(nh,)), jnp.float32)
    us_ref = _time(lambda *a: ssm_scan(*a, use_pallas=False),
                   x, Bm, Cm, dt, A, D)
    emit("kernel.ssm_scan.xla_ref", us_ref, f"T={T2};state={hp}x{N}")
    us_pl = _time(lambda *a: ssm_scan(*a, use_pallas=True),
                  x, Bm, Cm, dt, A, D)
    emit("kernel.ssm_scan.pallas_interp", us_pl, "interpret=True")

    # rwkv6
    from repro.kernels.rwkv6_wkv.ops import rwkv6_wkv
    B3, T3, H3, hd3 = 1, 256, 2, 64
    r = jnp.asarray(rng.normal(size=(B3, T3, H3, hd3)) * .5, jnp.float32)
    k3 = jnp.asarray(rng.normal(size=(B3, T3, H3, hd3)) * .5, jnp.float32)
    v3 = jnp.asarray(rng.normal(size=(B3, T3, H3, hd3)) * .5, jnp.float32)
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(B3, T3, H3, hd3)) * .5 - 1.5,
                              jnp.float32))
    u = jnp.asarray(rng.normal(size=(H3, hd3)) * .5, jnp.float32)
    us_ref = _time(lambda *a: rwkv6_wkv(*a, use_pallas=False),
                   r, k3, v3, lw, u)
    emit("kernel.rwkv6_wkv.xla_ref", us_ref, f"T={T3};state={hd3}x{hd3}")
    us_pl = _time(lambda *a: rwkv6_wkv(*a, use_pallas=True), r, k3, v3, lw, u)
    emit("kernel.rwkv6_wkv.pallas_interp", us_pl, "interpret=True")


if __name__ == "__main__":
    run()
