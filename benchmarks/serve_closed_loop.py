"""Closed-loop serving: forecast-aware replanning vs fixed cadence vs static.

One `repro.serve()` run per controller mode over the same seeded diurnal
day of Poisson traffic (`core/trace.diurnal_multipliers("busy")`,
lognormal token-length noise, plan-aware weighted-random routing):

* ``forecast`` — the tentpole controller: EWMA arrival-rate forecast +
  drift/SLO-violation trigger (`serving.ReplanController`), warm
  `PlanSession.replan()` on firings only;
* ``fixed``    — blind cadence: replan every ``replan_every`` windows
  (PR 5's ``rolling(replan_every=)`` behavior, the baseline the paper's
  operating loop implies);
* ``static``   — never replan (the frozen-plan floor).

Every mode starts from the same cold AGH plan of the queueing-margin view
(`with_queueing_margin(inst, RHO_MAX)` — ~`1/(1-rho)` latency headroom so
p99, not mean, meets the SLO under simulated queueing + slowest-member
batch coupling), and every replan re-applies the same margin to its
forecast basis so a mid-run replan never sheds the headroom policy.

The acceptance claim this benchmark demonstrates at (100,80,40): the
forecast controller keeps worst-type p99 e2e within its SLO through the
diurnal cycle with strictly fewer replans than the fixed cadence at
equal-or-better attainment, and total planner wall time stays under 5% of
the simulated horizon.

Row identity for the CI regression gate encodes the mode into the size
string (``"(100,80,40)|forecast"``; `check_regression._row_key` is
``(size, engine)``).  Traffic, routing, and the simulator are seeded and
numpy-only, so attainment / replan counts / p99 ratios are deterministic
and exact-gated (``*_obj``); planner wall time is machine-dependent and
runtime-gated (``*_s``).

``--trajectory-out PATH`` appends this run's rows to the append-only
``BENCH_allocator.json`` artifact, same as `allocator_scaling`.
"""
from __future__ import annotations

import numpy as np

from repro.core import random_instance
from repro.core.queueing import with_queueing_margin
from repro.planner import PlanOptions, PlanSession
from repro.serving import ControllerSpec, TrafficSpec, serve

from .common import Timer, emit

SIZES = [(100, 80, 40)]                  # the acceptance fleet scale
QUICK_SIZES = [(24, 20, 10)]             # CI smoke
RHO_MAX = 0.65                           # queueing-margin utilization cap
HORIZON_S = 86400.0                      # one full diurnal day
QUICK_HORIZON_S = 7200.0
WINDOW_S = 300.0                         # 5-minute control windows
RATE_SCALE = 0.005                       # Poisson thinning of fleet rates
QUICK_RATE_SCALE = 0.02
TRACE = "busy"                           # core.trace diurnal day
MODES = ("forecast", "fixed", "static")
# Forecast-trigger knobs tuned for the diurnal trace: slower EWMA + a
# higher drift bar than the defaults, so the controller tracks the ramp
# with a handful of replans instead of firing every cooldown.
FORECAST_KW = dict(drift_threshold=0.5, cooldown=6, ewma_alpha=0.5)


def _controller(mode: str) -> ControllerSpec:
    kw = FORECAST_KW if mode == "forecast" else {}
    return ControllerSpec(mode=mode, rho_max=RHO_MAX, **kw)


def run(sizes=SIZES, horizon_s: float = HORIZON_S,
        rate_scale: float = RATE_SCALE, quick: bool = False) -> list[dict]:
    if quick:
        sizes, horizon_s, rate_scale = (QUICK_SIZES, QUICK_HORIZON_S,
                                        QUICK_RATE_SCALE)
    rows: list[dict] = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        traffic = TrafficSpec(horizon_s=horizon_s, window_s=WINDOW_S,
                              rate_scale=rate_scale, trace=TRACE, seed=1)
        size = f"({I},{J},{K})"
        mode_rows: dict[str, dict] = {}
        for mode in MODES:
            # Fresh session per mode: serve() advances the session in
            # place (the incumbent after a run is the last replan's).
            sess = PlanSession(options=PlanOptions(workers=0))
            with Timer() as t_plan:
                res = sess.plan(instance=with_queueing_margin(inst, RHO_MAX))
            sr = serve(res, instance=inst, session=sess, traffic=traffic,
                       controller=_controller(mode))
            p99_slo = float(np.nanmax(sr.per_type_e2e_p99 / inst.Delta))
            cal = sr.calibration()
            row = {
                "size": f"{size}|{mode}", "engine": "numpy",
                "initial_obj": round(res.objective, 4),
                "attain_obj": round(sr.attainment(), 6),
                "replans_obj": len(sr.replans),
                "served_obj": sr.n_served, "shed_obj": sr.n_shed,
                "p99_slo_ratio_obj": round(p99_slo, 4),
                "rental_per_h_obj": round(sr.mean_rental_per_h, 4),
                "calibration_med_obj": round(float(np.nanmedian(cal)), 4),
                "plan_wall_s": round(t_plan.dt, 4),
                "replan_wall_s": round(sr.planner_wall_s, 4),
                "planner_frac": round(
                    (t_plan.dt + sr.planner_wall_s) / horizon_s, 6),
            }
            rows.append(row)
            mode_rows[mode] = row
            emit(f"serve_closed_loop.{size}.{mode}",
                 sr.planner_wall_s * 1e6,
                 f"attain={row['attain_obj']:.4f};"
                 f"replans={row['replans_obj']};"
                 f"p99/slo={p99_slo:.3f};shed={sr.n_shed};"
                 f"pfrac={row['planner_frac']:.5f}")

        # Acceptance facts (informational in quick mode — the tiny smoke
        # instance is not the claim; the (100,80,40) day is).
        fc, fx = mode_rows["forecast"], mode_rows["fixed"]
        facts = {
            "fewer_replans": fc["replans_obj"] < fx["replans_obj"],
            "attain_ok": fc["attain_obj"] >= fx["attain_obj"] - 1e-9,
            "p99_within_slo": fc["p99_slo_ratio_obj"] <= 1.0,
            "planner_under_5pct": fc["planner_frac"] < 0.05,
        }
        emit(f"serve_closed_loop.{size}.acceptance", 0.0,
             ";".join(f"{k}={v}" for k, v in facts.items()))
        if not quick and not all(facts.values()):
            raise AssertionError(
                f"closed-loop acceptance failed at {size}: {facts}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small instance + short horizon (CI smoke)")
    ap.add_argument("--horizon", type=float, default=HORIZON_S,
                    help="simulated seconds (full mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump rows as a benchmarks.run-style JSON file "
                         "(consumed by check_regression)")
    ap.add_argument("--trajectory-out", default=None, metavar="PATH",
                    help="append this run's rows to the trajectory "
                         "artifact (e.g. BENCH_allocator.json)")
    args = ap.parse_args()
    out_rows = run(horizon_s=args.horizon, quick=args.quick)
    if args.json:
        import json

        from .common import JSON_SCHEMA_VERSION, ensure_outdir, git_sha
        ensure_outdir(args.json)
        with open(args.json, "w") as fh:
            json.dump({"schema_version": JSON_SCHEMA_VERSION,
                       "git_sha": git_sha(),
                       "sections": {"serve_closed_loop": out_rows}}, fh,
                      indent=2)
        print(f"# wrote {args.json}", flush=True)
    if args.trajectory_out:
        from .trajectory import append
        append(args.trajectory_out, out_rows, label="serve_closed_loop")
