"""CI benchmark-regression gate.

Diffs fresh ``benchmarks.run --json`` dumps against the committed baseline
(`benchmarks/baselines/ci_baseline.json`) and fails the job when the
allocator regresses:

* **objectives are exact** (relative tolerance ``--objective-rtol``,
  default 1e-6 — enough for cross-BLAS last-ulp noise, far below any real
  quality regression): every ``*_obj`` key of every baseline row must
  match the fresh value.  DM columns are excluded — the exact solver runs
  under a wall-clock limit, so its incumbent (and the AGH gap against it)
  is machine-dependent by construction;
* **runtimes get a generous factor** (``--runtime-factor``, default 5x):
  every ``*_s`` / ``*_us`` key may drift with machine speed but not blow
  past ``baseline * factor`` — catching order-of-magnitude engine
  regressions without flaking on CI hardware variance.  Keys whose
  baseline runtime is below ``--runtime-floor`` (10 ms) gate on an
  absolute allowance instead: ``fresh <= max(baseline * factor,
  --runtime-ceiling)`` (default 5 ms).  The old behavior skipped those
  keys entirely, which let a 0.5 ms hot path regress to 9 ms unnoticed;
  the ceiling keeps scheduler noise out of the gate while still bounding
  fast-path blowups;
* **stale baselines are rejected**: the baseline and every fresh dump
  must carry the current ``JSON_SCHEMA_VERSION`` (bumped whenever the row
  layout changes), so the gate never silently "passes" by comparing
  incompatible shapes.  Each dump also records its git SHA for
  provenance, printed in the report.

Usage (CI runs this after the benchmark smoke steps)::

    python -m benchmarks.check_regression \
        bench-out/table6.json bench-out/allocator_scaling.json

Exit code 0 = no regression, 1 = regression or malformed input.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .common import JSON_SCHEMA_VERSION

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baselines", "ci_baseline.json")
# DM/milp is an anytime MILP under a time limit: its incumbent objective
# and the AGH gap derived from it vary with machine speed — never gated.
# Prefixes match the FLATTENED key (registry-keyed sub-dicts flatten to
# "<solver>.<field>", so "milp." covers every exact-solver column).
SKIP_KEY_PREFIXES = ("DM_", "AGH_gap", "milp.", "dm.", "agh_gap")


def _is_runtime_key(key: str) -> bool:
    return key.endswith("_s") or key.endswith("_us")


def _is_objective_key(key: str) -> bool:
    return key.endswith("_obj") or key.endswith("objective")


def _runtime_seconds(key: str, val: float) -> float:
    return val / 1e6 if key.endswith("_us") else val


def _flatten(row: dict) -> dict:
    """Registry-keyed rows carry solver sub-dicts (`PlanResult.summary()`
    per registered solver); flatten one level to "<solver>.<field>" so
    the objective/runtime key rules below apply uniformly."""
    flat: dict = {}
    for key, val in row.items():
        if isinstance(val, dict):
            for k2, v2 in val.items():
                flat[f"{key}.{k2}"] = v2
        else:
            flat[key] = val
    return flat


def _row_key(row: dict) -> tuple:
    """Row identity within a section: (size, engine).  The engine field
    entered the schema with the xla allocator tier (v4) — without it an
    xla row and a numpy row of the same size would silently collide and
    the gate would diff one engine's fresh timings against the other's
    baseline."""
    return (row.get("size"), row.get("engine", "numpy"))


def check(baseline: dict, fresh_sections: dict, objective_rtol: float,
          runtime_factor: float, runtime_floor_s: float = 0.01,
          runtime_ceiling_s: float = 0.005) -> list[str]:
    """Returns a list of human-readable failure strings (empty = pass)."""
    failures: list[str] = []
    for section, base_rows in baseline["sections"].items():
        fresh_rows = fresh_sections.get(section)
        if fresh_rows is None:
            failures.append(f"{section}: section missing from fresh output")
            continue
        if isinstance(fresh_rows, dict) and "error" in fresh_rows:
            failures.append(f"{section}: fresh run errored: "
                            f"{fresh_rows['error']}")
            continue
        fresh_by_size = {_row_key(r): r for r in fresh_rows}
        for base_row in base_rows:
            size = base_row.get("size")
            fresh = fresh_by_size.get(_row_key(base_row))
            if fresh is None:
                failures.append(f"{section} {size}: row missing")
                continue
            fresh = _flatten(fresh)
            for key, base_val in _flatten(base_row).items():
                if key == "size" or key.startswith(SKIP_KEY_PREFIXES):
                    continue
                if not isinstance(base_val, (int, float)):
                    continue
                val = fresh.get(key)
                if not isinstance(val, (int, float)):
                    failures.append(
                        f"{section} {size} {key}: missing/non-numeric "
                        f"(baseline {base_val})")
                    continue
                if _is_objective_key(key):
                    tol = objective_rtol * max(1.0, abs(base_val))
                    if abs(val - base_val) > tol:
                        failures.append(
                            f"{section} {size} {key}: objective "
                            f"{val} != baseline {base_val} "
                            f"(rtol {objective_rtol})")
                elif _is_runtime_key(key):
                    if _runtime_seconds(key, base_val) < runtime_floor_s:
                        # Fast path: the factor alone would gate on
                        # scheduler jitter, but skipping entirely lets a
                        # sub-ms hot path blow up unnoticed — allow the
                        # larger of factor and the absolute ceiling.
                        scale = 1e6 if key.endswith("_us") else 1.0
                        limit = max(base_val * runtime_factor,
                                    runtime_ceiling_s * scale)
                        if val > limit:
                            failures.append(
                                f"{section} {size} {key}: fast-path "
                                f"runtime {val} > max({runtime_factor}x "
                                f"baseline {base_val}, ceiling {limit})")
                    elif val > base_val * runtime_factor:
                        failures.append(
                            f"{section} {size} {key}: runtime {val} > "
                            f"{runtime_factor}x baseline {base_val}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", nargs="+",
                    help="fresh benchmarks.run --json dumps to gate")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--objective-rtol", type=float, default=1e-6)
    ap.add_argument("--runtime-factor", type=float, default=5.0)
    ap.add_argument("--runtime-floor", type=float, default=0.01,
                    help="below this baseline runtime (seconds) the "
                         "factor check is replaced by the absolute "
                         "ceiling check")
    ap.add_argument("--runtime-ceiling", type=float, default=0.005,
                    help="absolute runtime allowance (seconds) for keys "
                         "whose baseline is under --runtime-floor")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from the fresh dumps "
                         "instead of checking against it")
    args = ap.parse_args(argv)

    if args.write_baseline:
        sections: dict = {}
        sha = "unknown"
        for path in args.fresh:
            with open(path) as fh:
                dump = json.load(fh)
            sha = dump.get("git_sha", sha)
            for name, rows in dump.get("sections", {}).items():
                if isinstance(rows, list):
                    sections[name] = rows
        payload = {"schema_version": JSON_SCHEMA_VERSION,
                   "source_git_sha": sha, "sections": sections}
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"# wrote baseline {args.baseline} "
              f"({sum(len(v) for v in sections.values())} rows)", flush=True)
        return 0

    with open(args.baseline) as fh:
        baseline = json.load(fh)
    if baseline.get("schema_version") != JSON_SCHEMA_VERSION:
        print(f"REGRESSION GATE: stale baseline — schema_version "
              f"{baseline.get('schema_version')} != current "
              f"{JSON_SCHEMA_VERSION}; regenerate "
              f"benchmarks/baselines/ci_baseline.json", flush=True)
        return 1

    fresh_sections: dict = {}
    for path in args.fresh:
        with open(path) as fh:
            dump = json.load(fh)
        if dump.get("schema_version") != JSON_SCHEMA_VERSION:
            print(f"REGRESSION GATE: {path} carries schema_version "
                  f"{dump.get('schema_version')} != current "
                  f"{JSON_SCHEMA_VERSION}", flush=True)
            return 1
        print(f"# {path}: git {dump.get('git_sha', 'unknown')[:12]}, "
              f"sections {sorted(dump.get('sections', {}))}", flush=True)
        fresh_sections.update(dump.get("sections", {}))
    print(f"# baseline: {args.baseline} "
          f"(source git {baseline.get('source_git_sha', 'unknown')[:12]})",
          flush=True)

    failures = check(baseline, fresh_sections,
                     objective_rtol=args.objective_rtol,
                     runtime_factor=args.runtime_factor,
                     runtime_floor_s=args.runtime_floor,
                     runtime_ceiling_s=args.runtime_ceiling)
    if failures:
        print(f"REGRESSION GATE: {len(failures)} failure(s)", flush=True)
        for f in failures:
            print(f"  FAIL {f}", flush=True)
        return 1
    n_rows = sum(len(v) for v in baseline["sections"].values())
    print(f"REGRESSION GATE: OK ({n_rows} baseline rows checked)",
          flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
