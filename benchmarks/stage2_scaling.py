"""Stage-2 / rolling evaluation scaling: before/after rows for the
pattern-reuse LP engine (PR 2).

"Before" is the frozen seed protocol — one `Instance.perturbed` rebuild plus
one from-scratch dict-of-tuples LP assembly (`_scalar_ref.stage2_lp_ref`)
per scenario; "after" is the batched `Stage2System` path `evaluate` /
`rolling` use now.  Emits one ``name,us_per_call`` row per (size, path) so
evaluation-pipeline regressions show up directly in CI logs, plus rolling
replay rows (busy day, volatile day, multi-day, 1.5x stress) on the default
instance.
"""
from __future__ import annotations

import numpy as np

from repro.core import default_instance, evaluate, gh, random_instance
from repro.core import replay_study
from repro.core._scalar_ref import stage2_lp_ref
from repro.core.stage2 import stage2_cost

from .common import Timer, emit

SIZES = [(6, 6, 10), (10, 10, 10), (20, 20, 20)]


def _seed_loop(inst, deploy, S: int, seed: int = 1234) -> float:
    """The pre-PR per-scenario evaluation loop, verbatim protocol."""
    rng = np.random.default_rng(seed)
    costs = np.zeros(S)
    for s in range(S):
        scen = inst.perturbed(rng, d_infl=0.15, e_infl=0.10, lam_pm=0.20)
        sol, _ = stage2_lp_ref(scen, deploy)
        costs[s] = stage2_cost(scen, sol)
    return float(costs.mean())


def run(sizes=SIZES, S: int = 120, S_before: int = 30,
        n_windows: int = 96, quick: bool = False) -> list[dict]:
    if quick:
        # Keep the smallest and the (20,20,20) acceptance size.
        sizes, S, S_before, n_windows = [sizes[0], sizes[-1]], 40, 10, 48
    rows = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        deploy = gh(inst)
        size = f"({I},{J},{K})"
        with Timer() as t:
            _seed_loop(inst, deploy, S_before)
        before_us = t.us / S_before            # per-scenario
        emit(f"stage2_scaling.{size}.evaluate.before", before_us,
             f"S={S_before};per-scenario")
        with Timer() as t:
            res = evaluate(inst, deploy, S=S)
        after_us = t.us / S
        emit(f"stage2_scaling.{size}.evaluate.after", after_us,
             f"S={S};viol={100 * res.violation_rate:.1f}%;"
             f"speedup={before_us / max(after_us, 1e-9):.1f}x")
        rows.append(dict(size=size, before_us=before_us, after_us=after_us))

    # Rolling replays on the default instance (static GH deployment).
    inst = default_instance()
    plan = gh(inst)
    planner = lambda i, p=plan: p
    for name, kw in [
        ("busy", dict(days=("busy",))),
        ("volatile", dict(days=("volatile",))),
        ("multi-day", dict(days=("busy", "volatile"))),
        ("stress-1.5x", dict(days=("busy",), stress=1.5)),
    ]:
        with Timer() as t:
            r = replay_study(inst, planner, n_windows=n_windows, **kw)
        emit(f"stage2_scaling.replay.{name}", t.us / r.per_window_cost.size,
             f"windows={r.per_window_cost.size};total=${r.total_cost:.1f};"
             f"viol={100 * r.violation_rate:.1f}%")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--S", type=int, default=120)
    args = ap.parse_args()
    run(S=args.S, quick=args.quick)
