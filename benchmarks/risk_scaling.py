"""Risk-evaluation scaling: batched pdhg engine vs the exact HiGHS oracle.

One row per scenario count S: wall clock for `repro.risk.risk_evaluate`
through the batched solver (anchor-basis warm starts + Woodbury kernel,
jax) against the sequential exact oracle.  The oracle is *measured* up
to ``EXACT_CAP`` scenarios and extrapolated linearly beyond (it is a
per-scenario loop, so extrapolation is exact in expectation); rows
record which.  Where the oracle runs in full, the row also carries the
relative objective agreement (the acceptance contract is rtol 1e-5,
pinned per-scenario in tests/test_risk.py).

A jit warm-up pass at the same S runs before the timed pdhg pass, so
compile time (and the persistent-cache load) never pollutes the timed
row — the same protocol as the xla allocator benchmarks (compile cost
is a one-off; the timed row is the steady state a sweep would see).

The closing row is the subsystem's reason to exist: `rank_deployments`
scores GH vs AGH under the paper's 1.5x stress family and reports the
expected-cost and CVaR_0.95 orderings side by side — a plan that wins
on average but loses the tail is visible in one line.

``--trajectory-out PATH`` appends this run's rows to the append-only
``BENCH_allocator.json`` artifact, same as `allocator_scaling`.
"""
from __future__ import annotations

import time

from repro.core import agh, gh, random_instance

from .common import emit

SIZE = (20, 20, 20)                  # the acceptance instance scale
S_LIST = (500, 5_000, 20_000)        # standard sweep
S_LIST_FULL = (500, 5_000, 20_000, 100_000)
S_LIST_QUICK = (300, 2_000)          # CI smoke
EXACT_CAP = 2_000                    # oracle measured up to here, then
                                     # extrapolated (per-scenario loop)
RANK_S = {"quick": 1_000, "std": 5_000, "full": 20_000}


def run(quick: bool = False, full: bool = False,
        s_list: tuple[int, ...] | None = None) -> list[dict]:
    from repro.risk import rank_deployments, risk_evaluate

    if s_list is None:
        s_list = (S_LIST_QUICK if quick
                  else (S_LIST_FULL if full else S_LIST))
    exact_cap = EXACT_CAP if not full else max(S_LIST_FULL[:-1])
    inst = random_instance(*SIZE, seed=42)
    deploy = gh(inst)
    size = "(%d,%d,%d)" % SIZE
    rows: list[dict] = []

    for S in s_list:
        s_ex = min(S, exact_cap)
        t0 = time.perf_counter()
        r_ex = risk_evaluate(inst, deploy, S=s_ex, engine="exact")
        exact_wall = time.perf_counter() - t0
        extrapolated = s_ex < S
        exact_full_wall = exact_wall * (S / s_ex)

        # Warm-up at the SAME S hits every (chunk-bucket, group-bucket)
        # compile combo the timed pass will use.
        risk_evaluate(inst, deploy, S=S, engine="pdhg")
        t0 = time.perf_counter()
        r_pd = risk_evaluate(inst, deploy, S=S, engine="pdhg")
        pdhg_wall = time.perf_counter() - t0

        row: dict = {
            "size": f"{size}|S={S}", "engine": "pdhg",
            "pdhg_wall_s": round(pdhg_wall, 4),
            "exact_wall_s": round(exact_full_wall, 4),
            "exact_extrapolated": extrapolated,
            "speedup": round(exact_full_wall / max(pdhg_wall, 1e-9), 2),
            "exp_cost": round(r_pd.expected_cost, 6),
            "cvar95": round(r_pd.cvar["0.95"], 6),
            "violation_rate": round(r_pd.violation_rate, 6),
        }
        for k in ("n_anchor0", "n_harvest_exact", "n_pdhg",
                  "n_fallback_exact", "n_anchors"):
            row[k] = r_pd.diagnostics.get(k, 0)
        derived = (f"S={S};speedup={row['speedup']}x"
                   f"{';extrap' if extrapolated else ''}")
        if not extrapolated:
            agree = (abs(r_pd.expected_cost - r_ex.expected_cost)
                     / max(abs(r_ex.expected_cost), 1e-12))
            row["agree_rel"] = float(agree)
            derived += f";agree={agree:.2e}"
        emit(f"risk_scaling.{size}.S={S}", pdhg_wall * 1e6 / S, derived)
        rows.append(row)

    # CVaR-vs-expected ranking under the 1.5x stress family.  On a
    # separate instance seed: at seed 42 AGH's local search finds nothing
    # to improve over GH (bit-identical deployments), which would make
    # the ranking row compare a plan against itself.
    S_rank = RANK_S["quick" if quick else ("full" if full else "std")]
    inst_r = random_instance(*SIZE, seed=0)
    plans = {"gh": gh(inst_r), "agh": agh(inst_r)}
    t0 = time.perf_counter()
    ranking = rank_deployments(inst_r, plans, S=S_rank, engine="pdhg",
                               stress=1.5)
    rank_wall = time.perf_counter() - t0
    summaries = ranking["summaries"]
    rows.append({
        "size": f"{size}|ranking", "engine": "pdhg",
        "rank_wall_s": round(rank_wall, 4),
        "S": S_rank, "stress": 1.5,
        "ranking_expected": ">".join(ranking["ranking_expected"]),
        "ranking_cvar": ">".join(ranking["ranking_cvar"]),
        "rank_agree": ranking["agree"],
        **{f"{name}_cvar95": round(s["cvar_0.95"], 4)
           for name, s in summaries.items()},
        **{f"{name}_exp": round(s["expected_cost"], 4)
           for name, s in summaries.items()},
    })
    emit(f"risk_scaling.{size}.ranking", rank_wall * 1e6,
         f"S={S_rank};exp={rows[-1]['ranking_expected']};"
         f"cvar={rows[-1]['ranking_cvar']};agree={ranking['agree']}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small S sweep (CI smoke)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep up to S=100k")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump rows as a benchmarks.run-style JSON file "
                         "(consumed by check_regression)")
    ap.add_argument("--trajectory-out", default=None, metavar="PATH",
                    help="append this run's rows to the trajectory "
                         "artifact (e.g. BENCH_allocator.json)")
    args = ap.parse_args()
    out_rows = run(quick=args.quick, full=args.full)
    if args.json:
        import json

        from .common import JSON_SCHEMA_VERSION, ensure_outdir, git_sha
        ensure_outdir(args.json)
        with open(args.json, "w") as fh:
            json.dump({"schema_version": JSON_SCHEMA_VERSION,
                       "git_sha": git_sha(),
                       "sections": {"risk_scaling": out_rows}}, fh,
                      indent=2)
        print(f"# wrote {args.json}", flush=True)
    if args.trajectory_out:
        from .trajectory import append
        append(args.trajectory_out, out_rows, label="risk_scaling")
