"""Table 3: ablation of the three constraint-aware mechanisms.

Disables each of M1 (feasibility filter), M2 (cost-per-effective-coverage
ranking), M3 (TP upgrade) in isolation on the default setup and reports
feasibility + cost. Expected (paper): w/o M1 -> memory violation;
w/o M3 -> delay violation; w/o M2 -> feasible but ~+50% cost.
"""
from __future__ import annotations

import numpy as np

from repro.core import default_instance, feasibility, objective
from repro.core.agh import agh
from repro.core.gh import greedy_heuristic

from .common import Timer, emit


def _agh_like(inst, ablation: frozenset):
    """Multi-start GH with the given ablation (local search preserves
    feasibility by construction, so ablation effects show in construction)."""
    best, best_obj = None, np.inf
    for key in (np.argsort(-inst.lam), np.argsort(inst.lam),
                np.argsort(-inst.phi), np.argsort(inst.eps)):
        sol, _ = greedy_heuristic(inst, order=key, ablation=ablation)
        obj = objective(inst, sol)
        if obj < best_obj:
            best, best_obj = sol, obj
    return best


def _ablate(inst, label: str) -> list[dict]:
    rows = []
    variants = [("all_M1-M3", frozenset()),
                ("wo_M1", frozenset({"no_m1"})),
                ("wo_M2", frozenset({"no_m2"})),
                ("wo_M3", frozenset({"no_m3"}))]
    base_cost = None
    for name, abl in variants:
        with Timer() as t:
            sol = (agh(inst) if not abl else _agh_like(inst, abl))
        v = feasibility(inst, sol, enforce_zeta=False)
        bad = {k: round(val, 4) for k, val in v.items() if val > 1e-4}
        feasible = not bad
        cost = objective(inst, sol)
        if name == "all_M1-M3":
            base_cost = cost
        delta = ""
        if feasible and base_cost:
            delta = f"{100 * (cost / base_cost - 1):+.0f}%"
        rows.append(dict(variant=name, feasible=feasible,
                         cost=round(cost, 2), violations=bad, delta=delta))
        emit(f"table3{label}.{name}", t.us,
             f"feasible={feasible};cost=${cost:.2f};viol={list(bad)};"
             f"delta={delta}")
    return rows


def run() -> list[dict]:
    rows = _ablate(default_instance(), "")
    # Strict-accuracy variant: ImageGen eps tightened so only 34B+ at
    # FP16/INT8 is admissible — the big-model-on-small-tier conflict the
    # paper's M1 guards against (in the default calibration INT4 shrinks
    # the 34B under the 24 GB tier, so M1's removal shows as cost, not a
    # memory violation).
    strict = default_instance()
    strict.eps[4] = 0.0125
    strict.__post_init__()
    rows += _ablate(strict, ".strict")
    return rows


if __name__ == "__main__":
    run()
