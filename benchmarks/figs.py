"""Figure reproductions (Figs. 2–5): parameter sweeps printed as CSV.

fig2 — budget sensitivity (expected cost & violations vs delta)
fig3 — uncertainty robustness (stress multiplier alpha on d, e)
fig4 — unmet-cap sensitivity (u_ub in {1%, 2%, 5%, soft})
fig5 — stress panels (GH/AGH/DM under 1.0/1.2/1.5x, strict 2% cap) and
        AGH sensitivity to Delta_i / eps_i scaling (panels d–f)
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import default_instance, evaluate
from repro.planner import PlanOptions, plan

from .common import emit


def _sol(solver: str, inst, **opt):
    """Registry-facade solve returning the bare Solution."""
    return plan(solver, instance=inst, options=PlanOptions(**opt)).solution


def fig2_budget(S: int = 60, budgets=(72, 75, 80, 90, 100, 120)) -> None:
    for b in budgets:
        inst = default_instance(budget=float(b))
        for name in ("gh", "agh", "hf"):
            r = evaluate(inst, _sol(name, inst), S=S, u_cap=np.full(6, 0.02))
            emit(f"fig2.budget{b}.{name}", 0.0,
                 f"cost=${r.expected_cost:.1f};viol={100*r.violation_rate:.1f}%")


def fig3_stress(S: int = 60, alphas=(1.0, 1.1, 1.2, 1.35, 1.5)) -> None:
    inst = default_instance()
    plans = [("gh", _sol("gh", inst)), ("agh", _sol("agh", inst)),
             ("lpr", _sol("lpr", inst, time_limit=120.0)),
             ("dvr", _sol("dvr", inst)), ("hf", _sol("hf", inst))]
    for alpha in alphas:
        stressed = inst.stressed(alpha)
        for name, dep in plans:
            r = evaluate(stressed, dep, S=S, d_infl=0.0, e_infl=0.0,
                         u_cap=np.full(6, 0.02))
            emit(f"fig3.a{alpha:.2f}.{name}", 0.0,
                 f"cost=${r.expected_cost:.1f};viol={100*r.violation_rate:.1f}%")


def fig4_unmet_cap(S: int = 60, caps=(0.01, 0.02, 0.05, 1.0),
                   include_dm: bool = False) -> None:
    inst = default_instance()
    plans = [(n, _sol(n, inst)) for n in ("gh", "agh", "hf")]
    if include_dm:
        plans.append(("milp", _sol("milp", inst, time_limit=180.0)))
    for cap in caps:
        label = "soft" if cap >= 1.0 else f"{int(cap*100)}pct"
        for name, dep in plans:
            r = evaluate(inst, dep, S=S, u_cap=np.full(6, cap))
            emit(f"fig4.cap_{label}.{name}", 0.0,
                 f"cost=${r.expected_cost:.1f};viol={100*r.violation_rate:.1f}%")


def fig5_stress_panels(S: int = 60, include_dm: bool = True) -> None:
    inst = default_instance()
    plans = [(n, _sol(n, inst)) for n in ("gh", "agh")]
    if include_dm:
        plans.append(("milp", _sol("milp", inst, time_limit=180.0)))
    for alpha in (1.0, 1.2, 1.5):
        stressed = inst.stressed(alpha)
        for name, dep in plans:
            r = evaluate(stressed, dep, S=S, d_infl=0.0, e_infl=0.0,
                         u_cap=np.full(6, 0.02))
            emit(f"fig5.stress{alpha:.1f}.{name}", 0.0,
                 f"cost=${r.expected_cost:.1f};viol={100*r.violation_rate:.1f}%")
    # (d) delay-SLO vs error-SLO scaling for AGH
    for dscale in (0.8, 1.0, 1.2):
        for escale in (0.8, 1.0, 1.2):
            mod = dataclasses.replace(inst)
            mod.Delta = inst.Delta * dscale
            mod.eps = inst.eps * escale
            mod.__post_init__()
            sol = _sol("agh", mod)
            from repro.core import objective, provisioning_cost
            emit(f"fig5d.D{dscale:.1f}.e{escale:.1f}.AGH", 0.0,
                 f"obj=${objective(mod, sol):.1f};"
                 f"gpus={int(sol.y.sum())};stage1=${provisioning_cost(mod, sol):.1f}")
    # (e) rental-price scaling
    for pscale in (0.75, 1.0, 1.5, 2.0):
        mod = dataclasses.replace(inst)
        mod.p_c = inst.p_c * pscale
        mod.__post_init__()
        sol = _sol("agh", mod)
        from repro.core import objective
        pairs = int(np.sum(sol.q))
        emit(f"fig5e.p{pscale:.2f}.AGH", 0.0,
             f"obj=${objective(mod, sol):.1f};pairs={pairs};"
             f"gpus={int(sol.y.sum())}")


def run(S: int = 60) -> None:
    fig2_budget(S=S)
    fig3_stress(S=S)
    fig4_unmet_cap(S=S)
    fig5_stress_panels(S=S)


if __name__ == "__main__":
    run()
