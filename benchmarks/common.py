"""Shared helpers for the per-table benchmarks."""
from __future__ import annotations

import os
import subprocess
import time

# JSON dump schema, bumped whenever the row-dict layout changes in a way
# the regression gate must not silently accept (see check_regression.py).
# v3: solver columns are registry-keyed sub-dicts (`PlanResult.summary()`
# rows keyed by the planner-registry solver name, e.g. "gh"/"agh"/
# "agh+reference") instead of flat per-method key prefixes.
# v4: rows carry an "engine" field ("numpy"/"xla") that is part of the
# row identity — xla and numpy rows of the same size never collide —
# and xla rows report jit compile time separately (`compile_s`) so the
# runtime gate sees steady-state timings only.
JSON_SCHEMA_VERSION = 4

_made_dirs: set[str] = set()


def ensure_outdir(path: str) -> None:
    """Create the directory holding `path` exactly once per process —
    repeated `--json` dumps (one per section invocation in CI) share the
    memo instead of re-running makedirs."""
    d = os.path.dirname(os.path.abspath(path))
    if d in _made_dirs:
        return
    os.makedirs(d, exist_ok=True)
    _made_dirs.add(d)


def git_sha() -> str:
    """Current commit SHA (`unknown` outside a work tree) — stamped into
    every JSON dump so the CI gate can reject stale baselines."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return "unknown"


def emit(name: str, us_per_call: float, derived: str) -> None:
    """CSV line per harness contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0

    @property
    def us(self) -> float:
        return self.dt * 1e6
