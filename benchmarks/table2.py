"""Table 2: Stage-2 evaluation across scenarios S1–S5.

Scenarios (paper §5.2): S1 default (delta=$100, phi_v=1x); S2 tight ($75);
S3 critical ($72); S4 high penalty ($75, phi_v=5x); S5 high penalty +
critical ($72, phi_v=5x). Methods: every registered heuristic solver
(gh, agh, lpr, dvr, hf; + milp optionally) — the grid is driven by the
planner registry, so a newly registered solver shows up as a new column
without touching this file.
Metrics: Stage-1 cost, expected cost over S perturbed scenarios, SLO
violation rate (>1% unserved per (scenario, type)).

With ``workers`` (``benchmarks.run --workers``), the 5 scenarios x N
methods cells are batched through ONE shared process pool — each cell
(plan + S-scenario Stage-2 evaluation) is independent, so the grid
parallelizes embarrassingly; results are gathered and emitted in the
canonical scenario/method order, so the output is identical to the
sequential path's.  Inside a pooled cell the Stage-2 ``workers=``
fan-out stays off (the pool already owns the cores).
"""
from __future__ import annotations

import numpy as np

from repro.core import default_instance, evaluate
from repro.planner import PlanOptions, plan

from .common import emit

SCENARIOS = {
    "S1": dict(budget=100.0, phi_v_mult=1.0),
    "S2": dict(budget=75.0, phi_v_mult=1.0),
    "S3": dict(budget=72.0, phi_v_mult=1.0),
    "S4": dict(budget=75.0, phi_v_mult=5.0),
    "S5": dict(budget=72.0, phi_v_mult=5.0),
}

METHODS = ("gh", "agh", "lpr", "dvr", "hf")


def _run_cell(args: tuple) -> tuple[dict, float]:
    """One (scenario, method) cell: plan on the forecast instance through
    the registry facade, then the frozen-deployment Stage-2 evaluation.
    Module-level and driven by picklable primitives so a process pool can
    run it."""
    sname, inst_kw, mname, S, u_cap, dm_limit = args
    inst = default_instance(seed=0, **inst_kw)
    # dm_limit caps the exact solver only; the other backends keep their
    # own defaults (LPR: 120 s) so --dm-limit never changes the baselines.
    limit = dm_limit if mname in ("milp", "dm") else None
    res = plan(mname, instance=inst, options=PlanOptions(time_limit=limit))
    ev = evaluate(inst, res.solution, S=S, u_cap=u_cap)
    row = dict(scenario=sname, method=mname,
               stage1=round(ev.stage1_cost, 1),
               cost=round(ev.expected_cost, 1),
               viol_pct=round(100 * ev.violation_rate, 1),
               plan_s=round(res.wall_s, 3))
    return row, res.wall_s * 1e6


def run(S: int = 100, include_dm: bool = False, dm_limit: float = 180.0,
        u_cap: float = 1.0, workers: int | None = None) -> list[dict]:
    cap = np.full(6, u_cap)
    methods = list(METHODS) + (["milp"] if include_dm else [])
    cells = [(sname, kw, mname, S, cap, dm_limit)
             for sname, kw in SCENARIOS.items() for mname in methods]
    import multiprocessing as mp
    if workers and workers > 1 and "fork" in mp.get_all_start_methods():
        import concurrent.futures as cf
        from concurrent.futures.process import BrokenProcessPool
        try:
            ctx = mp.get_context("fork")
            with cf.ProcessPoolExecutor(max_workers=workers,
                                        mp_context=ctx) as ex:
                results = list(ex.map(_run_cell, cells))
        except (OSError, BrokenProcessPool):
            # pool-infrastructure failure only; cell errors propagate
            results = [_run_cell(c) for c in cells]
    else:
        results = [_run_cell(c) for c in cells]
    rows = []
    for (sname, _, mname, *_), (row, us) in zip(cells, results):
        rows.append(row)
        emit(f"table2.{sname}.{mname}", us,
             f"stage1=${row['stage1']};cost=${row['cost']};"
             f"viol={row['viol_pct']}%")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--S", type=int, default=500)
    ap.add_argument("--dm", action="store_true")
    ap.add_argument("--workers", type=int, default=None,
                    help="fan the scenario x method grid over one shared "
                         "process pool")
    args = ap.parse_args()
    run(S=args.S, include_dm=args.dm, workers=args.workers)
