"""Table 2: Stage-2 evaluation across scenarios S1–S5.

Scenarios (paper §5.2): S1 default (delta=$100, phi_v=1x); S2 tight ($75);
S3 critical ($72); S4 high penalty ($75, phi_v=5x); S5 high penalty +
critical ($72, phi_v=5x). Methods: GH, AGH, LPR, DVR, HF (+DM optionally).
Metrics: Stage-1 cost, expected cost over S perturbed scenarios, SLO
violation rate (>1% unserved per (scenario, type)).
"""
from __future__ import annotations

import numpy as np

from repro.core import (agh, default_instance, dvr, evaluate, gh, hf, lpr,
                        solve_milp)

from .common import Timer, emit

SCENARIOS = {
    "S1": dict(budget=100.0, phi_v_mult=1.0),
    "S2": dict(budget=75.0, phi_v_mult=1.0),
    "S3": dict(budget=72.0, phi_v_mult=1.0),
    "S4": dict(budget=75.0, phi_v_mult=5.0),
    "S5": dict(budget=72.0, phi_v_mult=5.0),
}


def run(S: int = 100, include_dm: bool = False, dm_limit: float = 180.0,
        u_cap: float = 1.0) -> list[dict]:
    rows = []
    cap = np.full(6, u_cap)
    for sname, kw in SCENARIOS.items():
        inst = default_instance(seed=0, **kw)
        methods = [("GH", gh), ("AGH", agh), ("LPR", lpr), ("DVR", dvr),
                   ("HF", hf)]
        if include_dm:
            methods.append(("DM", lambda i: solve_milp(i, time_limit=dm_limit)))
        for mname, fn in methods:
            with Timer() as t:
                sol = fn(inst)
            res = evaluate(inst, sol, S=S, u_cap=cap)
            row = dict(scenario=sname, method=mname,
                       stage1=round(res.stage1_cost, 1),
                       cost=round(res.expected_cost, 1),
                       viol_pct=round(100 * res.violation_rate, 1),
                       plan_s=round(sol.runtime_s, 3))
            rows.append(row)
            emit(f"table2.{sname}.{mname}", t.us,
                 f"stage1=${row['stage1']};cost=${row['cost']};"
                 f"viol={row['viol_pct']}%")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--S", type=int, default=500)
    ap.add_argument("--dm", action="store_true")
    args = ap.parse_args()
    run(S=args.S, include_dm=args.dm)
