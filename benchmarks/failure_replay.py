"""Failure-injection replay: warm repair vs cold re-solve vs frozen static.

One diurnal rolling replay per (instance size, fault response) over a
seeded supply-fault schedule (`core/faults.py`): Poisson spot
revocations on the cheapest third of the tier catalog, a mid-replay
fleet-wide capacity shock, and a full outage of the busiest tier.  Every
supply change point triggers an event-driven re-solve; the three
responses differ only in how they react:

* ``repair``  — `PlanSession.repair` (warm `agh_repair`: evict, re-route,
  one incremental pass, graceful-degradation ladder);
* ``cold``    — a full cold AGH solve of the faulted instance per event;
* ``static``  — no reaction: the initial placement rides through the
  faults and loses the traffic its revoked pairs carried (the
  degradation floor the other two are measured against);
* ``nofault`` — the same replay with no fault schedule (the cost floor:
  ``cost_drift`` on the fault rows is total cost relative to this row).

Row identity for the CI regression gate (`check_regression._row_key` is
``(size, engine)``) encodes the response into the size string —
``"(100,80,40)|repair"`` — so the four rows of one size never collide.
``initial_obj`` is the deterministic cold solve of the unfaulted
instance (exact-gated); ``repair_wall_mean_s`` / ``repair_wall_max_s``
are the per-event re-solve latencies (runtime-gated 5x) — the
acceptance bar is sub-second warm repairs at (100,80,40).

``--trajectory-out PATH`` appends this run's rows to the append-only
``BENCH_allocator.json`` artifact, same as `allocator_scaling`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (CapacityShock, FaultSchedule, TierOutage,
                        poisson_revocations, random_instance, rolling,
                        with_spot_tiers)
from repro.core.trace import diurnal_multipliers
from repro.planner import PlanOptions, PlanSession, plan

from .common import emit

SIZES = [(100, 80, 40)]                  # the acceptance fleet scale
QUICK_SIZES = [(24, 20, 10)]             # CI smoke
WINDOWS = 32                             # full replay length (45-min windows)
QUICK_WINDOWS = 12
REPLAN_EVERY = 8                         # scheduled replans between events
SPOT_REVOKE_RATE = 0.02                  # revocations/hour per spot tier
SPOT_FRACTION = 3                        # cheapest 1/3 of tiers on spot
ZETA = 0.5                               # binding unmet cap (ladder-visible)
CAP_HEADROOM = 1.5                       # nominal avail = 1.5x cold usage + 4
RESPONSES = ("static", "cold", "repair")


def _build_case(I: int, J: int, K: int, T: int, seed: int = 42):
    """Instance with nominal availability caps + spot tiers, the seeded
    fault schedule, the diurnal demand path, and the deterministic cold
    solve of the unfaulted instance (the exact-gated anchor)."""
    inst = random_instance(I, J, K, seed=seed)
    inst = dataclasses.replace(inst, zeta=np.full(I, ZETA))
    opts = PlanOptions(workers=0)
    cold0 = plan("agh", instance=inst, options=opts)
    y_tier = cold0.solution.y.sum(axis=0)
    nominal = np.ceil(CAP_HEADROOM * y_tier) + 4
    capped = dataclasses.replace(inst, avail_gpus=nominal)
    spot_idx = np.argsort(inst.p_c)[: max(1, K // SPOT_FRACTION)]
    capped = with_spot_tiers(capped, spot_idx,
                             revoke_rate=SPOT_REVOKE_RATE)
    events = list(poisson_revocations(capped, T, seed=seed + 7, frac=0.6))
    dur = max(2, T // 8)
    busiest = int(np.argmax(y_tier))
    events += [
        CapacityShock(t0=T // 3, t1=T // 3 + dur, avail_frac=0.5),
        TierOutage(tier=busiest, t0=(2 * T) // 3, t1=(2 * T) // 3 + dur),
    ]
    sched = FaultSchedule(n_windows=T, events=tuple(events))
    mult = diurnal_multipliers("busy", seed=seed + 9, n_windows=T)
    lam_path = np.outer(mult, inst.lam)
    return capped, sched, lam_path, cold0, opts


def run(sizes=SIZES, T: int = WINDOWS, quick: bool = False) -> list[dict]:
    if quick:
        sizes, T = QUICK_SIZES, QUICK_WINDOWS
    rows: list[dict] = []
    for (I, J, K) in sizes:
        capped, sched, lam_path, cold0, opts = _build_case(I, J, K, T)
        size = f"({I},{J},{K})"

        def bare(inst, _opts=opts):
            return plan("agh", instance=inst, options=_opts).solution

        base = rolling(capped, lam_path, bare, replan_every=REPLAN_EVERY)
        rows.append({
            "size": f"{size}|nofault", "engine": "numpy",
            "initial_obj": round(cold0.objective, 4),
            "total_cost": round(base.total_cost, 4),
            "violation_rate": round(base.violation_rate, 6),
        })
        emit(f"failure_replay.{size}.nofault", 0.0,
             f"cost={base.total_cost:.2f};viol={base.violation_rate:.4f}")

        for response in RESPONSES:
            planner = (PlanSession(options=opts) if response == "repair"
                       else bare)
            r = rolling(capped, lam_path, planner,
                        replan_every=(None if response == "static"
                                      else REPLAN_EVERY),
                        faults=sched, fault_response=response)
            row: dict = {
                "size": f"{size}|{response}", "engine": "numpy",
                "initial_obj": round(cold0.objective, 4),
                "total_cost": round(r.total_cost, 4),
                "violation_rate": round(r.violation_rate, 6),
                "cost_drift": round(
                    r.total_cost / max(base.total_cost, 1e-9) - 1.0, 4),
                "fault_replans": r.fault_replans,
                "evictions": r.evictions,
            }
            if r.repair_wall_s:
                walls = np.asarray(r.repair_wall_s)
                row["repair_wall_mean_s"] = round(float(walls.mean()), 4)
                row["repair_wall_max_s"] = round(float(walls.max()), 4)
            if r.degradation_levels:
                row["deg_level_max"] = int(max(r.degradation_levels))
            rows.append(row)
            wall = float(np.mean(r.repair_wall_s)) if r.repair_wall_s else 0.0
            emit(f"failure_replay.{size}.{response}", wall * 1e6,
                 f"cost={r.total_cost:.2f};viol={r.violation_rate:.4f};"
                 f"drift={row['cost_drift']:+.3f};"
                 f"evict={r.evictions}")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small instance + short replay (CI smoke)")
    ap.add_argument("--windows", type=int, default=WINDOWS,
                    help="replay length in windows (full mode)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump rows as a benchmarks.run-style JSON file "
                         "(consumed by check_regression)")
    ap.add_argument("--trajectory-out", default=None, metavar="PATH",
                    help="append this run's rows to the trajectory "
                         "artifact (e.g. BENCH_allocator.json)")
    args = ap.parse_args()
    out_rows = run(T=args.windows, quick=args.quick)
    if args.json:
        import json

        from .common import JSON_SCHEMA_VERSION, ensure_outdir, git_sha
        ensure_outdir(args.json)
        with open(args.json, "w") as fh:
            json.dump({"schema_version": JSON_SCHEMA_VERSION,
                       "git_sha": git_sha(),
                       "sections": {"failure_replay": out_rows}}, fh,
                      indent=2)
        print(f"# wrote {args.json}", flush=True)
    if args.trajectory_out:
        from .trajectory import append
        append(args.trajectory_out, out_rows, label="failure_replay")
