"""Beyond-paper extensions (the paper's own future-work items, §6 of the
paper): load-dependent queueing delay + closed-loop serving simulation.

ext1 — queueing audit: queueing-adjusted delays of each planner's plan
       (does the load-free plan survive M/G/1-PS inflation?).
ext2 — queueing-aware planning: AGH on `with_queueing_margin(rho_max)`
       instances; the explicit headroom / coverage / budget trade-off.
ext3 — closed-loop validation: discrete-event simulation of the planned
       fleet under Poisson traffic; achieved SLO attainment vs the
       planner's analytical delay model.
"""
from __future__ import annotations

from repro.core import default_instance, provisioning_cost
from repro.core.queueing import (slo_attainment_with_queueing,
                                 with_queueing_margin)
from repro.planner import plan
from repro.serving.simulator import simulate

from .common import Timer, emit


def run() -> None:
    inst = default_instance()
    plans = [(n, plan(n, instance=inst).solution) for n in ("gh", "agh")]

    # ext1: queueing audit of load-free plans
    for name, sol in plans:
        q = slo_attainment_with_queueing(inst, sol)
        emit(f"ext1.queue_audit.{name}", 0.0,
             f"max_rho={q['max_rho']:.3f};"
             f"viol_load_free={q['violations_load_free']};"
             f"viol_queueing={q['violations_queueing']};"
             f"min_margin={q['margin_min']:.2f}")

    # ext2: queueing-aware planning (headroom knob) across budgets
    for budget in (100.0, 150.0):
        inst_b = default_instance(budget=budget)
        with Timer() as t:
            sol_m = plan("agh", instance=with_queueing_margin(
                inst_b, rho_max=0.5)).solution
        q = slo_attainment_with_queueing(inst_b, sol_m)
        emit(f"ext2.rho_max0.5.budget{int(budget)}", t.us,
             f"stage1=${provisioning_cost(inst_b, sol_m):.1f};"
             f"u_max={sol_m.u.max():.3f};"
             f"viol_queueing={q['violations_queueing']};"
             f"min_margin={q['margin_min']:.2f}")

    # ext4: carbon-intensity-aware tier costs (paper future-work #3)
    from repro.core.carbon import carbon_priced, emissions
    intensity = {n: (0.08 if ("H100" in n or "A100" in n) else 0.55)
                 for n in inst.tier_names}
    base_em = emissions(inst, plans[1][1])
    emit("ext4.carbon.baseline", 0.0,
         f"emissions={base_em:.1f}kg;stage1=${provisioning_cost(inst, plans[1][1]):.1f}")
    for cp, extra_budget in ((0.60, 0.0), (0.60, 30.0), (2.00, 60.0)):
        inst_c = default_instance(budget=100.0 + extra_budget)
        ci = carbon_priced(inst_c, carbon_price=cp, intensity=intensity)
        sol_c = plan("agh", instance=ci).solution
        emit(f"ext4.carbon.p{cp:.2f}.b{int(100+extra_budget)}", 0.0,
             f"emissions={emissions(inst_c, sol_c):.1f}kg;"
             f"stage1=${provisioning_cost(inst_c, sol_c):.1f};"
             f"u_max={sol_c.u.max():.2f}")

    # ext3: closed-loop simulation (load-free vs margin-planned)
    inst150 = default_instance(budget=150.0)
    cases = [("AGH_loadfree", plan("agh", instance=inst).solution, inst),
             ("AGH_rho0.5_b150",
              plan("agh",
                   instance=with_queueing_margin(inst150, 0.5)).solution,
              inst150)]
    for name, sol, icase in cases:
        st = simulate(icase, sol, horizon_s=300.0, rate_scale=0.02, seed=1)
        att = ";".join(f"{icase.query_names[i][:5]}="
                       f"{100*st.per_type_slo_attain[i]:.0f}%"
                       for i in range(icase.I))
        emit(f"ext3.sim.{name}", 0.0,
             f"served={st.n_served};unmet_planned={sol.u.max():.2f};{att}")


if __name__ == "__main__":
    run()
