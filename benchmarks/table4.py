"""Table 4: rolling-horizon cost under synthetic geometric-random-walk
volatility. Methods: DM-24h, GH-24h/5min, AGH-24h/5min over
sigma in {0.01..0.05}; strict u_i <= 0.02 per-window Stage-2 cap."""
from __future__ import annotations

import numpy as np

from repro.core import agh, default_instance, gh, solve_milp
from repro.core.rolling import rolling
from repro.core.trace import random_walk_lambdas

from .common import emit

SIGMAS = (0.01, 0.02, 0.03, 0.04, 0.05)


def run(trials: int = 3, n_windows: int = 288, sigmas=SIGMAS,
        dm_limit: float = 180.0, replan_every: int = 1) -> dict:
    inst = default_instance()
    # Static planners see the same t=0 demand in every trial: solve once.
    static_plans = {
        "DM-24h": solve_milp(inst, time_limit=dm_limit),
        "GH-24h": gh(inst),
        "AGH-24h": agh(inst),
    }
    fast = dict(GH=lambda i: gh(i), AGH=lambda i: agh(i, R=1, patience=2))
    results: dict[str, dict[float, float]] = {}
    for sigma in sigmas:
        for name, plan in static_plans.items():
            totals = []
            for tr in range(trials):
                rng = np.random.default_rng(hash((sigma, tr)) % 2**31)
                path = random_walk_lambdas(inst.lam, sigma, n_windows, rng)
                res = rolling(inst, path, lambda i, p=plan: p,
                              replan_every=None)
                totals.append(res.total_cost)
            results.setdefault(name, {})[sigma] = float(np.mean(totals))
        for name, planner in fast.items():
            totals = []
            for tr in range(trials):
                rng = np.random.default_rng(hash((sigma, tr)) % 2**31)
                path = random_walk_lambdas(inst.lam, sigma, n_windows, rng)
                res = rolling(inst, path, planner,
                              replan_every=replan_every)
                totals.append(res.total_cost)
            results.setdefault(f"{name}-5min", {})[sigma] = float(np.mean(totals))
    for name, per_sigma in results.items():
        derived = ";".join(f"s{int(s*100):02d}=${c:.0f}"
                           for s, c in per_sigma.items())
        emit(f"table4.{name}", 0.0, derived)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--windows", type=int, default=288)
    args = ap.parse_args()
    run(trials=args.trials, n_windows=args.windows)
