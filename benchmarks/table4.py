"""Table 4: rolling-horizon cost under synthetic geometric-random-walk
volatility. Methods: DM-24h, GH-24h/5min, AGH-24h/5min over
sigma in {0.01..0.05}; strict u_i <= 0.02 per-window Stage-2 cap.

The 5-minute AGH column replans through a `PlanSession`: every window
after the first warm-starts from the session incumbent (and replays its
winning ordering) instead of running a cold multi-start — the unified
planner API's replanning path exercised at benchmark scale."""
from __future__ import annotations

import numpy as np

from repro.core import default_instance
from repro.core.rolling import rolling
from repro.core.trace import random_walk_lambdas
from repro.planner import PlanOptions, PlanSession, plan

from .common import emit

SIGMAS = (0.01, 0.02, 0.03, 0.04, 0.05)


def run(trials: int = 3, n_windows: int = 288, sigmas=SIGMAS,
        dm_limit: float = 180.0, replan_every: int = 1) -> dict:
    inst = default_instance()
    # Static planners see the same t=0 demand in every trial: solve once.
    static_plans = {
        "DM-24h": plan("milp", instance=inst,
                       options=PlanOptions(time_limit=dm_limit)).solution,
        "GH-24h": plan("gh", instance=inst).solution,
        "AGH-24h": plan("agh", instance=inst).solution,
    }
    fast = {
        "GH": lambda: PlanSession(solver="gh"),
        # Fresh session per demand path: restarts/patience mirror the
        # pre-session fast-replan settings (R=1, patience=2) on the cold
        # first window; subsequent windows replan warm.
        "AGH": lambda: PlanSession(
            solver="agh", options=PlanOptions(restarts=1, patience=2)),
    }
    results: dict[str, dict[float, float]] = {}
    for sigma in sigmas:
        for name, dep in static_plans.items():
            totals = []
            for tr in range(trials):
                rng = np.random.default_rng(hash((sigma, tr)) % 2**31)
                path = random_walk_lambdas(inst.lam, sigma, n_windows, rng)
                res = rolling(inst, path, lambda i, p=dep: p,
                              replan_every=None)
                totals.append(res.total_cost)
            results.setdefault(name, {})[sigma] = float(np.mean(totals))
        for name, make_session in fast.items():
            totals = []
            for tr in range(trials):
                rng = np.random.default_rng(hash((sigma, tr)) % 2**31)
                path = random_walk_lambdas(inst.lam, sigma, n_windows, rng)
                res = rolling(inst, path, make_session(),
                              replan_every=replan_every)
                totals.append(res.total_cost)
            results.setdefault(f"{name}-5min", {})[sigma] = float(np.mean(totals))
    for name, per_sigma in results.items():
        derived = ";".join(f"s{int(s*100):02d}=${c:.0f}"
                           for s, c in per_sigma.items())
        emit(f"table4.{name}", 0.0, derived)
    return results


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=5)
    ap.add_argument("--windows", type=int, default=288)
    args = ap.parse_args()
    run(trials=args.trials, n_windows=args.windows)
