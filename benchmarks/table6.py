"""Table 6: runtime scaling with problem size (I, J, K).

Paper: DM exceeds 600 s at (15,15,10); GH < 1 s and AGH < 3 s everywhere
(>= 260x speedup at (20,20,20)).

The heuristic columns run on the vectorized allocation engine; with
``include_before`` each row also times the frozen scalar seed path
(`_scalar_ref.gh_scalar`) so the before/after speedup is visible next to
the paper's DM baseline.  `SIZES_EXT` pushes one size past the paper's
largest instance."""
from __future__ import annotations

from repro.core import agh, gh, objective, random_instance, solve_milp
from repro.core._scalar_ref import gh_scalar

from .common import Timer, emit

SIZES = [(4, 4, 5), (6, 6, 10), (10, 10, 10), (15, 15, 10), (20, 20, 20)]
SIZES_EXT = SIZES + [(30, 30, 20)]


def run(dm_limit: float = 600.0, dm_max_size: int = 1000,
        sizes=SIZES, include_before: bool = True) -> list[dict]:
    rows = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        row = dict(size=f"({I},{J},{K})")
        g = gh(inst)
        row["GH_s"] = round(g.runtime_s, 3)
        if include_before:
            with Timer() as t:
                gh_scalar(inst)
            row["GH_before_s"] = round(t.dt, 3)
        a = agh(inst)
        row["AGH_s"] = round(a.runtime_s, 3)
        row["AGH_obj"] = round(objective(inst, a), 1)
        if I * J * K <= dm_max_size:
            d = solve_milp(inst, time_limit=dm_limit)
            row["DM_s"] = round(d.runtime_s, 2)
            row["DM_obj"] = (round(objective(inst, d), 1)
                             if d.method == "DM" else "timeout")
            if d.method == "DM":
                row["AGH_gap_pct"] = round(
                    100 * (row["AGH_obj"] - row["DM_obj"])
                    / max(row["DM_obj"], 1e-9), 2)
        else:
            row["DM_s"] = f">{dm_limit:.0f} (skipped)"
        rows.append(row)
        emit(f"table6.{row['size']}", row["AGH_s"] * 1e6,
             ";".join(f"{k}={v}" for k, v in row.items() if k != "size"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dm-limit", type=float, default=600.0)
    ap.add_argument("--dm-max-size", type=int, default=10**9)
    ap.add_argument("--ext", action="store_true",
                    help="include the beyond-paper (30,30,20) size")
    args = ap.parse_args()
    run(dm_limit=args.dm_limit, dm_max_size=args.dm_max_size,
        sizes=SIZES_EXT if args.ext else SIZES)
