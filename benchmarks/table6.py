"""Table 6: runtime scaling with problem size (I, J, K).

Paper: DM exceeds 600 s at (15,15,10); GH < 1 s and AGH < 3 s everywhere
(>= 260x speedup at (20,20,20)).

The heuristic columns run on the vectorized allocation engine.  Three
"before" references are timed next to it: the frozen scalar seed GH
(`_scalar_ref.gh_scalar`, capped at `SCALAR_GH_MAX` — it takes tens of
seconds beyond (30,30,20)), and AGH in ``local_search="reference"`` mode
(the PR-2 first-improvement engine) so the batched-local-search speedup is
visible per size.

DM column: `dm_max_size` bounds the largest I*J*K for which the exact MILP
is attempted — the unified default of 1000 runs DM through (10,10,10) and
skips it above (at (15,15,10) the paper already reports >600 s; the CLI's
``--dm-max-size`` raises the bound for full-replication runs, as does
``benchmarks.run --full``).  Skipped rows show ``DM_s = skipped(>size)``.

``SIZES_EXT`` (CLI ``--ext``) pushes past the paper's largest instance:
(30,30,20) from PR 1, the PR-3 beyond-paper sizes (40,40,30), (60,60,40)
and (100,80,40), and the PR-4 fleet-scale points (150,120,60) and
(200,160,80).  ``local_search="reference"`` timing is capped at
`REF_AGH_MAX` — beyond (100,80,40) the first-improvement engine takes
minutes and the incremental engine is the only practical path."""
from __future__ import annotations

from repro.core import agh, gh, objective, random_instance, solve_milp
from repro.core._scalar_ref import gh_scalar

from .common import Timer, emit

SIZES = [(4, 4, 5), (6, 6, 10), (10, 10, 10), (15, 15, 10), (20, 20, 20)]
SIZES_EXT = SIZES + [(30, 30, 20), (40, 40, 30), (60, 60, 40), (100, 80, 40),
                     (150, 120, 60), (200, 160, 80)]
DM_MAX_SIZE = 1000              # unified default: DM through (10,10,10)
SCALAR_GH_MAX = 30 * 30 * 20    # frozen scalar GH beyond this: minutes
REF_AGH_MAX = 100 * 80 * 40     # reference-mode AGH beyond this: minutes


def run(dm_limit: float = 600.0, dm_max_size: int = DM_MAX_SIZE,
        sizes=SIZES, include_before: bool = True) -> list[dict]:
    rows = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        row = dict(size=f"({I},{J},{K})")
        g = gh(inst)
        row["GH_s"] = round(g.runtime_s, 3)
        row["GH_obj"] = round(objective(inst, g), 1)
        if include_before and I * J * K <= SCALAR_GH_MAX:
            with Timer() as t:
                gh_scalar(inst)
            row["GH_before_s"] = round(t.dt, 3)
        a = agh(inst)
        row["AGH_s"] = round(a.runtime_s, 3)
        row["AGH_obj"] = round(objective(inst, a), 1)
        if include_before and I * J * K <= REF_AGH_MAX:
            a_ref = agh(inst, local_search="reference")
            row["AGH_ref_s"] = round(a_ref.runtime_s, 3)
        if I * J * K <= dm_max_size:
            d = solve_milp(inst, time_limit=dm_limit)
            row["DM_s"] = round(d.runtime_s, 2)
            row["DM_obj"] = (round(objective(inst, d), 1)
                             if d.method == "DM" else "timeout")
            if d.method == "DM":
                row["AGH_gap_pct"] = round(
                    100 * (row["AGH_obj"] - row["DM_obj"])
                    / max(row["DM_obj"], 1e-9), 2)
        else:
            row["DM_s"] = f"skipped(>{dm_max_size})"
        rows.append(row)
        emit(f"table6.{row['size']}", row["AGH_s"] * 1e6,
             ";".join(f"{k}={v}" for k, v in row.items() if k != "size"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dm-limit", type=float, default=600.0)
    ap.add_argument("--dm-max-size", type=int, default=DM_MAX_SIZE,
                    help="largest I*J*K for which the exact MILP is "
                         "attempted (default skips DM above (10,10,10))")
    ap.add_argument("--ext", action="store_true",
                    help="include the beyond-paper sizes up to (100,80,40)")
    args = ap.parse_args()
    run(dm_limit=args.dm_limit, dm_max_size=args.dm_max_size,
        sizes=SIZES_EXT if args.ext else SIZES)
