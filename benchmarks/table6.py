"""Table 6: runtime scaling with problem size (I, J, K).

Paper: DM exceeds 600 s at (15,15,10); GH < 1 s and AGH < 3 s everywhere
(>= 260x speedup at (20,20,20)).

Rows are registry-keyed (schema v3): each solver column is the
`PlanResult.summary()` sub-dict of one facade solve — ``gh``, ``agh``,
``agh+reference`` (the PR-2 first-improvement engine, capped at
`REF_AGH_MAX`), and ``milp`` (the exact DM; an anytime incumbent under
``dm_limit``, so the CI gate skips its columns).  The frozen scalar seed
GH is timed next to them as flat ``GH_before_s`` (capped at
`SCALAR_GH_MAX` — it takes tens of seconds beyond (30,30,20)).

DM column: `dm_max_size` bounds the largest I*J*K for which the exact
MILP is attempted — the unified default of 1000 runs DM through
(10,10,10) and skips it above (at (15,15,10) the paper already reports
>600 s; the CLI's ``--dm-max-size`` raises the bound for
full-replication runs, as does ``benchmarks.run --full``).  Skipped rows
show ``DM_s = skipped(>size)``.

``SIZES_EXT`` (CLI ``--ext``) pushes past the paper's largest instance:
(30,30,20) from PR 1, the PR-3 beyond-paper sizes (40,40,30), (60,60,40)
and (100,80,40), and the PR-4 fleet-scale points (150,120,60) and
(200,160,80)."""
from __future__ import annotations

from repro.core import random_instance
from repro.core._scalar_ref import gh_scalar
from repro.planner import PlanOptions, plan

from .common import Timer, emit

SIZES = [(4, 4, 5), (6, 6, 10), (10, 10, 10), (15, 15, 10), (20, 20, 20)]
SIZES_EXT = SIZES + [(30, 30, 20), (40, 40, 30), (60, 60, 40), (100, 80, 40),
                     (150, 120, 60), (200, 160, 80)]
DM_MAX_SIZE = 1000              # unified default: DM through (10,10,10)
SCALAR_GH_MAX = 30 * 30 * 20    # frozen scalar GH beyond this: minutes
REF_AGH_MAX = 100 * 80 * 40     # reference-mode AGH beyond this: minutes


def run(dm_limit: float = 600.0, dm_max_size: int = DM_MAX_SIZE,
        sizes=SIZES, include_before: bool = True) -> list[dict]:
    rows = []
    for (I, J, K) in sizes:
        inst = random_instance(I, J, K, seed=42)
        row: dict = dict(size=f"({I},{J},{K})")
        row["gh"] = plan("gh", instance=inst).summary()
        if include_before and I * J * K <= SCALAR_GH_MAX:
            with Timer() as t:
                gh_scalar(inst)
            row["GH_before_s"] = round(t.dt, 3)
        a = plan("agh", instance=inst)
        row["agh"] = a.summary()
        if include_before and I * J * K <= REF_AGH_MAX:
            row["agh+reference"] = plan(
                "agh", instance=inst,
                options=PlanOptions(local_search="reference")).summary()
        if I * J * K <= dm_max_size:
            d = plan("milp", instance=inst,
                     options=PlanOptions(time_limit=dm_limit))
            row["milp"] = d.summary()
            solved = not d.diagnostics.get("timed_out", False)
            row["milp"]["status"] = d.diagnostics.get("status")
            if solved:
                row["AGH_gap_pct"] = round(
                    100 * (a.objective - d.objective)
                    / max(d.objective, 1e-9), 2)
        else:
            row["DM_s"] = f"skipped(>{dm_max_size})"
        rows.append(row)
        emit(f"table6.{row['size']}", row["agh"]["wall_s"] * 1e6,
             ";".join(f"{k}={v}" for k, v in row.items() if k != "size"))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dm-limit", type=float, default=600.0)
    ap.add_argument("--dm-max-size", type=int, default=DM_MAX_SIZE,
                    help="largest I*J*K for which the exact MILP is "
                         "attempted (default skips DM above (10,10,10))")
    ap.add_argument("--ext", action="store_true",
                    help="include the beyond-paper sizes up to (100,80,40)")
    args = ap.parse_args()
    run(dm_limit=args.dm_limit, dm_max_size=args.dm_max_size,
        sizes=SIZES_EXT if args.ext else SIZES)
