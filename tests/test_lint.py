"""Tests for the repro.analysis.lint invariant-checker suite.

Fixture files under tests/lint_fixtures/ mirror the src/repro layout so
path-scoped checkers (determinism in core/planner/serving, dtype in
core/xla + kernels, jit purity in core/xla + kernels) fire naturally.
Every bad fixture has a clean twin proving the rule does not over-fire.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.lint import (all_rules, lint_file, lint_source,
                                 run_paths, write_baseline)
from repro.core.contracts import mutates

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "lint_fixtures"
SRC = REPO / "src"


def codes(report) -> list[str]:
    return [d.rule for d in report.diagnostics]


def lint_fixture(rel: str):
    return lint_file(FIXTURES / rel)


# ---------------------------------------------------------------- rules

def test_rule_table_is_wellformed_and_unique():
    rules = all_rules()
    assert len({r.code for r in rules}) == len(rules)
    for r in rules:
        assert r.code.startswith("RPR")
        assert r.summary


# ------------------------------------------------- state mutation (1xx)

def test_unsanctioned_state_write_is_caught():
    # ISSUE acceptance demo: a raw write to a State field is flagged.
    got = codes(lint_fixture("repro/core/bad_state_write.py"))
    assert got.count("RPR101") == 4
    assert set(got) == {"RPR101"}


def test_sanctioned_mutator_is_clean():
    assert codes(lint_fixture("repro/core/clean_state_write.py")) == []


def test_mutates_declaration_mismatches():
    got = codes(lint_fixture("repro/core/bad_mutates_decl.py"))
    assert "RPR102" in got      # wrote a field it never declared
    assert "RPR103" in got      # declared a field it never writes


def test_inline_state_write_snippet():
    # Same contract exercised without a fixture file: the posix path is
    # what routes the source to the state-mutation checker.
    src = (
        "from repro.core.state import State\n"
        "def leak(st: State) -> None:\n"
        "    st.spend += 1.0\n"
    )
    rep = lint_source(src, display="snippet.py",
                      posix="x/repro/core/snippet.py")
    assert codes(rep) == ["RPR101"]


# ----------------------------------------------------- determinism (2xx)

def test_determinism_rules_fire():
    got = codes(lint_fixture("repro/core/bad_determinism.py"))
    # ISSUE acceptance demo: unseeded legacy RNG is flagged.
    assert "RPR201" in got
    assert got.count("RPR202") == 2     # import + call
    assert got.count("RPR203") == 2     # list(set) + bare for-over-set
    assert got.count("RPR204") == 2     # time.time + os.environ


def test_determinism_clean_twin():
    assert codes(lint_fixture("repro/core/clean_determinism.py")) == []


def test_determinism_is_path_scoped():
    # The same source outside core/planner/serving is nobody's business.
    bad = (FIXTURES / "repro/core/bad_determinism.py").read_text()
    rep = lint_source(bad, display="free.py", posix="x/repro/models/free.py")
    assert codes(rep) == []


# ------------------------------------------------------------ dtype (3xx)

def test_dtype_rules_fire():
    got = codes(lint_fixture("repro/core/xla/bad_dtype.py"))
    # ISSUE acceptance demo: implicit-dtype jnp.zeros is flagged.
    assert got.count("RPR301") == 2     # zeros + arange
    assert got.count("RPR302") == 2     # astype(f32) + np.float32 cast
    assert got.count("RPR303") == 1     # weak literal into jitted fn


def test_dtype_clean_twin():
    assert codes(lint_fixture("repro/core/xla/clean_dtype.py")) == []


def test_f32_narrowing_allowed_in_kernels():
    # kernels/ compute in f32 on the MXU by design: RPR302 is scoped to
    # core/xla only, RPR301 (implicit dtype) still applies everywhere.
    src = "import jax.numpy as jnp\n\ndef f(x):\n    return x.astype(jnp.float32)\n"
    rep = lint_source(src, display="k.py", posix="x/repro/kernels/k.py")
    assert codes(rep) == []


# ------------------------------------------------------- jit purity (4xx)

def test_jit_purity_rules_fire():
    got = codes(lint_fixture("repro/kernels/bad_jit_purity.py"))
    # ISSUE acceptance demo: Python `if` on a traced value is flagged.
    assert got.count("RPR401") == 2     # if + conditional expression
    assert got.count("RPR402") == 2     # float(...) + .item()
    assert got.count("RPR403") == 1     # traced range() bound
    assert len(got) == 5


def test_jit_purity_clean_twin():
    # static kw-only pallas params, shape-derived bounds, jnp.where,
    # static_argnames branching: none of it may fire.
    assert codes(lint_fixture("repro/kernels/clean_jit_purity.py")) == []


def test_unjitted_function_is_not_scanned():
    src = (
        "def host_side(x, n):\n"
        "    if x > 0:\n"
        "        return float(x)\n"
        "    return [i for i in range(n)]\n"
    )
    rep = lint_source(src, display="h.py", posix="x/repro/kernels/h.py")
    assert codes(rep) == []


# ----------------------------------------------------------- suppressions

def test_valid_suppressions_silence_and_count():
    rep = lint_fixture("repro/core/suppressed_ok.py")
    assert codes(rep) == []
    assert len(rep.suppressed) == 2     # standalone + same-line forms
    assert all(s.reason for _, s in rep.suppressed)


def test_bare_suppression_rejected_and_finding_kept():
    rep = lint_fixture("repro/core/suppressed_bare.py")
    got = codes(rep)
    assert "RPR002" in got      # the bare marker itself
    assert "RPR203" in got      # ...and it does NOT silence the finding
    assert rep.suppressed == []


def test_unknown_suppression_code_flagged():
    src = (
        "def f(s: set):\n"
        "    # repro-lint: ignore[RPR999] -- no such rule\n"
        "    return list(s)\n"
    )
    rep = lint_source(src, display="u.py", posix="x/repro/core/u.py")
    got = codes(rep)
    assert "RPR003" in got
    assert "RPR203" in got      # unknown code silences nothing


def test_meta_rules_are_unsuppressible():
    src = (
        "def f(s: set):\n"
        "    # repro-lint: ignore[RPR002, RPR203] -- trying to self-silence\n"
        "    # repro-lint: ignore[RPR203]\n"
        "    return list(s)\n"
    )
    rep = lint_source(src, display="m.py", posix="x/repro/core/m.py")
    assert "RPR002" in codes(rep)


def test_syntax_error_reported_as_rpr000():
    rep = lint_source("def broken(:\n", display="b.py",
                      posix="x/repro/core/b.py")
    assert codes(rep) == ["RPR000"]


# ---------------------------------------------------------------- baseline

def test_baseline_roundtrip(tmp_path):
    bad = FIXTURES / "repro/core/bad_determinism.py"
    first = run_paths([bad])
    assert first.exit_code == 1
    n = len(first.diagnostics)

    bl = tmp_path / "baseline.json"
    write_baseline(first, bl)
    second = run_paths([bad], baseline=bl)
    assert second.exit_code == 0
    assert second.baselined_count == n
    assert second.diagnostics == []


def test_baseline_expires_when_line_changes(tmp_path):
    f = tmp_path / "repro" / "core" / "drift.py"
    f.parent.mkdir(parents=True)
    f.write_text("import numpy as np\n\ndef f():\n    return np.random.rand()\n")
    bl = tmp_path / "baseline.json"
    write_baseline(run_paths([f]), bl)
    # Edit the offending line: the fingerprint must stop matching.
    f.write_text("import numpy as np\n\ndef f():\n    return np.random.rand(3)\n")
    again = run_paths([f], baseline=bl)
    assert again.exit_code == 1


# --------------------------------------------------------- committed tree

def test_committed_src_tree_is_lint_clean():
    """Regression guard: the shipped src/ tree must stay at zero
    unsuppressed diagnostics (the CI invariant-lint job enforces the
    same thing; this keeps it honest locally)."""
    result = run_paths([SRC])
    assert result.exit_code == 0, "\n".join(
        d.format() for d in result.diagnostics)
    assert result.files_checked > 50


# --------------------------------------------------------------------- CLI

def run_cli(*argv: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *argv],
        capture_output=True, text=True, cwd=REPO,
        env={"PYTHONPATH": str(SRC), "PATH": "/usr/bin:/bin"})


def test_cli_exit_codes():
    ok = run_cli("src")
    assert ok.returncode == 0, ok.stderr
    bad = run_cli(str(FIXTURES / "repro/core/bad_state_write.py"))
    assert bad.returncode == 1
    assert "RPR101" in bad.stdout


def test_cli_select_filters_rules():
    p = str(FIXTURES / "repro/core/bad_determinism.py")
    only_204 = run_cli(p, "--select", "RPR204")
    assert only_204.returncode == 1
    assert "RPR204" in only_204.stdout
    assert "RPR201" not in only_204.stdout
    none = run_cli(p, "--select", "RPR3")
    assert none.returncode == 0


def test_cli_list_rules():
    out = run_cli("--list-rules")
    assert out.returncode == 0
    for code in ("RPR101", "RPR201", "RPR301", "RPR401"):
        assert code in out.stdout


def test_cli_summary_json(tmp_path):
    dest = tmp_path / "summary.json"
    p = str(FIXTURES / "repro/core/bad_determinism.py")
    run_cli(p, "--summary-json", str(dest))
    data = json.loads(dest.read_text())
    assert data["diagnostics"] > 0
    assert data["by_rule"]["RPR201"] == 1


# -------------------------------------------------------------- decorator

def test_mutates_decorator_records_write_set():
    @mutates("spend", "q")
    def mutator(st):
        pass
    assert mutator.__mutates__ == frozenset({"spend", "q"})


def test_mutates_decorator_rejects_bad_fields():
    with pytest.raises(ValueError):
        mutates()
    with pytest.raises(ValueError):
        mutates("not an identifier")
