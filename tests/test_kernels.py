"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
swept over shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6_wkv.ops import rwkv6_wkv
from repro.kernels.rwkv6_wkv.ref import rwkv6_wkv_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref

TOLS = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}


def _tol(dtype):
    return TOLS[jnp.bfloat16] if dtype == jnp.bfloat16 else TOLS[jnp.float32]


@pytest.mark.parametrize("B,H,KV,T,hd", [
    (1, 2, 1, 128, 64), (2, 4, 2, 256, 64), (1, 8, 8, 256, 128),
    (2, 2, 2, 384, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 96])
def test_flash_attention_sweep(B, H, KV, T, hd, dtype, window):
    rng = np.random.default_rng(hash((B, H, T, window)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, H, T, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, KV, T, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, KV, T, hd)), dtype)
    out = flash_attention(q, k, v, window=window, block_q=128, block_k=128)
    ref = attention_ref(q, k, v, jnp.arange(T), jnp.arange(T), window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("B,KV,G,S,hd", [
    (1, 2, 4, 512, 64), (2, 1, 8, 1024, 128), (2, 4, 1, 512, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(B, KV, G, S, hd, dtype):
    rng = np.random.default_rng(hash((B, KV, G, S)) % 2**31)
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, KV, S, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, KV, S, hd)), dtype)
    pos = jnp.int32(S - S // 3)
    out = decode_attention(q, k, v, pos=pos, block_k=256)
    ref = decode_attention_ref(q, k, v, jnp.arange(S), pos)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_decode_attention_ring_positions():
    """Ring-buffer caches pass non-monotonic absolute positions."""
    rng = np.random.default_rng(3)
    B, KV, G, S, hd = 1, 2, 2, 256, 64
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, KV, S, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, KV, S, hd)), jnp.float32)
    pos = jnp.int32(300)
    last = 300
    idx = jnp.arange(S)
    k_pos = last - ((last - idx) % S)
    out = decode_attention(q, k, v, k_pos=k_pos, pos=pos, block_k=128)
    ref = decode_attention_ref(q, k, v, k_pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("B,T,nh,hp,N,chunk", [
    (1, 128, 2, 32, 16, 64), (2, 256, 3, 64, 64, 128), (1, 64, 1, 32, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_sweep(B, T, nh, hp, N, chunk, dtype):
    rng = np.random.default_rng(hash((B, T, nh)) % 2**31)
    x = jnp.asarray(rng.normal(size=(B, T, nh, hp)), dtype)
    Bm = jnp.asarray(rng.normal(size=(B, T, N)) * 0.5, dtype)
    Cm = jnp.asarray(rng.normal(size=(B, T, N)) * 0.5, dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, T, nh)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(nh,)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(nh,)), jnp.float32)
    y = ssm_scan(x, Bm, Cm, dt, A, D, chunk=chunk)
    yr = ssm_scan_ref(x, Bm, Cm, dt, A, D)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=5 * _tol(dtype), rtol=5 * _tol(dtype))


@pytest.mark.parametrize("B,T,H,hd,chunk", [
    (1, 64, 1, 32, 64), (2, 128, 2, 64, 64), (1, 192, 2, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_rwkv6_wkv_sweep(B, T, H, hd, chunk, dtype):
    rng = np.random.default_rng(hash((B, T, H)) % 2**31)
    r = jnp.asarray(rng.normal(size=(B, T, H, hd)) * 0.5, dtype)
    k = jnp.asarray(rng.normal(size=(B, T, H, hd)) * 0.5, dtype)
    v = jnp.asarray(rng.normal(size=(B, T, H, hd)) * 0.5, dtype)
    lw = -jnp.exp(jnp.asarray(rng.normal(size=(B, T, H, hd)) * 0.5 - 1.5,
                              jnp.float32))
    u = jnp.asarray(rng.normal(size=(H, hd)) * 0.5, jnp.float32)
    y = rwkv6_wkv(r, k, v, lw, u, chunk=chunk)
    yr = rwkv6_wkv_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=1e-4, rtol=1e-4)


def test_model_layer_matches_kernel_oracle_mamba():
    """models/mamba2.py chunked path == kernel oracle (same math)."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.mamba2 import mamba2_apply, mamba2_params

    cfg = get_config("zamba2-7b").smoke()
    rng = jax.random.PRNGKey(0)
    p = mamba2_params(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, cfg.d_model),
                          jnp.float32)
    out_chunked, _ = mamba2_apply(p, cfg, x, None)
    # step-by-step decode over the same tokens must agree
    from repro.models.mamba2 import mamba2_cache_init
    cache = mamba2_cache_init(cfg, 2)
    outs = []
    for t in range(x.shape[1]):
        o, cache = mamba2_apply(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(out_step),
                               atol=2e-3, rtol=2e-3)


def test_model_layer_matches_stepwise_rwkv():
    from repro.configs import get_config
    from repro.models.rwkv6 import (rwkv6_apply, rwkv6_cache_init,
                                    rwkv6_params)

    cfg = get_config("rwkv6-7b").smoke()
    rng = jax.random.PRNGKey(0)
    p = rwkv6_params(rng, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, cfg.d_model),
                          jnp.float32)
    out_chunked, _ = rwkv6_apply(p, cfg, x, None)
    cache = rwkv6_cache_init(cfg, 2)
    outs = []
    for t in range(x.shape[1]):
        o, cache = rwkv6_apply(p, cfg, x[:, t:t + 1], cache)
        outs.append(o)
    out_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(out_step),
                               atol=2e-3, rtol=2e-3)
