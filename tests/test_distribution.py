"""Distribution-layer tests: sharding rules, pipeline parallelism (in a
subprocess with 8 host devices so the main test process keeps 1 device),
dry-run smoke, HLO stats analyzer."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, devices: int = 8, timeout: float = 900.0):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)


def test_main_process_sees_one_device():
    """Assignment: smoke tests and benches must see 1 device, not 512."""
    assert len(jax.devices()) == 1


def test_param_specs_divisibility():
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.specs import params_specs
    from repro.parallel import sharding as shd

    # use a tiny host mesh: rules only read axis SIZES from the mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    for arch in ("qwen2-72b", "internvl2-26b", "kimi-k2-1t-a32b"):
        cfg = get_config(arch)
        shapes = params_specs(cfg)
        specs = shd.param_specs(shapes, mesh)
        flat_shapes = jax.tree.leaves(shapes)
        flat_specs = jax.tree.leaves(specs,
                                     is_leaf=lambda x: isinstance(x, P))
        for sds, spec in zip(flat_shapes, flat_specs, strict=True):
            for dim, axes in zip(sds.shape, tuple(spec), strict=True):
                if axes is None:
                    continue
                assert dim % shd.mesh_axis_size(mesh, axes) == 0


def test_pipeline_parallel_subprocess():
    """GPipe shard_map pipeline == sequential reference, on 4 stages."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.parallel.pipeline import pipelined_forward, split_stages, pipeline_utilization

    n_stages, n_micro, mb, d = 4, 8, 2, 16
    mesh = jax.make_mesh((n_stages,), ("stage",))
    rng = np.random.default_rng(0)
    L = 8
    W = jnp.asarray(rng.normal(size=(L, d, d)) * (d ** -0.5), jnp.float32)

    def stage_fn(sp, x):
        def body(h, w):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, sp)
        return h

    xs = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)
    fn = pipelined_forward(stage_fn, mesh, n_stages, n_micro)
    stacked = split_stages(W, n_stages)
    with mesh:
        out = fn(stacked, xs)
    # sequential reference
    ref = xs
    def body(h, w):
        return jnp.tanh(h @ w), None
    ref_out = []
    for m in range(n_micro):
        h, _ = jax.lax.scan(body, xs[m], W)
        ref_out.append(h)
    ref = jnp.stack(ref_out)
    err = float(jnp.abs(out - ref).max())
    assert err < 1e-5, err
    assert abs(pipeline_utilization(9, 4) - 0.75) < 1e-9
    print("PIPELINE_OK", err)
    """
    r = _run_sub(code, devices=4)
    assert "PIPELINE_OK" in r.stdout, r.stderr[-2000:]


def test_dryrun_smoke_subprocess():
    """Full dry-run path (lower+compile+analysis) on a reduced mesh/model
    in a subprocess — exercises the same code as the 512-device run."""
    code = """
    import os
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import decoder
    from repro.parallel import sharding as shd
    from repro.launch.specs import params_specs
    from repro.analysis.hlo_stats import analyze
    import dataclasses

    cfg = dataclasses.replace(get_config("qwen2-0.5b"), n_layers=4)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    p_shapes = params_specs(cfg)
    p_shard = shd.to_shardings(shd.param_specs(p_shapes, mesh), mesh)
    toks = jax.ShapeDtypeStruct((8, 256), jnp.int32)
    tok_shard = jax.sharding.NamedSharding(mesh, shd.batch_spec(mesh, toks.shape))
    with mesh:
        f = jax.jit(lambda p, t: decoder.train_loss(p, cfg, dict(tokens=t, targets=t)),
                    in_shardings=(p_shard, tok_shard))
        compiled = f.lower(p_shapes, toks).compile()
    s = analyze(compiled.as_text())
    assert s.flops > 1e9, s.flops
    assert s.collective_bytes > 0
    print("DRYRUN_OK", s.flops, s.collective_bytes)
    """
    r = _run_sub(code, devices=8)
    assert "DRYRUN_OK" in r.stdout, r.stderr[-2000:]


def test_hlo_stats_trip_count_weighting():
    """A scan of N matmuls must report ~N x the flops of one matmul."""
    import jax.numpy as jnp

    from repro.analysis.hlo_stats import analyze

    d, N = 64, 16

    def f(w, x):
        def body(h, _):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, None, length=N)
        return h

    w = jax.ShapeDtypeStruct((d, d), jnp.float32)
    x = jax.ShapeDtypeStruct((d, d), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    s = analyze(compiled.as_text())
    expect = 2.0 * d * d * d * N
    assert 0.5 * expect <= s.flops <= 1.5 * expect, (s.flops, expect)


def test_dryrun_results_artifact_sane():
    """The committed sweep artifact must cover every (arch, shape) pair
    on both meshes with ok/skipped status."""
    path = os.path.join(REPO, "experiments", "dryrun_results.json")
    if not os.path.exists(path):
        pytest.skip("sweep not yet run")
    rows = json.load(open(path))
    seen = {(r["arch"], r["shape"], r["multi_pod"]): r["status"] for r in rows}
    from repro.configs import ARCH_IDS
    from repro.launch.specs import SHAPES
    missing = [(a, s, mp) for a in ARCH_IDS for s in SHAPES
               for mp in (False, True) if (a, s, mp) not in seen]
    # allow missing only while the background sweep is still filling in
    if missing:
        pytest.skip(f"sweep incomplete: {len(missing)} combos outstanding")
    assert all(v in ("ok", "skipped") for v in seen.values()), seen
