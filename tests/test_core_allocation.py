"""Behaviour tests for the paper's allocation algorithms (MILP, GH, AGH,
baselines) on `P_DM`."""
import numpy as np
import pytest

from repro.core import (agh, default_instance, dvr, feasibility, gh, hf,
                        is_feasible, lpr, objective, proc_delay,
                        provisioning_cost, random_instance, solve_milp,
                        stage2_lp)
from repro.core.mechanisms import m1_select
from repro.core.solution import Solution


def test_gh_feasible_on_default(default_inst):
    sol = gh(default_inst)
    assert is_feasible(default_inst, sol, enforce_zeta=False)
    assert sol.u.max() <= 1e-6          # full coverage in the base setting
    assert sol.runtime_s < 1.0          # paper: GH < 1 s


def test_agh_no_worse_than_gh(default_inst):
    g = gh(default_inst)
    a = agh(default_inst)
    assert is_feasible(default_inst, a, enforce_zeta=False)
    assert objective(default_inst, a) <= objective(default_inst, g) + 1e-6
    assert a.runtime_s < 10.0           # paper: AGH < 3 s at (20,20,20)


def test_agh_within_few_percent_of_milp(default_inst):
    """Paper: AGH matches the exact optimum within a few percent on
    instances the solver completes."""
    a = agh(default_inst)
    d = solve_milp(default_inst, time_limit=240)
    if d.method == "DM(timeout)":
        pytest.skip("MILP did not finish")
    assert is_feasible(default_inst, d, enforce_zeta=False)
    gap = (objective(default_inst, a) - objective(default_inst, d)) \
        / max(objective(default_inst, d), 1e-9)
    assert gap <= 0.05


def test_m1_discards_oversized_models(default_inst):
    """A 70B model (140 GB) must never fit a 24 GB tier at TP*PP=1."""
    inst = default_inst
    j70 = int(np.argmax(inst.B))
    k4090 = inst.tier_names.index("RTX4090-FP16")
    c = m1_select(inst, 0, j70, k4090)
    if c is not None:
        n, m = inst.configs[c]
        assert inst.B_eff[j70, k4090] / (n * m) <= inst.C_gpu[k4090]


def test_m1_respects_delay():
    inst = default_instance()
    inst.Delta[:] = 1e-6                # impossible SLO
    inst.__post_init__()
    for j in range(inst.J):
        for k in range(inst.K):
            assert m1_select(inst, 0, j, k) is None


def test_gh_budget_respected(default_inst):
    sol = gh(default_inst)
    v = feasibility(default_inst, sol, enforce_zeta=False)
    assert v["budget"] <= 1e-6


def test_baselines_run_and_route(default_inst):
    for fn in (lpr, dvr, hf):
        sol = fn(default_inst)
        # Baselines may violate coupled constraints (that is the point),
        # but routing arithmetic must be consistent.
        assert np.all(sol.x >= -1e-9)
        total = sol.x.sum(axis=(1, 2)) + sol.u
        assert np.allclose(total, 1.0, atol=1e-5)


def test_stage2_lp_reroutes_under_perturbation(default_inst):
    deploy = agh(default_inst)
    rng = np.random.default_rng(7)
    scen = default_inst.perturbed(rng, d_infl=0.10, e_infl=0.10)
    sol, ok = stage2_lp(scen, deploy)
    assert sol.x.sum() > 0
    # deployment unchanged
    assert np.array_equal(sol.y, deploy.y)
    assert np.array_equal(sol.w, deploy.w)


def test_runtime_scaling_medium():
    """GH stays sub-second and AGH a few seconds on a (10,10,10) instance."""
    inst = random_instance(10, 10, 10, seed=3)
    g = gh(inst)
    assert g.runtime_s < 2.0
    a = agh(inst, R=3)
    assert a.runtime_s < 30.0
    assert objective(inst, a) <= objective(inst, g) + 1e-6


def test_milp_beats_or_matches_heuristics_small():
    inst = random_instance(4, 4, 5, seed=1)
    d = solve_milp(inst, time_limit=120)
    if d.method == "DM(timeout)":
        pytest.skip("MILP timeout")
    a = agh(inst)
    assert objective(inst, d) <= objective(inst, a) + 1e-6


def test_proc_delay_respects_slo(default_inst):
    sol = agh(default_inst)
    assert np.all(proc_delay(default_inst, sol) <= default_inst.Delta + 1e-9)


def test_empty_solution_is_all_unmet(default_inst):
    sol = Solution.empty(default_inst)
    assert np.allclose(sol.u, 1.0)
    assert provisioning_cost(default_inst, sol) == 0.0
