"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each family (2 layers, d_model <= 512, <= 4 experts) and
run one forward/train step on CPU asserting output shapes + no NaNs. Also
checks prefill+decode consistency against the full forward pass."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import decoder

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    if cfg.n_codebooks:
        toks = jax.random.randint(RNG, (B, S, cfg.n_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = dict(tokens=toks, targets=toks)
    if cfg.n_prefix_embeds:
        batch["prefix"] = jax.random.normal(
            RNG, (B, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4
    params = decoder.init_params(RNG, cfg)
    batch = _batch(cfg)

    from repro.training.optimizer import AdamWConfig, init_state
    from repro.training.train_loop import make_train_step
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    opt = init_state(params)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                    - b.astype(jnp.float32)).max()),
                         params, params2)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    cfg = get_config(arch).smoke()
    params = decoder.init_params(RNG, cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    P = cfg.n_prefix_embeds
    logits, cache = decoder.prefill(params, cfg, batch["tokens"],
                                    batch.get("prefix"), max_len=S + P + 8)
    nq = cfg.n_codebooks
    want = (B, 1, nq, cfg.vocab_size) if nq else (B, 1, cfg.vocab_size)
    assert logits.shape == want
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = batch["tokens"][:, :1]
    lg, cache = decoder.decode_step(params, cfg, cache, tok,
                                    jnp.int32(S + P))
    assert lg.shape == want
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "rwkv6-7b", "zamba2-7b",
                                  "kimi-k2-1t-a32b"])
def test_prefill_decode_matches_full_forward(arch):
    """Decoding token-by-token after a prefill must reproduce the logits of
    one big forward pass (the serving-correctness invariant).

    MoE note: capacity-based dispatch drops depend on the co-batched tokens,
    so the invariant only holds when capacity is large enough that nothing
    drops — we raise capacity_factor accordingly (documented behaviour of
    capacity-MoE serving, not a bug)."""
    import dataclasses
    cfg = get_config(arch).smoke()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = decoder.init_params(RNG, cfg)
    B, S = 1, 12
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, S), 0,
                              cfg.vocab_size)
    # full prefill over S tokens -> last logits
    full_logits, _ = decoder.prefill(params, cfg, toks, max_len=S + 2)
    # prefill first S-3, then decode 3 steps
    cut = S - 3
    _, cache = decoder.prefill(params, cfg, toks[:, :cut], max_len=S + 2)
    lg = None
    for t in range(cut, S):
        lg, cache = decoder.decode_step(params, cfg, cache, toks[:, t:t + 1],
                                        jnp.int32(t))
    np.testing.assert_allclose(np.asarray(lg[:, -1], np.float32),
                               np.asarray(full_logits[:, -1], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_sliding_window_cache_ring():
    """With window < seq, ring-buffer decode matches a fresh windowed
    forward pass."""
    import dataclasses
    cfg = get_config("qwen2-0.5b").smoke()
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = decoder.init_params(RNG, cfg)
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    _, cache = decoder.prefill(params, cfg, toks[:, :-1], max_len=S)
    lg, _ = decoder.decode_step(params, cfg, cache, toks[:, -1:],
                                jnp.int32(S - 1))
    full, _ = decoder.prefill(params, cfg, toks, max_len=S)
    np.testing.assert_allclose(np.asarray(lg[:, -1], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=2e-2, rtol=2e-2)


def test_moe_capacity_active_flops_shape():
    """MoE block output is finite and the capacity is bounded by
    N * top_k * capacity_factor / E."""
    cfg = get_config("kimi-k2-1t-a32b").smoke()
    from repro.models.moe import moe_apply, moe_params
    p = moe_params(RNG, cfg)
    x = jax.random.normal(RNG, (2, 32, cfg.d_model), jnp.float32)
    y = moe_apply(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


def test_param_count_sane():
    cfg = get_config("kimi-k2-1t-a32b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert 0.9e12 < total < 1.2e12          # ~1T (paper-table entry)
    assert 25e9 < active < 40e9             # ~32B active
    dense = get_config("qwen2-72b")
    assert 65e9 < dense.param_count() < 85e9
