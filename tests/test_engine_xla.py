"""Equivalence suite for the XLA allocator engine (`engine="xla"`).

The numpy engine is the bit-exact oracle; the XLA tier is accepted on a
*dominance* contract rather than bit-identity: on every instance of the
equivalence suite it must return a solution whose objective is <= the
numpy engine's (plus a float32-safe slack that in practice is never
needed — on CPU x64 the two match exactly), with feasibility verified by
the frozen scalar path.  The tier must also ride the whole planner
surface: `plan(..., engine="xla")`, `PlanOptions(engine=...)`, and
warm replans through `PlanSession(engine="xla")`.

Skipped wholesale when jax is unavailable; the jax-free
`EngineUnavailableError` contract is tested unconditionally.
"""
import numpy as np
import pytest

from repro.core import agh, default_instance, is_feasible, objective, \
    random_instance
from repro.core.solution import feasibility
from repro.planner import (EngineUnavailableError, PlanOptions, PlanSession,
                           plan)

jax = pytest.importorskip("jax")

from repro.core.xla.engine import agh_xla  # noqa: E402  (needs jax)


def _instances():
    return [
        ("default", default_instance()),
        ("random-6-6-10", random_instance(6, 6, 10, seed=1)),
        ("random-8-5-6", random_instance(8, 5, 6, seed=2)),
        ("random-10-10-10", random_instance(10, 10, 10, seed=3)),
        ("stressed-1.15", default_instance().stressed(1.15)),
        ("stressed-1.3", default_instance().stressed(1.3)),
        ("tight-budget", random_instance(6, 6, 10, seed=4, budget=40.0)),
    ]


def _tol(obj):
    return 1e-6 * max(1.0, abs(obj))


def _assert_feasible_scalar(inst, sol, label):
    """Feasibility via the frozen per-constraint walk (the same checker
    the scalar reference path relies on), not the engine's own state."""
    viol = feasibility(inst, sol, enforce_zeta=False)
    bad = {k: v for k, v in viol.items() if v > 1e-4}
    assert not bad, f"{label}: constraint violations {bad}"


@pytest.mark.parametrize("name,inst", _instances())
def test_xla_objective_dominates_numpy(name, inst):
    """engine='xla' evaluates every lane (no early stop), so its best
    objective can never exceed the sequential numpy engine's."""
    sol_np = agh(inst, seed=0)
    sol_x = agh_xla(inst, seed=0)
    o_np, o_x = objective(inst, sol_np), objective(inst, sol_x)
    assert o_x <= o_np + _tol(o_np), (name, o_x, o_np)
    assert is_feasible(inst, sol_x, enforce_zeta=False)
    _assert_feasible_scalar(inst, sol_x, name)
    assert sol_x.method == "AGH-XLA"


def test_xla_stats_counters():
    inst = random_instance(8, 5, 6, seed=2)
    stats = {}
    agh_xla(inst, stats=stats)
    assert stats["engine"] == "xla"
    assert isinstance(stats["early_stopped"], bool)
    # The first improvement wave always covers at least patience+1
    # orderings, so the evaluated set is never smaller than the
    # sequential driver's minimum stop point.
    assert stats["orderings_evaluated"] >= 6
    assert stats["device_calls_phase2"] > 0
    # The screen must actually screen: on this instance most sources are
    # proven move-free on device without an exact host scan.
    assert stats["screened_clean"] > 0
    assert stats["screened_clean"] <= stats["screen_sources"]


def test_xla_rejects_reference_local_search():
    with pytest.raises(ValueError, match="reference"):
        agh_xla(default_instance(), local_search="reference")


def test_plan_facade_engine_kwarg():
    inst = random_instance(6, 6, 10, seed=1)
    res_np = plan(instance=inst)
    res_x = plan(instance=inst, engine="xla")
    assert res_x.options["engine"] == "xla"
    assert res_x.diagnostics["engine"] == "xla"
    assert res_x.objective <= res_np.objective + _tol(res_np.objective)
    assert res_x.feasible
    with pytest.raises(ValueError, match="not both"):
        from repro.planner import PlanRequest
        plan(PlanRequest(instance=inst), engine="xla")


def test_plan_unknown_engine_rejected():
    inst = default_instance()
    with pytest.raises(ValueError, match="unknown engine"):
        plan(instance=inst, options=PlanOptions(engine="tpu"))


def test_session_warm_replan_xla():
    """Warm replans ride the same tier: the incumbent seeds the xla
    multi-start and the drifted solve stays feasible and competitive
    with a cold numpy solve of the drifted instance."""
    inst = random_instance(6, 6, 10, seed=1)
    ses = PlanSession(engine="xla")
    ses.plan(instance=inst)
    assert ses.options.engine == "xla"
    drift = inst.with_lam(inst.lam * 1.12)
    res = ses.replan(instance=drift)
    assert ses.warm_replans == 1
    assert res.diagnostics["engine"] == "xla"
    assert res.diagnostics.get("warm_started") is True
    assert res.feasible
    cold = plan(instance=drift)
    # Warm replan trades ordering coverage for wall clock; it must stay
    # within the replan-protocol band of the cold solve (same contract
    # the numpy session tests pin), not strictly dominate it.
    assert res.objective <= cold.objective * 1.05 + 1e-9
    _assert_feasible_scalar(drift, res.solution, "warm-replan")


def test_warm_start_dominates_incumbent():
    inst = random_instance(8, 5, 6, seed=2)
    s1 = agh_xla(inst, seed=0)
    drift = inst.with_lam(inst.lam * 1.1)
    stats = {}
    s2 = agh_xla(drift, warm_start=s1, stats=stats)
    assert stats["warm_started"] is True
    assert "warm_objective" in stats
    assert objective(drift, s2) <= stats["warm_objective"] + 1e-9
    assert is_feasible(drift, s2, enforce_zeta=False)


def test_batch_width_invariance():
    """With early stop disabled (huge patience), chunking the lane
    dimension must not change the result: lanes are independent and the
    reduction runs in lane order regardless of device batch width.
    Under finite patience, narrower waves replay the sequential stop
    rule more often, so widths are dominance-ordered instead."""
    inst = random_instance(8, 5, 6, seed=2)
    base = agh_xla(inst, seed=0, patience=10**9)
    for bw in (1, 3):
        sol = agh_xla(inst, seed=0, patience=10**9, batch_width=bw)
        assert abs(objective(inst, sol) - objective(inst, base)) <= 1e-9
        assert np.array_equal(sol.q, base.q)
        assert np.array_equal(sol.w, base.w)
    # Finite patience: every width still dominates the numpy sequential
    # driver (its evaluated prefix is a superset of the sequential one).
    o_np = objective(inst, agh(inst, seed=0, workers=0))
    for bw in (1, 4):
        o_bw = objective(inst, agh_xla(inst, seed=0, batch_width=bw))
        assert o_bw <= o_np + _tol(o_np)


def test_numpy_default_untouched():
    """engine='numpy' (and the default) never imports jax machinery and
    stays bit-identical to a direct agh() call."""
    inst = random_instance(6, 6, 10, seed=1)
    res = plan(instance=inst)
    assert res.options["engine"] == "numpy"
    direct = agh(inst)
    assert abs(res.objective - objective(inst, direct)) <= 1e-9


# ----------------------------------------------------------------------
# Hypothesis property test: dominance + feasibility on ANY instance.
# Guarded import so only this test skips when hypothesis is missing —
# a module-level importorskip would silently skip the whole suite.
# ----------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal hosts
    _HAS_HYPOTHESIS = False


if _HAS_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(3, 8), st.integers(3, 6), st.integers(4, 10),
           st.integers(0, 10_000))
    def test_xla_dominance_property(I, J, K, seed):
        inst = random_instance(I, J, K, seed=seed)
        sol_np = agh(inst, seed=0)
        sol_x = agh_xla(inst, seed=0)
        o_np, o_x = objective(inst, sol_np), objective(inst, sol_x)
        assert o_x <= o_np + _tol(o_np)
        assert is_feasible(inst, sol_x, enforce_zeta=False)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_xla_dominance_property():
        pass
