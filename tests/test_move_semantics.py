"""Move-semantics properties of the delta-evaluation engine.

For random relocate-style moves (remove a committed (i,j,k) fraction, land
it on another pair) the incremental path must agree with a from-scratch
recomputation:

  * `state_objective` after the move  ==  `objective()` on the materialized
    solution (tolerance 1e-9);
  * the State's incremental aggregates == einsum recomputation from x/z;
  * a move accepted by `max_commit`/`commit` leaves a solution that passes
    the full `feasibility()` system;
  * `undo_all` restores every field of the State exactly (bitwise).
"""
import numpy as np
import pytest

from repro.core import (default_instance, greedy_heuristic, is_feasible,
                        objective, random_instance)
from repro.core.mechanisms import (commit, max_commit,
                                   remove_assignment, solution_from_state,
                                   state_objective, state_restore,
                                   state_snapshot, undo_all)

RTOL = 1e-9


def _states():
    out = []
    for name, inst in [("default", default_instance()),
                       ("random-8-6-8", random_instance(8, 6, 8, seed=5)),
                       ("random-10-10-10", random_instance(10, 10, 10, seed=3))]:
        _, st = greedy_heuristic(inst)
        out.append((name, st))
    return out


def _check_aggregates(st):
    inst = st.inst
    kv = np.einsum("ijk,ijk->jk", inst.kv_tok_per_x, st.x)
    load = np.einsum("ijk,ijk->jk", inst.load_per_x, st.x)
    stor = (np.sum(inst.B[None, :, None] * st.z, axis=(1, 2))
            + inst.data_gb * st.x.sum(axis=(1, 2)))
    np.testing.assert_allclose(st.kv_tok, kv, atol=1e-6, rtol=RTOL)
    np.testing.assert_allclose(st.load, load, atol=1e-6, rtol=RTOL)
    np.testing.assert_allclose(st.stor_used, stor, atol=1e-6, rtol=RTOL)


def _fields(st):
    return (st.x.copy(), st.y.copy(), st.q.copy(), st.cfg.copy(), st.z.copy(),
            st.r_rem.copy(), st.E_used.copy(), st.D_used.copy(), st.spend,
            st.kv_tok.copy(), st.load.copy(), st.stor_used.copy(),
            set(st.uncovered))


def _assert_exact_restore(before, st):
    after = _fields(st)
    names = ["x", "y", "q", "cfg", "z", "r_rem", "E_used", "D_used",
             "spend", "kv_tok", "load", "stor_used", "uncovered"]
    for name, a, b in zip(names, before, after, strict=True):
        if isinstance(a, np.ndarray):
            assert np.array_equal(a, b), f"{name} not restored exactly"
        else:
            assert a == b, f"{name} not restored exactly"


@pytest.mark.parametrize("name,st", _states())
def test_random_moves_match_from_scratch(name, st):
    inst = st.inst
    rng = np.random.default_rng(0)
    assigned = np.argwhere(st.x > 1e-9)
    n_checked = 0
    for _ in range(200):
        i, j, k = (int(v) for v in assigned[rng.integers(len(assigned))])
        j2, k2 = int(rng.integers(inst.J)), int(rng.integers(inst.K))
        if (j2, k2) == (j, k):
            continue
        before = _fields(st)
        undo = []
        frac = remove_assignment(st, i, j, k, undo=undo)
        # Delta removal must agree with a from-scratch evaluation.
        assert abs(state_objective(st)
                   - objective(inst, solution_from_state(inst, st))) \
            <= RTOL * max(1.0, state_objective(st))
        c = int(st.cfg[j2, k2]) if st.q[j2, k2] > 0.5 \
            else int(inst.cfg_m1[i, j2, k2])
        landed = False
        if c >= 0 and inst.D_cfg[i, j2, k2, c] <= inst.Delta[i] \
                and max_commit(st, i, j2, k2, c) >= frac - 1e-9:
            commit(st, i, j2, k2, c, frac, undo=undo)
            landed = True
            sol = solution_from_state(inst, st)
            # O(1)-maintained objective == full eq. (8a) recomputation.
            assert abs(state_objective(st) - objective(inst, sol)) \
                <= RTOL * max(1.0, abs(objective(inst, sol)))
            # Every accepted move keeps the full constraint system happy.
            assert is_feasible(inst, sol, enforce_zeta=False)
            _check_aggregates(st)
            n_checked += 1
        undo_all(st, undo)
        _assert_exact_restore(before, st)
        del landed
    assert n_checked >= 5, f"too few landable moves exercised ({n_checked})"


@pytest.mark.parametrize("name,st", _states())
def test_snapshot_restore_is_exact(name, st):
    before = _fields(st)
    snap = state_snapshot(st)
    rng = np.random.default_rng(1)
    assigned = np.argwhere(st.x > 1e-9)
    # Scramble the state with a handful of irreversible-looking edits.
    for _ in range(5):
        i, j, k = (int(v) for v in assigned[rng.integers(len(assigned))])
        remove_assignment(st, i, j, k)
    state_restore(st, snap)
    _assert_exact_restore(before, st)


def test_construction_aggregates_match_from_scratch():
    """After a full GH construction the incremental aggregates must equal
    their einsum definitions (the invariant `commit` promises)."""
    for _, st in _states():
        _check_aggregates(st)
        sol = solution_from_state(st.inst, st)
        assert abs(state_objective(st) - objective(st.inst, sol)) \
            <= RTOL * max(1.0, abs(objective(st.inst, sol)))
