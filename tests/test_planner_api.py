"""Unified planner API: shim equivalence, registry, specs, sessions.

The facade contract (ISSUE 5): `plan()` solutions are BITWISE-identical
to direct calls of the legacy entry points (`gh`/`agh`/`solve_milp`/
`dvr`/`hf`/`lpr`) — the old functions stay the implementation, the
facade is a wrapper, and these tests pin that nothing drifts in between.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (agh, default_instance, dvr, gh, hf, lpr, objective,
                        random_instance, solve_milp)
from repro.planner import (PlanOptions, PlanRequest, PlanResult, PlanSession,
                           SolverSpec, UnknownSolverError, plan,
                           register_solver, scenario, solver_names,
                           unregister_solver)
from repro.planner.specs import FleetSpec, ScenarioSpec, WorkloadSpec


def _instances():
    return [
        ("default", default_instance()),
        ("random-6-6-10", random_instance(6, 6, 10, seed=1)),
        ("random-8-5-6", random_instance(8, 5, 6, seed=2)),
        ("stressed-1.15", default_instance().stressed(1.15)),
        ("tight-budget", random_instance(6, 6, 10, seed=4, budget=40.0)),
    ]


def _assert_bitwise_equal(a, b, label):
    for f in ("x", "y", "q", "w", "z", "u"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), \
            f"{label}: field {f} differs"


# ---------------------------------------------------------------------------
# Shim layer: facade == direct calls, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,inst", _instances())
def test_facade_gh_bitwise_equals_direct(name, inst):
    res = plan("gh", instance=inst)
    _assert_bitwise_equal(res.solution, gh(inst), f"gh/{name}")
    assert res.objective == pytest.approx(objective(inst, res.solution),
                                          abs=0.0)


@pytest.mark.parametrize("name,inst", _instances())
def test_facade_agh_bitwise_equals_direct(name, inst):
    res = plan("agh", instance=inst)
    _assert_bitwise_equal(res.solution, agh(inst), f"agh/{name}")
    assert res.diagnostics["orderings_evaluated"] >= 1


def test_facade_agh_options_map_through():
    inst = random_instance(6, 6, 10, seed=1)
    opts = PlanOptions(restarts=2, patience=3, seed=5,
                       local_search="batched-rescan", workers=0)
    res = plan("agh", instance=inst, options=opts)
    direct = agh(inst, R=2, patience=3, seed=5,
                 local_search="batched-rescan", workers=0)
    _assert_bitwise_equal(res.solution, direct, "agh/options")


def test_facade_milp_bitwise_equals_direct():
    # Small enough that HiGHS converges to proven optimality in well
    # under a second — far from the time limit, so branch-and-bound is
    # deterministic and the facade/direct solutions are bitwise equal.
    inst = random_instance(3, 3, 4, seed=3)
    res = plan("milp", instance=inst,
               options=PlanOptions(time_limit=120.0))
    direct = solve_milp(inst, time_limit=120.0)
    assert res.diagnostics["status"] == direct.method == "DM"
    _assert_bitwise_equal(res.solution, direct, "milp")
    # the alias resolves to the same canonical spec
    assert plan("dm", instance=inst,
                options=PlanOptions(time_limit=120.0)).solver == "milp"


@pytest.mark.parametrize("solver,fn", [("dvr", dvr), ("hf", hf)])
def test_facade_baselines_bitwise_equal_direct(solver, fn):
    inst = default_instance()
    res = plan(solver, instance=inst)
    _assert_bitwise_equal(res.solution, fn(inst), solver)


def test_facade_lpr_bitwise_equals_direct():
    inst = random_instance(3, 3, 4, seed=3)
    res = plan("lpr", instance=inst, options=PlanOptions(time_limit=120.0))
    _assert_bitwise_equal(res.solution, lpr(inst, time_limit=120.0), "lpr")


def test_gh_rejects_unknown_kwargs_loudly():
    """Satellite: `gh` has an explicit signature now — a typo'd option is
    a TypeError at the call site, not a silently ignored kwarg."""
    inst = default_instance()
    with pytest.raises(TypeError):
        gh(inst, ordering=np.arange(inst.I))  # typo of order=


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_unknown_solver_lists_registered_names():
    with pytest.raises(UnknownSolverError) as ei:
        plan("aghh", instance=default_instance())
    msg = str(ei.value)
    for name in ("gh", "agh", "milp", "lpr", "dvr", "hf"):
        assert name in msg
    assert "aghh" in msg


def test_register_custom_solver_roundtrip():
    def _noop(inst, options, warm_start):
        return gh(inst), {"custom": True}

    spec = SolverSpec("custom-test", _noop, "test-only solver")
    register_solver(spec)
    try:
        assert "custom-test" in solver_names()
        res = plan("custom-test", instance=default_instance())
        assert res.diagnostics["custom"] is True
        with pytest.raises(ValueError, match="already registered"):
            register_solver(spec)
    finally:
        unregister_solver("custom-test")
    assert "custom-test" not in solver_names()


def test_register_before_first_lookup_loads_builtins():
    """A plugin registering a builtin name at import time (before any
    get_solver call) must fail loudly at registration — not poison the
    deferred builtin import for every later lookup."""
    with pytest.raises(ValueError, match="already registered"):
        register_solver(SolverSpec("gh", lambda i, o, w: None, "clash"))
    # registry still fully works afterwards
    assert "agh" in solver_names()


def test_overwrite_clears_stale_alias():
    """Overwriting a name that was previously an alias ("dm" -> "milp")
    must make the new spec reachable — a stale alias entry would silently
    route lookups to the old target."""
    def _custom(inst, options, warm_start):
        return gh(inst), {"custom_dm": True}

    register_solver(SolverSpec("dm", _custom, "test"), overwrite=True)
    try:
        res = plan("dm", instance=default_instance())
        assert res.diagnostics.get("custom_dm") is True
        assert res.solver == "dm"
    finally:
        unregister_solver("dm")
        # restore the builtin alias for the rest of the suite
        from repro.planner.registry import _ALIASES
        _ALIASES["dm"] = "milp"
    assert plan("dm", instance=random_instance(3, 3, 4, seed=3),
                options=PlanOptions(time_limit=60.0)).solver == "milp"


# ---------------------------------------------------------------------------
# PlanResult structure + JSON round trip
# ---------------------------------------------------------------------------

def test_plan_result_json_round_trip():
    inst = default_instance()
    res = plan("agh", instance=inst)
    res2 = PlanResult.from_json(res.to_json())
    _assert_bitwise_equal(res2.solution, res.solution, "json")
    assert res2.objective == res.objective
    assert res2.cost_breakdown == res.cost_breakdown
    assert res2.slack == res.slack
    assert res2.violations == res.violations
    assert res2.diagnostics == res.diagnostics
    assert res2.options == res.options
    assert res2.feasible == res.feasible
    # summary rows are JSON-safe and registry-keyed
    row = res.summary()
    assert row["solver"] == "agh"
    assert isinstance(row["objective"], float)


def test_plan_result_reports_cost_and_slack():
    inst = default_instance()
    res = plan("gh", instance=inst)
    assert res.objective == pytest.approx(
        sum(res.cost_breakdown.values()), rel=1e-12)
    assert res.feasible
    # every slack of a feasible plan is >= (tiny negative float fuzz)
    assert all(v >= -1e-6 for v in res.slack.values()), res.slack
    assert set(res.slack) == {"budget", "memory", "compute", "storage",
                              "delay", "error", "unmet"}
    assert res.wall_s > 0 and res.cpu_s >= 0


def test_plan_request_validation():
    inst = default_instance()
    with pytest.raises(ValueError, match="exactly one"):
        plan(PlanRequest(solver="gh"))
    with pytest.raises(ValueError, match="exactly one"):
        plan(PlanRequest(solver="gh", instance=inst,
                         scenario="paper-default"))
    with pytest.raises(ValueError, match="not both"):
        plan(PlanRequest(solver="gh", instance=inst), instance=inst)


def test_plan_options_round_trip():
    opts = PlanOptions(restarts=4, ablation=frozenset({"no_m1"}),
                       order=(2, 0, 1))
    opts2 = PlanOptions.from_dict(opts.to_dict())
    assert opts2 == opts


# ---------------------------------------------------------------------------
# Scenario specs
# ---------------------------------------------------------------------------

def test_paper_default_scenario_matches_default_instance():
    inst = scenario("paper-default").build()
    want = default_instance()
    assert np.array_equal(inst.lam, want.lam)
    assert np.array_equal(inst.e_base, want.e_base)
    assert inst.delta == want.delta
    assert list(inst.tier_names) == list(want.tier_names)


def test_named_scenarios_build_and_override():
    assert scenario("budget-tight").build().delta == 72.0
    assert scenario("budget-tight", budget=80.0).build().delta == 80.0
    tpu = scenario("tpu-fleet").build()
    assert any(t.startswith("v5e") for t in tpu.tier_names)
    assert max(tpu.tp_degrees) == 16
    stressed = scenario("stress-1.5x").build()
    assert np.allclose(stressed.tau, default_instance().tau * 1.5)


def test_unknown_scenario_lists_registered_names():
    with pytest.raises(KeyError, match="paper-default"):
        scenario("no-such-scenario")


def test_demand_paths():
    spec = scenario("azure-diurnal", n_windows=32)
    inst = spec.build()
    path = spec.demand_path(inst)
    assert path.shape == (32, inst.I)
    assert (path > 0).all()
    flat = scenario("paper-default", n_windows=8)
    assert np.array_equal(flat.demand_path(inst)[0], inst.lam)
    rw = dataclasses.replace(
        spec, workload=dataclasses.replace(spec.workload,
                                           demand="random-walk",
                                           n_windows=16))
    assert rw.demand_path(inst).shape == (16, inst.I)


def test_synthetic_scenario_spec():
    spec = ScenarioSpec(workload=WorkloadSpec(family="synthetic",
                                              I=6, J=6, K=10), seed=1)
    inst = spec.build()
    want = random_instance(6, 6, 10, seed=1)
    assert np.array_equal(inst.lam, want.lam)
    with pytest.raises(ValueError, match="catalog"):
        ScenarioSpec(fleet=FleetSpec(catalog="asic")).build()


def test_plan_accepts_scenario_names():
    res = plan("gh", scenario="budget-tight")
    assert res.feasible is not None
    assert res.solution.x.shape[0] == 6


# ---------------------------------------------------------------------------
# PlanSession warm replanning
# ---------------------------------------------------------------------------

def test_session_cold_then_warm_replan():
    inst = random_instance(6, 6, 10, seed=1)
    ses = PlanSession(options=PlanOptions(workers=0))
    r0 = ses.plan(instance=inst)
    assert ses.plans == 1 and ses.warm_replans == 0
    assert not r0.diagnostics["warm_started"]
    drifted = inst.with_lam(inst.lam * 1.08)
    r1 = ses.replan(instance=drifted)
    assert ses.plans == 2 and ses.warm_replans == 1
    assert r1.diagnostics["warm_started"]
    assert r1.feasible
    # incumbent rolls forward
    _assert_bitwise_equal(ses.incumbent, r1.solution, "incumbent")
    # lam= shorthand replans the remembered instance
    r2 = ses.replan(lam=inst.lam * 0.95)
    assert r2.feasible and ses.warm_replans == 2


def test_session_without_incumbent_degrades_to_cold():
    ses = PlanSession()
    res = ses.replan(instance=default_instance())
    assert not res.diagnostics.get("warm_started", False)
    assert ses.plans == 1 and ses.warm_replans == 0


def test_session_remembers_winning_order():
    inst = random_instance(8, 5, 6, seed=2)
    ses = PlanSession(options=PlanOptions(workers=0))
    ses.plan(instance=inst)
    if ses.winning_order is not None:
        assert sorted(ses.winning_order) == list(range(inst.I))
    r1 = ses.replan(instance=inst.with_lam(inst.lam * 1.05))
    # replayed priority ordering keeps the replan's quality contract:
    # never worse than the incumbent re-scored... (empirical bound is in
    # test_perf_smoke); here just assert the plumbing round-trips.
    assert r1.diagnostics["restarts"] == ses.replan_restarts


def test_session_drives_rolling_replay():
    from repro.core import rolling
    inst = default_instance()
    path = np.outer(np.linspace(0.9, 1.1, 12), inst.lam)
    ses = PlanSession(options=PlanOptions(restarts=1, patience=2,
                                          workers=0))
    res = rolling(inst, path, ses, replan_every=4)
    assert res.per_window_cost.shape == (12,)
    assert ses.plans >= 2            # initial plan + >=1 window replan
    assert ses.warm_replans >= 1


def test_session_seed_installs_external_incumbent():
    inst = random_instance(6, 6, 10, seed=1)
    res = plan("agh", instance=inst, options=PlanOptions(workers=0))
    ses = PlanSession(options=PlanOptions(workers=0))
    ses.seed(inst, res)
    _assert_bitwise_equal(ses.incumbent, res.solution, "seed")
    r1 = ses.replan(lam=inst.lam * 1.05)
    assert r1.diagnostics["warm_started"] and ses.warm_replans == 1


def test_non_warm_solver_session_stays_cold():
    ses = PlanSession(solver="gh")
    inst = default_instance()
    ses.plan(instance=inst)
    r = ses.replan(instance=inst.with_lam(inst.lam * 1.1))
    # gh cannot warm-start: the facade drops the incumbent and reports so.
    assert r.diagnostics["warm_started"] is False
    assert ses.warm_replans == 0