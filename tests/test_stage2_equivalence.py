"""Equivalence of the vectorized Stage-2 engine and the frozen seed path.

`Stage2System` assembles the scenario LP from precomputed per-triple factor
arrays on a fixed sparsity pattern; `_scalar_ref.stage2_lp_ref` freezes the
seed's per-call dict-of-tuples assembly.  Both must agree on every instance
× deployment × cap combination: same capped-feasibility verdict, same
routing objective (the LP optimum is unique even when the vertex is not),
and the batched / looped / fanned-out evaluation protocols must agree on
violation rates and expected costs because they draw bit-identical
scenarios.
"""
import numpy as np
import pytest

from repro.core import (agh, default_instance, evaluate, gh, hf,
                        random_instance)
from repro.core._scalar_ref import stage2_lp_ref
from repro.core.stage2 import Stage2System, stage2_cost, stage2_lp


def _cases():
    d = default_instance()
    r = random_instance(8, 5, 6, seed=2)
    t = random_instance(6, 6, 10, seed=4, budget=40.0)
    return [
        ("default+GH", d, gh(d)),
        ("default+AGH", d, agh(d)),
        ("random-8-5-6+GH", r, gh(r)),
        ("tight-budget+HF", t, hf(t)),
    ]


CASES = _cases()


@pytest.mark.parametrize("name,inst,deploy", CASES,
                         ids=[c[0] for c in CASES])
def test_stage2_lp_matches_reference(name, inst, deploy):
    """Base + perturbed scenarios, default and strict caps, both admission
    modes: identical capped flags, objectives within 1e-9."""
    rng = np.random.default_rng(11)
    scens = [inst, *(inst.perturbed(rng, d_infl=0.15, e_infl=0.10)
                     for _ in range(3))]
    strict = np.full(inst.I, 0.02)
    for si, scen in enumerate(scens):
        for cap, any_dep in [(None, False), (strict, False),
                             (np.ones(inst.I), True)]:
            got, ok_got = stage2_lp(scen, deploy, u_cap=cap,
                                    allow_any_deployed=any_dep)
            want, ok_want = stage2_lp_ref(scen, deploy, u_cap=cap,
                                          allow_any_deployed=any_dep)
            label = (name, si, "strict" if cap is not None else "zeta",
                     any_dep)
            assert ok_got == ok_want, label
            c_got, c_want = stage2_cost(scen, got), stage2_cost(scen, want)
            assert abs(c_got - c_want) <= 1e-9 * max(1.0, abs(c_want)), \
                (label, c_got, c_want)
            assert np.allclose(got.u, want.u, atol=1e-7), label
            # Deployment untouched, demand balance holds.
            assert np.array_equal(got.y, deploy.y), label
            assert np.allclose(got.x.sum(axis=(1, 2)) + got.u, 1.0,
                               atol=1e-6), label


def test_stage2_system_reuse_matches_one_shot():
    """One system solving many scenarios == one stage2_lp call per scenario
    (the pattern-reuse refresh leaves no stale coefficients behind)."""
    inst = default_instance()
    deploy = gh(inst)
    system = Stage2System(inst, deploy)
    rng = np.random.default_rng(3)
    scens = [inst.perturbed(rng) for _ in range(4)]
    for scen in scens:
        r = system.solve(tau=scen.tau, e_base=scen.e_base, lam=scen.lam)
        sol, ok = stage2_lp(scen, deploy)
        assert ok == r.capped_ok
        want = stage2_cost(scen, sol)
        assert abs(r.cost - want) <= 1e-9 * max(1.0, abs(want))


def test_perturbed_batch_matches_sequential_draws():
    """Batched sampling must replay the sequential RNG stream bitwise."""
    inst = default_instance()
    batch = inst.perturbed_batch(np.random.default_rng(42), 5,
                                 d_infl=0.15, e_infl=0.10, lam_pm=0.20)
    rng = np.random.default_rng(42)
    for s in range(5):
        scen = inst.perturbed(rng, d_infl=0.15, e_infl=0.10, lam_pm=0.20)
        assert np.array_equal(batch.tau[s], scen.tau)
        assert np.array_equal(batch.e_base[s], scen.e_base)
        assert np.array_equal(batch.lam[s], scen.lam)
        mat = batch.materialize(inst, s)
        assert np.array_equal(mat.lam, scen.lam)
        assert np.array_equal(mat.D_cfg, scen.D_cfg)


def test_evaluate_batched_matches_loop():
    """Identical violation rate, expected cost within 1e-6 (acceptance)."""
    inst = default_instance()
    deploy = gh(inst)
    rb = evaluate(inst, deploy, S=30, seed=9)
    rl = evaluate(inst, deploy, S=30, seed=9, batched=False)
    assert rb.violation_rate == rl.violation_rate
    assert abs(rb.expected_cost - rl.expected_cost) < 1e-6
    assert np.allclose(rb.per_scenario_cost, rl.per_scenario_cost, atol=1e-6)


def test_evaluate_batched_matches_seed_protocol():
    """Agreement with the seed protocol reconstructed verbatim: sequential
    perturbed() + stage2_lp_ref per scenario."""
    inst = default_instance()
    deploy = gh(inst)
    S = 10
    res = evaluate(inst, deploy, S=S, seed=5)
    rng = np.random.default_rng(5)
    costs = np.zeros(S)
    viol = 0
    for s in range(S):
        scen = inst.perturbed(rng, d_infl=0.15, e_infl=0.10, lam_pm=0.20)
        sol, _ = stage2_lp_ref(scen, deploy)
        costs[s] = stage2_cost(scen, sol)
        viol += int(np.sum(sol.u > 0.01))
    assert res.violation_rate == viol / (S * inst.I)
    assert np.allclose(res.per_scenario_cost, costs, atol=1e-6)


def test_evaluate_strict_cap_paths_agree():
    """The strict-cap → relaxed-fallback branch agrees across paths too."""
    inst = default_instance()
    deploy = gh(inst)
    cap = np.full(inst.I, 0.02)
    rb = evaluate(inst, deploy, S=20, seed=2, u_cap=cap)
    rl = evaluate(inst, deploy, S=20, seed=2, u_cap=cap, batched=False)
    assert rb.violation_rate == rl.violation_rate
    assert np.allclose(rb.per_scenario_cost, rl.per_scenario_cost, atol=1e-6)


def test_evaluate_process_pool_matches_serial():
    inst = default_instance()
    deploy = gh(inst)
    rs = evaluate(inst, deploy, S=8, seed=1)
    rw = evaluate(inst, deploy, S=8, seed=1, workers=2)
    assert rs.violation_rate == rw.violation_rate
    assert np.array_equal(rs.per_scenario_cost, rw.per_scenario_cost)


def test_ssm_models_match_reference():
    """kv_applicable=False models get no (8f) row (constant recurrent
    state, not per-token KV) — the factored assembly must mirror the seed's
    skip, including for deployments that actually use such a model."""
    inst = default_instance()
    deploy0 = gh(inst)
    used = np.flatnonzero(deploy0.q.sum(axis=1) > 0.5)
    assert used.size > 0
    inst.kv_applicable = np.ones(inst.J, dtype=bool)
    inst.kv_applicable[used[0]] = False       # one deployed model is SSM
    inst.__post_init__()
    deploy = gh(inst)
    rng = np.random.default_rng(17)
    for scen in (inst, inst.perturbed(rng, d_infl=0.15, e_infl=0.10)):
        got, ok_got = stage2_lp(scen, deploy)
        want, ok_want = stage2_lp_ref(scen, deploy)
        assert ok_got == ok_want
        c_got, c_want = stage2_cost(scen, got), stage2_cost(scen, want)
        assert abs(c_got - c_want) <= 1e-9 * max(1.0, abs(c_want))
        assert np.allclose(got.u, want.u, atol=1e-7)


def test_empty_deployment_full_unmet():
    """A deployment that can route nothing: u = 1 everywhere, not a crash."""
    from repro.core import Solution
    inst = default_instance()
    empty = Solution.empty(inst)
    sol, ok = stage2_lp(inst, empty, u_cap=np.full(inst.I, 0.02))
    assert not ok
    assert np.allclose(sol.u, 1.0)
    assert sol.x.sum() == 0.0
