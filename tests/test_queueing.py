"""Direct tests for core/queueing.py — the M/G/1-PS load extension.

The load-free constraint system (tests/test_core_allocation.py) never
exercises the queueing layer directly; these pin its three contracts:
the PS inflation is a true inflation (never below the load-free delay),
`with_queueing_margin` makes the TRUE loaded delay of any emitted plan
satisfy the ORIGINAL SLO (D_true <= Delta) while keeping utilization
under the rho_max cap, and the margin transform itself moves
monotonically in rho_max.
"""
import numpy as np

from repro.core import agh, default_instance, random_instance
from repro.core.queueing import (queueing_delay, queueing_violations,
                                 slo_attainment_with_queueing, utilization,
                                 with_queueing_margin)
from repro.core.solution import proc_delay

RHO_GRID = (0.5, 0.7, 0.9)


def _cases():
    return [default_instance(), random_instance(10, 10, 10, seed=3)]


def test_queueing_delay_is_an_inflation():
    """D_queue >= D_proc pointwise (PS factor 1/(1-rho) >= 1), equality
    exactly where the plan routes nothing."""
    for inst in _cases():
        sol = agh(inst)
        d0, dq = proc_delay(inst, sol), queueing_delay(inst, sol)
        assert np.all(dq >= d0 - 1e-9)
        rho = utilization(inst, sol)
        assert np.all(rho >= 0.0) and np.all(rho <= 0.999)
        # inactive pairs carry zero utilization by construction
        assert np.all(rho[sol.y <= 0] == 0.0)


def test_margin_bound_true_delay_within_slo():
    """The paper-extension guarantee: plan against
    `with_queueing_margin(inst, rho_max)`, then the queueing-ADJUSTED
    delay evaluated on the ORIGINAL instance still meets the original
    Delta — D_true = D/(1-rho) <= Delta — and the measured utilization
    stays under the cap."""
    for inst in _cases():
        for rho_max in RHO_GRID:
            sol = agh(with_queueing_margin(inst, rho_max))
            assert int(queueing_violations(inst, sol).sum()) == 0, \
                (inst.I, rho_max)
            assert utilization(inst, sol).max() <= rho_max + 1e-9
        # contrast: the load-free plan does break SLOs once load counts
        # (both fixture instances exhibit this; if a future engine change
        # removes it the contrast assertion below should be revisited,
        # not deleted)
        base = agh(inst)
        assert int(queueing_violations(inst, base).sum()) > 0


def test_margin_transform_monotone_in_rho_max():
    """Both knobs scale UP with rho_max: eta (usable capacity grows as
    the utilization cap loosens) and the tau pre-inflation (a looser cap
    means a larger worst-case PS factor 1/(1-rho_max) to plan against);
    both strictly monotone, landing exactly on the documented formulas."""
    inst = default_instance()
    prev_eta = prev_tau = -np.inf
    for rho_max in RHO_GRID:
        m = with_queueing_margin(inst, rho_max)
        assert np.isclose(m.eta, inst.eta * rho_max)
        assert np.allclose(m.tau, inst.tau / (1.0 - rho_max))
        assert m.eta > prev_eta
        assert np.all(m.tau > prev_tau)
        prev_eta, prev_tau = m.eta, np.max(m.tau)


def test_slo_attainment_summary_consistent():
    inst = default_instance()
    sol = agh(inst)
    rep = slo_attainment_with_queueing(inst, sol)
    assert rep["violations_queueing"] == int(
        queueing_violations(inst, sol).sum())
    assert rep["violations_load_free"] == int(
        np.sum(rep["proc_delay"] > inst.Delta + 1e-9))
    assert np.isclose(rep["max_rho"], utilization(inst, sol).max())
    assert np.isclose(rep["margin_min"], float(np.min(
        (inst.Delta - rep["queue_delay"]) / inst.Delta)))
    # the summary's two delay views agree with the module's own functions
    assert np.allclose(rep["queue_delay"], queueing_delay(inst, sol))
    assert np.allclose(rep["proc_delay"], proc_delay(inst, sol))
