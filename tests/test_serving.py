"""Closed-loop serving layer: `repro.serve()`, stations, router, controller.

Covers the PR's API-redesign surface end to end on the small default
instance (seconds, not minutes):

* the legacy `simulator.simulate()` stays bit-identical under an explicit
  ``max_batch=`` (pinned oracle), while the new default derives each
  station's concurrency bound from its committed capacity (the satellite
  bugfix) — small-capacity stations admit fewer than the old blanket 32;
* `serve()` is deterministic under its seeds, conserves routed traffic
  according to the plan's `x` fractions, and degrades monotonically as
  traffic scales past the plan's capacity;
* the forecast controller fires on genuine demand drift and stays quiet
  on stationary traffic; fault injection triggers a warm `repair()`;
* `ServeResult` JSON round-trips exactly and ``from repro import serve``
  works with jax missing entirely.
"""
import dataclasses
import json
import subprocess
import sys

import numpy as np
import pytest

from repro.core import agh, default_instance
from repro.core.faults import FaultSchedule, TierOutage
from repro.core.queueing import with_queueing_margin
from repro.serving import (ControllerSpec, ReplanController, ServeResult,
                           TrafficSpec, serve)
from repro.serving.router import SHED, Router
from repro.serving.simulator import simulate
from repro.serving.stations import Req, StationSim, build_stations

# Pinned pre-refactor output of simulate(default_instance, agh, 300 s,
# rate_scale=0.02, max_batch=32, seed=0) — captured before simulator.py
# learned the derived bound.  An explicit max_batch must stay
# bit-identical to the historical fixed-bound behaviour.
ORACLE_N_SERVED = 88
ORACLE_TTFT = [2.28044048, 0.51294643, 0.87837302,
               0.61875, 0.86235424, 1.75080767]
ORACLE_E2E_P95 = [10.05448856, 9.16854819, 8.61619262,
                  7.05208464, 23.89130779, 4.28654712]
ORACLE_ATTAIN = [0.26666667, 0.36363636, 0.56521739,
                 0.77777778, 0.66666667, 1.0]


@pytest.fixture(scope="module")
def default_plan():
    inst = default_instance()
    return inst, agh(inst)


def test_legacy_simulate_explicit_max_batch_bit_identical(default_plan):
    inst, sol = default_plan
    st = simulate(inst, sol, horizon_s=300.0, rate_scale=0.02,
                  max_batch=32, seed=0)
    assert st.n_served == ORACLE_N_SERVED
    np.testing.assert_allclose(st.per_type_ttft_p50, ORACLE_TTFT, rtol=1e-7)
    np.testing.assert_allclose(st.per_type_e2e_p95, ORACLE_E2E_P95,
                               rtol=1e-7)
    np.testing.assert_allclose(st.per_type_slo_attain, ORACLE_ATTAIN,
                               rtol=1e-7)


def test_station_b_max_tracks_committed_capacity(default_plan):
    """The satellite bugfix: B_max follows the plan's y, not a fixed 32 —
    shrinking a station's committed GPUs shrinks what it may admit."""
    inst, sol = default_plan
    full = build_stations(inst, sol)
    assert full and all(s.b_max >= 1 for s in full)
    small = dataclasses.replace(sol, y=np.maximum(1.0, sol.y * 0.1))
    shrunk = build_stations(inst, small)
    by_jk = {(s.j, s.k): s for s in shrunk}
    for s in full:
        assert by_jk[(s.j, s.k)].b_max < s.b_max
    # A ~1-GPU station admits what it can sustain, not the blanket 32.
    assert all(s.b_max < 32 for s in shrunk)


def test_station_sim_respects_concurrency_bound(default_plan):
    inst, sol = default_plan
    st = build_stations(inst, sol)[0]
    sim = StationSim(inst, st, b_eff=3)
    sim.push([Req(qtype=0, t_arrive=0.01 * a, h=32, f=16)
              for a in range(50)])
    sim.drain()
    done = sim.take_done()
    assert len(done) == 50
    assert sim.peak_inflight <= 3
    for r in done:
        assert 0 <= r.t_first <= r.t_done


def test_serve_deterministic_under_seeds(default_plan):
    inst, sol = default_plan
    tr = TrafficSpec(horizon_s=900.0, window_s=300.0, rate_scale=0.02,
                     seed=3)
    ctl = ControllerSpec(mode="static")
    a = serve(sol, instance=inst, traffic=tr, controller=ctl)
    b = serve(sol, instance=inst, traffic=tr, controller=ctl)
    assert a.to_json(sort_keys=True) == b.to_json(sort_keys=True)
    assert a.n_arrived > 0 and a.n_served > 0


def test_router_conserves_plan_fractions(default_plan):
    """Weighted-random routing reproduces the plan's x fractions (and the
    shed residual 1 - sum_jk x) on a deterministic uniform grid."""
    inst, sol = default_plan
    stations = build_stations(inst, sol)
    router = Router(inst, sol, stations)
    us = np.linspace(0.0, 1.0, 20001)[:-1]   # [0, 1)
    for i in range(inst.I):
        hits = np.zeros(len(stations))
        shed = 0
        for u in us:
            s = router.route(i, float(u))
            if s == SHED:
                shed += 1
            else:
                hits[s] += 1
        want = np.array([sol.x[i, st.j, st.k] for st in stations])
        np.testing.assert_allclose(hits / len(us), want, atol=1e-3)
        assert abs(shed / len(us) - (1.0 - want.sum())) < 1e-3


def test_attainment_monotone_in_rate_scale():
    """With the station concurrency pinned (concurrency_scale=1.0),
    pushing more traffic through the same fleet never improves SLO
    attainment."""
    inst = default_instance()
    sol = agh(inst)                  # no queueing margin: saturable
    attains = []
    for rs in (0.2, 0.8, 1.6):
        r = serve(sol, instance=inst,
                  traffic=TrafficSpec(horizon_s=900.0, window_s=300.0,
                                      rate_scale=rs, concurrency_scale=1.0,
                                      seed=5),
                  controller=ControllerSpec(mode="static"))
        attains.append(r.attainment())
    assert attains[0] >= attains[1] - 0.02
    assert attains[1] >= attains[2] - 0.02
    assert attains[0] > attains[2]          # capacity actually saturates


def test_controller_fires_on_drift_quiet_when_stationary():
    lam = np.array([100.0, 50.0])
    spec = ControllerSpec(mode="forecast", warmup=1, cooldown=2,
                          ewma_alpha=0.5, drift_threshold=0.25)
    quiet = ReplanController(spec, lam)
    for w in range(10):
        cause, drift = quiet.observe(w, lam, viol_frac=0.0)
        assert cause is None and drift < 1e-9
    drifting = ReplanController(spec, lam)
    fired = []
    for w in range(10):
        cause, _ = drifting.observe(w, lam * 3.0, viol_frac=0.0)
        if cause is not None:
            fired.append((w, cause))
            drifting.adopted(w, drifting.forecast)
    assert fired and fired[0][1] == "drift"
    # Cooldown: no two firings closer than `cooldown` windows.
    gaps = np.diff([w for w, _ in fired])
    assert np.all(gaps >= spec.cooldown)


def test_controller_slo_budget_and_fixed_cadence():
    lam = np.array([10.0])
    spec = ControllerSpec(mode="forecast", warmup=0, cooldown=1,
                          violation_budget=0.05, budget_windows=2,
                          drift_threshold=10.0)   # drift can never fire
    ctl = ReplanController(spec, lam)
    assert ctl.observe(0, lam, viol_frac=0.2)[0] is None   # streak = 1
    assert ctl.observe(1, lam, viol_frac=0.2)[0] == "slo"  # streak = 2
    fixed = ReplanController(ControllerSpec(mode="fixed", replan_every=3),
                             lam)
    causes = [fixed.observe(w, lam, viol_frac=1.0)[0] for w in range(7)]
    assert causes == [None, None, None, "scheduled", None, None,
                      "scheduled"]
    static = ReplanController(ControllerSpec(mode="static"), lam)
    assert all(static.observe(w, lam * 9, viol_frac=1.0)[0] is None
               for w in range(5))


def test_serve_forecast_replans_on_diurnal_drift():
    """End to end: diurnal traffic moves demand, the controller replans
    with cause 'drift'/'slo'; the same day under mode='static' does not."""
    inst = default_instance()
    sol = agh(with_queueing_margin(inst, rho_max=0.5))
    tr = TrafficSpec(horizon_s=3600.0, window_s=300.0, rate_scale=0.02,
                     trace="volatile", seed=2)
    r_fc = serve(sol, instance=inst, traffic=tr,
                 controller=ControllerSpec(mode="forecast", rho_max=0.5,
                                           warmup=1, cooldown=2))
    assert r_fc.replans and all(e.cause in ("drift", "slo")
                                for e in r_fc.replans)
    r_st = serve(sol, instance=inst, traffic=tr,
                 controller=ControllerSpec(mode="static"))
    assert not r_st.replans


def test_serve_fault_triggers_warm_repair():
    inst = default_instance()
    sol = agh(inst)
    busiest = int(np.argmax(sol.y.sum(axis=0)))
    sched = FaultSchedule(n_windows=6, events=(
        TierOutage(tier=busiest, t0=2, t1=5),))
    r = serve(sol, instance=inst,
              traffic=TrafficSpec(horizon_s=1800.0, window_s=300.0,
                                  rate_scale=0.01, seed=4),
              controller=ControllerSpec(mode="static"), faults=sched)
    assert any(e.cause == "fault" for e in r.replans)


def test_serve_result_json_roundtrip(default_plan):
    inst, sol = default_plan
    r = serve(sol, instance=inst,
              traffic=TrafficSpec(horizon_s=600.0, window_s=300.0,
                                  rate_scale=0.02, seed=6),
              controller=ControllerSpec(mode="static"))
    r2 = ServeResult.from_json(r.to_json())
    assert r2.to_json(sort_keys=True) == r.to_json(sort_keys=True)
    assert r2.summary() == r.summary()
    # nan round-trips as null and back
    assert json.loads(r.to_json())["per_type_ttft_p50"] is not None


def test_serve_importable_without_jax():
    """`from repro import serve` must work when jax cannot be imported —
    the serving driver and types are numpy/stdlib only."""
    code = (
        "import sys; sys.modules['jax'] = None; "
        "from repro import serve, ServeResult, TrafficSpec, ControllerSpec;"
        " print('ok')"
    )
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "ok"
