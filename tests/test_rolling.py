"""Rolling-horizon replay: window-cost pricing, keep-best, fast path.

Headline regression: the seed's `_window_cost` hardcoded the T=288 window
fraction (24.0/288.0), so any replay with n_windows != 288 mispriced the
operation cost.  With `window_h` threaded through, the total replay cost of
a given demand profile is invariant to how finely the day is windowed —
`test_window_pricing_invariant_to_window_count` fails on the seed code
(which would price the 96-window day at a third of the 288-window day).
"""
import numpy as np
import pytest

from repro.core import Solution, default_instance, gh, rolling
from repro.core import replay_study
from repro.core._scalar_ref import stage2_lp_ref
from repro.core.rolling import STRICT_CAP, _ewma_forecasts
from repro.core.solution import provisioning_cost
from repro.core.stage2 import stage2_cost


@pytest.fixture(scope="module")
def inst():
    return default_instance()


@pytest.fixture(scope="module")
def plan(inst):
    return gh(inst)


def test_window_pricing_invariant_to_window_count(inst, plan):
    """T=96 vs T=288 consistency (acceptance): same constant demand day,
    same deployment => same total cost, windows just slice it finer."""
    totals = {}
    for T in (96, 288):
        path = np.tile(inst.lam, (T, 1))
        r = rolling(inst, path, lambda i, p=plan: p, replan_every=None)
        assert r.per_window_cost.shape == (T,)
        totals[T] = r.total_cost
    assert totals[96] == pytest.approx(totals[288], rel=1e-9)
    # Per-window cost scales with the window length instead.
    assert totals[96] / 96 == pytest.approx(totals[288] / 288 * 3, rel=1e-9)


def test_window_pricing_matches_seed_at_288(inst, plan):
    """At T=288 the parameterized window_h reproduces the seed's 24/288
    pricing exactly: rental share + stage2_cost * window_h per window."""
    T = 288
    path = np.tile(inst.lam, (T, 1))
    r = rolling(inst, path, lambda i, p=plan: p, replan_every=None)
    cap = np.full(inst.I, STRICT_CAP)
    sol, _ = stage2_lp_ref(inst, plan, u_cap=cap)
    want = (provisioning_cost(inst, plan) / inst.Delta_T * (24.0 / 288.0)
            + stage2_cost(inst, sol) * (24.0 / 288.0))
    assert r.per_window_cost[0] == pytest.approx(want, rel=1e-9)
    assert r.total_cost == pytest.approx(T * want, rel=1e-9)


def test_rolling_batched_matches_window_loop(inst):
    """Segment-batched fast path == per-window stage2_lp loop, including
    across replan boundaries."""
    rng = np.random.default_rng(0)
    mult = 1.0 + 0.5 * np.sin(np.linspace(0, 2 * np.pi, 18)) \
        + rng.uniform(-0.05, 0.05, 18)
    path = np.outer(mult, inst.lam)
    planner = lambda i: gh(i)
    rb = rolling(inst, path, planner, replan_every=6)
    rl = rolling(inst, path, planner, replan_every=6, batched=False)
    assert rb.replans == rl.replans
    assert rb.violation_rate == rl.violation_rate
    assert np.allclose(rb.per_window_cost, rl.per_window_cost,
                       rtol=1e-9, atol=1e-9)


def test_keep_best_rejects_worse_candidate(inst, plan):
    """A candidate that scores worse on the current forecast is never
    adopted: the dead-state bug would have made this vacuous."""
    calls = {"n": 0}

    def planner(i):
        calls["n"] += 1
        if calls["n"] == 1:
            return plan
        return Solution.empty(i)      # objective: everything unmet — awful

    path = np.tile(inst.lam, (12, 1))
    r = rolling(inst, path, planner, replan_every=4)
    assert calls["n"] > 1             # candidates were generated...
    assert r.replans == 0             # ...and every one rejected
    r_static = rolling(inst, path, lambda i, p=plan: p, replan_every=None)
    assert r.total_cost == pytest.approx(r_static.total_cost, rel=1e-9)


def test_keep_best_adopts_better_candidate(inst):
    """Starting from an empty deployment, the first GH candidate must win
    the keep-best comparison and be used for subsequent windows."""
    calls = {"n": 0}

    def planner(i):
        calls["n"] += 1
        if calls["n"] == 1:
            return Solution.empty(i)
        return gh(i)

    path = np.tile(inst.lam, (8, 1))
    r = rolling(inst, path, planner, replan_every=4)
    assert r.replans >= 1
    r_bad = rolling(inst, path, lambda i: Solution.empty(i),
                    replan_every=None)
    assert r.total_cost < r_bad.total_cost


def test_ewma_forecasts_recursion():
    path = np.array([[1.0], [2.0], [4.0]])
    fc = _ewma_forecasts(path, 0.5)
    # seeded at lam[0]: fc0 = .5*1+.5*1 = 1; fc1 = .5*2+.5*1 = 1.5; ...
    assert np.allclose(fc[:, 0], [1.0, 1.5, 2.75])


def test_replay_study_multi_day_and_stress(inst, plan):
    planner = lambda i, p=plan: p
    r = replay_study(inst, planner, days=("busy", "volatile"), n_windows=12)
    assert r.per_window_cost.shape == (24,)
    assert np.isfinite(r.total_cost)
    r_s = replay_study(inst, planner, days=("busy",), n_windows=12,
                       stress=1.5)
    assert np.isfinite(r_s.total_cost)
    # 1.5x delay/error inflation can only make operation costlier.
    r_b = replay_study(inst, planner, days=("busy",), n_windows=12)
    assert r_s.total_cost >= r_b.total_cost - 1e-9


def test_multi_day_window_h_spans_days(inst, plan):
    """Two concatenated days keep the per-day window length: the replay is
    48 h long, so its rental share alone must total ~2 provisioning days."""
    planner = lambda i, p=plan: p
    one = replay_study(inst, planner, days=("busy",), n_windows=12, seed=3)
    two = replay_study(inst, planner, days=("busy", "busy"), n_windows=12,
                       seed=3)
    assert two.per_window_cost.shape[0] == 2 * one.per_window_cost.shape[0]
    # First day of the two-day replay is the same series (same seed).
    assert np.allclose(two.per_window_cost[:12], one.per_window_cost)


def test_rolling_lp_reuse_bit_identical(inst):
    """The affine-in-lambda re-solve skip (lp_reuse, on by default) must
    be bit-identical to always-solve: certified windows are priced from
    the representative vertex only when the per-window dual/primal
    certificate proves the basis optimal there, so costs AND violation
    counts match exactly — on flat demand (all windows certified),
    diurnal demand (partial certification), and across replans."""
    rng = np.random.default_rng(3)
    mult = (1.0 + 0.4 * np.sin(np.linspace(0, 2 * np.pi, 24))
            + rng.uniform(-0.05, 0.05, 24))
    paths = {
        "constant": np.tile(inst.lam, (12, 1)),
        "diurnal": np.outer(mult, inst.lam),
    }
    planner = lambda i: gh(i)
    for name, path in paths.items():
        for replan in (None, 6):
            a = rolling(inst, path, planner, replan_every=replan,
                        lp_reuse=True)
            b = rolling(inst, path, planner, replan_every=replan,
                        lp_reuse=False)
            assert np.array_equal(a.per_window_cost, b.per_window_cost), \
                (name, replan)
            assert a.violation_rate == b.violation_rate, (name, replan)
            assert a.total_cost == b.total_cost, (name, replan)
            assert a.replans == b.replans, (name, replan)
