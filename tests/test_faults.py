"""Supply-side fault injection + warm repair (core/faults.py,
core/agh.py::agh_repair, planner/session.py::PlanSession.repair).

Covers the schedule algebra (composition, Recovery clipping, change
points), the `apply_faults` instance transform, the seeded generators'
determinism, eviction correctness, the allocator's availability-cap
guards, the repair protocol (feasible or an explicit degradation report
— never silently infeasible), the repair-vs-cold dominance on a faulted
replay, and the spot-fleet / multi-region scenario specs that feed
failure replays.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import (CapacityShock, FaultSchedule, PriceSpike, Recovery,
                        SpotRevocation, TierOutage, agh, agh_repair,
                        apply_faults, default_instance, diurnal_outages,
                        evict_unavailable, is_feasible, lost_pairs,
                        poisson_revocations, random_instance, rolling,
                        with_spot_tiers)
from repro.planner import PlanOptions, PlanSession


def _binding(inst, zeta: float = 0.1):
    """Copy with a binding unmet cap so shedding demand is a violation."""
    return dataclasses.replace(inst, zeta=np.full(inst.I, zeta))


# ------------------------------------------------------ schedule algebra

def test_schedule_composition_min_avail_product_price():
    K = 4
    sched = FaultSchedule(n_windows=10, events=(
        TierOutage(tier=1, t0=2, t1=5),
        CapacityShock(t0=3, t1=7, avail_frac=0.5),
        SpotRevocation(tier=2, t0=3, t1=6, frac=0.8),
        PriceSpike(tier=0, t0=1, t1=9, mult=3.0),
        PriceSpike(tier=0, t0=2, t1=4, mult=2.0),
    ))
    assert not sched.is_empty
    # t=3: outage (tier 1 -> 0), shock (everything x0.5), revocation
    # (tier 2 keeps min(0.5, 1-0.8)); price spikes multiply on tier 0.
    af = sched.avail_frac(3, K)
    assert af[1] == 0.0
    assert af[0] == af[3] == 0.5
    assert np.isclose(af[2], min(0.5, 0.2))
    pm = sched.price_mult(3, K)
    assert np.isclose(pm[0], 6.0)
    assert np.all(pm[1:] == 1.0)
    # outside every window: identity
    assert np.all(sched.avail_frac(0, K) == 1.0)
    assert np.all(sched.price_mult(0, K) == 1.0)


def test_recovery_clips_matching_events():
    sched = FaultSchedule(n_windows=10, events=(
        TierOutage(tier=1, t0=2, t1=8),
        TierOutage(tier=2, t0=2, t1=8),
    ), )
    clipped = FaultSchedule(n_windows=10,
                            events=sched.events + (Recovery(t=5, tier=1),))
    assert clipped.avail_frac(6, 3)[1] == 1.0      # tier 1 recovered early
    assert clipped.avail_frac(6, 3)[2] == 0.0      # tier 2 still down
    everyone = FaultSchedule(n_windows=10,
                             events=sched.events + (Recovery(t=5),))
    assert np.all(everyone.avail_frac(6, 3) == 1.0)


def test_change_points_cover_every_state_transition():
    K = 3
    sched = FaultSchedule(n_windows=12, events=(
        TierOutage(tier=0, t0=3, t1=6),
        PriceSpike(tier=1, t0=6, t1=9, mult=2.0),
    ))
    pts = sorted(sched.change_points(K))
    assert pts == [3, 6, 9]
    for t in range(1, 12):
        same = (np.array_equal(sched.avail_frac(t, K),
                               sched.avail_frac(t - 1, K))
                and np.array_equal(sched.price_mult(t, K),
                                   sched.price_mult(t - 1, K)))
        assert same == (t not in pts)
    # state_key is injective over the distinct states of this schedule:
    # nominal (the trailing windows re-coincide with it), outage, spike
    keys = {sched.state_key(t, K) for t in range(12)}
    assert len(keys) == 3


# --------------------------------------------------------- apply_faults

def test_apply_faults_identity_fast_path():
    inst = default_instance()
    sched = FaultSchedule(n_windows=8,
                          events=(TierOutage(tier=0, t0=4, t1=6),))
    assert apply_faults(inst, sched, 1) is inst       # nothing active
    assert apply_faults(inst, FaultSchedule(8, ()), 5) is inst


def test_apply_faults_outage_kills_tier_and_spike_scales_price():
    inst = default_instance()
    sched = FaultSchedule(n_windows=8, events=(
        TierOutage(tier=2, t0=1, t1=5),
        PriceSpike(tier=3, t0=1, t1=5, mult=2.5),
    ))
    f = apply_faults(inst, sched, 2)
    assert f.avail_gpus is not None and f.avail_gpus[2] == 0.0
    assert np.isclose(f.p_c[3], inst.p_c[3] * 2.5)
    # a dead tier admits no (j, k) deployment at all
    assert not np.any(f.mem_ok[:, 2, :])
    # other tiers stay unbounded and unpriced
    assert np.isinf(f.avail_gpus[0])
    assert np.isclose(f.p_c[0], inst.p_c[0])


def test_apply_faults_scales_nominal_caps():
    inst = dataclasses.replace(default_instance(),
                               avail_gpus=np.full(10, 8.0))
    sched = FaultSchedule(n_windows=4,
                          events=(CapacityShock(t0=0, t1=4,
                                                avail_frac=0.49),))
    f = apply_faults(inst, sched, 1)
    assert np.all(f.avail_gpus == np.floor(8.0 * 0.49))


# ----------------------------------------------------- seeded generators

def test_generators_are_deterministic():
    inst = with_spot_tiers(default_instance(), np.arange(10),
                           revoke_rate=0.4)
    a = poisson_revocations(inst, 48, seed=5)
    b = poisson_revocations(inst, 48, seed=5)
    assert a == b and len(a) > 0
    assert a != poisson_revocations(inst, 48, seed=6)
    # no spot tiers -> no events
    assert poisson_revocations(default_instance(), 48, seed=5) == []
    da = diurnal_outages(default_instance(), 48, n_events=4, seed=2)
    assert da == diurnal_outages(default_instance(), 48, n_events=4, seed=2)
    assert len(da) == 4
    for ev in da:
        assert 0 <= ev.t0 < 48


def test_with_spot_tiers_discounts_and_marks():
    inst = default_instance()
    spot = with_spot_tiers(inst, np.array([1, 3]), discount=0.7,
                           revoke_rate=0.3)
    assert np.isclose(spot.p_c[1], inst.p_c[1] * 0.7)
    assert np.isclose(spot.p_c[0], inst.p_c[0])
    assert list(np.flatnonzero(spot.spot)) == [1, 3]
    assert spot.revoke_rate[3] == 0.3 and spot.revoke_rate[0] == 0.0


# ------------------------------------------------------------- eviction

def test_lost_pairs_evicts_smallest_first_until_under_cap():
    inst = default_instance()
    sol = agh(inst)
    used = sol.y.sum(axis=0)
    k = int(np.argmax(used))
    # cap the busiest tier to force exactly the smallest deployment out
    jj = np.flatnonzero(sol.y[:, k] > 0)
    smallest = jj[np.argmin(sol.y[jj, k])]
    cap = np.full(inst.K, np.inf)
    cap[k] = used[k] - sol.y[smallest, k]
    capped = dataclasses.replace(inst, avail_gpus=cap)
    lost = lost_pairs(capped, sol.y)
    assert (int(smallest), k) in lost
    y_after = sol.y.copy()
    for (j, kk) in lost:
        y_after[j, kk] = 0.0
    assert np.all(y_after.sum(axis=0) <= cap + 1e-9)


def test_evict_unavailable_preserves_demand_identity():
    inst = default_instance()
    sol = agh(inst)
    k = int(np.argmax(sol.y.sum(axis=0)))
    dead = dataclasses.replace(
        inst, avail_gpus=np.where(np.arange(inst.K) == k, 0.0, np.inf))
    op, lost = evict_unavailable(dead, sol)
    assert lost and all(kk == k for (_, kk) in lost)
    assert np.all(op.y[:, k] == 0) and not np.any(op.x[:, :, k] > 0)
    assert np.allclose(op.x.sum(axis=(1, 2)) + op.u, 1.0)
    # untouched pairs keep their routing
    keep = np.ones(inst.K, bool)
    keep[k] = False
    assert np.array_equal(op.y[:, keep], sol.y[:, keep])


# ------------------------------------- allocator availability-cap guards

def test_agh_respects_availability_caps():
    inst = random_instance(8, 8, 6, seed=1)
    ref = agh(inst)
    caps = np.maximum(np.ceil(ref.y.sum(axis=0) * 0.6), 1.0)
    capped = dataclasses.replace(inst, avail_gpus=caps)
    sol = agh(capped)
    assert np.all(sol.y.sum(axis=0) <= caps + 1e-9)
    assert is_feasible(capped, sol, enforce_zeta=False)
    # the uncapped solve is bit-identical to the pre-fault engine path
    again = agh(inst)
    assert np.array_equal(ref.x, again.x) and np.array_equal(ref.y, again.y)


def test_agh_repair_feasible_and_subsumes_eviction():
    inst = default_instance()
    base = agh(inst)
    k = int(np.argmax(base.y.sum(axis=0)))
    faulted = dataclasses.replace(
        inst, avail_gpus=np.where(np.arange(inst.K) == k, 0.0, np.inf))
    stats: dict = {}
    rep = agh_repair(faulted, base, stats=stats)
    assert rep.method == "AGH-repair"
    assert stats["repair"] and len(stats["evicted"]) > 0
    assert all(kk == k for (_, kk) in stats["evicted"])
    assert is_feasible(faulted, rep, enforce_zeta=False)
    assert np.all(rep.y[:, k] == 0)


# ---------------------------------------------- PlanSession.repair ladder

def test_repair_survivable_fault_is_feasible_level0():
    sess = PlanSession()
    inst = _binding(default_instance(), zeta=0.9)
    sess.plan(instance=inst)
    k = int(np.argmax(sess.incumbent.y.sum(axis=0)))
    sched = FaultSchedule(n_windows=6,
                          events=(TierOutage(tier=k, t0=1, t1=5),))
    res = sess.repair(schedule=sched, t=2)
    rep = res.diagnostics["repair"]
    assert rep["warm"] is True and rep["evicted"]
    assert res.feasible and rep["degradation"]["level"] == 0
    assert sess.repairs == 1
    # the repaired plan became the session incumbent
    assert sess.incumbent is res.solution


def test_repair_catastrophe_reports_degradation_never_silent():
    inst = _binding(default_instance())
    sess = PlanSession()
    sess.plan(instance=inst)
    sched = FaultSchedule(n_windows=4, events=tuple(
        TierOutage(tier=k, t0=0, t1=4) for k in range(inst.K)))
    res = sess.repair(schedule=sched, t=1)
    deg = res.diagnostics["repair"]["degradation"]
    assert not res.feasible
    assert deg["level"] >= 1
    assert deg["violations"]                       # non-empty report
    assert deg["ladder"][0] == "strict"
    assert deg["zeta_overshoot"] > 0
    # deterministic: same session history, same fault -> same report
    sess2 = PlanSession()
    sess2.plan(instance=inst)
    res2 = sess2.repair(schedule=sched, t=1)
    assert res2.diagnostics["repair"]["degradation"]["level"] == deg["level"]
    assert np.isclose(res2.objective, res.objective)


def test_repair_without_incumbent_falls_back_cold():
    sess = PlanSession()
    res = sess.repair(instance=default_instance())
    rep = res.diagnostics["repair"]
    assert rep["warm"] is False and rep["evicted"] == []
    assert res.feasible and rep["degradation"]["level"] == 0


def test_repair_requires_some_instance():
    with pytest.raises(ValueError):
        PlanSession().repair()


# --------------------------------------------- faulted replay dominance

def test_faulted_replay_repair_dominates_static_and_matches_cold():
    """The acceptance ordering on a small replay: the frozen static
    placement degrades visibly; warm repair keeps the violation rate no
    worse than the cold re-solve response."""
    inst = _binding(default_instance(), zeta=0.5)
    spot = with_spot_tiers(inst, np.arange(inst.K), revoke_rate=0.3)
    T = 12
    evs = poisson_revocations(spot, T, seed=3)
    base = agh(inst)
    busiest = int(np.argmax(base.y.sum(axis=0)))
    sched = FaultSchedule(T, tuple(evs) + (
        TierOutage(tier=busiest, t0=4, t1=8),))
    assert sorted(sched.change_points(inst.K))
    rng = np.random.default_rng(0)
    lam_path = np.clip(
        inst.lam[None, :] * (1.0 + 0.1 * rng.standard_normal((T, inst.I))),
        0.0, None)
    opts = PlanOptions(workers=0)

    def bare(inst):
        from repro.planner import plan
        return plan("agh", instance=inst, options=opts).solution

    results = {}
    for mode in ("repair", "cold", "static"):
        planner = PlanSession(options=opts) if mode == "repair" else bare
        results[mode] = rolling(
            spot, lam_path, planner,
            replan_every=(None if mode == "static" else 4),
            faults=sched, fault_response=mode)
    assert results["repair"].fault_replans > 0
    assert results["repair"].evictions > 0
    assert all(w < 1.0 for w in results["repair"].repair_wall_s)
    assert (results["static"].violation_rate
            >= results["repair"].violation_rate - 1e-9)
    assert (results["repair"].violation_rate
            <= results["cold"].violation_rate + 1e-9)
    # fault-free replay is untouched by the new kwargs (identity default)
    r_empty = rolling(spot, lam_path, PlanSession(options=opts),
                      replan_every=4, faults=FaultSchedule(T, ()))
    r_none = rolling(spot, lam_path, PlanSession(options=opts),
                     replan_every=4)
    assert np.allclose(r_empty.per_window_cost, r_none.per_window_cost)


# ------------------------------------------------------- scenario specs

def test_spot_fleet_scenario_builds_and_schedules():
    from repro.planner.specs import scenario
    spec = scenario("spot-fleet", n_windows=24)
    inst = spec.build()
    assert inst.spot is not None and inst.spot.any()
    # exactly the INT-quantized tiers ride the spot pool, discounted
    for k, name in enumerate(inst.tier_names):
        assert inst.spot[k] == ("INT" in str(name).upper())
    base = scenario("paper-default").build()
    assert np.allclose(inst.p_c[inst.spot], base.p_c[inst.spot] * 0.8)
    assert np.allclose(inst.p_c[~inst.spot], base.p_c[~inst.spot])
    fs = spec.fault_schedule(inst)
    assert not fs.is_empty and fs == spec.fault_schedule(inst)
    sol = agh(inst)
    assert is_feasible(inst, sol, enforce_zeta=False)


def test_multi_region_scenario_carbon_prices_rental():
    from repro.planner.specs import REGION_INTENSITY, scenario
    spec = scenario("multi-region")
    inst = spec.build()
    base = scenario("paper-default").build()
    # carbon pricing strictly raises every rental rate, and dirtier
    # regions pay more per kW than cleaner ones
    assert np.all(inst.p_c > base.p_c)
    placed = spec.fleet.region_of(base)
    assert set(placed) == set(REGION_INTENSITY)
    # no spot tiers -> the matching fault schedule is empty
    assert spec.fault_schedule(inst, n_windows=12).is_empty
    sol = agh(inst)
    assert is_feasible(inst, sol, enforce_zeta=False)


# ------------------------------------------------------- lint coverage

def test_faults_module_is_lint_clean_and_in_determinism_scope():
    """faults.py must stay inside the determinism rule scope (RPR2xx):
    the shipped file lints clean, and the same path with a stdlib-random
    call injected trips the rule — proving the scope actually covers it
    rather than silently excluding it."""
    from pathlib import Path

    from repro.analysis.lint import lint_file, lint_source
    path = (Path(__file__).resolve().parent.parent
            / "src" / "repro" / "core" / "faults.py")
    report = lint_file(path)
    assert [d.rule for d in report.diagnostics] == []
    doctored = path.read_text() + "\n\ndef _bad():\n    import random\n" \
        "    return random.random()\n"
    got = [d.rule for d in lint_source(
        doctored, display=str(path), posix=path.as_posix(), path=path)
        .diagnostics]
    assert "RPR202" in got


# ------------------------------------------------- property: never silent

# Guarded import so only the property test skips when hypothesis is
# missing — a module-level importorskip would silently skip this whole
# suite (same pattern as tests/test_engine_xla.py).
try:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def faulted_repairs(draw):
        I = draw(st.integers(2, 5))
        J = draw(st.integers(2, 4))
        K = draw(st.integers(2, 5))
        inst = random_instance(I, J, K, seed=draw(st.integers(0, 5_000)))
        inst = _binding(inst, zeta=draw(st.floats(0.05, 0.6)))
        T = 8
        n_down = draw(st.integers(1, K))
        tiers = draw(st.permutations(list(range(K))))[:n_down]
        events = tuple(TierOutage(tier=k, t0=1, t1=T) for k in tiers)
        if draw(st.booleans()):
            events += (CapacityShock(
                t0=1, t1=T, avail_frac=draw(st.floats(0.0, 0.8))),)
        return inst, FaultSchedule(n_windows=T, events=events)

    @settings(max_examples=20, deadline=None)
    @given(faulted_repairs())
    def test_repair_feasible_or_explicit_degradation(case):
        """THE robustness contract: for ANY instance and ANY supply-fault
        state, `PlanSession.repair` either returns a feasible plan or an
        explicit degradation report (level >= 1, non-empty violation
        families) — an infeasible repair is never silent."""
        inst, sched = case
        sess = PlanSession()
        sess.plan(instance=inst)
        res = sess.repair(schedule=sched, t=2)
        deg = res.diagnostics["repair"]["degradation"]
        if res.feasible:
            assert deg["level"] == 0
        else:
            assert deg["level"] >= 1, deg
            assert deg["violations"], deg
            assert deg["ladder"] and deg["ladder"][0] == "strict"
        # whatever the outcome, the result is installed as incumbent and
        # hard-feasibility of the SOLUTION tensors still holds
        assert sess.incumbent is res.solution
        assert np.allclose(
            res.solution.x.sum(axis=(1, 2)) + res.solution.u, 1.0)
except ImportError:          # pragma: no cover - CI always has hypothesis
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_repair_feasible_or_explicit_degradation():
        pass
